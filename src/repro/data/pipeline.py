"""Deterministic synthetic LM data pipeline.

Produces Zipf-mixture token streams packed to (batch, seq+1); fully seeded so
restart-resume tests are bit-exact. Host-sharded placement onto the mesh's dp
axes via ``jax.make_array_from_callback`` (each host materializes only its
shard — the 1000-node-ready path)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


class TokenStream:
    """Stateless per-step batch generator: batch(step) is pure in (seed, step)."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0, zipf_a: float = 1.3):
        self.vocab, self.batch, self.seq, self.seed, self.zipf_a = vocab, batch, seq, seed, zipf_a

    def batch_np(self, step: int) -> np.ndarray:
        # entropy tuple, not seed arithmetic: (seed << 20) ^ step aliased
        # streams whenever step spilled past 20 bits
        rng = np.random.default_rng(np.random.SeedSequence((self.seed, step)))
        # zipf over a permuted vocab + short repeated motifs (compressible)
        raw = rng.zipf(self.zipf_a, size=(self.batch, self.seq + 1)).astype(np.int64)
        toks = (raw - 1) % self.vocab
        # inject motif repetitions so the LM has learnable structure
        motif = rng.integers(0, self.vocab, size=16)
        pos = rng.integers(0, self.seq - 16, size=self.batch)
        for i, p in enumerate(pos):
            if rng.random() < 0.5:
                toks[i, p : p + 16] = motif
        return toks.astype(np.int32)

    def batch_sharded(self, step: int, mesh, dp_axes) -> jax.Array:
        spec = P(tuple(dp_axes) if len(dp_axes) > 1 else dp_axes[0], None)
        sharding = NamedSharding(mesh, spec)
        full_shape = (self.batch, self.seq + 1)

        def cb(index):
            # materialize only the requested shard
            full = self.batch_np(step)
            return full[index]

        return jax.make_array_from_callback(full_shape, sharding, cb)


def make_batch(cfg, stream: TokenStream, step: int, mesh=None, dp_axes=("data",)):
    toks = stream.batch_np(step) if mesh is None else stream.batch_sharded(step, mesh, dp_axes)
    batch = {"tokens": jnp.asarray(toks) if mesh is None else toks}
    if cfg.encoder_layers:
        rng = np.random.default_rng(
            np.random.SeedSequence((stream.seed, step, 1)))
        batch["frames"] = jnp.asarray(
            rng.normal(size=(stream.batch, stream.seq, cfg.d_model)), jnp.dtype(cfg.dtype))
    elif cfg.n_patches:
        rng = np.random.default_rng(
            np.random.SeedSequence((stream.seed, step, 2)))
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(stream.batch, cfg.n_patches, cfg.d_model)), jnp.dtype(cfg.dtype))
    return batch
