"""Mamba2 (SSD — state-space duality) block: chunked training/prefill scan
and O(1) single-token decode. Faithful to Dao & Gu 2024 at the block level
(zxbcdt projection, causal depthwise conv, scalar-decay SSD, gated RMSNorm);
the chunked algorithm maps the recurrence onto MXU-friendly per-chunk
matmuls with a `lax.scan` carrying the (heads, head_dim, d_state) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_linear, rms_norm


def dims(cfg: ModelConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nh = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.n_groups * s.d_state
    zxbcdt = 2 * d_inner + 2 * s.n_groups * s.d_state + nh
    return d_inner, nh, conv_dim, zxbcdt


def init_mamba2(key, cfg: ModelConfig, dtype):
    s = cfg.ssm
    d_inner, nh, conv_dim, zxbcdt = dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        "in_proj": init_linear(ks[0], cfg.d_model, zxbcdt, dtype),
        "conv_w": jax.random.normal(ks[1], (conv_dim, s.conv_kernel), jnp.float32).astype(dtype) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), dtype),
        "out_proj": init_linear(ks[2], d_inner, cfg.d_model, dtype),
    }


def _causal_conv(x, w, b, state=None):
    """x: (b, s, c); w: (c, K) depthwise causal. state: (b, K-1, c) history."""
    K = w.shape[1]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[:, i] for i in range(K))
    new_state = xp[:, -(K - 1) :, :]
    return jax.nn.silu(out + b), new_state


def _split_zxbcdt(cfg, zx):
    s = cfg.ssm
    d_inner, nh, conv_dim, _ = dims(cfg)
    gs = s.n_groups * s.d_state
    z = zx[..., :d_inner]
    xBC = zx[..., d_inner : d_inner + conv_dim]
    dt = zx[..., d_inner + conv_dim :]
    return z, xBC, dt


def ssd_chunked(xh, dt, A, B, C, chunk: int, h0=None):
    """Chunked SSD scan.
    xh: (b,s,nh,hp); dt: (b,s,nh) (post-softplus); A: (nh,) negative;
    B, C: (b,s,g,ds). Returns (y, h_last) with y: (b,s,nh,hp),
    h_last: (b,nh,hp,ds)."""
    b, s, nh, hp = xh.shape
    g, ds = B.shape[2], B.shape[3]
    h_per_g = nh // g
    Q = chunk
    pad = (-s) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    T = xh.shape[1] // Q

    def resh(t, shape):
        return t.reshape(b, T, Q, *shape).swapaxes(0, 1)  # (T, b, Q, ...)

    xh_c, dt_c = resh(xh, (nh, hp)), resh(dt, (nh,))
    B_c, C_c = resh(B, (g, ds)), resh(C, (g, ds))
    if h0 is None:
        h0 = jnp.zeros((b, nh, hp, ds), jnp.float32)

    def body(h, inp):
        xq, dtq, Bq, Cq = inp                      # (b,Q,nh,hp), (b,Q,nh), (b,Q,g,ds)
        dA = dtq * A[None, None, :]                # (b,Q,nh) negative increments
        cum = jnp.cumsum(dA, axis=1)               # (b,Q,nh)
        # intra-chunk: decay(i>=j) = exp(cum_i - cum_j)
        rel = cum[:, :, None, :] - cum[:, None, :, :]          # (b,Q,Q,nh)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        L = jnp.where(tri[None, :, :, None], jnp.exp(rel), 0.0)  # (b,Q,Q,nh)
        G = jnp.einsum("bqgn,bkgn->bqkg", Cq.astype(jnp.float32), Bq.astype(jnp.float32))
        Lh = L.reshape(b, Q, Q, g, h_per_g)
        M = G[..., None] * Lh                                   # (b,Q,Q,g,hpg)
        xdt = (xq.astype(jnp.float32) * dtq[..., None]).reshape(b, Q, g, h_per_g, hp)
        y_intra = jnp.einsum("bqkgh,bkghp->bqghp", M, xdt)
        # inter-chunk: contribution of carried state
        Cg = Cq.astype(jnp.float32)
        y_inter = jnp.einsum("bqgn,bghpn->bqghp", Cg, h.reshape(b, g, h_per_g, hp, ds))
        y_inter = y_inter * jnp.exp(cum).reshape(b, Q, g, h_per_g)[..., None]
        y = (y_intra + y_inter).reshape(b, Q, nh, hp)
        # state update
        decay_to_end = jnp.exp(cum[:, -1:, :] - cum)            # (b,Q,nh)
        w = xdt * decay_to_end.reshape(b, Q, g, h_per_g)[..., None]
        h_new = h * jnp.exp(cum[:, -1, :])[:, :, None, None] + jnp.einsum(
            "bkgn,bkghp->bghpn", Bq.astype(jnp.float32), w
        ).reshape(b, nh, hp, ds)
        return h_new, y

    h_last, ys = jax.lax.scan(body, h0, (xh_c, dt_c, B_c, C_c))
    y = ys.swapaxes(0, 1).reshape(b, T * Q, nh, hp)[:, :s]
    return y, h_last


def mamba2_full(p, cfg: ModelConfig, x, conv_state=None, h0=None):
    """Full-sequence Mamba2 block. Returns (out, cache)."""
    s_cfg = cfg.ssm
    d_inner, nh, conv_dim, _ = dims(cfg)
    zx = jnp.einsum("bsd,dz->bsz", x, p["in_proj"])
    z, xBC, dt = _split_zxbcdt(cfg, zx)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    xs = xBC[..., :d_inner]
    gs = s_cfg.n_groups * s_cfg.d_state
    B = xBC[..., d_inner : d_inner + gs].reshape(*x.shape[:2], s_cfg.n_groups, s_cfg.d_state)
    C = xBC[..., d_inner + gs :].reshape(*x.shape[:2], s_cfg.n_groups, s_cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(*x.shape[:2], nh, s_cfg.head_dim)
    y, h_last = ssd_chunked(xh, dt, A, B, C, s_cfg.chunk, h0=h0)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, {"state": h_last, "conv": conv_state}


def mamba2_decode(p, cfg: ModelConfig, x, cache):
    """Single-token recurrent update. x: (b,1,d)."""
    s_cfg = cfg.ssm
    d_inner, nh, conv_dim, _ = dims(cfg)
    zx = jnp.einsum("bsd,dz->bsz", x, p["in_proj"])
    z, xBC, dt = _split_zxbcdt(cfg, zx)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], cache["conv"])
    xs = xBC[..., :d_inner]
    gs = s_cfg.n_groups * s_cfg.d_state
    B = xBC[..., d_inner : d_inner + gs].reshape(x.shape[0], 1, s_cfg.n_groups, s_cfg.d_state)
    C = xBC[..., d_inner + gs :].reshape(x.shape[0], 1, s_cfg.n_groups, s_cfg.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]     # (b,nh)
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(x.shape[0], nh, s_cfg.head_dim).astype(jnp.float32)   # (b,nh,hp)
    h = cache["state"]                                                     # (b,nh,hp,ds)
    h_per_g = nh // s_cfg.n_groups
    decay = jnp.exp(dt * A[None, :])                                       # (b,nh)
    Bb = B[:, 0].astype(jnp.float32)                                       # (b,g,ds)
    Cb = C[:, 0].astype(jnp.float32)
    Bh = jnp.repeat(Bb, h_per_g, axis=1)                                   # (b,nh,ds)
    Ch = jnp.repeat(Cb, h_per_g, axis=1)
    h_new = h * decay[:, :, None, None] + (dt[:, :, None] * xh)[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Ch) + p["D"][None, :, None] * xh
    y = y.reshape(x.shape[0], 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return out, {"state": h_new, "conv": conv_state}
