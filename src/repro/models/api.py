"""Uniform model API: family dispatch + abstract input specs for every
(architecture × shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every step
input (the dry-run lowers against these; nothing is allocated).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer


@dataclass(frozen=True)
class ModelAPI:
    init_params: Callable
    forward: Callable        # (params, cfg, batch) -> (logits, aux)
    hidden: Callable         # (params, cfg, batch) -> (pre-norm hidden, aux)
    prefill: Callable
    decode_step: Callable
    init_cache: Callable


def get_api(cfg: ModelConfig) -> ModelAPI:
    if cfg.encoder_layers:
        return ModelAPI(
            init_params=encdec.init_params,
            forward=lambda p, c, batch: encdec.forward(p, c, batch["frames"], batch["tokens"])[:2],
            hidden=lambda p, c, batch: encdec.forward(
                p, c, batch["frames"], batch["tokens"], return_hidden=True)[:2],
            prefill=lambda p, c, batch, cache_len=None: encdec.prefill(p, c, batch["frames"], batch["tokens"], cache_len),
            decode_step=encdec.decode_step,
            init_cache=lambda c, b, s: encdec.init_cache(c, b, s, s),
        )
    return ModelAPI(
        init_params=transformer.init_params,
        forward=lambda p, c, batch: transformer.forward(p, c, batch["tokens"], embeds=batch.get("embeds"))[:2],
        hidden=lambda p, c, batch: transformer.forward(
            p, c, batch["tokens"], embeds=batch.get("embeds"), return_hidden=True)[:2],
        prefill=lambda p, c, batch, cache_len=None: transformer.prefill(
            p, c, batch["tokens"], embeds=batch.get("embeds"), cache_len=cache_len),
        decode_step=transformer.decode_step,
        init_cache=transformer.init_cache,
    )


def abstract_params(cfg: ModelConfig):
    """Parameter ShapeDtypeStructs without allocation."""
    api = get_api(cfg)
    return jax.eval_shape(lambda k: api.init_params(cfg, k), jax.random.key(0))


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract step inputs for one (arch × shape) cell.

    train:   tokens (B, S+1) — model sees [:, :-1], labels [:, 1:]
    prefill: tokens (B, S)
    decode:  token (B, 1) + cache with S filled slots + pos scalar
    Modality stubs: vlm patch embeds (B, n_patches, d) are part of S;
    encdec frames (B, S, d) feed the encoder.
    """
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "train":
        if cfg.encoder_layers:
            return {"frames": _sds((B, S, cfg.d_model), dt), "tokens": _sds((B, S + 1), jnp.int32)}
        if cfg.n_patches:
            s_text = S - cfg.n_patches
            return {"embeds": _sds((B, cfg.n_patches, cfg.d_model), dt),
                    "tokens": _sds((B, s_text + 1), jnp.int32)}
        return {"tokens": _sds((B, S + 1), jnp.int32)}
    if shape.kind == "prefill":
        if cfg.encoder_layers:
            return {"frames": _sds((B, S, cfg.d_model), dt), "tokens": _sds((B, S), jnp.int32)}
        if cfg.n_patches:
            return {"embeds": _sds((B, cfg.n_patches, cfg.d_model), dt),
                    "tokens": _sds((B, S - cfg.n_patches), jnp.int32)}
        return {"tokens": _sds((B, S), jnp.int32)}
    # decode: one new token over a cache of S entries
    api = get_api(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(cfg, B, S))
    return {"cache": cache, "token": _sds((B, 1), jnp.int32), "pos": _sds((), jnp.int32)}


def lm_loss(params, cfg: ModelConfig, batch, aux_weight: float = 0.01,
            ce_chunk_tokens: int = 32_768):
    """Next-token cross-entropy with a CHUNKED vocab projection.

    The (B, S, V) f32 logits tensor is the single largest training activation
    (151k vocab × 4k seq ≈ 27 GiB/device at our shapes), so we keep the
    backbone output (B, S, d) and scan over sequence chunks: each step
    projects one (B, C, d) slice to logits, evaluates the NLL, and is wrapped
    in jax.checkpoint so the backward pass re-projects per chunk instead of
    saving any logits. MoE aux loss folds in unchanged."""
    api = get_api(cfg)
    tokens = batch["tokens"]
    inputs = dict(batch)
    inputs["tokens"] = tokens[:, :-1]
    x, aux = api.hidden(params, cfg, inputs)
    # vlm: hidden covers [patches + text]; score text positions only
    if cfg.n_patches and not cfg.encoder_layers:
        x = x[:, cfg.n_patches:, :]
    labels = tokens[:, 1:]
    B, S = labels.shape
    from repro.models.sharding import constrain
    from repro.models.transformer import _logits

    C = max(1, min(S, ce_chunk_tokens // max(B, 1)))
    while S % C:
        C -= 1
    nC = S // C
    pad_mask = (jnp.arange(cfg.padded_vocab) < cfg.vocab) if cfg.padded_vocab != cfg.vocab else None

    @jax.checkpoint
    def chunk_nll(x_c, y_c):
        logits = _logits(params, cfg, x_c).astype(jnp.float32)
        logits = constrain(logits, ("dp", None, "model"))
        if pad_mask is not None:
            logits = jnp.where(pad_mask, logits, -1e30)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        return (logz - gold).sum()

    if nC == 1:
        total = chunk_nll(x, labels)
    else:
        xc = jnp.moveaxis(x.reshape(B, nC, C, x.shape[-1]), 1, 0)
        yc = jnp.moveaxis(labels.reshape(B, nC, C), 1, 0)
        total, _ = jax.lax.scan(
            lambda acc, args: (acc + chunk_nll(*args), None), 0.0, (xc, yc))
    loss = total / (B * S)
    return loss + aux_weight * aux
