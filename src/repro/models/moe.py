"""Mixture-of-Experts FFN with sort+gather dispatch.

Top-k routing into per-expert capacity buffers. Dispatch/combine are
gathers (O(t·k·d) bytes, zero matmul FLOPs) instead of the GShard one-hot
einsum (which costs t·s_g·k·cf·d fake FLOPs and would poison the roofline's
compute term). Expert buffers are sharded over ("model" = EP) × (dp = the
capacity dim), so per-device memory is t·k·cf·d / (EP·DP).

Ranks within an expert come from a stable argsort of the flat expert
assignments — deterministic, and identical between prefill/decode when
capacity is sufficient (serving-consistency tests rely on this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import init_linear, lowp_matmul_f32
from repro.models.sharding import constrain


def init_moe(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    mo = cfg.moe
    ks = jax.random.split(key, 7)
    p = {
        "router": init_linear(ks[0], d, mo.n_experts, jnp.float32),
        "we_gate": jax.random.normal(ks[1], (mo.n_experts, d, mo.d_expert), jnp.float32).astype(dtype) / (d ** 0.5),
        "we_up": jax.random.normal(ks[2], (mo.n_experts, d, mo.d_expert), jnp.float32).astype(dtype) / (d ** 0.5),
        "we_down": jax.random.normal(ks[3], (mo.n_experts, mo.d_expert, d), jnp.float32).astype(dtype) / (mo.d_expert ** 0.5),
    }
    if mo.n_shared:
        ds = mo.d_shared or mo.d_expert
        p["ws_gate"] = init_linear(ks[4], d, ds, dtype)
        p["ws_up"] = init_linear(ks[5], d, ds, dtype)
        p["ws_down"] = init_linear(ks[6], ds, d, dtype)
    return p


def _capacity(mo, n_tok: int) -> int:
    cap = int(mo.capacity_factor * n_tok * mo.top_k / mo.n_experts)
    cap = max(cap, mo.top_k)
    return ((cap + 511) // 512) * 512 if cap > 512 else cap  # shard-friendly


def moe_ffn(p, cfg: ModelConfig, x):
    """x: (b, s, d) -> (b, s, d).

    Dispatch is GROUPED per batch row: every sort/gather/scatter is batched
    over the (dp-sharded) group axis, so nothing materializes a global
    buffer and no cross-shard sort is needed. Expert buffers are
    (groups, e, cap, d) sharded (dp, model=EP, ·, ·)."""
    mo = cfg.moe
    b, s, d = x.shape
    k, e = mo.top_k, mo.n_experts
    xg = x                                                  # groups = batch rows
    # router fwd AND bwd in bf16 with f32 accumulation: a full-x f32 convert
    # here gets hoisted into the remat-saved residual stack (see rms_norm)
    logits = lowp_matmul_f32(xg, p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                  # (g, s, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    cap = _capacity(mo, s)
    flat_e = top_e.reshape(b, s * k)
    # rank within expert, per group (stable sort; no scatter: inverse argsort)
    order = jnp.argsort(flat_e, axis=-1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e)))(sorted_e)
    rank_sorted = jnp.arange(s * k)[None, :] - jnp.take_along_axis(starts, sorted_e, axis=-1)
    inv_order = jnp.argsort(order, axis=-1)
    rank = jnp.take_along_axis(rank_sorted, inv_order, axis=-1).astype(jnp.int32)
    keep = rank < cap
    slot = jnp.where(keep, flat_e * cap + rank, e * cap)    # (g, s*k); overflow -> sentinel

    # dispatch: slot -> source position within the group (sentinel -> zero row)
    gi = jnp.arange(b, dtype=jnp.int32)[:, None]
    src = jnp.full((b, e * cap + 1), s, jnp.int32).at[gi, slot].set(
        jnp.broadcast_to(jnp.arange(s * k, dtype=jnp.int32)[None, :] // k, (b, s * k)), mode="drop")
    xg_pad = jnp.concatenate([xg, jnp.zeros((b, 1, d), xg.dtype)], axis=1)
    expert_in = jnp.take_along_axis(xg_pad, src[:, : e * cap, None], axis=1)
    expert_in = expert_in.reshape(b, e, cap, d)
    expert_in = constrain(expert_in, ("dp", "model", None, None))

    g_ = jnp.einsum("gecd,edf->gecf", expert_in, p["we_gate"])
    u_ = jnp.einsum("gecd,edf->gecf", expert_in, p["we_up"])
    eo = jnp.einsum("gecf,efd->gecd", jax.nn.silu(g_) * u_, p["we_down"])
    eo = constrain(eo, ("dp", "model", None, None))

    # combine: each (token, k) reads its slot; dropped slots read the zero row
    eo_pad = jnp.concatenate(
        [eo.reshape(b, e * cap, d), jnp.zeros((b, 1, d), eo.dtype)], axis=1)
    gathered = jnp.take_along_axis(eo_pad, slot[:, :, None], axis=1)
    gathered = gathered.reshape(b, s, k, d)
    out = (gathered * top_p.astype(gathered.dtype)[..., None]).sum(axis=2)

    if mo.n_shared:
        gs = jnp.einsum("gsd,df->gsf", xg, p["ws_gate"])
        us = jnp.einsum("gsd,df->gsf", xg, p["ws_up"])
        out = out + jnp.einsum("gsf,fd->gsd", jax.nn.silu(gs) * us, p["ws_down"])
    aux = _load_balance_loss(probs.reshape(b * s, e), top_e.reshape(b * s, k), e)
    return out, aux


def _load_balance_loss(probs, top_e, n_experts):
    """Switch-style auxiliary load-balancing loss."""
    me = probs.mean(0)
    ce = jax.nn.one_hot(top_e[:, 0], n_experts).mean(0)
    return n_experts * jnp.sum(me * ce)
