"""Attention variants: GQA (optionally sliding-window, optionally biased),
MLA (DeepSeek-V2 latent attention), cross-attention — each with a full-
sequence path (train/prefill) and a single-token decode path over a cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig, ModelConfig
from repro.models.layers import apply_rope, init_linear


# --------------------------------------------------------------------- GQA
def init_gqa(key, cfg: ModelConfig, dtype):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_linear(ks[0], d, hq * hd, dtype),
        "wk": init_linear(ks[1], d, hkv * hd, dtype),
        "wv": init_linear(ks[2], d, hkv * hd, dtype),
        "wo": init_linear(ks[3], hq * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _sdpa(q, k, v, mask):
    """q: (b,sq,hkv,g,hd); k/v: (b,sk,hkv,hd); mask: (b|1, sq, sk)."""
    scale = 1.0 / jnp.sqrt(q.shape[-1])
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32) * scale
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out


def _causal_mask(sq, sk, q_offset, window):
    pos_q = q_offset + jnp.arange(sq)[:, None]
    pos_k = jnp.arange(sk)[None, :]
    m = pos_k <= pos_q
    if window:
        m &= pos_k > pos_q - window
    return m[None]  # (1, sq, sk)


def chunked_sdpa(q, k, v, *, causal: bool, window: int = 0, chunk: int = 1024):
    """Flash-style blocked attention in pure XLA (the TPU Pallas kernel's
    portable twin): Python loop over q chunks × ``lax.scan`` over exactly the
    kv chunks each q chunk can see (causal/SWA block pruning is STATIC), with
    an online-softmax (m, l, acc) carry.  Peak temp is one
    (b, chunk, heads, chunk) score block instead of the full (b, h, S, S)
    score matrix — this is what lets 32k×32k prefill fit a 16 GiB chip.

    q: (b, sq, hkv, g, hd); k: (b, sk, hkv, hd); v: (b, sk, hkv, vd).
    Returns (b, sq, hkv, g, vd). Falls back to one-shot `_sdpa` when the
    problem already fits in a single block or shapes don't divide.
    """
    b, sq, hkv, g, hd = q.shape
    sk, vd = k.shape[1], v.shape[-1]
    cq, ck = min(chunk, sq), min(chunk, sk)
    if (sq <= chunk and sk <= chunk) or sq % cq or sk % ck:
        mask = _causal_mask(sq, sk, 0, window) if causal else jnp.ones((1, sq, sk), bool)
        return _sdpa(q, k, v, mask)
    nq, nk = sq // cq, sk // ck
    scale = 1.0 / jnp.sqrt(hd)
    kb = jnp.moveaxis(k.reshape(b, nk, ck, hkv, hd), 1, 0)  # (nk,b,ck,hkv,hd)
    vb = jnp.moveaxis(v.reshape(b, nk, ck, hkv, vd), 1, 0)
    outs = []
    for i in range(nq):
        qi = jax.lax.slice_in_dim(q, i * cq, (i + 1) * cq, axis=1)
        if causal:
            lo = max(0, (i * cq - window) // ck) if window else 0
            hi = i + 1 if cq == ck else min(nk, ((i + 1) * cq + ck - 1) // ck)
        else:
            lo, hi = 0, nk

        def body(carry, inp, i=i):
            acc, m, l = carry
            kc, vc, j = inp
            s = jnp.einsum("bqkgd,bskd->bkgqs", qi, kc).astype(jnp.float32) * scale
            if causal:
                qpos = i * cq + jnp.arange(cq)
                kpos = j * ck + jnp.arange(ck)
                msk = kpos[None, :] <= qpos[:, None]
                if window:
                    msk = msk & (kpos[None, :] > qpos[:, None] - window)
                s = jnp.where(msk[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            if causal:
                p = jnp.where(msk[None, None, None], p, 0.0)
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(vc.dtype), vc).astype(jnp.float32)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, hkv, g, cq, vd), jnp.float32)
        m0 = jnp.full((b, hkv, g, cq), -1e30, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq), jnp.float32)
        (acc, _, l), _ = jax.lax.scan(
            body, (acc0, m0, l0),
            (kb[lo:hi], vb[lo:hi], jnp.arange(lo, hi)))
        oi = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        outs.append(jnp.transpose(oi, (0, 3, 1, 2, 4)))  # (b,cq,hkv,g,vd)
    return jnp.concatenate(outs, axis=1)


def _attn_dispatch(cfg, q, k, v, *, causal, window):
    """attn_impl selection: the Pallas flash kernel (TPU; interpret elsewhere)
    or its pure-XLA chunked twin (identical blocking — default, CPU-lowerable)."""
    if getattr(cfg, "attn_impl", "xla_chunked") == "pallas_flash":
        from repro.kernels.flash_attn.ops import flash_attention

        interp = jax.default_backend() != "tpu"
        bq = bk = min(512, q.shape[1], k.shape[1])
        return flash_attention(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=interp)
    return chunked_sdpa(q, k, v, causal=causal, window=window)


def gqa_full(p, cfg: ModelConfig, x, positions, causal=True):
    """Full-sequence attention. Returns (out, cache) with post-rope k and v."""
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if causal:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    g = hq // hkv
    qg = q.reshape(b, s, hkv, g, hd)
    out = _attn_dispatch(cfg, qg, k, v, causal=causal,
                         window=cfg.sliding_window if causal else 0).reshape(b, s, hq * hd)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return out, {"k": k, "v": v}


def gqa_decode(p, cfg: ModelConfig, x, cache, pos):
    """x: (b,1,d); cache k/v: (b,S,hkv,hd); pos: scalar position of the new
    token. Writes kv at pos % S (ring for SWA) and attends over valid keys."""
    b, _, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    S = cache["k"].shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, 1, hq, hd)
    k = k.reshape(b, 1, hkv, hd)
    v = v.reshape(b, 1, hkv, hd)
    posa = jnp.full((b, 1), pos)
    q = apply_rope(q, posa, cfg.rope_theta)
    k = apply_rope(k, posa, cfg.rope_theta)
    slot = pos % S
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    g = hq // hkv
    qg = q.reshape(b, 1, hkv, g, hd)
    # valid cache slots: everything written so far; once the ring is full
    # (SWA: S == window) every slot is a live key.
    idx = jnp.arange(S)[None, :]
    valid = (idx <= slot) | (pos >= S)
    mask = jnp.broadcast_to(valid[:, None, :], (b, 1, S))
    out = _sdpa(qg, ck, cv, mask).reshape(b, 1, hq * hd)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return out, {"k": ck, "v": cv}


# --------------------------------------------------------------------- MLA
def init_mla(key, cfg: ModelConfig, dtype):
    d, h = cfg.d_model, cfg.n_heads
    m: MLAConfig = cfg.mla
    ks = jax.random.split(key, 5)
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wq": init_linear(ks[0], d, h * qk, dtype),
        "wkv_a": init_linear(ks[1], d, m.kv_lora_rank + m.qk_rope_head_dim, dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "wkv_b": init_linear(ks[2], m.kv_lora_rank, h * (m.qk_nope_head_dim + m.v_head_dim), dtype),
        "wo": init_linear(ks[3], h * m.v_head_dim, d, dtype),
    }


def _mla_expand(p, cfg, ckv):
    """Latent (b,S,r) -> per-head k_nope (b,S,h,nope), v (b,S,h,vd)."""
    m, h = cfg.mla, cfg.n_heads
    kv = jnp.einsum("bsr,rh->bsh", ckv, p["wkv_b"])
    kv = kv.reshape(*ckv.shape[:2], h, m.qk_nope_head_dim + m.v_head_dim)
    return kv[..., : m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim :]


def mla_full(p, cfg: ModelConfig, x, positions):
    from repro.models.layers import rms_norm

    b, s, d = x.shape
    m, h = cfg.mla, cfg.n_heads
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, s, h, -1)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ca = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv, k_rope = ca[..., : m.kv_lora_rank], ca[..., m.kv_lora_rank :]
    ckv = rms_norm(ckv, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)  # (b,s,1,rd)
    k_nope, v = _mla_expand(p, cfg, ckv)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (*k_nope.shape[:3], m.qk_rope_head_dim))], axis=-1)
    qh = jnp.concatenate([q_nope, q_rope], axis=-1)
    # treat as MHA (hkv == h, group 1)
    out = _attn_dispatch(cfg, qh.reshape(b, s, h, 1, -1), k, v, causal=True, window=0).reshape(b, s, -1)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return out, {"ckv": ckv, "krope": k_rope[:, :, 0, :]}


def mla_decode(p, cfg: ModelConfig, x, cache, pos):
    from repro.models.layers import rms_norm

    b = x.shape[0]
    m, h = cfg.mla, cfg.n_heads
    S = cache["ckv"].shape[1]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, 1, h, -1)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    posa = jnp.full((b, 1), pos)
    q_rope = apply_rope(q_rope, posa, cfg.rope_theta)
    ca = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv_new, k_rope_new = ca[..., : m.kv_lora_rank], ca[..., m.kv_lora_rank :]
    ckv_new = rms_norm(ckv_new, p["kv_norm"], cfg.norm_eps)
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], posa, cfg.rope_theta)[:, :, 0, :]
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new, (0, pos % S, 0))
    krope = jax.lax.dynamic_update_slice(cache["krope"], k_rope_new, (0, pos % S, 0))
    # baseline (paper-faithful naive) decode: expand the latent cache per step
    k_nope, v = _mla_expand(p, cfg, ckv)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :], (*k_nope.shape[:3], m.qk_rope_head_dim))], axis=-1
    )
    qh = jnp.concatenate([q_nope, q_rope], axis=-1)
    mask = (jnp.arange(S)[None, :] <= pos % S)[None] * jnp.ones((b, 1, S), bool)
    out = _sdpa(qh.reshape(b, 1, h, 1, -1), k, v, mask).reshape(b, 1, -1)
    out = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return out, {"ckv": ckv, "krope": krope}


def mla_decode_absorbed(p, cfg: ModelConfig, x, cache, pos):
    """Optimized decode (§Perf): absorb wkv_b into the query/output side so
    attention runs directly in the latent space — no per-step expansion of the
    whole cache. FLOPs drop from O(S·h·(nope+vd)·r) to O(S·h·r)."""
    from repro.models.layers import rms_norm

    b = x.shape[0]
    m, h = cfg.mla, cfg.n_heads
    S = cache["ckv"].shape[1]
    r = m.kv_lora_rank
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, 1, h, -1)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim :]
    posa = jnp.full((b, 1), pos)
    q_rope = apply_rope(q_rope, posa, cfg.rope_theta)
    ca = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    ckv_new, k_rope_new = ca[..., :r], ca[..., r:]
    ckv_new = rms_norm(ckv_new, p["kv_norm"], cfg.norm_eps)
    k_rope_new = apply_rope(k_rope_new[:, :, None, :], posa, cfg.rope_theta)[:, :, 0, :]
    ckv = jax.lax.dynamic_update_slice(cache["ckv"], ckv_new, (0, pos % S, 0))
    krope = jax.lax.dynamic_update_slice(cache["krope"], k_rope_new, (0, pos % S, 0))
    wkv_b = p["wkv_b"].reshape(r, h, m.qk_nope_head_dim + m.v_head_dim)
    wk = wkv_b[..., : m.qk_nope_head_dim]  # (r, h, nope)
    wv = wkv_b[..., m.qk_nope_head_dim :]  # (r, h, vd)
    # absorb: q_latent = q_nope · wk  -> (b,1,h,r)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, wk)
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (
        jnp.einsum("bqhr,bsr->bhqs", q_lat, ckv)
        + jnp.einsum("bqhc,bsc->bhqs", q_rope, krope)
    ).astype(jnp.float32) * scale
    mask = (jnp.arange(S)[None, None, None, :] <= pos % S)
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhqs,bsr->bqhr", w, ckv)          # latent context
    out_h = jnp.einsum("bqhr,rhv->bqhv", ctx, wv)        # expand once per step
    out = jnp.einsum("bsh,hd->bsd", out_h.reshape(b, 1, -1), p["wo"])
    return out, {"ckv": ckv, "krope": krope}


# ------------------------------------------------------------- cross-attn
def init_cross(key, cfg: ModelConfig, dtype):
    d, hq, hd = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": init_linear(ks[0], d, hq * hd, dtype),
        "wk": init_linear(ks[1], d, hq * hd, dtype),
        "wv": init_linear(ks[2], d, hq * hd, dtype),
        "wo": init_linear(ks[3], hq * hd, d, dtype),
    }


def cross_full(p, cfg: ModelConfig, x, enc_kv):
    """x: (b,sq,d); enc_kv: precomputed {"k","v"} (b,se,h,hd)."""
    b, sq, d = x.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, sq, h, hd)
    out = _attn_dispatch(cfg, q.reshape(b, sq, h, 1, hd), enc_kv["k"], enc_kv["v"],
                         causal=False, window=0).reshape(b, sq, h * hd)
    return jnp.einsum("bsh,hd->bsd", out, p["wo"])


def cross_precompute(p, cfg: ModelConfig, enc_out):
    b, se, d = enc_out.shape
    h, hd = cfg.n_heads, cfg.resolved_head_dim
    k = jnp.einsum("bsd,dh->bsh", enc_out, p["wk"]).reshape(b, se, h, hd)
    v = jnp.einsum("bsd,dh->bsh", enc_out, p["wv"]).reshape(b, se, h, hd)
    return {"k": k, "v": v}
