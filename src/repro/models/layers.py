"""Shared neural building blocks (pure functions over param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x, w, eps: float = 1e-6):
    """RMSNorm whose fwd AND bwd never materialize an f32 copy of x.

    Full-tensor bf16→f32 converts here get hoisted by XLA into the
    remat-saved residual stacks of the layer scan, doubling their memory
    (observed in the dry-run HLO: a 13.5 GiB f32[27,b,s,d] stack next to the
    legitimate bf16 one). All reductions accumulate in f32 via
    ``preferred_element_type``; element-wise math stays in x.dtype.
    """
    out, _ = _rms_fwd(x, w, eps)
    return out


def _rms_inv(x, eps):
    var = jnp.einsum("...d,...d->...", x, x, preferred_element_type=jnp.float32)[..., None]
    return jax.lax.rsqrt(var / x.shape[-1] + eps)  # f32, (..., 1)


def _rms_fwd(x, w, eps):
    inv = _rms_inv(x, eps)
    y = x * inv.astype(x.dtype) * w
    return y, (x, w, inv)


def _rms_bwd(eps, res, dy):
    x, w, inv = res
    d = x.shape[-1]
    inv_l = inv.astype(x.dtype)
    dyw = dy * w
    # dw: accumulate in f32 over all leading dims
    dw = jnp.einsum("...d,...d->d", dy, x * inv_l, preferred_element_type=jnp.float32).astype(w.dtype)
    # dx = inv * dyw - x * inv^3/d * <dyw, x>
    dot = jnp.einsum("...d,...d->...", dyw, x, preferred_element_type=jnp.float32)[..., None]
    coeff = (inv ** 3 * dot / d).astype(x.dtype)
    dx = dyw * inv_l - x * coeff
    return dx, dw


rms_norm.defvjp(_rms_fwd, _rms_bwd)


@jax.custom_vjp
def lowp_matmul_f32(x, w):
    """einsum('...d,de->...e') with f32 accumulation whose VJP keeps BOTH
    operands in x.dtype (the default VJP promotes the full x to f32 for the
    weight gradient — which XLA then hoists into remat-saved stacks)."""
    return jnp.einsum("...d,de->...e", x, w.astype(x.dtype),
                      preferred_element_type=jnp.float32)


def _lowp_fwd(x, w):
    return lowp_matmul_f32(x, w), (x, w)


def _lowp_bwd(res, dy):
    x, w = res
    dyl = dy.astype(x.dtype)
    dx = jnp.einsum("...e,de->...d", dyl, w.astype(x.dtype))
    dw = jnp.einsum("...e,...d->de", dyl, x, preferred_element_type=jnp.float32).astype(w.dtype)
    return dx, dw


lowp_matmul_f32.defvjp(_lowp_fwd, _lowp_bwd)


def init_linear(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)
