"""Encoder–decoder LM (Whisper-small backbone). The audio frontend is a stub
per the assignment: ``input_specs()`` feeds precomputed frame embeddings
(b, s_enc, d); the conv frontend is a learned projection placeholder."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models.layers import init_linear, rms_norm, swiglu
from repro.models.sharding import constrain
from repro.models.transformer import _init_ffn, _logits, _maybe_remat


def init_enc_layer(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": A.init_gqa(k1, cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "ffn": _init_ffn(k2, cfg, dtype),
    }


def init_dec_layer(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": A.init_gqa(k1, cfg, dtype),
        "lnx": jnp.ones((cfg.d_model,), dtype),
        "xattn": A.init_cross(k2, cfg, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "ffn": _init_ffn(k3, cfg, dtype),
    }


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 6)
    ek = jax.random.split(ks[0], cfg.encoder_layers)
    dk = jax.random.split(ks[1], cfg.n_layers)
    return {
        "frontend": init_linear(ks[2], cfg.d_model, cfg.d_model, dtype),  # conv stub
        "embed": (jax.random.normal(ks[3], (cfg.padded_vocab, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg, dtype))(ek),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "layers": jax.vmap(lambda k: init_dec_layer(k, cfg, dtype))(dk),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "lm_head": init_linear(ks[4], cfg.d_model, cfg.padded_vocab, dtype),
    }


def encode(params, cfg: ModelConfig, frames):
    x = jnp.einsum("bsd,de->bse", frames.astype(params["frontend"].dtype), params["frontend"])
    x = constrain(x, ("dp", None, None))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(xx, lp):
        h, _ = A.gqa_full(lp["attn"], cfg, rms_norm(xx, lp["ln1"], cfg.norm_eps), positions, causal=False)
        xx = xx + h
        f = swiglu(rms_norm(xx, lp["ln2"], cfg.norm_eps), lp["ffn"]["w_gate"], lp["ffn"]["w_up"], lp["ffn"]["w_down"])
        return constrain(xx + f, ("dp", None, None)), None

    x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, frames, tokens, return_caches=False,
            return_hidden=False, enc=None):
    if enc is None:
        enc = encode(params, cfg, frames)
    x = jnp.take(params["embed"], tokens, axis=0)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(xx, lp):
        h, kv = A.gqa_full(lp["attn"], cfg, rms_norm(xx, lp["ln1"], cfg.norm_eps), positions)
        xx = xx + h
        ekv = A.cross_precompute(lp["xattn"], cfg, enc)
        xx = xx + A.cross_full(lp["xattn"], cfg, rms_norm(xx, lp["lnx"], cfg.norm_eps), ekv)
        f = swiglu(rms_norm(xx, lp["ln2"], cfg.norm_eps), lp["ffn"]["w_gate"], lp["ffn"]["w_up"], lp["ffn"]["w_down"])
        return constrain(xx + f, ("dp", None, None)), kv

    x, kv = jax.lax.scan(_maybe_remat(body, cfg), x, params["layers"])
    caches = {"attn": kv} if return_caches else None
    if return_hidden:
        return x, 0.0, caches
    logits = _logits(params, cfg, x)
    return logits, 0.0, caches


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, enc_len: int, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    L = cfg.n_layers
    return {
        "attn": {
            "k": jnp.zeros((L, batch, cache_len, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((L, batch, cache_len, cfg.n_kv_heads, hd), dtype),
        },
        # precomputed cross-attention K/V per decoder layer
        "cross": {
            "k": jnp.zeros((L, batch, enc_len, cfg.n_heads, hd), dtype),
            "v": jnp.zeros((L, batch, enc_len, cfg.n_heads, hd), dtype),
        },
    }


def prefill(params, cfg: ModelConfig, frames, tokens, cache_len=None):
    """Encode once + teacher-forced decoder pass; build decode caches.
    Logits are last-position-only (b, 1, V)."""
    enc = encode(params, cfg, frames)
    x, _, caches = forward(params, cfg, frames, tokens, return_caches=True,
                           return_hidden=True, enc=enc)
    logits = _logits(params, cfg, x[:, -1:])
    b, s = tokens.shape
    cache_len = cache_len or s
    out = init_cache(cfg, b, cache_len, enc.shape[1])

    def fit(dst, src):
        S, T = dst.shape[2], src.shape[2]
        if T >= S:
            return jax.lax.slice_in_dim(src, T - S, T, axis=2).astype(dst.dtype)
        pad = [(0, 0)] * src.ndim
        pad[2] = (0, S - T)
        return jnp.pad(src, pad).astype(dst.dtype)

    out["attn"]["k"] = fit(out["attn"]["k"], caches["attn"]["k"])
    out["attn"]["v"] = fit(out["attn"]["v"], caches["attn"]["v"])

    def cross_body(_, lp):
        ekv = A.cross_precompute(lp["xattn"], cfg, enc)
        return None, (ekv["k"], ekv["v"])

    _, (ck, cv) = jax.lax.scan(cross_body, None, params["layers"])
    out["cross"]["k"], out["cross"]["v"] = ck, cv
    return logits, out


def decode_step(params, cfg: ModelConfig, cache, token, pos):
    x = jnp.take(params["embed"], token, axis=0)

    def body(xx, inp):
        lp, kv, ck, cv = inp
        h, kv2 = A.gqa_decode(lp["attn"], cfg, rms_norm(xx, lp["ln1"], cfg.norm_eps), kv, pos)
        xx = xx + h
        xx = xx + A.cross_full(lp["xattn"], cfg, rms_norm(xx, lp["lnx"], cfg.norm_eps), {"k": ck, "v": cv})
        f = swiglu(rms_norm(xx, lp["ln2"], cfg.norm_eps), lp["ffn"]["w_gate"], lp["ffn"]["w_up"], lp["ffn"]["w_down"])
        return xx + f, kv2

    x, kv = jax.lax.scan(body, x, (params["layers"], cache["attn"], cache["cross"]["k"], cache["cross"]["v"]))
    logits = jnp.einsum("bsd,dv->bsv", rms_norm(x, params["final_norm"], cfg.norm_eps), params["lm_head"])
    return logits, {"attn": kv, "cross": cache["cross"]}
