"""Decoder-only LM covering the dense / moe / ssm / hybrid / vlm families.

Layers are stacked on a leading L axis and driven by ``lax.scan`` (small HLO,
fast multi-pod compiles); the hybrid (Zamba2-style) path scans Mamba2 groups
and interleaves ONE shared attention block (parameters reused at every
application — the paper's 'shared attn blocks'). Activation sharding is
injected via `repro.models.sharding.constrain`.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.layers import init_linear, rms_norm, swiglu
from repro.models.sharding import constrain


# ----------------------------------------------------------------- init
def _init_ffn(key, cfg, dtype):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": init_linear(ks[0], cfg.d_model, cfg.d_ff, dtype),
        "w_up": init_linear(ks[1], cfg.d_model, cfg.d_ff, dtype),
        "w_down": init_linear(ks[2], cfg.d_ff, cfg.d_model, dtype),
    }


def init_attn_block(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    p = {"ln1": jnp.ones((cfg.d_model,), dtype), "ln2": jnp.ones((cfg.d_model,), dtype)}
    if cfg.mla is not None:
        p["attn"] = A.init_mla(k1, cfg, dtype)
    else:
        p["attn"] = A.init_gqa(k1, cfg, dtype)
    if cfg.moe is not None:
        p["moe"] = MOE.init_moe(k2, cfg, dtype)
    else:
        p["ffn"] = _init_ffn(k2, cfg, dtype)
    return p


def init_ssm_block(key, cfg: ModelConfig, dtype):
    return {"ln": jnp.ones((cfg.d_model,), dtype), "mamba": SSM.init_mamba2(key, cfg, dtype)}


def init_params(cfg: ModelConfig, key):
    dtype = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "embed": (jax.random.normal(ks[0], (cfg.padded_vocab, cfg.d_model), jnp.float32) * 0.02).astype(dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = init_linear(ks[1], cfg.d_model, cfg.padded_vocab, dtype)
    lkeys = jax.random.split(ks[2], cfg.n_layers)
    if cfg.family == "ssm" or cfg.attn_every:
        p["layers"] = jax.vmap(lambda k: init_ssm_block(k, cfg, dtype))(lkeys)
        if cfg.attn_every:
            p["shared_attn"] = init_attn_block(ks[3], cfg, dtype)
    else:
        p["layers"] = jax.vmap(lambda k: init_attn_block(k, cfg, dtype))(lkeys)
    return p


# ----------------------------------------------------------- block bodies
def attn_block_full(p, cfg: ModelConfig, x, positions):
    h, cache = (A.mla_full if cfg.mla is not None else A.gqa_full)(p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), positions)
    x = constrain(x + h, ("dp", None, None))
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        f, aux = MOE.moe_ffn(p["moe"], cfg, h2)
    else:
        f, aux = swiglu(h2, p["ffn"]["w_gate"], p["ffn"]["w_up"], p["ffn"]["w_down"]), 0.0
    x = constrain(x + f, ("dp", None, None))
    return x, cache, aux


def attn_block_decode(p, cfg: ModelConfig, x, cache, pos):
    if cfg.mla is not None:
        fn = A.mla_decode_absorbed if getattr(cfg, "_absorbed_mla", False) else A.mla_decode
        h, cache = fn(p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), cache, pos)
    else:
        h, cache = A.gqa_decode(p["attn"], cfg, rms_norm(x, p["ln1"], cfg.norm_eps), cache, pos)
    x = x + h
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        f, _ = MOE.moe_ffn(p["moe"], cfg, h2)
    else:
        f = swiglu(h2, p["ffn"]["w_gate"], p["ffn"]["w_up"], p["ffn"]["w_down"])
    return x + f, cache


def ssm_block_full(p, cfg, x, conv_state=None, h0=None):
    h, cache = SSM.mamba2_full(p["mamba"], cfg, rms_norm(x, p["ln"], cfg.norm_eps), conv_state, h0)
    return constrain(x + h, ("dp", None, None)), cache


def ssm_block_decode(p, cfg, x, cache):
    h, cache = SSM.mamba2_decode(p["mamba"], cfg, rms_norm(x, p["ln"], cfg.norm_eps), cache)
    return x + h, cache


# --------------------------------------------------------------- forward
def _maybe_remat(fn, cfg):
    """remat policy: "full" (save layer boundaries only — minimum memory),
    "dots" (additionally save matmul outputs: no recompute of projections in
    the backward pass — trades ~(b,s,ff)/layer of HBM for ~25% of the
    recompute FLOPs and its HBM traffic; §Perf lever), "none"."""
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return fn


def _embed(params, cfg, tokens, embeds):
    x = jnp.take(params["embed"], tokens, axis=0)
    if embeds is not None:
        x = jnp.concatenate([embeds.astype(x.dtype), x], axis=1)
    return constrain(x, ("dp", None, None))


def _logits(params, cfg, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"])


def _hybrid_groups(cfg):
    """[(start, len)] mamba-layer groups, each followed by the shared block."""
    out, i = [], 0
    while i < cfg.n_layers:
        out.append((i, min(cfg.attn_every, cfg.n_layers - i)))
        i += cfg.attn_every
    return out


def forward(params, cfg: ModelConfig, tokens, embeds=None, return_caches=False,
            return_hidden=False):
    """Full-sequence forward. Returns (logits|hidden, aux, caches|None).

    ``return_hidden=True`` skips the (B,S,V) logits projection — the chunked
    cross-entropy in ``api.lm_loss`` and the last-position-only prefill both
    project tiny slices instead (the full logits tensor is the single biggest
    activation at 32k×152k vocab)."""
    x = _embed(params, cfg, tokens, embeds)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    caches = None
    aux = 0.0
    if cfg.family == "ssm" or cfg.attn_every:
        def body(carry, lp):
            xx = carry
            xx, cache = ssm_block_full(lp, cfg, xx)
            return xx, cache
        body = _maybe_remat(body, cfg)
        if cfg.attn_every:
            attn_caches = []
            mamba_caches = []
            for (start, ln) in _hybrid_groups(cfg):
                chunk = jax.tree.map(lambda t: jax.lax.slice_in_dim(t, start, start + ln, axis=0), params["layers"])
                x, mc = jax.lax.scan(body, x, chunk)
                x, ac, _ = attn_block_full(params["shared_attn"], cfg, x, positions)
                mamba_caches.append(mc)
                attn_caches.append(ac)
            if return_caches:
                caches = {
                    "mamba": jax.tree.map(lambda *ts: jnp.concatenate(ts, axis=0), *mamba_caches),
                    "attn": jax.tree.map(lambda *ts: jnp.stack(ts, axis=0), *attn_caches),
                }
        else:
            x, mc = jax.lax.scan(body, x, params["layers"])
            caches = {"mamba": mc} if return_caches else None
    else:
        def body(carry, lp):
            xx, aux_acc = carry
            xx, cache, a = attn_block_full(lp, cfg, xx, positions)
            return (xx, aux_acc + a), cache
        body = _maybe_remat(body, cfg)
        (x, aux), kv = jax.lax.scan(body, (x, 0.0), params["layers"])
        caches = {"attn": kv} if return_caches else None
    if return_hidden:
        return x, aux, caches
    logits = _logits(params, cfg, x)
    return logits, aux, caches


# ----------------------------------------------------------------- serve
def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None):
    """Abstract-friendly cache constructor (jnp.zeros everywhere)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm" or cfg.attn_every:
        s = cfg.ssm
        d_inner, nh, conv_dim, _ = SSM.dims(cfg)
        cache = {
            "mamba": {
                "state": jnp.zeros((L, batch, nh, s.head_dim, s.d_state), jnp.float32),
                "conv": jnp.zeros((L, batch, s.conv_kernel - 1, conv_dim), dtype),
            }
        }
        if cfg.attn_every:
            n_attn = len(_hybrid_groups(cfg))
            eff = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
            cache["attn"] = {
                "k": jnp.zeros((n_attn, batch, eff, cfg.n_kv_heads, hd), dtype),
                "v": jnp.zeros((n_attn, batch, eff, cfg.n_kv_heads, hd), dtype),
            }
        return cache
    if cfg.mla is not None:
        m = cfg.mla
        return {"attn": {
            "ckv": jnp.zeros((L, batch, cache_len, m.kv_lora_rank), dtype),
            "krope": jnp.zeros((L, batch, cache_len, m.qk_rope_head_dim), dtype),
        }}
    eff = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
    return {"attn": {
        "k": jnp.zeros((L, batch, eff, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((L, batch, eff, cfg.n_kv_heads, hd), dtype),
    }}


def prefill(params, cfg: ModelConfig, tokens, embeds=None, cache_len: Optional[int] = None):
    """Forward + cache extraction, padded/clipped to cache_len slots.
    Logits are computed for the LAST position only (b, 1, V) — that is all a
    serving loop samples from, and it avoids a (B,S,V) tensor at 32k."""
    x, _, caches = forward(params, cfg, tokens, embeds=embeds, return_caches=True,
                           return_hidden=True)
    logits = _logits(params, cfg, x[:, -1:])
    b = tokens.shape[0]
    s_total = x.shape[1]
    cache_len = cache_len or s_total
    out = init_cache(cfg, b, cache_len)

    def fit(dst, src, time_axis):
        S = dst.shape[time_axis]
        T = src.shape[time_axis]
        if T >= S:  # keep the last S entries (ring semantics)
            src = jax.lax.slice_in_dim(src, T - S, T, axis=time_axis)
            return src.astype(dst.dtype)
        pad = [(0, 0)] * src.ndim
        pad[time_axis] = (0, S - T)
        return jnp.pad(src, pad).astype(dst.dtype)

    if "attn" in caches and "k" in caches["attn"]:
        out["attn"]["k"] = fit(out["attn"]["k"], caches["attn"]["k"], 2)
        out["attn"]["v"] = fit(out["attn"]["v"], caches["attn"]["v"], 2)
    if "attn" in caches and "ckv" in caches["attn"]:
        out["attn"]["ckv"] = fit(out["attn"]["ckv"], caches["attn"]["ckv"], 2)
        out["attn"]["krope"] = fit(out["attn"]["krope"], caches["attn"]["krope"], 2)
    if "mamba" in caches:
        out["mamba"]["state"] = caches["mamba"]["state"].astype(jnp.float32)
        out["mamba"]["conv"] = caches["mamba"]["conv"].astype(out["mamba"]["conv"].dtype)
    return logits, out


def decode_step(params, cfg: ModelConfig, cache, token, pos):
    """token: (b, 1) int32; pos: scalar int32 — absolute position of token."""
    x = jnp.take(params["embed"], token, axis=0)
    if cfg.family == "ssm" or cfg.attn_every:
        if cfg.attn_every:
            new_mamba, new_attn = [], []
            li = 0
            for gi, (start, ln) in enumerate(_hybrid_groups(cfg)):
                chunk = jax.tree.map(lambda t: jax.lax.slice_in_dim(t, start, start + ln, axis=0), params["layers"])
                mcache = jax.tree.map(lambda t: jax.lax.slice_in_dim(t, start, start + ln, axis=0), cache["mamba"])
                def body(xx, inp):
                    lp, cl = inp
                    xx, c2 = ssm_block_decode(lp, cfg, xx)
                    return xx, c2
                # scan over (params, cache) pairs
                def body2(xx, inp):
                    lp, cl = inp
                    h, c2 = SSM.mamba2_decode(lp["mamba"], cfg, rms_norm(xx, lp["ln"], cfg.norm_eps), cl)
                    return xx + h, c2
                x, mc = jax.lax.scan(body2, x, (chunk, mcache))
                acache = jax.tree.map(lambda t: t[gi], cache["attn"])
                x, ac = attn_block_decode(params["shared_attn"], cfg, x, acache, pos)
                new_mamba.append(mc)
                new_attn.append(ac)
            cache = {
                "mamba": jax.tree.map(lambda *ts: jnp.concatenate(ts, axis=0), *new_mamba),
                "attn": jax.tree.map(lambda *ts: jnp.stack(ts, axis=0), *new_attn),
            }
        else:
            def body2(xx, inp):
                lp, cl = inp
                h, c2 = SSM.mamba2_decode(lp["mamba"], cfg, rms_norm(xx, lp["ln"], cfg.norm_eps), cl)
                return xx + h, c2
            x, mc = jax.lax.scan(body2, x, (params["layers"], cache["mamba"]))
            cache = {"mamba": mc}
    else:
        def body(xx, inp):
            lp, cl = inp
            xx, c2 = attn_block_decode(lp, cfg, xx, cl, pos)
            return xx, c2
        x, kv = jax.lax.scan(body, x, (params["layers"], cache["attn"]))
        cache = {"attn": kv}
    logits = _logits(params, cfg, x)
    return logits, cache
