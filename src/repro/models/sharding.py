"""Sharding rules: parameter PartitionSpecs + activation constraints.

Axis roles: ``dp`` = the data-parallel axes (("pod","data") on the multi-pod
mesh, ("data",) on a single pod), ``model`` = tensor/expert parallelism.
A thread-local context carries the active mesh so model code stays
mesh-agnostic (smoke tests run with no mesh and constraints become no-ops).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_CTX = threading.local()


@contextmanager
def mesh_context(mesh, dp_axes):
    """dp_axes: tuple of mesh axis names acting as data parallelism."""
    prev = getattr(_CTX, "v", None)
    _CTX.v = (mesh, tuple(dp_axes))
    try:
        yield
    finally:
        _CTX.v = prev


def current():
    return getattr(_CTX, "v", None)


def _resolve(spec_entry):
    """Map the symbolic 'dp' to the context's dp axes."""
    mesh, dp = current()
    if spec_entry == "dp":
        return dp if len(dp) > 1 else dp[0]
    return spec_entry


def constrain(x, symbolic_spec):
    """with_sharding_constraint if a mesh context is active, else identity."""
    ctx = current()
    if ctx is None:
        return x
    mesh, _ = ctx
    spec = P(*[_resolve(e) for e in symbolic_spec])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# --------------------------------------------------------------------------
# Parameter specs
# --------------------------------------------------------------------------
def _divisible(dim: int, mesh, axis) -> bool:
    """pjit in_shardings require exact divisibility; non-divisible dims
    replicate (vocab is pre-padded in the config so the big tables shard)."""
    if axis is None:
        return True
    names = axis if isinstance(axis, tuple) else (axis,)
    total = int(np.prod([mesh.shape[n] for n in names]))
    return dim % total == 0


def _guard(spec: tuple, shape: tuple, mesh) -> P:
    out = []
    for dim, ax in zip(shape, spec):
        out.append(ax if _divisible(dim, mesh, ax) else None)
    return P(*out)


def param_pspecs(cfg, params_tree, mesh, dp_axes):
    """Build a PartitionSpec pytree matching ``params_tree`` (abstract ok).

    Rules (path-name driven):
      embed (V,d)->(model,None); lm_head (d,V)->(None,model)
      wq/wk/wv/wkv_b (…,d,H)->(None,model); wo/w_down/out_proj (…,H,d)->(model,None)
      w_gate/w_up/in_proj (…,d,f)->(None,model)
      experts we_* (L,E,…)->(model on E [, data on d if cfg.fsdp])
      conv_w (C,K)->(model,None);  1-D params replicated
    """
    fsdp_ax = dp_axes[-1] if cfg.fsdp else None

    def rule(path, leaf):
        name = path[-1] if path else ""
        nd = len(leaf.shape)
        stacked = name not in ("embed", "lm_head", "final_norm") and "shared_attn" not in path and "encoder_embed" not in path
        # leading L axis for stacked layer params
        def with_l(spec):
            return ((None,) + spec) if (stacked and "layers" in path) else spec

        if name == "embed":
            return _guard(("model", None), leaf.shape, mesh)
        if name == "lm_head":
            return _guard((None, "model"), leaf.shape, mesh)
        if nd <= 1 + (1 if ("layers" in path and stacked) else 0):
            return P(*([None] * nd))  # norms, biases, scalars
        if name in ("we_gate", "we_up", "we_down"):
            spec = ["model", None, None]  # (E, d, f) / (E, f, d)
            if cfg.fsdp:
                spec[1] = fsdp_ax
            return _guard(tuple(with_l(tuple(spec))), leaf.shape, mesh)
        if name == "router":
            return _guard(with_l((None, None)), leaf.shape, mesh)
        if name in ("wq", "wk", "wv", "wkv_b", "w_gate", "w_up", "in_proj", "ws_gate", "ws_up", "wkv_a"):
            spec = (fsdp_ax, "model") if cfg.fsdp else (None, "model")
            return _guard(with_l(spec), leaf.shape, mesh)
        if name in ("wo", "w_down", "out_proj", "ws_down"):
            spec = ("model", fsdp_ax) if cfg.fsdp else ("model", None)
            return _guard(with_l(spec), leaf.shape, mesh)
        if name == "conv_w":
            return _guard(with_l(("model", None)), leaf.shape, mesh)
        return P(*([None] * nd))

    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(params_tree)
    specs = []
    for kp, leaf in paths_leaves:
        path = tuple(
            k.key if hasattr(k, "key") else str(getattr(k, "idx", k)) for k in kp
        )
        specs.append(rule(path, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def cache_pspecs(cfg, cache_tree, mesh, dp_axes, batch: int):
    """KV/state cache sharding: batch over dp when divisible; heads/latent
    over model; batch==1 long-context attention caches shard the TIME axis
    over dp (sequence parallelism for the cache)."""
    dp = tuple(dp_axes)
    dp_total = int(np.prod([mesh.shape[a] for a in dp]))
    batch_ax = (dp if len(dp) > 1 else dp[0]) if batch % dp_total == 0 and batch >= dp_total else None

    def rule(path, leaf):
        name = path[-1]
        nd = len(leaf.shape)
        if name in ("k", "v"):  # (L|G, b, S, hkv, hd)
            head_ax = "model" if _divisible(leaf.shape[3], mesh, "model") else None
            # few-KV-head archs (GQA kv ∈ {2,4,8,12} vs model=16): shard the
            # TIME axis over "model" instead — decode attention contracts over
            # time, which SPMD handles with partial scores + small softmax-stat
            # all-reduces instead of gathering the cache (§Perf iteration).
            time_ax = "model" if head_ax is None and _divisible(leaf.shape[2], mesh, "model") else None
            if batch_ax is None and time_ax is None and _divisible(leaf.shape[2], mesh, dp if len(dp) > 1 else dp[0]):
                # batch-1 long-context: sequence-parallel cache over the free
                # dp axes (heads may still take "model")
                time_ax = dp if len(dp) > 1 else dp[0]
            if batch_ax is None and head_ax is None and time_ax is None:
                return _guard((None, None, (dp if len(dp) > 1 else dp[0]), None, None), leaf.shape, mesh)
            return _guard((None, batch_ax, time_ax, head_ax, None), leaf.shape, mesh)
        if name in ("ckv", "krope"):  # (L, b, S, r) — latent has no head dim;
            # shard time over "model" (same partial-attention argument)
            time_ax = "model" if _divisible(leaf.shape[2], mesh, "model") else None
            if batch_ax is None and time_ax is None:
                return _guard((None, None, (dp if len(dp) > 1 else dp[0]), None), leaf.shape, mesh)
            return _guard((None, batch_ax, time_ax, None), leaf.shape, mesh)
        if name == "state":  # (L, b, nh, hp, ds)
            return _guard((None, batch_ax, "model", None, None), leaf.shape, mesh)
        if name == "conv":  # (L, b, K-1, conv_dim)
            return _guard((None, batch_ax, None, "model"), leaf.shape, mesh)
        return P(*([None] * nd))

    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(cache_tree)
    specs = []
    for kp, leaf in paths_leaves:
        path = tuple(k.key if hasattr(k, "key") else str(getattr(k, "idx", k)) for k in kp)
        specs.append(rule(path, leaf))
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_pspec(mesh, dp_axes, batch: int):
    dp = tuple(dp_axes)
    dp_total = int(np.prod([mesh.shape[a] for a in dp]))
    if batch % dp_total == 0 and batch >= dp_total:
        return P(dp if len(dp) > 1 else dp[0], None)
    return P(None, None)


def zero1_spec(param_spec: P, shape: tuple, mesh, dp_axes) -> P:
    """ZeRO-1: shard optimizer moments over the dp axes on the first
    divisible unsharded dim. Only dp axes NOT already used by the param spec
    are added (fsdp params already consume one dp axis); falls back to the
    param spec when nothing further shards."""
    entries = list(param_spec) + [None] * (len(shape) - len(param_spec))
    used = set()
    for ax in entries:
        if ax is None:
            continue
        for a in (ax if isinstance(ax, tuple) else (ax,)):
            used.add(a)
    free = tuple(a for a in dp_axes if a not in used)
    if not free:
        return P(*entries)
    total = int(np.prod([mesh.shape[a] for a in free]))
    for i, (dim, ax) in enumerate(zip(shape, entries)):
        if ax is None and dim % total == 0 and dim >= total:
            entries[i] = free if len(free) > 1 else free[0]
            return P(*entries)
    return P(*entries)
