"""Deterministic fault injection + the degradation ledger (DESIGN.md §11).

Crash-safety needs killable code paths: tests (and the CI chaos smoke) must
be able to stop the summarizer at an exact, reproducible point and prove the
checkpoint/resume path restores a bit-identical run. `FaultPlan` is that
kill switch — a declarative (site, iteration, hit) trigger that raises
`InjectedFault` from an instrumented site, armed either by the
`faults.inject(...)` context manager or the ``REPRO_FAULTS`` env var.

Instrumented sites (each calls ``faults.check(site, ...)``):

================================  =========================================
site                              where
================================  =========================================
``engine.shingle`` … ``engine.exchange``
                                  each stage boundary of
                                  `core.engine.SummarizerEngine` (the check
                                  runs AFTER the stage, so a kill lands
                                  between stages, before the iteration's
                                  checkpoint commits)
``kernel.bitset_fold.<op>``       device dispatch wrappers in
``kernel.bitset_jaccard.<op>``    `kernels/*/ops.py` (checked BEFORE the
                                  compiled call, so donated buffers are
                                  still intact and a retry is safe)
``resident.bank.extract``         `ResidentBitmapArena.from_bank`
``resident.bank.advance``         `ResidentAdjacencyBank.advance_batches`
``transfer.h2d`` / ``transfer.d2h``
                                  every accounted host↔device crossing
                                  (`core.transfer.TransferCounter`)
``datasets.fetch``                the download attempt in
                                  `graphs.datasets.fetch`
================================  =========================================

Site matching is exact, or by prefix when the pattern ends with ``"."``
(``"kernel."`` matches every kernel dispatch). Env var syntax is
``site[@iteration][#hit]`` — e.g. ``REPRO_FAULTS=engine.merge_round@3`` or
``REPRO_FAULTS=kernel.#5``.

The module also owns the degradation ledger: every graceful fallback
(Pallas dispatch retried on the `ref.py` twin, adjacency bank dropped for
the host-rebuilt path) is recorded here; the engine snapshots the ledger
around a run and reports the delta as ``engine.stats["degradations"]``.
Everything is thread-safe — merge-round thunks run on a pool.
"""
from __future__ import annotations

import os
import threading
from contextlib import contextmanager

import numpy as np

ENV_VAR = "REPRO_FAULTS"

STAGE_SITES = ("engine.shingle", "engine.group", "engine.pack",
               "engine.merge_round", "engine.exchange")


class InjectedFault(RuntimeError):
    """A deterministic fault fired by an active `FaultPlan`."""

    def __init__(self, site: str, iteration=None, hit: int = 0):
        self.site = site
        self.iteration = iteration
        self.hit = hit
        where = f"site={site!r}"
        if iteration is not None:
            where += f" iteration={iteration}"
        super().__init__(f"injected fault at {where} (hit {hit})")


class BankFault(RuntimeError):
    """A failure on the resident adjacency-bank path, wrapped so the engine
    can identify it and degrade to the host-rebuilt workspace path for the
    rest of the run (DESIGN.md §11 degradation policy)."""


class FaultPlan:
    """Deterministic fault schedule: raise at the ``hit``-th occurrence of a
    matching ``(site, iteration)``.

    * ``site`` — exact site name, or a prefix ending in ``"."``.
    * ``iteration`` — only occurrences carrying this iteration match
      (``None`` matches any, including sites that report no iteration).
    * ``hit`` — fire on the N-th matching occurrence (1-based).
    * ``times`` — how many firings before the plan disarms (default 1, so
      a degradation retry of the same site succeeds).
    """

    def __init__(self, site: str, iteration=None, hit: int = 1,
                 times: int = 1):
        if not site:
            raise ValueError("FaultPlan needs a non-empty site")
        self.site = str(site)
        self.iteration = None if iteration is None else int(iteration)
        self.hit = max(1, int(hit))
        self.times = max(1, int(times))
        self._lock = threading.Lock()
        self._seen = 0
        self._fired = 0

    @classmethod
    def seeded(cls, seed: int, sites=STAGE_SITES, iterations: int = 5,
               times: int = 1) -> "FaultPlan":
        """Pick a (site, iteration) deterministically from ``seed`` — the
        chaos-smoke constructor: same seed, same kill point, every run."""
        rng = np.random.default_rng(np.random.SeedSequence((int(seed),
                                                            0xFA17)))
        site = sites[int(rng.integers(0, len(sites)))]
        iteration = int(rng.integers(1, max(int(iterations), 1) + 1))
        return cls(site, iteration=iteration, times=times)

    @classmethod
    def from_spec(cls, spec: str) -> "FaultPlan":
        """Parse the env-var syntax ``site[@iteration][#hit]``."""
        spec = spec.strip()
        hit = 1
        if "#" in spec:
            spec, _, h = spec.partition("#")
            hit = int(h)
        iteration = None
        if "@" in spec:
            spec, _, it = spec.partition("@")
            iteration = int(it)
        return cls(spec, iteration=iteration, hit=hit)

    def _matches(self, site: str, iteration) -> bool:
        if self.site.endswith("."):
            if not site.startswith(self.site):
                return False
        elif site != self.site:
            return False
        return self.iteration is None or iteration == self.iteration

    def note(self, site: str, iteration=None):
        """Record one occurrence; raise `InjectedFault` when it is the one."""
        if not self._matches(site, iteration):
            return
        with self._lock:
            if self._fired >= self.times:
                return
            self._seen += 1
            if self._seen < self.hit:
                return
            self._fired += 1
            self._seen = 0  # re-arm the hit counter for times > 1
            hit = self.hit
        raise InjectedFault(site, iteration=iteration, hit=hit)

    def __repr__(self):
        return (f"FaultPlan(site={self.site!r}, iteration={self.iteration}, "
                f"hit={self.hit}, times={self.times})")


# --------------------------------------------------------------- activation
_lock = threading.Lock()
_plans: list = []          # context-manager plans (innermost last)
_env_plan = None           # FaultPlan parsed from $REPRO_FAULTS, or None
_armed = False             # fast-path gate read without the lock


def _rearm():
    global _armed
    _armed = bool(_plans) or _env_plan is not None


def install_env_plan(env=os.environ):
    """(Re)read ``$REPRO_FAULTS`` — called at import and from tests that
    set the variable after import."""
    global _env_plan
    spec = env.get(ENV_VAR, "").strip()
    with _lock:
        _env_plan = FaultPlan.from_spec(spec) if spec else None
        _rearm()
    return _env_plan


def check(site: str, iteration=None):
    """Fault-injection hook: no-op unless a plan is armed (one module-level
    bool read), else give every active plan a chance to fire."""
    if not _armed:
        return
    with _lock:
        active = list(_plans) + ([_env_plan] if _env_plan is not None else [])
    for plan in active:
        plan.note(site, iteration=iteration)


@contextmanager
def inject(plan, iteration=None, hit: int = 1, times: int = 1):
    """Arm a `FaultPlan` (or build one from a site string) for the body."""
    if not isinstance(plan, FaultPlan):
        plan = FaultPlan(plan, iteration=iteration, hit=hit, times=times)
    with _lock:
        _plans.append(plan)
        _rearm()
    try:
        yield plan
    finally:
        with _lock:
            _plans.remove(plan)
            _rearm()


# ---------------------------------------------------------------- ledger
class DegradationLog:
    """Thread-safe append-only record of every graceful fallback."""

    def __init__(self):
        self._lock = threading.Lock()
        self._events: list = []

    def record(self, site: str, detail) -> None:
        with self._lock:
            self._events.append({"site": site, "detail": repr(detail)})

    def count(self) -> int:
        with self._lock:
            return len(self._events)

    def events_since(self, mark: int) -> list:
        with self._lock:
            return list(self._events[mark:])


DEGRADATIONS = DegradationLog()

install_env_plan()
