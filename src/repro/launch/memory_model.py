"""Analytic per-device HBM model for every (arch × shape × mesh) cell.

Why this exists: the dry-run compiles for the CPU backend, whose
float-normalization pass promotes bf16 dots / collectives / in-place updates
to f32 (visible as `convert` + `_promoted` ops in the optimized HLO). The
CPU buffer arena therefore OVERSTATES what the identical program needs on a
TPU, where bf16 is native. We report both numbers per cell:

  * ``measured``  — XLA:CPU ``compiled.memory_analysis()`` (upper bound),
  * ``analytic``  — this model (what the TPU lowering needs):
      params(shard) + optimizer moments(shard) + gradients(shard, f32)
      + remat-saved layer-boundary activations (bf16)
      + peak single-layer recompute working set
      + CE-chunk logits (f32) / KV-cache shards for serving.

Shard factors come from the SAME PartitionSpec trees used by the real step
(so a sharding bug shows up as an analytic-vs-expected mismatch in tests).
"""
from __future__ import annotations

import numpy as np
import jax

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import sharding as SH
from repro.models.api import abstract_params, get_api, input_specs


def _shard_factor(spec, shape, mesh) -> int:
    f = 1
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))):
        if ax is None:
            continue
        names = ax if isinstance(ax, tuple) else (ax,)
        k = int(np.prod([mesh.shape[n] for n in names]))
        if dim % k == 0:
            f *= k
    return f


def _tree_bytes(tree, specs, mesh, dtype_bytes=None) -> float:
    flat, treedef = jax.tree.flatten(tree)
    flat_s = treedef.flatten_up_to(specs)
    total = 0.0
    for leaf, spec in zip(flat, flat_s):
        nbytes = int(np.prod(leaf.shape)) * (dtype_bytes or leaf.dtype.itemsize)
        total += nbytes / _shard_factor(spec, leaf.shape, mesh)
    return total


def analytic_hbm(cfg: ModelConfig, shape: ShapeConfig, mesh, dp_axes,
                 microbatch=None, opt_bytes_per_param: int = 8) -> dict:
    """Returns a per-device byte breakdown dict (floats)."""
    params_abs = abstract_params(cfg)
    pspecs = SH.param_pspecs(cfg, params_abs, mesh, dp_axes)
    p_bytes = _tree_bytes(params_abs, pspecs, mesh)
    dp_total = int(np.prod([mesh.shape[a] for a in dp_axes]))
    out = {"params": p_bytes}
    d, S = cfg.d_model, shape.seq_len
    dt = 2  # bf16 activations

    if shape.kind == "train":
        # optimizer moments: ZeRO-1 sharded over the free dp axes
        flat_p, treedef = jax.tree.flatten(params_abs)
        flat_spec = treedef.flatten_up_to(pspecs)
        out["opt_moments"] = sum(
            int(np.prod(l.shape)) * opt_bytes_per_param / _shard_factor(
                SH.zero1_spec(s, l.shape, mesh, dp_axes), l.shape, mesh)
            for l, s in zip(flat_p, flat_spec))
        # gradients accumulate in f32 with the param sharding
        out["grads_f32"] = _tree_bytes(params_abs, pspecs, mesh, dtype_bytes=4)
        mb = microbatch or cfg.train_microbatch or shape.global_batch
        b_local = max(1, mb // dp_total)
        units = cfg.n_layers + cfg.encoder_layers
        if cfg.attn_every:
            units = cfg.n_layers + (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every
        # remat=full saves one (b_local, S, d) residual per layer unit
        out["saved_residuals"] = float(units * b_local * S * d * dt)
        # live recompute: one layer's working set ≈ qkv+ffn intermediates
        ff = cfg.d_ff or (cfg.ssm.expand * d if cfg.ssm else d)
        if cfg.moe:
            ff = cfg.moe.top_k * cfg.moe.d_expert * cfg.moe.capacity_factor
        out["recompute_peak"] = float(b_local * S * (4 * d + 2 * ff) * 4)
        # chunked-CE logits: one (B, C, V/model) f32 chunk (+1 in flight)
        C = max(1, min(S, 32_768 // max(shape.global_batch, 1)))
        model_k = mesh.shape.get("model", 1)
        out["ce_chunk"] = float(2 * b_local * C * (cfg.padded_vocab // model_k) * 4)
    else:
        api = get_api(cfg)
        cache_abs = jax.eval_shape(lambda: api.init_cache(cfg, shape.global_batch, S))
        cspecs = SH.cache_pspecs(cfg, cache_abs, mesh, dp_axes, shape.global_batch)
        out["kv_cache"] = _tree_bytes(cache_abs, cspecs, mesh)
        if shape.kind == "prefill":
            b_local = max(1, shape.global_batch // dp_total)
            out["live_activations"] = float(8 * b_local * S * d * dt)
        else:
            out["kv_cache"] *= 2  # in+out copies unless donation aliases
    out["total"] = float(sum(out.values()))
    return out
