"""Roofline analysis from AOT-compiled artifacts (no hardware execution).

Terms per (arch × shape × mesh) — TPU v5e constants:
    compute    = HLO_FLOPs   / (chips × 197e12 FLOP/s)      [bf16]
    memory     = HLO_bytes   / (chips × 819e9  B/s HBM)
    collective = Σ per-category collective bytes / (chips × 50e9 B/s × links)

Collective bytes are parsed from the optimized HLO text: shaped operands of
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) exposes remat/dispatch
overhead as the ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # B/s / chip
ICI_BW = 50e9               # B/s / link (≈ per direction)
ICI_LINKS = 4               # 2D torus: 4 links/chip

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[128,1024]{1,0}' -> byte size. Tuples handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-category output-shape bytes of every collective op in the HLO."""
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # ops look like:  %x = bf16[...]{...} all-gather(...), replica_groups=...
        m = re.match(r"%?[\w\.\-]+ = (\(?[^=]*?)\s*(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)", s)
        if not m:
            continue
        cat = m.group(2)
        # skip -start/-done duplicates (count the -start only when present)
        if cat + "-done" in s:
            continue
        nbytes = _shape_bytes(m.group(1))
        out[cat] += nbytes
        out["count"][cat] += 1
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: dict
    model_flops: float
    per_device_hbm: float
    compile_s: float = 0.0
    model_bytes: float = 0.0   # decode ideal: params + cache read once

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / (self.chips * ICI_BW * ICI_LINKS)

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory, "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.hlo_flops, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """How close the step is to its roofline: ideal step time over the
        achievable step time (max of terms). Ideal = MODEL_FLOPS at peak
        compute, or for decode shapes the params+cache-once memory floor —
        whichever bound is higher (the binding one)."""
        ideal = max(self.model_flops / (self.chips * PEAK_FLOPS),
                    self.model_bytes / (self.chips * HBM_BW))
        ach = max(self.t_compute, self.t_memory, self.t_collective)
        return ideal / max(ach, 1e-12)

    def to_json(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh, "chips": self.chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "coll_bytes": self.coll_bytes, "coll_breakdown": self.coll_breakdown,
            "model_flops": self.model_flops, "per_device_hbm": self.per_device_hbm,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective, "bottleneck": self.bottleneck,
            "useful_ratio": self.useful_ratio, "roofline_fraction": self.roofline_fraction,
            "compile_s": self.compile_s, "model_bytes": self.model_bytes,
        }


def model_flops_for(cfg, shape) -> float:
    """6·N·D for training; 2·N·D per generated/processed token for serving."""
    n = cfg.param_count(active_only=cfg.moe is not None)
    if shape.kind == "train":
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if shape.kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def ideal_decode_bytes(cfg, shape) -> float:
    """Decode is memory-bound by construction: the floor for one step is
    reading every (bf16) weight once plus the whole KV/state cache once.
    Used as the decode-shape roofline ideal (the 2·N·B FLOPs ideal is ~0)."""
    import jax
    from repro.models.api import get_api

    n = cfg.param_count(active_only=False)  # all experts resident
    api = get_api(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(cfg, shape.global_batch, shape.seq_len))
    cache_bytes = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(cache))
    return 2.0 * n + float(cache_bytes)


def from_compiled(arch, shape_name, mesh_name, chips, compiled, hlo_text, cfg, shape, compile_s=0.0):
    # Under GSPMD, the optimized HLO describes the PER-DEVICE partitioned
    # program. We run our trip-count-aware analyzer over it (XLA's own
    # cost_analysis counts while bodies once — useless for scanned layers) and
    # record GLOBAL quantities (× chips) so the roofline formulas divide back.
    from repro.launch.hlo_analysis import analyze_hlo

    res = analyze_hlo(hlo_text)
    mem = compiled.memory_analysis()
    coll = {c: res["coll"][c] for c in res["coll"]}
    coll["count"] = res["coll_count"]
    per_dev = (
        mem.argument_size_in_bytes + mem.output_size_in_bytes + mem.temp_size_in_bytes
        - mem.alias_size_in_bytes
    )
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=float(res["flops"]) * chips,
        hlo_bytes=float(res["bytes"]) * chips,
        coll_bytes=float(res["coll_bytes"]) * chips,
        coll_breakdown=coll,
        model_flops=model_flops_for(cfg, shape),
        per_device_hbm=float(per_dev),
        compile_s=compile_s,
        model_bytes=ideal_decode_bytes(cfg, shape) if shape.kind == "decode" else 0.0,
    )
