import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (assignment e): lower + compile EVERY
(architecture × applicable shape) on the 16×16 single-pod mesh and the
2×16×16 multi-pod mesh, against ShapeDtypeStruct inputs only (no allocation).

Per cell we record: per-device memory, HLO FLOPs/bytes, the collective
schedule (bytes per category), and the three roofline terms — written as one
JSON artifact per cell under artifacts/dryrun/ (incremental: existing
artifacts are skipped unless --force).

Usage:
  python -m repro.launch.dryrun --all [--mesh single|multi|both]
  python -m repro.launch.dryrun --arch mamba2-130m --shape train_4k --mesh single
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs.base import SHAPES, applicable_shapes
from repro.configs.registry import ARCH_NAMES, get_config
from repro.launch import roofline as RL
from repro.launch.mesh import dp_axes_of, make_production_mesh
from repro.models.api import input_specs
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainPlan, build_serve_step, build_train_step

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")


def cell_id(arch, shape, mesh_name, variant=""):
    v = f"_{variant}" if variant else ""
    return f"{arch}__{shape}__{mesh_name}{v}"


def _lower_compiled(cfg, shape, mesh, dp, microbatch=None, absorbed_mla=False,
                    moment_dtype="float32"):
    """Lower+compile one step for (cfg, shape) on mesh; returns compiled."""
    if shape.kind == "train":
        plan = TrainPlan(cfg=cfg, mesh=mesh, dp_axes=dp,
                         opt=AdamWConfig(moment_dtype=moment_dtype), microbatch=microbatch)
        step, _, _, state_abs = build_train_step(plan, shape)
        return step.lower(state_abs, input_specs(cfg, shape)).compile()
    step, _, _, params_abs = build_serve_step(cfg, mesh, dp, shape, absorbed_mla=absorbed_mla)
    batch_abs = input_specs(cfg, shape)
    if shape.kind == "prefill":
        return step.lower(params_abs, batch_abs).compile()
    return step.lower(params_abs, batch_abs["cache"], batch_abs["token"], batch_abs["pos"]).compile()


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str,
             force: bool = False, variant: str = "", microbatch=None,
             remat=None, absorbed_mla=False, moment_dtype="float32", verbose=True):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, cell_id(arch, shape_name, mesh_name, variant) + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    if remat is not None:
        import dataclasses
        cfg = dataclasses.replace(cfg, remat=remat)
    shape = SHAPES[shape_name]
    app = applicable_shapes(cfg)[shape_name]
    if app != "run":
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "status": "skipped", "reason": app}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if verbose:
            print(f"[dryrun] {arch:24s} {shape_name:12s} {mesh_name:6s} SKIP ({app})")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    dp = dp_axes_of(mesh)
    chips = mesh.devices.size
    if microbatch is None and shape.kind == "train" and cfg.train_microbatch:
        microbatch = cfg.train_microbatch  # per-arch default (fits 16 GiB)
    t0 = time.perf_counter()
    try:
        compiled = _lower_compiled(cfg, shape, mesh, dp, microbatch, absorbed_mla, moment_dtype)
        compile_s = time.perf_counter() - t0
        hlo = compiled.as_text()
        # RL.from_compiled runs the trip-count-aware HLO analyzer (XLA's own
        # cost_analysis counts while bodies once — wrong for scanned layers).
        rl = RL.from_compiled(arch, shape_name, mesh_name, chips, compiled, hlo, cfg, shape, compile_s)
        mem = compiled.memory_analysis()
        rec = rl.to_json()
        try:
            from repro.launch.memory_model import analytic_hbm
            rec["analytic_hbm"] = analytic_hbm(cfg, shape, mesh, dp, microbatch)
        except Exception as e:  # analytic model must never block the dry-run
            rec["analytic_hbm"] = {"error": repr(e)}
        rec.update({
            "status": "ok",
            "variant": variant,
            "microbatch": microbatch,
            "memory_analysis": {
                "argument_size": mem.argument_size_in_bytes,
                "output_size": mem.output_size_in_bytes,
                "temp_size": mem.temp_size_in_bytes,
                "alias_size": mem.alias_size_in_bytes,
                "code_size": mem.generated_code_size_in_bytes,
            },
        })
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if verbose:
            print(
                f"[dryrun] {arch:24s} {shape_name:12s} {mesh_name:6s} OK "
                f"hbm/dev={rec['per_device_hbm']/2**30:.2f}GiB "
                f"t_comp={rec['t_compute']*1e3:.2f}ms t_mem={rec['t_memory']*1e3:.2f}ms "
                f"t_coll={rec['t_collective']*1e3:.2f}ms bottleneck={rec['bottleneck']} "
                f"({compile_s:.0f}s compile)"
            )
        return rec
    except Exception as e:
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "status": "error",
               "error": repr(e), "trace": traceback.format_exc()[-3000:]}
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        if verbose:
            print(f"[dryrun] {arch:24s} {shape_name:12s} {mesh_name:6s} ERROR {e!r}")
        return rec


def run_summarize_cell(mesh_name: str, out_dir: str, force: bool = False,
                       variant: str = "", sharded_out: bool = False,
                       hist: str = "sort", verbose=True):
    """Extra row: the paper's own distributed summarize_step on the mesh.

    ``sharded_out=True`` is the §Perf iteration: keep the per-node shingle
    table SHARDED across the dp axes (reduce-scatter) instead of replicating
    it (all-reduce) — the downstream grouping only ever reads each node's
    shingle once, so replication is pure waste.
    """
    import jax.numpy as jnp
    from repro.core.distributed import summarize_step_fn
    from repro.launch.hlo_analysis import analyze_hlo
    from jax.sharding import NamedSharding, PartitionSpec as P

    path = os.path.join(out_dir, cell_id("slugger-summarize", "edges_1b", mesh_name, variant) + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    dp = dp_axes_of(mesh)
    chips = mesh.devices.size
    n_nodes, n_edges = 64_000_000, 1_024_000_000  # UK-05-scale graph (0.8B undirected)
    step = summarize_step_fn(n_nodes, hist=hist)
    dspec = P(dp if len(dp) > 1 else dp[0])
    espec = NamedSharding(mesh, dspec)
    rspec = NamedSharding(mesh, P(None))
    out_sh = (NamedSharding(mesh, dspec), NamedSharding(mesh, dspec)) if sharded_out else None
    t0 = time.perf_counter()
    lowered = jax.jit(step, in_shardings=(espec, espec, rspec, None),
                      out_shardings=out_sh).lower(
        jax.ShapeDtypeStruct((n_edges,), jnp.int32),
        jax.ShapeDtypeStruct((n_edges,), jnp.int32),
        jax.ShapeDtypeStruct((n_nodes,), jnp.int32),
        jax.ShapeDtypeStruct((), jnp.uint32),
    )
    compiled = lowered.compile()
    res = analyze_hlo(compiled.as_text())
    mem = compiled.memory_analysis()
    coll = dict(res["coll"])
    coll["count"] = res["coll_count"]
    rec = {
        "status": "ok", "arch": "slugger-summarize", "shape": "edges_1b", "mesh": mesh_name,
        "variant": variant, "chips": chips,
        "hlo_flops": float(res["flops"]) * chips, "hlo_bytes": float(res["bytes"]) * chips,
        "coll_bytes": float(res["coll_bytes"]) * chips,
        "coll_breakdown": coll, "compile_s": time.perf_counter() - t0,
        "t_compute": float(res["flops"]) / RL.PEAK_FLOPS,
        "t_memory": float(res["bytes"]) / RL.HBM_BW,
        "t_collective": float(res["coll_bytes"]) / (RL.ICI_BW * RL.ICI_LINKS),
        "per_device_hbm": float(mem.argument_size_in_bytes + mem.output_size_in_bytes + mem.temp_size_in_bytes),
    }
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        print(f"[dryrun] slugger-summarize edges_1b {mesh_name}{' '+variant if variant else ''}: OK "
              f"t_mem={rec['t_memory']*1e3:.1f}ms t_coll={rec['t_collective']*1e3:.1f}ms "
              f"({rec['compile_s']:.0f}s)")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=ARTIFACTS)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--variant", default="")
    ap.add_argument("--absorbed-mla", action="store_true")
    ap.add_argument("--moment-dtype", default="float32")
    ap.add_argument("--sharded-out", action="store_true")
    ap.add_argument("--hist", default="sort", choices=["sort", "scatter"])
    ap.add_argument("--summarize-step", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.summarize_step:
        for m in meshes:
            run_summarize_cell(m, args.out, args.force, variant=args.variant,
                               sharded_out=args.sharded_out, hist=args.hist)
        return
    archs = ARCH_NAMES if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if args.shape is None else [args.shape]
    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape in shapes:
            for m in meshes:
                rec = run_cell(arch, shape, m, args.out, force=args.force,
                               variant=args.variant, microbatch=args.microbatch,
                               remat=args.remat, absorbed_mla=args.absorbed_mla,
                               moment_dtype=args.moment_dtype)
                st = rec.get("status")
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
    print(f"[dryrun] done: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
