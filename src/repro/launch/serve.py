"""Batched serving driver: continuous-batching loop over prefill + decode.

CPU-scale usage:
  python -m repro.launch.serve --arch qwen2.5-3b --smoke --requests 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models.api import get_api


def mask_pad_logits(cfg, logits):
    if cfg.padded_vocab != cfg.vocab:
        return jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, logits, -1e30)
    return logits


def pad_to_slots(chunk: list, slots: int) -> list:
    """Pad a request chunk to exactly ``slots`` entries by repeating the last
    one (fixed-slot batching needs a full batch; duplicates are discarded by
    the caller). Raises on an empty chunk — there is nothing to repeat.

    Shared by the LM `BatchServer` and the summary-query server
    (`launch/summary_serve.py`)."""
    if not chunk:
        raise ValueError("cannot pad an empty chunk")
    return list(chunk) + [chunk[-1]] * (slots - len(chunk))


class BatchServer:
    """Fixed-slot continuous batching: requests occupy slots; every step is
    one batched decode; finished slots are refilled from the queue."""

    def __init__(self, cfg, params, batch_slots=4, max_len=64):
        self.cfg, self.params = cfg, params
        self.api = get_api(cfg)
        self.B, self.S = batch_slots, max_len
        self.decode = jax.jit(
            lambda p, c, t, pos: self.api.decode_step(p, cfg, c, t, pos))

    def run(self, prompts: list, gen_tokens: int = 16, greedy=True, seed=0):
        """prompts: list of 1-D int arrays (equal length for simplicity)."""
        if not prompts:  # nothing queued: don't pad (chunk[-1] of []) or decode
            return []
        cfg = self.cfg
        rng = np.random.default_rng(seed)
        out = []
        for i in range(0, len(prompts), self.B):
            chunk = pad_to_slots(prompts[i : i + self.B], self.B)
            toks = jnp.asarray(np.stack(chunk), jnp.int32)
            plen = toks.shape[1]
            logits, cache = self.api.prefill(
                self.params, cfg, {"tokens": toks}, cache_len=plen + gen_tokens)
            cur = jnp.argmax(mask_pad_logits(cfg, logits[:, -1]), axis=-1)[:, None].astype(jnp.int32)
            gen = [np.asarray(cur)]
            for g in range(gen_tokens - 1):
                logits, cache = self.decode(self.params, cache, cur, jnp.int32(plen + g))
                lg = mask_pad_logits(cfg, logits[:, -1] if logits.ndim == 3 else logits)
                cur = jnp.argmax(lg, axis=-1).reshape(-1, 1).astype(jnp.int32)
                gen.append(np.asarray(cur))
            seqs = np.concatenate(gen, axis=1)
            out.extend(seqs[: len(prompts[i : i + self.B])])
        return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    server = BatchServer(cfg, params)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=args.prompt_len) for _ in range(args.requests)]
    t0 = time.perf_counter()
    outs = server.run(prompts, gen_tokens=args.gen)
    dt = time.perf_counter() - t0
    total = args.requests * args.gen
    print(f"[serve] {args.requests} requests × {args.gen} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i}: {o.tolist()}")
    return outs


if __name__ == "__main__":
    main()
