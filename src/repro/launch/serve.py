"""Batched serving driver: continuous-batching loop over prefill + decode.

CPU-scale usage:
  python -m repro.launch.serve --arch qwen2.5-3b --smoke --requests 8
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.models.api import get_api


def mask_pad_logits(cfg, logits):
    if cfg.padded_vocab != cfg.vocab:
        return jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, logits, -1e30)
    return logits


def pad_to_slots(chunk: list, slots: int) -> list:
    """Pad a request chunk to exactly ``slots`` entries by repeating the last
    one (fixed-slot batching needs a full batch; duplicates are discarded by
    the caller). Raises on an empty chunk — there is nothing to repeat.

    Shared by the LM `BatchServer` and the summary-query server
    (`launch/summary_serve.py`)."""
    if not chunk:
        raise ValueError("cannot pad an empty chunk")
    return list(chunk) + [chunk[-1]] * (slots - len(chunk))


class RequestError:
    """Per-request failure record returned IN PLACE of an answer.

    A malformed request (or one cut off by a batch timeout) must not kill
    the whole drain loop — the server answers everything else and marks the
    failed slot with one of these, keeping submission-order alignment.
    Shared by the LM `BatchServer` and the summary-query server."""

    __slots__ = ("request", "reason")

    def __init__(self, request, reason: str):
        self.request = request
        self.reason = str(reason)

    def __repr__(self):
        return f"RequestError({self.request!r}, {self.reason!r})"


class BatchServer:
    """Fixed-slot continuous batching: requests occupy slots; every step is
    one batched decode; finished slots are refilled from the queue."""

    def __init__(self, cfg, params, batch_slots=4, max_len=64):
        self.cfg, self.params = cfg, params
        self.api = get_api(cfg)
        self.B, self.S = batch_slots, max_len
        self.decode = jax.jit(
            lambda p, c, t, pos: self.api.decode_step(p, cfg, c, t, pos))

    def _invalid_reason(self, arr: np.ndarray, ref_len):
        if arr.ndim != 1 or arr.size == 0:
            return "prompt must be a non-empty 1-D token array"
        if arr.dtype.kind not in "iu":
            return f"prompt dtype {arr.dtype} is not integer"
        if int(arr.min()) < 0 or int(arr.max()) >= self.cfg.vocab:
            return f"token ids out of range [0, {self.cfg.vocab})"
        if ref_len is not None and arr.size != ref_len:
            return f"prompt length {arr.size} != batch length {ref_len}"
        return None

    def run(self, prompts: list, gen_tokens: int = 16, greedy=True, seed=0,
            timeout: float | None = None):
        """prompts: list of 1-D int arrays (equal length for simplicity).

        Answers come back in submission order. A malformed prompt (wrong
        rank/dtype/length, out-of-vocab tokens) gets a `RequestError` in
        its slot instead of poisoning the whole drain loop. With
        ``timeout`` (wall-clock seconds) the loop stops starting new
        batches once the deadline passes — at least one batch always runs,
        finished answers are flushed, and the cut-off slots are marked
        with timeout `RequestError`\\ s."""
        if not prompts:  # nothing queued: don't pad (chunk[-1] of []) or decode
            return []
        cfg = self.cfg
        out: list = [None] * len(prompts)
        valid: list = []
        ref_len = None
        for i, p in enumerate(prompts):
            arr = np.asarray(p)
            reason = self._invalid_reason(arr, ref_len)
            if reason is not None:
                out[i] = RequestError(p, reason)
                continue
            ref_len = arr.size
            valid.append((i, arr))
        deadline = (None if timeout is None
                    else time.perf_counter() + float(timeout))
        started = False
        for c0 in range(0, len(valid), self.B):
            # the first batch always runs — a timeout bounds extra batches,
            # it never starves the queue of all progress
            if started and deadline is not None \
                    and time.perf_counter() >= deadline:
                break
            chunk = valid[c0 : c0 + self.B]
            toks = jnp.asarray(
                np.stack([a for _, a in pad_to_slots(chunk, self.B)]),
                jnp.int32)
            plen = toks.shape[1]
            logits, cache = self.api.prefill(
                self.params, cfg, {"tokens": toks}, cache_len=plen + gen_tokens)
            cur = jnp.argmax(mask_pad_logits(cfg, logits[:, -1]), axis=-1)[:, None].astype(jnp.int32)
            gen = [np.asarray(cur)]
            for g in range(gen_tokens - 1):
                logits, cache = self.decode(self.params, cache, cur, jnp.int32(plen + g))
                lg = mask_pad_logits(cfg, logits[:, -1] if logits.ndim == 3 else logits)
                cur = jnp.argmax(lg, axis=-1).reshape(-1, 1).astype(jnp.int32)
                gen.append(np.asarray(cur))
            seqs = np.concatenate(gen, axis=1)
            for j, (i, _) in enumerate(chunk):
                out[i] = seqs[j]
            started = True
        for i, p in enumerate(prompts):
            if out[i] is None:
                out[i] = RequestError(
                    p, f"batch timed out after {timeout:.3f}s; "
                       f"partial results flushed")
        return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    server = BatchServer(cfg, params)
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab, size=args.prompt_len) for _ in range(args.requests)]
    t0 = time.perf_counter()
    outs = server.run(prompts, gen_tokens=args.gen)
    dt = time.perf_counter() - t0
    total = args.requests * args.gen
    print(f"[serve] {args.requests} requests × {args.gen} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s)")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i}: {o.tolist()}")
    return outs


if __name__ == "__main__":
    main()
