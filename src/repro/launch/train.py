"""End-to-end training driver (example application + fault-tolerance host).

CPU-scale usage (quickstart / ~100M-model run):
  python -m repro.launch.train --arch mamba2-130m --smoke --steps 200
Resume after a crash (restores the latest checkpoint, replays the stream):
  python -m repro.launch.train --arch mamba2-130m --smoke --steps 200 --resume
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config
from repro.data.pipeline import TokenStream, make_batch
from repro.launch.mesh import dp_axes_of, make_host_mesh
from repro.models.api import get_api
from repro.optim import adamw
from repro.train import checkpoint as CKPT
from repro.train.fault_tolerance import FaultToleranceConfig, ResilientLoop
from repro.train.train_step import TrainPlan, build_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    mesh = make_host_mesh(args.data_parallel, args.model_parallel)
    dp = dp_axes_of(mesh)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    plan = TrainPlan(cfg=cfg, mesh=mesh, dp_axes=dp,
                     opt=adamw.AdamWConfig(lr=args.lr), total_steps=args.steps)
    step_fn, state_sh, batch_sh, state_abs = build_train_step(plan, shape)

    api = get_api(cfg)
    stream = TokenStream(cfg.vocab, args.batch, args.seq, seed=args.seed)

    start_step = 0
    state = None
    if args.resume:
        state, start_step = CKPT.restore(state_abs, args.ckpt_dir, shardings=state_sh)
        if state is not None:
            print(f"[train] resumed from step {start_step}")
    if state is None:
        params = api.init_params(cfg, jax.random.key(args.seed))
        state = {"params": params, "opt": adamw.init_state(params)}
        start_step = 0

    ckpt = CKPT.AsyncCheckpointer(args.ckpt_dir)
    losses = []

    def metrics_cb(step, metrics):
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0:
            print(f"[train] step {step:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}")

    def restore_fn():
        st, sp = CKPT.restore(state_abs, args.ckpt_dir, shardings=state_sh)
        return st, sp

    loop = ResilientLoop(
        step_fn=step_fn,
        state=state,
        make_batch=lambda s: make_batch(cfg, stream, s),
        checkpointer=ckpt,
        ft=FaultToleranceConfig(ckpt_every=args.ckpt_every),
        restore_fn=restore_fn,
    )
    t0 = time.perf_counter()
    state, end_step = loop.run(start_step, args.steps - start_step, metrics_cb)
    ckpt.close()
    dt = time.perf_counter() - t0
    print(f"[train] finished at step {end_step} in {dt:.1f}s "
          f"({(end_step-start_step)/max(dt,1e-9):.2f} steps/s); "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}" if losses else "")
    return losses


if __name__ == "__main__":
    main()
