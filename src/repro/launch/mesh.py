"""Production mesh builders (assignment: 16×16 single-pod, 2×16×16 multi-pod).

``make_production_mesh`` is a FUNCTION so importing this module never touches
jax device state; the dry-run sets XLA_FLAGS *before* any jax import.
"""
from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    """jax.make_mesh with Auto axis types; older jax has no AxisType (its
    meshes are Auto by default)."""
    try:
        kinds = (jax.sharding.AxisType.Auto,) * len(axes)
    except AttributeError:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=kinds)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def dp_axes_of(mesh) -> tuple:
    """All non-'model' axes act as data parallelism."""
    return tuple(a for a in mesh.axis_names if a != "model")


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    model = min(model, n)
    data = max(1, min(data, n // model))
    return _make_mesh((data, model), ("data", "model"))


def make_data_mesh():
    """Pure data-parallel mesh over every visible device — what the
    summarization engine's mesh-dispatched shingle/Jaccard path shards over
    (`core/engine.SummarizerEngine`, DESIGN.md §8)."""
    return _make_mesh((len(jax.devices()),), ("data",))
