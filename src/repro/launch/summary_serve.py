"""Batched summary-query serving driver: continuous batching over a frozen
summary artifact.

The LM path (`launch/serve.py`) drains a prompt queue through fixed decode
slots; this driver drains a `neighbors`/`edge_exists` query queue through
fixed query slots against a `PackedSummary` (`core/summary_ir.py`), answered
whole-batch-at-a-time by `core/query_batch`. Short final chunks share
`serve.pad_to_slots`.

CPU-scale usage:
  PYTHONPATH=src python -m repro.launch.summary_serve --smoke
  PYTHONPATH=src python -m repro.launch.summary_serve --edges 220k --backend jax
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time

import numpy as np

from repro.core.query_batch import (BACKENDS, edge_exists_batch,
                                    neighbors_batch)
from repro.core.slugger import summarize
from repro.core.summary_ir import PackedSummary
from repro.graphs.generators import SERVING_GRAPHS
from repro.launch.serve import RequestError, pad_to_slots


class SummaryQueryServer:
    """Fixed-slot continuous batching for summary queries: queries occupy
    slots, every step answers one full batch, finished slots refill from the
    queue — the `BatchServer` drain loop with batched interval sweeps in
    place of decode steps. Short final chunks are padded by repeating the
    last query (`pad_to_slots`) and the pad answers dropped."""

    def __init__(self, packed: PackedSummary, batch_slots: int = 256,
                 backend: str = "numpy"):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; use one of {BACKENDS}")
        self.ps = packed
        self.B = int(batch_slots)
        self.backend = backend

    def _invalid_reason(self, q):
        """Reason string for a malformed/out-of-range query, else None."""
        if not isinstance(q, (tuple, list)) or not q:
            return "query must be a ('neighbors', v) or ('edge', u, v) tuple"
        kind = q[0]
        if kind not in ("neighbors", "edge"):
            return f"unknown query kind {kind!r}"
        want = 2 if kind == "neighbors" else 3
        if len(q) != want:
            return f"{kind!r} query takes {want - 1} id(s), got {len(q) - 1}"
        for v in q[1:]:
            if not isinstance(v, (int, np.integer)):
                return f"query id {v!r} is not an integer"
            if not 0 <= int(v) < self.ps.n_leaves:
                return (f"query id {int(v)} out of range "
                        f"[0, {self.ps.n_leaves})")
        return None

    def run(self, queries: list, timeout: float | None = None) -> list:
        """``queries``: ("neighbors", v) or ("edge", u, v) tuples.

        Returns answers in submission order: a sorted int64 id array per
        neighbors query, a bool per edge query. A malformed or
        out-of-range query gets a `RequestError` record in its slot — the
        drain loop keeps serving the rest of the batch. With ``timeout``
        (wall-clock seconds) no NEW batch starts after the deadline (the
        first always runs); answered batches are flushed and cut-off
        queries come back as timeout `RequestError`\\ s."""
        if not queries:
            return []
        out: list = [None] * len(queries)
        nb: list = []
        eg: list = []
        for i, q in enumerate(queries):
            reason = self._invalid_reason(q)
            if reason is not None:
                out[i] = RequestError(q, reason)
            elif q[0] == "neighbors":
                nb.append((i, q[1]))
            else:
                eg.append((i, q[1], q[2]))
        deadline = (None if timeout is None
                    else time.perf_counter() + float(timeout))
        started = False

        def expired():
            return (started and deadline is not None
                    and time.perf_counter() >= deadline)

        for c0 in range(0, len(nb), self.B):
            if expired():
                break
            real = nb[c0: c0 + self.B]
            vs = np.array([v for _, v in pad_to_slots(real, self.B)], dtype=np.int64)
            indptr, ids = neighbors_batch(self.ps, vs, backend=self.backend)
            for j, (i, _) in enumerate(real):
                out[i] = ids[indptr[j]: indptr[j + 1]]
            started = True
        for c0 in range(0, len(eg), self.B):
            if expired():
                break
            real = eg[c0: c0 + self.B]
            chunk = pad_to_slots(real, self.B)
            us = np.array([u for _, u, _ in chunk], dtype=np.int64)
            vs = np.array([v for _, _, v in chunk], dtype=np.int64)
            hit = edge_exists_batch(self.ps, us, vs, backend=self.backend)
            for j, (i, _, _) in enumerate(real):
                out[i] = bool(hit[j])
            started = True
        for i, q in enumerate(queries):
            if out[i] is None:
                out[i] = RequestError(
                    q, f"batch timed out after {timeout:.3f}s; "
                       f"partial results flushed")
        return out


def make_queries(n: int, count: int, edge_frac: float = 0.25, seed: int = 1) -> list:
    rng = np.random.default_rng(seed)
    kinds = rng.random(count) < edge_frac
    a = rng.integers(0, n, size=count)
    b = rng.integers(0, n, size=count)
    return [("edge", int(a[i]), int(b[i])) if kinds[i]
            else ("neighbors", int(a[i])) for i in range(count)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny graph + save/load round-trip + answer check")
    ap.add_argument("--edges", default="55k", choices=sorted(SERVING_GRAPHS))
    ap.add_argument("--backend", default="numpy", choices=BACKENDS)
    ap.add_argument("--requests", type=int, default=4096)
    ap.add_argument("--batch-slots", type=int, default=256)
    ap.add_argument("--artifact", default=None,
                    help="write the packed .npz here and serve from the reload")
    ap.add_argument("--iters", type=int, default=5, help="merge iterations")
    args = ap.parse_args(argv)

    name = "smoke" if args.smoke else args.edges
    g = SERVING_GRAPHS[name]()
    print(f"[summary-serve] graph {name}: {g.n} nodes, {g.m} edges")
    t0 = time.perf_counter()
    s = summarize(g, T=args.iters, seed=0)
    packed = s.pack_for_serving()
    print(f"[summary-serve] summarized+packed in {time.perf_counter()-t0:.2f}s "
          f"(cost {s.cost()}, artifact {packed.nbytes()/1e6:.2f} MB)")

    path = args.artifact
    if args.smoke and path is None:
        path = os.path.join(tempfile.mkdtemp(prefix="slugger-serve-"),
                            "packed.npz")
    if path is not None:
        path = packed.save(path)  # save normalizes to the real .npz path
        packed = PackedSummary.load(path)
        print(f"[summary-serve] artifact round-trip via {path}")

    requests = 256 if args.smoke else args.requests
    queries = make_queries(g.n, requests)
    server = SummaryQueryServer(packed, batch_slots=args.batch_slots,
                                backend=args.backend)
    server.run(queries[: args.batch_slots])  # warm jit/kernel caches
    t0 = time.perf_counter()
    answers = server.run(queries)
    dt = time.perf_counter() - t0
    print(f"[summary-serve] {len(queries)} queries in {dt:.3f}s "
          f"({len(queries)/dt:.0f} q/s, backend={args.backend}, "
          f"slots={args.batch_slots})")

    if args.smoke:
        # every answer must match the per-call reference engine
        for q, a in zip(queries, answers):
            if q[0] == "neighbors":
                assert np.array_equal(a, s.neighbors(q[1])), q
            else:
                want = bool(np.isin(q[2], s.neighbors(q[1])))
                assert a == want, q
        print(f"[summary-serve] smoke OK: {len(queries)} answers match the "
              "per-call engine")
    return answers


if __name__ == "__main__":
    main()
