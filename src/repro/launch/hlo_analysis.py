"""Trip-count-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so any model
that scans over layers (all of ours — small HLO, fast multi-pod compiles)
under-reports FLOPs/bytes/collectives by ~the layer count.  This module
re-derives the three roofline inputs from the optimized HLO text itself:

  * computations are parsed into a call graph (fusion ``calls=``, while
    ``condition=/body=``, ``to_apply=``),
  * each ``while`` multiplies its body+cond cost by the trip count recovered
    from the loop condition (scalar integer constant in the cond computation),
  * dot/convolution FLOPs are computed exactly from operand/result shapes,
    elementwise ops contribute numel,
  * bytes = operand + result bytes at fusion granularity (the optimized HLO is
    post-fusion, so this matches "HBM traffic" the way XLA's own
    bytes-accessed does),
  * collective bytes are summed per category (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute) with loop multipliers.

Validated against ``cost_analysis()`` on loop-free programs and against
hand-unrolled scans in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# "%name = <type> opcode(...), attrs" — opcode is letters/dashes
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s([a-z][a-z0-9\-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->\s*(.*?)\s*{\s*$")
_PARAM_RE = re.compile(r"%?([\w\.\-]+):\s*((?:\([^)]*\)|[\w\[\],\{\}\/\* ]+))")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONST_RE = re.compile(r"[su](?:8|16|32|64)\[\]\s+constant\((\d+)\)")

# opcodes that move no data / cost nothing
_FREE = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
         "after-all", "partition-id", "replica-id", "iota", "copy-start",
         "copy-done", "domain", "opt-barrier"}
# elementwise-ish ops: 1 flop per output element
_EltWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "tanh", "rsqrt", "sqrt", "power",
    "floor", "ceil", "sign", "compare", "select", "and", "or", "xor", "not",
    "atan2", "remainder", "clamp", "exponential-minus-one", "log-plus-one",
    "logistic", "cosine", "sine", "round-nearest-afz", "round-nearest-even",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "erf",
    "cbrt", "tan", "popcnt", "count-leading-zeros", "stochastic-convert",
}


def _shape_elems(shape_str: str):
    """All (dtype, numel) arrays inside a (possibly tuple) type string."""
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        yield dt, n


def shape_bytes(shape_str: str) -> int:
    return sum(n * _DTYPE_BYTES[dt] for dt, n in _shape_elems(shape_str))


def shape_numel(shape_str: str) -> int:
    return sum(n for _, n in _shape_elems(shape_str))


def _first_dims(shape_str: str):
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    result_type: str
    opcode: str
    rest: str              # everything after the '(' — operands + attrs


@dataclass
class Computation:
    name: str
    params: dict = field(default_factory=dict)   # name -> type string
    ops: list = field(default_factory=list)
    text: str = ""

    def shape_of(self, operand: str, table: dict) -> str:
        if operand in table:
            return table[operand]
        return self.params.get(operand, "")


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    coll_count: dict = field(default_factory=lambda: {c: 0 for c in COLLECTIVES})

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for c in COLLECTIVES:
            self.coll[c] += o.coll[c]
            self.coll_count[c] += o.coll_count[c]
        return self

    def scaled(self, k: float) -> "Cost":
        out = Cost(self.flops * k, self.bytes * k)
        for c in COLLECTIVES:
            out.coll[c] = self.coll[c] * k
            out.coll_count[c] = int(self.coll_count[c] * k)
        return out


def parse_computations(hlo_text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_RE.match(line.strip())
            if m and ("->" in line):
                cur = Computation(name=m.group(1))
                for pm in _PARAM_RE.finditer(m.group(2)):
                    cur.params[pm.group(1)] = pm.group(2)
                continue
        else:
            s = line.strip()
            if s == "}" or s.startswith("}"):
                comps[cur.name] = cur
                cur = None
                continue
            om = _OP_RE.match(s)
            if om:
                cur.ops.append(Op(om.group(1), om.group(2), om.group(3), om.group(4)))
            cur.text += s + "\n"
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _attr_ref(rest: str, attr: str):
    m = re.search(attr + r"=%?([\w\.\-]+)", rest)
    return m.group(1) if m else None


def _dims_attr(rest: str, attr: str):
    m = re.search(attr + r"=\{([\d,]*)\}", rest)
    if not m:
        return []
    return [int(d) for d in m.group(1).split(",") if d]


def trip_count(cond: Computation) -> int:
    """Largest scalar integer constant in the loop condition. JAX scans and
    fori_loops lower to `i < N` with N literal in the cond computation; when
    nothing is found the loop is dynamic and we conservatively use 1."""
    consts = [int(v) for v in _CONST_RE.findall(cond.text)]
    # also catch constants declared in the caller and passed in — present in
    # the cond body for all jax.lax.scan/fori_loop lowerings we emit
    return max(consts) if consts else 1


class Analyzer:
    def __init__(self, hlo_text: str):
        self.comps = parse_computations(hlo_text)
        self._memo: dict[str, Cost] = {}
        self.while_loops: list = []
        # entry = computation whose name appears after ENTRY, else the one
        # that is not referenced by anyone (fallback: last parsed)
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", hlo_text, re.M)
        self.entry = m.group(1) if m and m.group(1) in self.comps else None
        if self.entry is None:
            referenced = set()
            for c in self.comps.values():
                for o in c.ops:
                    for a in ("calls", "condition", "body", "to_apply"):
                        r = _attr_ref(o.rest, a)
                        if r:
                            referenced.add(r)
            roots = [n for n in self.comps if n not in referenced]
            self.entry = roots[-1] if roots else list(self.comps)[-1]

    # -------------------------------------------------------------- FLOPs
    def _dot_flops(self, comp: Computation, op: Op, table: dict) -> float:
        out_elems = shape_numel(op.result_type)
        operands = _OPERAND_RE.findall(op.rest.split(", lhs_")[0])
        lhs_shape = comp.shape_of(operands[0], table) if operands else ""
        lhs_dims = _first_dims(lhs_shape)
        contract = _dims_attr(op.rest, "lhs_contracting_dims")
        k = 1
        for d in contract:
            if d < len(lhs_dims):
                k *= lhs_dims[d]
        return 2.0 * out_elems * max(k, 1)

    def _conv_flops(self, comp: Computation, op: Op, table: dict) -> float:
        out_elems = shape_numel(op.result_type)
        operands = _OPERAND_RE.findall(op.rest.split("), ")[0] + ")")
        if len(operands) < 2:
            return 2.0 * out_elems
        kshape = _first_dims(comp.shape_of(operands[1], table))
        kelems = 1
        for d in kshape:
            kelems *= d
        # dim_labels like b01f_01io->b01f : output-feature dim 'o' in kernel
        m = re.search(r"dim_labels=\w+_(\w+)->", op.rest)
        out_feat = 1
        if m and kshape:
            lbl = m.group(1)
            oi = lbl.find("o")
            if 0 <= oi < len(kshape):
                out_feat = kshape[oi]
        groups = 1
        g = re.search(r"feature_group_count=(\d+)", op.rest)
        if g:
            groups = int(g.group(1))
        return 2.0 * out_elems * kelems / max(out_feat, 1) / max(groups, 1)

    # ------------------------------------------------------- slice analysis
    def _param_index(self, comp: Computation, opname: str):
        """Resolve an operand name through bitcast/convert/copy chains to a
        fusion parameter index, or None."""
        defs = {o.name: o for o in comp.ops}
        seen = 0
        while opname in defs and seen < 20:
            o = defs[opname]
            if o.opcode == "parameter":
                m = re.match(r"(\d+)", o.rest)  # "12), ..." -> 12
                if m:
                    return int(m.group(1))
                break
            if o.opcode in ("bitcast", "convert", "copy"):
                ops = _OPERAND_RE.findall(o.rest)
                if not ops:
                    return None
                opname = ops[0]
                seen += 1
            else:
                return None
        m = re.match(r"param_(\d+)", opname)
        return int(m.group(1)) if m else None

    def _fusion_slice_adjust(self, comp: Computation, table: dict):
        """For a fused computation: which fusion operands are only read
        through dynamic-slice (charge slice bytes), and whether the root is a
        dynamic-update-slice (charge update bytes for the in-place result).

        Returns (sliced: {param_idx: slice_bytes}, dus_update_bytes|None).
        """
        sliced = {}
        dus_bytes = None
        for o in comp.ops:
            if o.opcode == "dynamic-slice":
                ops = _OPERAND_RE.findall(o.rest)
                pi = self._param_index(comp, ops[0]) if ops else None
                if pi is not None:
                    sliced[pi] = sliced.get(pi, 0) + shape_bytes(o.result_type)
            elif o.opcode == "dynamic-update-slice":
                ops = _OPERAND_RE.findall(o.rest)
                if len(ops) >= 2:
                    upd = comp.shape_of(ops[1], {x.name: x.result_type for x in comp.ops})
                    ub = shape_bytes(upd)
                    pi = self._param_index(comp, ops[0])
                    if pi is not None:
                        sliced[pi] = sliced.get(pi, 0) + ub
                    dus_bytes = (dus_bytes or 0) + ub
        return sliced, dus_bytes

    # ---------------------------------------------------------------- cost
    def cost_of(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            self._memo[name] = total
            return total
        self._memo[name] = total  # break cycles defensively
        table = {o.name: o.result_type for o in comp.ops}
        for op in comp.ops:
            oc = op.opcode
            if oc in _FREE:
                continue
            if oc == "while":
                body = _attr_ref(op.rest, "body")
                cond = _attr_ref(op.rest, "condition")
                trips = trip_count(self.comps[cond]) if cond in self.comps else 1
                inner = Cost()
                inner += self.cost_of(body)
                inner += self.cost_of(cond)
                self.while_loops.append({"name": op.name, "body": body, "trips": trips})
                total += inner.scaled(trips)
                continue
            if oc == "conditional":
                # count the most expensive branch
                branches = re.findall(r"(?:branch_computations=\{([^}]*)\}|true_computation=%?([\w\.\-]+), false_computation=%?([\w\.\-]+))", op.rest)
                names = []
                for b in branches:
                    for part in b:
                        if part:
                            names += [p.strip().lstrip("%") for p in part.split(",")]
                if names:
                    best = max((self.cost_of(n) for n in names), key=lambda c: c.flops + c.bytes)
                    total += best
                continue
            # collectives -------------------------------------------------
            cat = next((c for c in COLLECTIVES if oc.startswith(c)), None)
            if cat is not None and not oc.endswith("-done"):
                # traffic ≈ operand bytes (the shard each device contributes)
                opers = _OPERAND_RE.findall(op.rest.split(")")[0])
                b = sum(shape_bytes(comp.shape_of(o, table)) for o in opers)
                if b == 0:
                    b = shape_bytes(op.result_type)
                total.coll[cat] += b
                total.coll_count[cat] += 1
                total.bytes += b + shape_bytes(op.result_type)
                continue
            if oc.endswith("-done"):
                continue
            # flops -------------------------------------------------------
            if oc == "dot":
                total.flops += self._dot_flops(comp, op, table)
            elif oc == "convolution":
                total.flops += self._conv_flops(comp, op, table)
            elif oc in _EltWISE:
                total.flops += shape_numel(op.result_type)
            elif oc in ("reduce", "reduce-window"):
                opers = _OPERAND_RE.findall(op.rest.split(")")[0])
                if opers:
                    total.flops += shape_numel(comp.shape_of(opers[0], table))
            # descend for called computations (fusions carry their flops;
            # bytes stay at the fusion boundary — internal values never touch
            # HBM, so only `call`/`map` bodies contribute their own bytes)
            callee = _attr_ref(op.rest, "calls") or (
                _attr_ref(op.rest, "to_apply") if oc in ("call", "map") else None)
            if callee:
                sub = self.cost_of(callee)
                total.flops += sub.flops
                for c in COLLECTIVES:
                    total.coll[c] += sub.coll[c]
                    total.coll_count[c] += sub.coll_count[c]
                if oc != "fusion":
                    total.bytes += sub.bytes
            # bytes -------------------------------------------------------
            # charged at fusion/op boundary; slicing ops touch only the slice
            # (matching XLA's HloCostAnalysis semantics for DS/DUS/gather)
            head = op.rest.split(", calls=")[0].split(", to_apply=")[0]
            opers = _OPERAND_RE.findall(head.split("), ")[0])
            res_bytes = shape_bytes(op.result_type)
            if oc == "dynamic-slice":
                total.bytes += 2 * res_bytes
            elif oc == "dynamic-update-slice":
                upd = shape_bytes(comp.shape_of(opers[1], table)) if len(opers) > 1 else res_bytes
                total.bytes += 2 * upd
            elif oc == "gather":
                total.bytes += 2 * res_bytes
            elif oc == "scatter":
                upd = shape_bytes(comp.shape_of(opers[2], table)) if len(opers) > 2 else res_bytes
                total.bytes += 2 * upd
            elif oc == "fusion" and callee and callee in self.comps:
                fcomp = self.comps[callee]
                f_table = {o.name: o.result_type for o in fcomp.ops}
                sliced, dus_bytes = self._fusion_slice_adjust(fcomp, f_table)
                b_in = 0
                for i, o in enumerate(opers):
                    b_in += sliced[i] if i in sliced else shape_bytes(comp.shape_of(o, table))
                total.bytes += b_in + (dus_bytes if dus_bytes is not None else res_bytes)
            else:
                b_in = sum(shape_bytes(comp.shape_of(o, table)) for o in opers)
                total.bytes += b_in + res_bytes
        self._memo[name] = total
        return total

    def analyze(self) -> dict:
        c = self.cost_of(self.entry)
        coll_total = sum(c.coll.values())
        return {
            "flops": c.flops,
            "bytes": c.bytes,
            "coll_bytes": coll_total,
            "coll": dict(c.coll),
            "coll_count": dict(c.coll_count),
            "while_loops": self.while_loops,
        }


def analyze_hlo(hlo_text: str) -> dict:
    """Per-device flops / bytes / collective bytes of an optimized HLO module,
    with while-loop bodies multiplied by their trip counts."""
    return Analyzer(hlo_text).analyze()
