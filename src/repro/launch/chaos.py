"""Chaos smoke: kill the summarizer at every stage boundary and prove the
plan-log checkpoint resumes bit-identically (DESIGN.md §11).

Default mode injects an `InjectedFault` at each of the five engine stage
boundaries (``engine.shingle``/``group``/``pack``/``merge_round``/
``exchange``) mid-run, then resumes from the surviving checkpoint and
asserts the summary equals an uninterrupted run array-for-array — the CI
teeth behind the crash-safety claim. ``--kernel-fault`` instead injects a
Pallas dispatch fault into a resident-backend run and asserts the engine
finishes on the jnp twin with a lossless, numpy-identical summary and a
non-zero degradation count (pair with ``REPRO_FORCE_PALLAS=1`` so the
kernel path is actually live on CPU).

CI usage:
  PYTHONPATH=src python -m repro.launch.chaos
  REPRO_FORCE_PALLAS=1 PYTHONPATH=src python -m repro.launch.chaos --kernel-fault
"""
from __future__ import annotations

import argparse
import shutil
import tempfile

import numpy as np

from repro import faults
from repro.core.engine import STAGE_ORDER, SummarizerEngine
from repro.graphs import generators


def _engine(backend: str = "numpy", partitions: int = 1,
            T: int = 5) -> SummarizerEngine:
    return SummarizerEngine(partitions=partitions, backend=backend, T=T,
                            seed=3)


def run_stage_kills(T: int = 5, kill_at: int = 3) -> int:
    """Kill at every stage boundary of iteration ``kill_at``; resume each
    time and demand bit-identity with the uninterrupted run."""
    g = generators.caveman(14, 6, 0.05, seed=13)
    want = _engine(T=T).run(g)
    assert want.validate_lossless(g)
    for stage in STAGE_ORDER:
        ckpt = tempfile.mkdtemp(prefix=f"slugger-chaos-{stage}-")
        try:
            try:
                with faults.inject(f"engine.{stage}", iteration=kill_at):
                    _engine(T=T).run(g, checkpoint_dir=ckpt)
                raise AssertionError(f"engine.{stage} fault never fired")
            except faults.InjectedFault:
                pass
            eng = _engine(T=T)
            got = eng.run(g, checkpoint_dir=ckpt, resume=True)
            resumed = eng.stats.get("resumed_from")
            # the commit lands AFTER iteration kill_at's stages, so every
            # kill inside iteration kill_at resumes from kill_at - 1
            assert resumed == kill_at - 1, (stage, resumed)
            assert np.array_equal(got.parent, want.parent), stage
            assert np.array_equal(got.edges, want.edges), stage
            assert got.validate_lossless(g), stage
            print(f"[chaos] kill @ engine.{stage} (iter {kill_at}): resumed "
                  f"from {resumed}, bit-identical")
        finally:
            shutil.rmtree(ckpt, ignore_errors=True)
    print(f"[chaos] OK: {len(STAGE_ORDER)} stage-boundary kills, "
          f"{len(STAGE_ORDER)} bit-identical resumes")
    return 0


def run_kernel_fault(T: int = 3) -> int:
    """Inject a Pallas dispatch fault into a resident run: the engine must
    retry on the jnp reference twin and finish losslessly, numpy-identical,
    with the degradation counted."""
    g = generators.caveman(40, 5, 0.05, seed=0)
    want = _engine(T=T).run(g)
    eng = _engine(backend="resident", T=T)
    # kernel sites carry no engine-iteration context (the check sits in the
    # dispatch wrapper) — target the Nth dispatch instead
    with faults.inject("kernel.bitset_fold.round", hit=2):
        got = eng.run(g)
    degr = eng.stats["degradations"]
    assert degr > 0, "kernel fault injected but no degradation recorded"
    assert np.array_equal(got.parent, want.parent)
    assert np.array_equal(got.edges, want.edges)
    assert got.validate_lossless(g)
    print(f"[chaos] OK: kernel dispatch fault degraded to the jnp twin "
          f"({degr} degradation(s)), summary lossless and numpy-identical")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel-fault", action="store_true",
                    help="resident-backend Pallas dispatch fault → jnp-twin "
                         "fallback (pair with REPRO_FORCE_PALLAS=1)")
    ap.add_argument("--iters", type=int, default=5,
                    help="engine iterations T for the stage-kill mode")
    ap.add_argument("--kill-at", type=int, default=3,
                    help="iteration the stage-boundary faults fire in")
    args = ap.parse_args(argv)
    if args.kernel_fault:
        return run_kernel_fault()
    return run_stage_kills(T=args.iters, kill_at=args.kill_at)


if __name__ == "__main__":
    raise SystemExit(main())
