"""repro: SLUGGER lossless hierarchical graph summarization — JAX framework."""
__version__ = "1.0.0"
