"""Flat Summary IR: Euler-tour/DFS-interval view of a merge forest.

Every post-merge stage (encoding emission, pruning, partial/full
decompression) used to walk the forest through recursive ``TreeView`` builds
or dict-of-set adjacency. The IR replaces those with five int64 arrays plus
two CSR indexes, built level-synchronously in O(height) vectorized passes:

  ``first[x] : last[x]``  half-open interval of x's leaves in global DFS order
  ``depth[x]``            #ancestors of x (roots are 0; dead ids are -1)
  ``parent[x]``           forest parent (-1 root, -2 pruned tombstone)
  ``order[p]``            leaf id at DFS position p  (``pos_of`` inverts it)
  ``child_ptr/child_ids`` CSR children, siblings ordered by id == by ``first``
  ``inc_ptr/inc_eid``     CSR signed-edge incidence (built per edge array)

Leaf membership of any supernode is the single gather
``order[first[x]:last[x]]``; ancestor tests are interval containment; subtree
aggregates are ``reduceat`` over root intervals. DESIGN.md §5.

Construction relies on the forest invariant ``parent[x] > x`` for every
alive non-root (merges always mint fresh, larger parent ids and pruning only
splices, which preserves the property); the builder asserts it.
"""
from __future__ import annotations

import numpy as np


def segmented_indices(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Flat gather indices for CSR slices: ``concat(arange(s, s+l))``.

    The one CSR-expansion idiom every IR consumer shares — one np.repeat of
    the slice starts plus a per-segment local offset."""
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    ends = np.cumsum(lens)
    return np.repeat(starts, lens) + (
        np.arange(total, dtype=np.int64) - np.repeat(ends - lens, lens)
    )


def canon_edges(arr: np.ndarray) -> np.ndarray:
    """Canonical (lo, hi, sign) lexicographic row order. Edge row order is
    not semantically meaningful, so every emitter/pruner exports this order
    and equivalence tests can compare arrays bit-for-bit."""
    arr = np.asarray(arr, dtype=np.int64).reshape(-1, 3)
    if arr.shape[0] == 0:
        return np.zeros((0, 3), dtype=np.int64)
    lo = np.minimum(arr[:, 0], arr[:, 1])
    hi = np.maximum(arr[:, 0], arr[:, 1])
    arr = np.stack([lo, hi, arr[:, 2]], axis=1)
    return arr[np.lexsort((arr[:, 2], arr[:, 1], arr[:, 0]))]


def group_pairs(a: np.ndarray, b: np.ndarray):
    """Group index pairs without forming a combined integer key.

    Returns ``(order, starts)``: ``order`` sorts the pairs lexicographically
    by (a, b) and ``starts`` marks the first element of each distinct pair in
    the sorted view (append ``len`` for bounds). Unlike the
    ``a * (max(b)+1) + b`` keying this cannot overflow int64 for any id range
    — the same reason ``SluggerState.gather_rows`` keys with a bounded
    multiplier; here we drop the multiplier entirely and split on the sorted
    component diffs instead.
    """
    a = np.asarray(a, dtype=np.int64)
    b = np.asarray(b, dtype=np.int64)
    order = np.lexsort((b, a))
    if a.size == 0:
        return order, np.zeros(0, dtype=np.int64)
    sa, sb = a[order], b[order]
    head = np.empty(a.size, dtype=bool)
    head[0] = True
    np.not_equal(sa[1:], sa[:-1], out=head[1:])
    head[1:] |= sb[1:] != sb[:-1]
    return order, np.flatnonzero(head)


class SummaryIR:
    """Flat interval representation of one merge forest."""

    __slots__ = (
        "n_leaves", "n_ids", "parent", "alive", "depth", "first", "last",
        "order", "pos_of", "child_ptr", "child_ids", "roots", "levels",
        "inc_ptr", "inc_eid",
    )

    def __init__(self, parent: np.ndarray, n_leaves: int):
        parent = np.asarray(parent, dtype=np.int64)
        n_ids = parent.shape[0]
        self.n_leaves = int(n_leaves)
        self.n_ids = n_ids
        self.parent = parent
        self.alive = parent > -2
        ids = np.arange(n_ids, dtype=np.int64)
        has_par = self.alive & (parent >= 0)
        if has_par.any() and not (parent[has_par] > ids[has_par]).all():
            raise ValueError("SummaryIR requires parent[x] > x (merge-forest order)")

        # children CSR: stable sort by parent keeps siblings id-ascending,
        # which below becomes first-ascending as intervals are dealt in order.
        kids = ids[has_par]
        kpar = parent[kids]
        k_order = np.argsort(kpar, kind="stable")
        self.child_ids = kids[k_order]
        counts = np.bincount(kpar, minlength=n_ids)
        self.child_ptr = np.zeros(n_ids + 1, dtype=np.int64)
        np.cumsum(counts, out=self.child_ptr[1:])

        self.roots = ids[self.alive & (parent == -1)]
        depth = np.full(n_ids, -1, dtype=np.int64)
        depth[self.roots] = 0
        # level-synchronous BFS: each pass gathers the children of the whole
        # frontier through the CSR in one repeat/arange indexing op.
        levels = [self.roots]
        frontier = self.roots
        while True:
            lens = self.child_ptr[frontier + 1] - self.child_ptr[frontier]
            idx = segmented_indices(self.child_ptr[frontier], lens)
            if idx.size == 0:
                break
            nxt = self.child_ids[idx]
            depth[nxt] = depth[np.repeat(frontier, lens)] + 1
            levels.append(nxt)
            frontier = nxt
        self.depth = depth
        self.levels = levels

        # subtree leaf counts, bottom-up one level at a time (duplicate
        # parents within a level are why this is add.at and not plain fancy
        # assignment).
        nleaf = np.zeros(n_ids, dtype=np.int64)
        nleaf[: self.n_leaves][self.alive[: self.n_leaves]] = 1
        for lvl in levels[:0:-1]:
            np.add.at(nleaf, parent[lvl], nleaf[lvl])

        # DFS intervals, top-down: roots get consecutive blocks in id order;
        # each child starts at its parent's start plus the leaf mass of its
        # earlier siblings (an exclusive segment prefix-sum).
        first = np.full(n_ids, -1, dtype=np.int64)
        csum = np.cumsum(nleaf[self.roots])
        first[self.roots] = csum - nleaf[self.roots]
        for lvl in levels[:-1]:
            lens = self.child_ptr[lvl + 1] - self.child_ptr[lvl]
            par_l = lvl[lens > 0]
            lens = lens[lens > 0]
            total = int(lens.sum())
            if total == 0:
                continue
            ends = np.cumsum(lens)
            idx = segmented_indices(self.child_ptr[par_l], lens)
            kids_l = self.child_ids[idx]
            pref = np.cumsum(nleaf[kids_l]) - nleaf[kids_l]
            seg_base = np.repeat(pref[ends - lens], lens)
            first[kids_l] = np.repeat(first[par_l], lens) + (pref - seg_base)
        self.first = first
        self.last = first + nleaf

        leaves = np.arange(self.n_leaves, dtype=np.int64)
        self.pos_of = first[: self.n_leaves].copy()
        order = np.empty(self.n_leaves, dtype=np.int64)
        if self.n_leaves:
            order[self.pos_of] = leaves
        self.order = order
        self.inc_ptr = None
        self.inc_eid = None

    # ------------------------------------------------------------- accessors
    def size(self, x) -> np.ndarray:
        return self.last[x] - self.first[x]

    def leaves_of(self, x: int) -> np.ndarray:
        """Leaf ids contained in supernode x (DFS order) — one gather."""
        return self.order[self.first[x]: self.last[x]]

    def children_of(self, x: int) -> np.ndarray:
        return self.child_ids[self.child_ptr[x]: self.child_ptr[x + 1]]

    def n_children(self) -> np.ndarray:
        return self.child_ptr[1:] - self.child_ptr[:-1]

    def max_children(self) -> int:
        return int(self.n_children().max()) if self.n_ids else 0

    def tree_heights(self) -> np.ndarray:
        """Height of each root's tree = max leaf depth inside its interval."""
        if self.roots.size == 0:
            return np.zeros(0, dtype=np.int64)
        leaf_depth = self.depth[self.order]  # depth per DFS position
        starts = self.first[self.roots]
        nonempty = self.last[self.roots] > starts
        out = np.zeros(self.roots.size, dtype=np.int64)
        if nonempty.any():
            out[nonempty] = np.maximum.reduceat(leaf_depth, starts[nonempty])
        return out

    # ------------------------------------------------------------- incidence
    def build_incidence(self, edges: np.ndarray):
        """CSR incidence for a (k, 3) signed edge array; self-loops once."""
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 3)
        k = edges.shape[0]
        if k == 0:
            self.inc_ptr = np.zeros(self.n_ids + 1, dtype=np.int64)
            self.inc_eid = np.zeros(0, dtype=np.int64)
            return
        nonloop = edges[:, 0] != edges[:, 1]
        ends = np.concatenate([edges[:, 0], edges[nonloop, 1]])
        eids = np.concatenate([
            np.arange(k, dtype=np.int64),
            np.flatnonzero(nonloop),
        ])
        order = np.argsort(ends, kind="stable")
        self.inc_eid = eids[order]
        counts = np.bincount(ends, minlength=self.n_ids)
        self.inc_ptr = np.zeros(self.n_ids + 1, dtype=np.int64)
        np.cumsum(counts, out=self.inc_ptr[1:])

    def incident_eids(self, xs: np.ndarray) -> tuple:
        """Concatenated incident edge ids of ``xs`` plus a segment index."""
        xs = np.asarray(xs, dtype=np.int64)
        lens = self.inc_ptr[xs + 1] - self.inc_ptr[xs]
        idx = segmented_indices(self.inc_ptr[xs], lens)
        if idx.size == 0:
            return idx, idx
        seg = np.repeat(np.arange(xs.size, dtype=np.int64), lens)
        return self.inc_eid[idx], seg


# ---------------------------------------------------------------------------
# Frozen serving artifact
# ---------------------------------------------------------------------------
def pack_sign_bits(sign: np.ndarray) -> np.ndarray:
    """(k,) ±1 signs -> bit-packed uint32 words (bit set = positive)."""
    sign = np.asarray(sign, dtype=np.int64)
    bits = np.zeros((sign.size + 31) // 32, dtype=np.uint32)
    pos = np.flatnonzero(sign > 0)
    if pos.size:
        np.bitwise_or.at(bits, pos >> 5, np.uint32(1) << (pos & 31).astype(np.uint32))
    return bits


def unpack_sign_bits(bits: np.ndarray, k: int) -> np.ndarray:
    """Inverse of `pack_sign_bits`: uint32 words -> (k,) int64 ±1 signs."""
    e = np.arange(k, dtype=np.int64)
    hit = (bits[e >> 5] >> (e & 31).astype(np.uint32)) & np.uint32(1)
    return np.where(hit.astype(bool), 1, -1).astype(np.int64)


class PackedSummary:
    """Frozen, device-ready serving artifact of one (pruned) summary.

    The mutable `Summary` answers one query at a time through lazily built
    caches; serving wants an immutable blob of flat arrays that batched
    backends (NumPy / JAX / Pallas, `core/query_batch.py`) can gather from
    without touching the forest again. Serialized state (``save``/``load``,
    compact ``.npz``):

      ``parent/first/last``   interval table per supernode id (int32)
      ``order``               leaf id per global DFS position (int32)
      ``inc_ptr/inc_eid``     CSR signed-edge incidence per supernode
      ``edge_x/edge_y``       edge endpoints (int32)
      ``sign_bits``           1 bit per edge (set = p-edge), uint32-packed

    Everything else is derived on construction: ``pos_of`` inverts ``order``;
    ``inc_lo/inc_hi/inc_sign`` pre-resolve, for every incidence entry, the
    *other* endpoint's DFS interval and the edge sign, so a query never
    chases ``edge_x/edge_y`` indirection at serve time; ``max_depth`` bounds
    the ancestor-chain climb. DESIGN.md §7.
    """

    __slots__ = (
        "n_leaves", "n_ids", "parent", "first", "last", "order",
        "inc_ptr", "inc_eid", "edge_x", "edge_y", "sign_bits",
        "pos_of", "inc_lo", "inc_hi", "inc_sign", "max_depth",
    )

    def __init__(self, n_leaves: int, parent, first, last, order,
                 inc_ptr, inc_eid, edge_x, edge_y, sign_bits):
        self.n_leaves = int(n_leaves)
        self.n_ids = int(np.asarray(parent).shape[0])
        self.parent = np.asarray(parent, dtype=np.int32)
        self.first = np.asarray(first, dtype=np.int32)
        self.last = np.asarray(last, dtype=np.int32)
        self.order = np.asarray(order, dtype=np.int32)
        self.inc_ptr = np.asarray(inc_ptr, dtype=np.int64)
        self.inc_eid = np.asarray(inc_eid, dtype=np.int32)
        self.edge_x = np.asarray(edge_x, dtype=np.int32)
        self.edge_y = np.asarray(edge_y, dtype=np.int32)
        self.sign_bits = np.asarray(sign_bits, dtype=np.uint32)
        self._derive()

    @property
    def n_edges(self) -> int:
        return int(self.edge_x.shape[0])

    def _derive(self):
        self.pos_of = self.first[: self.n_leaves].astype(np.int64)
        sign = unpack_sign_bits(self.sign_bits, self.n_edges)
        # per incidence entry: owning node, then the other endpoint's interval
        node = np.repeat(np.arange(self.n_ids, dtype=np.int64),
                         np.diff(self.inc_ptr))
        eid = self.inc_eid.astype(np.int64)
        ex, ey = self.edge_x[eid].astype(np.int64), self.edge_y[eid].astype(np.int64)
        other = np.where(ex == node, ey, ex)
        self.inc_lo = self.first[other].astype(np.int64)
        self.inc_hi = self.last[other].astype(np.int64)
        self.inc_sign = sign[eid]
        # deepest leaf chain, by climbing all leaves level-synchronously
        depth = 0
        cur = self.parent[: self.n_leaves].astype(np.int64)
        cur = cur[cur >= 0]
        while cur.size:
            depth += 1
            cur = self.parent[cur].astype(np.int64)
            cur = cur[cur >= 0]
        self.max_depth = depth

    # ------------------------------------------------------------------- io
    @staticmethod
    def _npz_path(path: str) -> str:
        # savez_compressed appends ".npz" to suffix-less paths; normalize in
        # BOTH directions so save(p) and load(p) always name the same file
        return path if path.endswith(".npz") else path + ".npz"

    def save(self, path: str) -> str:
        path = self._npz_path(path)
        np.savez_compressed(
            path, n_leaves=np.int64(self.n_leaves), parent=self.parent,
            first=self.first, last=self.last, order=self.order,
            inc_ptr=self.inc_ptr, inc_eid=self.inc_eid,
            edge_x=self.edge_x, edge_y=self.edge_y, sign_bits=self.sign_bits,
            n_edges=np.int64(self.n_edges))
        return path

    @classmethod
    def load(cls, path: str) -> "PackedSummary":
        with np.load(cls._npz_path(path)) as d:
            return cls(int(d["n_leaves"]), d["parent"], d["first"], d["last"],
                       d["order"], d["inc_ptr"], d["inc_eid"],
                       d["edge_x"], d["edge_y"], d["sign_bits"])

    def nbytes(self) -> int:
        """Serialized payload size (uncompressed array bytes)."""
        return sum(getattr(self, f).nbytes for f in (
            "parent", "first", "last", "order", "inc_ptr", "inc_eid",
            "edge_x", "edge_y", "sign_bits"))


def pack_for_serving(summary) -> PackedSummary:
    """Freeze a (pruned) `Summary` into the immutable serving artifact.

    Accepts any object with ``n_leaves``/``parent``/``edges`` — the
    `Summary` dataclass itself — without importing it (core.summary already
    imports this module)."""
    parent = np.asarray(summary.parent, dtype=np.int64)
    n = int(summary.n_leaves)
    if parent.shape[0] >= np.iinfo(np.int32).max:
        raise ValueError("packed artifact uses int32 ids; summary too large")
    edges = np.asarray(summary.edges, dtype=np.int64).reshape(-1, 3)
    ir = SummaryIR(parent, n)
    ir.build_incidence(edges)
    return PackedSummary(
        n, parent, ir.first, ir.last, ir.order, ir.inc_ptr, ir.inc_eid,
        edges[:, 0], edges[:, 1], pack_sign_bits(edges[:, 2]))
