"""SLUGGER (Algorithm 1): scalable lossless hierarchical graph summarization.

Pipeline, exactly as the paper's:
  1. initialize Ḡ = G (singleton supernodes, P⁺ = E)
  2. T iterations of {candidate generation → in-group greedy merging with the
     decaying threshold θ(t) = 1/(1+t), θ(T) = 0}
  3. encoding emission (the paper maintains encodings incrementally with the
     memoized ≤10-supernode local search; we defer to the exact per-pair DP —
     see DESIGN.md §2.1: same model, search space a superset of the paper's,
     so per-pair cost is never worse given the same merge forest)
  4. pruning (three substeps, Sect. III-B4)

Losslessness is structural: the emission DP re-encodes the *input* edges
exactly, so any merge forest — however heuristic — yields an exact summary.

Merging runs on one of four engines selected by ``backend=`` (DESIGN.md
§3/§9):
  * ``"numpy"``  — batched group-merge engine, NumPy popcount ranking
    (default)
  * ``"batched"`` — batched engine dispatching the Pallas bitset
    intersection kernel over size-bucketed ``(B, G, W)`` bitmap batches
    (per merge round; mesh-sharded when devices allow)
  * ``"resident"`` — device-resident merge rounds: bitmaps upload once per
    workspace chunk, ranking is the fused on-device top-J, merges fold the
    resident bitmaps in place (`core/resident.py`); bit-identical to
    ``"numpy"``/``"batched"``
  * ``"loop"``   — the original per-group Python loop (kept as the benchmark
    baseline and as a semantics reference)
"""
from __future__ import annotations

import logging
import sys

import numpy as np

from repro.core import encode_dp
from repro.core.encode_batched import encode_forest, forest_is_binary
from repro.core.summary import Summary
from repro.core.summary_ir import SummaryIR, canon_edges
from repro.graphs.csr import Graph


class SluggerState:
    """Merge forest + root-level subedge counts in flat-array storage.

    Adjacency lives in an append-only arena (``arena_ids``/``arena_cnt``) with
    one ``(row_ptr, row_len)`` slot per supernode id — CSR rows seed the arena
    directly. Neighbor ids stored in a row may be stale (merged away); reads
    resolve them through the ``forward`` pointer array (with path compression
    and in-place row compaction), so a merge costs O(deg(A)+deg(B)) array work
    and never touches the rows of the merged node's neighbors (DESIGN.md §4).
    """

    def __init__(self, g: Graph):
        n = g.n
        self.g = g
        cap = 2 * n + 8
        self.parent = np.full(cap, -1, dtype=np.int64)
        self.size = np.ones(cap, dtype=np.int64)
        self.height = np.zeros(cap, dtype=np.int64)
        self.ndesc = np.zeros(cap, dtype=np.int64)
        self.selfcnt = np.zeros(cap, dtype=np.int64)
        self.forward = np.arange(cap, dtype=np.int64)
        self.alive_mask = np.zeros(cap, dtype=bool)
        self.alive_mask[:n] = True
        self.n_ids = n
        self.children: dict = {}
        acap = max(2 * int(g.indices.size) + 16, 64)
        self.arena_ids = np.zeros(acap, dtype=np.int64)
        self.arena_cnt = np.zeros(acap, dtype=np.int64)
        self.arena_ids[: g.indices.size] = g.indices
        self.arena_cnt[: g.indices.size] = 1
        self.arena_top = int(g.indices.size)
        self.row_ptr = np.zeros(cap, dtype=np.int64)
        self.row_ptr[:n] = g.indptr[:-1]
        self.row_len = np.zeros(cap, dtype=np.int64)
        self.row_len[:n] = np.diff(g.indptr)
        self._root_cache: np.ndarray | None = None

    # -- id/arena growth ---------------------------------------------------
    def _ensure_ids(self, need: int):
        cap = self.parent.shape[0]
        if need <= cap:
            return
        new = max(2 * cap, need)
        for name in ("parent", "size", "height", "ndesc", "selfcnt",
                     "row_ptr", "row_len"):
            old = getattr(self, name)
            arr = np.zeros(new, dtype=old.dtype)
            arr[:cap] = old
            setattr(self, name, arr)
        self.parent[cap:] = -1
        self.size[cap:] = 1
        fwd = np.arange(new, dtype=np.int64)
        fwd[:cap] = self.forward
        self.forward = fwd
        am = np.zeros(new, dtype=bool)
        am[:cap] = self.alive_mask
        self.alive_mask = am

    def _ensure_arena(self, extra: int):
        if self.arena_top + extra <= self.arena_ids.shape[0]:
            return
        new = max(2 * self.arena_ids.shape[0], self.arena_top + extra)
        for name in ("arena_ids", "arena_cnt"):
            old = getattr(self, name)
            arr = np.zeros(new, dtype=np.int64)
            arr[: self.arena_top] = old[: self.arena_top]
            setattr(self, name, arr)

    def _append_row(self, i: int, ids: np.ndarray, cnts: np.ndarray):
        k = ids.shape[0]
        self._ensure_arena(k)
        self.row_ptr[i] = self.arena_top
        self.row_len[i] = k
        self.arena_ids[self.arena_top : self.arena_top + k] = ids
        self.arena_cnt[self.arena_top : self.arena_top + k] = cnts
        self.arena_top += k

    # -- resolution --------------------------------------------------------
    def resolve(self, ids: np.ndarray) -> np.ndarray:
        """Map (possibly stale) supernode ids to their current alive roots."""
        orig = np.asarray(ids, dtype=np.int64)
        out = orig
        while True:
            nxt = self.forward[out]
            if np.array_equal(nxt, out):
                break
            out = nxt
        if out is not orig:
            self.forward[orig] = out  # path compression
        return out

    @property
    def root_of(self) -> np.ndarray:
        """Current root of every leaf (recomputed lazily after merges)."""
        if self._root_cache is None:
            self._root_cache = self.resolve(np.arange(self.g.n, dtype=np.int64))
        return self._root_cache

    @property
    def alive(self) -> np.ndarray:
        return np.flatnonzero(self.alive_mask[: self.n_ids])

    def root_min_leaf(self) -> np.ndarray:
        """Smallest leaf id owned by each root (n for leafless ids) — THE
        partition key of a root (DESIGN.md §8.1). The engine's group
        assignment and the partition-aware emission both key through this
        one method so their bucketing can never drift apart."""
        ml = np.full(self.n_ids, self.g.n, dtype=np.int64)
        np.minimum.at(ml, self.root_of, np.arange(self.g.n, dtype=np.int64))
        return ml

    # -- adjacency reads ---------------------------------------------------
    def gather_rows(self, roots: np.ndarray):
        """Resolved, per-root-aggregated adjacency of distinct ``roots``.

        Returns ``(seg, nbr, cnt)``: concatenated row entries with ``seg``
        indexing into ``roots``. As a side effect the touched rows are
        compacted in place (stale duplicates folded, shrinking ``row_len``).
        """
        roots = np.asarray(roots, dtype=np.int64)
        lens = self.row_len[roots]
        total = int(lens.sum())
        empty = np.zeros(0, dtype=np.int64)
        if total == 0:
            return empty, empty, empty
        starts = self.row_ptr[roots]
        ends = np.cumsum(lens)
        off = np.arange(total, dtype=np.int64) - np.repeat(ends - lens, lens)
        idx = np.repeat(starts, lens) + off
        seg = np.repeat(np.arange(roots.size, dtype=np.int64), lens)
        nbr = self.resolve(self.arena_ids[idx])
        cnt = self.arena_cnt[idx]
        key = seg * np.int64(self.n_ids + 1) + nbr
        order = np.argsort(key, kind="stable")
        key, nbr, cnt, seg = key[order], nbr[order], cnt[order], seg[order]
        head = np.empty(key.size, dtype=bool)
        head[0] = True
        np.not_equal(key[1:], key[:-1], out=head[1:])
        starts_u = np.flatnonzero(head)
        cnt_u = np.add.reduceat(cnt, starts_u)
        seg_u, nbr_u = seg[starts_u], nbr[starts_u]
        # write the compacted rows back in place (they only ever shrink)
        lens_u = np.bincount(seg_u, minlength=roots.size).astype(np.int64)
        ends_u = np.cumsum(lens_u)
        pos = self.row_ptr[roots][seg_u] + (
            np.arange(seg_u.size, dtype=np.int64) - (ends_u - lens_u)[seg_u]
        )
        self.arena_ids[pos] = nbr_u
        self.arena_cnt[pos] = cnt_u
        self.row_len[roots] = lens_u
        return seg_u, nbr_u, cnt_u

    # -- merge -------------------------------------------------------------
    def merge(self, A: int, B: int) -> int:
        """Merge roots A, B under a fresh parent M; returns M's id."""
        return int(self.merge_batch(
            np.array([A], dtype=np.int64), np.array([B], dtype=np.int64))[0])

    def merge_batch(self, A: np.ndarray, B: np.ndarray) -> np.ndarray:
        """Merge m disjoint root pairs (A[i], B[i]) in one arena operation.

        All per-id bookkeeping is vectorized; the merged rows of every pair
        are built from ONE gather + segment aggregation and bulk-appended.
        Returns the m fresh parent ids.
        """
        A = np.asarray(A, dtype=np.int64)
        B = np.asarray(B, dtype=np.int64)
        m = A.size
        base = self.n_ids
        self._ensure_ids(base + m)
        self.n_ids = base + m
        M = base + np.arange(m, dtype=np.int64)
        self.parent[A] = M
        self.parent[B] = M
        self.parent[M] = -1
        for i in range(m):
            self.children[base + i] = [int(A[i]), int(B[i])]
        self.size[M] = self.size[A] + self.size[B]
        self.height[M] = np.maximum(self.height[A], self.height[B]) + 1
        self.ndesc[M] = self.ndesc[A] + self.ndesc[B] + 2
        roots = np.concatenate([A, B])
        pair_of_root = np.concatenate([np.arange(m), np.arange(m)])
        seg, nbr, cnt = self.gather_rows(roots)
        pair = pair_of_root[seg]
        cab = np.zeros(m, dtype=np.int64)
        lens = np.zeros(m, dtype=np.int64)
        nbr_k = cnt_k = np.zeros(0, dtype=np.int64)
        if nbr.size:
            # aggregate the two rows of each pair, drop internal A↔B entries
            key = pair * np.int64(self.n_ids + 1) + nbr
            order = np.argsort(key, kind="stable")
            key, pair, nbr, cnt = key[order], pair[order], nbr[order], cnt[order]
            head = np.empty(key.size, dtype=bool)
            head[0] = True
            np.not_equal(key[1:], key[:-1], out=head[1:])
            starts = np.flatnonzero(head)
            cnt_u = np.add.reduceat(cnt, starts)
            pair_u, nbr_u = pair[starts], nbr[starts]
            internal = (nbr_u == A[pair_u]) | (nbr_u == B[pair_u])
            # A→B and B→A each counted once
            cab = (np.bincount(pair_u[internal], weights=cnt_u[internal],
                               minlength=m).astype(np.int64) // 2)
            keep = ~internal
            pair_k, nbr_k, cnt_k = pair_u[keep], nbr_u[keep], cnt_u[keep]
            lens = np.bincount(pair_k, minlength=m).astype(np.int64)
        total = int(lens.sum())
        self._ensure_arena(total)
        ends = np.cumsum(lens)
        self.row_ptr[M] = self.arena_top + ends - lens
        self.row_len[M] = lens
        self.arena_ids[self.arena_top : self.arena_top + total] = nbr_k
        self.arena_cnt[self.arena_top : self.arena_top + total] = cnt_k
        self.arena_top += total
        self.selfcnt[M] = self.selfcnt[A] + self.selfcnt[B] + cab
        self.forward[A] = M
        self.forward[B] = M
        self.alive_mask[A] = False
        self.alive_mask[B] = False
        self.alive_mask[M] = True
        self.row_len[A] = 0
        self.row_len[B] = 0
        self._root_cache = None
        return M


def _emit_encoding_reference(state: SluggerState) -> Summary:
    """Per-root-pair recursive DP emission — the semantics reference the
    batched emitter is cross-checked against (kept as ``backend="loop"``)."""
    g = state.g
    n = g.n
    root_of = state.root_of
    pos_of = np.zeros(n, dtype=np.int64)
    tvs: dict = {}
    # TreeView/DP recursion depth tracks the forest height; raise the limit
    # locally instead of mutating it for the whole process.
    limit = int(4 * state.height[: state.n_ids].max() + 2000)
    old_limit = sys.getrecursionlimit()
    # lint: disable=NO-RECURSION-LIMIT -- reference emitter only: scoped to this call, restored in the finally, and the recursive-DP cross-check is the point
    sys.setrecursionlimit(max(old_limit, limit))
    try:
        for r in np.unique(root_of):
            tv = encode_dp.TreeView(int(r), state.children, n)
            tvs[int(r)] = tv
            order = tv.leaf_order(state.children, n)
            pos_of[order] = np.arange(order.shape[0])

        el = g.edge_list()
        edges_out: list = []
        if el.size:
            ra = root_of[el[:, 0]]
            rb = root_of[el[:, 1]]
            # normalize: endpoint order follows (min root, max root)
            swap = ra > rb
            u = np.where(swap, el[:, 1], el[:, 0])
            v = np.where(swap, el[:, 0], el[:, 1])
            ka, kb = np.minimum(ra, rb), np.maximum(ra, rb)
            order = np.lexsort((kb, ka))
            u, v, ka, kb = u[order], v[order], ka[order], kb[order]
            # root-pair groups split on component diffs — unlike the previous
            # ka * (max(kb)+1) + kb keying this cannot overflow int64 however
            # large the supernode ids grow (see summary_ir.group_pairs).
            head = (np.diff(ka) != 0) | (np.diff(kb) != 0)
            bounds = np.concatenate([[0], np.flatnonzero(head) + 1, [ka.shape[0]]])
            for i in range(bounds.shape[0] - 1):
                s, e = bounds[i], bounds[i + 1]
                A, B = int(ka[s]), int(kb[s])
                if A == B:
                    pu, pv = pos_of[u[s:e]], pos_of[v[s:e]]
                    lo, hi = np.minimum(pu, pv), np.maximum(pu, pv)
                    _, ee = encode_dp.encode_self(tvs[A], lo, hi)
                else:
                    pa, pb = pos_of[u[s:e]], pos_of[v[s:e]]
                    _, ee = encode_dp.encode_pair(tvs[A], tvs[B], pa, pb)
                edges_out.extend(ee)
    finally:
        # lint: disable=NO-RECURSION-LIMIT -- restores the caller's limit after the reference emitter's scoped bump above
        sys.setrecursionlimit(old_limit)

    parent = state.parent[: state.n_ids].copy()
    arr = canon_edges(np.array(edges_out, dtype=np.int64).reshape(-1, 3))
    return Summary(n_leaves=n, parent=parent, edges=arr)


def _emit_encoding(state: SluggerState, backend: str = "numpy",
                   owner=None) -> Summary:
    """Exact hierarchical encoding of the input graph over the current merge
    forest (plays the paper's 'update of encoding' role).

    ``backend="loop"`` runs the per-root-pair recursive DP; other backends
    run the batched level-synchronous DP over the flat Summary IR
    (`core/encode_batched.py`), with the per-level membership counts
    dispatched through the Pallas seghist kernel on ``backend="batched"``.
    Both produce bit-identical canonical edge arrays (test-enforced).

    ``owner`` (node → partition, DESIGN.md §8) buckets the root pairs by
    partition and emits each bucket separately: per-pair encodings are
    independent and the export is canonical-sorted, so the result is
    bit-identical to the monolithic emission for any ownership map."""
    g = state.g
    if g.n == 0:
        return Summary(n_leaves=0, parent=np.zeros(0, dtype=np.int64),
                       edges=np.zeros((0, 3), dtype=np.int64))
    if backend == "loop":
        return _emit_encoding_reference(state)
    parent = state.parent[: state.n_ids].copy()
    ir = SummaryIR(parent, g.n)
    if not forest_is_binary(ir):  # only the recursive DP handles n-ary trees
        return _emit_encoding_reference(state)
    el = g.edge_list()
    u = el[:, 0] if el.size else np.zeros(0, dtype=np.int64)
    v = el[:, 1] if el.size else np.zeros(0, dtype=np.int64)
    if owner is None or u.size == 0:
        _, edges = encode_forest(ir, u, v, backend=backend)
        return Summary(n_leaves=g.n, parent=parent, edges=edges)
    # partition-aware emission: a root pair belongs to the partition owning
    # the smaller root's smallest leaf; buckets encode independently
    root_of = state.root_of
    min_leaf = state.root_min_leaf()
    key_root = np.minimum(root_of[u], root_of[v])
    part = np.asarray(owner, dtype=np.int64)[min_leaf[key_root]]
    chunks = []
    for p in np.unique(part):
        sel = part == p
        _, e_p = encode_forest(ir, u[sel], v[sel], backend=backend)
        chunks.append(e_p)
    edges = canon_edges(np.concatenate(chunks, axis=0))
    return Summary(n_leaves=g.n, parent=parent, edges=edges)


def summarize(
    g: Graph,
    T: int = 20,
    seed: int = 0,
    max_group: int = 500,
    top_j: int = 16,
    height_bound=None,
    prune_steps=(1, 2, 3),
    verbose: bool = False,
    backend: str = "numpy",
    partitions: int = 1,
) -> Summary:
    """Run SLUGGER end to end. ``prune_steps=()`` skips pruning (paper's
    'state 0' in Table IV); ``height_bound`` is the Table-V H_b variant.
    ``backend`` selects the merge engine (see module docstring).

    This is a thin wrapper over `repro.core.engine.SummarizerEngine` — the
    stage-based partition-parallel driver (DESIGN.md §8). ``partitions``
    shards the work by node ownership; the result is bit-identical for
    every value. ``verbose`` raises the engine loggers to INFO (progress
    goes through `logging`, not prints)."""
    from repro.core.engine import SummarizerEngine  # circular-safe

    engine = SummarizerEngine(
        partitions=partitions, backend=backend, T=T, seed=seed,
        max_group=max_group, top_j=top_j, height_bound=height_bound,
        prune_steps=prune_steps)
    if not verbose:
        return engine.run(g)
    restore = _ensure_info_logging()
    try:
        return engine.run(g)
    finally:
        restore()


def _ensure_info_logging():
    """`verbose=True` compatibility shim: surface engine INFO logs on
    stderr when the caller has not configured logging themselves. Returns
    a restore callback — a later ``verbose=False`` call must be silent
    again, so nothing may stick to the logger."""
    logger = logging.getLogger("repro.engine")
    old_level = logger.level
    logger.setLevel(logging.INFO)
    handler = None
    if not logging.getLogger().handlers and not logger.handlers:
        handler = logging.StreamHandler()
        handler.setFormatter(logging.Formatter("[%(name)s] %(message)s"))
        logger.addHandler(handler)

    def restore():
        logger.setLevel(old_level)
        if handler is not None:
            logger.removeHandler(handler)

    return restore
