"""SLUGGER (Algorithm 1): scalable lossless hierarchical graph summarization.

Pipeline, exactly as the paper's:
  1. initialize Ḡ = G (singleton supernodes, P⁺ = E)
  2. T iterations of {candidate generation → in-group greedy merging with the
     decaying threshold θ(t) = 1/(1+t), θ(T) = 0}
  3. encoding emission (the paper maintains encodings incrementally with the
     memoized ≤10-supernode local search; we defer to the exact per-pair DP —
     see DESIGN.md §2.1: same model, search space a superset of the paper's,
     so per-pair cost is never worse given the same merge forest)
  4. pruning (three substeps, Sect. III-B4)

Losslessness is structural: the emission DP re-encodes the *input* edges
exactly, so any merge forest — however heuristic — yields an exact summary.
"""
from __future__ import annotations

import sys
import time

import numpy as np

from repro.core import encode_dp
from repro.core.merging import process_group
from repro.core.minhash import candidate_groups
from repro.core.pruning import prune
from repro.core.summary import Summary
from repro.graphs.csr import Graph

sys.setrecursionlimit(200_000)


class SluggerState:
    """Merge forest + root-level subedge counts, updated per merger."""

    def __init__(self, g: Graph):
        n = g.n
        self.g = g
        self.root_of = np.arange(n, dtype=np.int64)
        self.parent: list[int] = [-1] * n
        self.children: dict = {}
        self.leaves: dict = {u: [u] for u in range(n)}
        self.size: list[int] = [1] * n
        self.height: list[int] = [0] * n
        self.ndesc: list[int] = [0] * n
        self.selfcnt: dict = {u: 0 for u in range(n)}
        self.adj: dict = {u: {int(v): 1 for v in g.neighbors(u)} for u in range(n)}
        self.alive: set = set(range(n))

    def merge(self, A: int, B: int) -> int:
        """Merge roots A, B under a fresh parent M; returns M's id."""
        M = len(self.parent)
        self.parent.append(-1)
        self.parent[A] = M
        self.parent[B] = M
        self.children[M] = [A, B]
        la, lb = self.leaves.pop(A), self.leaves.pop(B)
        lm = la + lb
        self.leaves[M] = lm
        self.root_of[np.asarray(lm, dtype=np.int64)] = M
        self.size.append(self.size[A] + self.size[B])
        self.height.append(max(self.height[A], self.height[B]) + 1)
        self.ndesc.append(self.ndesc[A] + self.ndesc[B] + 2)
        na, nb = self.adj.pop(A), self.adj.pop(B)
        cab = na.pop(B, 0)
        nb.pop(A, None)
        merged = na
        for c, v in nb.items():
            merged[c] = merged.get(c, 0) + v
        for c in merged:
            d = self.adj[c]
            d.pop(A, None)
            d.pop(B, None)
            d[M] = merged[c]
        self.adj[M] = merged
        self.selfcnt[M] = self.selfcnt.pop(A) + self.selfcnt.pop(B) + cab
        self.alive.discard(A)
        self.alive.discard(B)
        self.alive.add(M)
        return M


def _emit_encoding(state: SluggerState) -> Summary:
    """Exact per-pair hierarchical encoding of the input graph over the
    current merge forest (plays the paper's 'update of encoding' role)."""
    g = state.g
    n = g.n
    pos_of = np.zeros(n, dtype=np.int64)
    tvs: dict = {}
    for r, lv in state.leaves.items():
        arr = np.asarray(lv, dtype=np.int64)
        pos_of[arr] = np.arange(arr.shape[0])
        tvs[r] = encode_dp.TreeView(r, state.children, n)

    el = g.edge_list()
    edges_out: list = []
    if el.size:
        ra = state.root_of[el[:, 0]]
        rb = state.root_of[el[:, 1]]
        # normalize: endpoint order follows (min root, max root)
        swap = ra > rb
        u = np.where(swap, el[:, 1], el[:, 0])
        v = np.where(swap, el[:, 0], el[:, 1])
        ka, kb = np.minimum(ra, rb), np.maximum(ra, rb)
        order = np.lexsort((kb, ka))
        u, v, ka, kb = u[order], v[order], ka[order], kb[order]
        key = ka * (np.max(kb) + 1) + kb
        bounds = np.concatenate([[0], np.flatnonzero(np.diff(key)) + 1, [key.shape[0]]])
        for i in range(bounds.shape[0] - 1):
            s, e = bounds[i], bounds[i + 1]
            A, B = int(ka[s]), int(kb[s])
            if A == B:
                pu, pv = pos_of[u[s:e]], pos_of[v[s:e]]
                lo, hi = np.minimum(pu, pv), np.maximum(pu, pv)
                _, ee = encode_dp.encode_self(tvs[A], lo, hi)
            else:
                pa, pb = pos_of[u[s:e]], pos_of[v[s:e]]
                _, ee = encode_dp.encode_pair(tvs[A], tvs[B], pa, pb)
            edges_out.extend(ee)

    parent = np.array(state.parent, dtype=np.int64)
    if edges_out:
        arr = np.array(edges_out, dtype=np.int64)
        lo = np.minimum(arr[:, 0], arr[:, 1])
        hi = np.maximum(arr[:, 0], arr[:, 1])
        arr = np.stack([lo, hi, arr[:, 2]], axis=1)
    else:
        arr = np.zeros((0, 3), dtype=np.int64)
    return Summary(n_leaves=n, parent=parent, edges=arr)


def summarize(
    g: Graph,
    T: int = 20,
    seed: int = 0,
    max_group: int = 500,
    top_j: int = 16,
    height_bound=None,
    prune_steps=(1, 2, 3),
    verbose: bool = False,
) -> Summary:
    """Run SLUGGER end to end. ``prune_steps=()`` skips pruning (paper's
    'state 0' in Table IV); ``height_bound`` is the Table-V H_b variant."""
    state = SluggerState(g)
    rng = np.random.default_rng(seed)
    for t in range(1, T + 1):
        theta = 0.0 if t == T else 1.0 / (1 + t)
        alive = np.fromiter(state.alive, dtype=np.int64)
        groups = candidate_groups(g, state.root_of, alive, seed=seed * 7919 + t, max_group=max_group)
        merges = 0
        t0 = time.time()
        for grp in groups:
            merges += process_group(state, grp, theta, rng, top_j=top_j, height_bound=height_bound)
        if verbose:
            print(
                f"[slugger] iter {t:3d}: θ={theta:.3f} groups={len(groups)} "
                f"merges={merges} roots={len(state.alive)} ({time.time()-t0:.2f}s)"
            )
    summary = _emit_encoding(state)
    if prune_steps:
        summary = prune(summary, steps=prune_steps)
    return summary
