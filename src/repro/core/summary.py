"""The hierarchical graph summarization model  Ḡ = (S, P⁺, P⁻, H).

Supernode ids: ``0..n_leaves-1`` are leaves (subnodes); larger ids are
internal/root supernodes created by merging. The forest is stored as a parent
array; ``H`` is implicit: one h-edge per retained supernode with a retained
parent. An edge (u, v) exists in the decompressed graph iff

    #{p-edges between (ancestors(u) ∪ {u}) × (ancestors(v) ∪ {v})}
  > #{n-edges …}                                                   (Sect. II-B)

All structure/query methods run on the flat Summary IR (`core/summary_ir.py`,
DESIGN.md §5): leaf membership is one gather over DFS intervals, full
decompression is one vectorized expansion over all edges, and `neighbors`
(Algorithm 4, partial decompression) is a difference-array sweep over the
intervals of the edges incident to v's ancestor chain — no recursion
anywhere. `_decompress_reference`/`_neighbors_reference` keep the per-edge
Python loops as the cross-checked semantics baseline.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.summary_ir import (SummaryIR, pack_for_serving,
                                   segmented_indices)
from repro.graphs.csr import Graph


@dataclass
class Summary:
    n_leaves: int
    # parent id per supernode (index = supernode id), -1 for roots.
    # Pruned supernodes have parent == -2 (tombstone) and must carry no edges.
    parent: np.ndarray
    # signed supernode edges: (k, 3) int64 rows (X, Y, sign) with sign ∈ {+1,-1};
    # X <= Y normalized; X == Y is a supernode self-loop.
    edges: np.ndarray

    _ir: SummaryIR = field(default=None, repr=False, compare=False)
    _inc_built: bool = field(default=False, repr=False, compare=False)

    # ------------------------------------------------------------------ basic
    @property
    def num_pos(self) -> int:
        return int(np.sum(self.edges[:, 2] > 0)) if self.edges.size else 0

    @property
    def num_neg(self) -> int:
        return int(np.sum(self.edges[:, 2] < 0)) if self.edges.size else 0

    @property
    def num_h(self) -> int:
        return int(np.sum(self.parent >= 0))

    def cost(self) -> int:
        """Encoding cost |P⁺| + |P⁻| + |H|   (Eq. 1)."""
        return self.num_pos + self.num_neg + self.num_h

    def relative_size(self, g: Graph) -> float:
        """Eq. (10): cost / |E|."""
        return self.cost() / max(1, g.m)

    def alive(self) -> np.ndarray:
        return np.where(self.parent > -2)[0]

    def roots(self) -> np.ndarray:
        return np.where(self.parent == -1)[0]

    # ------------------------------------------------------------- structure
    @property
    def ir(self) -> SummaryIR:
        """Flat interval view of the forest (built once, invalidated on edit)."""
        if self._ir is None:
            self._ir = SummaryIR(self.parent, self.n_leaves)
            self._inc_built = False
        return self._ir

    def _inc(self) -> SummaryIR:
        ir = self.ir
        if not self._inc_built:
            ir.build_incidence(self.edges)
            self._inc_built = True
        return ir

    def children(self, x: int):
        return self.ir.children_of(int(x)).tolist()

    def leaves(self, x: int) -> np.ndarray:
        """Subnodes contained in supernode x (DFS order) — one gather."""
        return self.ir.leaves_of(int(x))

    def depth_of_leaves(self) -> np.ndarray:
        """#ancestors per leaf (0 when the leaf is itself a root)."""
        return self.ir.depth[: self.n_leaves].copy()

    def tree_heights(self) -> list:
        """Height of each root's hierarchy tree."""
        return self.ir.tree_heights().tolist()

    def composition(self) -> dict:
        return {"pos": self.num_pos, "neg": self.num_neg, "h": self.num_h}

    # ---------------------------------------------------------- decompression
    def decompress(self) -> Graph:
        """Exact reconstruction of the input graph (full decompression).

        One pass: cross edges (X ≠ Y) expand to their interval products with
        a flat repeat/tile decomposition over ALL edges at once; self-loops
        expand per distinct supernode size through one shared triu template.
        """
        n = self.n_leaves
        ir = self.ir
        edges = self.edges
        if edges.shape[0] == 0:
            return Graph.from_edges(n, np.zeros((0, 2), dtype=np.int64))
        X, Y, S = edges[:, 0], edges[:, 1], edges[:, 2]
        keys, weights = [], []

        cross = X != Y
        if cross.any():
            cx, cy, cs = X[cross], Y[cross], S[cross]
            sx, sy = ir.size(cx), ir.size(cy)
            lens = sx * sy
            if lens.sum():
                local = segmented_indices(np.zeros_like(lens), lens)
                wid = np.repeat(sy, lens)
                i = local // wid
                j = local - i * wid
                u = ir.order[np.repeat(ir.first[cx], lens) + i]
                v = ir.order[np.repeat(ir.first[cy], lens) + j]
                lo, hi = np.minimum(u, v), np.maximum(u, v)
                keys.append(lo * n + hi)
                weights.append(np.repeat(cs, lens))

        if (~cross).any():
            lx, ls = X[~cross], S[~cross]
            sz = ir.size(lx)
            for s in np.unique(sz):
                if s < 2:
                    continue
                iu, iv = np.triu_indices(int(s), k=1)
                sel = lx[sz == s]
                base = np.repeat(ir.first[sel], iu.size)
                u = ir.order[base + np.tile(iu, sel.size)]
                v = ir.order[base + np.tile(iv, sel.size)]
                lo, hi = np.minimum(u, v), np.maximum(u, v)
                keys.append(lo * n + hi)
                weights.append(np.repeat(ls[sz == s], iu.size))

        if not keys:
            return Graph.from_edges(n, np.zeros((0, 2), dtype=np.int64))
        keys = np.concatenate(keys)
        weights = np.concatenate(weights)
        uniq, inv = np.unique(keys, return_inverse=True)
        tot = np.bincount(inv, weights=weights.astype(np.float64))
        sel = uniq[tot > 0]
        return Graph.from_edges(n, np.stack([sel // n, sel % n], axis=1))

    def neighbors(self, v: int) -> np.ndarray:
        """Partial decompression (Algorithm 4): one node's neighborhood,
        touching only the edges incident to v's ancestors.

        Each incident edge contributes a signed (start, end) event pair over
        DFS positions; one sort + prefix-sum sweep over the ≤ 2·deg events
        yields the positive-count ranges — O(deg·log(deg) + |answer|) per
        query, independent of n."""
        ir = self._inc()
        v = int(v)
        chain = [v]
        x = v
        while ir.parent[x] >= 0:
            x = int(ir.parent[x])
            chain.append(x)
        eids, seg = ir.incident_eids(np.array(chain, dtype=np.int64))
        if eids.size == 0:
            return np.zeros(0, dtype=np.int64)
        ex, ey, es = self.edges[eids, 0], self.edges[eids, 1], self.edges[eids, 2]
        mine = np.array(chain, dtype=np.int64)[seg]
        # the side whose leaves receive the count: the other endpoint, or the
        # supernode itself for self-loops (pairs within X).
        other = np.where(ex == mine, ey, ex)
        pos = np.concatenate([ir.first[other], ir.last[other]])
        val = np.concatenate([es, -es]).astype(np.int64)
        order = np.argsort(pos, kind="stable")
        pos, val = pos[order], val[order]
        cum = np.cumsum(val)
        tail = np.empty(pos.shape[0], dtype=bool)  # last event per position
        tail[-1] = True
        np.not_equal(pos[1:], pos[:-1], out=tail[:-1])
        seg_pos, seg_cnt = pos[tail], cum[tail]
        active = np.flatnonzero(seg_cnt[:-1] > 0)
        lens = seg_pos[active + 1] - seg_pos[active]
        hit = segmented_indices(seg_pos[active], lens)
        if hit.size == 0:
            return np.zeros(0, dtype=np.int64)
        hit = hit[hit != ir.pos_of[v]]
        return np.sort(ir.order[hit])

    # ------------------------------------------------ reference (slow) paths
    def _decompress_reference(self) -> Graph:
        """Per-edge Python loop kept as the semantics baseline for tests and
        the pipeline-breakdown benchmark."""
        n = self.n_leaves
        keys, weights = [], []
        for X, Y, s in self.edges:
            lx, ly = self.leaves(int(X)), self.leaves(int(Y))
            if X == Y:
                if lx.shape[0] < 2:
                    continue
                iu, iv = np.triu_indices(lx.shape[0], k=1)
                u, v = lx[iu], lx[iv]
            else:
                u = np.repeat(lx, ly.shape[0])
                v = np.tile(ly, lx.shape[0])
            lo, hi = np.minimum(u, v), np.maximum(u, v)
            keys.append(lo * n + hi)
            weights.append(np.full(lo.shape[0], int(s), dtype=np.int64))
        if not keys:
            return Graph.from_edges(n, np.zeros((0, 2), dtype=np.int64))
        keys = np.concatenate(keys)
        weights = np.concatenate(weights)
        uniq, inv = np.unique(keys, return_inverse=True)
        tot = np.bincount(inv, weights=weights.astype(np.float64))
        sel = uniq[tot > 0]
        return Graph.from_edges(n, np.stack([sel // n, sel % n], axis=1))

    def _neighbors_reference(self, v: int) -> np.ndarray:
        ir = self._inc()
        count = np.zeros(self.n_leaves, dtype=np.int64)
        chain = [int(v)]
        while ir.parent[chain[-1]] >= 0:
            chain.append(int(ir.parent[chain[-1]]))
        for X in chain:
            eids, _ = ir.incident_eids(np.array([X], dtype=np.int64))
            for e in eids:
                ex, ey, s = self.edges[e]
                other = int(ey if ex == X else ex) if ex != ey else int(ex)
                count[self.leaves(other)] += int(s)
        count[int(v)] = 0
        return np.where(count > 0)[0].astype(np.int64)

    # ------------------------------------------------------------- validation
    def validate_lossless(self, g: Graph) -> bool:
        return self.decompress() == g

    def stats(self, g: Graph) -> dict:
        heights = self.tree_heights()
        return {
            "cost": self.cost(),
            "relative_size": self.relative_size(g),
            **self.composition(),
            "max_height": int(max(heights)) if heights else 0,
            "avg_leaf_depth": float(np.mean(self.depth_of_leaves())),
            "n_supernodes": int(self.alive().shape[0]),
            "n_roots": int(self.roots().shape[0]),
        }

    def pack_for_serving(self):
        """Freeze into the immutable batched-serving artifact
        (`summary_ir.PackedSummary`; query it via `core.query_batch`)."""
        return pack_for_serving(self)

    def invalidate_caches(self):
        self._ir = None
        self._inc_built = False
