"""The hierarchical graph summarization model  Ḡ = (S, P⁺, P⁻, H).

Supernode ids: ``0..n_leaves-1`` are leaves (subnodes); larger ids are
internal/root supernodes created by merging. The forest is stored as a parent
array; ``H`` is implicit: one h-edge per retained supernode with a retained
parent. An edge (u, v) exists in the decompressed graph iff

    #{p-edges between (ancestors(u) ∪ {u}) × (ancestors(v) ∪ {v})}
  > #{n-edges …}                                                   (Sect. II-B)
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.graphs.csr import Graph


@dataclass
class Summary:
    n_leaves: int
    # parent id per supernode (index = supernode id), -1 for roots.
    # Pruned supernodes have parent == -2 (tombstone) and must carry no edges.
    parent: np.ndarray
    # signed supernode edges: (k, 3) int64 rows (X, Y, sign) with sign ∈ {+1,-1};
    # X <= Y normalized; X == Y is a supernode self-loop.
    edges: np.ndarray

    _children: dict = field(default=None, repr=False, compare=False)
    _leaves: dict = field(default=None, repr=False, compare=False)
    _incidence: dict = field(default=None, repr=False, compare=False)

    # ------------------------------------------------------------------ basic
    @property
    def num_pos(self) -> int:
        return int(np.sum(self.edges[:, 2] > 0)) if self.edges.size else 0

    @property
    def num_neg(self) -> int:
        return int(np.sum(self.edges[:, 2] < 0)) if self.edges.size else 0

    @property
    def num_h(self) -> int:
        return int(np.sum(self.parent >= 0))

    def cost(self) -> int:
        """Encoding cost |P⁺| + |P⁻| + |H|   (Eq. 1)."""
        return self.num_pos + self.num_neg + self.num_h

    def relative_size(self, g: Graph) -> float:
        """Eq. (10): cost / |E|."""
        return self.cost() / max(1, g.m)

    def alive(self) -> np.ndarray:
        return np.where(self.parent > -2)[0]

    def roots(self) -> np.ndarray:
        return np.where(self.parent == -1)[0]

    # ------------------------------------------------------------- structure
    def children(self, x: int):
        if self._children is None:
            ch: dict = {}
            for i, p in enumerate(self.parent):
                if p >= 0:
                    ch.setdefault(int(p), []).append(i)
            self._children = ch
        return self._children.get(int(x), [])

    def leaves(self, x: int) -> np.ndarray:
        """Subnodes contained in supernode x (DFS order)."""
        if self._leaves is None:
            self._leaves = {}
        cached = self._leaves.get(int(x))
        if cached is not None:
            return cached
        if x < self.n_leaves:
            out = np.array([x], dtype=np.int64)
        else:
            out = (
                np.concatenate([self.leaves(c) for c in self.children(x)])
                if self.children(x)
                else np.zeros(0, dtype=np.int64)
            )
        self._leaves[int(x)] = out
        return out

    def depth_of_leaves(self) -> np.ndarray:
        """#ancestors per leaf (0 when the leaf is itself a root)."""
        d = np.zeros(self.n_leaves, dtype=np.int64)
        for u in range(self.n_leaves):
            x, depth = u, 0
            while self.parent[x] >= 0:
                x = int(self.parent[x])
                depth += 1
            d[u] = depth
        return d

    def tree_heights(self) -> list:
        """Height of each root's hierarchy tree."""
        heights = {}

        def h(x):
            if x in heights:
                return heights[x]
            ch = self.children(x)
            r = 0 if not ch else 1 + max(h(c) for c in ch)
            heights[x] = r
            return r

        return [h(int(r)) for r in self.roots()]

    def composition(self) -> dict:
        return {"pos": self.num_pos, "neg": self.num_neg, "h": self.num_h}

    # ---------------------------------------------------------- decompression
    def decompress(self) -> Graph:
        """Exact reconstruction of the input graph (full decompression)."""
        n = self.n_leaves
        keys, weights = [], []
        for X, Y, s in self.edges:
            lx, ly = self.leaves(int(X)), self.leaves(int(Y))
            if X == Y:
                if lx.shape[0] < 2:
                    continue
                iu, iv = np.triu_indices(lx.shape[0], k=1)
                u, v = lx[iu], lx[iv]
            else:
                u = np.repeat(lx, ly.shape[0])
                v = np.tile(ly, lx.shape[0])
            lo, hi = np.minimum(u, v), np.maximum(u, v)
            keys.append(lo * n + hi)
            weights.append(np.full(lo.shape[0], int(s), dtype=np.int64))
        if not keys:
            return Graph.from_edges(n, np.zeros((0, 2), dtype=np.int64))
        keys = np.concatenate(keys)
        weights = np.concatenate(weights)
        uniq, inv = np.unique(keys, return_inverse=True)
        tot = np.bincount(inv, weights=weights.astype(np.float64))
        sel = uniq[tot > 0]
        return Graph.from_edges(n, np.stack([sel // n, sel % n], axis=1))

    def _incident(self, x: int):
        if self._incidence is None:
            inc: dict = {}
            for i, (X, Y, s) in enumerate(self.edges):
                inc.setdefault(int(X), []).append((int(Y), int(s)))
                if X != Y:
                    inc.setdefault(int(Y), []).append((int(X), int(s)))
            self._incidence = inc
        return self._incidence.get(int(x), [])

    def neighbors(self, v: int) -> np.ndarray:
        """Partial decompression (Algorithm 4): one node's neighborhood,
        touching only the edges incident to v's ancestors."""
        count = np.zeros(self.n_leaves, dtype=np.int64)
        x = int(v)
        chain = []
        while True:
            chain.append(x)
            if self.parent[x] < 0:
                break
            x = int(self.parent[x])
        for X in chain:
            for Y, s in self._incident(X):
                if Y == X:  # self-loop: applies to pairs within X
                    count[self.leaves(X)] += s
                else:
                    count[self.leaves(Y)] += s
        count[v] = 0
        return np.where(count > 0)[0].astype(np.int64)

    # ------------------------------------------------------------- validation
    def validate_lossless(self, g: Graph) -> bool:
        return self.decompress() == g

    def stats(self, g: Graph) -> dict:
        heights = self.tree_heights()
        return {
            "cost": self.cost(),
            "relative_size": self.relative_size(g),
            **self.composition(),
            "max_height": int(max(heights)) if heights else 0,
            "avg_leaf_depth": float(np.mean(self.depth_of_leaves())),
            "n_supernodes": int(self.alive().shape[0]),
            "n_roots": int(self.roots().shape[0]),
        }

    def invalidate_caches(self):
        self._children = None
        self._leaves = None
        self._incidence = None
