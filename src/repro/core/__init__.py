from repro.core.summary import Summary
from repro.core.slugger import summarize, SluggerState
from repro.core.engine import SummarizerEngine
from repro.core import baselines, encode_dp, minhash, pruning

__all__ = ["Summary", "summarize", "SluggerState", "SummarizerEngine",
           "baselines", "encode_dp", "minhash", "pruning"]
