from repro.core.summary import Summary
from repro.core.slugger import summarize, SluggerState
from repro.core import baselines, encode_dp, minhash, pruning

__all__ = ["Summary", "summarize", "SluggerState", "baselines", "encode_dp", "minhash", "pruning"]
