"""Plan-log checkpointing for the summarizer engine (DESIGN.md §11).

The record-mode replay contract (DESIGN.md §8) makes the whole merge forest
a pure function of ``(graph, engine config, plan log)``: every iteration's
`MergePlan` list replays in one canonical order via `merging.apply_plans`,
and the per-iteration RNG streams are respawned from the engine seed. So a
crash-safe checkpoint does not need the O(n) summarizer state at all — it
is just the tiny plan log plus enough identity to refuse a mismatched
resume:

    <dir>/it_<t>/            committed atomically (write tmp, rename)
        manifest.json        {version, t, fingerprint, config, counts}
        plans.npz            plan log for iterations 1..t, COLUMNAR: each
                             iteration's thousands of small per-plan arrays
                             are flattened into six int64 arrays
                             (members/rounds/pairs + their lengths)

Checkpoints are self-contained (each holds the FULL log so far — plans are
KBs, not GBs), which keeps GC trivial: retain the last ``keep`` dirs, and
resume only ever reads the newest. The commit protocol is the same
write-temp-then-``os.rename`` used by `train/checkpoint.py` — a kill
mid-save leaves only a ``.tmp`` dir, which the next writer (or
`load_latest`) sweeps away.

The columnar form exists for the < 5 % commit-overhead gate
(``BENCH_partitioned.json``): a per-plan pickle walks ~10⁴ python objects
per commit, which alone cost ~20 % of merge wall on the bench graph.
Packing is C-level ``np.concatenate``/``np.split``, and the checkpointer
caches each iteration's packed columns after the first commit touching it,
so commit ``t`` does O(iteration t) conversion work plus one sequential
``np.savez`` write — not O(t) re-serialization.

``fingerprint`` is a sha256 over the canonical CSR arrays; resuming against
a different graph, or with decision-relevant config changed (T, seed,
max_group, top_j, height_bound), raises `CheckpointMismatch`. Backend and
partition count are recorded but NOT enforced — replay determinism makes a
checkpoint written by ``numpy/partitions=1`` resumable under
``resident/partitions=4`` with a bit-identical summary.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil

import numpy as np

from repro.core.merging import MergePlan

_I64 = np.int64
_FIELDS = ("m0", "m0_len", "n_rounds", "pair_len", "a", "z")


def _cat(parts):
    return (np.concatenate(parts).astype(_I64, copy=False) if parts
            else np.zeros(0, dtype=_I64))


def _splits(flat, lens):
    if lens.size == 0:
        return []
    return np.split(flat, np.cumsum(lens)[:-1])


def pack_plans(plans: list) -> dict:
    """One iteration's `MergePlan` list → six flat int64 columns.

    ``m0``/``m0_len`` flatten the per-plan ``members0``; ``n_rounds`` is
    rounds per plan; ``a``/``z``/``pair_len`` flatten every round's pair
    arrays in (plan, round) order. Pure reshaping — `unpack_plans` is the
    exact inverse (plan/row order preserved, which replay depends on)."""
    pairs = [r for p in plans for r in p.rounds]
    return {
        "m0": _cat([p.members0 for p in plans]),
        "m0_len": np.array([p.members0.size for p in plans], dtype=_I64),
        "n_rounds": np.array([len(p.rounds) for p in plans], dtype=_I64),
        "pair_len": np.array([a.size for a, _ in pairs], dtype=_I64),
        "a": _cat([a for a, _ in pairs]),
        "z": _cat([z for _, z in pairs]),
    }


def unpack_plans(cols: dict) -> list:
    m0s = _splits(cols["m0"], cols["m0_len"])
    a_parts = _splits(cols["a"], cols["pair_len"])
    z_parts = _splits(cols["z"], cols["pair_len"])
    plans, k = [], 0
    for i, nr in enumerate(cols["n_rounds"]):
        plan = MergePlan(m0s[i])
        for _ in range(int(nr)):
            plan.rounds.append((a_parts[k], z_parts[k]))
            k += 1
        plans.append(plan)
    return plans

CKPT_VERSION = 1
# config keys that change merge decisions; a mismatch makes the logged
# plans meaningless for the requested run, so resume refuses
DECISION_KEYS = ("T", "seed", "max_group", "top_j", "height_bound")

_PREFIX = "it_"


class CheckpointMismatch(RuntimeError):
    """The checkpoint on disk belongs to a different graph or config."""


def graph_fingerprint(g) -> str:
    """sha256 of the canonical CSR arrays — the resume identity check."""
    h = hashlib.sha256()
    h.update(np.int64(g.n).tobytes())
    h.update(np.ascontiguousarray(g.indptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(g.indices, dtype=np.int32).tobytes())
    return h.hexdigest()


def _iter_dirs(ckpt_dir: str) -> list:
    """Committed iteration numbers, ascending; ``.tmp`` leftovers excluded."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(d[len(_PREFIX):]) for d in os.listdir(ckpt_dir)
                  if d.startswith(_PREFIX) and not d.endswith(".tmp"))


def _sweep_tmp(ckpt_dir: str) -> None:
    """Remove half-written ``.tmp`` dirs left by a kill mid-save."""
    if not os.path.isdir(ckpt_dir):
        return
    for d in os.listdir(ckpt_dir):
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


class PlanCheckpointer:
    """Atomic plan-log checkpoint writer/reader for one engine run."""

    def __init__(self, ckpt_dir: str, keep: int = 2):
        self.ckpt_dir = ckpt_dir
        self.keep = max(1, int(keep))
        self._packed: dict = {}  # iteration (1-based) -> packed columns
        os.makedirs(ckpt_dir, exist_ok=True)
        _sweep_tmp(ckpt_dir)

    # ------------------------------------------------------------------ save
    def save(self, t: int, plan_log: list, fingerprint: str,
             config: dict) -> str:
        """Commit the plan log for iterations ``1..t`` (``plan_log[i]`` is
        iteration ``i+1``). Atomic: the final dir appears only after
        manifest and plans are fully on disk. Iterations already packed by
        an earlier commit (or by `load_latest`) reuse their cached columns."""
        final = os.path.join(self.ckpt_dir, f"{_PREFIX}{t:06d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        arrays = {}
        for i, plans in enumerate(plan_log, start=1):
            if i not in self._packed:
                self._packed[i] = pack_plans(plans)
            for field, arr in self._packed[i].items():
                arrays[f"i{i:06d}_{field}"] = arr
        with open(os.path.join(tmp, "plans.npz"), "wb") as f:
            np.savez(f, **arrays)
        manifest = {
            "version": CKPT_VERSION,
            "t": int(t),
            "fingerprint": fingerprint,
            "config": config,
            "plan_counts": [len(plans) for plans in plan_log],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        self._gc()
        return final

    def _gc(self) -> None:
        for t in _iter_dirs(self.ckpt_dir)[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"{_PREFIX}{t:06d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------ load
    def load_latest(self, fingerprint: str, config: dict):
        """Newest committed checkpoint as ``(t, plan_log)``, or ``None``.

        Verifies the graph fingerprint and the decision-relevant config
        keys; raises `CheckpointMismatch` on any disagreement rather than
        silently producing a summary the logged plans don't describe.
        """
        its = _iter_dirs(self.ckpt_dir)
        if not its:
            return None
        d = os.path.join(self.ckpt_dir, f"{_PREFIX}{its[-1]:06d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        if manifest.get("version") != CKPT_VERSION:
            raise CheckpointMismatch(
                f"checkpoint version {manifest.get('version')} != "
                f"{CKPT_VERSION}")
        if manifest.get("fingerprint") != fingerprint:
            raise CheckpointMismatch(
                "graph fingerprint mismatch: checkpoint "
                f"{manifest.get('fingerprint')!r} vs run {fingerprint!r}")
        saved_cfg = manifest.get("config", {})
        for key in DECISION_KEYS:
            if saved_cfg.get(key) != config.get(key):
                raise CheckpointMismatch(
                    f"config mismatch on {key!r}: checkpoint "
                    f"{saved_cfg.get(key)!r} vs run {config.get(key)!r}")
        t_done = int(manifest["t"])
        plan_log = []
        with np.load(os.path.join(d, "plans.npz")) as npz:
            for i in range(1, t_done + 1):
                cols = {field: npz[f"i{i:06d}_{field}"]
                        for field in _FIELDS}
                self._packed[i] = cols
                plan_log.append(unpack_plans(cols))
        return t_done, plan_log
