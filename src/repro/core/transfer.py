"""Host↔device transfer accounting for the merge-round device paths.

The resident merge-round work (DESIGN.md §9) is justified by a transfer
model, so the model is *measured*, not asserted: every dispatch that moves
bytes across the host↔device boundary in the merge hot path — the mesh
intersection dispatch, the single-device batched ops, and the
`ResidentBitmapArena` upload/rank/fold/carry cycle — reports into the module
`GLOBAL` counter. A "round" is one device exchange cycle: one ranking
round-trip (a full-matrix intersection dispatch on the batched path, one
fused rank+Saving call on the resident path). `benchmarks/scalability.py
--resident` gates the resident backend's bytes-per-iteration reduction on
these numbers (``BENCH_resident.json``).

Counts are attributed to a *phase* — ``init`` (one-time edge/bank seeding),
``upload`` (host-rebuilt workspace state), ``rank``, ``fold``, ``carry``
(legacy root-map replay), ``candgen``, ``bank`` (adjacency-bank advance
slabs), ``extract`` (bank→arena index slabs), and ``sync`` (verification
downloads) — so a bytes regression localizes to the lifecycle stage that
caused it instead of a single aggregate number. On the ISSUE-9 bank path
the steady-state recurring uploads are ONLY ``rank``/``fold``/``bank``/
``extract`` instruction slabs; ``upload`` stays zero after seeding.

Thread safety: the engine's merge_round stage runs workspace thunks on a
``ThreadPoolExecutor``, and every thunk's arena reports into the shared
``GLOBAL`` counter — all mutation happens under one lock so concurrent
sweeps never lose counts (plain ``+=`` on the singleton did, pre-ISSUE 7).

On a single-host CPU backend the "transfer" is a memcpy rather than PCIe,
but the byte counts are exactly what a TPU deployment would ship, which is
what the model predicts and the benchmark gates.
"""
from __future__ import annotations

import threading

from repro import faults


class TransferCounter:
    """Byte/round tallies for one device path (monotonic; snapshot+delta).

    All mutators take the instance lock — `stage_merge_round` runs resident
    arena thunks on a thread pool and they all report here. Reads used for
    gating go through ``snapshot()`` (also locked) so a snapshot is always
    internally consistent.
    """

    __slots__ = ("bytes_h2d", "bytes_d2h", "rounds", "phases", "_lock")

    def __init__(self):
        self._lock = threading.Lock()
        self.reset()

    def reset(self):
        with self._lock:
            self.bytes_h2d = 0
            self.bytes_d2h = 0
            self.rounds = 0
            self.phases = {}

    def _phase_add(self, phase: str | None, nbytes: int):
        if phase is None:
            return
        self.phases[phase] = self.phases.get(phase, 0) + int(nbytes)

    def add_h2d(self, nbytes: int, phase: str | None = None):
        faults.check("transfer.h2d")
        with self._lock:
            self.bytes_h2d += int(nbytes)
            self._phase_add(phase, nbytes)

    def add_d2h(self, nbytes: int, phase: str | None = None):
        faults.check("transfer.d2h")
        with self._lock:
            self.bytes_d2h += int(nbytes)
            self._phase_add(phase, nbytes)

    def tick_round(self):
        """One device exchange cycle (ranking round-trip) completed."""
        with self._lock:
            self.rounds += 1

    def snapshot(self) -> dict:
        with self._lock:
            return {"bytes_h2d": self.bytes_h2d, "bytes_d2h": self.bytes_d2h,
                    "rounds": self.rounds, "phases": dict(self.phases)}

    def delta_since(self, snap: dict, now: dict | None = None) -> dict:
        """Totals accumulated since ``snap`` (up to ``now`` if given — the
        engine's per-iteration breakdown reuses one snapshot as both an
        interval's end and the next one's start), plus bytes/round."""
        cur = self.snapshot() if now is None else now
        d = {k: cur[k] - snap.get(k, 0)
             for k in ("bytes_h2d", "bytes_d2h", "rounds")}
        base = snap.get("phases", {})
        d["phases"] = {k: v - base.get(k, 0)
                       for k, v in cur["phases"].items()}
        total = d["bytes_h2d"] + d["bytes_d2h"]
        d["bytes_total"] = total
        d["bytes_per_round"] = total / d["rounds"] if d["rounds"] else 0.0
        return d


GLOBAL = TransferCounter()
