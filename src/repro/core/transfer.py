"""Host↔device transfer accounting for the merge-round device paths.

The resident merge-round work (DESIGN.md §9) is justified by a transfer
model, so the model is *measured*, not asserted: every dispatch that moves
bytes across the host↔device boundary in the merge hot path — the mesh
intersection dispatch, the single-device batched ops, and the
`ResidentBitmapArena` upload/top-J/fold cycle — reports into the module
`GLOBAL` counter. A "round" is one device exchange cycle: one ranking
round-trip (a full-matrix intersection dispatch on the batched path, one
fused top-J call on the resident path). `benchmarks/scalability.py
--resident` gates the resident backend's bytes-per-round reduction on these
numbers (``BENCH_resident.json``).

On a single-host CPU backend the "transfer" is a memcpy rather than PCIe,
but the byte counts are exactly what a TPU deployment would ship, which is
what the model predicts and the benchmark gates.
"""
from __future__ import annotations


class TransferCounter:
    """Byte/round tallies for one device path (monotonic; snapshot+delta)."""

    __slots__ = ("bytes_h2d", "bytes_d2h", "rounds")

    def __init__(self):
        self.reset()

    def reset(self):
        self.bytes_h2d = 0
        self.bytes_d2h = 0
        self.rounds = 0

    def add_h2d(self, nbytes: int):
        self.bytes_h2d += int(nbytes)

    def add_d2h(self, nbytes: int):
        self.bytes_d2h += int(nbytes)

    def tick_round(self):
        """One device exchange cycle (ranking round-trip) completed."""
        self.rounds += 1

    def snapshot(self) -> dict:
        return {"bytes_h2d": self.bytes_h2d, "bytes_d2h": self.bytes_d2h,
                "rounds": self.rounds}

    def delta_since(self, snap: dict) -> dict:
        """Totals accumulated since ``snap``, plus the bytes/round ratio."""
        d = {k: getattr(self, k) - snap[k] for k in snap}
        total = d["bytes_h2d"] + d["bytes_d2h"]
        d["bytes_total"] = total
        d["bytes_per_round"] = total / d["rounds"] if d["rounds"] else 0.0
        return d


GLOBAL = TransferCounter()
