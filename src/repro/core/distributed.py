"""Distributed (JAX) engine pieces for SLUGGER.

Deployment story (DESIGN.md §2.2/§6/§8): the O(|E|) scans (hashing,
segment-min shingles) and the O(k²) in-group scoring are device-side,
sharded with ``shard_map`` over the mesh's data axis; only the tiny,
inherently sequential merge decisions run on host. On a real pod the edge
list lives sharded in HBM and never leaves the devices; the host sees
(n_roots,) shingles and per-group top-pairs.

`shingle_provider` and `batched_intersections_mesh` are the production
hooks: the `SummarizerEngine` plugs them into its shingle stage and its
candidate ranking whenever ``backend="batched"`` sees more than one device
(or an explicit mesh) — this module is the engine's multi-device path, not
a stand-alone demo.

Engines:
  * ``shingles_sharded``     — edge-sharded minhash shingles (pmin combine)
  * ``shingle_provider``     — the engine hook: sharded shingles + host
                               root segment-min + leafless-root sentinel
  * ``batched_intersections_mesh`` — (B, G, W) bitset batches shard_map'd
                               over the data axis, masked kernel per shard
                               (padding early-exits; transfer-only)
  * ``greedy_group_matching``— vmapped on-device greedy matching per group
  * ``summarize_jax``        — hybrid engine: device scoring + host decisions,
                               exactness restored by the emission DP
  * ``summarize_step_fn``    — the jit-able step used by the multi-pod dry-run
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.slugger import SluggerState, _emit_encoding
from repro.core.minhash import rootwise_min
from repro.core.pruning import prune
from repro.graphs.csr import Graph

MAXU = jnp.uint32(0xFFFFFFFF)

try:  # jax ≥ 0.4.38 re-exports shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # older jax: experimental location
    from jax.experimental.shard_map import shard_map as _shard_map


def _hash_u32(x, a, b):
    h = x.astype(jnp.uint32) * jnp.uint32(a) + jnp.uint32(b)
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> jnp.uint32(15))
    return h


def node_shingles_dense(src, dst, n, a, b):
    """Replicated-reference shingle computation (src/dst = directed edges)."""
    h_self = _hash_u32(jnp.arange(n, dtype=jnp.uint32), a, b)
    h_nbr = _hash_u32(dst.astype(jnp.uint32), a, b)
    seg = jax.ops.segment_min(h_nbr, src, num_segments=n)
    return jnp.minimum(h_self, seg)


def shingles_sharded(mesh, data_axes=("data",)):
    """Edge-sharded shingles: local segment-min + cross-shard pmin.

    Returns a function (src, dst, n_static, a, b) -> (n,) uint32, where the
    edge arrays are sharded along ``data_axes`` and padded with src == n
    (padding rows fold into a dummy segment).
    """

    def _local(src, dst, h_self, a, b):
        n = h_self.shape[0]
        h_nbr = _hash_u32(dst.astype(jnp.uint32), a, b)
        seg = jax.ops.segment_min(h_nbr, src, num_segments=n + 1)[:n]
        local = jnp.minimum(h_self, seg)
        for ax in data_axes:
            local = jax.lax.pmin(local, ax)
        return local

    def fn(src, dst, n, a, b):
        h_self = _hash_u32(jnp.arange(n, dtype=jnp.uint32), a, b)
        edge_spec = P(data_axes if len(data_axes) > 1 else data_axes[0])
        return _shard_map(
            functools.partial(_local, a=a, b=b),
            mesh=mesh,
            in_specs=(edge_spec, edge_spec, P(None)),
            out_specs=P(None),
        )(src, dst, h_self)

    return fn


def root_shingles_jax(node_sh, root_of, n_ids):
    return jax.ops.segment_min(node_sh, root_of, num_segments=n_ids)


def _data_axes_of(mesh, data_axes):
    if data_axes is not None:
        return tuple(data_axes)
    from repro.launch.mesh import dp_axes_of
    return dp_axes_of(mesh)


def shingle_provider(g: Graph, mesh, data_axes=None):
    """Engine hook: mesh-sharded shingle computation (DESIGN.md §8).

    Uploads the padded, edge-sharded adjacency once; returns
    ``for_roots(root_of) -> shingle_fn(sub_seed, n_ids)`` matching the
    `minhash.candidate_groups` provider protocol. Node-level minima come
    from the `shingles_sharded` shard_map (local segment-min + cross-shard
    pmin); the root-level segment-min and the leafless-root sentinel run on
    host via the same `rootwise_min` the host path uses. Sentinels are
    ``2^32 + id`` — device hashes are uint32, so they can never collide.
    """
    data_axes = _data_axes_of(mesh, data_axes)
    n_shards = int(np.prod([mesh.shape[a] for a in data_axes]))
    src = np.repeat(np.arange(g.n), np.diff(g.indptr)).astype(np.int32)
    dst = np.asarray(g.indices, dtype=np.int32)
    pad = (-src.size) % max(n_shards, 1)
    src_p = jnp.asarray(np.concatenate([src, np.full(pad, g.n, np.int32)]))
    dst_p = jnp.asarray(np.concatenate([dst, np.zeros(pad, np.int32)]))
    sharded = shingles_sharded(mesh, data_axes)

    def for_roots(root_of: np.ndarray):
        root_of = np.asarray(root_of, dtype=np.int64)

        def shingle_fn(sub_seed: int, n_ids: int) -> np.ndarray:
            a = np.uint32((2654435761 * (int(sub_seed) | 1)) & 0xFFFFFFFF)
            b = np.uint32((int(sub_seed) * 0x9E3779B9) & 0xFFFFFFFF)
            node_sh = np.asarray(sharded(src_p, dst_p, g.n, a, b))
            return rootwise_min(node_sh.astype(np.int64), root_of, n_ids,
                                1 << 32)

        return shingle_fn

    return for_roots


from repro.kernels.common import LruCache, mesh_content_key, shard_map_no_check

_MESH_JACCARD_CACHE = LruCache(8)  # compiled shard_map executables, by shape


def batched_intersections_mesh(mesh, data_axes=None):
    """Engine hook: the bitset intersection dispatch shard_map'd over the
    mesh — the ``backend="batched"`` ranking source.

    Returns ``fn((B, G, W) uint32) -> (B, G, G) int64``: the batch is
    padded to a pow2 multiple of the shard count (jit-cache shaping), each
    shard runs `batch_masked_intersection_kernel` on its slice with its OWN
    valid-row count — real rows live in a contiguous prefix, so shard s of
    size Bs holds ``clip(B − s·Bs, 0, Bs)`` of them and the padded rows
    early-exit before the O(G²·W) popcount: padding is transfer-only
    (ISSUE 5). Intersection counts are exact integers, so merge decisions
    are bit-identical to the host ranking given the same bitmaps. Transfers
    report to `core.transfer.GLOBAL` (one ranking round per dispatch).
    """
    from repro.core.transfer import GLOBAL as TRANSFER
    from repro.kernels.bitset_jaccard.kernel import (
        batch_masked_intersection_kernel)
    from repro.kernels.common import default_interpret, pow2

    data_axes = _data_axes_of(mesh, data_axes)
    n_shards = int(np.prod([mesh.shape[a] for a in data_axes]))
    spec = P(data_axes if len(data_axes) > 1 else data_axes[0])
    mesh_key = mesh_content_key(mesh)

    def fn(bits: np.ndarray) -> np.ndarray:
        B, G, W = bits.shape
        Wp = pow2(W)
        # pad the batch to a pow2 multiple of the shard count so the jit
        # cache stays small (same rule as the single-device ops tiling)
        Bs = pow2((B + n_shards - 1) // n_shards, floor=1)
        Bp = n_shards * Bs
        batch = np.zeros((Bp, G, Wp), dtype=np.uint32)
        batch[:B, :, :W] = bits
        # per-shard valid-row counts (real rows are a contiguous prefix);
        # shipped as a sharded input so the compiled fn is B-agnostic
        valid = np.clip(B - np.arange(n_shards, dtype=np.int64) * Bs,
                        0, Bs).astype(np.int32)
        key = (mesh_key, Bp, G, Wp)
        f = _MESH_JACCARD_CACHE.get(key)
        if f is None:
            interpret = default_interpret()

            def local(bb, vv):
                return batch_masked_intersection_kernel(bb, vv,
                                                        interpret=interpret)

            f = jax.jit(shard_map_no_check(local, mesh, (spec, spec), spec))
            _MESH_JACCARD_CACHE[key] = f
        TRANSFER.add_h2d(batch.nbytes + valid.nbytes)
        inter = np.asarray(f(batch, valid))
        TRANSFER.add_d2h(inter.nbytes)
        TRANSFER.tick_round()
        return inter[:B].astype(np.int64)

    return fn


# --------------------------------------------------------------------------
# On-device greedy matching within padded candidate groups
# --------------------------------------------------------------------------
def _match_one_group(scores, threshold, max_merges):
    """Greedy maximum-score matching on a (K, K) score matrix.

    Returns (max_merges, 2) int32 pair indices, padded with -1.
    """
    K = scores.shape[0]
    scores = jnp.where(jnp.eye(K, dtype=bool), -jnp.inf, scores)

    def body(carry, _):
        sc, out, i = carry
        flat = jnp.argmax(sc)
        r, c = flat // K, flat % K
        ok = sc[r, c] >= threshold
        pair = jnp.where(ok, jnp.array([r, c], dtype=jnp.int32), jnp.array([-1, -1], dtype=jnp.int32))
        # mask the merged pair's rows/cols
        mask_r = (jnp.arange(K) == r) | (jnp.arange(K) == c)
        sc = jnp.where(ok & (mask_r[:, None] | mask_r[None, :]), -jnp.inf, sc)
        out = out.at[i].set(pair)
        return (sc, out, i + 1), None

    out0 = jnp.full((max_merges, 2), -1, dtype=jnp.int32)
    (_, out, _), _ = jax.lax.scan(body, (scores, out0, 0), None, length=max_merges)
    return out


def greedy_group_matching(scores, threshold, max_merges=None):
    """vmapped greedy matching: scores (G, K, K) -> (G, max_merges, 2)."""
    G, K, _ = scores.shape
    if max_merges is None:
        max_merges = K // 2
    return jax.vmap(lambda s: _match_one_group(s, threshold, max_merges))(scores)


def _pack_bits_jax(memb_cols):
    """(G, K, R) bool -> (G, K, W) uint32 packed."""
    G, K, R = memb_cols.shape
    W = (R + 31) // 32
    pad = W * 32 - R
    m = jnp.pad(memb_cols, ((0, 0), (0, 0), (0, pad)))
    m = m.reshape(G, K, W, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (m * weights).sum(axis=-1).astype(jnp.uint32)


def group_jaccard_scores(nbr_onehot):
    """nbr_onehot: (G, K, R) bool neighbor indicators per group member.
    Returns (G, K, K) Jaccard matrices (einsum form — MXU-friendly)."""
    x = nbr_onehot.astype(jnp.float32)
    inter = jnp.einsum("gkr,glr->gkl", x, x)
    deg = x.sum(-1)
    union = deg[:, :, None] + deg[:, None, :] - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1.0), 0.0)


# --------------------------------------------------------------------------
# The jit-able candidate-generation step used by the multi-pod dry-run
# --------------------------------------------------------------------------
def summarize_step_fn(n_nodes: int, hist: str = "sort"):
    """One SLUGGER candidate-generation + scoring step over a sharded edge
    list: shingles → candidate-group-size histogram. Lowered/compiled in the
    dry-run.

    ``hist``:
      * "sort"    — exact group sizes via jnp.unique (paper-faithful baseline;
        the sort's O(n log n) merge passes dominate HBM traffic),
      * "scatter" — §Perf iteration: hash shingles into n/500 buckets and
        scatter-add ones (O(n) traffic). Group sizes become bucket sizes —
        exactly the cap-at-500 random split the paper applies anyway
        (Sect. III-B2), so downstream semantics are unchanged.
    """

    def step(src, dst, root_of, seed):
        a = jnp.uint32(2654435761) * (seed.astype(jnp.uint32) | jnp.uint32(1))
        b = seed.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
        h_self = _hash_u32(jnp.arange(n_nodes, dtype=jnp.uint32), a, b)
        h_nbr = _hash_u32(dst.astype(jnp.uint32), a, b)
        seg = jax.ops.segment_min(h_nbr, src, num_segments=n_nodes + 1)[:n_nodes]
        node_sh = jnp.minimum(h_self, seg)
        root_sh = jax.ops.segment_min(node_sh, root_of, num_segments=n_nodes)
        if hist == "scatter":
            n_buckets = max(n_nodes // 500, 1)
            bucket = (_hash_u32(root_sh, a ^ jnp.uint32(0xA5A5A5A5), b) % jnp.uint32(n_buckets)).astype(jnp.int32)
            counts = jax.ops.segment_sum(jnp.ones_like(bucket), bucket, num_segments=n_buckets)
            return root_sh, counts[bucket]
        # group-size histogram (how full candidate sets are)
        _, inv, counts = jnp.unique(
            root_sh, return_inverse=True, return_counts=True, size=n_nodes, fill_value=MAXU
        )
        return root_sh, counts[inv]

    return step


# --------------------------------------------------------------------------
# Hybrid engine: device scoring, host decisions, DP emission for exactness
# --------------------------------------------------------------------------
def summarize_jax(
    g: Graph,
    T: int = 20,
    seed: int = 0,
    max_group: int = 128,
    prune_steps=(1, 2, 3),
    min_jaccard: float = 0.05,
):
    """Approximate-selection engine (merge picks by device-side Jaccard
    matching, verified by host-side Saving ≥ θ). Lossless by construction —
    the emission DP re-encodes the exact input graph."""
    from repro.core.merging import GroupWorkspace
    from repro.core.minhash import candidate_groups

    state = SluggerState(g)
    iter_streams = np.random.SeedSequence((seed, 31337)).spawn(max(T, 1))
    for t in range(1, T + 1):
        theta = 0.0 if t == T else 1.0 / (1 + t)
        alive = state.alive
        groups = candidate_groups(g, state.root_of, alive,
                                  seed=iter_streams[t - 1], max_group=max_group)
        if not groups:
            continue
        K = max(len(gr) for gr in groups)
        for grp in groups:
            ws = GroupWorkspace(state, grp)
            k = len(grp)
            R = ws.CNT.shape[1]
            onehot = (ws.CNT > 0)[None, :, :]
            scores = group_jaccard_scores(jnp.asarray(onehot))
            pairs = np.asarray(greedy_group_matching(scores, min_jaccard, max_merges=k // 2))[0]
            for r, c in pairs:
                if r < 0:
                    break
                if not (ws.alive[r] and ws.alive[c]):
                    continue
                sav = ws.savings(int(r), np.array([int(c)]))
                if sav[0] >= theta:
                    ws.merge(int(r), int(c))
    summary = _emit_encoding(state)
    if prune_steps:
        summary = prune(summary, steps=prune_steps)
    return summary
