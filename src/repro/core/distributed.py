"""Distributed (JAX) engine for SLUGGER.

Deployment story (DESIGN.md §2.2/§6): the O(|E|) scans (hashing, segment-min
shingles) and the O(k²) in-group scoring are device-side, sharded with
``shard_map`` over the mesh's data axis; only the tiny, inherently sequential
merge decisions run on host. On a real pod the edge list lives sharded in HBM
and never leaves the devices; the host sees (n_roots,) shingles and per-group
top-pairs.

Engines:
  * ``shingles_sharded``     — edge-sharded minhash shingles (pmin combine)
  * ``greedy_group_matching``— vmapped on-device greedy matching per group
  * ``summarize_jax``        — hybrid engine: device scoring + host decisions,
                               exactness restored by the emission DP
  * ``summarize_step_fn``    — the jit-able step used by the multi-pod dry-run
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.slugger import SluggerState, _emit_encoding
from repro.core.pruning import prune
from repro.graphs.csr import Graph

MAXU = jnp.uint32(0xFFFFFFFF)

try:  # jax ≥ 0.4.38 re-exports shard_map at the top level
    _shard_map = jax.shard_map
except AttributeError:  # older jax: experimental location
    from jax.experimental.shard_map import shard_map as _shard_map


def _hash_u32(x, a, b):
    h = x.astype(jnp.uint32) * jnp.uint32(a) + jnp.uint32(b)
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> jnp.uint32(15))
    return h


def node_shingles_dense(src, dst, n, a, b):
    """Replicated-reference shingle computation (src/dst = directed edges)."""
    h_self = _hash_u32(jnp.arange(n, dtype=jnp.uint32), a, b)
    h_nbr = _hash_u32(dst.astype(jnp.uint32), a, b)
    seg = jax.ops.segment_min(h_nbr, src, num_segments=n)
    return jnp.minimum(h_self, seg)


def shingles_sharded(mesh, data_axes=("data",)):
    """Edge-sharded shingles: local segment-min + cross-shard pmin.

    Returns a function (src, dst, n_static, a, b) -> (n,) uint32, where the
    edge arrays are sharded along ``data_axes`` and padded with src == n
    (padding rows fold into a dummy segment).
    """

    def _local(src, dst, h_self, a, b):
        n = h_self.shape[0]
        h_nbr = _hash_u32(dst.astype(jnp.uint32), a, b)
        seg = jax.ops.segment_min(h_nbr, src, num_segments=n + 1)[:n]
        local = jnp.minimum(h_self, seg)
        for ax in data_axes:
            local = jax.lax.pmin(local, ax)
        return local

    def fn(src, dst, n, a, b):
        h_self = _hash_u32(jnp.arange(n, dtype=jnp.uint32), a, b)
        edge_spec = P(data_axes if len(data_axes) > 1 else data_axes[0])
        return _shard_map(
            functools.partial(_local, a=a, b=b),
            mesh=mesh,
            in_specs=(edge_spec, edge_spec, P(None)),
            out_specs=P(None),
        )(src, dst, h_self)

    return fn


def root_shingles_jax(node_sh, root_of, n_ids):
    return jax.ops.segment_min(node_sh, root_of, num_segments=n_ids)


# --------------------------------------------------------------------------
# On-device greedy matching within padded candidate groups
# --------------------------------------------------------------------------
def _match_one_group(scores, threshold, max_merges):
    """Greedy maximum-score matching on a (K, K) score matrix.

    Returns (max_merges, 2) int32 pair indices, padded with -1.
    """
    K = scores.shape[0]
    scores = jnp.where(jnp.eye(K, dtype=bool), -jnp.inf, scores)

    def body(carry, _):
        sc, out, i = carry
        flat = jnp.argmax(sc)
        r, c = flat // K, flat % K
        ok = sc[r, c] >= threshold
        pair = jnp.where(ok, jnp.array([r, c], dtype=jnp.int32), jnp.array([-1, -1], dtype=jnp.int32))
        # mask the merged pair's rows/cols
        mask_r = (jnp.arange(K) == r) | (jnp.arange(K) == c)
        sc = jnp.where(ok & (mask_r[:, None] | mask_r[None, :]), -jnp.inf, sc)
        out = out.at[i].set(pair)
        return (sc, out, i + 1), None

    out0 = jnp.full((max_merges, 2), -1, dtype=jnp.int32)
    (_, out, _), _ = jax.lax.scan(body, (scores, out0, 0), None, length=max_merges)
    return out


def greedy_group_matching(scores, threshold, max_merges=None):
    """vmapped greedy matching: scores (G, K, K) -> (G, max_merges, 2)."""
    G, K, _ = scores.shape
    if max_merges is None:
        max_merges = K // 2
    return jax.vmap(lambda s: _match_one_group(s, threshold, max_merges))(scores)


def _pack_bits_jax(memb_cols):
    """(G, K, R) bool -> (G, K, W) uint32 packed."""
    G, K, R = memb_cols.shape
    W = (R + 31) // 32
    pad = W * 32 - R
    m = jnp.pad(memb_cols, ((0, 0), (0, 0), (0, pad)))
    m = m.reshape(G, K, W, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (m * weights).sum(axis=-1).astype(jnp.uint32)


def group_jaccard_scores(nbr_onehot):
    """nbr_onehot: (G, K, R) bool neighbor indicators per group member.
    Returns (G, K, K) Jaccard matrices (einsum form — MXU-friendly)."""
    x = nbr_onehot.astype(jnp.float32)
    inter = jnp.einsum("gkr,glr->gkl", x, x)
    deg = x.sum(-1)
    union = deg[:, :, None] + deg[:, None, :] - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1.0), 0.0)


# --------------------------------------------------------------------------
# The jit-able candidate-generation step used by the multi-pod dry-run
# --------------------------------------------------------------------------
def summarize_step_fn(n_nodes: int, hist: str = "sort"):
    """One SLUGGER candidate-generation + scoring step over a sharded edge
    list: shingles → candidate-group-size histogram. Lowered/compiled in the
    dry-run.

    ``hist``:
      * "sort"    — exact group sizes via jnp.unique (paper-faithful baseline;
        the sort's O(n log n) merge passes dominate HBM traffic),
      * "scatter" — §Perf iteration: hash shingles into n/500 buckets and
        scatter-add ones (O(n) traffic). Group sizes become bucket sizes —
        exactly the cap-at-500 random split the paper applies anyway
        (Sect. III-B2), so downstream semantics are unchanged.
    """

    def step(src, dst, root_of, seed):
        a = jnp.uint32(2654435761) * (seed.astype(jnp.uint32) | jnp.uint32(1))
        b = seed.astype(jnp.uint32) * jnp.uint32(0x9E3779B9)
        h_self = _hash_u32(jnp.arange(n_nodes, dtype=jnp.uint32), a, b)
        h_nbr = _hash_u32(dst.astype(jnp.uint32), a, b)
        seg = jax.ops.segment_min(h_nbr, src, num_segments=n_nodes + 1)[:n_nodes]
        node_sh = jnp.minimum(h_self, seg)
        root_sh = jax.ops.segment_min(node_sh, root_of, num_segments=n_nodes)
        if hist == "scatter":
            n_buckets = max(n_nodes // 500, 1)
            bucket = (_hash_u32(root_sh, a ^ jnp.uint32(0xA5A5A5A5), b) % jnp.uint32(n_buckets)).astype(jnp.int32)
            counts = jax.ops.segment_sum(jnp.ones_like(bucket), bucket, num_segments=n_buckets)
            return root_sh, counts[bucket]
        # group-size histogram (how full candidate sets are)
        _, inv, counts = jnp.unique(
            root_sh, return_inverse=True, return_counts=True, size=n_nodes, fill_value=MAXU
        )
        return root_sh, counts[inv]

    return step


# --------------------------------------------------------------------------
# Hybrid engine: device scoring, host decisions, DP emission for exactness
# --------------------------------------------------------------------------
def summarize_jax(
    g: Graph,
    T: int = 20,
    seed: int = 0,
    max_group: int = 128,
    prune_steps=(1, 2, 3),
    min_jaccard: float = 0.05,
):
    """Approximate-selection engine (merge picks by device-side Jaccard
    matching, verified by host-side Saving ≥ θ). Lossless by construction —
    the emission DP re-encodes the exact input graph."""
    from repro.core.merging import GroupWorkspace
    from repro.core.minhash import candidate_groups

    state = SluggerState(g)
    rng = np.random.default_rng(seed)
    for t in range(1, T + 1):
        theta = 0.0 if t == T else 1.0 / (1 + t)
        alive = state.alive
        groups = candidate_groups(g, state.root_of, alive, seed=seed * 31337 + t, max_group=max_group)
        if not groups:
            continue
        K = max(len(gr) for gr in groups)
        for grp in groups:
            ws = GroupWorkspace(state, grp)
            k = len(grp)
            R = ws.CNT.shape[1]
            onehot = (ws.CNT > 0)[None, :, :]
            scores = group_jaccard_scores(jnp.asarray(onehot))
            pairs = np.asarray(greedy_group_matching(scores, min_jaccard, max_merges=k // 2))[0]
            for r, c in pairs:
                if r < 0:
                    break
                if not (ws.alive[r] and ws.alive[c]):
                    continue
                sav = ws.savings(int(r), np.array([int(c)]))
                if sav[0] >= theta:
                    ws.merge(int(r), int(c))
    summary = _emit_encoding(state)
    if prune_steps:
        summary = prune(summary, steps=prune_steps)
    return summary
