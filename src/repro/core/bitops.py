"""Shared bit-twiddling helpers for the merge engines.

``np.bitwise_count`` only exists on NumPy >= 2.0; every popcount consumer
(the per-group Jaccard ranking, the batched engine's NumPy fallback, the
benchmark harness) goes through :func:`popcount` so older NumPy falls back to
the same SWAR sequence the Pallas kernel uses on TPU (where there is no
popcount primitive either).
"""
from __future__ import annotations

import numpy as np

_HAS_NATIVE = hasattr(np, "bitwise_count")


def popcount_swar(x: np.ndarray) -> np.ndarray:
    """SWAR per-element popcount for uint32/uint64 arrays (uint8 result)."""
    x = np.asarray(x)
    if x.dtype == np.uint64:
        one, two, four = np.uint64(1), np.uint64(2), np.uint64(4)
        x = x - ((x >> one) & np.uint64(0x5555555555555555))
        x = (x & np.uint64(0x3333333333333333)) + ((x >> two) & np.uint64(0x3333333333333333))
        x = (x + (x >> four)) & np.uint64(0x0F0F0F0F0F0F0F0F)
        return ((x * np.uint64(0x0101010101010101)) >> np.uint64(56)).astype(np.uint8)
    if x.dtype == np.uint32:
        one, two, four = np.uint32(1), np.uint32(2), np.uint32(4)
        x = x - ((x >> one) & np.uint32(0x55555555))
        x = (x & np.uint32(0x33333333)) + ((x >> two) & np.uint32(0x33333333))
        x = (x + (x >> four)) & np.uint32(0x0F0F0F0F)
        return ((x * np.uint32(0x01010101)) >> np.uint32(24)).astype(np.uint8)
    raise TypeError(f"popcount_swar expects uint32/uint64, got {x.dtype}")


def popcount(x: np.ndarray) -> np.ndarray:
    """Per-element popcount: native ``np.bitwise_count`` when available."""
    if _HAS_NATIVE:
        return np.bitwise_count(x)
    return popcount_swar(x)
