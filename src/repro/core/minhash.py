"""Candidate generation via min-hash shingles (Sect. III-B2).

Roots whose (subnode-level) neighborhoods share their minimum hash value land
in the same candidate set — a 1-permutation min-hash that groups roots within
graph distance ≤ 2 with high probability (mergers at distance ≥ 3 always
increase cost, Lemma 1). Oversized groups are re-shingled with fresh seeds up
to ``max_rehash`` times (paper: 10) and finally split randomly to ≤
``max_group`` (paper: 500).

The numpy implementation below is the exact engine's; `repro.core.distributed`
holds the jax/shard_map version and `repro.kernels.minhash` the Pallas kernel.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.csr import Graph

_P = (1 << 61) - 1  # Mersenne prime for universal hashing


def _hash(x: np.ndarray, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = int(rng.integers(1, _P))
    b = int(rng.integers(0, _P))
    return (a * x.astype(np.int64) + b) % _P


def node_level_min(g: Graph, seed: int) -> np.ndarray:
    """min(h(u), min_{w ∈ N(u)} h(w)) per subnode — one O(|E|) pass."""
    h = _hash(np.arange(g.n), seed)
    nm = h.copy()
    if g.indices.size:
        src = np.repeat(np.arange(g.n), np.diff(g.indptr))
        np.minimum.at(nm, src, h[g.indices])
    return nm


def root_shingles(g: Graph, root_of: np.ndarray, seed: int) -> dict:
    """shingle(A) = min over leaves u ∈ A of node_level_min(u)."""
    nm = node_level_min(g, seed)
    out: dict = {}
    # segment-min over root ids
    order = np.argsort(root_of, kind="stable")
    sorted_roots = root_of[order]
    sorted_vals = nm[order]
    boundaries = np.flatnonzero(np.diff(sorted_roots)) + 1
    starts = np.concatenate([[0], boundaries])
    ends = np.concatenate([boundaries, [sorted_roots.shape[0]]])
    mins = np.minimum.reduceat(sorted_vals, starts)
    for s, e, mn in zip(starts, ends, mins):
        out[int(sorted_roots[s])] = int(mn)
    return out


def candidate_groups(
    g: Graph,
    root_of: np.ndarray,
    alive_roots: np.ndarray,
    seed: int,
    max_group: int = 500,
    max_rehash: int = 10,
) -> list:
    """Partition alive roots into candidate sets of size ≤ max_group."""
    rng = np.random.default_rng(seed)
    sh = root_shingles(g, root_of, seed)
    buckets: dict = {}
    for r in alive_roots:
        buckets.setdefault(sh.get(int(r), int(r)), []).append(int(r))

    groups: list = []
    pending = [grp for grp in buckets.values() if len(grp) > 1]
    rehash = 0
    while pending:
        oversized = [grp for grp in pending if len(grp) > max_group]
        groups.extend(grp for grp in pending if 1 < len(grp) <= max_group)
        if not oversized:
            break
        rehash += 1
        if rehash > max_rehash:
            # random split to max_group
            for grp in oversized:
                grp = list(grp)
                rng.shuffle(grp)
                for i in range(0, len(grp), max_group):
                    chunk = grp[i : i + max_group]
                    if len(chunk) > 1:
                        groups.append(chunk)
            break
        sh2 = root_shingles(g, root_of, seed * 1000003 + rehash)
        pending = []
        for grp in oversized:
            sub: dict = {}
            for r in grp:
                sub.setdefault(sh2.get(int(r), int(r)), []).append(r)
            pending.extend(v for v in sub.values() if len(v) > 1)
    return groups
