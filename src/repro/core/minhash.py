"""Candidate generation via min-hash shingles (Sect. III-B2).

Roots whose (subnode-level) neighborhoods share their minimum hash value land
in the same candidate set — a 1-permutation min-hash that groups roots within
graph distance ≤ 2 with high probability (mergers at distance ≥ 3 always
increase cost, Lemma 1). Oversized groups are re-shingled with fresh seeds up
to ``max_rehash`` times (paper: 10) and finally split randomly to ≤
``max_group`` (paper: 500).

Everything below is O(|E|) segment array work (argsort/reduceat) — no Python
dict loops; `repro.core.distributed` holds the jax/shard_map version and
`repro.kernels.minhash` the Pallas kernel.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.csr import Graph

_P = (1 << 61) - 1  # Mersenne prime for universal hashing


def _hash(x: np.ndarray, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = int(rng.integers(1, _P))
    b = int(rng.integers(0, _P))
    return (a * x.astype(np.int64) + b) % _P


def node_level_min(g: Graph, seed: int) -> np.ndarray:
    """min(h(u), min_{w ∈ N(u)} h(w)) per subnode — one O(|E|) pass."""
    h = _hash(np.arange(g.n), seed)
    nm = h.copy()
    if g.indices.size:
        src = np.repeat(np.arange(g.n), np.diff(g.indptr))
        np.minimum.at(nm, src, h[g.indices])
    return nm


def root_shingles(g: Graph, root_of: np.ndarray, seed: int, n_ids=None) -> np.ndarray:
    """shingle(A) = min over leaves u ∈ A of node_level_min(u).

    Returns an array indexed by root id (size ``n_ids``); ids owning no
    leaves get ``_P + id`` as a unique sentinel — genuine hashes live in
    [0, _P), so a leafless root can never collide with (and spuriously
    group under) another root's real shingle value.
    """
    if n_ids is None:
        n_ids = int(root_of.max()) + 1 if root_of.size else 0
    nm = node_level_min(g, seed)
    out = np.full(n_ids, -1, dtype=np.int64)
    if root_of.size:
        # segment-min over root ids
        order = np.argsort(root_of, kind="stable")
        sorted_roots = root_of[order]
        sorted_vals = nm[order]
        starts = np.concatenate([[0], np.flatnonzero(np.diff(sorted_roots)) + 1])
        out[sorted_roots[starts]] = np.minimum.reduceat(sorted_vals, starts)
    missing = np.flatnonzero(out < 0)
    out[missing] = _P + missing
    return out


def _split_groups(roots: np.ndarray, keys: np.ndarray, sub_keys=None) -> list:
    """Partition ``roots`` by key (optionally refined by ``sub_keys``),
    dropping singletons. Returns a list of int64 arrays."""
    if roots.size < 2:
        return []
    if sub_keys is None:
        order = np.argsort(keys, kind="stable")
        k = keys[order]
        head = np.empty(k.size, dtype=bool)
        head[0] = True
        np.not_equal(k[1:], k[:-1], out=head[1:])
    else:
        order = np.lexsort((sub_keys, keys))
        k, sk = keys[order], sub_keys[order]
        head = np.empty(k.size, dtype=bool)
        head[0] = True
        head[1:] = (k[1:] != k[:-1]) | (sk[1:] != sk[:-1])
    sorted_roots = roots[order]
    bounds = np.flatnonzero(head)
    sizes = np.diff(np.concatenate([bounds, [roots.size]]))
    pieces = np.split(sorted_roots, bounds[1:])
    return [p for p, sz in zip(pieces, sizes) if sz > 1]


def candidate_groups(
    g: Graph,
    root_of: np.ndarray,
    alive_roots: np.ndarray,
    seed: int,
    max_group: int = 500,
    max_rehash: int = 10,
) -> list:
    """Partition alive roots into candidate sets of size ≤ max_group."""
    alive_roots = np.asarray(alive_roots, dtype=np.int64)
    if alive_roots.size < 2:
        return []
    n_ids = int(max(int(root_of.max()) if root_of.size else 0, int(alive_roots.max()))) + 1
    rng = np.random.default_rng(seed)
    sh = root_shingles(g, root_of, seed, n_ids)
    pending = _split_groups(alive_roots, sh[alive_roots])

    groups: list = []
    rehash = 0
    while pending:
        oversized = [grp for grp in pending if grp.size > max_group]
        groups.extend(grp for grp in pending if grp.size <= max_group)
        if not oversized:
            break
        rehash += 1
        members = np.concatenate(oversized)
        if rehash > max_rehash:
            # random split to max_group
            gidx = np.repeat(np.arange(len(oversized)), [o.size for o in oversized])
            perm = rng.permutation(members.size)
            members, gidx = members[perm], gidx[perm]
            order = np.argsort(gidx, kind="stable")
            members, gidx = members[order], gidx[order]
            bounds = np.concatenate([[0], np.flatnonzero(np.diff(gidx)) + 1, [gidx.size]])
            for s, e in zip(bounds[:-1], bounds[1:]):
                for i in range(s, e, max_group):
                    chunk = members[i : min(i + max_group, e)]
                    if chunk.size > 1:
                        groups.append(chunk)
            break
        sh2 = root_shingles(g, root_of, seed * 1000003 + rehash, n_ids)
        gidx = np.repeat(np.arange(len(oversized)), [o.size for o in oversized])
        pending = _split_groups(members, gidx, sh2[members])
    return groups
