"""Candidate generation via min-hash shingles (Sect. III-B2).

Roots whose (subnode-level) neighborhoods share their minimum hash value land
in the same candidate set — a 1-permutation min-hash that groups roots within
graph distance ≤ 2 with high probability (mergers at distance ≥ 3 always
increase cost, Lemma 1). Oversized groups are re-shingled with fresh seeds up
to ``max_rehash`` times (paper: 10) and finally split randomly to ≤
``max_group`` (paper: 500).

Everything below is O(|E|) segment array work (argsort/reduceat) — no Python
dict loops; `repro.core.distributed` holds the jax/shard_map version and
`repro.kernels.minhash` the Pallas kernel.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.csr import Graph

_P = (1 << 61) - 1  # Mersenne prime for universal hashing


def _hash(x: np.ndarray, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    a = int(rng.integers(1, _P))
    b = int(rng.integers(0, _P))
    return (a * x.astype(np.int64) + b) % _P


def node_level_min(g: Graph, seed: int) -> np.ndarray:
    """min(h(u), min_{w ∈ N(u)} h(w)) per subnode — one O(|E|) pass."""
    h = _hash(np.arange(g.n), seed)
    nm = h.copy()
    if g.indices.size:
        src = np.repeat(np.arange(g.n), np.diff(g.indptr))
        np.minimum.at(nm, src, h[g.indices])
    return nm


def rootwise_min(values: np.ndarray, root_of: np.ndarray, n_ids: int,
                 sentinel_base: int) -> np.ndarray:
    """Segment-min of per-leaf ``values`` over root ids, with ids owning no
    leaves set to the unique sentinel ``sentinel_base + id``. Shared by the
    host shingle path and the mesh-sharded one (`core/distributed`) — the
    sentinel rule must match so leafless roots never spuriously group."""
    out = np.full(n_ids, -1, dtype=np.int64)
    if root_of.size:
        order = np.argsort(root_of, kind="stable")
        sorted_roots = root_of[order]
        sorted_vals = np.asarray(values, dtype=np.int64)[order]
        starts = np.concatenate([[0], np.flatnonzero(np.diff(sorted_roots)) + 1])
        out[sorted_roots[starts]] = np.minimum.reduceat(sorted_vals, starts)
    missing = np.flatnonzero(out < 0)
    out[missing] = sentinel_base + missing
    return out


def root_shingles(g: Graph, root_of: np.ndarray, seed: int, n_ids=None) -> np.ndarray:
    """shingle(A) = min over leaves u ∈ A of node_level_min(u).

    Returns an array indexed by root id (size ``n_ids``); ids owning no
    leaves get ``_P + id`` as a unique sentinel — genuine hashes live in
    [0, _P), so a leafless root can never collide with (and spuriously
    group under) another root's real shingle value.
    """
    if n_ids is None:
        n_ids = int(root_of.max()) + 1 if root_of.size else 0
    nm = node_level_min(g, seed)
    return rootwise_min(nm, root_of, n_ids, _P)


# ---------------------------------------------------------------------------
# Unified u32 shingle family (DESIGN.md §9, ISSUE 7)
#
# The engine's FOUR shingle paths — this host twin, the mesh shard_map
# (`core/distributed.shingles_sharded`), the replicated device reference
# (`node_shingles_dense`) and the resident run context's on-device root
# shingles — all hash with the same uint32 mix, so every backend of one run
# groups identically and the cross-backend bit-identity contract covers
# candidate generation too. (`candidate_groups`' DEFAULT shingle, used by
# the classic `slugger.summarize` internals and direct API callers, remains
# the Mersenne `_hash` family above.)
# ---------------------------------------------------------------------------
def u32_seed_consts(sub_seed: int):
    """The (a, b) uint32 hash constants every path derives from a seed."""
    a = np.uint32((2654435761 * (int(sub_seed) | 1)) & 0xFFFFFFFF)
    b = np.uint32((int(sub_seed) * 0x9E3779B9) & 0xFFFFFFFF)
    return a, b


def hash_u32(x: np.ndarray, a, b) -> np.ndarray:
    """NumPy twin of `core/distributed._hash_u32` — identical bit mix."""
    h = x.astype(np.uint32) * np.uint32(a) + np.uint32(b)
    h = h ^ (h >> np.uint32(16))
    h = h * np.uint32(0x7FEB352D)
    h = h ^ (h >> np.uint32(15))
    return h


def node_shingles_u32(g: Graph, sub_seed: int) -> np.ndarray:
    """Per-subnode u32 shingle: min(h(u), min over neighbors h(w))."""
    a, b = u32_seed_consts(sub_seed)
    h_self = hash_u32(np.arange(g.n, dtype=np.uint32), a, b)
    seg = np.full(g.n, 0xFFFFFFFF, dtype=np.uint32)
    if g.indices.size:
        src = np.repeat(np.arange(g.n), np.diff(g.indptr))
        np.minimum.at(seg, src, hash_u32(
            np.asarray(g.indices, dtype=np.uint32), a, b))
    return np.minimum(h_self, seg)


def host_shingle_provider(g: Graph):
    """Engine hook: the single-device host path of the unified u32 family.

    ``for_roots(root_of) -> shingle_fn(sub_seed, n_ids)`` with the same
    provider protocol (and the same ``2^32 + id`` leafless-root sentinel)
    as the mesh `core/distributed.shingle_provider` — given the same
    root_of and seeds, both return identical arrays.
    """

    def for_roots(root_of: np.ndarray):
        root_of = np.asarray(root_of, dtype=np.int64)

        def shingle_fn(sub_seed: int, n_ids: int) -> np.ndarray:
            node_sh = node_shingles_u32(g, sub_seed)
            return rootwise_min(node_sh.astype(np.int64), root_of, n_ids,
                                1 << 32)

        return shingle_fn

    return for_roots


def _split_groups(roots: np.ndarray, keys: np.ndarray, sub_keys=None) -> list:
    """Partition ``roots`` by key (optionally refined by ``sub_keys``),
    dropping singletons. Returns a list of int64 arrays."""
    if roots.size < 2:
        return []
    if sub_keys is None:
        order = np.argsort(keys, kind="stable")
        k = keys[order]
        head = np.empty(k.size, dtype=bool)
        head[0] = True
        np.not_equal(k[1:], k[:-1], out=head[1:])
    else:
        order = np.lexsort((sub_keys, keys))
        k, sk = keys[order], sub_keys[order]
        head = np.empty(k.size, dtype=bool)
        head[0] = True
        head[1:] = (k[1:] != k[:-1]) | (sk[1:] != sk[:-1])
    sorted_roots = roots[order]
    bounds = np.flatnonzero(head)
    sizes = np.diff(np.concatenate([bounds, [roots.size]]))
    pieces = np.split(sorted_roots, bounds[1:])
    return [p for p, sz in zip(pieces, sizes) if sz > 1]


def shingle_seed_streams(seed, max_rehash: int):
    """Per-rehash shingle seeds + the split RNG, derived collision-free.

    ``seed`` may be an int or a ``np.random.SeedSequence``; either way the
    ``max_rehash + 1`` shingle seeds and the random-split generator come from
    spawned children, so distinct (outer seed, iteration) pairs can never
    alias the way the old ``seed * 7919 + t`` / ``seed * 1000003 + rehash``
    arithmetic could (e.g. seed=0,t=7919 vs seed=1,t=0).
    """
    ss = (seed if isinstance(seed, np.random.SeedSequence)
          else np.random.SeedSequence(seed))
    children = ss.spawn(max_rehash + 2)
    seeds = [int(c.generate_state(1, dtype=np.uint64)[0]) for c in children[:-1]]
    return seeds, np.random.default_rng(children[-1])


def candidate_groups(
    g: Graph,
    root_of: np.ndarray,
    alive_roots: np.ndarray,
    seed,
    max_group: int = 500,
    max_rehash: int = 10,
    shingle_fn=None,
) -> list:
    """Partition alive roots into candidate sets of size ≤ max_group.

    ``seed`` is an int or a ``SeedSequence`` (engine iterations pass spawned
    streams). ``shingle_fn(sub_seed, n_ids) -> (n_ids,) int64`` overrides how
    per-root shingles are computed — the engine's mesh-dispatched path
    (`core/distributed.shingle_provider`) plugs in here; the default is the
    host `root_shingles`.
    """
    alive_roots = np.asarray(alive_roots, dtype=np.int64)
    if alive_roots.size < 2:
        return []
    n_ids = int(max(int(root_of.max()) if root_of.size else 0, int(alive_roots.max()))) + 1
    if shingle_fn is None:
        def shingle_fn(sub_seed, nn):
            return root_shingles(g, root_of, sub_seed, nn)
    seeds, rng = shingle_seed_streams(seed, max_rehash)
    sh = shingle_fn(seeds[0], n_ids)
    pending = _split_groups(alive_roots, sh[alive_roots])

    groups: list = []
    rehash = 0
    while pending:
        oversized = [grp for grp in pending if grp.size > max_group]
        groups.extend(grp for grp in pending if grp.size <= max_group)
        if not oversized:
            break
        rehash += 1
        members = np.concatenate(oversized)
        if rehash > max_rehash:
            # random split to max_group
            gidx = np.repeat(np.arange(len(oversized)), [o.size for o in oversized])
            perm = rng.permutation(members.size)
            members, gidx = members[perm], gidx[perm]
            order = np.argsort(gidx, kind="stable")
            members, gidx = members[order], gidx[order]
            bounds = np.concatenate([[0], np.flatnonzero(np.diff(gidx)) + 1, [gidx.size]])
            for s, e in zip(bounds[:-1], bounds[1:]):
                for i in range(s, e, max_group):
                    chunk = members[i : min(i + max_group, e)]
                    if chunk.size > 1:
                        groups.append(chunk)
            break
        sh2 = shingle_fn(seeds[rehash], n_ids)
        gidx = np.repeat(np.arange(len(oversized)), [o.size for o in oversized])
        pending = _split_groups(members, gidx, sh2[members])
    return groups
