"""Optimal pairwise hierarchical encoding (recursive dynamic program).

Role in the system: the paper updates p/n-edges *locally* during each merger,
exhaustively searching encodings over ≤10 supernodes with a memoized pattern
table (Sect. III-B3). We implement the same search as an exact DP over the two
hierarchy trees of a root pair, which (a) contains the paper's option space,
(b) contains the flat model's option space (descend to leaves), and (c) runs
in O(points · depth) with full/empty shortcuts. Per-(X,Y,parity) memoization
plays the role of the paper's lookup table.

This module is the SEMANTICS REFERENCE: production emission runs the batched
level-synchronous form of the same DP over the flat Summary IR
(`core/encode_batched.py`, DESIGN.md §5.2), which must reproduce this
recursion's edge output bit for bit (test-enforced). The recursion remains
the `backend="loop"` path and the fallback for non-binary forests.

Semantics: ``parity`` is the p−n balance contributed by edges placed at
strict-ancestor pairs. At a pair (X, Y) with parity c we may either descend
(children pairs inherit c), or place one edge — a p-edge if c == 0, an n-edge
if c == 1 (the paper's validity restriction p−n ∈ {0,1} for every subnode
pair holds by construction) — after which descendants see parity 1−c.

    enc(X, Y, 0) = 0                       if E_XY empty
                 = min(1 + D(X,Y,1), D(X,Y,0))   otherwise
    enc(X, Y, 1) = 0                       if E_XY complete
                 = min(1 + D(X,Y,0), D(X,Y,1))   otherwise
    D(X, Y, c)   = Σ_{children pairs} enc(x_i, y_j, c)   (∞ at leaf pairs)

Ties prefer descending: edges land as deep as possible, which lets the pruning
pass remove hierarchy nodes that carry no edges (maximizing |H| savings).
"""
from __future__ import annotations

import numpy as np

INF = float("inf")


class TreeView:
    """A root's hierarchy tree with contiguous DFS leaf intervals per node."""

    __slots__ = ("root_gid", "gid", "lo", "hi", "kids", "n_leaves")

    def __init__(self, root_gid: int, children: dict, n_graph_leaves: int):
        self.root_gid = int(root_gid)
        self.gid: list[int] = []
        self.lo: list[int] = []
        self.hi: list[int] = []
        self.kids: list[list[int]] = []
        counter = [0]

        def build(g: int) -> int:
            my = len(self.gid)
            self.gid.append(int(g))
            self.lo.append(0)
            self.hi.append(0)
            self.kids.append([])
            ch = children.get(int(g), []) if g >= n_graph_leaves else []
            if not ch:
                self.lo[my] = counter[0]
                counter[0] += 1
                self.hi[my] = counter[0]
            else:
                self.lo[my] = counter[0]
                for c in ch:
                    self.kids[my].append(build(c))
                self.hi[my] = counter[0]
            return my

        build(root_gid)
        self.n_leaves = counter[0]

    def size(self, x: int) -> int:
        return self.hi[x] - self.lo[x]

    def leaf_order(self, children: dict, n_graph_leaves: int) -> np.ndarray:
        """Global leaf ids in this tree's DFS order."""
        out = []

        def walk(g):
            ch = children.get(int(g), []) if g >= n_graph_leaves else []
            if not ch:
                out.append(int(g))
            else:
                for c in ch:
                    walk(c)

        walk(self.root_gid)
        return np.array(out, dtype=np.int64)


def _split_by_children(tv: TreeView, x: int, pos: np.ndarray) -> np.ndarray:
    """Child-bucket index of each position under node x."""
    bounds = np.array([tv.lo[k] for k in tv.kids[x]], dtype=np.int64)
    return np.searchsorted(bounds, pos, side="right") - 1


def encode_pair(tvA: TreeView, tvB: TreeView, pa: np.ndarray, pb: np.ndarray):
    """Minimal encoding of the bipartite subedges between two root trees.

    ``pa[k], pb[k]``: leaf positions (in each tree's DFS order) of subedge k.
    Returns (cost, edges) with edges = [(gidA, gidB, sign), ...].
    """
    memo: dict = {}

    def enc(x: int, y: int, par: int, pa, pb):
        key = (x, y, par)
        hit = memo.get(key)
        if hit is not None:
            return hit
        cnt = pa.shape[0]
        poss = tvA.size(x) * tvB.size(y)
        if par == 0 and cnt == 0:
            res = (0, [])
        elif par == 1 and cnt == poss:
            res = (0, [])
        else:
            c_desc, e_desc = _descend(x, y, par, pa, pb)
            c_flip, e_flip = _descend(x, y, 1 - par, pa, pb)
            sign = 1 if par == 0 else -1
            placed = 1 + c_flip
            if c_desc <= placed:
                res = (c_desc, e_desc)
            else:
                res = (placed, [(tvA.gid[x], tvB.gid[y], sign)] + e_flip)
        memo[key] = res
        return res

    def _descend(x: int, y: int, par: int, pa, pb):
        kx, ky = tvA.kids[x], tvB.kids[y]
        if not kx and not ky:  # leaf-leaf: direct cost
            cnt = pa.shape[0]
            ok = (par == 1 and cnt == 1) or (par == 0 and cnt == 0)
            if ok:
                return 0, []
            sign = 1 if par == 0 else -1
            return 1, [(tvA.gid[x], tvB.gid[y], sign)]
        if kx and ky:
            ca = _split_by_children(tvA, x, pa)
            cb = _split_by_children(tvB, y, pb)
            total, edges = 0, []
            for i, xi in enumerate(kx):
                mi = ca == i
                for j, yj in enumerate(ky):
                    m = mi & (cb == j)
                    c, e = enc(xi, yj, par, pa[m], pb[m])
                    if c == INF:
                        return INF, []
                    total += c
                    edges += e
            return total, edges
        if kx:
            ca = _split_by_children(tvA, x, pa)
            total, edges = 0, []
            for i, xi in enumerate(kx):
                m = ca == i
                c, e = enc(xi, y, par, pa[m], pb[m])
                total += c
                edges += e
            return total, edges
        cb = _split_by_children(tvB, y, pb)
        total, edges = 0, []
        for j, yj in enumerate(ky):
            m = cb == j
            c, e = enc(x, yj, par, pa[m], pb[m])
            total += c
            edges += e
        return total, edges

    # shortcut for empty pairs handled inside enc
    return enc(0, 0, 0, np.asarray(pa, dtype=np.int64), np.asarray(pb, dtype=np.int64))


def encode_self(tv: TreeView, pu: np.ndarray, pv: np.ndarray):
    """Minimal encoding of the subedges *within* one root tree.

    ``pu[k] < pv[k]``: positions of subedge k's endpoints in DFS order.
    """
    memo_self: dict = {}
    memo_cross: dict = {}

    def enc_cross(x: int, y: int, par: int, pa, pb):
        key = (x, y, par)
        hit = memo_cross.get(key)
        if hit is not None:
            return hit
        cnt = pa.shape[0]
        poss = tv.size(x) * tv.size(y)
        if par == 0 and cnt == 0:
            res = (0, [])
        elif par == 1 and cnt == poss:
            res = (0, [])
        else:
            c_desc, e_desc = _descend_cross(x, y, par, pa, pb)
            c_flip, e_flip = _descend_cross(x, y, 1 - par, pa, pb)
            sign = 1 if par == 0 else -1
            placed = 1 + c_flip
            if c_desc <= placed:
                res = (c_desc, e_desc)
            else:
                res = (placed, [(tv.gid[x], tv.gid[y], sign)] + e_flip)
        memo_cross[key] = res
        return res

    def _descend_cross(x: int, y: int, par: int, pa, pb):
        kx, ky = tv.kids[x], tv.kids[y]
        if not kx and not ky:
            cnt = pa.shape[0]
            ok = (par == 1 and cnt == 1) or (par == 0 and cnt == 0)
            if ok:
                return 0, []
            sign = 1 if par == 0 else -1
            return 1, [(tv.gid[x], tv.gid[y], sign)]
        if kx and ky:
            ca = _split_by_children(tv, x, pa)
            cb = _split_by_children(tv, y, pb)
            total, edges = 0, []
            for i, xi in enumerate(kx):
                mi = ca == i
                for j, yj in enumerate(ky):
                    m = mi & (cb == j)
                    c, e = enc_cross(xi, yj, par, pa[m], pb[m])
                    total += c
                    edges += e
            return total, edges
        if kx:
            ca = _split_by_children(tv, x, pa)
            total, edges = 0, []
            for i, xi in enumerate(kx):
                m = ca == i
                c, e = enc_cross(xi, y, par, pa[m], pb[m])
                total += c
                edges += e
            return total, edges
        cb = _split_by_children(tv, y, pb)
        total, edges = 0, []
        for j, yj in enumerate(ky):
            m = cb == j
            c, e = enc_cross(x, yj, par, pa[m], pb[m])
            total += c
            edges += e
        return total, edges

    def enc_self(x: int, par: int, pu, pv):
        key = (x, par)
        hit = memo_self.get(key)
        if hit is not None:
            return hit
        s = tv.size(x)
        poss = s * (s - 1) // 2
        cnt = pu.shape[0]
        if poss == 0:
            res = (0, [])
        elif par == 0 and cnt == 0:
            res = (0, [])
        elif par == 1 and cnt == poss:
            res = (0, [])
        else:
            c_desc, e_desc = _descend_self(x, par, pu, pv)
            c_flip, e_flip = _descend_self(x, 1 - par, pu, pv)
            sign = 1 if par == 0 else -1
            placed = 1 + c_flip
            if c_desc <= placed:
                res = (c_desc, e_desc)
            else:
                res = (placed, [(tv.gid[x], tv.gid[x], sign)] + e_flip)
        memo_self[key] = res
        return res

    def _descend_self(x: int, par: int, pu, pv):
        kx = tv.kids[x]
        if not kx:  # single leaf: poss == 0, nothing to encode
            return 0, []
        cu = _split_by_children(tv, x, pu)
        cv = _split_by_children(tv, x, pv)
        total, edges = 0, []
        for i, xi in enumerate(kx):
            m = (cu == i) & (cv == i)
            c, e = enc_self(xi, par, pu[m], pv[m])
            total += c
            edges += e
            for j in range(i + 1, len(kx)):
                mc = (cu == i) & (cv == j)
                c, e = enc_cross(xi, kx[j], par, pu[mc], pv[mc])
                total += c
                edges += e
        return total, edges

    return enc_self(0, 0, np.asarray(pu, dtype=np.int64), np.asarray(pv, dtype=np.int64))


def flat_pair_cost(cnt: int, sa: int, sb: int) -> int:
    """Flat (previous-model) cost of a root pair: either leaf corrections only
    (cnt) or one p-edge plus negative corrections (poss − cnt + 1)."""
    if cnt == 0:
        return 0
    poss = sa * sb
    return min(cnt, poss - cnt + 1)


def flat_self_cost(cnt: int, s: int) -> int:
    if cnt == 0:
        return 0
    poss = s * (s - 1) // 2
    return min(cnt, poss - cnt + 1)
