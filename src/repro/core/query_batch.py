"""Batched summary queries on the frozen serving artifact.

`Summary.neighbors` (Algorithm 4) answers one query per Python call; the
serving workload is thousands of concurrent `neighbors`/`edge_exists`
queries against an immutable summary (`PackedSummary`). This module answers
whole batches at once, in three phases:

  gather   climb all ancestor chains level-synchronously and gather every
           incident edge's pre-resolved (lo, hi, sign) interval — flat
           segment arrays, one CSR expansion (`segmented_indices`) total.
  sweep    turn intervals into per-query active DFS-position ranges. Three
           interchangeable backends:
             * ``numpy``  — one global event sweep (lexsort + cumsum); the
               per-query signed sums never interact because each query's
               events sum to zero, so a single flat cumsum serves the batch.
             * ``jax``    — jit'd fixed-shape sweep over (B, E)-padded rows
               (argsort + cumsum per row), cached on padded shapes.
             * ``pallas`` — the `kernels/interval_expand` compare-and-sum
               kernel evaluates the signed membership count at every interval
               boundary directly (count at a boundary == the sweep's running
               sum over the range it opens), trading the sort for an
               MXU/VPU-friendly O(E·P) tile reduction.
  expand   shared range-to-leaf expansion: one `segmented_indices` gather,
           drop each query's own position, sort per query. Because every
           backend feeds the same expansion with the same ranges, answers are
           bit-identical across backends (test-enforced) and identical to
           `Summary.neighbors` / decompressed rows.

`edge_exists_batch` is the one-probe special case: the signed membership
count of v's DFS position in u's chain intervals, > 0 iff the edge exists.
"""
from __future__ import annotations

import numpy as np

from repro.core.summary_ir import PackedSummary, segmented_indices

from repro.kernels.common import LruCache

BACKENDS = ("numpy", "jax", "pallas")

# bounded: padded (B, E) shapes drift with traffic and each compiled sweep
# would otherwise live for the life of the serving process (ISSUE 5)
_JAX_SWEEP_CACHE = LruCache(16)
_JAX_COUNT_CACHE = LruCache(16)


def _require_backend(backend: str):
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; use one of {BACKENDS}")


# ---------------------------------------------------------------------------
# gather phase (shared by all backends)
# ---------------------------------------------------------------------------
def _gather_chain_intervals(ps: PackedSummary, vs: np.ndarray):
    """Flat (seg, lo, hi, sign) of every edge incident to each query's
    ancestor chain. ``seg`` indexes into ``vs`` and is non-decreasing only
    after explicit sorting — chains are emitted level by level."""
    vs = np.asarray(vs, dtype=np.int64)
    seg = np.arange(vs.size, dtype=np.int64)
    node = vs
    segs, nodes = [seg], [node]
    for _ in range(ps.max_depth):
        node = ps.parent[node].astype(np.int64)
        up = node >= 0
        if not up.any():
            break
        seg, node = seg[up], node[up]
        segs.append(seg)
        nodes.append(node)
    seg_n = np.concatenate(segs)
    nodes = np.concatenate(nodes)
    lens = ps.inc_ptr[nodes + 1] - ps.inc_ptr[nodes]
    idx = segmented_indices(ps.inc_ptr[nodes], lens)
    ent_seg = np.repeat(seg_n, lens)
    return ent_seg, ps.inc_lo[idx], ps.inc_hi[idx], ps.inc_sign[idx]


def _padded_batch(ent_seg, lo, hi, sg, B: int):
    """Scatter the flat per-entry intervals into pow2-padded (Bp, E) int32
    tiles — the shared fixed-shape layout of the jax and pallas backends.
    Padded slots are (0, 0, 0): zero-sign empty intervals that match nothing
    and move no count."""
    from repro.kernels.common import pow2

    cnt = np.bincount(ent_seg, minlength=B)
    E = pow2(int(cnt.max()), floor=8)
    Bp = pow2(B, floor=8)
    order = np.argsort(ent_seg, kind="stable")
    ends = np.cumsum(cnt)
    rank = np.arange(ent_seg.size, dtype=np.int64) - np.repeat(ends - cnt, cnt)
    rows = ent_seg[order]
    out = []
    for col in (lo, hi, sg):
        m = np.zeros((Bp, E), dtype=np.int32)
        m[rows, rank] = col[order]
        out.append(m)
    return (*out, Bp, E)


# ---------------------------------------------------------------------------
# sweep phase: intervals -> active (seg, start, len) ranges
# ---------------------------------------------------------------------------
def _ranges_numpy(ent_seg, lo, hi, sg, B: int):
    """One flat event sweep over the whole batch. Each interval contributes
    (+s at lo, -s at hi); within a query the running sum over sorted events
    is the membership count of the half-open range a boundary opens. Event
    sums are zero per query, so the global cumsum needs no per-segment
    reset."""
    if ent_seg.size == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z, z
    pos = np.concatenate([lo, hi])
    val = np.concatenate([sg, -sg])
    seg2 = np.concatenate([ent_seg, ent_seg])
    order = np.lexsort((pos, seg2))
    seg2, pos, val = seg2[order], pos[order], val[order]
    cum = np.cumsum(val)
    tail = np.empty(pos.size, dtype=bool)  # last event of each (seg, pos)
    tail[-1] = True
    tail[:-1] = (seg2[1:] != seg2[:-1]) | (pos[1:] != pos[:-1])
    active = np.flatnonzero(tail & (cum > 0))
    # a query's final boundary always sweeps to zero, so active events have a
    # successor in the same segment and pos[i + 1] is this range's end
    return seg2[active], pos[active], pos[active + 1] - pos[active]


def _ranges_jax(ent_seg, lo, hi, sg, B: int):
    """Fixed-shape per-row sweep, jit-cached on the pow2-padded (B, E).
    Padded slots are (0, 0, 0) zero-weight events at position 0 — they move
    no count and a boundary is only active when its count is positive."""
    import jax
    import jax.numpy as jnp

    if ent_seg.size == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z, z
    lo_p, hi_p, sg_p, Bp, E = _padded_batch(ent_seg, lo, hi, sg, B)
    key = (Bp, E)
    fn = _JAX_SWEEP_CACHE.get(key)
    if fn is None:
        @jax.jit
        def fn(l, h, s):
            pos = jnp.concatenate([l, h], axis=1)
            val = jnp.concatenate([s, -s], axis=1)
            order = jnp.argsort(pos, axis=1)
            pos = jnp.take_along_axis(pos, order, axis=1)
            val = jnp.take_along_axis(val, order, axis=1)
            cum = jnp.cumsum(val, axis=1)
            tail = jnp.concatenate(
                [pos[:, 1:] != pos[:, :-1],
                 jnp.ones((pos.shape[0], 1), dtype=bool)], axis=1)
            nxt = jnp.concatenate([pos[:, 1:], pos[:, -1:]], axis=1)
            return pos, nxt, tail & (cum > 0)
        _JAX_SWEEP_CACHE[key] = fn
    pos, nxt, act = (np.asarray(a) for a in fn(lo_p, hi_p, sg_p))
    rseg, col = np.nonzero(act)
    start = pos[rseg, col].astype(np.int64)
    return rseg.astype(np.int64), start, nxt[rseg, col].astype(np.int64) - start


def _ranges_pallas(ent_seg, lo, hi, sg, B: int):
    """Boundary evaluation through the interval-expand kernel: probe every
    (sorted) interval boundary, keep boundaries whose signed membership
    count is positive. No cumsum — the count at a boundary IS the sweep's
    running sum there."""
    from repro.kernels.interval_expand.ops import batch_interval_counts

    if ent_seg.size == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z, z
    lo_p, hi_p, sg_p, _, _ = _padded_batch(ent_seg, lo, hi, sg, B)
    pos = np.sort(np.concatenate([lo_p, hi_p], axis=1), axis=1)
    cnt = batch_interval_counts(lo_p, hi_p, sg_p, pos, backend="pallas")
    tail = np.empty(pos.shape, dtype=bool)
    tail[:, -1] = True
    tail[:, :-1] = pos[:, 1:] != pos[:, :-1]
    rseg, col = np.nonzero(tail & (cnt > 0))
    start = pos[rseg, col].astype(np.int64)
    return (rseg.astype(np.int64), start,
            pos[rseg, col + 1].astype(np.int64) - start)


_RANGES = {"numpy": _ranges_numpy, "jax": _ranges_jax, "pallas": _ranges_pallas}


# ---------------------------------------------------------------------------
# expand phase (shared) and the public batch queries
# ---------------------------------------------------------------------------
def _expand_ranges(ps: PackedSummary, vs, rseg, rstart, rlen, B: int):
    hits = segmented_indices(rstart, rlen)
    hseg = np.repeat(rseg, rlen)
    keep = hits != ps.pos_of[vs[hseg]]  # each query drops its own position
    hits, hseg = hits[keep], hseg[keep]
    ids = ps.order[hits].astype(np.int64)
    order = np.lexsort((ids, hseg))
    hseg, ids = hseg[order], ids[order]
    indptr = np.zeros(B + 1, dtype=np.int64)
    np.cumsum(np.bincount(hseg, minlength=B), out=indptr[1:])
    return indptr, ids


def neighbors_batch(ps: PackedSummary, vs, backend: str = "numpy"):
    """Batched Algorithm 4: the neighborhood of every query leaf.

    Returns CSR ``(indptr, ids)`` — query i's neighbors are
    ``ids[indptr[i]:indptr[i+1]]``, sorted ascending, bit-identical to
    ``Summary.neighbors(vs[i])``."""
    _require_backend(backend)
    vs = np.asarray(vs, dtype=np.int64)
    ent_seg, lo, hi, sg = _gather_chain_intervals(ps, vs)
    rseg, rstart, rlen = _RANGES[backend](ent_seg, lo, hi, sg, vs.size)
    return _expand_ranges(ps, vs, rseg, rstart, rlen, vs.size)


def edge_exists_batch(ps: PackedSummary, us, vs, backend: str = "numpy"):
    """Batched membership probes: does edge (us[i], vs[i]) exist?

    The signed count of v's DFS position over the intervals incident to u's
    ancestor chain is exactly the p-minus-n count of Sect. II-B; the edge
    exists iff it is positive (and u != v)."""
    _require_backend(backend)
    us = np.asarray(us, dtype=np.int64)
    vs = np.asarray(vs, dtype=np.int64)
    B = us.size
    ent_seg, lo, hi, sg = _gather_chain_intervals(ps, us)
    pv = ps.pos_of[vs]
    if ent_seg.size == 0:
        return np.zeros(B, dtype=bool)
    if backend == "numpy":
        inside = (lo <= pv[ent_seg]) & (pv[ent_seg] < hi)
        cnt = np.zeros(B, dtype=np.int64)
        np.add.at(cnt, ent_seg[inside], sg[inside])
    else:
        from repro.kernels.interval_expand.ops import batch_interval_counts

        lo_p, hi_p, sg_p, Bp, _ = _padded_batch(ent_seg, lo, hi, sg, B)
        probes = np.full((Bp, 1), -1, dtype=np.int32)
        probes[:B, 0] = pv
        if backend == "pallas":
            cnt = batch_interval_counts(lo_p, hi_p, sg_p, probes,
                                        backend="pallas")[:B, 0]
        else:
            cnt = _jax_probe_counts(lo_p, hi_p, sg_p, probes)[:B, 0]
    return (cnt > 0) & (us != vs)


def _jax_probe_counts(lo_p, hi_p, sg_p, probes):
    import jax
    import jax.numpy as jnp

    key = lo_p.shape
    fn = _JAX_COUNT_CACHE.get(key)
    if fn is None:
        @jax.jit
        def fn(l, h, s, p):
            inside = (l <= p) & (p < h)
            return (inside * s).sum(axis=1, keepdims=True)
        _JAX_COUNT_CACHE[key] = fn
    return np.asarray(fn(lo_p, hi_p, sg_p, probes)).astype(np.int64)


def unpack_csr(indptr: np.ndarray, ids: np.ndarray) -> list:
    """CSR batch answer -> list of per-query arrays (convenience)."""
    return [ids[indptr[i]: indptr[i + 1]] for i in range(indptr.size - 1)]
