"""Pruning step (Sect. III-B4): remove supernodes that do not pay for their
h-edges, without any information loss.

  Step 1 — splice every non-leaf supernode with no incident p/n-edges
           (−1 h-edge each; −#children when it is a root).
  Step 2 — the paper's exactly-one-incident-non-loop-edge rule for roots:
           push the edge down to the children (guaranteed net reduction ≥ 1).
  Step 3 — the paper falls back to the *flat* encoding per root pair when
           cheaper. Our emission DP's per-pair cost is ≤ flat by construction
           (DESIGN.md §2.1), so the residual opportunity is in |H|: we
           generalize to a benefit-tested *root flattening* — remove a root,
           promote its children, re-attach its edges at child granularity —
           applied whenever it strictly reduces |P⁺|+|P⁻|+|H|.

All steps preserve the decompressed graph exactly (test-enforced).
"""
from __future__ import annotations

import numpy as np

from repro.core.summary import Summary


class _Work:
    def __init__(self, s: Summary):
        self.n = s.n_leaves
        self.parent = {i: int(p) for i, p in enumerate(s.parent) if p != -2}
        self.children: dict = {}
        for i, p in self.parent.items():
            if p >= 0:
                self.children.setdefault(p, []).append(i)
        # signed multiplicity per normalized pair
        self.edges: dict = {}
        for X, Y, sg in s.edges:
            k = (int(min(X, Y)), int(max(X, Y)))
            self.edges[k] = self.edges.get(k, 0) + int(sg)
            if self.edges[k] == 0:
                del self.edges[k]
        self.incident: dict = {}
        for (X, Y), c in self.edges.items():
            self.incident.setdefault(X, set()).add((X, Y))
            if X != Y:
                self.incident.setdefault(Y, set()).add((X, Y))
        self._size: dict = {}

    # ---- helpers ----------------------------------------------------------
    def size(self, x: int) -> int:
        if x in self._size:
            return self._size[x]
        r = 1 if x < self.n else sum(self.size(c) for c in self.children.get(x, []))
        self._size[x] = r
        return r

    def deg(self, x: int) -> int:
        return len(self.incident.get(x, ()))

    def _add(self, X: int, Y: int, sg: int):
        k = (min(X, Y), max(X, Y))
        c = self.edges.get(k, 0) + sg
        if c == 0:
            self.edges.pop(k, None)
            self.incident.get(k[0], set()).discard(k)
            if k[0] != k[1]:
                self.incident.get(k[1], set()).discard(k)
        else:
            self.edges[k] = c
            self.incident.setdefault(k[0], set()).add(k)
            if k[0] != k[1]:
                self.incident.setdefault(k[1], set()).add(k)

    def _remove_node(self, a: int):
        """Splice a out of the forest; children attach to a's parent."""
        p = self.parent[a]
        for c in self.children.get(a, []):
            self.parent[c] = p
            if p >= 0:
                self.children.setdefault(p, []).append(c)
        if p >= 0 and a in self.children.get(p, []):
            self.children[p].remove(a)
        self.children.pop(a, None)
        del self.parent[a]
        self._size.clear()

    # ---- step 1 -----------------------------------------------------------
    def step1(self) -> int:
        removed = 0
        queue = [x for x in list(self.parent) if x >= self.n]
        while queue:
            a = queue.pop()
            if a not in self.parent or a < self.n:
                continue
            if self.deg(a) == 0 and self.children.get(a):
                p = self.parent[a]
                kids = list(self.children[a])
                self._remove_node(a)
                removed += 1
                if p >= 0:
                    queue.append(p)
                queue.extend(k for k in kids if k >= self.n)
        return removed

    # ---- step 2 (paper Algorithm 3, lines 13-27) --------------------------
    def step2(self) -> int:
        removed = 0
        queue = [x for x, p in list(self.parent.items()) if p == -1 and x >= self.n]
        while queue:
            a = queue.pop()
            if a not in self.parent or self.parent[a] != -1 or not self.children.get(a):
                continue
            inc = list(self.incident.get(a, ()))
            nonloop = [e for e in inc if e[0] != e[1]]
            if len(inc) != 1 or len(nonloop) != 1 or abs(self.edges[nonloop[0]]) != 1:
                continue
            (X, Y) = nonloop[0]
            b = Y if X == a else X
            sg = 1 if self.edges[(X, Y)] > 0 else -1
            kids = list(self.children[a])
            self._add(X, Y, -self.edges[(X, Y)])
            for c in kids:
                self._add(c, b, sg)
            self._remove_node(a)
            removed += 1
            queue.extend(k for k in kids if k >= self.n)
        return removed

    # ---- step 3 (benefit-tested splice of any non-leaf supernode) ----------
    def _depth(self, x: int) -> int:
        d = 0
        while self.parent.get(x, -1) >= 0:
            x = self.parent[x]
            d += 1
        return d

    def step3(self) -> int:
        removed = 0
        nodes = [x for x in list(self.parent) if x >= self.n and self.children.get(x)]
        # bottom-up: splice deepest first so parents see their final child lists
        nodes.sort(key=self._depth, reverse=True)
        queue = list(nodes)
        while queue:
            a = queue.pop(0)
            if a not in self.parent or not self.children.get(a):
                continue
            kids = list(self.children[a])
            big_kids = [c for c in kids if self.size(c) > 1]
            is_root = self.parent[a] == -1
            # h-edges saved: every child edge when a is a root (children get no
            # replacement parent), else just a's own parent edge.
            delta = -len(kids) if is_root else -1
            plan: list = []
            feasible = True
            for (X, Y) in list(self.incident.get(a, ())):
                cur = self.edges[(X, Y)]
                if abs(cur) != 1:
                    feasible = False
                    break
                sg = 1 if cur > 0 else -1
                delta -= 1  # the removed edge itself
                if X == Y:  # self-loop: expand to child pairs + child loops
                    for i in range(len(kids)):
                        for j in range(i + 1, len(kids)):
                            plan.append((kids[i], kids[j], sg))
                    for c in big_kids:
                        plan.append((c, c, sg))
                else:
                    b = Y if X == a else X
                    for c in kids:
                        plan.append((c, b, sg))
            if not feasible:
                continue
            for (u, v, sg) in plan:
                k = (min(u, v), max(u, v))
                delta += -1 if self.edges.get(k, 0) == -sg else 1
            if delta <= 0 and (delta < 0 or not is_root):
                for (X, Y) in list(self.incident.get(a, ())):
                    self._add(X, Y, -self.edges[(X, Y)])
                for (u, v, sg) in plan:
                    self._add(u, v, sg)
                self._remove_node(a)
                removed += 1
        return removed

    # ---- export ------------------------------------------------------------
    def to_summary(self, total_ids: int) -> Summary:
        parent = np.full(total_ids, -2, dtype=np.int64)
        for x, p in self.parent.items():
            parent[x] = p
        rows = []
        for (X, Y), c in self.edges.items():
            sg = 1 if c > 0 else -1
            for _ in range(abs(c)):
                rows.append((X, Y, sg))
        edges = np.array(rows, dtype=np.int64) if rows else np.zeros((0, 3), dtype=np.int64)
        return Summary(n_leaves=self.n, parent=parent, edges=edges)


def prune(summary: Summary, steps=(1, 2, 3), rounds: int = 3) -> Summary:
    """Run the selected pruning substeps (repeated until fixpoint, ≤ rounds)."""
    w = _Work(summary)
    for _ in range(rounds):
        changed = 0
        if 1 in steps:
            changed += w.step1()
        if 2 in steps:
            changed += w.step2()
        if 3 in steps:
            changed += w.step3()
        if not changed:
            break
    return w.to_summary(summary.parent.shape[0])
