"""Pruning step (Sect. III-B4): remove supernodes that do not pay for their
h-edges, without any information loss.

  Step 1 — splice every non-leaf supernode with no incident p/n-edges
           (−1 h-edge each; −#children when it is a root).
  Step 2 — the paper's exactly-one-incident-non-loop-edge rule for roots:
           push the edge down to the children (guaranteed net reduction ≥ 1).
  Step 3 — the paper falls back to the *flat* encoding per root pair when
           cheaper. Our emission DP's per-pair cost is ≤ flat by construction
           (DESIGN.md §2.1), so the residual opportunity is in |H|: we
           generalize to a benefit-tested *root flattening* — remove a root,
           promote its children, re-attach its edges at child granularity —
           applied whenever it strictly reduces |P⁺|+|P⁻|+|H|.

Two interchangeable implementations (``prune(impl=...)``), equivalence
test-enforced:

  * ``_IRWork`` (default ``impl="ir"``) — flat arrays on the Summary IR
    (DESIGN.md §5). Steps 1 and 2 are vectorized mask passes over bincount
    degrees with pointer-jump splicing; step 3 precomputes every candidate's
    benefit delta in one bincount/reduceat sweep over the incidence CSR and
    walks candidates with an index cursor (no ``queue.pop(0)``), recomputing
    only candidates whose neighborhood a previous splice dirtied.
  * ``_Work`` (``impl="dict"``) — the original dict-of-set reference.

Determinism: both implementations process step-2 candidates in synchronized
passes (an edge whose two endpoints both qualify keeps the larger id) and
step-3 candidates in (depth desc, id asc) order, and both export edge rows
in canonical (lo, hi, sign) lexicographic order — two runs on the same
summary produce identical arrays, independent of dict/set iteration order.

All steps preserve the decompressed graph exactly (test-enforced).
"""
from __future__ import annotations

import numpy as np

from repro.core.summary import Summary
from repro.core.summary_ir import (SummaryIR, canon_edges, group_pairs,
                                   segmented_indices)


def _aggregate_pairs(ex, ey, ec):
    """Normalize (x, y) pairs, sum multiplicities, drop zero nets."""
    lo = np.minimum(ex, ey)
    hi = np.maximum(ex, ey)
    order, starts = group_pairs(lo, hi)
    if lo.size == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z.copy(), z.copy()
    lo, hi, c = lo[order], hi[order], ec[order]
    sums = np.add.reduceat(c, starts)
    keep = sums != 0
    return lo[starts][keep], hi[starts][keep], sums[keep]


def _pair_lookup(bex, bey, bec, qx, qy):
    """Multiplicity of each query pair in the base pair list (0 if absent).

    Both inputs are pair lists; the base is unique per pair. One shared
    lexsort aligns queries next to their base row — no combined integer key,
    so arbitrarily large ids cannot overflow (see summary_ir.group_pairs).
    """
    nq = qx.shape[0]
    if nq == 0 or bex.shape[0] == 0:
        return np.zeros(nq, dtype=np.int64)
    allx = np.concatenate([bex, qx])
    ally = np.concatenate([bey, qy])
    isq = np.zeros(allx.shape[0], dtype=np.int64)
    isq[bex.shape[0]:] = 1
    order = np.lexsort((isq, ally, allx))
    head = np.empty(allx.shape[0], dtype=bool)
    head[0] = True
    sx, sy = allx[order], ally[order]
    np.not_equal(sx[1:], sx[:-1], out=head[1:])
    head[1:] |= sy[1:] != sy[:-1]
    gid = np.cumsum(head) - 1
    vals = np.where(isq[order] == 0, np.concatenate([bec, np.zeros(nq, dtype=np.int64)])[order], 0)
    gval = np.zeros(gid[-1] + 1, dtype=np.int64)
    np.add.at(gval, gid, vals)
    out = np.empty(allx.shape[0], dtype=np.int64)
    out[order] = gval[gid]
    return out[bex.shape[0]:]


class _IRWork:
    """Array-based pruning working set over the flat Summary IR."""

    def __init__(self, s: Summary):
        self.n = s.n_leaves
        self.parent = np.asarray(s.parent, dtype=np.int64).copy()
        edges = np.asarray(s.edges, dtype=np.int64).reshape(-1, 3)
        self.ex, self.ey, self.ec = _aggregate_pairs(
            edges[:, 0], edges[:, 1], edges[:, 2])

    # ---- helpers ----------------------------------------------------------
    def _cap(self) -> int:
        return self.parent.shape[0]

    def _alive(self) -> np.ndarray:
        return self.parent > -2

    def _nkids(self) -> np.ndarray:
        alive = self._alive()
        haspar = alive & (self.parent >= 0)
        return np.bincount(self.parent[haspar], minlength=self._cap())

    def _deg(self) -> np.ndarray:
        nonloop = self.ex != self.ey
        ends = np.concatenate([self.ex, self.ey[nonloop]])
        return np.bincount(ends, minlength=self._cap())

    def _splice(self, rem: np.ndarray):
        """Remove masked nodes; their children attach to the nearest kept
        ancestor (or become roots), via vectorized pointer jumping."""
        par = self.parent
        new_par = par.copy()
        mask = (new_par >= 0) & rem[new_par]
        while mask.any():
            new_par[mask] = par[new_par[mask]]
            mask = (new_par >= 0) & rem[new_par]
        new_par[rem] = -2
        self.parent = new_par

    # ---- step 1 -----------------------------------------------------------
    def step1(self) -> int:
        """One vectorized pass: splicing an edge-free node never changes any
        other node's degree or children, so the qualifying set is closed."""
        ids = np.arange(self._cap())
        rem = (self._alive() & (ids >= self.n) & (self._deg() == 0)
               & (self._nkids() > 0))
        if not rem.any():
            return 0
        self._splice(rem)
        return int(rem.sum())

    # ---- step 2 (paper Algorithm 3, lines 13-27) --------------------------
    def _step2_candidates(self):
        """Roots with exactly one incident edge, non-loop, multiplicity ±1.
        Returns (cands, eid, other, sign) after the larger-id conflict rule."""
        cap = self._cap()
        ids = np.arange(cap)
        nonloop = self.ex != self.ey
        ends = np.concatenate([self.ex, self.ey[nonloop]])
        eids = np.concatenate([np.arange(self.ex.shape[0], dtype=np.int64),
                               np.flatnonzero(nonloop)])
        inc_total = np.bincount(ends, minlength=cap)
        loop_cnt = np.bincount(self.ex[~nonloop], minlength=cap)
        cand_mask = (self._alive() & (self.parent == -1) & (ids >= self.n)
                     & (self._nkids() > 0) & (inc_total == 1) & (loop_cnt == 0))
        cands = np.flatnonzero(cand_mask)
        if cands.size == 0:
            return cands, cands, cands, cands
        order = np.argsort(ends, kind="stable")
        pos = np.searchsorted(ends[order], cands)
        eid = eids[order][pos]
        ok = np.abs(self.ec[eid]) == 1
        cands, eid = cands[ok], eid[ok]
        cand_mask = np.zeros(cap, dtype=bool)
        cand_mask[cands] = True
        other = self.ex[eid] + self.ey[eid] - cands
        keep = ~cand_mask[other] | (cands > other)
        cands, eid, other = cands[keep], eid[keep], other[keep]
        return cands, eid, other, np.sign(self.ec[eid])

    def step2(self) -> int:
        removed = 0
        while True:
            cands, eid, other, sg = self._step2_candidates()
            if cands.size == 0:
                return removed
            # push each candidate's single edge down to its children
            nk = self._nkids()
            haspar = self._alive() & (self.parent >= 0)
            kids = np.flatnonzero(haspar)
            kids = kids[np.argsort(self.parent[kids], kind="stable")]
            kptr = np.zeros(self._cap() + 1, dtype=np.int64)
            np.cumsum(nk, out=kptr[1:])
            lens = nk[cands]
            idx = segmented_indices(kptr[cands], lens)
            new_x = kids[idx]
            new_y = np.repeat(other, lens)
            new_c = np.repeat(sg, lens)
            keep = np.ones(self.ex.shape[0], dtype=bool)
            keep[eid] = False
            self.ex, self.ey, self.ec = _aggregate_pairs(
                np.concatenate([self.ex[keep], new_x]),
                np.concatenate([self.ey[keep], new_y]),
                np.concatenate([self.ec[keep], new_c]),
            )
            rem = np.zeros(self._cap(), dtype=bool)
            rem[cands] = True
            self._splice(rem)  # candidates are roots: children become roots
            removed += cands.size

    # ---- step 3 (benefit-tested splice of any non-leaf supernode) ----------
    def _step3_bulk(self, ir, cands, nk, sizes, bex, bey, bec, delta):
        """Bulk feasibility/plan/delta pass for one candidate subset.

        Emits the subset's plan rows (plo, phi, ps, pc) and accumulates each
        candidate's benefit delta into ``delta`` in place. Per-candidate
        outputs never interact, which is what lets `step3` run this per
        partition bucket with bit-identical results."""
        z = np.zeros(0, dtype=np.int64)
        if cands.size == 0:
            return z, z.copy(), z.copy(), z.copy()
        eids, seg = ir.incident_eids(cands)  # per-candidate incident edges
        a_of = cands[seg]
        loop_m = bex[eids] == bey[eids]
        # non-loop incident edges: plan (kid, b, sg) per kid of a
        nl = ~loop_m
        a_nl, e_nl = a_of[nl], eids[nl]
        b_nl = bex[e_nl] + bey[e_nl] - a_nl
        reps = nk[a_nl]
        kid_nl = ir.child_ids[segmented_indices(ir.child_ptr[a_nl], reps)]
        pu1 = kid_nl
        pv1 = np.repeat(b_nl, reps)
        ps1 = np.repeat(np.sign(bec[e_nl]), reps)
        pc1 = np.repeat(a_nl, reps)
        # self-loop incident edges: kid-pair expansion + kid self-loops
        a_lp = a_of[loop_m]
        e_lp = eids[loop_m]
        pu2 = [np.zeros(0, dtype=np.int64)]
        pv2 = [np.zeros(0, dtype=np.int64)]
        ps2 = [np.zeros(0, dtype=np.int64)]
        pc2 = [np.zeros(0, dtype=np.int64)]
        if a_lp.size:
            sg_lp = np.sign(bec[e_lp])
            for k in np.unique(nk[a_lp]):
                sel = nk[a_lp] == k
                aa, ss = a_lp[sel], sg_lp[sel]
                kid_rows = ir.child_ids[
                    ir.child_ptr[aa][:, None] + np.arange(int(k))[None, :]]
                iu, iv = np.triu_indices(int(k), k=1)
                pu2.append(kid_rows[:, iu].ravel())
                pv2.append(kid_rows[:, iv].ravel())
                ps2.append(np.repeat(ss, iu.size))
                pc2.append(np.repeat(aa, iu.size))
                big = sizes[kid_rows] > 1  # child self-loops for non-singletons
                pu2.append(kid_rows[big])
                pv2.append(kid_rows[big])
                ps2.append(np.repeat(ss, int(k))[big.ravel()])
                pc2.append(np.repeat(aa, int(k))[big.ravel()])
        pu = np.concatenate([pu1] + pu2)
        pv = np.concatenate([pv1] + pv2)
        ps = np.concatenate([ps1] + ps2)
        pc = np.concatenate([pc1] + pc2)
        plo, phi = np.minimum(pu, pv), np.maximum(pu, pv)
        cur = _pair_lookup(bex, bey, bec, plo, phi)
        contrib = np.where(cur == -ps, -1, 1)
        np.add.at(delta, pc, contrib)
        return plo, phi, ps, pc

    def step3(self, partition_map=None) -> int:
        cap = self._cap()
        ir = SummaryIR(self.parent, self.n)
        nk = ir.n_children()
        ids = np.arange(cap)
        cand_mask = self._alive() & (ids >= self.n) & (nk > 0)
        cands = np.flatnonzero(cand_mask)
        if cands.size == 0:
            return 0
        # deterministic bottom-up order: deepest first, then ascending id
        cands = cands[np.lexsort((cands, -ir.depth[cands]))]
        sizes = ir.size(ids)
        bex, bey, bec = self.ex, self.ey, self.ec
        ir.build_incidence(np.stack([bex, bey, bec], axis=1))

        # -- bulk pass: feasibility, plans, deltas against the entry state --
        # Per-candidate outputs are independent, so the pass runs per
        # partition bucket when a partition map is given (DESIGN.md §8):
        # temporaries shrink to the bucket's plan size and the result is
        # bit-identical to the monolithic pass.
        bad = np.abs(bec) != 1
        bad_ends = np.concatenate([bex[bad], bey[bad & (bex != bey)]])
        infeasible_cnt = np.bincount(bad_ends, minlength=cap)
        deg_all = self._deg()
        is_root0 = self.parent == -1
        delta = np.where(is_root0, -nk, -1).astype(np.int64)
        delta = delta - deg_all

        if partition_map is None:
            buckets = [cands]
        else:
            part_of_cand = np.asarray(partition_map, dtype=np.int64)[
                ir.order[ir.first[cands]]]
            buckets = [cands[part_of_cand == p]
                       for p in np.unique(part_of_cand)]
        plo_b, phi_b, ps_b, pc_b = [], [], [], []
        for csub in buckets:
            plo_c, phi_c, ps_c, pc_c = self._step3_bulk(
                ir, csub, nk, sizes, bex, bey, bec, delta)
            plo_b.append(plo_c)
            phi_b.append(phi_c)
            ps_b.append(ps_c)
            pc_b.append(pc_c)
        plo = np.concatenate(plo_b)
        phi = np.concatenate(phi_b)
        ps = np.concatenate(ps_b)
        pc = np.concatenate(pc_b)
        # plan rows CSR by candidate (pc is emitted in ascending-candidate
        # runs per construction branch; re-sort to be safe)
        p_order = np.argsort(pc, kind="stable")
        plo, phi, ps, pc = plo[p_order], phi[p_order], ps[p_order], pc[p_order]
        p_counts = np.bincount(pc, minlength=cap)
        p_ptr = np.zeros(cap + 1, dtype=np.int64)
        np.cumsum(p_counts, out=p_ptr[1:])

        # -- sequential sweep with staleness tracking ------------------------
        overlay: dict = {}      # pair -> absolute current multiplicity
        extra_inc: dict = {}    # node -> overlay pairs not in the base list
        kids_mut: dict = {}     # node -> current child list (if changed)
        dirty = np.zeros(cap, dtype=bool)
        parent = self.parent
        b_order = np.argsort(bex, kind="stable")
        sbex, sbey = bex[b_order], bey[b_order]

        def base_mult(x, y):
            lo = np.searchsorted(sbex, x, side="left")
            hi = np.searchsorted(sbex, x, side="right")
            j = lo + np.searchsorted(sbey[lo:hi], y)
            if j < hi and sbey[j] == y:
                return int(bec[b_order[j]])
            return 0

        def mult(x, y):
            key = (int(min(x, y)), int(max(x, y)))
            if key in overlay:
                return overlay[key]
            return base_mult(*key)

        def kids_of(a):
            got = kids_mut.get(a)
            if got is not None:
                return got
            return ir.children_of(a).tolist()

        def incident_pairs(a):
            out = []
            ee, _ = ir.incident_eids(np.array([a], dtype=np.int64))
            for e in ee:
                key = (int(bex[e]), int(bey[e]))
                c = overlay.get(key)
                c = int(bec[e]) if c is None else c
                if c != 0:
                    out.append((key[0], key[1], c))
            for key in extra_inc.get(a, ()):
                c = overlay.get(key, 0)
                if c != 0:
                    out.append((key[0], key[1], c))
            return out

        def set_mult(x, y, value):
            key = (int(min(x, y)), int(max(x, y)))
            if key not in overlay and base_mult(*key) == 0:
                extra_inc.setdefault(key[0], set()).add(key)
                if key[0] != key[1]:
                    extra_inc.setdefault(key[1], set()).add(key)
            overlay[key] = value

        def eval_one(a):
            """(accept, removals, plan) from the *current* state — the same
            benefit test as the bulk pass, for dirtied candidates."""
            kids = kids_of(a)
            inc = incident_pairs(a)
            is_root = parent[a] == -1
            d = -len(kids) if is_root else -1
            plan = []
            for (x, y, c) in inc:
                if abs(c) != 1:
                    return False, None, None
                sg = 1 if c > 0 else -1
                d -= 1
                if x == y:
                    for i in range(len(kids)):
                        for j in range(i + 1, len(kids)):
                            plan.append((kids[i], kids[j], sg))
                    for kk in kids:
                        if sizes[kk] > 1:
                            plan.append((kk, kk, sg))
                else:
                    b = y if x == a else x
                    for kk in kids:
                        plan.append((kk, b, sg))
            for (u, v, sg) in plan:
                d += -1 if mult(u, v) == -sg else 1
            accept = d <= 0 and (d < 0 or not is_root)
            return accept, inc, plan

        removed = 0
        for a in cands:
            a = int(a)
            if dirty[a]:
                accept, inc, plan = eval_one(a)
                if not accept:
                    continue
            else:
                if infeasible_cnt[a] or not (
                    delta[a] <= 0 and (delta[a] < 0 or parent[a] != -1)
                ):
                    continue
                inc = incident_pairs(a)
                s, e = p_ptr[a], p_ptr[a + 1]
                plan = list(zip(plo[s:e].tolist(), phi[s:e].tolist(), ps[s:e].tolist()))
            # apply: drop a's edges, add the plan at child granularity
            touched = set()
            for (x, y, _c) in inc:
                set_mult(x, y, 0)
                touched.add(x)
                touched.add(y)
            for (u, v, sg) in plan:
                set_mult(u, v, mult(u, v) + sg)
                touched.add(u)
                touched.add(v)
            kids = kids_of(a)
            p = int(parent[a])
            for kk in kids:
                parent[kk] = p
            if p >= 0:
                pk = kids_of(p)
                pk = [k for k in pk if k != a] + list(kids)
                kids_mut[p] = pk
                dirty[p] = True
            parent[a] = -2
            for w in sorted(touched):
                dirty[w] = True
                if parent[w] >= 0:
                    dirty[parent[w]] = True
            for kk in kids:
                dirty[kk] = True
            removed += 1

        if overlay:
            ov = sorted(overlay.items())
            ovx = np.array([k[0] for k, _ in ov], dtype=np.int64)
            ovy = np.array([k[1] for k, _ in ov], dtype=np.int64)
            ovc = np.array([v for _, v in ov], dtype=np.int64)
            # overlay values are absolute: drop overlaid base rows, then add
            overlaid = _pair_lookup(ovx, ovy, np.ones_like(ovc), bex, bey) > 0
            nz = ovc != 0
            self.ex, self.ey, self.ec = _aggregate_pairs(
                np.concatenate([bex[~overlaid], ovx[nz]]),
                np.concatenate([bey[~overlaid], ovy[nz]]),
                np.concatenate([bec[~overlaid], ovc[nz]]),
            )
        return removed

    # ---- export ------------------------------------------------------------
    def to_summary(self) -> Summary:
        reps = np.abs(self.ec)
        rows = np.stack([
            np.repeat(self.ex, reps),
            np.repeat(self.ey, reps),
            np.repeat(np.sign(self.ec), reps),
        ], axis=1)
        return Summary(n_leaves=self.n, parent=self.parent,
                       edges=canon_edges(rows))


class _Work:
    """Dict-of-set reference implementation (kept for equivalence tests)."""

    def __init__(self, s: Summary):
        self.n = s.n_leaves
        self.parent = {i: int(p) for i, p in enumerate(s.parent) if p != -2}
        self.children: dict = {}
        for i, p in self.parent.items():
            if p >= 0:
                self.children.setdefault(p, []).append(i)
        # signed multiplicity per normalized pair
        self.edges: dict = {}
        for X, Y, sg in s.edges:
            k = (int(min(X, Y)), int(max(X, Y)))
            self.edges[k] = self.edges.get(k, 0) + int(sg)
            if self.edges[k] == 0:
                del self.edges[k]
        self.incident: dict = {}
        for (X, Y), c in self.edges.items():
            self.incident.setdefault(X, set()).add((X, Y))
            if X != Y:
                self.incident.setdefault(Y, set()).add((X, Y))
        self._size: dict = {}

    # ---- helpers ----------------------------------------------------------
    def size(self, x: int) -> int:
        if x in self._size:
            return self._size[x]
        r = 1 if x < self.n else sum(self.size(c) for c in self.children.get(x, []))
        self._size[x] = r
        return r

    def deg(self, x: int) -> int:
        return len(self.incident.get(x, ()))

    def _add(self, X: int, Y: int, sg: int):
        k = (min(X, Y), max(X, Y))
        c = self.edges.get(k, 0) + sg
        if c == 0:
            self.edges.pop(k, None)
            self.incident.get(k[0], set()).discard(k)
            if k[0] != k[1]:
                self.incident.get(k[1], set()).discard(k)
        else:
            self.edges[k] = c
            self.incident.setdefault(k[0], set()).add(k)
            if k[0] != k[1]:
                self.incident.setdefault(k[1], set()).add(k)

    def _remove_node(self, a: int):
        """Splice a out of the forest; children attach to a's parent."""
        p = self.parent[a]
        for c in self.children.get(a, []):
            self.parent[c] = p
            if p >= 0:
                self.children.setdefault(p, []).append(c)
        if p >= 0 and a in self.children.get(p, []):
            self.children[p].remove(a)
        self.children.pop(a, None)
        del self.parent[a]
        self._size.clear()

    # ---- step 1 -----------------------------------------------------------
    def step1(self) -> int:
        removed = 0
        queue = [x for x in list(self.parent) if x >= self.n]
        while queue:
            a = queue.pop()
            if a not in self.parent or a < self.n:
                continue
            if self.deg(a) == 0 and self.children.get(a):
                p = self.parent[a]
                kids = list(self.children[a])
                self._remove_node(a)
                removed += 1
                if p >= 0:
                    queue.append(p)
                queue.extend(k for k in kids if k >= self.n)
        return removed

    # ---- step 2 (paper Algorithm 3, lines 13-27) --------------------------
    def step2(self) -> int:
        """Pass-synchronous: each pass snapshots the qualifying roots, drops
        the smaller endpoint when one edge connects two of them, then applies
        all push-downs — matching `_IRWork.step2` bit for bit."""
        removed = 0
        while True:
            quals = {}
            for a, p in self.parent.items():
                if p != -1 or a < self.n or not self.children.get(a):
                    continue
                inc = list(self.incident.get(a, ()))
                nonloop = [e for e in inc if e[0] != e[1]]
                if len(inc) != 1 or len(nonloop) != 1 or abs(self.edges[nonloop[0]]) != 1:
                    continue
                (X, Y) = nonloop[0]
                quals[a] = (X, Y, Y if X == a else X)
            if not quals:
                return removed
            batch = [(a, X, Y, b) for a, (X, Y, b) in quals.items()
                     if b not in quals or a > b]
            for a, X, Y, b in batch:
                sg = 1 if self.edges[(X, Y)] > 0 else -1
                kids = list(self.children[a])
                self._add(X, Y, -self.edges[(X, Y)])
                for c in kids:
                    self._add(c, b, sg)
                self._remove_node(a)
                removed += 1

    # ---- step 3 (benefit-tested splice of any non-leaf supernode) ----------
    def _depth(self, x: int) -> int:
        d = 0
        while self.parent.get(x, -1) >= 0:
            x = self.parent[x]
            d += 1
        return d

    def step3(self) -> int:
        removed = 0
        nodes = [x for x in sorted(self.parent) if x >= self.n and self.children.get(x)]
        # bottom-up: splice deepest first so parents see their final child
        # lists; ties broken by ascending id (stable sort over sorted ids)
        nodes.sort(key=self._depth, reverse=True)
        i = 0
        while i < len(nodes):
            a = nodes[i]
            i += 1
            if a not in self.parent or not self.children.get(a):
                continue
            kids = list(self.children[a])
            big_kids = [c for c in kids if self.size(c) > 1]
            is_root = self.parent[a] == -1
            # h-edges saved: every child edge when a is a root (children get no
            # replacement parent), else just a's own parent edge.
            delta = -len(kids) if is_root else -1
            plan: list = []
            feasible = True
            for (X, Y) in list(self.incident.get(a, ())):
                cur = self.edges[(X, Y)]
                if abs(cur) != 1:
                    feasible = False
                    break
                sg = 1 if cur > 0 else -1
                delta -= 1  # the removed edge itself
                if X == Y:  # self-loop: expand to child pairs + child loops
                    for ii in range(len(kids)):
                        for jj in range(ii + 1, len(kids)):
                            plan.append((kids[ii], kids[jj], sg))
                    for c in big_kids:
                        plan.append((c, c, sg))
                else:
                    b = Y if X == a else X
                    for c in kids:
                        plan.append((c, b, sg))
            if not feasible:
                continue
            for (u, v, sg) in plan:
                k = (min(u, v), max(u, v))
                delta += -1 if self.edges.get(k, 0) == -sg else 1
            if delta <= 0 and (delta < 0 or not is_root):
                for (X, Y) in list(self.incident.get(a, ())):
                    self._add(X, Y, -self.edges[(X, Y)])
                for (u, v, sg) in plan:
                    self._add(u, v, sg)
                self._remove_node(a)
                removed += 1
        return removed

    # ---- export ------------------------------------------------------------
    def to_summary(self, total_ids: int) -> Summary:
        parent = np.full(total_ids, -2, dtype=np.int64)
        for x, p in self.parent.items():
            parent[x] = p
        rows = []
        for (X, Y), c in self.edges.items():
            sg = 1 if c > 0 else -1
            for _ in range(abs(c)):
                rows.append((X, Y, sg))
        edges = (np.array(rows, dtype=np.int64) if rows
                 else np.zeros((0, 3), dtype=np.int64))
        return Summary(n_leaves=self.n, parent=parent, edges=canon_edges(edges))


def prune(summary: Summary, steps=(1, 2, 3), rounds: int = 3,
          impl: str = "ir", partition_map=None) -> Summary:
    """Run the selected pruning substeps (repeated until fixpoint, ≤ rounds).

    ``impl="ir"`` (default) runs the vectorized array implementation;
    ``impl="dict"`` the dict-of-set reference. Both produce bit-identical
    summaries (test-enforced). ``partition_map`` (node → partition,
    DESIGN.md §8) makes the step-3 bulk pass run per partition bucket —
    bounded temporaries, bit-identical output; the dict reference ignores
    it."""
    if impl not in ("ir", "dict"):
        raise ValueError(f"unknown prune impl {impl!r}; use 'ir' or 'dict'")
    w = _IRWork(summary) if impl == "ir" else _Work(summary)
    for _ in range(rounds):
        changed = 0
        if 1 in steps:
            changed += w.step1()
        if 2 in steps:
            changed += w.step2()
        if 3 in steps:
            if impl == "ir":
                changed += w.step3(partition_map=partition_map)
            else:
                changed += w.step3()
        if not changed:
            break
    if impl == "ir":
        return w.to_summary()
    return w.to_summary(summary.parent.shape[0])
