"""Batched, level-synchronous emission of the pairwise encoding DP.

Same option space and tie-breaking as the recursive reference
(`core/encode_dp.py`), evaluated over ALL root pairs at once on the flat
Summary IR instead of one memoized recursion per pair (DESIGN.md §5).

The key reduction: a pair state — cross ``(x, y)`` over disjoint supernodes
or self ``(x, x)`` — only needs the recursion when it is *mixed*
(``0 < cnt < poss``). Empty and full states have closed forms that already
fold in the reference's descend-on-tie rule:

  empty, parity 1 → one n-edge   full, parity 0 → one p-edge
  placed at (x, y) for cross states; for self states at the leaf pair when x
  has exactly two leaf children (the reference descends through the tied
  single child cross pair), else at the (x, x) loop. Parities 0/empty and
  1/full cost nothing.

Leaf–leaf and single-leaf states are never mixed, so the mixed frontier
descends one tree level per step and the whole DP is three array passes:

  1. expansion — every mixed state materializes its child-state slots
     (3 for self, ≤4 for cross); each active subedge finds its child slot
     with one interval comparison against the IR's ``first`` bounds, and the
     per-state membership counts come from one histogram dispatch
     (`kernels/seghist`, Pallas on ``backend="batched"``).
  2. bottom-up — ``D0/D1`` are `reduceat` segment sums over each state's
     contiguous child slots; ``E0 = min(D0, 1+D1)``, ``E1 = min(D1, 1+D0)``.
  3. top-down — each state holds one parity; a mixed state descends iff
     ``D(par) <= 1 + D(1-par)`` (the reference's tie rule), else places the
     signed edge and flips the children's parity.

Only strictly binary forests take this path (merge forests always are);
`encode_forest` raises ``ValueError`` otherwise and the caller falls back to
the recursive reference.
"""
from __future__ import annotations

import numpy as np

from repro.core.summary_ir import SummaryIR, group_pairs
from repro.kernels.seghist.ops import membership_counts


def forest_is_binary(ir: SummaryIR) -> bool:
    """True iff every internal node has exactly two children — the shape the
    batched emitter handles (merge forests always satisfy it)."""
    nk = ir.n_children()
    return bool(np.all(nk[nk > 0] == 2))


def _kid_arrays(ir: SummaryIR):
    """(kid0, kid1) per node; -1 for leaves. Raises on non-binary nodes."""
    if not forest_is_binary(ir):
        raise ValueError("batched emitter requires a strictly binary forest")
    nk = ir.n_children()
    internal = nk > 0
    kid0 = np.full(ir.n_ids, -1, dtype=np.int64)
    kid1 = np.full(ir.n_ids, -1, dtype=np.int64)
    kid0[internal] = ir.child_ids[ir.child_ptr[:-1][internal]]
    kid1[internal] = ir.child_ids[ir.child_ptr[:-1][internal] + 1]
    return kid0, kid1


def _state_poss(ir: SummaryIR, sx: np.ndarray, sy: np.ndarray) -> np.ndarray:
    size_x, size_y = ir.size(sx), ir.size(sy)
    self_mask = sx == sy
    poss = size_x * size_y
    poss[self_mask] = size_x[self_mask] * (size_x[self_mask] - 1) // 2
    return poss


def _dedup_states(sx_e, sy_e):
    """Edge-level (sx, sy) pairs -> unique state table + per-edge index."""
    order, starts = group_pairs(sx_e, sy_e)
    nstates = starts.shape[0]
    st_sorted = np.zeros(sx_e.shape[0], dtype=np.int64)
    st_sorted[starts] = 1
    st_sorted = np.cumsum(st_sorted) - 1
    st = np.empty(sx_e.shape[0], dtype=np.int64)
    st[order] = st_sorted
    sx = sx_e[order][starts]
    sy = sy_e[order][starts]
    return sx, sy, st


def encode_forest(ir: SummaryIR, u: np.ndarray, v: np.ndarray,
                  backend: str = "numpy"):
    """Minimal hierarchical encoding of subedges (u, v) over the forest.

    Returns ``(cost, edges)`` with edges a (k, 3) int64 array (gid, gid,
    sign), rows in canonical (lo, hi, sign) lexicographic order.
    """
    empty = np.zeros((0, 3), dtype=np.int64)
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    if u.size == 0:
        return 0, empty
    kid0, kid1 = _kid_arrays(ir)
    first, last, n_leaves = ir.first, ir.last, ir.n_leaves

    # -- level 0: root-pair states ----------------------------------------
    p0, p1 = ir.pos_of[u], ir.pos_of[v]
    root_first = first[ir.roots]
    ru = ir.roots[np.searchsorted(root_first, p0, side="right") - 1]
    rv = ir.roots[np.searchsorted(root_first, p1, side="right") - 1]
    sx_e = np.minimum(ru, rv)
    sy_e = np.maximum(ru, rv)
    # p0 rides the sx side, p1 the sy side; self states keep p0 < p1
    swap = np.where(ru == rv, p0 > p1, ru > rv)
    p0, p1 = np.where(swap, p1, p0), np.where(swap, p0, p1)
    sx, sy, st = _dedup_states(sx_e, sy_e)

    levels = []
    while True:
        cnt = membership_counts(st, sx.shape[0], backend=backend)
        poss = _state_poss(ir, sx, sy)
        mixed = (cnt > 0) & (cnt < poss)
        lvl = {"sx": sx, "sy": sy, "cnt": cnt, "poss": poss, "mixed": mixed}
        levels.append(lvl)
        m_idx = np.flatnonzero(mixed)
        if m_idx.size == 0:
            break
        mrank = np.full(sx.shape[0], -1, dtype=np.int64)
        mrank[m_idx] = np.arange(m_idx.size)
        mx, my = sx[m_idx], sy[m_idx]
        is_self = mx == my
        x_int = kid0[mx] >= 0
        y_int = kid0[my] >= 0
        nslots = np.where(is_self, 3,
                          np.where(x_int, 2, 1) * np.where(y_int, 2, 1))
        slot_ptr = np.zeros(m_idx.size + 1, dtype=np.int64)
        np.cumsum(nslots, out=slot_ptr[1:])
        lvl["slot_ptr"] = slot_ptr
        total = int(slot_ptr[-1])
        nsx = np.empty(total, dtype=np.int64)
        nsy = np.empty(total, dtype=np.int64)
        base = slot_ptr[:-1]
        sm = is_self
        if sm.any():
            b = base[sm]
            k0, k1 = kid0[mx[sm]], kid1[mx[sm]]
            nsx[b], nsy[b] = k0, k0
            nsx[b + 1], nsy[b + 1] = k1, k1
            nsx[b + 2], nsy[b + 2] = k0, k1  # k0 < k1 by CSR construction
        cm = ~is_self
        bb = cm & x_int & y_int
        if bb.any():
            b = base[bb]
            x0, x1 = kid0[mx[bb]], kid1[mx[bb]]
            y0, y1 = kid0[my[bb]], kid1[my[bb]]
            for s_i, (cx, cy) in enumerate(((x0, y0), (x0, y1), (x1, y0), (x1, y1))):
                nsx[b + s_i] = np.minimum(cx, cy)
                nsy[b + s_i] = np.maximum(cx, cy)
        xl = cm & x_int & ~y_int
        if xl.any():
            b = base[xl]
            x0, x1, yy = kid0[mx[xl]], kid1[mx[xl]], my[xl]
            for s_i, cx in enumerate((x0, x1)):
                nsx[b + s_i] = np.minimum(cx, yy)
                nsy[b + s_i] = np.maximum(cx, yy)
        yl = cm & ~x_int & y_int
        if yl.any():
            b = base[yl]
            y0, y1, xx = kid0[my[yl]], kid1[my[yl]], mx[yl]
            nsx[b] = np.minimum(y0, xx)
            nsy[b] = np.maximum(y0, xx)
            nsx[b + 1] = np.minimum(y1, xx)
            nsy[b + 1] = np.maximum(y1, xx)

        # -- descend the active edges one level --------------------------
        act = mixed[st]
        if not act.any():
            # mixed states with no surviving edges cannot exist (mixed ⇒ cnt>0)
            raise AssertionError("mixed state without active edges")
        st_a, p0_a, p1_a = st[act], p0[act], p1[act]
        x_a, y_a = sx[st_a], sy[st_a]
        self_a = x_a == y_a
        # child on each side: kid1 iff the position is right of kid1.first
        def _descend(node, pos):
            internal = kid0[node] >= 0
            k1 = np.where(internal, kid1[node], 0)
            take1 = internal & (pos >= first[k1])
            return np.where(internal, np.where(take1, k1, kid0[node]), node)

        c0 = _descend(x_a, p0_a)
        c1 = _descend(y_a, p1_a)
        slot = np.empty(st_a.shape[0], dtype=np.int64)
        if self_a.any():
            same = c0[self_a] == c1[self_a]
            hi = c0[self_a] == kid1[x_a[self_a]]
            slot[self_a] = np.where(same, np.where(hi, 1, 0), 2)
        ca = ~self_a
        if ca.any():
            xi = x_a[ca]
            yi = y_a[ca]
            i = (kid0[xi] >= 0) & (c0[ca] == kid1[xi])
            j = (kid0[yi] >= 0) & (c1[ca] == kid1[yi])
            both = (kid0[xi] >= 0) & (kid0[yi] >= 0)
            slot[ca] = np.where(both, 2 * i + j, np.where(kid0[xi] >= 0, i, j))
        nst = slot_ptr[mrank[st_a]] + slot
        # keep p0 on the (smaller-id) sx side after normalization
        swap = c0 > c1
        p0, p1 = np.where(swap, p1_a, p0_a), np.where(swap, p0_a, p1_a)
        sx, sy, st = nsx, nsy, nst

    # -- bottom-up D/E ----------------------------------------------------
    for li in range(len(levels) - 1, -1, -1):
        lvl = levels[li]
        cnt, poss, mixed = lvl["cnt"], lvl["poss"], lvl["mixed"]
        e0 = ((cnt > 0) & ~mixed).astype(np.int64)
        e1 = ((cnt == 0) & (poss > 0)).astype(np.int64)
        if mixed.any():
            nxt = levels[li + 1]
            sp = lvl["slot_ptr"]
            D0 = np.add.reduceat(nxt["e0"], sp[:-1])
            D1 = np.add.reduceat(nxt["e1"], sp[:-1])
            e0[mixed] = np.minimum(D0, 1 + D1)
            e1[mixed] = np.minimum(D1, 1 + D0)
            lvl["D0"], lvl["D1"] = D0, D1
        lvl["e0"], lvl["e1"] = e0, e1
    cost = int(levels[0]["e0"].sum())

    # -- top-down parity + emission ---------------------------------------
    out_x, out_y, out_s = [], [], []
    par = np.zeros(levels[0]["sx"].shape[0], dtype=np.int64)
    for li, lvl in enumerate(levels):
        sx, sy, cnt, poss, mixed = (
            lvl["sx"], lvl["sy"], lvl["cnt"], lvl["poss"], lvl["mixed"])
        full = ~mixed & (cnt > 0)
        emp = ~mixed & (cnt == 0) & (poss > 0)
        hit = (full & (par == 0)) | (emp & (par == 1))
        if hit.any():
            hx, hy = sx[hit], sy[hit]
            sign = np.where(full[hit], 1, -1).astype(np.int64)
            # self states over exactly two leaves place at the leaf pair
            self_h = hx == hy
            two_leaves = self_h & (kid0[hx] >= 0) & (kid0[hx] < n_leaves) \
                & (kid1[hx] < n_leaves)
            ex = np.where(two_leaves, kid0[hx], hx)
            ey = np.where(two_leaves, kid1[hx], hy)
            out_x.append(ex)
            out_y.append(ey)
            out_s.append(sign)
        if not mixed.any():
            break
        D0, D1 = lvl["D0"], lvl["D1"]
        mpar = par[mixed]
        desc = np.where(mpar == 0, D0 <= 1 + D1, D1 <= 1 + D0)
        place = ~desc
        if place.any():
            out_x.append(sx[mixed][place])
            out_y.append(sy[mixed][place])
            out_s.append(np.where(mpar[place] == 0, 1, -1).astype(np.int64))
        childpar = np.where(desc, mpar, 1 - mpar)
        sp = lvl["slot_ptr"]
        par = np.repeat(childpar, np.diff(sp))

    if not out_x:
        return cost, empty
    ex = np.concatenate(out_x)
    ey = np.concatenate(out_y)
    es = np.concatenate(out_s)
    lo, hi = np.minimum(ex, ey), np.maximum(ex, ey)
    edges = np.stack([lo, hi, es], axis=1)
    order = np.lexsort((edges[:, 2], edges[:, 1], edges[:, 0]))
    return cost, edges[order]
