"""Device-resident merge rounds: persistent bitmap+count arenas (§9).

`ResidentBitmapArena` is the ``backend="resident"`` engine's device half.
One arena wraps ONE batched workspace chunk (`merging.BatchedGroupWorkspace`,
a (B, G, W) packed-bitmap batch). Since ISSUE 7 the arena holds the WHOLE
merge-round state — bitmaps AND the exact integer count tensors (``CNT``,
column sizes, member columns, sizes, self-counts, descendant counts,
heights, row costs, the dirty queue) — so a full sweep round is two
on-device ops:

1. **fused proposal round** (`kernels/bitset_fold.round_fn`): the device
   derives the dirty-row list from its own ``dirty`` mirror, ranks
   candidates by the quantized-Jaccard key, evaluates the EXACT integer
   Saving of each (32-bit-limb rational compare) and applies the
   quantized-θ̂ acceptance; only (K, 2) int8 ``[accept, partner]`` rows
   come back — no dirty-row upload, no score download;
2. **count-carrying fold** (`kernels/bitset_fold.fold_counts_fn`): the
   round's accepted pairs fold bitmaps, counts, stats and row costs in
   place (donated buffers), mirroring the host `apply_merges` phases
   bit-for-bit.

Only the conflict-free matching stays on host (it needs the group-seed
hashes), so per round the boundary carries the accepted-pair instruction
slab up and the per-dirty-row verdict down. The legacy v1 protocol
(`topj_rows` ranking + bitmap-only `fold`) remains for tests and tools.

Since ISSUE 9 the per-iteration workspace upload is gone too:
`ResidentAdjacencyBank` carries every root's coalesced adjacency row on
device ACROSS iterations (append-only ``gid``/``cnt`` streams advanced
straight from the applied `MergePlan` batches), and
`ResidentBitmapArena.from_bank` EXTRACTS each chunk's (B, G, W) bitmaps and
count tensors on device — the host workspaces become shape-only shells and
the device bank is authoritative within a run. The host materializes bank
rows only for verification (`host_rows`, the `sync_rows`-style contract).

`sync_rows` keeps the verification contract: tests pull selected rows back
and assert the device fold is bit-identical to the host fold.

Every upload/download reports to `core.transfer.GLOBAL` under a lifecycle
phase (``init``/``upload``/``rank``/``fold``/``carry``/``candgen``/
``bank``/``extract``/``sync``), and each proposal round-trip ticks the
round counter — `benchmarks/scalability.py --resident` gates the
bytes-per-iteration reduction on these numbers.
"""
from __future__ import annotations

import logging

import numpy as np

from repro import faults
from repro.core.transfer import GLOBAL as TRANSFER

log = logging.getLogger("repro.engine")


def _jax():
    try:
        import jax
    except ImportError as e:  # pragma: no cover - jax is a hard dep of this path
        raise RuntimeError(
            "backend='resident' needs jax; install jax or use "
            "backend='numpy'") from e
    return jax


def _run_round_op(arena, site: str, build, args):
    """Run one compiled round op; a failed Pallas dispatch retries ONCE on
    the jnp `ref.py` twin (§11 degradation policy — bit-identical by the
    kernel twin contract), dropping ``use_kernel`` for the arena's life.
    The retry is safe for injected faults because the dispatch wrappers in
    `kernels/*/ops.py` raise BEFORE the compiled call touches its donated
    buffers; a genuine mid-execution failure may have consumed them, in
    which case the retry surfaces that error instead of masking it."""
    fn = build(arena.use_kernel)
    try:
        return fn(*args)
    except Exception as e:
        if not arena.use_kernel:
            raise
        faults.DEGRADATIONS.record(site, e)
        log.warning("kernel dispatch %s failed; retrying on the jnp twin: "
                    "%r", site, e)
        arena.use_kernel = False
        return build(False)(*args)


class ResidentBitmapArena:
    """Persistent device copy of one workspace chunk's packed bitmaps."""

    def __init__(self, bits_u32: np.ndarray, alive: np.ndarray, *,
                 top_j: int = 16, mesh=None, use_kernel=None,
                 interpret=None, counter=TRANSFER):
        jax = _jax()
        from repro.kernels.common import (default_interpret,
                                          default_use_kernel, pow2)

        B, G, W = bits_u32.shape
        self.counter = counter
        self.G = int(G)
        self.J = max(1, min(int(top_j), G - 1))
        self.use_kernel = (default_use_kernel() if use_kernel is None
                           else bool(use_kernel))
        self.interpret = (default_interpret() if interpret is None
                          else bool(interpret))
        if mesh is not None:
            from repro.launch.mesh import dp_axes_of
            axes = dp_axes_of(mesh)
            n_shards = int(np.prod([mesh.shape[a] for a in axes]))
            if n_shards <= 1:  # a 1-device mesh shards nothing: skip the
                mesh = None    # shard_map layer, compile the plain jit
        if mesh is not None:
            self.axes = axes
        else:
            self.axes = ("data",)
            n_shards = 1
        self.mesh = mesh
        # pad W to a pow2 and B to a pow2 multiple of the shard count so the
        # per-shape jit caches stay small; padded rows are dead and all-zero
        self.B = int(B)
        self.Bp = n_shards * pow2(-(-B // n_shards), floor=1)
        self.Wp = pow2(int(W), floor=2)
        bits_p = np.zeros((self.Bp, G, self.Wp), dtype=np.uint32)
        bits_p[:B, :, :W] = bits_u32
        alive_p = np.zeros((self.Bp, G), dtype=np.int8)  # 1 byte/row on the wire
        alive_p[:B] = np.asarray(alive, dtype=bool)
        self._put = self._sharder(jax)
        self._bits = self._put(bits_p)
        self._alive = self._put(alive_p)
        counter.add_h2d(bits_p.nbytes + alive_p.nbytes, phase="upload")
        self.rounds = 0
        self.Rp = 0            # set by attach_counts
        self._counts = None    # v2 resident count state, or None (v1 mode)

    @classmethod
    def from_workspace(cls, ws, *, top_j: int = 16, mesh=None,
                       use_kernel=None, interpret=None, counter=TRANSFER,
                       with_counts: bool = True):
        """Upload a `BatchedGroupWorkspace` chunk's bitmaps (uint32 view of
        its uint64 words — bit positions follow the uint32 layout), and —
        unless ``with_counts=False`` — its exact integer count tensors, so
        the whole sweep runs against resident state."""
        bits = ws.bits.view(np.uint32)
        arena = cls(bits, ws.alive, top_j=top_j, mesh=mesh,
                    use_kernel=use_kernel, interpret=interpret,
                    counter=counter)
        if with_counts:
            arena.attach_counts(ws.CNT, ws.colsize, ws.memcol, ws.s,
                                ws.selfc, ws.nd, ws.hgt, ws.cost_row,
                                ws.alive)
        return arena

    def attach_counts(self, CNT, colsize, memcol, s, selfc, nd, hgt, cost,
                      alive):
        """Upload the integer count state (all values int32-guarded by the
        workspace build). The dirty queue starts as the alive mask —
        exactly the host sweep's initial queue."""
        from repro.kernels.common import pow2

        B, G, R = CNT.shape
        self.Rp = pow2(int(R), floor=8)
        cnt_p = np.zeros((self.Bp, G, self.Rp), dtype=np.int32)
        cnt_p[:B, :, :R] = CNT
        colsize_p = np.zeros((self.Bp, self.Rp), dtype=np.int32)
        colsize_p[:B, :R] = colsize
        # padded groups are all-dead: their zero state is inert in every op
        per_g = [np.zeros((self.Bp, G), dtype=np.int32) for _ in range(6)]
        for arr, src in zip(per_g, (memcol, s, selfc, nd, hgt, cost)):
            arr[:B] = src
        dirty_p = np.zeros((self.Bp, G), dtype=np.int8)
        dirty_p[:B] = np.asarray(alive, dtype=bool)
        self._CNT = self._put(cnt_p)
        self._colsize = self._put(colsize_p)
        (self._memcol, self._s, self._selfc, self._nd, self._hgt,
         self._cost) = [self._put(a) for a in per_g]
        self._dirty = self._put(dirty_p)
        self._counts = True
        self.counter.add_h2d(cnt_p.nbytes + colsize_p.nbytes +
                             sum(a.nbytes for a in per_g) + dirty_p.nbytes,
                             phase="upload")

    @classmethod
    def from_bank(cls, bank, ws, res_map, *, top_j: int = 16,
                  use_kernel=None, interpret=None, counter=TRANSFER):
        """Build a chunk arena by on-device EXTRACTION from the resident
        adjacency bank (ISSUE 9) — no bitmap/count upload at all.

        ``ws`` is a shape-only shell workspace (`BatchedGroupWorkspace`
        built with ``shell=True``): only its member layout (``members``,
        ``B``, ``G``, ``R``) is read; the big tensors never exist on host.
        The only h2d traffic is the (Bp, G) member/ptr/len index slab
        (phase ``extract``). Bank arrays are read without donation, so
        concurrent chunk thunks may extract from one bank. The extracted
        state is bit-identical to `from_workspace` of a fully host-packed
        chunk (test-enforced).
        """
        jax = _jax()
        import jax.numpy as jnp
        from repro.kernels.bitset_fold.ops import extract_fn
        from repro.kernels.common import (default_interpret,
                                          default_use_kernel, pow2)

        faults.check("resident.bank.extract")
        arena = cls.__new__(cls)
        B, G, R = int(ws.B), int(ws.G), int(ws.R)
        arena.counter = counter
        arena.G = G
        arena.J = max(1, min(int(top_j), G - 1))
        arena.use_kernel = (default_use_kernel() if use_kernel is None
                            else bool(use_kernel))
        arena.interpret = (default_interpret() if interpret is None
                           else bool(interpret))
        arena.mesh = None
        arena.axes = ("data",)
        arena.B = B
        arena.Bp = pow2(B, floor=1)
        arena.Wp = pow2(2 * max((R + 63) // 64, 1), floor=2)
        arena.Rp = pow2(R, floor=8)
        arena._put = arena._sharder(jax)
        members = np.full((arena.Bp, G), -1, dtype=np.int32)
        members[:B] = ws.members
        live = ws.members >= 0
        mem_c = np.where(live, ws.members, 0)
        ptr = np.zeros((arena.Bp, G), dtype=np.int32)
        lens = np.zeros((arena.Bp, G), dtype=np.int32)
        ptr[:B] = np.where(live, bank.ptr_host[mem_c], 0)
        lens[:B] = np.where(live, bank.len_host[mem_c], 0)
        Lp = pow2(int(lens.sum(axis=1).max()), floor=64)
        fn = extract_fn(arena.Bp, G, arena.Rp, arena.Wp, Lp, bank.cap,
                        int(bank._gids.shape[0]))
        counter.add_h2d(members.nbytes + ptr.nbytes + lens.nbytes,
                        phase="extract")
        (arena._bits, arena._alive, arena._dirty, arena._CNT,
         arena._colsize, arena._memcol, arena._s, arena._selfc, arena._nd,
         arena._hgt, arena._cost) = fn(
            bank._gids, bank._cnts, bank._size, bank._selfc, bank._nd,
            bank._hgt, res_map, jnp.asarray(members), jnp.asarray(ptr),
            jnp.asarray(lens))
        arena.rounds = 0
        arena._counts = True
        return arena

    # ------------------------------------------------------------- plumbing
    def _sharder(self, jax):
        if self.mesh is None:
            import jax.numpy as jnp
            return jnp.asarray
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = P(self.axes if len(self.axes) > 1 else self.axes[0])
        sh = NamedSharding(self.mesh, spec)
        return lambda arr: jax.device_put(arr, sh)

    def _replicate(self, arr):
        if self.mesh is None:
            import jax.numpy as jnp
            return jnp.asarray(arr)
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.device_put(arr, NamedSharding(self.mesh, P()))

    # ------------------------------------------------------------ round ops
    def topj_rows(self, rb: np.ndarray, rr: np.ndarray) -> np.ndarray:
        """Ranked top-J candidate columns of rows (rb[i], rr[i]) — one fused
        device ranking over the resident bitmaps; (n, J) int64 comes back."""
        from repro.kernels.bitset_fold.ops import topj_fn
        from repro.kernels.common import pow2

        n = rb.size
        # floor 64 keeps the per-shape jit cache tiny: late rounds all land
        # on one shape, and 64 padded rows cost ~J·64 wasted bytes at most
        n_pad = pow2(n, floor=64)
        rows = np.zeros((n_pad, 2), dtype=np.int32)
        rows[:n, 0] = rb
        rows[:n, 1] = rr

        def build(uk):
            return topj_fn(self.Bp, self.G, self.Wp, self.J, n_pad,
                           use_kernel=uk, interpret=self.interpret,
                           mesh=self.mesh, axes=self.axes)
        self.counter.add_h2d(rows.nbytes, phase="rank")
        out = np.asarray(_run_round_op(
            self, "kernel.bitset_fold.topj", build,
            (self._bits, self._alive, self._replicate(rows))))
        self.counter.add_d2h(out.nbytes, phase="rank")
        self.counter.tick_round()
        self.rounds += 1
        return out[:n].astype(np.int64)

    def fold(self, b: np.ndarray, a: np.ndarray, z: np.ndarray,
             ca: np.ndarray, cz: np.ndarray):
        """Fold one round's accepted pairs (rows z into rows a of groups b,
        member columns ca/cz) into the resident bitmaps, in place."""
        from repro.kernels.bitset_fold.ops import fold_fn
        from repro.kernels.common import pow2

        m = b.size
        if m == 0:
            return
        # slot of each pair within its group (b arrives sorted ascending)
        head = np.concatenate([[True], b[1:] != b[:-1]])
        starts = np.flatnonzero(head)
        counts = np.diff(np.concatenate([starts, [m]]))
        slot = np.arange(m) - np.repeat(starts, counts)
        P_pairs = min(pow2(int(counts.max()), floor=2), max(self.G // 2, 1))
        # int16 on the wire when it provably fits (rows < G ≤ 128; word
        # indices < Wp ≤ 2^13); a wide column universe widens to int32
        # instead of truncating — the device casts to int32 either way
        dtype = np.int16 if self.Wp <= (1 << 13) else np.int32
        instr = np.zeros((self.Bp, P_pairs, 8), dtype=dtype)
        instr[b, slot, 0] = a
        instr[b, slot, 1] = z
        instr[b, slot, 2] = ca >> 5
        instr[b, slot, 3] = ca & 31
        instr[b, slot, 4] = cz >> 5
        instr[b, slot, 5] = cz & 31
        instr[b, slot, 6] = 1

        def build(uk):
            return fold_fn(self.Bp, self.G, self.Wp, P_pairs,
                           use_kernel=uk, interpret=self.interpret,
                           mesh=self.mesh, axes=self.axes)
        self.counter.add_h2d(instr.nbytes, phase="fold")
        self._bits, self._alive = _run_round_op(
            self, "kernel.bitset_fold.fold", build,
            (self._bits, self._alive, self._put(instr)))

    # ----------------------------------------- v2: whole-iteration residency
    def _state(self):
        return (self._bits, self._alive, self._dirty, self._CNT,
                self._colsize, self._memcol, self._s, self._selfc, self._nd,
                self._hgt, self._cost)

    def propose_rows(self, rb: np.ndarray, rr: np.ndarray, j_max: int,
                     theta_p: int, height_bound):
        """One fused proposal round over the resident state.

        ``rb``/``rr`` are the HOST's dirty rows — the device never sees
        them (it derives the identical list from its resident ``dirty``
        mirror); they only size the padded row count and order the returned
        verdicts. Returns ``(accept, partner)`` bool/(int64) arrays of
        length ``rb.size``. ``j_max`` is ignored for compilation (the op
        always traces J = top_j and masks per-row, so every round of an
        iteration hits one executable).
        """
        import jax.numpy as jnp
        from repro.kernels.bitset_fold.ops import round_fn
        from repro.kernels.common import pow2

        if self._counts is None:
            raise RuntimeError("propose_rows needs attach_counts state")
        n = rb.size
        K = pow2(n, floor=64)

        def build(uk):
            return round_fn(self.Bp, self.G, self.Rp, self.Wp, K, self.J,
                            self.J, height_bound=height_bound,
                            use_kernel=uk, interpret=self.interpret,
                            mesh=self.mesh, axes=self.axes)
        self.counter.add_h2d(4, phase="rank")  # the θ̂ scalar
        self._dirty, out = _run_round_op(
            self, "kernel.bitset_fold.round", build,
            self._state() + (jnp.uint32(theta_p),))
        out = np.asarray(out)
        self.counter.add_d2h(out.nbytes, phase="rank")
        self.counter.tick_round()
        self.rounds += 1
        if self.mesh is not None:
            out = out[rb, rr]          # (B, G, 2) → host-side dirty gather
        else:
            out = out[:n]
        return out[:, 0] > 0, out[:, 1].astype(np.int64)

    def fold_counts(self, b: np.ndarray, a: np.ndarray, z: np.ndarray):
        """Fold one round's accepted pairs (rows z into rows a of groups b)
        into the WHOLE resident state, in place. Member columns come from
        the resident ``memcol`` — the instruction slab is 12 bytes/pair."""
        from repro.kernels.bitset_fold.ops import fold_counts_fn
        from repro.kernels.common import pow2

        if self._counts is None:
            raise RuntimeError("fold_counts needs attach_counts state")
        m = b.size
        if m == 0:
            return
        # slot of each pair within its group (b arrives sorted ascending)
        head = np.concatenate([[True], b[1:] != b[:-1]])
        starts = np.flatnonzero(head)
        counts = np.diff(np.concatenate([starts, [m]]))
        slot = np.arange(m) - np.repeat(starts, counts)
        P_pairs = min(pow2(int(counts.max()), floor=2), max(self.G // 2, 1))
        instr = np.zeros((self.Bp, P_pairs, 3), dtype=np.int32)
        instr[b, slot, 0] = a
        instr[b, slot, 1] = z
        instr[b, slot, 2] = 1

        def build(uk):
            return fold_counts_fn(self.Bp, self.G, self.Rp, self.Wp,
                                  P_pairs, use_kernel=uk,
                                  interpret=self.interpret, mesh=self.mesh,
                                  axes=self.axes)
        self.counter.add_h2d(instr.nbytes, phase="fold")
        (self._bits, self._alive, self._dirty, self._CNT, self._colsize,
         self._s, self._selfc, self._nd, self._hgt,
         self._cost) = _run_round_op(
            self, "kernel.bitset_fold.fold_counts", build,
            self._state() + (self._put(instr),))

    # --------------------------------------------------- sync-back contract
    def sync_rows(self, b: np.ndarray, g: np.ndarray) -> np.ndarray:
        """Download selected (dirty) bitmap rows — (n, Wp) uint32. The
        verification hook of DESIGN.md §9: callers compare these against the
        host fold; the engine itself never needs them (Savings run on the
        host-resident count tensors)."""
        rows = np.asarray(self._bits)[np.asarray(b), np.asarray(g)]
        self.counter.add_d2h(rows.nbytes, phase="sync")
        return rows

    def host_bits(self) -> np.ndarray:
        """Full (B, G, Wp) download (tests/debug only — counts as d2h)."""
        out = np.asarray(self._bits)[: self.B]
        self.counter.add_d2h(out.nbytes, phase="sync")
        return out

    def host_alive(self) -> np.ndarray:
        out = np.asarray(self._alive)[: self.B] > 0
        self.counter.add_d2h(out.nbytes, phase="sync")
        return out

    def host_counts(self):
        """Download the resident count state — ``(CNT, colsize, memcol, s,
        selfc, nd, hgt, cost)`` host copies trimmed to the live batch rows.
        Verification contract only (phase ``sync``): tests compare these
        against a host `_fill` of the same chunk."""
        if self._counts is None:
            raise RuntimeError("host_counts needs attach_counts state")
        arrs = [np.asarray(a) for a in
                (self._CNT, self._colsize, self._memcol, self._s,
                 self._selfc, self._nd, self._hgt, self._cost)]
        self.counter.add_d2h(sum(a.nbytes for a in arrs), phase="sync")
        return tuple(a[: self.B] for a in arrs)


class ResidentAdjacencyBank:
    """Per-root adjacency rows carried ON DEVICE across iterations (§9).

    Append-only ``gid``/``cnt`` int32 streams (pow2-grown, donated across
    advances) hold every root's coalesced external adjacency row exactly as
    `SluggerState` would materialize it at the root's mint time: entries are
    ``(gid, cnt)`` with gids resolved to roots AS OF that mint (stored ids
    go stale as neighbours merge — extraction re-resolves them through the
    current ``res_map`` and re-coalesces, which is precisely the host's
    `gather_rows` resolve+coalesce). Four (cap,) stat arrays mirror
    ``size``/``selfcnt``/``ndesc``/``height``. The HOST keeps only the
    integer row directory (``ptr_host``/``len_host``/``top``) — row
    lengths are known host-side because `merge_batch` computes the same
    ``row_len`` and the engine forwards it with each applied batch.

    Exactness guard: merges only coalesce counts (sum-preserving) or drop
    internal pairs, so Σcnt never exceeds the seed edge count ``m``; every
    extracted CNT value is ≤ m and every clamped integer row cost is
    ≤ 3m/2 + 2n + 16. The constructor refuses (OverflowError) any graph
    where that bound reaches C_CLAMP — callers fall back to the
    host-rebuilt path, whose `_fill` re-checks per chunk at runtime — so
    ON the bank path all device int32 cost arithmetic is provably exact
    and extraction needs no overflow checks (and no downloads at all).
    """

    def __init__(self, g, *, counter=TRANSFER, min_capacity: int = 0):
        _jax()
        import jax.numpy as jnp
        from repro.core.merging import C_CLAMP
        from repro.kernels.common import pow2

        self.counter = counter
        self.n = int(g.n)
        self.cap = 2 * self.n + 8
        indices = np.asarray(g.indices)
        m = int(indices.size)
        if (3 * m) // 2 + 2 * self.n + 16 >= C_CLAMP:
            raise OverflowError(
                "graph too heavy for the int32 adjacency bank: the "
                "conservation bound 3m/2 + 2n + 16 reaches C_CLAMP")
        E0 = pow2(max(2 * m, int(min_capacity), 64))
        gids = np.zeros(E0, dtype=np.int32)
        gids[:m] = indices
        cnts = np.zeros(E0, dtype=np.int32)
        cnts[:m] = 1
        self.ptr_host = np.zeros(self.cap, dtype=np.int64)
        self.len_host = np.zeros(self.cap, dtype=np.int64)
        self.ptr_host[: self.n] = g.indptr[:-1]
        self.len_host[: self.n] = np.diff(g.indptr)
        self.top = m
        self._gids = jnp.asarray(gids)
        self._cnts = jnp.asarray(cnts)
        # stats live on device from the start — zero h2d for them
        self._size = jnp.ones(self.cap, dtype=jnp.int32)
        self._selfc = jnp.zeros(self.cap, dtype=jnp.int32)
        self._nd = jnp.zeros(self.cap, dtype=jnp.int32)
        self._hgt = jnp.zeros(self.cap, dtype=jnp.int32)
        counter.add_h2d(gids.nbytes + cnts.nbytes, phase="init")

    @property
    def capacity(self) -> int:
        return int(self._gids.shape[0])

    def advance_batches(self, res_map, batches: list):
        """Advance the bank by one iteration's applied merge batches.

        ``batches`` is a list of ``(A, Z, M, lens)`` — the exact arrays the
        engine captured at `apply_plans`'s ``on_batch`` hook, with ``lens ==
        state.row_len[M]`` read at that instant (the freshly minted rows'
        unique-external counts). Batches are replayed SEQUENTIALLY so each
        device batch resolves gids through the same pre-batch root map the
        host `merge_batch` used; ``res_map`` is threaded through and
        returned. Per batch the only upload is the (8, Pp) i32 instruction
        slab (32 B/pair, phase ``bank``); regrows are device-to-device.
        """
        import jax.numpy as jnp
        from repro.kernels.bitset_fold.carry import (bank_advance_fn,
                                                     bank_grow_fn)
        from repro.kernels.common import pow2

        # checked BEFORE any host directory mutation: a fault here leaves
        # the bank untouched, so the engine's advance degradation can just
        # drop the run context without unwinding partial state
        faults.check("resident.bank.advance")
        for A, Z, M, lens in batches:
            m = int(A.size)
            if m == 0:
                continue
            ub = self.len_host[A] + self.len_host[Z]
            tot = int(ub.sum())
            need = self.top + tot
            E = self.capacity
            if need > E:
                newE = pow2(max(need, 2 * E))
                if newE >= (1 << 31):
                    raise OverflowError(
                        "adjacency bank outgrew int32 addressing")
                self._gids, self._cnts = bank_grow_fn(E, newE)(
                    self._gids, self._cnts)
                E = newE
            Pp = pow2(m, floor=64)
            Tp = pow2(max(tot, 1), floor=256)
            outp = self.top + np.cumsum(ub) - ub
            slab = np.zeros((8, Pp), dtype=np.int32)
            slab[0] = self.cap          # pads: ids scatter-drop at cap,
            slab[1] = self.cap          # out_ptr drops at E, lengths 0
            slab[2] = self.cap
            slab[3] = E
            slab[0, :m] = A
            slab[1, :m] = Z
            slab[2, :m] = M
            slab[3, :m] = outp
            slab[4, :m] = self.ptr_host[A]
            slab[5, :m] = self.len_host[A]
            slab[6, :m] = self.ptr_host[Z]
            slab[7, :m] = self.len_host[Z]
            fn = bank_advance_fn(self.cap, E, Pp, Tp)
            self.counter.add_h2d(slab.nbytes, phase="bank")
            (self._gids, self._cnts, self._size, self._selfc, self._nd,
             self._hgt, res_map) = fn(self._gids, self._cnts, self._size,
                                      self._selfc, self._nd, self._hgt,
                                      res_map, jnp.asarray(slab))
            self.ptr_host[M] = outp
            self.len_host[M] = lens
            self.len_host[A] = 0       # consumed roots own no row anymore
            self.len_host[Z] = 0
            self.top = need
        return res_map

    # --------------------------------------------------- sync-back contract
    def host_rows(self, roots, res_map):
        """Materialize the CURRENT coalesced adjacency rows of ``roots`` on
        host — the bank's verification contract (phase ``sync``): resolve
        each stored gid through ``res_map`` and re-coalesce, exactly like
        `SluggerState.gather_rows`. Returns a list of ``(nbr, cnt)`` int64
        pairs sorted ascending by nbr. Tests/debug only."""
        gids = np.asarray(self._gids)
        cnts = np.asarray(self._cnts)
        rm = np.asarray(res_map)
        self.counter.add_d2h(gids.nbytes + cnts.nbytes + rm.nbytes,
                             phase="sync")
        out = []
        for r in np.asarray(roots, dtype=np.int64):
            p = int(self.ptr_host[r])
            l = int(self.len_host[r])
            rg = rm[gids[p:p + l]]
            c = cnts[p:p + l]
            order = np.argsort(rg, kind="stable")
            rg = rg[order]
            c = c[order]
            if l:
                head = np.concatenate([[True], rg[1:] != rg[:-1]])
                idx = np.flatnonzero(head)
                out.append((rg[idx].astype(np.int64),
                            np.add.reduceat(c, idx).astype(np.int64)))
            else:
                out.append((np.zeros(0, np.int64), np.zeros(0, np.int64)))
        return out


class ResidentRunContext:
    """Per-run device state of the single-device resident backend.

    Holds what outlives one iteration (the arenas are per-iteration,
    per-chunk):

    * the STATIC edge arrays, uploaded once per run (phase ``init``) —
      candidate generation's O(|E|) hashing never re-ships the graph;
    * ``res_map`` (cap,) int32 — the current root of every arena id,
      advanced at every exchange stage by replaying the applied merge
      plans (`merging.apply_plans`'s ``on_batch`` hook feeds the exact
      (A, Z, M) batches): a forward map with the iteration's merges is
      built on device and collapsed by pointer doubling (2^16 covers any
      in-iteration merge chain), then composed into ``res_map``. Per
      iteration only the ~12 bytes/merge instruction stream crosses up
      (phase ``carry``) — the map itself never leaves the device.

    ``for_roots`` is the engine's shingle-provider hook: root shingles
    compute ON DEVICE from the resident edges and ``res_map`` (tentpole 3
    of ISSUE 7 — resident candidate generation); per rehash only the
    (n_ids,) shingle vector and the per-root leaf counts come back (phase
    ``candgen``). The results are bit-identical to the host u32 twin
    (`minhash.host_shingle_provider`) and the mesh shard_map path.

    With ``bank=True`` the context additionally carries a
    `ResidentAdjacencyBank` (ISSUE 9) — the device-resident row arena
    that `ResidentBitmapArena.from_bank` extracts next-iteration
    workspaces from, making host workspaces shape-only shells. In bank
    mode `advance` expects the engine's 4-tuple ``(A, Z, M, lens)``
    batches and the plan-replay ``carry`` upload is superseded: the
    bank-advance slab already names (A, Z, M), so ``res_map`` composes
    inside the same donated device call. If the bank's exactness guard
    declines the graph (`OverflowError` at seed time), ``bank`` stays
    ``None`` and the engine falls back to the host-rebuilt upload path.
    """

    def __init__(self, g, *, counter=TRANSFER, bank: bool = False,
                 bank_min_capacity: int = 0):
        _jax()
        import jax.numpy as jnp

        self.counter = counter
        self.n = int(g.n)
        self.cap = 2 * self.n + 8      # SluggerState's id capacity
        src = np.repeat(np.arange(g.n), np.diff(g.indptr)).astype(np.int32)
        dst = np.asarray(g.indices, dtype=np.int32)
        self._src = jnp.asarray(src)
        self._dst = jnp.asarray(dst)
        self._res_map = jnp.arange(self.cap, dtype=jnp.int32)
        counter.add_h2d(src.nbytes + dst.nbytes, phase="init")
        self.bank = None
        if bank:
            try:
                self.bank = ResidentAdjacencyBank(
                    g, counter=counter, min_capacity=bank_min_capacity)
            except OverflowError:
                # exactness guard tripped — stay on the host-rebuilt path
                # (its per-chunk `_fill` guards re-check at runtime)
                self.bank = None

    # ------------------------------------------------------- plan replay
    def advance(self, batches: list):
        """Replay one iteration's applied merge batches against the
        resident root map — and, when the adjacency bank is live, against
        the bank itself.

        Legacy (bank-less) mode takes ``(A, Z, M)`` global id triples in
        application order and composes them in ONE device call. Bank mode
        requires ``(A, Z, M, lens)`` 4-tuples (``lens = state.row_len[M]``
        captured at the ``on_batch`` hook) and replays them sequentially —
        each bank batch must see the pre-batch root map, exactly like the
        host `merge_batch`.
        """
        import jax.numpy as jnp
        from repro.kernels.bitset_fold.carry import advance_fn
        from repro.kernels.common import pow2

        if self.bank is not None:
            if any(len(b) < 4 for b in batches):
                raise ValueError(
                    "bank carry needs (A, Z, M, lens) batches — pass "
                    "state.row_len[M] captured at the on_batch hook")
            self._res_map = self.bank.advance_batches(self._res_map,
                                                      batches)
            return
        m = sum(b[0].size for b in batches)
        if m == 0:
            return
        mp = pow2(m, floor=64)
        tri = np.full((3, mp), self.cap, dtype=np.int32)  # pads scatter-drop
        tri[0, :m] = np.concatenate([b[0] for b in batches])
        tri[1, :m] = np.concatenate([b[1] for b in batches])
        tri[2, :m] = np.concatenate([b[2] for b in batches])
        fn = advance_fn(self.cap, mp)
        self.counter.add_h2d(tri.nbytes, phase="carry")
        self._res_map = fn(self._res_map, jnp.asarray(tri))

    def root_of_host(self) -> np.ndarray:
        """Download res_map[:n] (tests/debug — the verification contract
        against `SluggerState.root_of`; the engine never calls this)."""
        out = np.asarray(self._res_map)[: self.n].astype(np.int64)
        self.counter.add_d2h(out.nbytes, phase="sync")
        return out

    # ----------------------------------------------- resident candidate gen
    def for_roots(self, root_of: np.ndarray):
        """Shingle-provider hook (`minhash.candidate_groups` protocol).

        ``root_of`` (the host map) is intentionally unused: the resident
        ``res_map`` IS that mapping — `advance` replayed every applied
        plan — so the roots come from device state and only the per-root
        results cross the boundary.
        """
        import jax.numpy as jnp
        from repro.kernels.bitset_fold.carry import shingle_roots_fn
        from repro.core.minhash import u32_seed_consts

        fn = shingle_roots_fn(self.n, self.cap, self._src.shape[0])

        def shingle_fn(sub_seed: int, n_ids: int) -> np.ndarray:
            a, b = u32_seed_consts(sub_seed)
            sh, cnt = fn(self._src, self._dst, self._res_map,
                         jnp.uint32(a), jnp.uint32(b))
            sh = np.asarray(sh)
            cnt = np.asarray(cnt)
            self.counter.add_d2h(sh.nbytes + cnt.nbytes, phase="candgen")
            out = sh.astype(np.int64)[:n_ids]
            # leafless ids take the unique sentinel 2^32 + id — the same
            # rule as `minhash.rootwise_min(…, sentinel_base=1 << 32)`
            missing = np.flatnonzero(cnt[:n_ids] == 0)
            out[missing] = (1 << 32) + missing
            return out

        return shingle_fn
