"""Device-resident merge rounds: persistent bitmap arenas (DESIGN.md §9).

`ResidentBitmapArena` is the ``backend="resident"`` engine's device half.
One arena wraps ONE batched workspace chunk (`merging.BatchedGroupWorkspace`,
a (B, G, W) packed-bitmap batch): the bitmaps are uploaded ONCE, stay
resident across every merge round of the iteration, and the round loop
becomes three on-device ops —

1. **fused ranking** (`kernels/bitset_fold.topj_fn`): pairwise quantized-
   Jaccard keys reduced to per-row ranked top-J candidate columns on
   device; the host downloads (n_dirty, J) int8 instead of a dense
   (B, G, G) score matrix;
2. **bitset-OR fold** (`kernels/bitset_fold.fold_fn`): the round's accepted
   merge pairs fold the resident bitmaps in place (donated buffers — on
   backends with donation support the fold never copies);
3. a host exchange of the TINY artifacts only: dirty-row ids up, ranked
   candidates down, fold instructions up.

The exact-Saving evaluation needs no bitmap sync-back — the workspace keeps
the integer count tensors (`CNT`, sizes, self-counts) on host, and Savings
are computed from those; bitmaps only drive the ranking. `sync_rows` exists
for the verification contract: tests pull selected (dirty) rows back and
assert the device fold is bit-identical to the host fold.

Every upload/download reports to `core.transfer.GLOBAL`, and each ranking
round-trip ticks the round counter — `benchmarks/scalability.py --resident`
gates the bytes-per-round reduction on these numbers.
"""
from __future__ import annotations

import numpy as np

from repro.core.transfer import GLOBAL as TRANSFER


def _jax():
    try:
        import jax
    except ImportError as e:  # pragma: no cover - jax is a hard dep of this path
        raise RuntimeError(
            "backend='resident' needs jax; install jax or use "
            "backend='numpy'") from e
    return jax


class ResidentBitmapArena:
    """Persistent device copy of one workspace chunk's packed bitmaps."""

    def __init__(self, bits_u32: np.ndarray, alive: np.ndarray, *,
                 top_j: int = 16, mesh=None, use_kernel=None,
                 interpret=None, counter=TRANSFER):
        jax = _jax()
        from repro.kernels.common import (default_interpret,
                                          default_use_kernel, pow2)

        B, G, W = bits_u32.shape
        self.counter = counter
        self.G = int(G)
        self.J = max(1, min(int(top_j), G - 1))
        self.use_kernel = (default_use_kernel() if use_kernel is None
                           else bool(use_kernel))
        self.interpret = (default_interpret() if interpret is None
                          else bool(interpret))
        if mesh is not None:
            from repro.launch.mesh import dp_axes_of
            axes = dp_axes_of(mesh)
            n_shards = int(np.prod([mesh.shape[a] for a in axes]))
            if n_shards <= 1:  # a 1-device mesh shards nothing: skip the
                mesh = None    # shard_map layer, compile the plain jit
        if mesh is not None:
            self.axes = axes
        else:
            self.axes = ("data",)
            n_shards = 1
        self.mesh = mesh
        # pad W to a pow2 and B to a pow2 multiple of the shard count so the
        # per-shape jit caches stay small; padded rows are dead and all-zero
        self.B = int(B)
        self.Bp = n_shards * pow2(-(-B // n_shards), floor=1)
        self.Wp = pow2(int(W), floor=2)
        bits_p = np.zeros((self.Bp, G, self.Wp), dtype=np.uint32)
        bits_p[:B, :, :W] = bits_u32
        alive_p = np.zeros((self.Bp, G), dtype=np.int8)  # 1 byte/row on the wire
        alive_p[:B] = np.asarray(alive, dtype=bool)
        self._put = self._sharder(jax)
        self._bits = self._put(bits_p)
        self._alive = self._put(alive_p)
        counter.add_h2d(bits_p.nbytes + alive_p.nbytes)
        self.rounds = 0

    @classmethod
    def from_workspace(cls, ws, *, top_j: int = 16, mesh=None,
                       use_kernel=None, interpret=None, counter=TRANSFER):
        """Upload a `BatchedGroupWorkspace` chunk's bitmaps (uint32 view of
        its uint64 words — bit positions follow the uint32 layout)."""
        bits = ws.bits.view(np.uint32)
        return cls(bits, ws.alive, top_j=top_j, mesh=mesh,
                   use_kernel=use_kernel, interpret=interpret,
                   counter=counter)

    # ------------------------------------------------------------- plumbing
    def _sharder(self, jax):
        if self.mesh is None:
            import jax.numpy as jnp
            return jnp.asarray
        from jax.sharding import NamedSharding, PartitionSpec as P
        spec = P(self.axes if len(self.axes) > 1 else self.axes[0])
        sh = NamedSharding(self.mesh, spec)
        return lambda arr: jax.device_put(arr, sh)

    def _replicate(self, arr):
        if self.mesh is None:
            import jax.numpy as jnp
            return jnp.asarray(arr)
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.device_put(arr, NamedSharding(self.mesh, P()))

    # ------------------------------------------------------------ round ops
    def topj_rows(self, rb: np.ndarray, rr: np.ndarray) -> np.ndarray:
        """Ranked top-J candidate columns of rows (rb[i], rr[i]) — one fused
        device ranking over the resident bitmaps; (n, J) int64 comes back."""
        from repro.kernels.bitset_fold.ops import topj_fn
        from repro.kernels.common import pow2

        n = rb.size
        # floor 64 keeps the per-shape jit cache tiny: late rounds all land
        # on one shape, and 64 padded rows cost ~J·64 wasted bytes at most
        n_pad = pow2(n, floor=64)
        rows = np.zeros((n_pad, 2), dtype=np.int32)
        rows[:n, 0] = rb
        rows[:n, 1] = rr
        fn = topj_fn(self.Bp, self.G, self.Wp, self.J, n_pad,
                     use_kernel=self.use_kernel, interpret=self.interpret,
                     mesh=self.mesh, axes=self.axes)
        self.counter.add_h2d(rows.nbytes)
        out = np.asarray(fn(self._bits, self._alive, self._replicate(rows)))
        self.counter.add_d2h(out.nbytes)
        self.counter.tick_round()
        self.rounds += 1
        return out[:n].astype(np.int64)

    def fold(self, b: np.ndarray, a: np.ndarray, z: np.ndarray,
             ca: np.ndarray, cz: np.ndarray):
        """Fold one round's accepted pairs (rows z into rows a of groups b,
        member columns ca/cz) into the resident bitmaps, in place."""
        from repro.kernels.bitset_fold.ops import fold_fn
        from repro.kernels.common import pow2

        m = b.size
        if m == 0:
            return
        # slot of each pair within its group (b arrives sorted ascending)
        head = np.concatenate([[True], b[1:] != b[:-1]])
        starts = np.flatnonzero(head)
        counts = np.diff(np.concatenate([starts, [m]]))
        slot = np.arange(m) - np.repeat(starts, counts)
        P_pairs = min(pow2(int(counts.max()), floor=2), max(self.G // 2, 1))
        # int16 on the wire when it provably fits (rows < G ≤ 128; word
        # indices < Wp ≤ 2^13); a wide column universe widens to int32
        # instead of truncating — the device casts to int32 either way
        dtype = np.int16 if self.Wp <= (1 << 13) else np.int32
        instr = np.zeros((self.Bp, P_pairs, 8), dtype=dtype)
        instr[b, slot, 0] = a
        instr[b, slot, 1] = z
        instr[b, slot, 2] = ca >> 5
        instr[b, slot, 3] = ca & 31
        instr[b, slot, 4] = cz >> 5
        instr[b, slot, 5] = cz & 31
        instr[b, slot, 6] = 1
        fn = fold_fn(self.Bp, self.G, self.Wp, P_pairs,
                     use_kernel=self.use_kernel, interpret=self.interpret,
                     mesh=self.mesh, axes=self.axes)
        self.counter.add_h2d(instr.nbytes)
        self._bits, self._alive = fn(self._bits, self._alive,
                                     self._put(instr))

    # --------------------------------------------------- sync-back contract
    def sync_rows(self, b: np.ndarray, g: np.ndarray) -> np.ndarray:
        """Download selected (dirty) bitmap rows — (n, Wp) uint32. The
        verification hook of DESIGN.md §9: callers compare these against the
        host fold; the engine itself never needs them (Savings run on the
        host-resident count tensors)."""
        rows = np.asarray(self._bits)[np.asarray(b), np.asarray(g)]
        self.counter.add_d2h(rows.nbytes)
        return rows

    def host_bits(self) -> np.ndarray:
        """Full (B, G, Wp) download (tests/debug only — counts as d2h)."""
        out = np.asarray(self._bits)[: self.B]
        self.counter.add_d2h(out.nbytes)
        return out

    def host_alive(self) -> np.ndarray:
        out = np.asarray(self._alive)[: self.B] > 0
        self.counter.add_d2h(out.nbytes)
        return out
