"""Partition-parallel, stage-based summarization engine (DESIGN.md §8).

`SummarizerEngine` is the driver behind `slugger.summarize()`: the old
monolithic per-iteration loop broken into five explicit, pluggable stages

    shingle → group → pack → merge_round → exchange

run T times over a `PartitionedGraph`, followed by partition-aware emission
and pruning. Candidate generation is global (shingles and groups are cheap,
O(|E|) array passes); candidate GROUPS — where the quadratic in-group work
lives — are assigned to partitions by node ownership and swept shard-local
in record mode (`merging.MergePlan`), so the only data crossing a partition
boundary between rounds is the exchange stage's replay of forward/root
pointer updates (`merging.apply_plans`).

Determinism is the load-bearing property: every stage is either global and
seeded (shingle/group), a pure function of one group's snapshot tensors and
its own spawned RNG stream (merge_round), or a canonical-order replay
(exchange). Consequently ``partitions=k`` produces BIT-IDENTICAL summaries
to ``partitions=1`` for every backend and any thread schedule —
test-enforced in `tests/test_engine_partitioned.py`.

Per-iteration randomness comes from `np.random.SeedSequence(seed).spawn(T)`
— no arithmetic on raw seeds anywhere, so distinct (seed, iteration, group)
triples can never alias (the old ``seed * 7919 + t`` did: seed=0,t=7919 ≡
seed=1,t=0).

``backend="batched"`` additionally routes shingles and the bitset
intersection ranking through `core/distributed`'s `shard_map` dispatches
when more than one device is visible (or a mesh is passed explicitly) — the
multi-device path of the production engine rather than a disconnected demo.
``backend="resident"`` goes further: each workspace chunk's bitmaps are
uploaded ONCE into a `core/resident.ResidentBitmapArena` and every merge
round runs as on-device fused top-J ranking + bitset-OR folds, with only
tiny plans crossing the host↔device boundary (DESIGN.md §9).
"""
from __future__ import annotations

import logging
import os
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro import faults
from repro.core.merging import apply_plans, build_merge_work
from repro.core.minhash import candidate_groups
from repro.core.pruning import prune
from repro.core.slugger import SluggerState, _emit_encoding
from repro.graphs.partitioned import PartitionedGraph, as_partitioned

log = logging.getLogger("repro.engine")

STAGE_ORDER = ("shingle", "group", "pack", "merge_round", "exchange")


class IterationContext:
    """Mutable scratch shared by one iteration's stages."""

    __slots__ = ("t", "theta", "state", "pg", "ss_groups", "ss_merge",
                 "shingle_fn", "groups", "group_children", "group_seeds",
                 "plans", "thunks", "merges")

    def __init__(self, t: int, theta: float, state, pg):
        self.t = t
        self.theta = theta
        self.state = state
        self.pg = pg
        self.shingle_fn = None
        self.groups = []
        self.group_children = []
        self.group_seeds = np.zeros(0, dtype=np.uint64)
        self.plans = []
        self.thunks = []
        self.merges = 0


class SummarizerEngine:
    """Configured, reusable SLUGGER driver.

    Parameters mirror `summarize()` plus:

    * ``partitions`` — number of node-ownership shards; ``1`` is the
      monolithic special case and the semantics never depend on the value.
    * ``workers`` — threads for the merge_round stage (record-mode sweeps
      are pure local array work, so they parallelize safely). Defaults to
      ``min(partitions, cpu count)``.
    * ``mesh`` — a jax mesh for the multi-device shingle/intersection
      dispatch (``backend="batched"``) and the resident arena placement
      (``backend="resident"``). ``None`` auto-enables when more than one
      device is visible.
    * ``stages`` — dict overriding any of the five stage callables (each
      called as ``fn(engine, ctx)``).
    """

    def __init__(self, partitions: int = 1, backend: str = "numpy",
                 T: int = 20, seed: int = 0, max_group: int = 500,
                 top_j: int = 16, height_bound=None, prune_steps=(1, 2, 3),
                 workers: int | None = None, mesh=None, stages: dict | None = None):
        if backend not in ("numpy", "batched", "loop", "resident"):
            raise ValueError(
                f"unknown backend {backend!r}; use 'numpy', 'batched', "
                f"'resident' or 'loop'")
        if partitions < 1:
            raise ValueError("partitions must be >= 1")
        self.partitions = int(partitions)
        self.backend = backend
        self.T = int(T)
        self.seed = seed
        self.max_group = max_group
        self.top_j = top_j
        self.height_bound = height_bound
        self.prune_steps = tuple(prune_steps)
        self.workers = (min(self.partitions, os.cpu_count() or 1)
                        if workers is None else max(1, int(workers)))
        self.mesh = mesh
        self.stages = {name: getattr(type(self), f"stage_{name}")
                       for name in STAGE_ORDER}
        if stages:
            unknown = set(stages) - set(STAGE_ORDER)
            if unknown:
                raise ValueError(f"unknown stages {sorted(unknown)}; "
                                 f"valid: {STAGE_ORDER}")
            self.stages.update(stages)
        self.stats: dict = {}
        self._shingle_provider = None
        self._rank_dispatch = None
        self._resident_factory = None
        self._run_ctx = None

    # ------------------------------------------------------------- plumbing
    def _mesh_active(self):
        """Resolve the mesh for the multi-device dispatches (or None)."""
        if self.backend not in ("batched", "resident"):
            return None
        if self.mesh is not None:
            return self.mesh
        try:
            import jax
            if jax.device_count() > 1:
                from repro.launch.mesh import make_data_mesh
                return make_data_mesh()
        except Exception:  # jax unavailable/misconfigured: host path
            return None
        return None

    def _setup_dispatches(self, g):
        """Wire the distributed/resident device paths for this run."""
        self._shingle_provider = None
        self._rank_dispatch = None
        self._resident_factory = None
        self._run_ctx = None
        mesh = self._mesh_active()
        if self.backend == "resident":
            from repro.core.resident import ResidentBitmapArena

            def factory(ws, _mesh=mesh, _j=self.top_j):
                rc = self._run_ctx
                if _mesh is None and rc is not None and rc.bank is not None:
                    # bank path: the chunk state EXTRACTS on device from the
                    # resident adjacency bank — ws is a shape-only shell.
                    # Extraction failures surface as BankFault so the stage
                    # loop can degrade to host-rebuilt workspaces (§11) —
                    # the shell ws carries no tensors, so a plain retry
                    # against it would read garbage.
                    try:
                        return ResidentBitmapArena.from_bank(
                            rc.bank, ws, rc._res_map, top_j=_j)
                    except Exception as e:
                        raise faults.BankFault(
                            f"bank extract failed: {e!r}") from e
                return ResidentBitmapArena.from_workspace(ws, top_j=_j,
                                                          mesh=_mesh)
            self._resident_factory = factory
        if mesh is None:
            # Single device: every backend shingles with the unified u32
            # family so the cross-backend bit-identity contract covers
            # candidate generation. The resident backend computes them ON
            # DEVICE from its run context (edges uploaded once, root map
            # advanced by plan replay); the others use the NumPy twin.
            if self.backend == "resident":
                try:
                    from repro.core.resident import ResidentRunContext
                    self._run_ctx = ResidentRunContext(g, bank=True)
                    self._shingle_provider = self._run_ctx.for_roots
                except Exception:  # jax unavailable: host twin, same bits
                    self._run_ctx = None
            if self._shingle_provider is None:
                from repro.core.minhash import host_shingle_provider
                self._shingle_provider = host_shingle_provider(g)
            return
        from repro.core import distributed as D
        self._shingle_provider = D.shingle_provider(g, mesh)
        if self.backend == "batched":
            self._rank_dispatch = D.batched_intersections_mesh(mesh)

    # --------------------------------------------------------------- stages
    def stage_shingle(self, ctx: IterationContext):
        """Prepare this iteration's shingle provider (host segment-min by
        default; mesh-sharded `shard_map` dispatch on the multi-device
        batched path). The provider is consumed by the group stage, which
        owns the rehash loop."""
        if self._shingle_provider is not None:
            ctx.shingle_fn = self._shingle_provider(ctx.state.root_of)

    def stage_group(self, ctx: IterationContext):
        """Global candidate generation + per-group RNG stream spawning."""
        state = ctx.state
        ctx.groups = candidate_groups(
            state.g, state.root_of, state.alive, seed=ctx.ss_groups,
            max_group=self.max_group, shingle_fn=ctx.shingle_fn)
        if ctx.groups:
            ctx.group_children = ctx.ss_merge.spawn(len(ctx.groups))
            ctx.group_seeds = np.array(
                [c.generate_state(1, dtype=np.uint64)[0]
                 for c in ctx.group_children], dtype=np.uint64)

    def stage_pack(self, ctx: IterationContext):
        """Assign groups to partitions by node ownership and build their
        record-mode workspaces against the iteration-start snapshot."""
        groups = ctx.groups
        ctx.plans = [None] * len(groups)
        ctx.thunks = []
        if not groups:
            return
        part_of_group = self._group_partitions(ctx)
        shell = (self.backend == "resident" and self._run_ctx is not None
                 and getattr(self._run_ctx, "bank", None) is not None)
        for p in np.unique(part_of_group):
            idxs = np.flatnonzero(part_of_group == p)
            plans_p, thunks_p = build_merge_work(
                ctx.state, [groups[i] for i in idxs], ctx.theta,
                group_seeds=ctx.group_seeds[idxs],
                rng_of=lambda li, idxs=idxs: np.random.default_rng(
                    ctx.group_children[idxs[li]]),
                top_j=self.top_j, height_bound=self.height_bound,
                backend=self.backend, rank_dispatch=self._rank_dispatch,
                resident_factory=self._resident_factory,
                shell_workspaces=shell)
            for li, gi in enumerate(idxs):
                ctx.plans[int(gi)] = plans_p[li]
            ctx.thunks.extend(thunks_p)

    def stage_merge_round(self, ctx: IterationContext):
        """Run the shard-local sweeps — serial or thread-parallel; record
        mode makes the schedule irrelevant to the outcome."""
        if self.workers > 1 and len(ctx.thunks) > 1:
            with ThreadPoolExecutor(max_workers=self.workers) as pool:
                list(pool.map(lambda f: f(), ctx.thunks))
        else:
            for thunk in ctx.thunks:
                thunk()

    def stage_exchange(self, ctx: IterationContext):
        """Replay all recorded merge rounds against the global state in
        canonical group order — the only cross-partition communication.
        Under the single-device resident backend the applied (A, Z, M)
        batches also feed the run context, which replays them against its
        device root map (plan-driven carry — the map never re-uploads)."""
        ctx.merges = self._replay_plans(ctx.state, ctx.plans)

    def _replay_plans(self, state, plans: list) -> int:
        """Apply recorded plans to the global state — shared by the
        exchange stage and checkpoint-resume replay. A live resident run
        context rides along on the applied (A, Z, M) batches; if its bank
        advance fails the GLOBAL state is already correct (plans applied
        first), so the run degrades to the host workspace path and keeps
        going instead of crashing."""
        if self._run_ctx is not None:
            batches: list = []
            # row_len[M] is pristine exactly at the on_batch hook — the bank
            # carry needs the minted rows' unique-external counts
            merges = apply_plans(
                state, plans,
                on_batch=lambda A, Z, M: batches.append(
                    (A, Z, M, state.row_len[M].copy())))
            try:
                self._run_ctx.advance(batches)
            except Exception as e:
                self._degrade_to_host(state, "resident.bank.advance", e)
            return merges
        return apply_plans(state, plans)

    def _degrade_to_host(self, state, site: str, exc) -> None:
        """§11 degradation policy: drop the resident run context (bank,
        device root map, device shingles) and finish the run on the
        host-rebuilt workspace path — bit-identical by the unified-u32
        shingle/ranking contract, just slower. Counted in
        ``stats["degradations"]`` via the global ledger."""
        faults.DEGRADATIONS.record(site, exc)
        log.warning("degrading to host workspace path after %s fault: %r",
                    site, exc)
        self._run_ctx = None
        from repro.core.minhash import host_shingle_provider
        self._shingle_provider = host_shingle_provider(state.g)

    def _group_partitions(self, ctx: IterationContext) -> np.ndarray:
        """Partition of each group = owner of its smallest member root's
        smallest leaf (`SluggerState.root_min_leaf`, the same keying the
        partition-aware emission uses; ownership keeps a root's groups
        co-resident with most of its adjacency)."""
        n_groups = len(ctx.groups)
        if self.partitions == 1:
            return np.zeros(n_groups, dtype=np.int64)
        min_leaf = ctx.state.root_min_leaf()
        key_roots = np.array([int(g.min()) for g in ctx.groups],
                             dtype=np.int64)
        return ctx.pg.owner[min_leaf[key_roots]]

    # ------------------------------------------------------------------ run
    def _config(self) -> dict:
        """JSON-safe config snapshot recorded in checkpoints. The
        DECISION_KEYS subset is resume-enforced; backend/partitions are
        informational — replay determinism makes checkpoints portable
        across both (test-enforced in tests/test_checkpoint_resume.py)."""
        height = self.height_bound
        return {
            "T": self.T,
            "seed": int(self.seed),
            "max_group": int(self.max_group),
            "top_j": int(self.top_j),
            "height_bound": None if height is None else int(height),
            "prune_steps": list(self.prune_steps),
            "backend": self.backend,
            "partitions": self.partitions,
        }

    def merge_forest(self, g, checkpoint_dir=None, resume: bool = False,
                     checkpoint_every: int = 1):
        """Run the T merge iterations only; returns ``(state, pg)`` — the
        merge-forest state and the partitioned graph. Per-stage wall
        seconds land in ``self.stats``; the partition-sweep benchmark
        reads the merge phase from there.

        With ``checkpoint_dir`` set, the iteration's applied plan log is
        committed atomically after every ``checkpoint_every``-th iteration
        (`core/checkpoint.PlanCheckpointer`); ``resume=True`` replays the
        newest committed log and continues from the next iteration — the
        resumed summary is bit-identical to an uninterrupted run on every
        backend and partition count (DESIGN.md §11)."""
        from repro.core.transfer import GLOBAL as TRANSFER

        pg = as_partitioned(g, self.partitions)
        state = SluggerState(pg.to_graph())
        transfer0 = TRANSFER.snapshot()  # before setup: run-context init counts
        self._setup_dispatches(state.g)
        self.stats = {name: 0.0 for name in STAGE_ORDER}
        self.stats["merges"] = 0
        self.stats["checkpoint"] = 0.0
        deg_mark = faults.DEGRADATIONS.count()
        transfer_prev = transfer0
        self.stats["transfer_iters"] = []
        ckpt = None
        fingerprint = None
        plan_log: list = []
        t_start = 1
        if checkpoint_dir is not None:
            from repro.core.checkpoint import PlanCheckpointer, \
                graph_fingerprint
            fingerprint = graph_fingerprint(state.g)
            ckpt = PlanCheckpointer(checkpoint_dir)
            if resume:
                loaded = ckpt.load_latest(fingerprint, self._config())
                if loaded is not None:
                    t_done, plan_log = loaded
                    t0 = time.perf_counter()
                    for plans in plan_log:
                        self.stats["merges"] += self._replay_plans(state,
                                                                   plans)
                    self.stats["exchange"] += time.perf_counter() - t0
                    t_start = t_done + 1
                    self.stats["resumed_from"] = t_done
                    log.info("resumed from checkpoint at iter %d (%d plans "
                             "replayed)", t_done,
                             sum(len(p) for p in plan_log))
        iter_streams = np.random.SeedSequence(self.seed).spawn(max(self.T, 1))
        for t in range(t_start, self.T + 1):
            theta = 0.0 if t == self.T else 1.0 / (1 + t)
            ctx = IterationContext(t, theta, state, pg)
            ctx.ss_groups, ctx.ss_merge = iter_streams[t - 1].spawn(2)
            for name in STAGE_ORDER:
                t0 = time.perf_counter()
                try:
                    self.stages[name](self, ctx)
                except faults.BankFault as e:
                    # bank extraction died mid-stage: plans/thunks built
                    # against the bank are shells — degrade, then rebuild
                    # pack onward against the same iteration-start snapshot
                    # and spawned streams (pure functions → identical
                    # decisions, DESIGN.md §11)
                    self._degrade_to_host(ctx.state,
                                          "resident.bank.extract", e)
                    self.stages["pack"](self, ctx)
                    if name == "merge_round":
                        self.stages["merge_round"](self, ctx)
                self.stats[name] += time.perf_counter() - t0
                faults.check(f"engine.{name}", iteration=t)
            self.stats["merges"] += ctx.merges
            if ckpt is not None:
                plan_log.append(ctx.plans)
                if t % max(1, checkpoint_every) == 0 or t == self.T:
                    t0 = time.perf_counter()
                    ckpt.save(t, plan_log, fingerprint, self._config())
                    self.stats["checkpoint"] += time.perf_counter() - t0
            snap = TRANSFER.snapshot()
            self.stats["transfer_iters"].append(
                TRANSFER.delta_since(transfer_prev, now=snap))
            transfer_prev = snap
            log.info(
                "iter %3d: θ=%.3f groups=%d merges=%d roots=%d parts=%d",
                t, theta, len(ctx.groups), ctx.merges, state.alive.size,
                self.partitions)
        self.stats["transfer"] = TRANSFER.delta_since(transfer0)
        self.stats["degradations"] = faults.DEGRADATIONS.count() - deg_mark
        return state, pg

    def run(self, g, checkpoint_dir=None, resume: bool = False,
            checkpoint_every: int = 1):
        """Summarize end to end; returns the (pruned) `Summary`."""
        state, pg = self.merge_forest(g, checkpoint_dir=checkpoint_dir,
                                      resume=resume,
                                      checkpoint_every=checkpoint_every)
        owner = pg.owner if self.partitions > 1 else None
        t0 = time.perf_counter()
        summary = _emit_encoding(state, backend=self.backend, owner=owner)
        self.stats["emit"] = time.perf_counter() - t0
        if self.prune_steps:
            t0 = time.perf_counter()
            summary = prune(summary, steps=self.prune_steps,
                            partition_map=owner)
            self.stats["prune"] = time.perf_counter() - t0
        return summary
