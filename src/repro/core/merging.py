"""Merging step (Algorithm 2): greedy in-group merging by Saving (Eq. 8).

Two engines share the group-local dense view (`GroupWorkspace`):

* `process_group` — the original sequential loop: pick a random root A, rank
  partners by packed-bitmap Jaccard, evaluate the exact Saving for the top-J,
  merge when ``Saving(A, B) ≥ θ(t)``. Kept as the benchmark baseline.

* `process_groups` — the batched group-merge engine (DESIGN.md §3/§9):
  groups are size-bucketed, their neighbor bitmaps packed into one
  ``(B, G, W)`` uint32 batch, and every round's candidate ranking comes
  from the CURRENT bitmaps through a pluggable rank source — a chunked
  NumPy popcount (``backend="numpy"``), the Pallas/mesh intersection
  dispatch (``backend="batched"``), or the device-resident fused top-J of
  `core/resident.py` (``backend="resident"``). Ranking uses the quantized
  integer Jaccard key (`rank_keys`) so every source orders candidates
  bit-identically; each group then runs vectorized Algorithm-2 sweeps:
  every dirty row's top-J partners are scored by the exact Saving in one
  array op, and a conflict-free random subset of the proposed mergers is
  applied per round.

The Saving is the flat 2-level cost estimate SWEG uses; the hierarchy's
benefit is realized by the optimal encoding DP at emission time, which also
makes every engine lossless by construction regardless of merge order.
"""
from __future__ import annotations

import logging

import numpy as np

from repro.core.bitops import popcount


def _pair_cost(cnt, poss):
    """min(cnt, poss − cnt + 1), which is 0 at cnt == 0 (vectorized).

    Valid inputs satisfy 0 ≤ cnt ≤ poss, so poss − cnt + 1 ≥ 1 and the
    single `minimum` already lands on 0 for absent pairs — no mask needed.
    """
    return np.minimum(cnt, poss - cnt + 1)


# ---------------------------------------------------------------------------
# Integer-exact Saving contract (DESIGN.md §9)
#
# The batched sweep evaluates Savings as exact integer rationals so the host
# and the device-resident round op (`kernels/bitset_fold`, int32/uint32 limb
# arithmetic — x64 stays disabled on device) agree BIT-FOR-BIT:
#   * "possible pairs" terms are clamped at C_CLAMP with expressions that
#     equal min(product, C_CLAMP) exactly on both sides; the workspace build
#     guards that real costs stay far below the clamp (exactness, not just
#     agreement — see `BatchedGroupWorkspace._fill`);
#   * the Saving-vs-best comparison is the cross-product n_j·d_b < n_b·d_j
#     (int64 here; 32-bit limbs on device), strict so ranked ties keep the
#     earlier candidate;
#   * θ is quantized to θ̂ = P/2^THETA_SHIFT and accepted by the integer
#     inequality (d − n)·2^20 ≥ P·d. θ = 0 → P = 0 accepts Saving ≥ 0, so
#     the final iteration is exact.
# `kernels/bitset_fold/ref.py` holds the device twins of these helpers; a
# test pins the two constant pairs to each other.
# ---------------------------------------------------------------------------
C_CLAMP = 1 << 30
THETA_SHIFT = 20


def theta_to_p(theta: float) -> int:
    """Quantize θ to the integer acceptance parameter P (host and device
    apply the SAME P, so the quantization never splits backends)."""
    import math

    p = int(math.ceil(float(theta) * (1 << THETA_SHIFT)))
    return min(max(p, 0), 1 << THETA_SHIFT)


def theta_accept_host(numer, denom, theta_p: int):
    """Saving ≥ θ̂ as the exact integer test (int64 twin of
    `bitset_fold.ref.theta_accept`). numer/denom < 2^31, so the products
    stay below 2^51."""
    numer = np.asarray(numer, dtype=np.int64)
    denom = np.asarray(denom, dtype=np.int64)
    return ((denom > 0) & (numer <= denom)
            & ((denom - numer) << THETA_SHIFT >= np.int64(theta_p) * denom))


def poss_pair_i(s, colsize):
    """min(s·colsize, C_CLAMP) in int64 — value-identical to the device's
    division-guarded where-expression (`bitset_fold.ref.poss_pair_c`)."""
    return np.minimum(np.asarray(s, dtype=np.int64)
                      * np.asarray(colsize, dtype=np.int64), C_CLAMP)


def poss_self_i(s):
    """min(s·(s−1)/2, C_CLAMP) in int64 (s·(s−1) is always even)."""
    s = np.asarray(s, dtype=np.int64)
    return np.minimum(s * (s - 1) // 2, C_CLAMP)


# ---------------------------------------------------------------------------
# Candidate ranking: quantized integer Jaccard keys (DESIGN.md §9)
# ---------------------------------------------------------------------------
_RANK_KEY_BITS = 15


def _bit_length(v: np.ndarray) -> np.ndarray:
    """Elementwise bit length of non-negative ints < 2^31 — the 5-step
    binary search mirrored bit-for-bit by `kernels/bitset_fold/ref.py`."""
    b = np.zeros_like(v)
    for s in (16, 8, 4, 2, 1):
        t = v >> s
        big = t > 0
        b += np.where(big, s, 0)
        v = np.where(big, t, v)
    return b + (v > 0)


def rank_keys(inter: np.ndarray, deg_r, deg_c) -> np.ndarray:
    """Quantized-Jaccard integer ranking keys in ``[0, 2^15]``.

    Shift intersection and union down together until the union fits 15
    bits, then take the exact integer quotient — shift and integer-divide
    only, so NumPy here, XLA, and the Pallas kernels produce the SAME key
    for the same bitmaps (no float division whose rounding could differ
    across backends). Ranking is (key desc, column asc): the quantization
    only coarsens which near-equal candidates tie; the tie-break keeps the
    order total and deterministic, which is what the cross-backend
    bit-identity needs (DESIGN.md §9).
    """
    inter = inter.astype(np.int64)
    union = np.asarray(deg_r + deg_c - inter, dtype=np.int64)
    sh = np.maximum(0, _bit_length(union) - _RANK_KEY_BITS)
    return ((inter >> sh) << _RANK_KEY_BITS) // np.maximum(union >> sh, 1)


def _row_intersections(bits: np.ndarray, rb: np.ndarray,
                       rr: np.ndarray) -> np.ndarray:
    """(n, G) intersection popcounts of rows (rb[i], rr[i]) against every
    column row of their group, chunked so the (chunk, G, W) temp stays
    within the memory budget."""
    n = rb.size
    _, G, W = bits.shape
    out = np.empty((n, G), dtype=np.int64)
    chunk = max(1, int(_MEM_BUDGET // max(1, G * W * 8)))
    for s0 in range(0, n, chunk):
        gb = rb[s0:s0 + chunk]
        rows = bits[gb, rr[s0:s0 + chunk]]
        out[s0:s0 + chunk] = popcount(
            rows[:, None, :] & bits[gb]).sum(axis=-1, dtype=np.int64)
    return out


# ---------------------------------------------------------------------------
# Shard-local merge plans (DESIGN.md §8)
# ---------------------------------------------------------------------------
class MergePlan:
    """Ordered merge decisions of ONE candidate group, recorded shard-local.

    ``rounds[r] = (a_rows, z_rows)`` are disjoint local row pairs (indices
    into ``members0``, the row → global-root map at build time); a pair in
    round r+1 may reference a row merged in rounds ≤ r. Recording instead of
    mutating the global state is what makes partition-parallel sweeps safe:
    workspaces decide everything locally, and `apply_plans` replays all
    groups' rounds against `SluggerState` in ONE canonical order — so the
    minted parent ids (and therefore the summary) are bit-identical however
    the groups were sharded or scheduled.
    """

    __slots__ = ("members0", "rounds")

    def __init__(self, members0: np.ndarray):
        self.members0 = np.asarray(members0, dtype=np.int64)
        self.rounds: list = []

    def record(self, a_rows: np.ndarray, z_rows: np.ndarray):
        self.rounds.append((np.asarray(a_rows, dtype=np.int64).copy(),
                            np.asarray(z_rows, dtype=np.int64).copy()))

    @property
    def n_merges(self) -> int:
        return sum(a.size for a, _ in self.rounds)

    # -- checkpoint serialization (core/checkpoint.py) ---------------------
    def to_state(self) -> dict:
        """Plain-dict form for the plan-log checkpoint — decoupled from the
        class layout so the on-disk format is versioned independently."""
        return {"members0": self.members0,
                "rounds": [(a, z) for a, z in self.rounds]}

    @classmethod
    def from_state(cls, state: dict) -> "MergePlan":
        plan = cls(state["members0"])
        for a, z in state["rounds"]:
            plan.rounds.append((np.asarray(a, dtype=np.int64),
                                np.asarray(z, dtype=np.int64)))
        return plan


def apply_plans(state, plans: list, on_batch=None) -> int:
    """Exchange stage: replay recorded merge rounds in canonical order.

    Round r applies every group's r-th recorded round in plan-list order via
    ONE ``merge_batch`` — all pairs are disjoint (rounds are matchings and
    candidate groups partition the alive roots). Only the forward/root
    pointers and freshly minted parents flow back; the decisions themselves
    never re-read global state, so the replay is scheduling-independent.
    Returns the number of merges applied.

    ``on_batch(A, Z, M)`` (optional) observes each applied round: the
    resolved global ids merged (A absorbs Z) and the minted parent ids M —
    the resident run context replays exactly these against its device maps
    (`core/resident.ResidentRunContext.advance`).
    """
    cur = [p.members0.copy() for p in plans]
    merges = 0
    r = 0
    while True:
        As, Zs, backrefs = [], [], []
        for gi, p in enumerate(plans):
            if r < len(p.rounds):
                a_rows, z_rows = p.rounds[r]
                As.append(cur[gi][a_rows])
                Zs.append(cur[gi][z_rows])
                backrefs.append((gi, a_rows))
        if not As:
            break
        A = np.concatenate(As)
        Z = np.concatenate(Zs)
        M = state.merge_batch(A, Z)
        if on_batch is not None:
            on_batch(A, Z, M)
        off = 0
        for gi, a_rows in backrefs:
            cur[gi][a_rows] = M[off:off + a_rows.size]
            off += a_rows.size
        merges += M.size
        r += 1
    return merges


def _mix64(seed: np.ndarray, round_no: int, rows: np.ndarray) -> np.ndarray:
    """Counter-based per-proposal priority: splitmix64 of (group seed, round,
    proposing row), with the row id appended in the low bits so priorities
    are UNIQUE within a group — randomized-priority matching then never ties,
    and the outcome is a pure function of (group, round, row), independent of
    how groups were chunked or sharded."""
    round_mix = np.uint64(((round_no + 1) * 0x9E3779B97F4A7C15) & (2**64 - 1))
    x = seed.astype(np.uint64) ^ round_mix
    x = x + rows.astype(np.uint64) * np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(30)
    x *= np.uint64(0xBF58476D1CE4E5B9)
    x ^= x >> np.uint64(27)
    x *= np.uint64(0x94D049BB133111EB)
    x ^= x >> np.uint64(31)
    return (x << np.uint64(8)) | rows.astype(np.uint64)  # rows < 256 = 2*G_max


class GroupWorkspace:
    """Dense group-local view: rows = group members, cols = neighbor roots.

    Construction is one `state.gather_rows` + `np.unique` — no Python loops
    over adjacency. Columns are the union of the members and their (resolved)
    neighbor roots, in sorted-id order; members always own a column.
    """

    def __init__(self, state, group, plan: MergePlan | None = None):
        self.state = state
        self.plan = plan  # record-mode: decisions go here, not to `state`
        members = np.asarray(group, dtype=np.int64)
        k = members.size
        self.members = members.tolist()  # global root ids (updated on merge)
        seg, nbr, cnt = state.gather_rows(members)
        ids = np.concatenate([members, nbr])
        uniq, inv = np.unique(ids, return_inverse=True)
        R = uniq.size
        self.col_gid = uniq.astype(np.int64)
        self.colid = {int(gid): j for j, gid in enumerate(uniq)}
        self.memcol = inv[:k].astype(np.int64)
        colidx = inv[k:].astype(np.int64)
        # exact edge counts are integers; int64 keeps the float-free storage
        # while `savings()` still evaluates in float64 (all values < 2^53,
        # so the sequential decisions are unchanged)
        self.CNT = np.zeros((k, R), dtype=np.int64)
        self.CNT[seg, colidx] = cnt
        self.s = state.size[members].astype(np.int64)
        self.colsize = state.size[self.col_gid].astype(np.int64)
        self.selfc = state.selfcnt[members].astype(np.int64)
        self.nd = state.ndesc[members].astype(np.int64)
        self.hgt = state.height[members].astype(np.int64)
        self.alive = np.ones(k, dtype=bool)
        # packed bitmaps over columns for Jaccard ranking
        W = (R + 63) // 64
        self.bits = np.zeros((k, max(W, 1)), dtype=np.uint64)
        if colidx.size:
            np.bitwise_or.at(
                self.bits, (seg, colidx >> 6),
                np.uint64(1) << (colidx & 63).astype(np.uint64),
            )
        self.cost_row = self._full_cost_rows()

    # -- cost bookkeeping --------------------------------------------------
    def _row_pair_costs(self, rows):
        cnt = self.CNT[rows]
        poss = self.s[rows, None] * self.colsize[None, :]
        c = _pair_cost(cnt, poss)
        # self/own columns never contribute (cnt to self column is 0 anyway)
        return c

    def _full_cost_rows(self):
        c = self._row_pair_costs(np.arange(len(self.members)))
        out = c.sum(axis=1)
        out += _pair_cost(self.selfc, self.s * (self.s - 1) // 2)
        out += self.nd
        return out

    def _recompute_row(self, i: int):
        c = _pair_cost(self.CNT[i], self.s[i] * self.colsize)
        poss_self = self.s[i] * (self.s[i] - 1) // 2
        self.cost_row[i] = c.sum() + _pair_cost(np.array([self.selfc[i]]), np.array([poss_self]))[0] + self.nd[i]

    # -- partner ranking -----------------------------------------------------
    def rank_to(self, a: int, cand: np.ndarray) -> np.ndarray:
        """Quantized integer Jaccard ranking keys of `cand` against row `a`
        (same `rank_keys` contract the batched/resident rankers use — no
        float division anywhere in the decision path)."""
        inter = popcount(self.bits[a][None, :] & self.bits[cand]).sum(axis=1, dtype=np.int64)
        da = popcount(self.bits[a]).sum(dtype=np.int64)
        dz = popcount(self.bits[cand]).sum(axis=1, dtype=np.int64)
        return rank_keys(inter, da, dz)

    # -- exact Saving (Eq. 8) -------------------------------------------------
    def saving_terms(self, a: int, cand: np.ndarray, height_bound=None):
        """Integer Saving terms ``(numer, denom, valid)`` with
        ``Saving = 1 − numer/denom``: the sequential twin of
        `BatchedGroupWorkspace.saving_terms_rows`. Everything stays int64
        (no C_CLAMP here — the dense view never squares group sizes past
        the arena bound), so sweeps can compare Savings as exact rationals."""
        merged = self.CNT[a][None, :] + self.CNT[cand]
        s_m = self.s[a] + self.s[cand]
        poss = s_m[:, None] * self.colsize[None, :]
        cost_cols = _pair_cost(merged, poss)
        ca, cz = self.memcol[a], self.memcol[cand]
        # edges to A or Z become internal to the merged node
        total = cost_cols.sum(axis=1) - cost_cols[:, ca] - cost_cols[np.arange(len(cand)), cz]
        cab = self.CNT[a, cz]
        self_m = self.selfc[a] + self.selfc[cand] + cab
        poss_self = s_m * (s_m - 1) // 2
        total += _pair_cost(self_m, poss_self)
        numer = total + self.nd[a] + self.nd[cand] + 2
        pair_c = _pair_cost(cab, self.s[a] * self.s[cand])
        denom = self.cost_row[a] + self.cost_row[cand] - pair_c
        valid = denom > 0
        if height_bound is not None:
            new_h = np.maximum(self.hgt[a], self.hgt[cand]) + 1
            valid &= new_h <= height_bound
        return numer.astype(np.int64), denom.astype(np.int64), valid

    def savings(self, a: int, cand: np.ndarray, height_bound=None) -> np.ndarray:
        """Float VIEW of `saving_terms` (diagnostics and the approximate
        `distributed.summarize_jax` engine); no exact decision reads it."""
        numer, denom, valid = self.saving_terms(a, cand, height_bound)
        sav = np.where(  # lint: disable=INT-RANK-ONLY -- float view of the integer terms; exact sweeps compare saving_terms rationals instead
            valid, 1.0 - numer / np.maximum(denom, 1), -np.inf)
        return sav

    # -- merge ---------------------------------------------------------------
    def merge(self, a: int, z: int):
        """Merge member z into member a (global state merge + local update)."""
        st = self.state
        ca, cz = int(self.memcol[a]), int(self.memcol[z])
        s_new = self.s[a] + self.s[z]
        # contributions of columns ca/cz to every row's cost, before update
        old_ca = _pair_cost(self.CNT[:, ca], self.s * self.colsize[ca])
        old_cz = _pair_cost(self.CNT[:, cz], self.s * self.colsize[cz])
        cab = self.CNT[a, cz]
        # global merge — or, in record mode, defer it to `apply_plans`
        if self.plan is not None:
            self.plan.record(np.array([a]), np.array([z]))
            m_gid = -1
        else:
            m_gid = st.merge(int(self.members[a]), int(self.members[z]))
            self.colid[m_gid] = ca
        self.members[a] = m_gid
        self.col_gid[ca] = m_gid
        # local rows
        self.CNT[a] += self.CNT[z]
        self.CNT[z] = 0
        # local columns
        self.CNT[:, ca] += self.CNT[:, cz]
        self.CNT[:, cz] = 0
        self.CNT[a, ca] = 0
        self.colsize[ca] = s_new
        self.colsize[cz] = 0
        self.selfc[a] = self.selfc[a] + self.selfc[z] + cab
        self.nd[a] = self.nd[a] + self.nd[z] + 2
        self.hgt[a] = max(self.hgt[a], self.hgt[z]) + 1
        self.s[a] = s_new
        self.alive[z] = False
        # bitmaps: fold column cz into ca, then OR rows
        wa, ba = ca >> 6, np.uint64(ca & 63)
        wz, bz = cz >> 6, np.uint64(cz & 63)
        zbit = (self.bits[:, wz] >> bz) & np.uint64(1)
        self.bits[:, wa] |= zbit << ba
        self.bits[:, wz] &= ~(np.uint64(1) << bz)
        self.bits[a] |= self.bits[z]
        self.bits[z] = 0
        # row a has no bit for its own column
        self.bits[a, wa] &= ~(np.uint64(1) << ba)
        # incremental cost updates for all rows (columns ca, cz changed)
        new_ca = _pair_cost(self.CNT[:, ca], self.s * self.colsize[ca])
        self.cost_row += new_ca - old_ca - old_cz
        self._recompute_row(a)


# ---------------------------------------------------------------------------
# Sequential engine (seed baseline)
# ---------------------------------------------------------------------------
def _sweep_sequential(ws: GroupWorkspace, theta: float,
                      rng: np.random.Generator, top_j: int = 16,
                      height_bound=None) -> int:
    """Algorithm 2 over one built workspace. Returns the number of merges.

    Decisions are integer-exact end to end: candidates are ranked by the
    quantized `rank_keys`, the best partner is the exact-rational argmax of
    the `saving_terms` fractions (cross-product compare, strict `<` so ties
    keep the earlier-ranked candidate), and acceptance is the quantized
    θ̂ = P/2^THETA_SHIFT integer inequality — the same contract the batched
    sweep applies, so oversized groups that fall back to this path merge
    identically under every backend.
    """
    k = len(ws.members)
    queue = list(rng.permutation(k))
    theta_p = theta_to_p(theta)
    merges = 0
    while len(queue) > 1:
        a = queue.pop()
        if not ws.alive[a]:
            continue
        cand = np.array([q for q in queue if ws.alive[q]], dtype=np.int64)
        if cand.size == 0:
            break
        if cand.size > top_j:
            keys = ws.rank_to(a, cand)
            cand = cand[np.argsort(-keys, kind="stable")[:top_j]]
        numer, denom, valid = ws.saving_terms(a, cand,
                                              height_bound=height_bound)
        # exact rational argmax of 1 − n/d over the valid candidates:
        # Python ints, so the cross products can't overflow int64
        best = -1
        n_b = d_b = 0
        for j in range(cand.size):
            if not valid[j]:
                continue
            n_j, d_j = int(numer[j]), int(denom[j])
            if best < 0 or n_j * d_b < n_b * d_j:
                best, n_b, d_b = j, n_j, d_j
        if best >= 0 and n_b <= d_b and (
                (d_b - n_b) << THETA_SHIFT) >= theta_p * d_b:
            z = int(cand[best])
            ws.merge(a, z)
            queue = [q for q in queue if q != z]
            queue.insert(0, a)  # merged node rejoins Q (Alg. 2 line 8)
            merges += 1
    return merges


def process_group(
    state,
    group,
    theta: float,
    rng: np.random.Generator,
    top_j: int = 16,
    height_bound=None,
    plan: MergePlan | None = None,
) -> int:
    """Algorithm 2 over one candidate set. Returns the number of merges.

    With ``plan`` given the sweep runs in record mode: decisions land in the
    plan (each as its own single-pair round) instead of mutating ``state``.
    """
    ws = GroupWorkspace(state, group, plan=plan)
    return _sweep_sequential(ws, theta, rng, top_j=top_j,
                             height_bound=height_bound)


# ---------------------------------------------------------------------------
# Batched group-merge engine
# ---------------------------------------------------------------------------
_MEM_BUDGET = 128 << 20  # bound on any (B, G, R)-shaped float64 temporary


class HostRankSource:
    """Per-round candidate ranking over the workspace's host-folded bitmaps.

    ``dispatch`` (optional) computes the (B, G, G) intersection tensor on
    device — the single-device kernel ops or the mesh shard_map dispatch
    (`core/distributed.batched_intersections_mesh`) plug in here; without
    it the intersections come from a chunked host popcount restricted to
    the dirty rows. Either way the integer intersections — and therefore
    the ranked order — are identical.
    """

    needs_host_bits = True    # `apply_merges` must keep folding ws.bits
    needs_host_counts = True  # … and the integer count/cost tensors

    def __init__(self, dispatch=None):
        self.dispatch = dispatch

    def ranked(self, ws, rb, rr, j_max):
        if self.dispatch is not None:
            try:
                inter_all = self.dispatch(ws.bits.view(np.uint32))  # (B, G, G)
            except Exception as e:
                # degrade: the host popcount computes the SAME integer
                # intersections, so ranking (and the summary) is unchanged —
                # drop the dispatch for the rest of this source's life
                from repro import faults
                faults.DEGRADATIONS.record("rank.dispatch", e)
                logging.getLogger("repro.engine").warning(
                    "rank dispatch failed, degrading to host popcount: %r", e)
                self.dispatch = None
        if self.dispatch is not None:
            deg = np.diagonal(inter_all, axis1=1, axis2=2)
            inter = inter_all[rb, rr]
        else:
            deg = popcount(ws.bits).sum(axis=-1, dtype=np.int64)
            inter = _row_intersections(ws.bits, rb, rr)
        keys = rank_keys(inter, deg[rb, rr][:, None], deg[rb])
        keys[~ws.alive[rb]] = -1                   # dead candidates last …
        keys[np.arange(rb.size), rr] = -1          # … along with self
        # deterministic total order: key desc, ties by asc column (stable)
        order = np.argsort(-keys, axis=1, kind="stable")
        return order[:, :j_max]

    def on_merges(self, ws, b, a, z):
        pass  # host bitmaps were folded by apply_merges


class ResidentRankSource:
    """Fused device proposals from a device-resident arena
    (`core/resident.py`): ranking, exact integer Saving and θ̂-acceptance
    all run in one device round op over the arena's resident bitmaps AND
    count tensors — the host copies of both go stale (the sweep never
    reads them again; only `alive`/plan bookkeeping stays host-side, see
    DESIGN.md §9). Per round only (accept, partner) per dirty row crosses
    the boundary down, and the merge instruction list crosses up."""

    needs_host_bits = False
    needs_host_counts = False

    def __init__(self, arena):
        self.arena = arena

    def propose(self, ws, rb, rr, j_max, theta_p, height_bound):
        return self.arena.propose_rows(rb, rr, j_max, theta_p, height_bound)

    def on_merges(self, ws, b, a, z):
        self.arena.fold_counts(b, a, z)


class BatchedGroupWorkspace:
    """All groups of a size bucket as one set of padded tensors.

    B groups of ≤ G members become ``CNT (B, G, R)``, ``bits (B, G, W)``,
    ``cost_row (B, G)`` … where R is the widest per-group column universe in
    the batch. Construction is ONE `state.gather_rows` over every member of
    every group plus one keyed `np.unique` — per-group column spaces are the
    segments of the sorted (group, id) key stream. Merging applies a whole
    round of disjoint pairs at once: local tensors fold with fancy-indexed
    array ops and the global state applies `merge_batch` (DESIGN.md §3).
    """

    def __init__(self, state, B: int, G: int, R: int, shell: bool = False):
        self.state = state
        self.B, self.G, self.R = B, G, R
        self.shell = shell  # shape-only shell: device bank owns the tensors
        self.plans = None  # record mode: per-local-group MergePlan targets
        self.gseed = np.zeros(B, dtype=np.uint64)  # per-group priority seeds
        self.memcol = np.zeros((B, G), dtype=np.int64)
        self.members = np.full((B, G), -1, dtype=np.int64)
        # CNT holds exact subedge counts — int32 (half the old float64
        # footprint, and the dtype the resident arena uploads verbatim);
        # the scalar per-row stats are int64 so host cross-products in the
        # Saving comparison stay exact without widening casts. A SHELL
        # workspace (ISSUE 9 bank path) keeps self.R as the LOGICAL column
        # width but allocates the big per-column tensors zero-width — the
        # resident extraction builds them on device from the adjacency bank.
        Rw = 0 if shell else R
        self.CNT = np.zeros((B, G, Rw), dtype=np.int32)
        self.col_gid = np.full((B, Rw), -1, dtype=np.int64)
        self.colsize = np.zeros((B, Rw), dtype=np.int64)
        self.s = np.zeros((B, G), dtype=np.int64)
        self.selfc = np.zeros((B, G), dtype=np.int64)
        self.nd = np.zeros((B, G), dtype=np.int64)
        self.hgt = np.zeros((B, G), dtype=np.int64)
        self.alive = np.zeros((B, G), dtype=bool)
        self.bits = np.zeros((B, G, max((Rw + 63) // 64, 1)),
                             dtype=np.uint64)
        self.cost_row = np.zeros((B, G), dtype=np.int64)

    def _fill(self, mb, mr, mc, gids, eb, er, ec, ecnt, cb, cc, cgid):
        """Populate the tensors from (member, entry, column) index streams."""
        st = self.state
        self.memcol[mb, mr] = mc
        self.members[mb, mr] = gids
        self.s[mb, mr] = st.size[gids]
        self.selfc[mb, mr] = st.selfcnt[gids]
        self.nd[mb, mr] = st.ndesc[gids]
        self.hgt[mb, mr] = st.height[gids]
        self.alive[mb, mr] = True
        if self.shell:
            # the bank extraction rebuilds CNT/bits/colsize/cost on device;
            # the bank's init-time conservation bound subsumes the int32 /
            # C_CLAMP runtime guards below
            return
        if ecnt.size and int(ecnt.max()) >= np.iinfo(np.int32).max:
            raise OverflowError(
                f"subedge count {int(ecnt.max())} exceeds the int32 CNT "
                f"tensor; the batched workspaces cannot represent this graph")
        self.CNT[eb, er, ec] = ecnt
        self.col_gid[cb, cc] = cgid
        self.colsize[cb, cc] = st.size[cgid]
        if ec.size:
            np.bitwise_or.at(
                self.bits, (eb, er, ec >> 6),
                np.uint64(1) << (ec & 63).astype(np.uint64),
            )
        # flat 2-level cost of every row (padding rows cost 0 → proposal
        # invalid), with the CLAMPED possible-pair terms of the integer
        # Saving contract — identical to the device evaluation
        cnt64 = self.CNT.astype(np.int64)
        cost = _pair_cost(cnt64, poss_pair_i(self.s[:, :, None],
                                             self.colsize[:, None, :])).sum(axis=-1)
        cost += _pair_cost(self.selfc, poss_self_i(self.s))
        cost += self.nd
        cost[~self.alive] = 0
        # guard the clamp: decisions stay host/device-identical even AT the
        # clamp, but exactness of the Saving itself needs real costs well
        # below it (and below int32 for the device tensors)
        if cost.size and int(cost.max()) >= C_CLAMP:
            raise OverflowError(
                f"row cost {int(cost.max())} reached the integer-Saving "
                f"clamp C_CLAMP=2^30; the exact-Saving contract no longer "
                f"holds for this graph")
        self.cost_row = cost

    @staticmethod
    def build_bucket(state, groups: list, G: int, plans=None,
                     group_seeds=None, shell: bool = False) -> list:
        """One gather + keyed unique for ALL groups of a size bucket, then
        workspaces chunked so column universes within a chunk are within 2×
        of each other and the (B, G, R) tensors respect the memory budget —
        a narrow group never pays a wide group's padding.

        ``plans``/``group_seeds`` (aligned with ``groups``) switch the
        workspaces to record mode with per-group deterministic priorities."""
        B = len(groups)
        ks = np.array([len(g) for g in groups], dtype=np.int64)
        members_flat = np.concatenate([np.asarray(g, dtype=np.int64) for g in groups])
        grp_of_member = np.repeat(np.arange(B), ks)
        row_in_group = np.arange(members_flat.size) - np.repeat(np.cumsum(ks) - ks, ks)
        seg, nbr, cnt = state.gather_rows(members_flat)
        # per-group column universes: segments of the sorted (group, id) keys
        big = np.int64(state.n_ids + 1)
        keys = np.concatenate([
            grp_of_member * big + members_flat,
            grp_of_member[seg] * big + nbr,
        ])
        uniq, inv = np.unique(keys, return_inverse=True)
        col_grp = (uniq // big).astype(np.int64)
        col_bounds = np.searchsorted(col_grp, np.arange(B + 1))
        R_b = np.diff(col_bounds)
        colidx = inv - col_bounds[col_grp[inv]]
        nm = members_flat.size

        # chunk groups into R-homogeneous, memory-bounded classes
        chunk_of_group = np.zeros(B, dtype=np.int64)
        newb_of_group = np.zeros(B, dtype=np.int64)
        chunks: list = []  # (group_count, Rmax)
        cur_n = cur_first = cur_max = 0
        for g in np.argsort(R_b, kind="stable"):
            r = int(R_b[g])
            if cur_n and ((cur_n + 1) * G * max(cur_max, r) * 8 > _MEM_BUDGET
                          or r > 2 * max(cur_first, 32)):
                chunks.append((cur_n, cur_max))
                cur_n = cur_max = 0
            if cur_n == 0:
                cur_first = r
            chunk_of_group[g] = len(chunks)
            newb_of_group[g] = cur_n
            cur_n += 1
            cur_max = max(cur_max, r)
        if cur_n:
            chunks.append((cur_n, cur_max))

        mem_chunk = chunk_of_group[grp_of_member]
        ent_grp = grp_of_member[seg]
        ent_chunk = chunk_of_group[ent_grp]
        col_chunk = chunk_of_group[col_grp]
        col_pos = np.arange(uniq.size) - col_bounds[col_grp]
        out: list = []
        for ci, (bc, rc) in enumerate(chunks):
            ws = BatchedGroupWorkspace(state, bc, G, max(int(rc), 1),
                                       shell=shell)
            msel = mem_chunk == ci
            esel = ent_chunk == ci
            csel = col_chunk == ci
            ws._fill(
                newb_of_group[grp_of_member[msel]], row_in_group[msel],
                colidx[:nm][msel], members_flat[msel],
                newb_of_group[ent_grp[esel]], row_in_group[seg[esel]],
                colidx[nm:][esel], cnt[esel],
                newb_of_group[col_grp[csel]], col_pos[csel], (uniq % big)[csel],
            )
            gsel = np.flatnonzero(chunk_of_group == ci)
            if group_seeds is not None:
                ws.gseed[newb_of_group[gsel]] = np.asarray(
                    group_seeds, dtype=np.uint64)[gsel]
            if plans is not None:
                pl = [None] * bc
                for gidx in gsel:
                    pl[int(newb_of_group[gidx])] = plans[int(gidx)]
                ws.plans = pl
            out.append(ws)
        return out

    # -- exact Saving (Eq. 8), every alive row's top-J in one op -----------
    def saving_terms_rows(self, rb: np.ndarray, rr: np.ndarray,
                          cands: np.ndarray, height_bound=None):
        """Integer Saving terms of merging row (rb[i], rr[i]) with members
        ``cands[i, j]``: ``(numer, denom, valid)`` int64/(bool), each (n, J),
        where Saving = 1 − numer/denom and ``valid`` masks defined terms
        (denom > 0, height bound respected).

        Exact-integer twin of the device round op
        (`bitset_fold.ref.round_rows`): same clamped possible-pair terms,
        same values. Rows are flat (alive rows only, across all groups of
        the batch); chunked so the (chunk, J, R) temps stay bounded.
        """
        R = self.R
        n, J = cands.shape
        numer_o = np.empty((n, J), dtype=np.int64)
        denom_o = np.empty((n, J), dtype=np.int64)
        valid_o = np.empty((n, J), dtype=bool)
        chunk = max(1, int(_MEM_BUDGET // max(1, J * R * 8 * 4)))
        for s0 in range(0, n, chunk):
            b = rb[s0:s0 + chunk]
            r = rr[s0:s0 + chunk]
            c = cands[s0:s0 + chunk]
            bj = b[:, None]
            cnt_r = self.CNT[b, r].astype(np.int64)                # (m, R)
            merged = cnt_r[:, None, :] + self.CNT[bj, c]           # (m, J, R)
            s_r = self.s[b, r]
            s_c = self.s[bj, c]                                    # (m, J)
            s_m = s_r[:, None] + s_c
            poss = poss_pair_i(s_m[..., None], self.colsize[b][:, None, :])
            cost_cols = _pair_cost(merged, poss)
            ca = self.memcol[b, r]                                 # (m,)
            cz = self.memcol[bj, c]                                # (m, J)
            total = cost_cols.sum(axis=-1)
            total -= np.take_along_axis(
                cost_cols, np.broadcast_to(ca[:, None, None], (b.size, J, 1)), axis=2)[..., 0]
            total -= np.take_along_axis(cost_cols, cz[..., None], axis=2)[..., 0]
            cab = np.take_along_axis(cnt_r, cz, axis=1)            # (m, J)
            self_m = self.selfc[b, r][:, None] + self.selfc[bj, c] + cab
            total += _pair_cost(self_m, poss_self_i(s_m))
            numer = total + self.nd[b, r][:, None] + self.nd[bj, c] + 2
            pair_c = _pair_cost(cab, poss_pair_i(s_r[:, None], s_c))
            denom = self.cost_row[b, r][:, None] + self.cost_row[bj, c] - pair_c
            valid = denom > 0
            if height_bound is not None:
                new_h = np.maximum(self.hgt[b, r][:, None], self.hgt[bj, c]) + 1
                valid &= new_h <= height_bound
            numer_o[s0:s0 + chunk] = numer
            denom_o[s0:s0 + chunk] = denom
            valid_o[s0:s0 + chunk] = valid
        return numer_o, denom_o, valid_o

    def savings_rows(self, rb: np.ndarray, rr: np.ndarray, cands: np.ndarray,
                     height_bound=None) -> np.ndarray:
        """Float view of `saving_terms_rows` (benchmark/diagnostic use; the
        sweep itself compares the integer terms exactly)."""
        numer, denom, valid = self.saving_terms_rows(
            rb, rr, cands, height_bound=height_bound)
        return np.where(  # lint: disable=INT-RANK-ONLY -- float view of the integer terms; the sweep compares saving_terms_rows rationals instead
            valid, 1.0 - numer / np.maximum(denom, 1), -np.inf)

    # -- batched merge application -----------------------------------------
    def apply_merges(self, b: np.ndarray, a: np.ndarray, z: np.ndarray,
                     fold_bits: bool = True, fold_counts: bool = True):
        """Fold row z into row a of group b for a round of disjoint pairs.

        ``fold_bits=False`` skips the host bitmap fold — the resident
        backend folds the DEVICE copy instead (`ResidentRankSource`), and
        nothing in the Saving evaluation reads ``self.bits``.
        ``fold_counts=False`` additionally skips the host count/cost-tensor
        fold (CNT, colsize, sizes, costs): the whole-iteration resident path
        keeps those tensors on device and folds them there
        (`kernels/bitset_fold.fold_counts_fn`) — the host then only tracks
        liveness, membership, and the recorded plan."""
        if b.size == 0:
            return
        G = self.G
        ca = self.memcol[b, a]
        cz = self.memcol[b, z]
        if fold_counts:
            s_new = self.s[b, a] + self.s[b, z]
            old_ca = _pair_cost(self.CNT[b, :, ca],
                                poss_pair_i(self.s[b], self.colsize[b, ca][:, None]))
            old_cz = _pair_cost(self.CNT[b, :, cz],
                                poss_pair_i(self.s[b], self.colsize[b, cz][:, None]))
            cab = self.CNT[b, a, cz].astype(np.int64)
        if self.plans is not None:
            # record mode: one round per group (b arrives sorted ascending)
            head = np.concatenate([[0], np.flatnonzero(b[1:] != b[:-1]) + 1,
                                   [b.size]])
            for s0, e0 in zip(head[:-1], head[1:]):
                self.plans[int(b[s0])].record(a[s0:e0], z[s0:e0])
            Ms = np.full(b.size, -1, dtype=np.int64)
        else:
            Ms = self.state.merge_batch(self.members[b, a], self.members[b, z])
        self.members[b, a] = Ms
        self.members[b, z] = -1
        if not self.shell:
            self.col_gid[b, ca] = Ms
            self.col_gid[b, cz] = -1
        if fold_counts:
            # rows fold, then columns fold
            self.CNT[b, a] += self.CNT[b, z]
            self.CNT[b, z] = 0
            self.CNT[b, :, ca] += self.CNT[b, :, cz]
            self.CNT[b, :, cz] = 0
            self.CNT[b, a, ca] = 0
            self.colsize[b, ca] = s_new
            self.colsize[b, cz] = 0
            self.selfc[b, a] += self.selfc[b, z] + cab
            self.nd[b, a] += self.nd[b, z] + 2
            self.hgt[b, a] = np.maximum(self.hgt[b, a], self.hgt[b, z]) + 1
            self.s[b, a] = s_new
        self.alive[b, z] = False
        if fold_bits:
            # bitmaps: fold column cz into ca for all rows, then OR rows.
            # Two pairs of the SAME group can fold columns living in the
            # same 64-bit word, so the word-level updates must be unbuffered
            # (.at) — plain fancy `|=`/`&=` would clobber one fold with the
            # other.
            one = np.uint64(1)
            wa, ba = (ca >> 6), (ca & 63).astype(np.uint64)
            wz, bz = (cz >> 6), (cz & 63).astype(np.uint64)
            rows = np.broadcast_to(np.arange(G), (b.size, G))
            bcol = np.broadcast_to(b[:, None], (b.size, G))
            zbit = (self.bits[b, :, wz] >> bz[:, None]) & one
            np.bitwise_or.at(
                self.bits,
                (bcol, rows, np.broadcast_to(wa[:, None], (b.size, G))),
                zbit << ba[:, None])
            np.bitwise_and.at(
                self.bits,
                (bcol, rows, np.broadcast_to(wz[:, None], (b.size, G))),
                np.broadcast_to((~(one << bz))[:, None], (b.size, G)))
            np.bitwise_or.at(self.bits, (b, a), self.bits[b, z])
            self.bits[b, z] = 0
            # row a has no bit for its own column
            self.bits[b, a, wa] &= ~(one << ba)
        if not fold_counts:
            return
        # incremental cost update for all rows (columns ca, cz changed) …
        new_ca = _pair_cost(self.CNT[b, :, ca],
                            poss_pair_i(self.s[b], self.colsize[b, ca][:, None]))
        np.add.at(self.cost_row, (b,), new_ca - old_ca - old_cz)
        # … and exact recomputation for the merged rows (absorbed rows die)
        crow = _pair_cost(self.CNT[b, a].astype(np.int64),
                          poss_pair_i(self.s[b, a][:, None], self.colsize[b])).sum(axis=-1)
        crow += _pair_cost(self.selfc[b, a], poss_self_i(self.s[b, a]))
        self.cost_row[b, a] = crow + self.nd[b, a]
        self.cost_row[b, z] = 0

    # -- the sweep ---------------------------------------------------------
    def sweep(self, theta: float, ranker, top_j: int = 16,
              height_bound=None) -> int:
        """Vectorized Algorithm-2 rounds over the whole batch.

        Per round: every DIRTY row's ranked top-J partners — by quantized
        integer Jaccard key over the CURRENT bitmaps, via the pluggable
        ``ranker`` (`HostRankSource` on host/dispatch bitmaps,
        `ResidentRankSource` from the device-resident arena) — are scored
        with the exact Saving in one array op; the proposals are thinned to
        a conflict-free set by randomized-priority matching (a proposal
        wins iff it holds the minimum priority at both endpoints — the
        global minimum always wins, so rounds make progress) and applied in
        one batched fold. The dirty set mirrors the sequential queue: every
        row starts dirty, a row whose best Saving falls below θ leaves it
        for good, a merged survivor re-enters it ("merged node rejoins Q"),
        and a row that lost the matching retries next round.

        Every random choice is a counter-based hash of (group seed, round,
        row), and the candidate ranking is a per-row total order (key desc,
        column asc, dead/self last) recomputed from the round's bitmap
        state, so a group's outcome is a pure function of its own tensors —
        independent of which chunk, partition, thread, or rank source swept
        it (DESIGN.md §8/§9).
        """
        B, G = self.B, self.G
        merges = 0
        dirty = self.alive.copy()
        alive_cnt = self.alive.sum(axis=1)
        theta_p = theta_to_p(theta)
        round_no = 0
        while G > 1 and dirty.any():
            # J adapts to the largest alive group for array sizing; each row
            # is masked to its OWN group's alive count below, so the chunk
            # composition never leaks into a group's candidate set
            j_max = min(top_j, int(alive_cnt.max()) - 1)
            if j_max < 1:
                break
            rb, rr = np.nonzero(dirty)
            if hasattr(ranker, "propose"):
                # fused device proposals: ranking, exact integer Saving and
                # θ̂-acceptance all ran on device — only (accept, partner)
                # per dirty row came back
                prop, best_z = ranker.propose(self, rb, rr, j_max, theta_p,
                                              height_bound)
            else:
                part = ranker.ranked(self, rb, rr, j_max)          # (n, j)
                numer, denom, valid = self.saving_terms_rows(
                    rb, rr, part, height_bound=height_bound)
                j_row = np.minimum(top_j, alive_cnt[rb] - 1)
                valid &= self.alive[rb[:, None], part] & (part != rr[:, None])
                valid &= np.arange(j_max)[None, :] < j_row[:, None]
                # exact rational argmax in ranked order: Saving_j > best ⟺
                # numer_j·denom_best < numer_best·denom_j (strict, so ties
                # keep the earlier-ranked candidate) — the device round op
                # runs the identical comparison in 32-bit limbs
                n_flat = rb.size
                has = np.zeros(n_flat, dtype=bool)
                n_b = np.ones(n_flat, dtype=np.int64)
                d_b = np.ones(n_flat, dtype=np.int64)
                best_z = np.zeros(n_flat, dtype=np.int64)
                for j in range(j_max):
                    take = valid[:, j] & (
                        ~has | (numer[:, j] * d_b < n_b * denom[:, j]))
                    n_b = np.where(take, numer[:, j], n_b)
                    d_b = np.where(take, denom[:, j], d_b)
                    best_z = np.where(take, part[:, j], best_z)
                    has |= take
                prop = has & theta_accept_host(n_b, d_b, theta_p)
            dirty[rb[~prop], rr[~prop]] = False
            if not prop.any():
                break
            gb, ar, zr = rb[prop], rr[prop], best_z[prop]
            # randomized-priority conflict resolution over node keys: a
            # proposal wins iff it holds the min priority at both endpoints;
            # priorities are row-unique, so there are never ties
            p = _mix64(self.gseed[gb], round_no, ar)
            a_key = gb * G + ar
            z_key = gb * G + zr
            winner = np.full(B * G, np.iinfo(np.uint64).max, dtype=np.uint64)
            np.minimum.at(winner, a_key, p)
            np.minimum.at(winner, z_key, p)
            acc = (winner[a_key] == p) & (winner[z_key] == p)
            ab, am, az = gb[acc], ar[acc], zr[acc]
            self.apply_merges(ab, am, az, fold_bits=ranker.needs_host_bits,
                              fold_counts=ranker.needs_host_counts)
            ranker.on_merges(self, ab, am, az)
            # survivors rejoin the queue, absorbed rows leave it; losers of
            # the matching stayed dirty and retry next round
            dirty[ab, az] = False
            dirty[ab, am] = True
            np.subtract.at(alive_cnt, ab, 1)
            merges += ab.size
            round_no += 1
        return merges


_BATCH_MAX_GROUP = 128  # larger groups amortize row-level vectorization alone


def _default_intersections_dispatch():
    """Single-device device path: the Pallas batched intersection ops, or
    None (→ host popcount) when jax is unavailable."""
    try:
        from repro.kernels.bitset_jaccard.ops import (
            batched_pairwise_intersections)
    except ImportError:  # jax unavailable: fall back to the NumPy ranking
        return None
    return batched_pairwise_intersections


def build_merge_work(
    state,
    groups: list,
    theta: float,
    *,
    group_seeds: np.ndarray,
    rng_of=None,
    top_j: int = 16,
    height_bound=None,
    backend: str = "numpy",
    rank_dispatch=None,
    resident_factory=None,
    shell_workspaces: bool = False,
):
    """Build record-mode workspaces for one iteration's candidate groups.

    Returns ``(plans, thunks)``: ``plans[i]`` is group i's `MergePlan`;
    each thunk runs one workspace chunk's (or one large group's) ranking +
    sweep entirely against local tensors and returns its merge count.
    Workspaces are built HERE, against the current state snapshot — builds
    stay serial because `gather_rows` compacts arena rows in place — while
    the returned thunks touch no shared state and may run on any schedule:
    sequentially, per partition, or on a thread pool (DESIGN.md §8).

    ``group_seeds`` are per-group uint64 priority seeds; ``rng_of(i)``
    supplies the queue-permutation generator for groups swept sequentially
    (``backend="loop"`` and oversized groups). ``rank_dispatch`` overrides
    the batched intersection dispatch (mesh sharding);
    ``resident_factory(ws)`` overrides how ``backend="resident"`` builds
    its per-chunk `ResidentBitmapArena` (mesh placement, kernel forcing).
    ``shell_workspaces`` (bank path, ISSUE 9) builds the batched chunks as
    shape-only shells — identical chunking and member layout, but the big
    CNT/bits/colsize tensors never materialize on host because the
    resident factory extracts them on device from the adjacency bank.
    Oversized groups keep their host `GroupWorkspace` sweep either way.
    """
    groups = [np.asarray(g, dtype=np.int64) for g in groups]
    group_seeds = np.asarray(group_seeds, dtype=np.uint64)
    plans = [MergePlan(g) for g in groups]
    if rng_of is None:
        def rng_of(i):
            return np.random.default_rng(group_seeds[i])
    thunks: list = []

    def _make_ranker(ws):
        if backend == "resident":
            factory = resident_factory
            if factory is None:
                from repro.core.resident import ResidentBitmapArena

                def factory(w):
                    return ResidentBitmapArena.from_workspace(w, top_j=top_j)
            return ResidentRankSource(factory(ws))
        if backend == "batched":
            dispatch = rank_dispatch or _default_intersections_dispatch()
            return HostRankSource(dispatch)
        return HostRankSource(None)

    def _seq_thunk(ws, rng):
        return lambda: _sweep_sequential(ws, theta, rng, top_j=top_j,
                                         height_bound=height_bound)

    def _batch_thunk(ws):
        def run():
            # the ranker is built at RUN time: the resident arena's one-time
            # bitmap upload belongs to the merge_round stage, not pack
            return ws.sweep(theta, _make_ranker(ws), top_j=top_j,
                            height_bound=height_bound)
        return run

    buckets: dict = {}
    for i, grp in enumerate(groups):
        if backend == "loop" or grp.size > _BATCH_MAX_GROUP:
            ws = GroupWorkspace(state, grp, plan=plans[i])
            thunks.append(_seq_thunk(ws, rng_of(i)))
            continue
        buckets.setdefault(1 << max(3, int(grp.size - 1).bit_length()),
                           []).append(i)
    for G in sorted(buckets):
        idxs = buckets[G]
        for ws in BatchedGroupWorkspace.build_bucket(
                state, [groups[i] for i in idxs], G,
                plans=[plans[i] for i in idxs],
                group_seeds=group_seeds[idxs],
                shell=shell_workspaces):
            thunks.append(_batch_thunk(ws))
    return plans, thunks


def process_groups(
    state,
    groups: list,
    theta: float,
    rng: np.random.Generator,
    top_j: int = 16,
    height_bound=None,
    backend: str = "numpy",
) -> int:
    """Batched engine: all groups of one iteration, bucketed by size.

    Groups up to ``_BATCH_MAX_GROUP`` members are packed into (B, G, ·)
    tensor batches — that is where one-Python-loop-per-group used to
    dominate. The few larger groups already amortize their array ops over
    wide rows, so they run the sequential per-group sweep.

    All workspaces snapshot the state BEFORE any of this iteration's merges
    (record mode, DESIGN.md §8); merges in one group never touch another
    group's rows (candidate sets partition the alive roots), so the only
    cross-group effect is slightly stale neighbor sizes in the Saving
    estimate — quality-neutral and lossless either way. The recorded plans
    are then replayed in canonical order by `apply_plans`.
    """
    group_seeds = rng.integers(0, np.iinfo(np.int64).max,
                               size=max(len(groups), 1)).astype(np.uint64)
    plans, thunks = build_merge_work(
        state, groups, theta, group_seeds=group_seeds,
        rng_of=lambda i: rng, top_j=top_j, height_bound=height_bound,
        backend=backend)
    for thunk in thunks:
        thunk()
    return apply_plans(state, plans)
