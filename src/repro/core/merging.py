"""Merging step (Algorithm 2): greedy in-group merging by Saving (Eq. 8).

Per candidate set we build dense group-local count matrices once, then run the
paper's loop: pick a random root A, find the best partner B, merge when
``Saving(A, B) ≥ θ(t)``. Partner search is accelerated exactly as the paper
describes ("rapidly and effectively samples promising node pairs"): a packed-
bitmap Jaccard pass ranks partners (this is what `kernels/bitset_jaccard`
computes on TPU), and the exact Saving — flat 2-level cost, the same estimate
SWEG uses; the hierarchy's benefit is realized by the optimal encoding DP at
emission time — is evaluated only for the top-J.
"""
from __future__ import annotations

import numpy as np


def _pair_cost(cnt, poss):
    """min(cnt, poss − cnt + 1) masked at cnt == 0 (vectorized)."""
    return np.where(cnt > 0, np.minimum(cnt, poss - cnt + 1), 0.0)


class GroupWorkspace:
    """Dense group-local view: rows = group members, cols = neighbor roots."""

    def __init__(self, state, group: list):
        self.state = state
        self.members = list(group)  # global root ids (updated in place on merge)
        k = len(group)
        cols: dict = {}
        for r in group:
            cols.setdefault(int(r), len(cols))
        for r in group:
            for c in state.adj[int(r)]:
                cols.setdefault(int(c), len(cols))
        self.colid = cols
        R = len(cols)
        self.col_gid = np.zeros(R, dtype=np.int64)
        for gid, j in cols.items():
            self.col_gid[j] = gid
        self.CNT = np.zeros((k, R), dtype=np.float64)
        for i, r in enumerate(group):
            for c, v in state.adj[int(r)].items():
                self.CNT[i, cols[int(c)]] = v
        self.s = np.array([state.size[int(r)] for r in group], dtype=np.float64)
        self.colsize = np.array([state.size[int(g)] for g in self.col_gid], dtype=np.float64)
        self.selfc = np.array([state.selfcnt[int(r)] for r in group], dtype=np.float64)
        self.nd = np.array([state.ndesc[int(r)] for r in group], dtype=np.float64)
        self.hgt = np.array([state.height[int(r)] for r in group], dtype=np.int64)
        self.memcol = np.array([cols[int(r)] for r in group], dtype=np.int64)
        self.alive = np.ones(k, dtype=bool)
        # packed bitmaps over columns for Jaccard ranking
        W = (R + 63) // 64
        self.bits = np.zeros((k, W), dtype=np.uint64)
        nz = self.CNT > 0
        for i in range(k):
            idx = np.flatnonzero(nz[i])
            np.bitwise_or.at(self.bits[i], idx >> 6, np.uint64(1) << (idx & 63).astype(np.uint64))
        self.cost_row = self._full_cost_rows()

    # -- cost bookkeeping --------------------------------------------------
    def _row_pair_costs(self, rows):
        cnt = self.CNT[rows]
        poss = self.s[rows, None] * self.colsize[None, :]
        c = _pair_cost(cnt, poss)
        # self/own columns never contribute (cnt to self column is 0 anyway)
        return c

    def _full_cost_rows(self):
        k = len(self.members)
        out = np.zeros(k, dtype=np.float64)
        c = self._row_pair_costs(np.arange(k))
        out = c.sum(axis=1)
        poss_self = self.s * (self.s - 1) / 2
        out += _pair_cost(self.selfc, poss_self)
        out += self.nd
        return out

    def _recompute_row(self, i: int):
        c = _pair_cost(self.CNT[i], self.s[i] * self.colsize)
        poss_self = self.s[i] * (self.s[i] - 1) / 2
        self.cost_row[i] = c.sum() + _pair_cost(np.array([self.selfc[i]]), np.array([poss_self]))[0] + self.nd[i]

    # -- partner ranking -----------------------------------------------------
    def jaccard_to(self, a: int, cand: np.ndarray) -> np.ndarray:
        inter = np.bitwise_count(self.bits[a][None, :] & self.bits[cand]).sum(axis=1).astype(np.float64)
        da = np.bitwise_count(self.bits[a]).sum()
        dz = np.bitwise_count(self.bits[cand]).sum(axis=1)
        union = da + dz - inter
        return np.where(union > 0, inter / np.maximum(union, 1), 0.0)

    # -- exact Saving (Eq. 8) -------------------------------------------------
    def savings(self, a: int, cand: np.ndarray, height_bound=None) -> np.ndarray:
        merged = self.CNT[a][None, :] + self.CNT[cand]
        s_m = self.s[a] + self.s[cand]
        poss = s_m[:, None] * self.colsize[None, :]
        cost_cols = _pair_cost(merged, poss)
        ca, cz = self.memcol[a], self.memcol[cand]
        # edges to A or Z become internal to the merged node
        total = cost_cols.sum(axis=1) - cost_cols[:, ca] - cost_cols[np.arange(len(cand)), cz]
        cab = self.CNT[a, cz]
        self_m = self.selfc[a] + self.selfc[cand] + cab
        poss_self = s_m * (s_m - 1) / 2
        total += _pair_cost(self_m, poss_self)
        numer = total + self.nd[a] + self.nd[cand] + 2.0
        pair_c = _pair_cost(cab, self.s[a] * self.s[cand])
        denom = self.cost_row[a] + self.cost_row[cand] - pair_c
        sav = np.where(denom > 0, 1.0 - numer / np.maximum(denom, 1e-12), -np.inf)
        if height_bound is not None:
            new_h = np.maximum(self.hgt[a], self.hgt[cand]) + 1
            sav = np.where(new_h > height_bound, -np.inf, sav)
        return sav

    # -- merge ---------------------------------------------------------------
    def merge(self, a: int, z: int):
        """Merge member z into member a (global state merge + local update)."""
        st = self.state
        ca, cz = int(self.memcol[a]), int(self.memcol[z])
        s_new = self.s[a] + self.s[z]
        # contributions of columns ca/cz to every row's cost, before update
        old_ca = _pair_cost(self.CNT[:, ca], self.s * self.colsize[ca])
        old_cz = _pair_cost(self.CNT[:, cz], self.s * self.colsize[cz])
        cab = self.CNT[a, cz]
        # global merge
        m_gid = st.merge(int(self.members[a]), int(self.members[z]))
        self.members[a] = m_gid
        self.colid[m_gid] = ca
        self.col_gid[ca] = m_gid
        # local rows
        self.CNT[a] += self.CNT[z]
        self.CNT[z] = 0.0
        # local columns
        self.CNT[:, ca] += self.CNT[:, cz]
        self.CNT[:, cz] = 0.0
        self.CNT[a, ca] = 0.0
        self.colsize[ca] = s_new
        self.colsize[cz] = 0.0
        self.selfc[a] = self.selfc[a] + self.selfc[z] + cab
        self.nd[a] = self.nd[a] + self.nd[z] + 2.0
        self.hgt[a] = max(self.hgt[a], self.hgt[z]) + 1
        self.s[a] = s_new
        self.alive[z] = False
        # bitmaps: fold column cz into ca, then OR rows
        wa, ba = ca >> 6, np.uint64(ca & 63)
        wz, bz = cz >> 6, np.uint64(cz & 63)
        zbit = (self.bits[:, wz] >> bz) & np.uint64(1)
        self.bits[:, wa] |= zbit << ba
        self.bits[:, wz] &= ~(np.uint64(1) << bz)
        self.bits[a] |= self.bits[z]
        self.bits[z] = 0
        # row a has no bit for its own column
        self.bits[a, wa] &= ~(np.uint64(1) << ba)
        # incremental cost updates for all rows (columns ca, cz changed)
        new_ca = _pair_cost(self.CNT[:, ca], self.s * self.colsize[ca])
        self.cost_row += new_ca - old_ca - old_cz
        self._recompute_row(a)


def process_group(
    state,
    group: list,
    theta: float,
    rng: np.random.Generator,
    top_j: int = 16,
    height_bound=None,
) -> int:
    """Algorithm 2 over one candidate set. Returns the number of merges."""
    ws = GroupWorkspace(state, group)
    k = len(group)
    queue = list(rng.permutation(k))
    merges = 0
    while len(queue) > 1:
        a = queue.pop()
        if not ws.alive[a]:
            continue
        cand = np.array([q for q in queue if ws.alive[q]], dtype=np.int64)
        if cand.size == 0:
            break
        if cand.size > top_j:
            jac = ws.jaccard_to(a, cand)
            cand = cand[np.argsort(-jac)[:top_j]]
        sav = ws.savings(a, cand, height_bound=height_bound)
        j = int(np.argmax(sav))
        if sav[j] >= theta and np.isfinite(sav[j]):
            z = int(cand[j])
            ws.merge(a, z)
            queue = [q for q in queue if q != z]
            queue.insert(0, a)  # merged node rejoins Q (Alg. 2 line 8)
            merges += 1
    return merges
