"""Flat graph-summarization baselines the paper compares against (Sect. IV-A).

All three produce the *previous* model G̃ = (S, P, C⁺, C⁻) — the height-≤1
special case of our model — and are evaluated with Eq. (11):
(|P| + |C⁺| + |C⁻| + |H*|) / |E| where |H*| counts root→subnode membership
edges of non-singleton supernodes.

  RANDOMIZED  (Navlakha et al., SIGMOD'08): random node, best 2-hop partner
              by flat saving, merge while positive.
  SWEG        (Shin et al., WWW'19): min-hash candidate groups; within each
              group pick a random node, choose the partner by Jaccard
              similarity, merge when SavingFlat ≥ θ(t) = 1/(1+t).
  SAGS-like   (Khan et al.): pure LSH — merge pairs whose signatures collide,
              no saving evaluation (fastest, least concise).

MoSSo (KDD'20) is a *streaming* algorithm; its offline compression rates are
comparable to SWEG's, so SWEG stands in as the strongest flat competitor here
(noted in EXPERIMENTS.md).

The flat summary is represented directly with our `Summary` class (height-1
forest), so Eq. (11) == Eq. (10) and all lossless checks reuse the same code.
"""
from __future__ import annotations

import numpy as np

from repro.core.minhash import candidate_groups
from repro.core.summary import Summary
from repro.graphs.csr import Graph


class _FlatState:
    """Disjoint supernodes over V with root-level counts (flat model)."""

    def __init__(self, g: Graph):
        self.g = g
        n = g.n
        self.root_of = np.arange(n, dtype=np.int64)
        self.members: dict = {u: [u] for u in range(n)}
        self.adj: dict = {u: {int(v): 1 for v in g.neighbors(u)} for u in range(n)}
        self.selfcnt: dict = {u: 0 for u in range(n)}
        self.size: dict = {u: 1 for u in range(n)}
        self.alive: set = set(range(n))

    def cost_of(self, a: int) -> float:
        s = self.size[a]
        c = sum(
            min(v, s * self.size[b] - v + 1) for b, v in self.adj[a].items()
        )
        sc = self.selfcnt[a]
        if sc:
            c += min(sc, s * (s - 1) // 2 - sc + 1)
        return c

    def pair_cost(self, a: int, b: int) -> float:
        v = self.adj[a].get(b, 0)
        return min(v, self.size[a] * self.size[b] - v + 1) if v else 0

    def merged_cost(self, a: int, b: int) -> float:
        sa, sb = self.size[a], self.size[b]
        s = sa + sb
        cnts: dict = dict(self.adj[a])
        for c, v in self.adj[b].items():
            cnts[c] = cnts.get(c, 0) + v
        cab = cnts.pop(a, 0) + cnts.pop(b, 0)
        cost = sum(min(v, s * self.size[c] - v + 1) for c, v in cnts.items() if v)
        sc = self.selfcnt[a] + self.selfcnt[b] + self.adj[a].get(b, 0)
        if sc:
            cost += min(sc, s * (s - 1) // 2 - sc + 1)
        return cost

    def saving(self, a: int, b: int) -> float:
        denom = self.cost_of(a) + self.cost_of(b) - self.pair_cost(a, b)
        if denom <= 0:
            return -np.inf
        return 1.0 - self.merged_cost(a, b) / denom

    def merge(self, a: int, b: int) -> int:
        """Absorb b into a (flat: no new supernode id)."""
        self.members[a].extend(self.members.pop(b))
        self.root_of[np.asarray(self.members[a])] = a
        na, nb = self.adj[a], self.adj.pop(b)
        cab = na.pop(b, 0)
        nb.pop(a, None)
        for c, v in nb.items():
            na[c] = na.get(c, 0) + v
        for c in list(na):
            d = self.adj[c]
            d.pop(b, None)
            d[a] = na[c]
        self.selfcnt[a] = self.selfcnt[a] + self.selfcnt.pop(b) + cab
        self.size[a] = self.size[a] + self.size.pop(b)
        self.alive.discard(b)
        return a

    # ---- flat encoding → Summary ------------------------------------------
    def to_summary(self) -> Summary:
        g = self.g
        n = g.n
        next_id = n
        parent = np.full(n, -1, dtype=np.int64)
        sn_of: dict = {}
        extra_parents: list = []
        for r in self.alive:
            if self.size[r] > 1:
                sid = next_id + len(extra_parents)
                extra_parents.append(-1)
                sn_of[r] = sid
                parent[np.asarray(self.members[r])] = sid
        parent = np.concatenate([parent, np.array(extra_parents, dtype=np.int64)])

        def sid_of(r):
            return sn_of.get(r, r)

        rows = []
        el = g.edge_list()
        ra, rb = self.root_of[el[:, 0]], self.root_of[el[:, 1]]
        # per root pair: choose p-edge + negative corrections, or positives only
        key_pairs: dict = {}
        for (u, v), A, B in zip(el, ra, rb):
            k = (int(min(A, B)), int(max(A, B)))
            key_pairs.setdefault(k, []).append((int(u), int(v)))
        for (A, B), uv in key_pairs.items():
            cnt = len(uv)
            if A == B:
                poss = self.size[A] * (self.size[A] - 1) // 2
            else:
                poss = self.size[A] * self.size[B]
            if poss - cnt + 1 < cnt:  # p-edge + n-corrections
                rows.append((sid_of(A), sid_of(B), 1))
                present = {(min(u, v), max(u, v)) for u, v in uv}
                mem_a, mem_b = self.members[A], self.members[B]
                if A == B:
                    for i, u in enumerate(mem_a):
                        for v in mem_a[i + 1 :]:
                            if (min(u, v), max(u, v)) not in present:
                                rows.append((u, v, -1))
                else:
                    for u in mem_a:
                        for v in mem_b:
                            if (min(u, v), max(u, v)) not in present:
                                rows.append((u, v, -1))
            else:  # positive corrections only
                rows.extend((u, v, 1) for u, v in uv)
        edges = np.array(
            [(min(x, y), max(x, y), s) for x, y, s in rows], dtype=np.int64
        ) if rows else np.zeros((0, 3), dtype=np.int64)
        return Summary(n_leaves=n, parent=parent, edges=edges)


def randomized(g: Graph, seed: int = 0, max_steps=None) -> Summary:
    """RANDOMIZED [12]: repeat {random u; best 2-hop partner; merge if saving>0}."""
    st = _FlatState(g)
    rng = np.random.default_rng(seed)
    unfinished = set(st.alive)
    steps = 0
    limit = max_steps if max_steps is not None else 10 * g.n
    while unfinished and steps < limit:
        steps += 1
        u = int(rng.choice(np.fromiter(unfinished, dtype=np.int64)))
        if u not in st.alive:
            unfinished.discard(u)
            continue
        hop2: set = set()
        for v in st.adj[u]:
            hop2.add(v)
            hop2.update(st.adj[v])
        hop2.discard(u)
        best, best_s = None, 0.0
        for v in hop2:
            s = st.saving(u, v)
            if s > best_s:
                best, best_s = v, s
        if best is None:
            unfinished.discard(u)
        else:
            m = st.merge(u, best)
            unfinished.discard(best)
            unfinished.add(m)
    return st.to_summary()


def sweg(g: Graph, T: int = 20, seed: int = 0, max_group: int = 500) -> Summary:
    """SWEG [2] (ε=0, lossless): minhash groups + Jaccard partner selection."""
    st = _FlatState(g)
    rng = np.random.default_rng(seed)
    for t in range(1, T + 1):
        theta = 0.0 if t == T else 1.0 / (1 + t)
        alive = np.fromiter(st.alive, dtype=np.int64)
        groups = candidate_groups(g, st.root_of, alive, seed=seed * 104729 + t, max_group=max_group)
        for grp in groups:
            queue = list(rng.permutation(np.asarray(grp)))
            while len(queue) > 1:
                a = int(queue.pop())
                if a not in st.alive:
                    continue
                cand = [int(z) for z in queue if int(z) in st.alive and int(z) != a]
                if not cand:
                    break
                # Jaccard over neighbor-root sets
                na = set(st.adj[a])
                best, best_j = None, -1.0
                for z in cand:
                    nz = set(st.adj[z])
                    inter = len(na & nz)
                    uni = len(na | nz)
                    j = inter / uni if uni else 0.0
                    if j > best_j:
                        best, best_j = z, j
                if best is None:
                    continue
                if st.saving(a, best) >= theta:
                    m = st.merge(a, best)
                    queue = [q for q in queue if int(q) != best]
                    queue.insert(0, m)
    return st.to_summary()


def sags_like(g: Graph, h: int = 30, b: int = 10, p: float = 0.3, seed: int = 0) -> Summary:
    """SAGS-like [27]: LSH banding without saving evaluation — merge signature
    collisions directly (fast, least concise — matches the paper's finding)."""
    st = _FlatState(g)
    rng = np.random.default_rng(seed)
    bands = max(1, h // b)
    for band in range(bands):
        hv = rng.permutation(g.n).astype(np.int64)
        sig = np.full(g.n, np.iinfo(np.int64).max, dtype=np.int64)
        src = np.repeat(np.arange(g.n), np.diff(g.indptr))
        np.minimum.at(sig, src, hv[g.indices])
        buckets: dict = {}
        for r in list(st.alive):
            mem = st.members[r]
            key = int(min(sig[m] for m in mem))
            buckets.setdefault(key, []).append(r)
        for grp in buckets.values():
            grp = [r for r in grp if r in st.alive]
            rng.shuffle(grp)
            for i in range(0, len(grp) - 1, 2):
                if rng.random() < p:
                    st.merge(grp[i], grp[i + 1])
    return st.to_summary()
