"""Offline stand-ins for the paper's 16 datasets (Table II).

Every stand-in is a seeded synthetic graph in the same *regime* (domain,
density, structure) at a size that runs on one CPU core. The mapping is
recorded so benchmark tables carry the paper's dataset mnemonics.
"""
from __future__ import annotations

from repro.graphs import generators as G
from repro.graphs.csr import Graph

# name -> (paper dataset, domain, builder)
_REGISTRY = {
    # Internet topology: hubs and spokes
    "CA": ("Caida", "Internet", lambda: G.star_of_cliques(400, 12, seed=1)),
    # Dense social ego-nets: overlapping dense communities
    "FA": ("Ego-Facebook", "Social", lambda: G.planted_hierarchy((4, 4), 24, (0.004, 0.35, 0.92), seed=2)),
    # PPI: strong hierarchical module structure (SLUGGER's best dataset)
    "PR": ("Protein", "PPI", lambda: G.planted_hierarchy((4, 4, 4), 12, (0.001, 0.10, 0.85, 0.99), seed=3)),
    # Email: heavy-tailed
    "EM": ("Email-Enron", "Email", lambda: G.barabasi_albert(4000, 5, seed=4)),
    # Collaboration: caveman cliques
    "DB": ("DBLP", "Collaboration", lambda: G.caveman(700, 6, rewire=0.08, seed=5)),
    # Co-purchase: sparse scale-free with communities
    "AM": ("Amazon0601", "Co-purchase", lambda: G.rmat(12, 5, seed=6)),
    # Hyperlinks: highly compressible rmat
    "CN": ("CNR-2000", "Hyperlinks", lambda: G.planted_hierarchy((6, 5, 4), 10, (0.0006, 0.02, 0.9, 1.0), seed=7)),
    # Social video: sparse heavy-tail (hardest to compress in the paper)
    "YO": ("Youtube", "Social", lambda: G.barabasi_albert(6000, 3, seed=8)),
    # Internet: rmat larger
    "SK": ("Skitter", "Internet", lambda: G.rmat(13, 6, seed=9)),
    # Hyperlinks dense: nested bipartite + hierarchy (very compressible)
    "EU": ("EU-05", "Hyperlinks", lambda: G.planted_hierarchy((5, 5, 5), 10, (0.001, 0.05, 0.9, 0.995), seed=10)),
}

_LARGE = {
    # Larger stand-ins used by scalability/speed runs when --full is given.
    "ES": ("Eswiki-13", "Social", lambda: G.rmat(14, 6, seed=11)),
    "LJ": ("LiveJournal", "Social", lambda: G.barabasi_albert(20000, 6, seed=12)),
    "HO": ("Hollywood", "Collaboration", lambda: G.caveman(2500, 8, rewire=0.05, seed=13)),
    "IC": ("IC-04", "Hyperlinks", lambda: G.planted_hierarchy((6, 6, 5), 12, (0.0004, 0.02, 0.85, 0.99), seed=14)),
    "U2": ("UK-02", "Hyperlinks", lambda: G.rmat(15, 6, seed=15)),
    "U5": ("UK-05", "Hyperlinks", lambda: G.rmat(16, 6, seed=16)),
}


def names(full: bool = False):
    return list(_REGISTRY) + (list(_LARGE) if full else [])


def info(name: str):
    reg = {**_REGISTRY, **_LARGE}
    paper_name, domain, _ = reg[name]
    return {"paper_dataset": paper_name, "domain": domain}


def load(name: str) -> Graph:
    reg = {**_REGISTRY, **_LARGE}
    return reg[name][2]()
