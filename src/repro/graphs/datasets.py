"""Offline stand-ins for the paper's 16 datasets (Table II) + real downloads.

Every stand-in is a seeded synthetic graph in the same *regime* (domain,
density, structure) at a size that runs on one CPU core. The mapping is
recorded so benchmark tables carry the paper's dataset mnemonics.

`load_remote` additionally fetches the real SNAP edge lists the paper uses,
with a disk cache under ``$REPRO_DATA_DIR`` (default
``~/.cache/repro-slugger``): downloads are verified against a sha256 sidecar
(trust-on-first-use when the registry pins no digest), cache hits never
touch the network, and network/corruption failures raise
`DatasetFetchError` with the exact path to drop a manually obtained file
into — never a raw ``URLError``.
"""
from __future__ import annotations

import gzip
import hashlib
import os
import time
import urllib.error
import urllib.request

import numpy as np

from repro import faults
from repro.graphs import generators as G
from repro.graphs.csr import Graph

# name -> (paper dataset, domain, builder)
_REGISTRY = {
    # Internet topology: hubs and spokes
    "CA": ("Caida", "Internet", lambda: G.star_of_cliques(400, 12, seed=1)),
    # Dense social ego-nets: overlapping dense communities
    "FA": ("Ego-Facebook", "Social", lambda: G.planted_hierarchy((4, 4), 24, (0.004, 0.35, 0.92), seed=2)),
    # PPI: strong hierarchical module structure (SLUGGER's best dataset)
    "PR": ("Protein", "PPI", lambda: G.planted_hierarchy((4, 4, 4), 12, (0.001, 0.10, 0.85, 0.99), seed=3)),
    # Email: heavy-tailed
    "EM": ("Email-Enron", "Email", lambda: G.barabasi_albert(4000, 5, seed=4)),
    # Collaboration: caveman cliques
    "DB": ("DBLP", "Collaboration", lambda: G.caveman(700, 6, rewire=0.08, seed=5)),
    # Co-purchase: sparse scale-free with communities
    "AM": ("Amazon0601", "Co-purchase", lambda: G.rmat(12, 5, seed=6)),
    # Hyperlinks: highly compressible rmat
    "CN": ("CNR-2000", "Hyperlinks", lambda: G.planted_hierarchy((6, 5, 4), 10, (0.0006, 0.02, 0.9, 1.0), seed=7)),
    # Social video: sparse heavy-tail (hardest to compress in the paper)
    "YO": ("Youtube", "Social", lambda: G.barabasi_albert(6000, 3, seed=8)),
    # Internet: rmat larger
    "SK": ("Skitter", "Internet", lambda: G.rmat(13, 6, seed=9)),
    # Hyperlinks dense: nested bipartite + hierarchy (very compressible)
    "EU": ("EU-05", "Hyperlinks", lambda: G.planted_hierarchy((5, 5, 5), 10, (0.001, 0.05, 0.9, 0.995), seed=10)),
}

_LARGE = {
    # Larger stand-ins used by scalability/speed runs when --full is given.
    "ES": ("Eswiki-13", "Social", lambda: G.rmat(14, 6, seed=11)),
    "LJ": ("LiveJournal", "Social", lambda: G.barabasi_albert(20000, 6, seed=12)),
    "HO": ("Hollywood", "Collaboration", lambda: G.caveman(2500, 8, rewire=0.05, seed=13)),
    "IC": ("IC-04", "Hyperlinks", lambda: G.planted_hierarchy((6, 6, 5), 12, (0.0004, 0.02, 0.85, 0.99), seed=14)),
    "U2": ("UK-02", "Hyperlinks", lambda: G.rmat(15, 6, seed=15)),
    "U5": ("UK-05", "Hyperlinks", lambda: G.rmat(16, 6, seed=16)),
}


def names(full: bool = False):
    return list(_REGISTRY) + (list(_LARGE) if full else [])


def info(name: str):
    reg = {**_REGISTRY, **_LARGE}
    paper_name, domain, _ = reg[name]
    return {"paper_dataset": paper_name, "domain": domain}


def load(name: str) -> Graph:
    reg = {**_REGISTRY, **_LARGE}
    return reg[name][2]()


# ---------------------------------------------------------------------------
# Real datasets: cached, checksummed downloads
# ---------------------------------------------------------------------------
_CACHE_ENV = "REPRO_DATA_DIR"

# name -> (url, pinned sha256 or None = trust-on-first-use via sidecar)
REMOTE = {
    "ca-GrQc": ("https://snap.stanford.edu/data/ca-GrQc.txt.gz", None),
    "ca-HepTh": ("https://snap.stanford.edu/data/ca-HepTh.txt.gz", None),
    "email-Enron": ("https://snap.stanford.edu/data/email-Enron.txt.gz", None),
}


class DatasetFetchError(RuntimeError):
    """Download/cache failure with an actionable recovery hint."""


def cache_dir() -> str:
    return os.environ.get(
        _CACHE_ENV, os.path.join(os.path.expanduser("~"), ".cache",
                                 "repro-slugger"))


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def fetch(name: str, cache: str | None = None, opener=None,
          retries: int = 3, backoff: float = 0.5, retry_seed: int = 0,
          sleep=time.sleep) -> str:
    """Return the local path of dataset ``name``, downloading on miss.

    Cache layout: ``<cache>/<name><ext>`` plus a ``.sha256`` sidecar. A hit
    is served only if its digest matches the pinned (or recorded) one; a
    corrupt file raises instead of silently re-parsing. ``opener`` overrides
    ``urllib.request.urlopen`` (tests inject a mock here).

    Transient network errors retry up to ``retries`` times with exponential
    backoff (``backoff * 2**attempt`` seconds) scaled by a DETERMINISTIC
    jitter in [0.5, 1.5) drawn from ``SeedSequence((retry_seed, attempt))``
    — reproducible like every other randomness in the repo, but still
    decorrelating parallel fetchers that pass distinct seeds. Checksum
    mismatches never retry: a pinned-digest failure means a corrupt or
    tampered payload, and re-downloading it would just re-fetch the same
    bytes. ``sleep`` is injectable so tests assert the schedule without
    waiting it out.
    """
    if name not in REMOTE:
        raise KeyError(f"unknown remote dataset {name!r}; "
                       f"known: {sorted(REMOTE)}")
    url, pinned = REMOTE[name]
    cache = cache or cache_dir()
    os.makedirs(cache, exist_ok=True)
    ext = ".txt.gz" if url.endswith(".gz") else ".txt"
    path = os.path.join(cache, name + ext)
    sidecar = path + ".sha256"
    if os.path.exists(path):
        want = pinned
        if want is None and os.path.exists(sidecar):
            with open(sidecar) as f:
                want = f.read().strip()
        got = _sha256(path)
        if want is None or got == want:
            return path
        raise DatasetFetchError(
            f"checksum mismatch for cached {path}: expected {want}, got "
            f"{got}. Delete the file to re-download, or replace it with a "
            f"correct copy from {url}.")
    opener = opener or urllib.request.urlopen
    last_err = None
    for attempt in range(max(0, int(retries)) + 1):
        if attempt:
            jitter = 0.5 + np.random.default_rng(
                np.random.SeedSequence((int(retry_seed), attempt))).random()
            sleep(backoff * 2 ** (attempt - 1) * jitter)
        faults.check("datasets.fetch")
        try:
            with opener(url) as resp:
                data = resp.read()
            break
        except (urllib.error.URLError, OSError, ValueError) as e:
            last_err = e
    else:
        raise DatasetFetchError(
            f"could not download {name} from {url} after "
            f"{max(0, int(retries)) + 1} attempts: {last_err}. If this "
            f"host is offline, fetch the file elsewhere and place it at "
            f"{path} (cache dir overridable via ${_CACHE_ENV}).") \
            from last_err
    got = hashlib.sha256(data).hexdigest()
    if pinned is not None and got != pinned:
        raise DatasetFetchError(
            f"downloaded {name} has sha256 {got}, registry pins {pinned}; "
            f"refusing to cache a corrupt/tampered file.")
    tmp = path + ".part"
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, path)
    tmp_sc = sidecar + ".part"
    with open(tmp_sc, "w") as f:
        f.write(got + "\n")
    os.replace(tmp_sc, sidecar)
    return path


def _parse_edge_text(raw: bytes) -> np.ndarray:
    """SNAP edge-list text: '#' comments, one 'u<ws>v' pair per line."""
    rows = []
    for line in raw.decode("utf-8", errors="replace").splitlines():
        line = line.strip()
        if not line or line.startswith(("#", "%")):
            continue
        parts = line.split()
        if len(parts) >= 2:
            rows.append((int(parts[0]), int(parts[1])))
    return (np.array(rows, dtype=np.int64) if rows
            else np.zeros((0, 2), dtype=np.int64))


def load_remote(name: str, cache: str | None = None, opener=None) -> Graph:
    """Fetch (or reuse) a remote dataset and parse it into a `Graph`.

    Node ids are compacted to ``0..n-1`` in ascending original-id order, so
    the result is deterministic for a fixed file.
    """
    path = fetch(name, cache=cache, opener=opener)
    with open(path, "rb") as f:
        raw = f.read()
    if path.endswith(".gz"):
        raw = gzip.decompress(raw)
    edges = _parse_edge_text(raw)
    if edges.size == 0:
        return Graph.from_edges(0, edges)
    uniq, inv = np.unique(edges, return_inverse=True)
    return Graph.from_edges(int(uniq.size), inv.reshape(-1, 2))
