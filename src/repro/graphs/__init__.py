from repro.graphs.csr import Graph
from repro.graphs.partitioned import (GraphShard, PartitionedGraph,
                                      as_partitioned, block_owner)
from repro.graphs import generators, datasets

__all__ = ["Graph", "PartitionedGraph", "GraphShard", "as_partitioned",
           "block_owner", "generators", "datasets"]
