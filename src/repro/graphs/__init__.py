from repro.graphs.csr import Graph
from repro.graphs import generators, datasets

__all__ = ["Graph", "generators", "datasets"]
