"""Deterministic synthetic graph generators.

The paper evaluates on 16 downloaded web-scale graphs; offline we mirror their
*regimes* (social / hyperlink / collaboration / PPI) with seeded generators so
every benchmark is reproducible bit-for-bit.
"""
from __future__ import annotations

import numpy as np

from repro.graphs.csr import Graph


def erdos_renyi(n: int, p: float, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    # sample via geometric skipping over the upper-triangle index space
    max_pairs = n * (n - 1) // 2
    expected = int(max_pairs * p)
    # oversample then dedupe (fine for the sparse regimes we use)
    k = int(expected * 1.2) + 16
    u = rng.integers(0, n, size=k, dtype=np.int64)
    v = rng.integers(0, n, size=k, dtype=np.int64)
    return Graph.from_edges(n, np.stack([u, v], axis=1))


def barabasi_albert(n: int, m: int, seed: int = 0) -> Graph:
    """Preferential attachment: heavy-tailed degree like social networks."""
    rng = np.random.default_rng(seed)
    targets = list(range(m))
    repeated = []  # nodes repeated by degree
    edges = []
    for v in range(m, n):
        for t in targets:
            edges.append((v, t))
        repeated.extend(targets)
        repeated.extend([v] * m)
        # next targets: sample m distinct from `repeated`
        targets = set()
        while len(targets) < m:
            targets.add(repeated[rng.integers(0, len(repeated))])
        targets = list(targets)
    return Graph.from_edges(n, np.array(edges, dtype=np.int64))


def rmat(scale: int, edge_factor: int = 8, a=0.57, b=0.19, c=0.19, seed: int = 0) -> Graph:
    """R-MAT / Kronecker-style generator (hyperlink-like, scale-free, communities)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    d = 1.0 - a - b - c
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for _ in range(scale):
        r = rng.random(m)
        # quadrant probabilities: (0,0)=a, (0,1)=b, (1,0)=c, (1,1)=d
        bit_src = (r >= a + b).astype(np.int64)
        r2 = rng.random(m)
        p_right = np.where(bit_src == 0, b / (a + b), d / (c + d))
        bit_dst = (r2 < p_right).astype(np.int64)
        src = src * 2 + bit_src
        dst = dst * 2 + bit_dst
    return Graph.from_edges(n, np.stack([src, dst], axis=1))


def planted_hierarchy(
    branching: tuple = (4, 4, 4),
    leaf_size: int = 8,
    densities: tuple = (0.02, 0.12, 0.5, 0.95),
    seed: int = 0,
) -> Graph:
    """Recursive planted partition: the regime SLUGGER is designed for.

    ``branching=(b1,..,bk)`` builds a k-level community tree; two leaves at
    lowest-common-ancestor level L are connected with prob ``densities[L]``
    (level 0 = root, level k = same leaf-community). ``densities`` must be
    increasing: deeper common ancestor => denser, i.e. students of the same
    advisor are more connected than students of the same university.
    """
    rng = np.random.default_rng(seed)
    n_groups = int(np.prod(branching))
    n = n_groups * leaf_size
    # community path of each node, as digits
    labels = np.zeros((n, len(branching)), dtype=np.int64)
    g = np.arange(n) // leaf_size
    for i in range(len(branching) - 1, -1, -1):
        labels[:, i] = g % branching[i]
        g = g // branching[i]
    edges = []
    # sample block-wise: iterate over pairs of groups (n_groups is small)
    group_labels = labels[::leaf_size]
    for gi in range(n_groups):
        for gj in range(gi, n_groups):
            lca = 0
            for lev in range(len(branching)):
                if group_labels[gi, lev] == group_labels[gj, lev]:
                    lca += 1
                else:
                    break
            p = densities[lca if gi != gj else len(branching)]
            if p <= 0:
                continue
            if gi == gj:
                pairs = [(u, v) for u in range(leaf_size) for v in range(u + 1, leaf_size)]
            else:
                pairs = [(u, v) for u in range(leaf_size) for v in range(leaf_size)]
            mask = rng.random(len(pairs)) < p
            base_i, base_j = gi * leaf_size, gj * leaf_size
            for (u, v), keep in zip(pairs, mask):
                if keep:
                    edges.append((base_i + u, base_j + v))
    return Graph.from_edges(n, np.array(edges, dtype=np.int64) if edges else np.zeros((0, 2)))


def caveman(n_cliques: int, clique_size: int, rewire: float = 0.05, seed: int = 0) -> Graph:
    """Connected caveman graph: cliques + sparse rewiring (collaboration-like)."""
    rng = np.random.default_rng(seed)
    n = n_cliques * clique_size
    edges = []
    for c in range(n_cliques):
        base = c * clique_size
        for u in range(clique_size):
            for v in range(u + 1, clique_size):
                edges.append((base + u, base + v))
    edges = np.array(edges, dtype=np.int64)
    k = int(len(edges) * rewire)
    if k:
        idx = rng.choice(len(edges), size=k, replace=False)
        edges[idx, 1] = rng.integers(0, n, size=k)
    return Graph.from_edges(n, edges)


def star_of_cliques(n_hubs: int, sat_per_hub: int, seed: int = 0) -> Graph:
    """Hub-and-spoke (internet-topology-like)."""
    rng = np.random.default_rng(seed)
    edges = []
    node = n_hubs
    for h in range(n_hubs):
        for _ in range(sat_per_hub):
            edges.append((h, node))
            node += 1
        if h:
            edges.append((h, rng.integers(0, h)))
    return Graph.from_edges(node, np.array(edges, dtype=np.int64))


def bipartite_nested(n_left: int, n_right: int, levels: int = 3, seed: int = 0) -> Graph:
    """Nested (hierarchically complete) bipartite graph — the Theorem-1 regime
    where hierarchical encodings are asymptotically smaller than flat ones."""
    edges = []
    # right node j at "depth" d(j) connects to the left prefix [0, n_left >> d(j));
    # prefixes are nested, so the hierarchical model encodes each right-depth
    # class with O(1) p-edges while the flat model needs per-node corrections.
    for j in range(n_right):
        depth = min(levels - 1, int(np.log2(j + 1)))
        for u in range(n_left >> depth):
            edges.append((u, n_left + j))
    return Graph.from_edges(n_left + n_right, np.array(edges, dtype=np.int64))


# Named serving-scale graphs, shared by the serving driver
# (launch/summary_serve.py) and its benchmark (benchmarks/query_serving.py)
# so the --edges presets and BENCH_serving_queries.json measure the SAME
# graphs. Keys name the edge count.
SERVING_GRAPHS = {
    "smoke": lambda: caveman(40, 8, 0.05, seed=0),
    "55k": lambda: caveman(1000, 11, 0.03, seed=0),
    "220k": lambda: caveman(4000, 11, 0.03, seed=0),
}


def sample_subgraph(g: Graph, n_nodes: int, seed: int = 0) -> Graph:
    """Random induced subgraph (used for the Fig. 1(b) scalability series)."""
    rng = np.random.default_rng(seed)
    nodes = rng.choice(g.n, size=min(n_nodes, g.n), replace=False)
    return g.subgraph(np.sort(nodes))


# ---------------------------------------------------------------------------
# Streamed emission (bounded-memory ingestion, DESIGN.md §8)
# ---------------------------------------------------------------------------
def as_chunks(edges: np.ndarray, chunk_edges: int = 1 << 18):
    """Yield an in-memory (m, 2) edge array in bounded chunks — the adapter
    that lets any eager generator feed `PartitionedGraph.from_edge_stream`."""
    edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
    for s in range(0, edges.shape[0], chunk_edges):
        yield edges[s:s + chunk_edges]


def stream_edges(g: Graph, chunk_edges: int = 1 << 18):
    """Yield a built graph's undirected edge list in chunks (tests/replay)."""
    yield from as_chunks(g.edge_list(), chunk_edges)


def rmat_stream(scale: int, edge_factor: int = 8, a=0.57, b=0.19, c=0.19,
                seed: int = 0, chunk_edges: int = 1 << 18):
    """Streamed R-MAT: emit the edge list in bounded chunks without ever
    materializing it whole. Each chunk draws from its own `SeedSequence`
    child, so the stream is deterministic per (seed, chunk_edges) and chunks
    can in principle be generated independently (out-of-core / parallel
    ingestion). Dedup/symmetrization is the consumer's job —
    `PartitionedGraph.from_edge_stream` applies the same cleaning as
    `Graph.from_edges`.
    """
    n = 1 << scale
    m = n * edge_factor
    d = 1.0 - a - b - c
    n_chunks = (m + chunk_edges - 1) // chunk_edges
    children = np.random.SeedSequence(seed).spawn(max(n_chunks, 1))
    for ci in range(n_chunks):
        k = min(chunk_edges, m - ci * chunk_edges)
        rng = np.random.default_rng(children[ci])
        src = np.zeros(k, dtype=np.int64)
        dst = np.zeros(k, dtype=np.int64)
        for _ in range(scale):
            r = rng.random(k)
            bit_src = (r >= a + b).astype(np.int64)
            r2 = rng.random(k)
            p_right = np.where(bit_src == 0, b / (a + b), d / (c + d))
            bit_dst = (r2 < p_right).astype(np.int64)
            src = src * 2 + bit_src
            dst = dst * 2 + bit_dst
        yield np.stack([src, dst], axis=1)
