"""Compact CSR representation of a simple undirected graph.

This is the substrate for the SLUGGER pipeline: every engine (exact numpy
engine, JAX distributed engine, Pallas kernels) consumes the same arrays.
"""
from __future__ import annotations

import numpy as np


class Graph:
    """Simple undirected graph in CSR form.

    Invariants:
      * no self-loops, no duplicate edges
      * symmetric: (u, v) present iff (v, u) present
      * ``indices`` sorted within each row
    """

    __slots__ = ("n", "indptr", "indices")

    def __init__(self, n: int, indptr: np.ndarray, indices: np.ndarray):
        self.n = int(n)
        self.indptr = np.asarray(indptr, dtype=np.int64)
        self.indices = np.asarray(indices, dtype=np.int32)

    # -- constructors ------------------------------------------------------
    @staticmethod
    def from_edges(n: int, edges: np.ndarray) -> "Graph":
        """Build from an (m, 2) array of (possibly dirty) edges.

        Removes self-loops and duplicates, symmetrizes, sorts rows.
        """
        edges = np.asarray(edges, dtype=np.int64).reshape(-1, 2)
        if edges.size:
            mask = edges[:, 0] != edges[:, 1]
            edges = edges[mask]
        if edges.size == 0:
            return Graph(n, np.zeros(n + 1, dtype=np.int64), np.zeros(0, dtype=np.int32))
        lo = np.minimum(edges[:, 0], edges[:, 1])
        hi = np.maximum(edges[:, 0], edges[:, 1])
        key = lo * n + hi
        key = np.unique(key)
        lo, hi = key // n, key % n
        src = np.concatenate([lo, hi])
        dst = np.concatenate([hi, lo])
        order = np.lexsort((dst, src))
        src, dst = src[order], dst[order]
        indptr = np.zeros(n + 1, dtype=np.int64)
        np.add.at(indptr, src + 1, 1)
        np.cumsum(indptr, out=indptr)
        return Graph(n, indptr, dst.astype(np.int32))

    @staticmethod
    def from_edge_set(n: int, edge_set) -> "Graph":
        if not edge_set:
            return Graph.from_edges(n, np.zeros((0, 2), dtype=np.int64))
        return Graph.from_edges(n, np.array(sorted(edge_set), dtype=np.int64))

    # -- accessors ---------------------------------------------------------
    @property
    def m(self) -> int:
        """Number of undirected edges."""
        return int(self.indices.shape[0] // 2)

    def degree(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, u: int) -> np.ndarray:
        return self.indices[self.indptr[u]:self.indptr[u + 1]]

    def edge_list(self) -> np.ndarray:
        """(m, 2) array with u < v per row."""
        src = np.repeat(np.arange(self.n, dtype=np.int32), np.diff(self.indptr))
        mask = src < self.indices
        return np.stack([src[mask], self.indices[mask]], axis=1)

    def edge_set(self) -> set:
        el = self.edge_list()
        return {(int(u), int(v)) for u, v in el}

    def has_edge(self, u: int, v: int) -> bool:
        row = self.neighbors(u)
        i = np.searchsorted(row, v)
        return bool(i < row.shape[0] and row[i] == v)

    def partitioned(self, n_parts: int = 1, owner=None):
        """This graph as shards — `Graph` is the one-partition special case
        of `PartitionedGraph` (DESIGN.md §8)."""
        from repro.graphs.partitioned import PartitionedGraph
        return PartitionedGraph.from_graph(self, n_parts, owner=owner)

    def subgraph(self, nodes: np.ndarray) -> "Graph":
        """Induced subgraph with nodes relabeled 0..len(nodes)-1."""
        nodes = np.asarray(nodes, dtype=np.int64)
        relabel = -np.ones(self.n, dtype=np.int64)
        relabel[nodes] = np.arange(nodes.shape[0])
        el = self.edge_list()
        keep = (relabel[el[:, 0]] >= 0) & (relabel[el[:, 1]] >= 0)
        el = relabel[el[keep]]
        return Graph.from_edges(nodes.shape[0], el)

    def __repr__(self):
        return f"Graph(n={self.n}, m={self.m})"

    def __eq__(self, other):
        return (
            isinstance(other, Graph)
            and self.n == other.n
            and np.array_equal(self.indptr, other.indptr)
            and np.array_equal(self.indices, other.indices)
        )
