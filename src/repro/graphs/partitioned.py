"""Partition-sharded graph storage (DESIGN.md §8).

A ``PartitionedGraph`` splits a simple undirected graph into per-partition
CSR *shards* keyed by node ownership: partition p stores the adjacency rows
of the nodes it owns (neighbor ids stay global). The summarization engine
(`core/engine.py`) runs its shard-local stages against these shards; the
single-partition case is exactly one shard whose CSR equals `csr.Graph` —
the monolithic graph is the ``n_parts=1`` special case, not a separate code
path.

Construction comes in two flavors:

* ``from_graph`` — slice an in-memory CSR by the ownership map (cheap:
  block ownership slices rows contiguously).
* ``from_edge_stream`` — chunked ingestion: edges arrive from any
  iterable; each chunk is cleaned, symmetrized, sorted, and split into
  per-partition *runs*; finalization merges each partition's sorted runs
  and dedupes. With ``spill_dir`` the runs live on disk between chunk and
  finalize, making peak memory O(chunk + largest partition) — graphs
  larger than RAM can be ingested; without it the run pool stays in
  memory for speed.

Ownership is any int array ``owner[node] -> partition``; the default is
balanced contiguous blocks (``block_owner``), which keeps shard rows
contiguous in node id and makes ``to_graph`` a concatenation.
"""
from __future__ import annotations

import os

import numpy as np

from repro.graphs.csr import Graph


def block_owner(n: int, n_parts: int) -> np.ndarray:
    """Balanced contiguous-block ownership map: node -> partition."""
    if n == 0:
        return np.zeros(0, dtype=np.int64)
    return (np.arange(n, dtype=np.int64) * n_parts) // n


def _clean_stale_runs(spill_dir: str) -> int:
    """Remove spill-run files left by a crashed prior ingestion.

    Run files are namespaced ``run-<part>-<idx>.npy`` (plus ``.tmp``
    half-writes from a kill mid-write) and are consumed by the ingestion
    that wrote them — any survivor is an orphan, and letting it linger
    would at best waste disk and at worst be merged into a LATER ingestion
    sharing the spill dir. Returns the number of files removed."""
    removed = 0
    for fname in os.listdir(spill_dir):
        if fname.startswith("run-") and (fname.endswith(".npy")
                                         or fname.endswith(".npy.tmp")):
            try:
                os.remove(os.path.join(spill_dir, fname))
                removed += 1
            except OSError:  # pragma: no cover - racing cleaner is fine
                pass
    return removed


def _check_owner(owner: np.ndarray, n: int, n_parts: int) -> np.ndarray:
    """Validate an ownership map: one entry per node, values in range —
    an out-of-range owner would silently drop that node's adjacency."""
    owner = np.asarray(owner, dtype=np.int64)
    if owner.shape != (n,):
        raise ValueError(f"owner must have shape ({n},), got {owner.shape}")
    if n and (owner.min() < 0 or owner.max() >= n_parts):
        raise ValueError(
            f"owner values must be in [0, {n_parts}); got range "
            f"[{owner.min()}, {owner.max()}]")
    return owner


class GraphShard:
    """Adjacency rows of one partition's owned nodes (neighbor ids global).

    ``nodes[i]`` is the global id of local row i; ``indptr/indices`` are the
    CSR over local rows. A shard of the trivial 1-partition split is exactly
    the input graph's CSR.
    """

    __slots__ = ("part", "nodes", "indptr", "indices")

    def __init__(self, part: int, nodes: np.ndarray, indptr: np.ndarray,
                 indices: np.ndarray):
        self.part = int(part)
        self.nodes = np.asarray(nodes, dtype=np.int64)
        self.indptr = np.asarray(indptr, dtype=np.int64)
        self.indices = np.asarray(indices, dtype=np.int32)

    @property
    def n_local(self) -> int:
        return int(self.nodes.shape[0])

    @property
    def n_entries(self) -> int:
        return int(self.indices.shape[0])

    def degree(self) -> np.ndarray:
        return np.diff(self.indptr)

    def neighbors(self, local_row: int) -> np.ndarray:
        return self.indices[self.indptr[local_row]:self.indptr[local_row + 1]]

    def __repr__(self):
        return (f"GraphShard(part={self.part}, rows={self.n_local}, "
                f"entries={self.n_entries})")


class PartitionedGraph:
    """A simple undirected graph stored as per-partition CSR shards."""

    __slots__ = ("n", "n_parts", "owner", "shards", "_source")

    def __init__(self, n: int, owner: np.ndarray, shards: list):
        self.n = int(n)
        self.owner = np.asarray(owner, dtype=np.int64)
        self.n_parts = len(shards)
        self.shards = shards
        self._source = None  # the Graph this was sliced from, if any

    # -- constructors ------------------------------------------------------
    @staticmethod
    def from_graph(g: Graph, n_parts: int = 1, owner=None) -> "PartitionedGraph":
        """Split an in-memory CSR into shards by the ownership map."""
        n_parts = max(1, int(n_parts))
        if owner is None:
            owner = block_owner(g.n, n_parts)
        owner = _check_owner(owner, g.n, n_parts)
        deg = np.diff(g.indptr)
        shards = []
        for p in range(n_parts):
            nodes = np.flatnonzero(owner == p)
            lens = deg[nodes]
            idx = _csr_slice_indices(g.indptr[nodes], lens)
            indptr = np.zeros(nodes.size + 1, dtype=np.int64)
            np.cumsum(lens, out=indptr[1:])
            shards.append(GraphShard(p, nodes, indptr, g.indices[idx]))
        pg = PartitionedGraph(g.n, owner, shards)
        pg._source = g  # shards are views of g; to_graph can return it as-is
        return pg

    @staticmethod
    def from_edge_stream(n: int, chunks, n_parts: int = 1, owner=None,
                         spill_dir=None) -> "PartitionedGraph":
        """Build from an iterable of (k, 2) edge chunks.

        Per chunk: drop self-loops, symmetrize into directed half-edges,
        dedupe within the chunk, and split into per-partition sorted runs
        (keyed ``src * n + dst`` — the same bounded keying `Graph.from_edges`
        uses). Finalization merges each partition's runs with one
        concatenate + unique and frees them as it goes.

        With ``spill_dir`` set, every run is written to disk as it is cut
        and loaded back only when its partition finalizes — peak memory is
        then O(one chunk + largest partition), so graphs larger than RAM can
        be ingested. The default keeps runs in memory (fast, but the run
        pool peaks at O(|E|) before finalization).
        """
        n = int(n)
        n_parts = max(1, int(n_parts))
        if owner is None:
            owner = block_owner(n, n_parts)
        owner = _check_owner(owner, n, n_parts)
        if spill_dir is not None:
            os.makedirs(spill_dir, exist_ok=True)
            _clean_stale_runs(spill_dir)
        runs: list = [[] for _ in range(n_parts)]
        n_runs = 0
        for chunk in chunks:
            chunk = np.asarray(chunk, dtype=np.int64).reshape(-1, 2)
            if chunk.size == 0:
                continue
            keep = chunk[:, 0] != chunk[:, 1]
            chunk = chunk[keep]
            if chunk.size == 0:
                continue
            src = np.concatenate([chunk[:, 0], chunk[:, 1]])
            dst = np.concatenate([chunk[:, 1], chunk[:, 0]])
            key = np.unique(src * np.int64(n) + dst)  # sorted run, deduped
            part = owner[key // n]
            for p in range(n_parts):
                sel = key[part == p]
                if sel.size == 0:
                    continue
                if spill_dir is not None:
                    path = os.path.join(spill_dir, f"run-{p}-{n_runs}.npy")
                    # temp + atomic rename: a kill mid-write leaves only a
                    # .tmp file, which the next ingestion sweeps away — a
                    # committed run file is always a complete .npy
                    tmp = path + ".tmp"
                    with open(tmp, "wb") as f:
                        np.save(f, sel)
                    os.replace(tmp, path)
                    runs[p].append(path)
                else:
                    runs[p].append(sel)
                n_runs += 1
        shards = []
        for p in range(n_parts):
            nodes = np.flatnonzero(owner == p)
            if runs[p]:
                loaded = [np.load(r) if isinstance(r, str) else r
                          for r in runs[p]]
                key = np.unique(np.concatenate(loaded))  # merge sorted runs
                src, dst = key // n, key % n
                if spill_dir is not None:
                    for r in runs[p]:
                        os.remove(r)
            else:
                src = dst = np.zeros(0, dtype=np.int64)
            runs[p] = None  # free (or forget) this partition's runs
            # local CSR: rows follow the shard's node order
            local_of = np.full(n, -1, dtype=np.int64)
            local_of[nodes] = np.arange(nodes.size)
            counts = np.bincount(local_of[src], minlength=nodes.size)
            indptr = np.zeros(nodes.size + 1, dtype=np.int64)
            np.cumsum(counts, out=indptr[1:])
            shards.append(GraphShard(p, nodes, indptr, dst.astype(np.int32)))
        return PartitionedGraph(n, owner, shards)

    # -- accessors ---------------------------------------------------------
    @property
    def m(self) -> int:
        """Number of undirected edges."""
        return sum(s.n_entries for s in self.shards) // 2

    def shard(self, p: int) -> GraphShard:
        return self.shards[p]

    def part_nodes(self, p: int) -> np.ndarray:
        return self.shards[p].nodes

    def to_graph(self) -> Graph:
        """Reassemble the full CSR (rows in global node-id order). When the
        shards were sliced from an in-memory Graph, that graph is returned
        directly — the ``partitions=1`` engine path then costs nothing."""
        if self._source is not None:
            return self._source
        deg = np.zeros(self.n, dtype=np.int64)
        for s in self.shards:
            deg[s.nodes] = s.degree()
        indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(deg, out=indptr[1:])
        indices = np.zeros(int(indptr[-1]), dtype=np.int32)
        for s in self.shards:
            idx = _csr_slice_indices(indptr[s.nodes], s.degree())
            indices[idx] = s.indices
        return Graph(self.n, indptr, indices)

    def __repr__(self):
        return (f"PartitionedGraph(n={self.n}, m={self.m}, "
                f"parts={self.n_parts})")


def _csr_slice_indices(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Flat gather indices for CSR row slices (concat of aranges)."""
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    ends = np.cumsum(lens)
    return np.repeat(starts, lens) + (
        np.arange(total, dtype=np.int64) - np.repeat(ends - lens, lens))


def as_partitioned(g, n_parts: int = 1) -> PartitionedGraph:
    """Coerce a Graph (or pass through a PartitionedGraph) to shards."""
    if isinstance(g, PartitionedGraph):
        return g
    return PartitionedGraph.from_graph(g, n_parts)
