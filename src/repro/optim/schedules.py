"""LR schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, warmup: int, total: int, min_ratio: float = 0.1):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(1.0, warmup), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos


def constant(step, **_):
    return jnp.ones_like(step, dtype=jnp.float32)
