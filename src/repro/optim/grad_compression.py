"""Gradient compression for the data-parallel reduction (distributed-
optimization trick, DESIGN.md §6).

int8 stochastic-quantized all-reduce with error feedback: each DP worker
quantizes (g - residual-carry) to int8 blocks, all-reduces the int8 payload
(4× less DP traffic than f32, 2× less than bf16), dequantizes, and carries
the quantization error into the next step. Used inside shard_map over the dp
axes; numerics are test-covered (convergence parity on a quadratic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _blockwise_scale(x):
    """Per-block absmax scales; x flattened to (nblocks, BLOCK)."""
    n = x.shape[0]
    pad = (-n) % BLOCK
    xb = jnp.pad(x, (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0
    return xb, scale, n


def quantize_int8(x, key=None):
    """x: (n,) f32 -> (int8 blocks, scales). Stochastic rounding when key."""
    xb, scale, n = _blockwise_scale(x)
    y = xb / jnp.maximum(scale, 1e-12)
    if key is not None:
        noise = jax.random.uniform(key, y.shape) - 0.5
        q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    else:
        q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    return q, scale, n


def dequantize_int8(q, scale, n):
    return (q.astype(jnp.float32) * scale).reshape(-1)[:n]


def compressed_psum(g_flat, err, axis_names, key=None):
    """One error-feedback compressed all-reduce step (inside shard_map).

    g_flat: (n,) local gradient shard-view; err: (n,) carried residual.
    Returns (g_reduced_mean, new_err).

    All workers quantize against a SHARED per-block scale (pmax of local
    absmax — a tiny f32 collective) so the int8 payloads are summable.
    """
    corrected = g_flat + err
    xb, scale, n = _blockwise_scale(corrected)
    for ax in axis_names:
        scale = jax.lax.pmax(scale, ax)
    y = xb / jnp.maximum(scale, 1e-12)
    if key is not None:
        noise = jax.random.uniform(key, y.shape) - 0.5
        q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    else:
        q = jnp.clip(jnp.round(y), -127, 127).astype(jnp.int8)
    deq_local = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    new_err = corrected - deq_local
    acc = q.astype(jnp.int32)
    for ax in axis_names:
        acc = jax.lax.psum(acc, ax)
    ndev = 1
    for ax in axis_names:
        if hasattr(jax.lax, "axis_size"):
            ndev *= jax.lax.axis_size(ax)
        else:  # older jax: count devices along the axis with a psum of ones
            ndev *= jax.lax.psum(1, ax)
    mean = (acc.astype(jnp.float32) * scale).reshape(-1)[:n] / ndev
    return mean, new_err


def flatten_grads(grads):
    leaves, treedef = jax.tree.flatten(grads)
    shapes = [l.shape for l in leaves]
    sizes = [int(jnp.size(l)) for l in leaves]
    flat = jnp.concatenate([l.reshape(-1).astype(jnp.float32) for l in leaves])
    return flat, (treedef, shapes, sizes)


def unflatten_grads(flat, meta):
    treedef, shapes, sizes = meta
    out, off = [], 0
    for shp, sz in zip(shapes, sizes):
        out.append(flat[off : off + sz].reshape(shp))
        off += sz
    return jax.tree.unflatten(treedef, out)
