"""AdamW with ZeRO-1 moment sharding (no external optimizer dependency).

State is a pytree mirroring params: {m, v} in f32 plus a scalar step count.
With a mesh context, moments carry ``zero1_spec`` shardings — sharded over
the data-parallel axes — which is what makes 100B+ training fit on v5e.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    # f32 is the paper-of-record default; "bfloat16" halves optimizer HBM and
    # its read/write traffic (§Perf memory-term iteration for 100B+ models;
    # math still runs in f32 — only storage is bf16)
    moment_dtype: str = "float32"


def init_state(params, moment_dtype: str = "float32"):
    dt = jnp.dtype(moment_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return {"m": zeros, "v": jax.tree.map(jnp.copy, zeros), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)))


def apply_updates(params, grads, opt_state, cfg: AdamWConfig, lr_scale=1.0):
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = opt_state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v2 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p2, m2.astype(m.dtype), v2.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm, "lr": lr}
