"""Jit'd wrapper: layout adaptation between the model's (b, s, hkv, g, hd)
attention convention and the kernel's (B, H, S, D), plus platform dispatch.

On TPU this is the production attention path (`cfg.attn_impl="pallas_flash"`);
the CPU dry-run keeps the pure-XLA `chunked_sdpa` twin (identical math and
blocking, validated against each other in tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attn.kernel import flash_attention_bhsd


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 512, bk: int = 512, interpret: bool = False):
    """q: (b, sq, hkv, g, hd); k/v: (b, sk, hkv, hd) — chunked_sdpa layout.
    Returns (b, sq, hkv, g, hd)."""
    b, sq, hkv, g, hd = q.shape
    sk = k.shape[1]
    qh = q.transpose(0, 2, 3, 1, 4).reshape(b, hkv * g, sq, hd)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)
    o = flash_attention_bhsd(qh, kh, vh, causal=causal, window=window,
                             bq=bq, bk=bk, interpret=interpret)
    return o.reshape(b, hkv, g, sq, hd).transpose(0, 3, 1, 2, 4)
