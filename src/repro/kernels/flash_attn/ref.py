"""Pure-jnp oracle for the flash attention kernel (dense softmax attention)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, H, Sq, D); k/v: (B, Hkv, Sk, D). GQA by head repetition."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = H // Hkv
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (D ** 0.5)
    if causal:
        qpos = jnp.arange(Sq)[:, None]
        kpos = jnp.arange(Sk)[None, :]
        mask = kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, -1e30)
    w = jnp.exp(s - s.max(axis=-1, keepdims=True))
    if causal:
        w = jnp.where(mask, w, 0.0)
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)
