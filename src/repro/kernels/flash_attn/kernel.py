"""Flash attention forward as a Pallas TPU kernel.

Grid: (batch, q_heads, q_blocks, kv_blocks) — TPU executes the grid
sequentially, so the (acc, m, l) VMEM scratch carries the online-softmax
state across the innermost kv_blocks dimension (initialized at j == 0,
finalized at the last visible block). Causal/sliding-window blocks that are
fully masked are skipped with `pl.when` — zero MXU work, and (the point of
the kernel) score blocks never leave VMEM, removing the O(S²) HBM traffic
the pure-XLA `chunked_sdpa` twin pays.

BlockSpecs tile q/k/v/o as (1, 1, block, head_dim) VMEM windows; head_dim is
the lane dimension (128-aligned for the MXU), block sizes default to 512
(sublane-aligned, 2 × (512×128) f32 + scratch ≈ 1.3 MiB of VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, causal: bool, window: int, scale: float,
                  nk: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    if causal:
        visible = (j * bk) <= (i * bq + bq - 1)
        if window:
            visible = jnp.logical_and(visible, (j * bk + bk - 1) > (i * bq - window))
    else:
        visible = (j >= 0)  # traced true

    @pl.when(visible)
    def _compute():
        qb = q_ref[0, 0].astype(jnp.float32)          # (bq, d)
        kb = k_ref[0, 0].astype(jnp.float32)          # (bk, d)
        s = jax.lax.dot_general(qb, kb, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            mask = kpos <= qpos
            if window:
                mask = jnp.logical_and(mask, kpos > qpos - window)
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        if causal:
            p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=-1)
        vb = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, vb, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[:, 0] = m_new

    last_j = jnp.minimum(((i + 1) * bq - 1) // bk, nk - 1) if causal else nk - 1

    @pl.when(j == last_j)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         bq: int = 512, bk: int = 512, interpret: bool = False):
    """q: (B, H, Sq, D); k/v: (B, Hkv, Sk, D), H % Hkv == 0 (GQA).
    Returns (B, H, Sq, D) in q.dtype."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    g = H // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, causal=causal, window=window,
        scale=1.0 / (D ** 0.5), nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
