"""Pure-jnp reference for the interval-membership count kernel."""
from __future__ import annotations

import jax.numpy as jnp


def interval_counts(lo, hi, sign, pos):
    """(B, E) intervals + (B, P) probes -> (B, P) int32 signed counts."""
    lo = jnp.asarray(lo, dtype=jnp.int32)
    hi = jnp.asarray(hi, dtype=jnp.int32)
    sign = jnp.asarray(sign, dtype=jnp.int32)
    pos = jnp.asarray(pos, dtype=jnp.int32)
    inside = (lo[:, :, None] <= pos[:, None, :]) & (pos[:, None, :] < hi[:, :, None])
    return (inside * sign[:, :, None]).sum(axis=1).astype(jnp.int32)
