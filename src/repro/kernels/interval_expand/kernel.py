"""Pallas TPU kernel: signed interval-membership counts.

Role in the system: the batched summary-query engine (`core/query_batch.py`)
answers ``neighbors``/``edge_exists`` on the packed serving artifact by
counting, for every probe position p of a query, the signed number of
incident-edge intervals that contain p:

    count[b, p] = sum_e sign[b, e] * [lo[b, e] <= pos[b, p] < hi[b, e]]

This is the membership-count inner loop of the interval sweep — for
``edge_exists`` the probes are the partner positions, for ``neighbors`` they
are the 2·deg interval boundaries (the count at a boundary equals the sweep's
running sum over the half-open range it opens). The kernel follows the
`seghist` layout: a (query, probe-block, interval-block) grid where each step
broadcasts a (BE, 1) interval column against a (1, BP) probe row and
accumulates compare-and-sum hits over the streamed interval axis.

Padding contract: callers pad intervals with lo == hi == 0 (empty, matches no
probe) and probes with -1 (contained in no interval, since lo >= 0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _interval_count_block(lo_ref, hi_ref, sg_ref, pos_ref, out_ref):
    k = pl.program_id(2)  # interval block (streamed, accumulated)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    lo = lo_ref[...]   # (1, BE) int32
    hi = hi_ref[...]   # (1, BE) int32
    sg = sg_ref[...]   # (1, BE) int32, padded entries are 0
    p = pos_ref[...]   # (1, BP) int32, padded probes are -1
    inside = (lo[0, :, None] <= p[0, None, :]) & (p[0, None, :] < hi[0, :, None])
    out_ref[...] += (inside * sg[0, :, None]).sum(axis=0, keepdims=True)


def interval_count_kernel(lo: jax.Array, hi: jax.Array, sign: jax.Array,
                          pos: jax.Array, block_p: int = 512,
                          block_e: int = 1024,
                          interpret: bool = True) -> jax.Array:
    """(B, E) int32 intervals + (B, P) int32 probes -> (B, P) int32 counts."""
    B, E = lo.shape
    P = pos.shape[1]
    bp = min(block_p, max(P, 1))
    be = min(block_e, max(E, 1))
    Ep = pl.cdiv(max(E, 1), be) * be
    Pp = pl.cdiv(max(P, 1), bp) * bp

    def _pad(a, width, fill):
        return jnp.full((B, width), fill, dtype=jnp.int32).at[:, : a.shape[1]].set(
            a.astype(jnp.int32))

    lo2, hi2, sg2 = _pad(lo, Ep, 0), _pad(hi, Ep, 0), _pad(sign, Ep, 0)
    pos2 = _pad(pos, Pp, -1)
    grid = (B, Pp // bp, Ep // be)
    out = pl.pallas_call(
        _interval_count_block,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, be), lambda b, j, k: (b, k)),
            pl.BlockSpec((1, be), lambda b, j, k: (b, k)),
            pl.BlockSpec((1, be), lambda b, j, k: (b, k)),
            pl.BlockSpec((1, bp), lambda b, j, k: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, bp), lambda b, j, k: (b, j)),
        out_shape=jax.ShapeDtypeStruct((B, Pp), jnp.int32),
        interpret=interpret,
    )(lo2, hi2, sg2, pos2)
    return out[:, :P]
