"""Public dispatch for batched signed interval-membership counts.

`batch_interval_counts` is what the batched query engine calls: given each
query's padded incident intervals (lo, hi, sign) and its probe positions,
return the signed containment count per probe. ``backend="pallas"`` routes
through the Pallas compare-and-sum kernel with a small jit cache keyed on
power-of-two padded shapes (mirroring `kernels/seghist/ops`);
``backend="numpy"`` is the plain broadcast reduction.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.kernels.common import LruCache, default_interpret, pow2
from repro.kernels.interval_expand.kernel import interval_count_kernel

_JIT_CACHE = LruCache(16)


def batch_interval_counts(lo: np.ndarray, hi: np.ndarray, sign: np.ndarray,
                          pos: np.ndarray, backend: str = "numpy",
                          interpret=None) -> np.ndarray:
    """(B, E) int intervals + (B, P) int probes -> (B, P) int64 counts.

    Padding contract: interval slots beyond a query's degree carry
    lo == hi == 0 (and sign 0); probe slots beyond a query's probe count are
    -1. Both match nothing, so padded slots contribute zero.
    """
    B, E = lo.shape
    P = pos.shape[1]
    if B == 0 or P == 0:
        return np.zeros((B, P), dtype=np.int64)
    if backend != "pallas":
        inside = (lo[:, :, None] <= pos[:, None, :]) & (pos[:, None, :] < hi[:, :, None])
        return (inside * sign[:, :, None].astype(np.int64)).sum(axis=1)
    if interpret is None:
        interpret = default_interpret()
    Ep = pow2(int(E), floor=128)
    Pp = pow2(int(P), floor=128)
    key = (Ep, Pp, interpret)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(
            lambda l, h, s, p: interval_count_kernel(l, h, s, p, interpret=interpret))
        _JIT_CACHE[key] = fn

    def _pad(a, width, fill):
        out = np.full((B, width), fill, dtype=np.int32)
        out[:, : a.shape[1]] = a
        return out

    counts = fn(_pad(lo, Ep, 0), _pad(hi, Ep, 0), _pad(sign, Ep, 0),
                _pad(pos, Pp, -1))
    return np.asarray(counts).astype(np.int64)[:, :P]
