"""Shared kernel-dispatch helpers: backend detection, jit-cache shaping, and
the bounded jit cache every ops module keys its compiled executables on.

Every kernel ops module (bitset_jaccard, bitset_fold, seghist, …) keys its
jit cache on power-of-two padded shapes and defaults to Pallas interpret
mode off-TPU — one copy of those rules lives here. `LruCache` bounds the
caches: before it, every new padded shape leaked a compiled executable for
the life of the process (ISSUE 5).
"""
from __future__ import annotations

import os
from collections import OrderedDict


def default_interpret() -> bool:
    """Pallas kernels run interpreted everywhere except real TPU backends."""
    import jax  # lazy: LruCache consumers must import without jax installed

    return jax.default_backend() != "tpu"


def default_use_kernel() -> bool:
    """Dispatch policy for ops that ship BOTH a Pallas kernel and a compiled
    jnp twin (`kernels/bitset_fold`): the kernel on real TPU backends, the
    jnp twin elsewhere — interpret-mode Pallas is a correctness emulation,
    not a fast path, and the twins are integer-exact equals (test-enforced).
    ``REPRO_FORCE_PALLAS=1`` forces the kernel (the CI resident smoke runs
    it in interpret mode); ``=0`` forces the jnp twin."""
    import jax

    env = os.environ.get("REPRO_FORCE_PALLAS")
    if env is not None:
        return env.strip() not in ("", "0", "false", "False")
    return jax.default_backend() == "tpu"


def pow2(x: int, floor: int = 8) -> int:
    """Round up to a power of two (≥ floor) so jit caches stay small."""
    return max(floor, 1 << (max(1, x) - 1).bit_length())


def mesh_content_key(mesh):
    """Cache key by mesh CONTENT, not object identity: the engine builds a
    fresh mesh per run, and equivalent meshes must reuse executables."""
    if mesh is None:
        return None
    import numpy as np

    return (tuple(int(d.id) for d in np.asarray(mesh.devices).ravel()),
            tuple(mesh.axis_names), tuple(mesh.shape.values()))


def shard_map_no_check(fn, mesh, in_specs, out_specs):
    """shard_map with the replication check disabled (pallas_call has no
    replication rule), papering over two jax API drifts: the top-level vs
    experimental import and the check_rep → check_vma kwarg rename."""
    import jax

    try:  # jax ≥ 0.4.38 re-exports shard_map at the top level
        sm = jax.shard_map
    except AttributeError:  # older jax: experimental location
        from jax.experimental.shard_map import shard_map as sm
    try:
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    except TypeError:  # newer jax renamed the kwarg
        return sm(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)


class LruCache:
    """Tiny LRU map for compiled executables, dict-compatible on the ops
    modules' ``cache.get(key)`` / ``cache[key] = fn`` usage.

    Compiled shard_map/pallas executables hold device buffers; an unbounded
    dict keyed on padded shapes grows for the life of the process as batch
    shapes drift across iterations. A small LRU keeps the hot shapes
    compiled and lets cold ones be rebuilt on the rare revisit.
    """

    def __init__(self, maxsize: int = 16):
        if maxsize < 1:
            raise ValueError("maxsize must be >= 1")
        self.maxsize = int(maxsize)
        self._d: OrderedDict = OrderedDict()

    def get(self, key, default=None):
        try:
            self._d.move_to_end(key)
        except KeyError:
            return default
        return self._d[key]

    def __setitem__(self, key, value):
        self._d[key] = value
        self._d.move_to_end(key)
        while len(self._d) > self.maxsize:
            self._d.popitem(last=False)

    def __getitem__(self, key):
        self._d.move_to_end(key)
        return self._d[key]

    def __contains__(self, key):
        return key in self._d

    def __len__(self):
        return len(self._d)

    def keys(self):
        return self._d.keys()

    def clear(self):
        self._d.clear()
