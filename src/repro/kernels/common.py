"""Shared kernel-dispatch helpers: backend detection and jit-cache shaping.

Every kernel ops module (bitset_jaccard, seghist) keys its jit cache on
power-of-two padded shapes and defaults to Pallas interpret mode off-TPU —
one copy of both rules lives here.
"""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Pallas kernels run interpreted everywhere except real TPU backends."""
    return jax.default_backend() != "tpu"


def pow2(x: int, floor: int = 8) -> int:
    """Round up to a power of two (≥ floor) so jit caches stay small."""
    return max(floor, 1 << (max(1, x) - 1).bit_length())
