"""Pure-jnp oracle for the bitset-jaccard kernel: pairwise popcount(AND)."""
from __future__ import annotations

import jax.numpy as jnp


def popcount_u32(x):
    """SWAR popcount on uint32 (TPU has no popcount primitive)."""
    x = x.astype(jnp.uint32)
    x = x - ((x >> jnp.uint32(1)) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> jnp.uint32(2)) & jnp.uint32(0x33333333))
    x = (x + (x >> jnp.uint32(4))) & jnp.uint32(0x0F0F0F0F)
    return ((x * jnp.uint32(0x01010101)) >> jnp.uint32(24)).astype(jnp.int32)


def pairwise_intersection(bits):
    """bits: (G, W) uint32 packed sets -> (G, G) int32 intersection sizes."""
    a = bits[:, None, :]
    b = bits[None, :, :]
    return popcount_u32(a & b).sum(axis=-1).astype(jnp.int32)


def pairwise_jaccard(bits):
    inter = pairwise_intersection(bits)
    deg = popcount_u32(bits).sum(axis=-1).astype(jnp.int32)
    union = deg[:, None] + deg[None, :] - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1), 0.0)
