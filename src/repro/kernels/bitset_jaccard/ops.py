"""Jit'd public wrappers: pack neighbor sets and score candidate groups.

`batched_pairwise_intersections` is the merge engine's entry point: a size
bucket of groups arrives as one (B, G, W) uint32 bitmap batch, gets zero-
padded into fixed tiles (tile count and W rounded to powers of two so the
jit cache stays small), and all pairwise intersection popcounts come back
from ONE dispatch of `batch_masked_intersection_kernel` per tile. The tile
padding is TRANSFER-ONLY: the kernel receives the valid batch count and
padded rows early-exit before the O(G²·W) popcount (ISSUE 5). Per-group
degrees are read off the diagonal (popcount(x & x) = |x|). Every
dispatch reports its h2d/d2h bytes and ticks a ranking round on
`core.transfer.GLOBAL`. The merge engine ranks on integer keys
(`core/merging.rank_keys`); `group_jaccard` keeps the float similarity
view for direct per-group scoring.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import faults
from repro.core.transfer import GLOBAL as TRANSFER
from repro.kernels.bitset_jaccard import ref
from repro.kernels.bitset_jaccard.kernel import (
    batch_masked_intersection_kernel, pairwise_intersection_kernel)
from repro.kernels.common import LruCache, default_interpret, pow2


def pack_bitsets(sets: list, universe: int) -> np.ndarray:
    """List of index-iterables -> (G, ceil(universe/32)) uint32 bitmaps."""
    W = (universe + 31) // 32
    out = np.zeros((len(sets), W), dtype=np.uint32)
    for i, s in enumerate(sets):
        idx = np.asarray(list(s), dtype=np.int64)
        if idx.size:
            np.bitwise_or.at(out[i], idx >> 5, np.uint32(1) << (idx & 31).astype(np.uint32))
    return out


def group_jaccard(bits, use_kernel: bool = True, interpret: bool = True):
    """(G, W) uint32 -> (G, G) float32 Jaccard similarity matrix."""
    bits = jnp.asarray(bits)
    if use_kernel:
        inter = pairwise_intersection_kernel(bits, interpret=interpret)
    else:
        inter = ref.pairwise_intersection(bits)
    deg = ref.popcount_u32(bits).sum(axis=-1).astype(jnp.int32)
    union = deg[:, None] + deg[None, :] - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1), 0.0).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Batched dispatch for the merge engine
# ---------------------------------------------------------------------------
_BATCH_JIT_CACHE = LruCache(16)


def _batched_intersection_fn(B: int, G: int, W: int, interpret: bool):
    key = (B, G, W, interpret)
    fn = _BATCH_JIT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(
            lambda b, v: batch_masked_intersection_kernel(
                b, v, interpret=interpret))
        _BATCH_JIT_CACHE[key] = fn
    return fn


def batched_pairwise_intersections(bits: np.ndarray, tile_b: int = 64,
                                   interpret=None) -> np.ndarray:
    """All-pairs intersection popcounts for a size-bucketed group batch.

    ``bits``: (B, G, W) uint32 bitmaps — one padded group per batch row.
    Returns (B, G, G) int64. W is rounded up to a power of two and B is
    processed in fixed ``tile_b`` tiles so the jit cache stays small; tile
    rows beyond the real batch are masked out inside the kernel, so the
    padding moves bytes but does no kernel work.
    """
    if interpret is None:
        interpret = default_interpret()
    # checked before any tile dispatch: an injected fault leaves the bitmap
    # batch untouched, so HostRankSource can fall back to the host popcount
    faults.check("kernel.bitset_jaccard.intersections")
    B, G, W = bits.shape
    Wp = pow2(W)
    out = np.empty((B, G, G), dtype=np.int64)
    for t0 in range(0, B, tile_b):
        nb = min(tile_b, B - t0)
        batch = np.zeros((tile_b, G, Wp), dtype=np.uint32)
        batch[:nb, :, :W] = bits[t0 : t0 + nb]
        fn = _batched_intersection_fn(tile_b, G, Wp, interpret)
        valid = np.array([nb], dtype=np.int32)
        TRANSFER.add_h2d(batch.nbytes + valid.nbytes)
        inter = np.asarray(fn(batch, valid))        # (tile_b, G, G) int32
        TRANSFER.add_d2h(inter.nbytes)
        TRANSFER.tick_round()
        out[t0 : t0 + nb] = inter[:nb].astype(np.int64)
    return out
