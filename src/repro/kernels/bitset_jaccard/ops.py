"""Jit'd public wrappers: pack neighbor sets and score candidate groups.

`batched_pairwise_jaccard` is the merge engine's entry point: a size bucket
of groups arrives as a list of (k_i, W_i) uint32 bitmaps, gets zero-padded
into (B, G, W) tiles (G, W rounded to powers of two so the jit cache stays
small), and all pairwise intersection popcounts come back from ONE vmap'd
`pairwise_intersection_kernel` dispatch per tile. Padded rows are all-zero,
so they never perturb real intersections; per-group degrees are read off the
diagonal (popcount(x & x) = |x|).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.bitset_jaccard import ref
from repro.kernels.bitset_jaccard.kernel import pairwise_intersection_kernel
from repro.kernels.common import default_interpret, pow2


def pack_bitsets(sets: list, universe: int) -> np.ndarray:
    """List of index-iterables -> (G, ceil(universe/32)) uint32 bitmaps."""
    W = (universe + 31) // 32
    out = np.zeros((len(sets), W), dtype=np.uint32)
    for i, s in enumerate(sets):
        idx = np.asarray(list(s), dtype=np.int64)
        if idx.size:
            np.bitwise_or.at(out[i], idx >> 5, np.uint32(1) << (idx & 31).astype(np.uint32))
    return out


def group_jaccard(bits, use_kernel: bool = True, interpret: bool = True):
    """(G, W) uint32 -> (G, G) float32 Jaccard similarity matrix."""
    bits = jnp.asarray(bits)
    if use_kernel:
        inter = pairwise_intersection_kernel(bits, interpret=interpret)
    else:
        inter = ref.pairwise_intersection(bits)
    deg = ref.popcount_u32(bits).sum(axis=-1).astype(jnp.int32)
    union = deg[:, None] + deg[None, :] - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1), 0.0).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Batched dispatch for the merge engine
# ---------------------------------------------------------------------------
_BATCH_JIT_CACHE: dict = {}


def _batched_intersection_fn(B: int, G: int, W: int, interpret: bool):
    key = (B, G, W, interpret)
    fn = _BATCH_JIT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(jax.vmap(
            lambda b: pairwise_intersection_kernel(b, interpret=interpret)
        ))
        _BATCH_JIT_CACHE[key] = fn
    return fn


def batched_pairwise_jaccard(bits: np.ndarray, tile_b: int = 64,
                             interpret=None) -> np.ndarray:
    """All-pairs Jaccard for a size-bucketed batch of groups.

    ``bits``: (B, G, W) uint32 bitmaps — one padded group per batch row.
    Returns (B, G, G) float64; padded (all-zero) rows score 0 everywhere.
    W is rounded up to a power of two so the jit cache stays small; B is
    processed in fixed ``tile_b`` tiles for the same reason.
    """
    if interpret is None:
        interpret = default_interpret()
    B, G, W = bits.shape
    Wp = pow2(W)
    out = np.empty((B, G, G), dtype=np.float64)
    for t0 in range(0, B, tile_b):
        nb = min(tile_b, B - t0)
        batch = np.zeros((tile_b, G, Wp), dtype=np.uint32)
        batch[:nb, :, :W] = bits[t0 : t0 + nb]
        fn = _batched_intersection_fn(tile_b, G, Wp, interpret)
        inter = np.asarray(fn(batch)).astype(np.int64)  # (tile_b, G, G)
        deg = np.diagonal(inter, axis1=1, axis2=2)      # popcount(x & x) = |x|
        union = deg[:, :, None] + deg[:, None, :] - inter
        out[t0 : t0 + nb] = np.where(
            union > 0, inter / np.maximum(union, 1), 0.0)[:nb]
    return out
