"""Jit'd public wrapper: pack neighbor sets and score candidate groups."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.bitset_jaccard import ref
from repro.kernels.bitset_jaccard.kernel import pairwise_intersection_kernel


def pack_bitsets(sets: list, universe: int) -> np.ndarray:
    """List of index-iterables -> (G, ceil(universe/32)) uint32 bitmaps."""
    W = (universe + 31) // 32
    out = np.zeros((len(sets), W), dtype=np.uint32)
    for i, s in enumerate(sets):
        idx = np.asarray(list(s), dtype=np.int64)
        if idx.size:
            np.bitwise_or.at(out[i], idx >> 5, np.uint32(1) << (idx & 31).astype(np.uint32))
    return out


def group_jaccard(bits, use_kernel: bool = True, interpret: bool = True):
    """(G, W) uint32 -> (G, G) float32 Jaccard similarity matrix."""
    bits = jnp.asarray(bits)
    if use_kernel:
        inter = pairwise_intersection_kernel(bits, interpret=interpret)
    else:
        inter = ref.pairwise_intersection(bits)
    deg = ref.popcount_u32(bits).sum(axis=-1).astype(jnp.int32)
    union = deg[:, None] + deg[None, :] - inter
    return jnp.where(union > 0, inter / jnp.maximum(union, 1), 0.0).astype(jnp.float32)
