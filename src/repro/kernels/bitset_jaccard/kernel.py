"""Pallas TPU kernel: blocked pairwise popcount(AND) over packed bitsets.

This is the candidate-scoring hot spot of the merging step (Sect. III-B3):
within a candidate group, partners are ranked by neighborhood Jaccard
similarity computed from packed uint32 bitmaps. The kernel tiles the (G, G)
output; each (BI, BJ) block streams the shared W dimension through VMEM in
BW-word chunks, accumulating SWAR popcounts of the AND — pure VPU arithmetic
with an MXU-friendly reduction layout.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _popcount(x):
    x = x - ((x >> np.uint32(1)) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> np.uint32(2)) & np.uint32(0x33333333))
    x = (x + (x >> np.uint32(4))) & np.uint32(0x0F0F0F0F)
    return ((x * np.uint32(0x01010101)) >> np.uint32(24)).astype(jnp.int32)


def _jaccard_block(a_ref, b_ref, out_ref, *, w_total: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]  # (BI, BW)
    b = b_ref[...]  # (BJ, BW)
    bw = a.shape[1]
    col = k * bw + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    a = jnp.where(col < w_total, a, jnp.uint32(0))
    inter = _popcount(a[:, None, :] & b[None, :, :]).sum(axis=-1)
    out_ref[...] += inter


def pairwise_intersection_kernel(bits: jax.Array,
                                 block_g: int = 128, block_w: int = 128,
                                 interpret: bool = True) -> jax.Array:
    """bits: (G, W) uint32 -> (G, G) int32 pairwise intersection popcounts."""
    G, W = bits.shape
    bg = min(block_g, G)
    bw = min(block_w, W)
    grid = (pl.cdiv(G, bg), pl.cdiv(G, bg), pl.cdiv(W, bw))
    return pl.pallas_call(
        functools.partial(_jaccard_block, w_total=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bg, bw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bg, bw), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bg, bg), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((G, G), jnp.int32),
        interpret=interpret,
    )(bits, bits)


def _masked_batch_block(valid_ref, bits_ref, out_ref, *, w_total: int):
    b = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # batch rows at/after the valid count are PADDING (the dispatch pads B
    # to a pow2 multiple of the shard count so the jit cache stays small):
    # they skip the O(G²·W) popcount entirely — padding costs transfer only
    @pl.when(b < valid_ref[0])
    def _accumulate():
        a = bits_ref[0]  # (G, BW)
        bw = a.shape[1]
        col = k * bw + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
        a = jnp.where(col < w_total, a, jnp.uint32(0))
        out_ref[0] += _popcount(a[:, None, :] & a[None, :, :]).sum(axis=-1)


def batch_masked_intersection_kernel(bits: jax.Array, valid: jax.Array,
                                     block_w: int = 128,
                                     interpret: bool = True) -> jax.Array:
    """bits (B, G, W) uint32, valid (1,) int32 -> (B, G, G) int32 pairwise
    intersection popcounts; batch rows ≥ valid early-exit to zeros."""
    B, G, W = bits.shape
    bw = min(block_w, W)
    grid = (B, pl.cdiv(W, bw))
    return pl.pallas_call(
        functools.partial(_masked_batch_block, w_total=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, G, bw), lambda b, k: (b, 0, k)),
        ],
        out_specs=pl.BlockSpec((1, G, G), lambda b, k: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, G, G), jnp.int32),
        interpret=interpret,
    )(valid, bits)
