"""Jit-cached dispatches for the resident merge-round device ops.

`ResidentBitmapArena` (core/resident.py) calls two functions per round:

* `topj_fn` — the fused ranking: all groups' (B, G, J) ranked top-J
  candidate columns from the RESIDENT bitmaps, then a device-side gather of
  the dirty rows, downloaded as (n, J) int8 — the only per-round score
  traffic.
* `fold_fn` — the bitset-OR fold: applies the round's accepted pairs to the
  resident bitmaps. Both positional buffers are donated, so the update is
  in place (the Pallas kernel additionally aliases input→output).

Dispatch picks the Pallas kernels on TPU and their integer-exact jnp twins
(`ref.py`) elsewhere (`kernels/common.default_use_kernel`); either path is
bit-identical (test-enforced). With a mesh, the batch axis is shard_map'd
over the data axes exactly like the PR-4 intersection dispatch. Compiled
executables live in small LRU caches keyed on padded shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.bitset_fold import ref
from repro.kernels.bitset_fold.kernel import (bitset_fold_kernel,
                                              jaccard_topj_kernel)
from repro.kernels.common import LruCache, mesh_content_key, shard_map_no_check

_TOPJ_CACHE = LruCache(16)
_FOLD_CACHE = LruCache(16)


def _shard(fn, mesh, axes, n_in, n_out):
    spec = P(axes if len(axes) > 1 else axes[0])
    return shard_map_no_check(
        fn, mesh, (spec,) * n_in,
        (spec,) * n_out if n_out > 1 else spec)


def topj_fn(B: int, G: int, W: int, J: int, n_pad: int, *, use_kernel: bool,
            interpret: bool, mesh=None, axes=("data",)):
    """Compiled ``(bits (B,G,W) u32, alive (B,G) i32, rows (n_pad,2) i32)
    -> (n_pad, J) int8`` ranked-candidate gather, LRU-cached on shapes."""
    key = ("topj", B, G, W, J, n_pad, use_kernel, interpret, mesh_content_key(mesh))
    fn = _TOPJ_CACHE.get(key)
    if fn is not None:
        return fn

    if use_kernel or mesh is not None:
        # all-groups compute (vmap/shard-friendly), dirty rows gathered on
        # device so only (n, J) crosses the boundary
        if use_kernel:
            def all_topj(bits, alive):
                return jax.vmap(
                    lambda bb, aa: jaccard_topj_kernel(bb, aa[:, None], J,
                                                       interpret=interpret)
                )(bits, alive)
        else:
            all_topj = functools.partial(ref.topj_all, J=J)
        ranked = (_shard(all_topj, mesh, axes, 2, 1) if mesh is not None
                  else all_topj)

        @jax.jit
        def fn(bits, alive, rows):
            t = ranked(bits, alive)                # (B, G, J) int32
            return t[rows[:, 0], rows[:, 1]].astype(jnp.int8)
    else:
        # single-device jnp twin: compute the selected rows only — integer-
        # identical to the gather above, O(n·G·W) instead of O(B·G²·W)
        @jax.jit
        def fn(bits, alive, rows):
            return ref.topj_rows(bits, alive, rows, J).astype(jnp.int8)

    _TOPJ_CACHE[key] = fn
    return fn


def fold_fn(B: int, G: int, W: int, P_pairs: int, *, use_kernel: bool,
            interpret: bool, mesh=None, axes=("data",)):
    """Compiled ``(bits, alive, instr (B,P,8) i32) -> (bits', alive')`` with
    bits/alive donated — the resident buffers fold in place."""
    key = ("fold", B, G, W, P_pairs, use_kernel, interpret, mesh_content_key(mesh))
    fn = _FOLD_CACHE.get(key)
    if fn is not None:
        return fn

    if use_kernel:
        def one(bits_g, alive_g, instr_g):
            b2, a2 = bitset_fold_kernel(bits_g, alive_g[:, None], instr_g,
                                        interpret=interpret)
            return b2, a2[:, 0]
    else:
        one = ref.fold_pairs
    v = jax.vmap(one)
    folded = _shard(v, mesh, axes, 3, 2) if mesh is not None else v

    def widened(bits, alive, instr):
        # instr crosses the wire as int16; index arithmetic wants int32
        return folded(bits, alive, instr.astype(jnp.int32))

    fn = jax.jit(widened, donate_argnums=(0, 1))
    _FOLD_CACHE[key] = fn
    return fn
