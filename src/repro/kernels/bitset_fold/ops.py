"""Jit-cached dispatches for the resident merge-round device ops.

`ResidentBitmapArena` (core/resident.py) calls two functions per round:

* `topj_fn` — the fused ranking: all groups' (B, G, J) ranked top-J
  candidate columns from the RESIDENT bitmaps, then a device-side gather of
  the dirty rows, downloaded as (n, J) int8 — the only per-round score
  traffic.
* `fold_fn` — the bitset-OR fold: applies the round's accepted pairs to the
  resident bitmaps. Both positional buffers are donated, so the update is
  in place (the Pallas kernel additionally aliases input→output).

Dispatch picks the Pallas kernels on TPU and their integer-exact jnp twins
(`ref.py`) elsewhere (`kernels/common.default_use_kernel`); either path is
bit-identical (test-enforced). With a mesh, the batch axis is shard_map'd
over the data axes exactly like the PR-4 intersection dispatch. Compiled
executables live in small LRU caches keyed on padded shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import faults
from repro.kernels.bitset_fold import ref
from repro.kernels.bitset_fold.kernel import (bitset_fold_kernel,
                                              jaccard_topj_kernel)
from repro.kernels.common import LruCache, mesh_content_key, shard_map_no_check

_TOPJ_CACHE = LruCache(16)
_FOLD_CACHE = LruCache(16)
_ROUND_CACHE = LruCache(32)
_FOLDC_CACHE = LruCache(16)
_EXTRACT_CACHE = LruCache(32)


def _checked(site: str, fn):
    """Fault-injection hook around one compiled dispatch. The check runs
    BEFORE the jit call, while the donated input buffers are still intact —
    an injected dispatch fault is therefore retry-safe (the arena retries
    once on the ref twin, DESIGN.md §11)."""
    def call(*args):
        faults.check(site)
        return fn(*args)
    return call


def _shard(fn, mesh, axes, n_in, n_out):
    spec = P(axes if len(axes) > 1 else axes[0])
    return shard_map_no_check(
        fn, mesh, (spec,) * n_in,
        (spec,) * n_out if n_out > 1 else spec)


def topj_fn(B: int, G: int, W: int, J: int, n_pad: int, *, use_kernel: bool,
            interpret: bool, mesh=None, axes=("data",)):
    """Compiled ``(bits (B,G,W) u32, alive (B,G) i32, rows (n_pad,2) i32)
    -> (n_pad, J) int8`` ranked-candidate gather, LRU-cached on shapes."""
    key = ("topj", B, G, W, J, n_pad, use_kernel, interpret, mesh_content_key(mesh))
    fn = _TOPJ_CACHE.get(key)
    if fn is not None:
        return fn

    if use_kernel or mesh is not None:
        # all-groups compute (vmap/shard-friendly), dirty rows gathered on
        # device so only (n, J) crosses the boundary
        if use_kernel:
            def all_topj(bits, alive):
                return jax.vmap(
                    lambda bb, aa: jaccard_topj_kernel(bb, aa[:, None], J,
                                                       interpret=interpret)
                )(bits, alive)
        else:
            all_topj = functools.partial(ref.topj_all, J=J)
        ranked = (_shard(all_topj, mesh, axes, 2, 1) if mesh is not None
                  else all_topj)

        @jax.jit
        def fn(bits, alive, rows):
            t = ranked(bits, alive)                # (B, G, J) int32
            return t[rows[:, 0], rows[:, 1]].astype(jnp.int8)
    else:
        # single-device jnp twin: compute the selected rows only — integer-
        # identical to the gather above, O(n·G·W) instead of O(B·G²·W)
        @jax.jit
        def fn(bits, alive, rows):
            return ref.topj_rows(bits, alive, rows, J).astype(jnp.int8)

    fn = _checked("kernel.bitset_fold.topj", fn)
    _TOPJ_CACHE[key] = fn
    return fn


def fold_fn(B: int, G: int, W: int, P_pairs: int, *, use_kernel: bool,
            interpret: bool, mesh=None, axes=("data",)):
    """Compiled ``(bits, alive, instr (B,P,8) i32) -> (bits', alive')`` with
    bits/alive donated — the resident buffers fold in place."""
    key = ("fold", B, G, W, P_pairs, use_kernel, interpret, mesh_content_key(mesh))
    fn = _FOLD_CACHE.get(key)
    if fn is not None:
        return fn

    if use_kernel:
        def one(bits_g, alive_g, instr_g):
            b2, a2 = bitset_fold_kernel(bits_g, alive_g[:, None], instr_g,
                                        interpret=interpret)
            return b2, a2[:, 0]
    else:
        one = ref.fold_pairs
    v = jax.vmap(one)
    folded = _shard(v, mesh, axes, 3, 2) if mesh is not None else v

    def widened(bits, alive, instr):
        # instr crosses the wire as int16; index arithmetic wants int32
        return folded(bits, alive, instr.astype(jnp.int32))

    fn = _checked("kernel.bitset_fold.fold",
                  jax.jit(widened, donate_argnums=(0, 1)))
    _FOLD_CACHE[key] = fn
    return fn


# ---------------------------------------------------------------------------
# Whole-iteration residency round ops (DESIGN.md §9, ISSUE 7)
# ---------------------------------------------------------------------------
def round_fn(B: int, G: int, R: int, W: int, K: int, J: int, top_j: int, *,
             height_bound, use_kernel: bool, interpret: bool, mesh=None,
             axes=("data",)):
    """Compiled fused proposal round over the RESIDENT state.

    ``(bits, alive, dirty, CNT, colsize, memcol, s, selfc, nd, hgt, cost,
    theta_p) -> (dirty', out)``. The dirty-row list never crosses the
    boundary: the device derives it from its own ``dirty`` mirror
    (`jnp.nonzero` in row-major order — exactly the host's
    ``np.nonzero``), evaluates ranking + exact integer Saving + θ̂
    acceptance, and updates ``dirty`` in place (rows whose best Saving
    fails θ̂ leave the queue, matching the host sweep). Only ``out``
    (K, 2) int8 ``[accept, partner]`` comes back. ``theta_p`` is a traced
    uint32 scalar so θ stays out of the compiled shapes.

    Under a mesh the batch axis is sharded and `ref.round_all` evaluates
    every row (a sharded nonzero has no global order), so ``out`` is
    (B, G, 2) and the host gathers its dirty rows; decisions are
    identical. With ``use_kernel`` the Pallas `jaccard_topj` kernel owns
    the O(G²·W) ranking and `ref.round_from_ranked` the exact-Saving
    tail — the jnp path fuses both in `ref.round_rows`.
    """
    key = ("round", B, G, R, W, K, J, top_j, height_bound, use_kernel,
           interpret, mesh_content_key(mesh))
    fn = _ROUND_CACHE.get(key)
    if fn is not None:
        return fn

    if mesh is not None:
        def all_round(bits, alive, dirty, CNT, colsize, memcol, s, selfc,
                      nd, hgt, cost):
            return ref.round_all(bits, alive, dirty, CNT, colsize, memcol,
                                 s, selfc, nd, hgt, cost, J, top_j,
                                 height_bound)
        sharded = _shard(all_round, mesh, axes, 11, 1)

        @functools.partial(jax.jit, donate_argnums=(2,))
        def fn(bits, alive, dirty, CNT, colsize, memcol, s, selfc, nd, hgt,
               cost, theta_p):
            res = sharded(bits, alive, dirty, CNT, colsize, memcol, s,
                          selfc, nd, hgt, cost)                 # (B, G, 4)
            ok = (res[..., 0] > 0) & ref.theta_accept(
                res[..., 1], res[..., 2], theta_p)
            out = jnp.stack([ok.astype(jnp.int8),
                             res[..., 3].astype(jnp.int8)], axis=-1)
            # non-dirty rows had has=0 → ok=0, so a plain overwrite IS the
            # host rule "dirty rows stay dirty iff their proposal passed"
            return ok.astype(dirty.dtype), out
    else:
        @functools.partial(jax.jit, donate_argnums=(2,))
        def fn(bits, alive, dirty, CNT, colsize, memcol, s, selfc, nd, hgt,
               cost, theta_p):
            rb, rr = jnp.nonzero(dirty > 0, size=K, fill_value=(B, 0))
            rows = jnp.stack([rb.astype(jnp.int32),
                              rr.astype(jnp.int32)], axis=1)
            if use_kernel:
                cand_all = jax.vmap(
                    lambda bb, aa: jaccard_topj_kernel(bb, aa[:, None], J,
                                                       interpret=interpret)
                )(bits, alive)                                  # (B, G, J)
                cand = cand_all[jnp.minimum(rows[:, 0], B - 1), rows[:, 1]]
                res = ref.round_from_ranked(
                    alive, dirty, CNT, colsize, memcol, s, selfc, nd, hgt,
                    cost, rows, cand, top_j, height_bound)
            else:
                res = ref.round_rows(bits, alive, dirty, CNT, colsize,
                                     memcol, s, selfc, nd, hgt, cost, rows,
                                     J, top_j, height_bound)    # (K, 4)
            ok = (res[:, 0] > 0) & ref.theta_accept(res[:, 1], res[:, 2],
                                                    theta_p)
            out = jnp.stack([ok.astype(jnp.int8),
                             res[:, 3].astype(jnp.int8)], axis=-1)
            dirty = dirty.at[rows[:, 0], rows[:, 1]].set(
                ok.astype(dirty.dtype), mode="drop")
            return dirty, out

    fn = _checked("kernel.bitset_fold.round", fn)
    _ROUND_CACHE[key] = fn
    return fn


def extract_fn(Bp: int, G: int, Rp: int, Wp: int, Lp: int, cap: int,
               E: int):
    """Compiled bank→arena extraction (ISSUE 9, DESIGN.md §9).

    ``(gids (E,), cnts (E,), size (cap,), selfc, nd, hgt, res_map (cap,),
    members (Bp,G) i32, ptr (Bp,G) i32, lens (Bp,G) i32) -> 11-tuple`` of
    a fresh chunk's resident state: bits (Bp,G,Wp) u32, alive/dirty i8,
    CNT (Bp,G,Rp) i32, colsize (Bp,Rp) i32, memcol/s/selfc/nd/hgt/cost
    (Bp,G) i32 — the exact shapes/dtypes `ResidentBitmapArena` uploads on
    the host-rebuilt path. The bank arrays are read WITHOUT donation, so
    concurrent chunk thunks may extract from the same bank.
    """
    key = ("extract", Bp, G, Rp, Wp, Lp, cap, E)
    fn = _EXTRACT_CACHE.get(key)
    if fn is not None:
        return fn

    per_b = functools.partial(ref.bank_extract_group, Rp=Rp, Wp=Wp, Lp=Lp)

    @jax.jit
    def fn(gids, cnts, size, selfc, nd, hgt, res_map, members, ptr, lens):
        return jax.vmap(per_b,
                        in_axes=(None, None, None, None, None, None, None,
                                 0, 0, 0))(gids, cnts, size, selfc, nd,
                                           hgt, res_map, members, ptr, lens)

    fn = _checked("kernel.bitset_fold.extract", fn)
    _EXTRACT_CACHE[key] = fn
    return fn


def fold_counts_fn(B: int, G: int, R: int, W: int, P_pairs: int, *,
                   use_kernel: bool, interpret: bool, mesh=None,
                   axes=("data",)):
    """Compiled count-carrying fold: ``(bits, alive, dirty, CNT, colsize,
    memcol, s, selfc, nd, hgt, cost, instr (B,P,3) i32) -> 10-tuple`` of
    updated state (everything but ``memcol``, which merges never change).
    All state buffers are donated — the resident iteration state folds in
    place. With ``use_kernel`` the bitmap phase runs in the Pallas
    `bitset_fold` kernel (instruction word/bit fields derived on device
    from the resident ``memcol``) and the count phases in the jnp ref;
    the phases share no reads, so the split is exact.
    """
    key = ("foldc", B, G, R, W, P_pairs, use_kernel, interpret,
           mesh_content_key(mesh))
    fn = _FOLDC_CACHE.get(key)
    if fn is not None:
        return fn

    if use_kernel:
        def one(bits, alive, dirty, CNT, colsize, memcol, s, selfc, nd,
                hgt, cost, instr):
            out = ref.fold_pairs_counts(bits, alive, dirty, CNT, colsize,
                                        memcol, s, selfc, nd, hgt, cost,
                                        instr, with_bits=False)
            valid = instr[:, 2] > 0
            ag = jnp.minimum(jnp.where(valid, instr[:, 0], 0), G - 1)
            zg = jnp.minimum(jnp.where(valid, instr[:, 1], 0), G - 1)
            ca = memcol[ag]
            cz = memcol[zg]
            instr8 = jnp.stack(
                [ag, zg, ca >> 5, ca & 31, cz >> 5, cz & 31,
                 instr[:, 2], jnp.zeros_like(ca)], axis=1).astype(jnp.int32)
            nb, _ = bitset_fold_kernel(bits, alive[:, None], instr8,
                                       interpret=interpret)
            return (nb,) + tuple(out[1:])
    else:
        one = ref.fold_pairs_counts
    v = jax.vmap(one)
    folded = _shard(v, mesh, axes, 12, 10) if mesh is not None else v
    fn = _checked("kernel.bitset_fold.fold_counts",
                  jax.jit(folded, donate_argnums=(0, 1, 2, 3, 4, 6, 7, 8,
                                                  9, 10)))
    _FOLDC_CACHE[key] = fn
    return fn
