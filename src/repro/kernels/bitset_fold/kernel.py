"""Pallas TPU kernels for device-resident merge rounds (DESIGN.md §9).

Two kernels over one group's packed (G, W) uint32 neighbor bitmaps:

* `jaccard_topj_kernel` — the fused ranking step: streams the W axis
  through VMEM accumulating pairwise SWAR intersection popcounts into a
  (G, G) scratch, then — on the last W block — turns them into quantized
  integer Jaccard keys and reduces to each row's ranked top-J candidate
  columns ON DEVICE. The host receives (G, J) instead of a (G, G) score
  matrix; the ranking order (key desc, column asc, dead/self last) is
  bit-identical to the host sweep's stable argsort (see `ref.py`).
* `bitset_fold_kernel` — the bitset-OR merge fold: applies one round's
  accepted pairs to the resident bitmaps in place (input/output aliased, so
  under jit donation nothing round-trips to host). Pairs are sequential in
  a fori_loop: their rows are disjoint, but member columns of different
  pairs may share a 32-bit word.

Both kernels hold a whole group block in VMEM — the merge engine caps
groups at G ≤ 128 members and chunks column universes by a memory budget,
so (G, W) and (G, G) blocks are a few hundred KB at most.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.bitset_fold import ref


def _popcount(x):
    x = x - ((x >> np.uint32(1)) & np.uint32(0x55555555))
    x = (x & np.uint32(0x33333333)) + ((x >> np.uint32(2)) & np.uint32(0x33333333))
    x = (x + (x >> np.uint32(4))) & np.uint32(0x0F0F0F0F)
    return ((x * np.uint32(0x01010101)) >> np.uint32(24)).astype(jnp.int32)


def _topj_block(alive_ref, bits_ref, out_ref, inter_ref, *, w_total: int,
                J: int):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        inter_ref[...] = jnp.zeros_like(inter_ref)

    a = bits_ref[...]  # (G, BW)
    bw = a.shape[1]
    word = k * bw + jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    a = jnp.where(word < w_total, a, jnp.uint32(0))
    inter_ref[...] += _popcount(a[:, None, :] & a[None, :, :]).sum(axis=-1)

    @pl.when(k == pl.num_programs(0) - 1)
    def _reduce():
        inter = inter_ref[...]
        G = inter.shape[0]
        deg = jnp.diagonal(inter)  # popcount(x & x) = |x|
        # the bit-identity-critical key arithmetic has ONE jnp home
        # (ref.rank_keys / ref.combined_key, pure elementwise, traceable
        # inside the kernel body); only top-k selection differs — unique
        # combined keys make iterative argmax here and lax.top_k in the
        # jnp twin rank identically with no tie rule anywhere
        key = ref.rank_keys(inter, deg[:, None], deg[None, :])
        col = jax.lax.broadcasted_iota(jnp.int32, (G, G), 1)
        row = jax.lax.broadcasted_iota(jnp.int32, (G, G), 0)
        ok = (alive_ref[...][:, 0] > 0)[None, :] & (col != row)
        ckey = ref.combined_key(key, ok, col, G)
        for j in range(J):
            idx = jnp.argmax(ckey, axis=1).astype(jnp.int32)
            out_ref[:, j] = idx
            ckey = jnp.where(col == idx[:, None], jnp.int32(-(2**31) + 1),
                             ckey)


def jaccard_topj_kernel(bits: jax.Array, alive: jax.Array, J: int,
                        block_w: int = 512, interpret: bool = True
                        ) -> jax.Array:
    """bits (G, W) uint32, alive (G, 1) int8/int32 -> (G, J) int32 ranked
    candidate columns (quantized-Jaccard desc, column asc, dead/self last).
    """
    G, W = bits.shape
    bw = min(block_w, W)
    grid = (pl.cdiv(W, bw),)
    return pl.pallas_call(
        functools.partial(_topj_block, w_total=W, J=J),
        grid=grid,
        in_specs=[
            pl.BlockSpec((G, 1), lambda k: (0, 0)),
            pl.BlockSpec((G, bw), lambda k: (0, k)),
        ],
        out_specs=pl.BlockSpec((G, J), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((G, J), jnp.int32),
        scratch_shapes=[pltpu.VMEM((G, G), jnp.int32)],
        interpret=interpret,
    )(alive, bits)


def _fold_block(instr_ref, bits_ref, alive_ref, obits_ref, oalive_ref, *,
                P: int):
    obits_ref[...] = bits_ref[...]
    oalive_ref[...] = alive_ref[...]
    one = jnp.uint32(1)

    def body(p, _):
        @pl.when(instr_ref[p, 6] > 0)
        def _pair():
            ar, zr = instr_ref[p, 0], instr_ref[p, 1]
            wa, wz = instr_ref[p, 2], instr_ref[p, 4]
            ba = instr_ref[p, 3].astype(jnp.uint32)
            bz = instr_ref[p, 5].astype(jnp.uint32)
            # fold member column cz into ca for every row …
            colz = (obits_ref[:, wz] >> bz) & one
            obits_ref[:, wa] = obits_ref[:, wa] | (colz << ba)
            obits_ref[:, wz] = obits_ref[:, wz] & ~(one << bz)
            # … then OR row z into row a and retire z
            rowz = obits_ref[zr, :]
            obits_ref[ar, :] = obits_ref[ar, :] | rowz
            obits_ref[zr, :] = jnp.zeros_like(rowz)
            obits_ref[ar, wa] = obits_ref[ar, wa] & ~(one << ba)
            oalive_ref[zr, 0] = jnp.int8(0)
        return 0

    jax.lax.fori_loop(0, P, body, 0)


def bitset_fold_kernel(bits: jax.Array, alive: jax.Array, instr: jax.Array,
                       interpret: bool = True):
    """Apply one round's merge pairs in place.

    bits (G, W) uint32, alive (G, 1) int8, instr (P, 8) int32 rows
    ``[a_row, z_row, wa, ba, wz, bz, valid, _]``. Returns (bits', alive'),
    aliased onto the inputs — with jit donation the resident buffers update
    without any host round-trip.
    """
    G, W = bits.shape
    P = instr.shape[0]
    return pl.pallas_call(
        functools.partial(_fold_block, P=P),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((G, W), lambda: (0, 0)),
            pl.BlockSpec((G, 1), lambda: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((G, W), lambda: (0, 0)),
            pl.BlockSpec((G, 1), lambda: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, W), jnp.uint32),
            jax.ShapeDtypeStruct((G, 1), jnp.int8),
        ],
        input_output_aliases={1: 0, 2: 1},
        interpret=interpret,
    )(instr, bits, alive)
