"""jnp twins for the resident merge-round kernels (bitset_fold).

Everything here is INTEGER-EXACT and must stay in bit-for-bit lockstep with
three other implementations: the Pallas kernels in `kernel.py`, the NumPy
host ranking in `core/merging.py` (`rank_keys` / the per-round argsort), and
the host bitmap fold in `BatchedGroupWorkspace.apply_merges`. The merge
engine's cross-backend bit-identity rests on that agreement (DESIGN.md §9),
so these functions avoid floating point entirely:

* ``rank_keys`` — the quantized-Jaccard ranking key: shift intersection and
  union down together until the union fits 15 bits, then take the exact
  integer quotient ``(iq << 15) // uq``. Pure int32-safe arithmetic, so the
  key is identical on NumPy, XLA CPU, and TPU (no float division whose
  rounding could differ across backends).
* ``topj_all`` — per-row ranked top-J candidate columns by (key desc,
  column asc), dead/self columns last; J iterative argmax passes over a
  combined key that encodes the column tie-break, so there are never ties.
* ``fold_pairs`` — the bitset-OR merge fold: per accepted pair, fold column
  cz into ca for every row, OR row z into row a, clear z, clear a's own
  bit. Sequential over the (disjoint) pairs of a group, exactly like the
  kernel's fori_loop.
* the ISSUE-7 on-device Saving layer: 32-bit-limb wide multiply/compare
  (`umul32_wide` / `prod_lt`) so the rational Saving argmax and the
  quantized-θ acceptance are EXACT in int32/uint32 arithmetic (x64 stays
  disabled on device), the clamped integer pair costs (`poss_pair_c` /
  `poss_self_c` / `pair_cost_c`, mirrored by `core/merging.py` in int64),
  and the fused per-round proposal evaluation (`round_all` / `round_rows`)
  plus the count-carrying fold (`fold_pairs_counts`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitset_jaccard.ref import popcount_u32 as _swar_popcount

_KEY_BITS = 15

# Integer-exact Saving contract (DESIGN.md §9). All backends clamp the
# "possible pairs" terms at C_CLAMP with the SAME expression, so decisions
# agree bit-for-bit even at the clamp; the host workspace build guards that
# real costs stay far below the clamp (exactness, not just agreement).
C_CLAMP = 1 << 30
# θ is quantized to θ̂ = P/2^20 with P = clip(ceil(θ·2^20), 0, 2^20): the
# acceptance test becomes the integer inequality (d−n)·2^20 ≥ P·d, identical
# on host int64 and device uint32 limbs. θ = 0 → P = 0 accepts Saving ≥ 0.
THETA_SHIFT = 20

if hasattr(jnp, "bitwise_count"):  # native popcnt lowering (jax ≥ 0.4.27)
    def popcount_u32(x):
        return jnp.bitwise_count(x).astype(jnp.int32)
else:  # pragma: no cover - old jax
    popcount_u32 = _swar_popcount  # quantized keys live in [0, 2^15]; (key+1)*G fits int32


def bit_length(v):
    """Elementwise bit length of non-negative int32/int64 (< 2^31) values —
    the 5-step binary search is identical in NumPy and jnp."""
    b = jnp.zeros_like(v)
    for s in (16, 8, 4, 2, 1):
        t = v >> s
        big = t > 0
        b = b + jnp.where(big, s, 0)
        v = jnp.where(big, t, v)
    return b + (v > 0).astype(v.dtype)


def rank_keys(inter, deg_r, deg_c):
    """Quantized-Jaccard integer ranking keys (DESIGN.md §9).

    ``inter`` intersection counts, ``deg_r``/``deg_c`` the two rows' set
    sizes (broadcastable). Returns keys in ``[0, 2^15]`` that order exactly
    like ``inter/union`` up to the 15-bit quantization, computed with shift
    and integer-divide only.
    """
    inter = inter.astype(jnp.int32)
    union = deg_r.astype(jnp.int32) + deg_c.astype(jnp.int32) - inter
    sh = jnp.maximum(0, bit_length(union) - _KEY_BITS)
    return ((inter >> sh) << _KEY_BITS) // jnp.maximum(union >> sh, 1)


def combined_key(keys, ok, col, G: int):
    """Strict total order encoding: ``(key+1)*G - 1 - col`` for eligible
    columns, ``-1 - col`` for dead/self. Every entry is UNIQUE (the column
    is folded into both branches), so any top-k — `lax.top_k`, the kernel's
    iterative argmax, the host's stable argsort on ``-key`` — produces the
    SAME ranking: key desc, column asc, dead/self last (in asc column
    order, matching the stable sort over the host's uniform -1 keys)."""
    return jnp.where(ok, (keys + 1) * G - 1 - col, -1 - col)


def _topk_ranked(ckey, J: int):
    """Ranked top-J columns of the (…, G) combined keys; keys are unique,
    so top_k needs no tie rule."""
    _, idx = jax.lax.top_k(ckey, J)
    return idx.astype(jnp.int32)


def topj_all(bits, alive, J: int):
    """All rows' ranked top-J candidate columns, one group batch at a time.

    ``bits`` (B, G, W) uint32 packed neighbor bitmaps, ``alive`` (B, G)
    int8/int32/bool. Returns (B, G, J) int32 column indices, ranked by the
    exact (quantized key desc, column asc) order with dead/self columns
    last — the device analogue of the host sweep's per-row stable argsort
    prefix.
    """
    B, G, W = bits.shape
    inter = popcount_u32(bits[:, :, None, :] & bits[:, None, :, :]).sum(
        axis=-1).astype(jnp.int32)                      # (B, G, G)
    deg = jnp.diagonal(inter, axis1=1, axis2=2)         # popcount(x&x) = |x|
    keys = rank_keys(inter, deg[:, :, None], deg[:, None, :])
    col = jax.lax.broadcasted_iota(jnp.int32, (B, G, G), 2)
    row = jax.lax.broadcasted_iota(jnp.int32, (B, G, G), 1)
    ok = (alive[:, None, :] > 0) & (col != row)
    return _topk_ranked(combined_key(keys, ok, col, G), J)


def topj_rows(bits, alive, rows, J: int):
    """Ranked top-J for SELECTED rows only — the single-device fast path.

    ``rows`` (n, 2) int32 [group, row] pairs (padded rows compute garbage
    the caller discards). Integer-identical to gathering those rows out of
    `topj_all`; computing (n, G) instead of (B, G, G) intersections is what
    makes late merge rounds (few dirty rows) cheap.
    """
    B, G, W = bits.shape
    rb, rr = rows[:, 0], rows[:, 1]
    rowbits = bits[rb, rr]                                   # (n, W)
    inter = popcount_u32(rowbits[:, None, :] & bits[rb]).sum(
        axis=-1).astype(jnp.int32)                           # (n, G)
    deg = popcount_u32(bits).sum(axis=-1).astype(jnp.int32)  # (B, G)
    keys = rank_keys(inter, deg[rb, rr][:, None], deg[rb])
    col = jax.lax.broadcasted_iota(jnp.int32, inter.shape, 1)
    ok = (alive[rb] > 0) & (col != rr[:, None])
    return _topk_ranked(combined_key(keys, ok, col, G), J)


def fold_pairs(bits, alive, instr):
    """Apply one round's accepted merges to one group's resident bitmaps.

    ``bits`` (G, W) uint32, ``alive`` (G,) int32, ``instr`` (P, 8) int32
    rows ``[a_row, z_row, wa, ba, wz, bz, valid, _]`` (word/bit positions of
    the a/z member columns in the uint32 layout; ``valid`` gates padding
    rows). Pairs apply sequentially — their rows are disjoint, but two
    pairs' member columns may share a 32-bit word, so the word updates must
    be read-modify-write in order, exactly as the kernel's fori_loop and
    the host fold's unbuffered ``.at`` ops.
    """
    one = jnp.uint32(1)

    def body(p, carry):
        b, a = carry
        row = instr[p]
        valid = row[6] > 0
        ar, zr, wa, wz = row[0], row[1], row[2], row[4]
        ba = row[3].astype(jnp.uint32)
        bz = row[5].astype(jnp.uint32)
        colz = (b[:, wz] >> bz) & one
        nb = b.at[:, wa].set(b[:, wa] | (colz << ba))
        nb = nb.at[:, wz].set(nb[:, wz] & ~(one << bz))
        rowz = nb[zr]
        nb = nb.at[ar].set(nb[ar] | rowz)
        nb = nb.at[zr].set(jnp.zeros_like(rowz))
        nb = nb.at[ar, wa].set(nb[ar, wa] & ~(one << ba))
        na = a.at[zr].set(0)
        return jnp.where(valid, nb, b), jnp.where(valid, na, a)

    return jax.lax.fori_loop(0, instr.shape[0], body, (bits, alive))


# ---------------------------------------------------------------------------
# 32-bit-limb exact arithmetic (device x64 is disabled; int64 is unavailable)
# ---------------------------------------------------------------------------
def umul32_wide(x, y):
    """Exact 64-bit product of two non-negative int32/uint32 values as
    (hi, lo) uint32 limbs, via 16-bit half-word partial products."""
    x = x.astype(jnp.uint32)
    y = y.astype(jnp.uint32)
    m = jnp.uint32(0xFFFF)
    xl, xh = x & m, x >> jnp.uint32(16)
    yl, yh = y & m, y >> jnp.uint32(16)
    ll = xl * yl
    lh = xl * yh
    hl = xh * yl
    mid = (ll >> jnp.uint32(16)) + (lh & m) + (hl & m)   # < 3·2^16, no wrap
    lo = (mid << jnp.uint32(16)) | (ll & m)
    hi = xh * yh + (lh >> jnp.uint32(16)) + (hl >> jnp.uint32(16)) + (
        mid >> jnp.uint32(16))
    return hi, lo


def wide_gt(h1, l1, h2, l2):
    return (h1 > h2) | ((h1 == h2) & (l1 > l2))


def prod_lt(a, b, c, d):
    """a·b < c·d, exact, for non-negative int32 operands (via limbs)."""
    h1, l1 = umul32_wide(a, b)
    h2, l2 = umul32_wide(c, d)
    return wide_gt(h2, l2, h1, l1)


def theta_accept(numer, denom, theta_p):
    """Saving ≥ θ̂ as an exact integer test: denom > 0, numer ≤ denom and
    (denom − numer)·2^20 ≥ theta_p·denom. ``theta_p`` is a traced uint32
    scalar (P = clip(ceil(θ·2^20), 0, 2^20)); host twin in int64 is
    `core/merging.theta_accept_host`."""
    ok = (denom > 0) & (numer <= denom)
    diff = jnp.maximum(denom - numer, 0)
    h1, l1 = umul32_wide(diff, jnp.uint32(1 << THETA_SHIFT))
    h2, l2 = umul32_wide(jnp.broadcast_to(theta_p, diff.shape), denom)
    ge = ~wide_gt(h2, l2, h1, l1)
    return ok & ge


# ---------------------------------------------------------------------------
# Clamped integer pair costs (identical expressions on host int64)
# ---------------------------------------------------------------------------
def poss_pair_c(s_m, colsize):
    """min(s_m·colsize, C_CLAMP) without int32 overflow: the div-guarded
    `where` is exactly the clamped product for non-negative operands."""
    C = jnp.int32(C_CLAMP)
    big = s_m > C // jnp.maximum(colsize, 1)
    return jnp.where(big, C, s_m * colsize)


def poss_self_c(s):
    """min(s·(s−1)/2, C_CLAMP) without overflow (divide the even factor
    by 2 before multiplying; clamp above s = 46341)."""
    C = jnp.int32(C_CLAMP)
    half = jnp.where(s % 2 == 0, (s >> 1) * (s - 1), s * ((s - 1) >> 1))
    return jnp.where(s > 46341, C, jnp.minimum(half, C))


def pair_cost_c(cnt, poss_c):
    """min(cnt, poss − cnt + 1) on the clamped poss — 0 at cnt == 0."""
    return jnp.minimum(cnt, poss_c - cnt + 1)


# ---------------------------------------------------------------------------
# Fused per-round proposal evaluation (rank + exact Saving + argmax)
# ---------------------------------------------------------------------------
def _row_saving_terms(cnt_r, cnt_c, colsize_r, ca, cz, s_r, s_c, selfc_r,
                      selfc_c, nd_r, nd_c, cost_r, cost_c):
    """(numer, denom) of merging each row r with one candidate c — all int32,
    elementwise over the leading axis; the int64 host twin is
    `BatchedGroupWorkspace.saving_terms_rows`."""
    ri = jnp.arange(cnt_r.shape[0])
    merged = cnt_r + cnt_c
    s_m = s_r + s_c
    poss = poss_pair_c(s_m[:, None], colsize_r)
    cost_cols = pair_cost_c(merged, poss)
    total = cost_cols.sum(axis=-1) - cost_cols[ri, ca] - cost_cols[ri, cz]
    cab = cnt_r[ri, cz]
    self_m = selfc_r + selfc_c + cab
    total = total + pair_cost_c(self_m, poss_self_c(s_m))
    numer = total + nd_r + nd_c + jnp.int32(2)
    pair_c = pair_cost_c(cab, poss_pair_c(s_r, s_c))
    denom = cost_r + cost_c - pair_c
    return numer, denom


def round_all(bits, alive, dirty, CNT, colsize, memcol, s, selfc, nd, hgt,
              cost, J: int, top_j: int, height_bound):
    """Best-candidate proposal of EVERY row of one batch: (B, G, 4) int32
    ``[has, numer, denom, z]``.

    Streams the ranked candidates one at a time (J argmax passes over the
    combined keys — identical ranking to `topj_all`), evaluating the exact
    integer Saving terms per candidate and keeping the best by the exact
    rational comparison ``n_j·d_best < n_best·d_j`` (strict, so ranked ties
    keep the earlier candidate — the host sweep's first-max rule). θ is NOT
    applied here: the caller tests `theta_accept` on (numer, denom), which
    keeps θ out of the compiled shapes. ``dirty`` only masks ``has`` so
    clean rows never propose.
    """
    B, G, W = bits.shape
    R = CNT.shape[-1]
    inter = popcount_u32(bits[:, :, None, :] & bits[:, None, :, :]).sum(
        axis=-1).astype(jnp.int32)                       # (B, G, G)
    deg = jnp.diagonal(inter, axis1=1, axis2=2)
    keys = rank_keys(inter, deg[:, :, None], deg[:, None, :])
    col = jax.lax.broadcasted_iota(jnp.int32, (B, G, G), 2)
    row = jax.lax.broadcasted_iota(jnp.int32, (B, G, G), 1)
    okc = (alive[:, None, :] > 0) & (col != row)
    ckey = combined_key(keys, okc, col, G)
    alive_cnt = (alive > 0).astype(jnp.int32).sum(axis=1)          # (B,)
    j_row = jnp.minimum(jnp.int32(top_j), alive_cnt - 1)[:, None]  # (B, 1)
    bi = jnp.arange(B)[:, None]
    colsize_b = jnp.broadcast_to(colsize[:, None, :], (B, G, R))

    def body(j, carry):
        ckey, has, n_b, d_b, z_b = carry
        idx = jnp.argmax(ckey, axis=2).astype(jnp.int32)           # (B, G)
        kmax = jnp.take_along_axis(ckey, idx[:, :, None], axis=2)[..., 0]
        numer, denom = jax.vmap(_row_saving_terms)(
            CNT, CNT[bi, idx], colsize_b, memcol, memcol[bi, idx],
            s, s[bi, idx], selfc, selfc[bi, idx], nd, nd[bi, idx],
            cost, cost[bi, idx])
        valid = (kmax >= 0) & (j < j_row) & (denom > 0)
        if height_bound is not None:
            new_h = jnp.maximum(hgt, hgt[bi, idx]) + 1
            valid = valid & (new_h <= jnp.int32(height_bound))
        take = valid & (~has | prod_lt(numer, d_b, n_b, denom))
        n_b = jnp.where(take, numer, n_b)
        d_b = jnp.where(take, denom, d_b)
        z_b = jnp.where(take, idx, z_b)
        has = has | take
        ckey = jnp.where(col == idx[:, :, None], jnp.int32(-(2**31) + 1),
                         ckey)
        return ckey, has, n_b, d_b, z_b

    one0 = jnp.ones((B, G), dtype=jnp.int32)
    _, has, n_b, d_b, z_b = jax.lax.fori_loop(
        0, J, body,
        (ckey, jnp.zeros((B, G), dtype=bool), one0, one0,
         jnp.zeros((B, G), dtype=jnp.int32)))
    has = has & (dirty > 0) & (alive > 0)
    return jnp.stack([has.astype(jnp.int32), n_b, d_b, z_b], axis=-1)


def round_rows(bits, alive, dirty, CNT, colsize, memcol, s, selfc, nd, hgt,
               cost, rows, J: int, top_j: int, height_bound):
    """`round_all` restricted to the selected rows — the single-device fast
    path: O(K·G·(W+R)) per round instead of O(B·G²·(W+R)).

    ``rows`` (K, 2) int32 [group, row]; padding rows carry group index B
    (out of range: gathers clip, and the caller's scatters drop them).
    Returns (K, 4) int32 ``[has, numer, denom, z]``, integer-identical to
    gathering those rows out of `round_all`.
    """
    B, G, W = bits.shape
    R = CNT.shape[-1]
    rb = jnp.minimum(rows[:, 0], B - 1)
    rr = rows[:, 1]
    pad_ok = rows[:, 0] < B
    K = rb.shape[0]
    rowbits = bits[rb, rr]                                         # (K, W)
    inter = popcount_u32(rowbits[:, None, :] & bits[rb]).sum(
        axis=-1).astype(jnp.int32)                                 # (K, G)
    deg = popcount_u32(bits).sum(axis=-1).astype(jnp.int32)        # (B, G)
    keys = rank_keys(inter, deg[rb, rr][:, None], deg[rb])
    col = jax.lax.broadcasted_iota(jnp.int32, (K, G), 1)
    okc = (alive[rb] > 0) & (col != rr[:, None])
    ckey = combined_key(keys, okc, col, G)
    alive_cnt = (alive > 0).astype(jnp.int32).sum(axis=1)
    j_row = jnp.minimum(jnp.int32(top_j), alive_cnt[rb] - 1)       # (K,)
    ki = jnp.arange(K)
    cnt_r = CNT[rb, rr]                                            # (K, R)
    colsize_r = colsize[rb]                                        # (K, R)
    ca = memcol[rb, rr]
    s_r, selfc_r = s[rb, rr], selfc[rb, rr]
    nd_r, hgt_r, cost_r = nd[rb, rr], hgt[rb, rr], cost[rb, rr]

    def body(j, carry):
        ckey, has, n_b, d_b, z_b = carry
        idx = jnp.argmax(ckey, axis=1).astype(jnp.int32)           # (K,)
        kmax = ckey[ki, idx]
        numer, denom = _row_saving_terms(
            cnt_r, CNT[rb, idx], colsize_r, ca, memcol[rb, idx], s_r,
            s[rb, idx], selfc_r, selfc[rb, idx], nd_r, nd[rb, idx], cost_r,
            cost[rb, idx])
        valid = (kmax >= 0) & (j < j_row) & (denom > 0)
        if height_bound is not None:
            new_h = jnp.maximum(hgt_r, hgt[rb, idx]) + 1
            valid = valid & (new_h <= jnp.int32(height_bound))
        take = valid & (~has | prod_lt(numer, d_b, n_b, denom))
        n_b = jnp.where(take, numer, n_b)
        d_b = jnp.where(take, denom, d_b)
        z_b = jnp.where(take, idx, z_b)
        has = has | take
        ckey = jnp.where(col == idx[:, None], jnp.int32(-(2**31) + 1), ckey)
        return ckey, has, n_b, d_b, z_b

    one0 = jnp.ones(K, dtype=jnp.int32)
    _, has, n_b, d_b, z_b = jax.lax.fori_loop(
        0, J, body,
        (ckey, jnp.zeros(K, dtype=bool), one0, one0,
         jnp.zeros(K, dtype=jnp.int32)))
    has = has & pad_ok & (dirty[rb, rr] > 0) & (alive[rb, rr] > 0)
    return jnp.stack([has.astype(jnp.int32), n_b, d_b, z_b], axis=-1)


def round_from_ranked(alive, dirty, CNT, colsize, memcol, s, selfc, nd, hgt,
                      cost, rows, cand, top_j: int, height_bound):
    """The Saving/argmax tail of `round_rows` over an EXTERNALLY ranked
    candidate list — the kernel-path hybrid: the Pallas `jaccard_topj`
    kernel produces ``cand`` (K, J) ranked columns (eligible candidates
    strictly precede dead/self ones in the combined-key order, so position
    j of the list IS the j-th eligible candidate while any remain), and
    this evaluates the identical exact first-wins rational argmax over it.
    Integer-identical to `round_rows` on the same state.
    """
    B, G = alive.shape
    rb = jnp.minimum(rows[:, 0], B - 1)
    rr = rows[:, 1]
    pad_ok = rows[:, 0] < B
    K, J = cand.shape
    alive_cnt = (alive > 0).astype(jnp.int32).sum(axis=1)
    j_row = jnp.minimum(jnp.int32(top_j), alive_cnt[rb] - 1)       # (K,)
    cnt_r = CNT[rb, rr]
    colsize_r = colsize[rb]
    ca = memcol[rb, rr]
    s_r, selfc_r = s[rb, rr], selfc[rb, rr]
    nd_r, hgt_r, cost_r = nd[rb, rr], hgt[rb, rr], cost[rb, rr]

    def body(j, carry):
        has, n_b, d_b, z_b = carry
        idx = cand[:, j]
        elig = (alive[rb, idx] > 0) & (idx != rr)
        numer, denom = _row_saving_terms(
            cnt_r, CNT[rb, idx], colsize_r, ca, memcol[rb, idx], s_r,
            s[rb, idx], selfc_r, selfc[rb, idx], nd_r, nd[rb, idx], cost_r,
            cost[rb, idx])
        valid = elig & (j < j_row) & (denom > 0)
        if height_bound is not None:
            new_h = jnp.maximum(hgt_r, hgt[rb, idx]) + 1
            valid = valid & (new_h <= jnp.int32(height_bound))
        take = valid & (~has | prod_lt(numer, d_b, n_b, denom))
        n_b = jnp.where(take, numer, n_b)
        d_b = jnp.where(take, denom, d_b)
        z_b = jnp.where(take, idx, z_b)
        return has | take, n_b, d_b, z_b

    one0 = jnp.ones(K, dtype=jnp.int32)
    has, n_b, d_b, z_b = jax.lax.fori_loop(
        0, J, body,
        (jnp.zeros(K, dtype=bool), one0, one0,
         jnp.zeros(K, dtype=jnp.int32)))
    has = has & pad_ok & (dirty[rb, rr] > 0) & (alive[rb, rr] > 0)
    return jnp.stack([has.astype(jnp.int32), n_b, d_b, z_b], axis=-1)


# ---------------------------------------------------------------------------
# Adjacency-bank carry (ISSUE 9): advance + extract twins
# ---------------------------------------------------------------------------
_INT32_INF = (1 << 31) - 1


def bank_advance(gids, cnts, size, selfc, nd, hgt, res_map, slab, Tp: int):
    """Advance the resident adjacency bank by ONE applied merge batch.

    ``gids``/``cnts`` are the (E,) append-only id/count streams, the four
    (cap,) stat arrays mirror `SluggerState`'s size/selfcnt/ndesc/height,
    ``res_map`` is the pre-batch root map, and ``slab`` is the (8, Pp) i32
    instruction ``[A, Z, M, out_ptr, a_ptr, a_len, z_ptr, z_len]`` (pads
    carry ``A = Z = M = cap``, ``out_ptr = E``, zero lengths — every pad
    write scatter-drops). ``Tp`` is the padded flattened entry count.

    The batch is the device twin of `SluggerState.merge_batch`'s row build:
    gather both parents' bank rows, resolve every gid through the PRE-batch
    ``res_map`` (exactly the host's `resolve` at gather time), drop entries
    internal to the pair (their count sum, halved, is ``cab``), coalesce
    duplicate roots (stable two-key sort + segment heads — the host's keyed
    `argsort` + `reduceat`), and append each pair's unique external
    ``(root, count)`` entries at ``out_ptr`` in ascending-root order. The
    head count per pair equals the host's ``row_len[M]`` at creation, which
    the caller mirrors into its host length table.
    """
    i32 = jnp.int32
    E = gids.shape[0]
    cap = res_map.shape[0]
    Pp = slab.shape[1]
    A, Z, M, outp = slab[0], slab[1], slab[2], slab[3]
    aptr, alen, zptr, zlen = slab[4], slab[5], slab[6], slab[7]
    ub = alen + zlen
    cum = jnp.cumsum(ub)
    total = cum[Pp - 1]
    j = jnp.arange(Tp, dtype=i32)
    p = jnp.searchsorted(cum, j, side="right").astype(i32)
    pc = jnp.minimum(p, Pp - 1)
    w = j - (cum[pc] - ub[pc])
    from_z = w >= alen[pc]
    idx = jnp.where(from_z, zptr[pc] + (w - alen[pc]), aptr[pc] + w)
    ev = j < total
    idxc = jnp.clip(idx, 0, E - 1)
    e_cnt = jnp.where(ev, cnts[idxc], 0)
    rg = res_map[jnp.clip(gids[idxc], 0, cap - 1)]
    internal = ev & ((rg == A[pc]) | (rg == Z[pc]))
    # A→B and B→A each counted once — the exact host `cab` halving
    cab = jax.ops.segment_sum(jnp.where(internal, e_cnt, 0), pc,
                              num_segments=Pp) // 2
    keep = ev & ~internal
    # stable sort by (pair, root): one composite i32 key would overflow, so
    # sort by root first, then stably by pair — kept entries of one pair end
    # up contiguous and ascending by root, dropped entries sink to the end
    o1 = jnp.argsort(jnp.where(keep, rg, _INT32_INF), stable=True)
    o2 = jnp.argsort(jnp.where(keep, pc, Pp)[o1], stable=True)
    o = o1[o2]
    sp, srg, skeep, sc = pc[o], rg[o], keep[o], e_cnt[o]
    prev_p = jnp.concatenate([jnp.full((1,), -1, i32), sp[:-1]])
    prev_r = jnp.concatenate([jnp.full((1,), -1, i32), srg[:-1]])
    head = skeep & ((sp != prev_p) | (srg != prev_r))
    rank = jnp.cumsum(head.astype(i32)) - 1          # unique-entry index
    rankc = jnp.clip(rank, 0, Tp - 1)
    csum = jax.ops.segment_sum(jnp.where(skeep, sc, 0), rankc,
                               num_segments=Tp)      # coalesced counts
    base = jax.ops.segment_min(jnp.where(skeep, rank, Tp),
                               jnp.where(skeep, sp, Pp),
                               num_segments=Pp + 1)[:Pp]
    tgt = jnp.where(head, outp[sp] + (rank - base[sp]), E)
    gids = gids.at[tgt].set(srg, mode="drop")
    cnts = cnts.at[tgt].set(csum[rankc], mode="drop")
    # per-id stats of the minted parents (pads gather id 0, scatter-drop)
    Ac = jnp.clip(A, 0, cap - 1)
    Zc = jnp.clip(Z, 0, cap - 1)
    size = size.at[M].set(size[Ac] + size[Zc], mode="drop")
    selfc = selfc.at[M].set(selfc[Ac] + selfc[Zc] + cab, mode="drop")
    nd = nd.at[M].set(nd[Ac] + nd[Zc] + 2, mode="drop")
    hgt = hgt.at[M].set(jnp.maximum(hgt[Ac], hgt[Zc]) + 1, mode="drop")
    # ids rooted at A or Z now root at M (single composition step — A and Z
    # were roots before this batch, so no pointer chasing is needed)
    upd = jnp.arange(cap, dtype=i32)
    upd = upd.at[A].set(M, mode="drop").at[Z].set(M, mode="drop")
    return gids, cnts, size, selfc, nd, hgt, upd[res_map]


def bank_extract_group(gids, cnts, size, selfc, nd, hgt, res_map, members,
                       ptr, lens, Rp: int, Wp: int, Lp: int):
    """Build ONE group's resident-arena tensors straight from the bank.

    ``members``/``ptr``/``lens`` are the group's (G,) member roots (pad −1)
    and their bank row extents. The column universe is the sorted union of
    the members and their entries' CURRENT roots (``res_map`` resolution =
    the host's `resolve` at gather time); duplicate-root entries coalesce by
    scatter-add, exactly like the host's keyed unique — so CNT/colsize/
    memcol/bits come out bit-identical to a host `_fill` of the same chunk.
    Cost rows evaluate the clamped integer-Saving terms in int32; the bank
    init guard (Σcnt conservation) keeps every count and cost below C_CLAMP,
    so no device-side overflow check is needed.
    """
    i32 = jnp.int32
    INF = jnp.int32(_INT32_INF)
    G = members.shape[0]
    E = gids.shape[0]
    cap = res_map.shape[0]
    valid_mem = members >= 0
    mem_c = jnp.clip(members, 0, cap - 1)
    cum = jnp.cumsum(lens)
    total = cum[G - 1]
    j = jnp.arange(Lp, dtype=i32)
    r = jnp.searchsorted(cum, j, side="right").astype(i32)
    rc = jnp.minimum(r, G - 1)
    idx = ptr[rc] + (j - (cum[rc] - lens[rc]))
    ev = j < total
    idxc = jnp.clip(idx, 0, E - 1)
    e_cnt = jnp.where(ev, cnts[idxc], 0)
    e_root = res_map[jnp.clip(gids[idxc], 0, cap - 1)]
    # sorted column universe (members always own a column; INF pads last)
    U = jnp.sort(jnp.concatenate([jnp.where(valid_mem, members, INF),
                                  jnp.where(ev, e_root, INF)]))
    prev = jnp.concatenate([jnp.full((1,), -1, i32), U[:-1]])
    head = (U != prev) & (U != INF)
    rankU = jnp.cumsum(head.astype(i32)) - 1
    colgid = jnp.full((Rp,), INF, i32).at[
        jnp.where(head, rankU, Rp)].set(U, mode="drop")
    memcol = jnp.where(valid_mem,
                       jnp.searchsorted(colgid, mem_c).astype(i32), 0)
    ec = jnp.minimum(jnp.searchsorted(colgid, e_root).astype(i32), Rp - 1)
    CNT = jnp.zeros((G, Rp), i32).at[rc, ec].add(e_cnt)
    colsize = jnp.where(colgid != INF, size[jnp.clip(colgid, 0, cap - 1)], 0)
    s_g = jnp.where(valid_mem, size[mem_c], 0)
    selfc_g = jnp.where(valid_mem, selfc[mem_c], 0)
    nd_g = jnp.where(valid_mem, nd[mem_c], 0)
    hgt_g = jnp.where(valid_mem, hgt[mem_c], 0)
    # packed bitmaps: presence of column c lands in u32 word c>>5 bit c&31 —
    # the uint32 view of the host's little-endian uint64 layout
    pres = jnp.zeros((G, Wp * 32), dtype=jnp.uint32).at[:, :Rp].set(
        (CNT > 0).astype(jnp.uint32))
    bits = (pres.reshape(G, Wp, 32)
            << jnp.arange(32, dtype=jnp.uint32)).sum(
                axis=-1, dtype=jnp.uint32)
    terms = pair_cost_c(CNT, poss_pair_c(s_g[:, None], colsize[None, :]))
    cost = terms.sum(axis=-1, dtype=i32)
    cost = cost + pair_cost_c(selfc_g, poss_self_c(s_g)) + nd_g
    cost = jnp.where(valid_mem, cost, 0)
    alive = valid_mem.astype(jnp.int8)
    return (bits, alive, alive, CNT, colsize, memcol, s_g, selfc_g, nd_g,
            hgt_g, cost)


# ---------------------------------------------------------------------------
# Fold with resident counts (the whole-iteration residency fold)
# ---------------------------------------------------------------------------
def fold_pairs_counts(bits, alive, dirty, CNT, colsize, memcol, s, selfc,
                      nd, hgt, cost, instr, with_bits: bool = True):
    """Apply one round's accepted pairs to ONE group's resident tensors.

    ``instr`` (P, 3) int32 rows ``[a_row, z_row, valid]``; member columns
    come from the resident ``memcol``. The update is PHASED exactly like the
    host fold (`BatchedGroupWorkspace.apply_merges`): capture pre-round
    costs/cab for every pair, fold all CNT rows then all CNT columns, fold
    bitmap columns (all ORs, then all clears) then rows, update the scalar
    per-row stats, and finally apply the incremental + exact cost updates.
    Within one round pairs are disjoint in rows and member columns, so every
    phase's scatters hit distinct targets (word-level bit scatters combine
    distinct bits and are built as masks before a single OR/ANDNOT).

    ``with_bits=False`` skips the bitmap phase (bits pass through
    unchanged) — the kernel-path hybrid folds the bitmaps with the Pallas
    `bitset_fold` kernel and only the count phases run here; no count
    phase reads ``bits``, so the split changes nothing.
    """
    G, R = CNT.shape
    W = bits.shape[1]
    P = instr.shape[0]
    valid = instr[:, 2] > 0
    # drop-mode indices: invalid pairs scatter out of range / gather row 0
    a = jnp.where(valid, instr[:, 0], G)
    z = jnp.where(valid, instr[:, 1], G)
    ag = jnp.minimum(a, G - 1)
    zg = jnp.minimum(z, G - 1)
    ca = jnp.where(valid, memcol[ag], R)
    cz = jnp.where(valid, memcol[zg], R)
    cag = jnp.minimum(ca, R - 1)
    czg = jnp.minimum(cz, R - 1)
    vz32 = valid.astype(jnp.int32)

    # -- phase 0: pre-round captures ------------------------------------
    s_new = s[ag] + s[zg]
    cab = CNT[ag, czg] * vz32
    old_ca = pair_cost_c(CNT[:, cag], poss_pair_c(s[:, None], colsize[cag][None, :])).T   # (P, G)
    old_cz = pair_cost_c(CNT[:, czg], poss_pair_c(s[:, None], colsize[czg][None, :])).T   # (P, G)

    # -- phase 1: CNT rows fold, then columns fold ----------------------
    zrows = CNT[zg] * vz32[:, None]
    CNT = CNT.at[a].add(zrows, mode="drop")
    CNT = CNT.at[z].set(0, mode="drop")
    zcols = CNT[:, czg] * vz32[None, :]
    CNT = CNT.at[:, ca].add(zcols, mode="drop")
    CNT = CNT.at[:, cz].set(0, mode="drop")
    CNT = CNT.at[a, ca].set(0, mode="drop")

    # -- phase 2: bitmaps (column ORs, column clears, row ORs) ----------
    if with_bits:
        one = jnp.uint32(1)
        wa, ba = cag >> 5, (cag & 31).astype(jnp.uint32)
        wz, bz = czg >> 5, (czg & 31).astype(jnp.uint32)
        zbit = ((bits[:, wz] >> bz[None, :]) & one) * vz32.astype(jnp.uint32)
        # distinct pairs own distinct columns → distinct (word, bit)
        # targets: scatter-ADD builds the OR/clear masks without carries
        ormask = jnp.zeros_like(bits).at[:, wa].add(zbit << ba[None, :])
        clrmask = jnp.zeros_like(bits).at[:, wz].add(
            jnp.broadcast_to((one << bz) * vz32.astype(jnp.uint32), (G, P)))
        bits = (bits | ormask) & ~clrmask
        rowz = bits[zg] * vz32[:, None].astype(jnp.uint32)
        bits = bits.at[a].set((bits[ag] | rowz) * valid[:, None] +
                              bits[ag] * (~valid[:, None]), mode="drop")
        bits = bits.at[z].set(0, mode="drop")
        ownmask = jnp.zeros_like(bits).at[a, wa].add(
            (one << ba) * valid.astype(jnp.uint32), mode="drop")
        bits = bits & ~ownmask

    # -- phase 3: per-row scalar stats ----------------------------------
    colsize = colsize.at[ca].set(s_new, mode="drop")
    colsize = colsize.at[cz].set(0, mode="drop")
    selfc = selfc.at[a].set(selfc[ag] + selfc[zg] + cab, mode="drop")
    nd = nd.at[a].set(nd[ag] + nd[zg] + 2, mode="drop")
    hgt = hgt.at[a].set(jnp.maximum(hgt[ag], hgt[zg]) + 1, mode="drop")
    s = s.at[a].set(s_new, mode="drop")
    alive = alive.at[z].set(0, mode="drop")
    dirty = dirty.at[z].set(0, mode="drop")
    dirty = dirty.at[a].set(1, mode="drop")

    # -- phase 4: incremental cost update + exact merged-row recompute --
    new_ca = pair_cost_c(CNT[:, cag], poss_pair_c(s[:, None], colsize[cag][None, :])).T
    cost = cost + ((new_ca - old_ca - old_cz) * vz32[:, None]).sum(axis=0)
    crow = pair_cost_c(CNT[ag], poss_pair_c(s[ag][:, None], colsize[None, :])).sum(axis=-1)
    crow = crow + pair_cost_c(selfc[ag], poss_self_c(s[ag])) + nd[ag]
    cost = cost.at[a].set(crow, mode="drop")
    cost = cost.at[z].set(0, mode="drop")
    return bits, alive, dirty, CNT, colsize, s, selfc, nd, hgt, cost
