"""jnp twins for the resident merge-round kernels (bitset_fold).

Everything here is INTEGER-EXACT and must stay in bit-for-bit lockstep with
three other implementations: the Pallas kernels in `kernel.py`, the NumPy
host ranking in `core/merging.py` (`rank_keys` / the per-round argsort), and
the host bitmap fold in `BatchedGroupWorkspace.apply_merges`. The merge
engine's cross-backend bit-identity rests on that agreement (DESIGN.md §9),
so these functions avoid floating point entirely:

* ``rank_keys`` — the quantized-Jaccard ranking key: shift intersection and
  union down together until the union fits 15 bits, then take the exact
  integer quotient ``(iq << 15) // uq``. Pure int32-safe arithmetic, so the
  key is identical on NumPy, XLA CPU, and TPU (no float division whose
  rounding could differ across backends).
* ``topj_all`` — per-row ranked top-J candidate columns by (key desc,
  column asc), dead/self columns last; J iterative argmax passes over a
  combined key that encodes the column tie-break, so there are never ties.
* ``fold_pairs`` — the bitset-OR merge fold: per accepted pair, fold column
  cz into ca for every row, OR row z into row a, clear z, clear a's own
  bit. Sequential over the (disjoint) pairs of a group, exactly like the
  kernel's fori_loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitset_jaccard.ref import popcount_u32 as _swar_popcount

_KEY_BITS = 15

if hasattr(jnp, "bitwise_count"):  # native popcnt lowering (jax ≥ 0.4.27)
    def popcount_u32(x):
        return jnp.bitwise_count(x).astype(jnp.int32)
else:  # pragma: no cover - old jax
    popcount_u32 = _swar_popcount  # quantized keys live in [0, 2^15]; (key+1)*G fits int32


def bit_length(v):
    """Elementwise bit length of non-negative int32/int64 (< 2^31) values —
    the 5-step binary search is identical in NumPy and jnp."""
    b = jnp.zeros_like(v)
    for s in (16, 8, 4, 2, 1):
        t = v >> s
        big = t > 0
        b = b + jnp.where(big, s, 0)
        v = jnp.where(big, t, v)
    return b + (v > 0).astype(v.dtype)


def rank_keys(inter, deg_r, deg_c):
    """Quantized-Jaccard integer ranking keys (DESIGN.md §9).

    ``inter`` intersection counts, ``deg_r``/``deg_c`` the two rows' set
    sizes (broadcastable). Returns keys in ``[0, 2^15]`` that order exactly
    like ``inter/union`` up to the 15-bit quantization, computed with shift
    and integer-divide only.
    """
    inter = inter.astype(jnp.int32)
    union = deg_r.astype(jnp.int32) + deg_c.astype(jnp.int32) - inter
    sh = jnp.maximum(0, bit_length(union) - _KEY_BITS)
    return ((inter >> sh) << _KEY_BITS) // jnp.maximum(union >> sh, 1)


def combined_key(keys, ok, col, G: int):
    """Strict total order encoding: ``(key+1)*G - 1 - col`` for eligible
    columns, ``-1 - col`` for dead/self. Every entry is UNIQUE (the column
    is folded into both branches), so any top-k — `lax.top_k`, the kernel's
    iterative argmax, the host's stable argsort on ``-key`` — produces the
    SAME ranking: key desc, column asc, dead/self last (in asc column
    order, matching the stable sort over the host's uniform -1 keys)."""
    return jnp.where(ok, (keys + 1) * G - 1 - col, -1 - col)


def _topk_ranked(ckey, J: int):
    """Ranked top-J columns of the (…, G) combined keys; keys are unique,
    so top_k needs no tie rule."""
    _, idx = jax.lax.top_k(ckey, J)
    return idx.astype(jnp.int32)


def topj_all(bits, alive, J: int):
    """All rows' ranked top-J candidate columns, one group batch at a time.

    ``bits`` (B, G, W) uint32 packed neighbor bitmaps, ``alive`` (B, G)
    int8/int32/bool. Returns (B, G, J) int32 column indices, ranked by the
    exact (quantized key desc, column asc) order with dead/self columns
    last — the device analogue of the host sweep's per-row stable argsort
    prefix.
    """
    B, G, W = bits.shape
    inter = popcount_u32(bits[:, :, None, :] & bits[:, None, :, :]).sum(
        axis=-1).astype(jnp.int32)                      # (B, G, G)
    deg = jnp.diagonal(inter, axis1=1, axis2=2)         # popcount(x&x) = |x|
    keys = rank_keys(inter, deg[:, :, None], deg[:, None, :])
    col = jax.lax.broadcasted_iota(jnp.int32, (B, G, G), 2)
    row = jax.lax.broadcasted_iota(jnp.int32, (B, G, G), 1)
    ok = (alive[:, None, :] > 0) & (col != row)
    return _topk_ranked(combined_key(keys, ok, col, G), J)


def topj_rows(bits, alive, rows, J: int):
    """Ranked top-J for SELECTED rows only — the single-device fast path.

    ``rows`` (n, 2) int32 [group, row] pairs (padded rows compute garbage
    the caller discards). Integer-identical to gathering those rows out of
    `topj_all`; computing (n, G) instead of (B, G, G) intersections is what
    makes late merge rounds (few dirty rows) cheap.
    """
    B, G, W = bits.shape
    rb, rr = rows[:, 0], rows[:, 1]
    rowbits = bits[rb, rr]                                   # (n, W)
    inter = popcount_u32(rowbits[:, None, :] & bits[rb]).sum(
        axis=-1).astype(jnp.int32)                           # (n, G)
    deg = popcount_u32(bits).sum(axis=-1).astype(jnp.int32)  # (B, G)
    keys = rank_keys(inter, deg[rb, rr][:, None], deg[rb])
    col = jax.lax.broadcasted_iota(jnp.int32, inter.shape, 1)
    ok = (alive[rb] > 0) & (col != rr[:, None])
    return _topk_ranked(combined_key(keys, ok, col, G), J)


def fold_pairs(bits, alive, instr):
    """Apply one round's accepted merges to one group's resident bitmaps.

    ``bits`` (G, W) uint32, ``alive`` (G,) int32, ``instr`` (P, 8) int32
    rows ``[a_row, z_row, wa, ba, wz, bz, valid, _]`` (word/bit positions of
    the a/z member columns in the uint32 layout; ``valid`` gates padding
    rows). Pairs apply sequentially — their rows are disjoint, but two
    pairs' member columns may share a 32-bit word, so the word updates must
    be read-modify-write in order, exactly as the kernel's fori_loop and
    the host fold's unbuffered ``.at`` ops.
    """
    one = jnp.uint32(1)

    def body(p, carry):
        b, a = carry
        row = instr[p]
        valid = row[6] > 0
        ar, zr, wa, wz = row[0], row[1], row[2], row[4]
        ba = row[3].astype(jnp.uint32)
        bz = row[5].astype(jnp.uint32)
        colz = (b[:, wz] >> bz) & one
        nb = b.at[:, wa].set(b[:, wa] | (colz << ba))
        nb = nb.at[:, wz].set(nb[:, wz] & ~(one << bz))
        rowz = nb[zr]
        nb = nb.at[ar].set(nb[ar] | rowz)
        nb = nb.at[zr].set(jnp.zeros_like(rowz))
        nb = nb.at[ar, wa].set(nb[ar, wa] & ~(one << ba))
        na = a.at[zr].set(0)
        return jnp.where(valid, nb, b), jnp.where(valid, na, a)

    return jax.lax.fori_loop(0, instr.shape[0], body, (bits, alive))
