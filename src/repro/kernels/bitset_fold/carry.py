"""Device ops for the resident run context (DESIGN.md §9, ISSUE 7).

These are the ops that make state OUTLIVE one iteration on device:

* `advance_fn` — plan replay: compose one iteration's applied merges
  ((A, Z, M) id triples) into the resident root map. The merges form a
  forest-forward map (an id merges at most once per iteration, and minted
  parents may merge again in LATER rounds of the same iteration), so the
  map collapses to its fixpoint by pointer doubling — 16 squarings cover
  chains of length 2^16, far beyond any real round count.
* `shingle_roots_fn` — resident candidate generation: per-root u32 min-hash
  shingles from the resident edge arrays and root map, plus per-root leaf
  counts (the host applies the leafless-root sentinel rule from the
  counts). Bit-identical to `core/minhash.node_shingles_u32` +
  `rootwise_min` and to the mesh shard_map path — same hash mix, and
  segment-min is order-independent.

Both are jit-cached on their (static) shapes via small LRU caches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import LruCache

from . import ref as _ref

_ADVANCE_CACHE = LruCache(8)
_SHINGLE_CACHE = LruCache(8)
_BANK_ADVANCE_CACHE = LruCache(16)
_BANK_GROW_CACHE = LruCache(8)


def _hash_u32(x, a, b):
    """The unified u32 mix (twin of `core/distributed._hash_u32` and the
    NumPy `core/minhash.hash_u32`)."""
    h = x.astype(jnp.uint32) * a + b
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> jnp.uint32(15))
    return h


def advance_fn(cap: int, mp: int):
    """Compiled ``(res_map (cap,) i32, tri (3, mp) i32) -> res_map'``.

    ``tri`` rows are the padded A / Z / M id streams (pads carry ``cap``,
    out of range — the scatters drop them). ``res_map`` is donated: the
    root map advances in place.
    """
    key = (cap, mp)
    fn = _ADVANCE_CACHE.get(key)
    if fn is not None:
        return fn

    @functools.partial(jax.jit, donate_argnums=(0,))
    def fn(res_map, tri):
        fwd = jnp.arange(cap, dtype=jnp.int32)
        fwd = fwd.at[tri[0]].set(tri[2], mode="drop")
        fwd = fwd.at[tri[1]].set(tri[2], mode="drop")
        for _ in range(16):            # pointer doubling to the fixpoint
            fwd = fwd[fwd]
        return fwd[res_map]

    _ADVANCE_CACHE[key] = fn
    return fn


def bank_advance_fn(cap: int, E: int, Pp: int, Tp: int):
    """Compiled one-batch adjacency-bank advance (ISSUE 9, DESIGN.md §9).

    ``(gids (E,), cnts (E,), size (cap,), selfc, nd, hgt, res_map (cap,),
    slab (8, Pp)) -> same seven carried arrays`` — all seven device arrays
    are donated so the bank truly advances in place; the (8, Pp) i32 slab is
    the only recurring upload (32 B per applied pair). The body is the pure
    `ref.bank_advance` twin; ``Tp`` pads the flattened entry workspace.
    """
    key = (cap, E, Pp, Tp)
    fn = _BANK_ADVANCE_CACHE.get(key)
    if fn is not None:
        return fn

    @functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3, 4, 5, 6))
    def fn(gids, cnts, size, selfc, nd, hgt, res_map, slab):
        return _ref.bank_advance(gids, cnts, size, selfc, nd, hgt, res_map,
                                 slab, Tp)

    _BANK_ADVANCE_CACHE[key] = fn
    return fn


def bank_grow_fn(E: int, newE: int):
    """Compiled pow2 regrow ``(gids (E,), cnts (E,)) -> ((newE,), (newE,))``.

    Device-to-device only — no host round trip, no transfer-counter bytes.
    No donation: the output shape differs from the input's, so XLA could
    never alias the buffers anyway (it would only warn). Tails are zero
    (cnt 0 entries are inert).
    """
    key = (E, newE)
    fn = _BANK_GROW_CACHE.get(key)
    if fn is not None:
        return fn

    @jax.jit
    def fn(gids, cnts):
        g = jnp.zeros(newE, dtype=jnp.int32).at[:E].set(gids)
        c = jnp.zeros(newE, dtype=jnp.int32).at[:E].set(cnts)
        return g, c

    _BANK_GROW_CACHE[key] = fn
    return fn


def shingle_roots_fn(n: int, cap: int, m_edges: int):
    """Compiled ``(src, dst, res_map, a, b) -> (sh (cap,) u32, cnt (cap,)
    i32)`` — per-root shingle minima and per-root leaf counts.

    Matches the host twin exactly: node shingle = min(h(u), min over
    neighbors h(w)); root shingle = min over the root's leaves. Roots
    owning no leaves come back as the uint32 maximum with ``cnt == 0`` —
    the host substitutes the ``2^32 + id`` sentinel.
    """
    key = (n, cap, m_edges)
    fn = _SHINGLE_CACHE.get(key)
    if fn is not None:
        return fn

    @jax.jit
    def fn(src, dst, res_map, a, b):
        h_self = _hash_u32(jnp.arange(n, dtype=jnp.uint32), a, b)
        seg = jax.ops.segment_min(_hash_u32(dst, a, b), src, num_segments=n)
        node_sh = jnp.minimum(h_self, seg)
        roots = res_map[:n]
        sh = jax.ops.segment_min(node_sh, roots, num_segments=cap)
        cnt = jax.ops.segment_sum(jnp.ones(n, dtype=jnp.int32), roots,
                                  num_segments=cap)
        return sh, cnt

    _SHINGLE_CACHE[key] = fn
    return fn
