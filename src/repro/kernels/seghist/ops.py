"""Public dispatch for per-level state membership counts.

`membership_counts` is what the batched emission DP calls once per tree
level: given each active subedge's pair-state id, return the number of
subedges per state (the DP compares these against the interval products to
classify states full/empty/mixed). ``backend="batched"`` routes through the
Pallas one-hot histogram kernel with a small jit cache keyed on padded
shapes, mirroring `kernels/bitset_jaccard/ops.batched_pairwise_intersections`;
``backend="numpy"`` is a plain ``np.bincount``.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.kernels.common import LruCache, default_interpret, pow2
from repro.kernels.seghist.kernel import segment_histogram_kernel

_JIT_CACHE = LruCache(16)


def membership_counts(state_of_edge: np.ndarray, num_states: int,
                      backend: str = "numpy", interpret=None) -> np.ndarray:
    """(E,) int64 state ids -> (num_states,) int64 subedge counts."""
    if num_states == 0:
        return np.zeros(0, dtype=np.int64)
    if backend != "batched":
        return np.bincount(state_of_edge, minlength=num_states).astype(np.int64)
    if interpret is None:
        interpret = default_interpret()
    # pad E and S to powers of two so the jit cache stays small
    Ep = pow2(int(state_of_edge.size), floor=256)
    Sp = pow2(int(num_states), floor=256)
    key = (Ep, Sp, interpret)
    fn = _JIT_CACHE.get(key)
    if fn is None:
        fn = jax.jit(
            lambda s: segment_histogram_kernel(s, Sp, interpret=interpret),
        )
        _JIT_CACHE[key] = fn
    seg = np.full(Ep, -1, dtype=np.int32)
    seg[: state_of_edge.size] = state_of_edge.astype(np.int32)
    return np.asarray(fn(seg)).astype(np.int64)[:num_states]
