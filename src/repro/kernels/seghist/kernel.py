"""Pallas TPU kernel: tiled segment histogram (one-hot compare-and-sum).

Role in the system: the batched emission DP (`core/encode_batched.py`)
classifies every pair state of a level as empty / full / mixed from its
subedge membership count. The counts are a histogram of per-edge state ids —
this kernel computes it as a tiled one-hot reduction: each (segment-block,
edge-block) grid step broadcasts a (BE, 1) id column against a (1, BS) iota
of segment ids and accumulates the match count, the same compare-and-reduce
layout the MXU one-hot-matmul histogram trick uses. Mirrors the
bitset-Jaccard kernel wiring (grid accumulation over the streamed axis,
interpret-mode default off-TPU).

Padding contract: callers pad the id array with -1, which matches no
segment block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _seghist_block(seg_ref, out_ref, *, block_s: int):
    j = pl.program_id(0)  # segment block
    k = pl.program_id(1)  # edge block (streamed, accumulated)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    seg = seg_ref[...]  # (1, BE) int32, padded entries are -1
    sid = j * block_s + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    hits = (seg[0, :, None] == sid[0, None, :]).astype(jnp.int32)  # (BE, BS)
    out_ref[...] += hits.sum(axis=0, keepdims=True)


def segment_histogram_kernel(seg: jax.Array, num_segments: int,
                             block_s: int = 512, block_e: int = 1024,
                             interpret: bool = True) -> jax.Array:
    """seg: (E,) int32 ids in [0, num_segments) or -1 -> (num_segments,) int32."""
    E = seg.shape[0]
    S = int(num_segments)
    bs = min(block_s, max(S, 1))
    be = min(block_e, max(E, 1))
    Ep = pl.cdiv(max(E, 1), be) * be
    Sp = pl.cdiv(max(S, 1), bs) * bs
    seg2 = jnp.full((1, Ep), -1, dtype=jnp.int32).at[0, :E].set(seg.astype(jnp.int32))
    grid = (Sp // bs, Ep // be)
    out = pl.pallas_call(
        functools.partial(_seghist_block, block_s=bs),
        grid=grid,
        in_specs=[pl.BlockSpec((1, be), lambda j, k: (0, k))],
        out_specs=pl.BlockSpec((1, bs), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, Sp), jnp.int32),
        interpret=interpret,
    )(seg2)
    return out[0, :S]
