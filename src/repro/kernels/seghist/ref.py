"""Pure-jnp reference for the segment histogram kernel."""
from __future__ import annotations

import jax.numpy as jnp


def segment_histogram(seg, num_segments: int):
    """seg: (E,) int ids in [0, num_segments) or -1 -> (num_segments,) int32."""
    seg = jnp.asarray(seg, dtype=jnp.int32)
    valid = seg >= 0
    return jnp.zeros(num_segments, dtype=jnp.int32).at[
        jnp.where(valid, seg, 0)
    ].add(valid.astype(jnp.int32))
