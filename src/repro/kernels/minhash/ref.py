"""Pure-jnp oracle for the minhash kernel.

The TPU formulation regularizes the irregular segment-min: adjacency is
packed into fixed-width rows (``nbr`` (R, W) uint32 with ``SENTINEL`` padding;
high-degree nodes span several rows, combined by the caller). The kernel
fuses the affine uint32 hash with the row-min reduction.
"""
from __future__ import annotations

import jax.numpy as jnp

SENTINEL = jnp.uint32(0xFFFFFFFF)
MAX_HASH = jnp.uint32(0xFFFFFFFF)


def hash_u32(x, a: int, b: int):
    """Affine hash in Z_2^32 (multiplicative mixing; odd ``a``)."""
    x = x.astype(jnp.uint32)
    h = x * jnp.uint32(a) + jnp.uint32(b)
    # one xorshift round to decorrelate low bits
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x7FEB352D)
    h = h ^ (h >> jnp.uint32(15))
    return h


def rowmin_hash(nbr, a: int, b: int):
    """min over valid entries of hash(nbr) per row; MAX_HASH for empty rows.

    nbr: (R, W) uint32 with SENTINEL padding.
    returns: (R,) uint32
    """
    valid = nbr != SENTINEL
    h = hash_u32(nbr, a, b)
    h = jnp.where(valid, h, MAX_HASH)
    return jnp.min(h, axis=1)
