"""Pallas TPU kernel: fused affine-hash + row-min (minhash shingles).

TPU adaptation (DESIGN.md §2.3): the CPU algorithm's irregular per-node
segment-min becomes a dense (R, W)-tiled reduction over fixed-width adjacency
rows — HBM-resident rows stream through VMEM in (BR, BW) blocks, each block
doing pure VPU work (uint32 multiply/xor/shift + min), with the W-dimension
reduced across grid steps into the (BR,) output block.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SENTINEL = np.uint32(0xFFFFFFFF)
_MAX_HASH = np.uint32(0xFFFFFFFF)


def _minhash_block(nbr_ref, out_ref, *, a: int, b: int, w_total: int):
    w = pl.program_id(1)

    @pl.when(w == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, _MAX_HASH)

    x = nbr_ref[...]
    bw = x.shape[1]
    # mask block-padding columns past the true width (non-divisible shapes)
    col = w * bw + jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    valid = (x != _SENTINEL) & (col < w_total)
    h = x * np.uint32(a) + np.uint32(b)
    h = h ^ (h >> np.uint32(16))
    h = h * np.uint32(0x7FEB352D)
    h = h ^ (h >> np.uint32(15))
    h = jnp.where(valid, h, _MAX_HASH)
    out_ref[...] = jnp.minimum(out_ref[...], jnp.min(h, axis=1))


def rowmin_hash_kernel(nbr: jax.Array, a: int, b: int,
                       block_r: int = 256, block_w: int = 128,
                       interpret: bool = True) -> jax.Array:
    """(R, W) uint32 padded adjacency -> (R,) uint32 shingle values."""
    R, W = nbr.shape
    br = min(block_r, R)
    bw = min(block_w, W)
    grid = (pl.cdiv(R, br), pl.cdiv(W, bw))
    return pl.pallas_call(
        functools.partial(_minhash_block, a=a, b=b, w_total=W),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bw), lambda r, w: (r, w))],
        out_specs=pl.BlockSpec((br,), lambda r, w: (r,)),
        out_shape=jax.ShapeDtypeStruct((R,), jnp.uint32),
        interpret=interpret,
    )(nbr)
