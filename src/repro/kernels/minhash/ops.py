"""Jit'd public wrapper for the minhash kernel: CSR graph -> root shingles."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.kernels.minhash import ref
from repro.kernels.minhash.kernel import rowmin_hash_kernel


def pack_adjacency(indptr: np.ndarray, indices: np.ndarray, width: int = 128):
    """Pack CSR rows into fixed-width uint32 rows (TPU-regular layout).

    High-degree nodes span ceil(deg/width) rows; ``row_owner`` maps each packed
    row back to its node. Includes the node itself (shingles hash N(u) ∪ {u}).
    """
    n = indptr.shape[0] - 1
    deg1 = np.diff(indptr) + 1  # + self
    rows_per = -(-deg1 // width)  # ceil; deg1 >= 1 so always >= 1
    owners = np.repeat(np.arange(n, dtype=np.int64), rows_per)
    R = int(rows_per.sum())
    out = np.full((R, width), np.uint32(0xFFFFFFFF), dtype=np.uint32)
    row0 = np.cumsum(rows_per) - rows_per
    # flat [u | N(u)] value stream + one scatter — no per-node Python loop
    total = int(deg1.sum())
    node_of = np.repeat(np.arange(n, dtype=np.int64), deg1)
    start_v = np.cumsum(deg1) - deg1
    off = np.arange(total, dtype=np.int64) - start_v[node_of]
    vals = np.empty(total, dtype=np.uint32)
    vals[off == 0] = np.arange(n, dtype=np.uint32)
    vals[off > 0] = np.asarray(indices, dtype=np.uint32)
    out[row0[node_of] + off // width, off % width] = vals
    return out, owners


def node_shingles(nbr_rows: jax.Array, row_owner: np.ndarray, n: int,
                  a: int, b: int, use_kernel: bool = True,
                  interpret: bool = True) -> jax.Array:
    """Per-node shingle = min hash over N(u) ∪ {u}."""
    if use_kernel:
        mins = rowmin_hash_kernel(nbr_rows, a, b, interpret=interpret)
    else:
        mins = ref.rowmin_hash(nbr_rows, a, b)
    seg = jax.ops.segment_min(mins, jnp.asarray(row_owner), num_segments=n)
    return seg


def root_shingles(node_sh: jax.Array, root_of: jax.Array, n_ids: int) -> jax.Array:
    """Root shingle = min over member nodes (segment-min over root ids)."""
    return jax.ops.segment_min(node_sh, root_of, num_segments=n_ids)
