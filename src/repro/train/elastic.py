"""Elastic scaling: re-shard a live train state onto a different mesh.

When the orchestrator reports a changed device pool (node loss / scale-up),
we rebuild the mesh, re-derive PartitionSpecs against it (divisibility guards
adapt — e.g. a dimension that sharded 16-way may replicate on 12 devices),
and `jax.device_put` every array onto its new sharding. The step function is
then re-jitted against the new shardings. Data-pipeline determinism makes the
transition exact: batch(step) is pure in (seed, step) regardless of mesh.
"""
from __future__ import annotations

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def make_mesh_for(devices, model_parallel: int, axis_names=("data", "model")):
    n = len(devices)
    model = min(model_parallel, n)
    while n % model:
        model -= 1
    data = n // model
    dev = np.asarray(devices)[: data * model].reshape(data, model)
    return jax.sharding.Mesh(dev, axis_names)


def remesh_state(state, new_mesh, spec_fn):
    """spec_fn(state, mesh) -> PartitionSpec pytree for the new mesh."""
    specs = spec_fn(state, new_mesh)
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(new_mesh, s)),
        state, specs,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)),
    )
