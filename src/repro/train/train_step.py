"""Train/serve step builders: loss → grads → (optionally compressed) DP
reduction → AdamW(+ZeRO-1) update, all under pjit with explicit shardings.

`build_train_step` returns (step_fn, state_shardings, batch_sharding) so the
same builder serves the real training loop, the dry-run (AOT lowering against
ShapeDtypeStructs) and the roofline analysis.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import sharding as SH
from repro.models.api import abstract_params, get_api, input_specs, lm_loss
from repro.optim import adamw, schedules


@dataclass
class TrainPlan:
    cfg: ModelConfig
    mesh: object
    dp_axes: tuple
    opt: adamw.AdamWConfig
    microbatch: Optional[int] = None   # grad-accumulation microbatch (per step)
    warmup: int = 100
    total_steps: int = 10_000


def state_specs(plan: TrainPlan, params_abs):
    """Shardings for {params, opt{m,v,step}}."""
    pspecs = SH.param_pspecs(plan.cfg, params_abs, plan.mesh, plan.dp_axes)
    flat_p, treedef = jax.tree.flatten(params_abs)
    flat_spec = treedef.flatten_up_to(pspecs)
    mspecs = treedef.unflatten([
        SH.zero1_spec(s, p.shape, plan.mesh, plan.dp_axes) for s, p in zip(flat_spec, flat_p)
    ])
    return {
        "params": pspecs,
        "opt": {"m": mspecs, "v": mspecs, "step": P()},
    }


def to_shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def build_train_step(plan: TrainPlan, shape: ShapeConfig):
    """Returns (jit_step, state_shardings, batch_shardings, abstract_state)."""
    cfg, mesh, dp = plan.cfg, plan.mesh, plan.dp_axes
    params_abs = abstract_params(cfg)
    specs = state_specs(plan, params_abs)
    opt_abs = jax.eval_shape(lambda p: adamw.init_state(p, plan.opt.moment_dtype), params_abs)
    state_abs = {"params": params_abs, "opt": opt_abs}
    state_sh = to_shardings(mesh, {"params": specs["params"], "opt": specs["opt"]})

    batch_abs = input_specs(cfg, shape)
    bspec = {}
    for k, v in batch_abs.items():
        if k == "tokens":
            bspec[k] = SH.batch_pspec(mesh, dp, v.shape[0])
        else:
            bspec[k] = P(*(SH.batch_pspec(mesh, dp, v.shape[0]) + (None,) * (len(v.shape) - 2)))
    batch_sh = {k: NamedSharding(mesh, s) for k, s in bspec.items()}

    nmicro = 1
    if plan.microbatch:
        gb = shape.global_batch
        assert gb % plan.microbatch == 0
        nmicro = gb // plan.microbatch

    def loss_fn(params, batch):
        return lm_loss(params, cfg, batch)

    def step(state, batch):
        with SH.mesh_context(mesh, dp):
            if nmicro == 1:
                loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
            else:
                def micro(i, carry):
                    acc, ltot = carry
                    mb = jax.tree.map(
                        lambda t: jax.lax.dynamic_slice_in_dim(t, i * plan.microbatch, plan.microbatch, 0),
                        batch)
                    l, g = jax.value_and_grad(loss_fn)(state["params"], mb)
                    return jax.tree.map(jnp.add, acc, g), ltot + l
                zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
                grads, loss = jax.lax.fori_loop(0, nmicro, micro, (zeros, 0.0))
                grads = jax.tree.map(lambda g: g / nmicro, grads)
                loss = loss / nmicro
            lr_scale = schedules.cosine_with_warmup(
                state["opt"]["step"], warmup=plan.warmup, total=plan.total_steps)
            new_params, new_opt, metrics = adamw.apply_updates(
                state["params"], grads, state["opt"], plan.opt, lr_scale)
            metrics["loss"] = loss
            return {"params": new_params, "opt": new_opt}, metrics

    jit_step = jax.jit(
        step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )
    return jit_step, state_sh, batch_sh, state_abs


def build_serve_step(cfg: ModelConfig, mesh, dp_axes, shape: ShapeConfig,
                     absorbed_mla: bool = False):
    """Prefill or decode step with cache shardings (kind from `shape`)."""
    api = get_api(cfg)
    params_abs = abstract_params(cfg)
    pspecs = SH.param_pspecs(cfg, params_abs, mesh, dp_axes)
    params_sh = to_shardings(mesh, pspecs)
    batch_abs = input_specs(cfg, shape)
    if absorbed_mla:
        object.__setattr__(cfg, "_absorbed_mla", True)

    if shape.kind == "prefill":
        bspec = {}
        for k, v in batch_abs.items():
            bspec[k] = P(*(SH.batch_pspec(mesh, dp_axes, v.shape[0]) + (None,) * (len(v.shape) - 2)))
        batch_sh = {k: NamedSharding(mesh, s) for k, s in bspec.items()}

        def prefill_step(params, batch):
            with SH.mesh_context(mesh, dp_axes):
                logits, cache = api.prefill(params, cfg, batch)
                return logits[:, -1:], cache

        jit_fn = jax.jit(prefill_step, in_shardings=(params_sh, batch_sh))
        return jit_fn, params_sh, batch_sh, params_abs

    # decode
    cache_abs = batch_abs["cache"]
    cspecs = SH.cache_pspecs(cfg, cache_abs, mesh, dp_axes, shape.global_batch)
    cache_sh = to_shardings(mesh, cspecs)
    tok_sh = NamedSharding(mesh, SH.batch_pspec(mesh, dp_axes, shape.global_batch))
    pos_sh = NamedSharding(mesh, P())

    def decode(params, cache, token, pos):
        with SH.mesh_context(mesh, dp_axes):
            logits, new_cache = api.decode_step(params, cfg, cache, token, pos)
            return logits, new_cache

    jit_fn = jax.jit(
        decode,
        in_shardings=(params_sh, cache_sh, tok_sh, pos_sh),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
    )
    return jit_fn, params_sh, {"cache": cache_sh, "token": tok_sh, "pos": pos_sh}, params_abs
