"""Fault tolerance + straggler mitigation for the training loop.

Mechanisms (all exercised by tests with injected failures):
  * checkpoint/restart — periodic async checkpoints, atomic commit, bit-exact
    resume (data pipeline is pure in (seed, step), so replay is deterministic)
  * step retry      — transient step failures are retried from the last good
    in-memory state; persistent failures trigger restore-from-checkpoint
  * straggler watch — per-step deadline from a running median; breaches are
    logged and surfaced to the orchestrator hook (on a real cluster this
    triggers hot-spare swap / re-shard; here the hook is injectable)
  * elastic scaling — on device-count change, `elastic.remesh_state` moves
    the state onto a new mesh and re-builds the step (see elastic.py)
"""
from __future__ import annotations

import logging
import statistics
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

log = logging.getLogger("repro.ft")


@dataclass
class FaultToleranceConfig:
    ckpt_every: int = 50
    max_retries: int = 2
    straggler_factor: float = 3.0
    min_history: int = 5


@dataclass
class StragglerWatch:
    factor: float = 3.0
    min_history: int = 5
    times: list = field(default_factory=list)
    events: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= self.min_history:
            med = statistics.median(self.times[-50:])
            if dt > self.factor * med:
                self.events.append({"step": step, "dt": dt, "median": med})
                is_straggler = True
        self.times.append(dt)
        return is_straggler


class ResilientLoop:
    """Wraps a step function with retry + checkpoint + straggler accounting."""

    def __init__(self, step_fn: Callable, state, make_batch: Callable,
                 checkpointer=None, ft: FaultToleranceConfig = FaultToleranceConfig(),
                 on_straggler: Optional[Callable] = None,
                 restore_fn: Optional[Callable] = None):
        self.step_fn = step_fn
        self.state = state
        self.make_batch = make_batch
        self.ckpt = checkpointer
        self.ft = ft
        self.watch = StragglerWatch(ft.straggler_factor, ft.min_history)
        self.on_straggler = on_straggler
        self.restore_fn = restore_fn
        self.failures: list = []

    def run(self, start_step: int, num_steps: int, metrics_cb=None):
        step = start_step
        while step < start_step + num_steps:
            batch = self.make_batch(step)
            t0 = time.monotonic()
            try:
                self.state, metrics = self._attempt(self.state, batch, step)
            except Exception as e:
                # persistent failure: restore from last checkpoint and replay
                self.failures.append({"step": step, "error": repr(e), "action": "restore"})
                if self.restore_fn is None:
                    raise
                self.state, restored_step = self.restore_fn()
                log.warning("step %d failed persistently; restored step %s", step, restored_step)
                step = restored_step
                continue
            dt = time.monotonic() - t0
            if self.watch.observe(step, dt) and self.on_straggler:
                self.on_straggler(step, dt)
            if metrics_cb:
                metrics_cb(step, metrics)
            step += 1
            if self.ckpt is not None and step % self.ft.ckpt_every == 0:
                self.ckpt.submit(self.state, step)
        if self.ckpt is not None:
            self.ckpt.submit(self.state, step)
            self.ckpt.wait()
        return self.state, step

    def _attempt(self, state, batch, step):
        last = None
        for attempt in range(self.ft.max_retries + 1):
            try:
                return self.step_fn(state, batch)
            except Exception as e:  # transient retry
                last = e
                self.failures.append({"step": step, "attempt": attempt, "error": repr(e), "action": "retry"})
                log.warning("step %d attempt %d failed: %r", step, attempt, e)
        raise last
