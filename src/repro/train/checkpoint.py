"""Sharded checkpointing with atomic commit and async save.

Layout: <dir>/step_<N>/ {manifest.json, arr_<i>.npy ...} written to a tmp dir
and committed via atomic rename — a killed run never leaves a half checkpoint
(fault-tolerance requirement). `save_async` offloads serialization to a
background thread so the train loop isn't blocked (compute/IO overlap)."""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading

import ml_dtypes
import numpy as np
import jax


def _np_dtype(name: str) -> np.dtype:
    """Resolve a dtype name, including ml_dtypes extensions (bfloat16, fp8)."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _paths_of(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in kp) for kp, _ in flat]
    leaves = [l for _, l in flat]
    return keys, leaves, treedef


def save(state, step: int, ckpt_dir: str):
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    keys, leaves, _ = _paths_of(state)
    manifest = {"step": step, "arrays": []}
    for i, (k, leaf) in enumerate(zip(keys, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        entry = {"key": k, "file": f"arr_{i}.npy",
                 "dtype": str(arr.dtype), "shape": list(arr.shape)}
        if arr.dtype.kind not in "biufc":
            # Extended dtype (bfloat16/fp8 from ml_dtypes): npy would silently
            # degrade it to a void dtype, so store raw bytes + logical dtype.
            arr = np.ascontiguousarray(arr).view(np.uint8).reshape(-1)
            entry["raw_bytes"] = True
        np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
        manifest["arrays"].append(entry)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    return final


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(state_like, ckpt_dir: str, step: int = None, shardings=None):
    """Restore into the structure of ``state_like`` (abstract or concrete)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        return None, None
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    keys, leaves, treedef = _paths_of(state_like)
    by_key = {a["key"]: a for a in manifest["arrays"]}
    out = []
    sh_leaves = jax.tree.leaves(shardings) if shardings is not None else [None] * len(leaves)
    for k, leaf, sh in zip(keys, leaves, sh_leaves):
        a = by_key[k]
        arr = np.load(os.path.join(d, a["file"]))
        if a.get("raw_bytes"):
            arr = arr.view(_np_dtype(a["dtype"])).reshape(a["shape"])
        out.append(jax.device_put(arr, sh) if sh is not None else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), step


class AsyncCheckpointer:
    """Background-thread saver with a bounded queue (drops never, blocks when
    a save is still in flight — backpressure instead of OOM)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self.q: "queue.Queue" = queue.Queue(maxsize=1)
        self.errors: list = []
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self.q.get()
            if item is None:
                return
            state_np, step = item
            try:
                save(state_np, step, self.ckpt_dir)
                self._gc()
            except Exception as e:  # pragma: no cover
                self.errors.append(e)
            finally:
                self.q.task_done()

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"), ignore_errors=True)

    def submit(self, state, step: int):
        state_np = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self.q.put((state_np, step))

    def wait(self):
        self.q.join()

    def close(self):
        self.q.join()
        self.q.put(None)
        self._t.join()
