"""Architecture registry: the 10 assigned architectures (+ the graph pillar).

Every entry is the exact published configuration from the assignment table;
``get_config(name, smoke=True)`` returns the reduced same-family variant used
by CPU smoke tests. Full configs are only ever lowered abstractly (dry-run).
"""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig

ARCH_NAMES = [
    "internvl2-26b",
    "whisper-small",
    "zamba2-7b",
    "qwen2.5-3b",
    "h2o-danube-1.8b",
    "deepseek-7b",
    "minitron-4b",
    "mamba2-130m",
    "qwen3-moe-235b-a22b",
    "deepseek-v2-lite-16b",
]

_MODULES = {n: "repro.configs." + n.replace("-", "_").replace(".", "_") for n in ARCH_NAMES}


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(_MODULES[name])
    cfg = mod.CONFIG
    return cfg.smoke() if smoke else cfg


def all_configs(smoke: bool = False):
    return {n: get_config(n, smoke) for n in ARCH_NAMES}
