"""Zamba2-7B [arXiv:2411.15242]: 81 Mamba2 layers + ONE shared attention
block applied every 6 layers (shared-parameter hybrid). d=3584, 32 heads,
d_ff=14336 (shared block FFN), vocab=32000, ssm_state=64."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab=32000, head_dim=112, attn_every=6,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_kernel=4, n_groups=1, chunk=256),
    subquadratic=True,
    train_microbatch=16,
)
