"""InternVL2-26B [arXiv:2404.16821]: InternViT frontend (stub) + InternLM2
backbone. 48L, d=6144, 48 heads (GQA kv=8), d_ff=16384, vocab=92553.
The ViT is a modality stub per the assignment: input_specs() provides
precomputed patch embeddings prepended to the token sequence."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=92553, head_dim=128, n_patches=1024, rope_theta=1_000_000.0,
    fsdp=True,
    train_microbatch=16,
)
