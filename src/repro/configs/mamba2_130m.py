"""Mamba2-130M [arXiv:2405.21060]: attention-free SSD. 24L, d=768,
vocab=50280, ssm_state=128."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, head_dim=64, tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_kernel=4, n_groups=1, chunk=256),
    subquadratic=True,
)
