"""DeepSeek-V2-Lite-16B [arXiv:2405.04434]: MLA (kv_lora=512) + MoE with
2 shared + 64 routed experts, top-6, d_expert=1408. 27L, d=2048, 16 heads."""
from repro.configs.base import ModelConfig, MLAConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b", family="moe",
    n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=102400, head_dim=128,
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2, d_shared=2816),
    mla=MLAConfig(kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128, q_lora_rank=0),
    train_microbatch=64,
)
