"""Whisper-small [arXiv:2212.04356]: enc-dec, 12+12L, d=768, 12 heads,
d_ff=3072, vocab=51865. Conv audio frontend is a stub (frame embeddings)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, encoder_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab=51865, head_dim=64,
    train_microbatch=64,
)
