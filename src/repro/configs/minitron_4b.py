"""Minitron-4B [arXiv:2407.14679]: pruned Nemotron. 32L, d=3072,
24 heads (GQA kv=8), d_ff=9216, vocab=256000."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=9216,
    vocab=256000, head_dim=128,
    train_microbatch=64,
)
