"""Config system: model + parallelism + shape configs.

One dataclass drives everything: model construction, sharding rules, the
dry-run input specs, and the roofline's MODEL_FLOPS accounting.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int           # per-expert FFN width
    n_shared: int = 0       # shared (always-on) experts
    d_shared: int = 0       # width of the shared expert block
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: int = 0    # 0 = plain q projection (DeepSeek-V2-Lite)


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_kernel: int = 4
    n_groups: int = 1
    chunk: int = 256


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0            # 0 → d_model // n_heads
    qkv_bias: bool = False
    sliding_window: int = 0      # 0 = full attention
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid: one attention block (shared params) applied every `attn_every`
    # ssm layers (Zamba2-style); 0 disables
    attn_every: int = 0
    # encoder-decoder (whisper): number of encoder layers (0 = decoder-only)
    encoder_layers: int = 0
    # vlm: number of prepended patch-embedding positions in input_specs
    n_patches: int = 0
    # parallelism / memory
    fsdp: bool = False           # additionally shard big weights on the data axis
    remat: str = "full"          # full | none
    dtype: str = "bfloat16"
    # sub-quadratic? (drives long_500k applicability)
    subquadratic: bool = False
    # attention implementation: "xla_chunked" (portable twin, what the CPU
    # dry-run lowers) | "pallas_flash" (TPU production path; interpret-mode
    # on CPU for tests)
    attn_impl: str = "xla_chunked"
    # embedding-table padding so the vocab dim shards evenly over any mesh
    # axis combination (16 model × 32 dp); logits at padded columns are
    # masked in the loss. 1 = no padding (smoke configs).
    vocab_pad: int = 512
    # default gradient-accumulation microbatch (global sequences per micro
    # step) for the train_4k shape; 0 = no accumulation. Sized so the
    # remat-saved layer-boundary stack fits a 16 GiB v5e chip.
    train_microbatch: int = 0

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab + self.vocab_pad - 1) // self.vocab_pad) * self.vocab_pad

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(1, self.n_heads))

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        kw = dict(
            n_layers=min(self.n_layers, 2 if not self.attn_every else 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128,
            vocab=257,
            vocab_pad=1,
            head_dim=16,
            sliding_window=8 if self.sliding_window else 0,
            n_patches=4 if self.n_patches else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            attn_every=2 if self.attn_every else 0,
            fsdp=False,
            train_microbatch=0,
        )
        if self.moe:
            kw["moe"] = MoEConfig(n_experts=4, top_k=2, d_expert=32,
                                  n_shared=self.moe.n_shared and 1,
                                  d_shared=32 if self.moe.d_shared else 0)
        if self.mla:
            kw["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                                  qk_rope_head_dim=8, v_head_dim=16)
        if self.ssm:
            kw["ssm"] = SSMConfig(d_state=16, head_dim=16, expand=2, chunk=8)
        return replace(self, **kw)

    # ---- parameter count (for MODEL_FLOPS = 6·N·D) -------------------------
    def param_count(self, active_only: bool = False) -> int:
        d, L = self.d_model, self.n_layers
        hd = self.resolved_head_dim
        n = 0
        # embeddings
        n += self.vocab * d
        if not self.tie_embeddings:
            n += self.vocab * d
        per_layer = 0
        if self.family == "ssm" or self.attn_every:
            s = self.ssm
            d_inner = s.expand * d
            nh = d_inner // s.head_dim
            conv_dim = d_inner + 2 * s.n_groups * s.d_state
            zxbcdt = 2 * d_inner + 2 * s.n_groups * s.d_state + nh
            per_layer += d * zxbcdt + conv_dim * s.conv_kernel + d_inner * d + 3 * nh + d_inner
        attn_params = 0
        if self.mla:
            m = self.mla
            qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
            attn_params += d * (m.kv_lora_rank + m.qk_rope_head_dim)
            attn_params += m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
            attn_params += d * self.n_heads * qk_dim
            attn_params += self.n_heads * m.v_head_dim * d
        elif self.n_heads:
            attn_params += d * self.n_heads * hd      # q
            attn_params += 2 * d * self.n_kv_heads * hd  # kv
            attn_params += self.n_heads * hd * d      # o
        ffn_params = 0
        if self.moe:
            mo = self.moe
            ffn_params += d * mo.n_experts  # router
            ffn_params += mo.n_experts * 3 * d * mo.d_expert
            if mo.n_shared:
                ffn_params += 3 * d * mo.d_shared
        elif self.d_ff:
            ffn_params = 3 * d * self.d_ff
        if self.family == "ssm":
            n += L * per_layer
        elif self.attn_every:  # hybrid: L ssm layers + ONE shared attn+ffn block
            n += L * per_layer + attn_params + ffn_params
        elif self.encoder_layers:
            n += (L + self.encoder_layers) * (attn_params + ffn_params)
            n += L * attn_params  # cross attention in decoder
        else:
            n += L * (attn_params + ffn_params)
        if active_only and self.moe:
            mo = self.moe
            active_ffn = d * mo.n_experts + (mo.top_k * 3 * d * mo.d_expert) + (3 * d * mo.d_shared if mo.n_shared else 0)
            n -= L * ffn_params
            n += L * active_ffn
        return int(n)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> dict:
    """Which of the 4 assigned shapes run for this arch (skips recorded)."""
    out = {}
    for name, sh in SHAPES.items():
        if name == "long_500k" and not cfg.subquadratic:
            out[name] = "skip: full-attention arch (long_500k needs sub-quadratic attention)"
        else:
            out[name] = "run"
    return out
