"""Qwen3-MoE-235B-A22B [hf:Qwen/Qwen3]: 94L, d=4096, 64 heads (GQA kv=4),
128 experts top-8 with d_expert=1536, vocab=151936. FSDP sharding on top of
EP/TP (235B params don't fit TP-16 alone on v5e)."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_ff=1536,
    vocab=151936, head_dim=128, rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=1536),
    fsdp=True,
    train_microbatch=16,
)
