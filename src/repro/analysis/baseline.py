"""Grandfather list for the invariant linter.

``baseline.json`` holds findings that are INTENTIONAL — each entry keys a
finding by its line-number-free identity `(rule, path, symbol, snippet)`
and carries a mandatory written justification. The CLI subtracts matched
entries from the live findings; anything left is NEW and fails the gate.

Staleness cuts the other way: a baseline entry no longer matched by any
live finding means the code it excused has changed — the entry must be
deleted (exit 2), so the list only ever shrinks by conscious edits and
the grandfathered debt is always real.
"""
from __future__ import annotations

import json
import os
from dataclasses import dataclass, field

DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baseline.json")


@dataclass
class BaselineMatch:
    """Outcome of subtracting the baseline from one linter pass."""

    new: list = field(default_factory=list)        # findings not baselined
    matched: list = field(default_factory=list)    # (finding, justification)
    stale: list = field(default_factory=list)      # unmatched baseline entries
    unjustified: list = field(default_factory=list)  # entries w/o reason
    size: int = 0                                  # total baseline entries


def load_baseline(path: str = DEFAULT_BASELINE) -> list:
    """The checked-in entry list (possibly empty if the file is absent)."""
    if not os.path.isfile(path):
        return []
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    return list(data.get("entries", []))


def entry_key(entry: dict):
    return (entry.get("rule", ""), entry.get("path", ""),
            entry.get("symbol", ""), entry.get("snippet", ""))


def apply_baseline(findings, entries) -> BaselineMatch:
    """Subtract `entries` from `findings` as a multiset keyed on
    Finding.key() — two identical snippets in one symbol need two
    entries, so baselining one occurrence never hides a second."""
    result = BaselineMatch(size=len(entries))
    budget: dict = {}
    for e in entries:
        just = (e.get("justification") or "").strip()
        if not just:
            result.unjustified.append(e)
            continue
        budget.setdefault(entry_key(e), []).append(e)
    for f in findings:
        bucket = budget.get(f.key())
        if bucket:
            entry = bucket.pop(0)
            result.matched.append((f, entry["justification"]))
        else:
            result.new.append(f)
    for bucket in budget.values():
        result.stale.extend(bucket)
    return result
