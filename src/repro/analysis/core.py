"""Rule engine for the invariant linter (stdlib `ast`, no hard deps).

A `Rule` inspects one parsed module at a time (`check(ctx)`) and yields
`Finding`s; a `TreeRule` additionally sees the whole checkout once
(`check_tree(root, relpaths)`) for cross-file contracts like the kernel
directory triple. `ModuleContext` carries the parsed tree, the raw source
lines, and a node → enclosing-qualname map so findings name the function
or class they live in (baseline matching keys on that symbol, not on line
numbers, so entries survive unrelated edits).

Suppression protocol: a finding on line L is silenced iff line L or L-1
carries ``# lint: disable=RULE[,RULE...] -- reason`` naming the rule. The
``-- reason`` part is MANDATORY — a suppression without a written
justification does not suppress (the finding stays, with a note), which
is what keeps inline exemptions as accountable as baseline entries.
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field

_SUPPRESS_RE = re.compile(
    r"#\s*lint:\s*disable=([A-Za-z0-9_,-]+)\s*(?:--\s*(\S.*))?")


@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str          # repo-relative posix path
    line: int          # 1-based
    col: int           # 0-based
    symbol: str        # enclosing qualname ("<module>" at top level)
    message: str
    snippet: str       # stripped source line — baseline identity component

    def key(self):
        """Line-number-free identity used for baseline matching."""
        return (self.rule, self.path, self.symbol, self.snippet)

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.symbol}] {self.message}")


class Rule:
    """One named invariant. Subclasses set `name`/`summary`/`contract` and
    implement `check`; `scope` is a tuple of repo-relative posix path
    prefixes the rule applies to (empty = everywhere)."""

    name: str = ""
    summary: str = ""       # one line, shown by --list-rules
    contract: str = ""      # the full contract + motivating PR/bug
    scope: tuple = ()
    exclude: tuple = ()

    def applies(self, relpath: str) -> bool:
        if any(relpath.startswith(p) for p in self.exclude):
            return False
        return (not self.scope
                or any(relpath.startswith(p) for p in self.scope))

    def check(self, ctx: "ModuleContext"):
        return ()


class TreeRule(Rule):
    """A rule over the whole checkout (runs once, not per module)."""

    def check_tree(self, root: str, relpaths: list):
        return ()


class ModuleContext:
    """Parsed view of one module handed to every applicable rule."""

    def __init__(self, relpath: str, source: str):
        self.relpath = relpath
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=relpath)
        self._qualname: dict = {}
        self._assign_qualnames(self.tree, "<module>")

    def _assign_qualnames(self, node, qual):
        for child in ast.iter_child_nodes(node):
            child_qual = qual
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef)):
                child_qual = (child.name if qual == "<module>"
                              else f"{qual}.{child.name}")
            self._qualname[child] = child_qual
            self._assign_qualnames(child, child_qual)

    def symbol_of(self, node) -> str:
        return self._qualname.get(node, "<module>")

    def snippet_at(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def finding(self, rule: Rule, node, message: str) -> Finding:
        line = getattr(node, "lineno", 1)
        return Finding(rule=rule.name, path=self.relpath, line=line,
                       col=getattr(node, "col_offset", 0),
                       symbol=self.symbol_of(node), message=message,
                       snippet=self.snippet_at(line))

    # ---------------------------------------------------------- suppression
    def suppression_for(self, finding: Finding):
        """Return the (rules, reason) suppression covering `finding`, or a
        (rules, None) malformed one, or None when no directive is present."""
        for line in (finding.line, finding.line - 1):
            if not (1 <= line <= len(self.lines)):
                continue
            m = _SUPPRESS_RE.search(self.lines[line - 1])
            if m:
                rules = tuple(r.strip() for r in m.group(1).split(","))
                if finding.rule in rules:
                    return rules, m.group(2)
        return None


@dataclass
class LintResult:
    """Everything one linter pass learned, pre-baseline."""

    findings: list = field(default_factory=list)    # live (unsuppressed)
    suppressed: list = field(default_factory=list)  # (finding, reason)
    errors: list = field(default_factory=list)      # unparsable files
    files_scanned: int = 0


def _apply_suppressions(ctx: ModuleContext, findings, result: LintResult):
    for f in findings:
        sup = ctx.suppression_for(f)
        if sup is None:
            result.findings.append(f)
        elif sup[1] is None:
            result.findings.append(Finding(
                rule=f.rule, path=f.path, line=f.line, col=f.col,
                symbol=f.symbol, snippet=f.snippet,
                message=(f.message + " (suppression present but has no "
                         "'-- reason'; a justification is mandatory)")))
        else:
            result.suppressed.append((f, sup[1]))


def lint_source(source: str, relpath: str, rules) -> LintResult:
    """Lint one in-memory module — the test/fixture entry point."""
    result = LintResult(files_scanned=1)
    try:
        ctx = ModuleContext(relpath, source)
    except SyntaxError as e:
        result.errors.append(f"{relpath}: {e}")
        return result
    for rule in rules:
        if isinstance(rule, TreeRule) or not rule.applies(relpath):
            continue
        _apply_suppressions(ctx, list(rule.check(ctx)), result)
    return result


def collect_files(root: str, paths) -> list:
    """All .py files under `paths` (files or dirs, relative to `root`),
    as sorted repo-relative posix paths — the walk order is part of the
    deterministic-output contract."""
    out = []
    for p in paths:
        ap = os.path.join(root, p)
        if os.path.isfile(ap) and ap.endswith(".py"):
            out.append(os.path.relpath(ap, root).replace(os.sep, "/"))
            continue
        for dirpath, dirnames, filenames in os.walk(ap):
            dirnames[:] = sorted(d for d in dirnames
                                 if not d.startswith(".")
                                 and d != "__pycache__")
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    rel = os.path.relpath(os.path.join(dirpath, fn), root)
                    out.append(rel.replace(os.sep, "/"))
    return sorted(set(out))


def lint_paths(root: str, paths, rules) -> LintResult:
    """Lint every module under `paths`, then run the tree rules once."""
    result = LintResult()
    relpaths = collect_files(root, paths)
    for relpath in relpaths:
        with open(os.path.join(root, relpath), encoding="utf-8") as fh:
            source = fh.read()
        try:
            ctx = ModuleContext(relpath, source)
        except SyntaxError as e:
            result.errors.append(f"{relpath}: {e}")
            continue
        result.files_scanned += 1
        for rule in rules:
            if isinstance(rule, TreeRule) or not rule.applies(relpath):
                continue
            _apply_suppressions(ctx, list(rule.check(ctx)), result)
    for rule in rules:
        if isinstance(rule, TreeRule):
            result.findings.extend(rule.check_tree(root, relpaths))
    return result
