"""The invariant catalog: one `Rule` per contract the repo's bug history
taught us to enforce (DESIGN.md §10 documents each with its motivating PR).

Every rule names the contract, the incident that motivated it, and its
scope. Suppress a deliberate exception inline with

    # lint: disable=RULE -- why this site is exempt

or grandfather it in ``baseline.json`` with a written justification.
"""
from __future__ import annotations

import ast
import os
import re

from repro.analysis.core import Finding, Rule, TreeRule


def dotted(node) -> str:
    """Dotted name of an expression ('np.random.default_rng'), or ''."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


def _walk_calls(tree):
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node


# ---------------------------------------------------------------------------
# SEED-DISCIPLINE
# ---------------------------------------------------------------------------
class SEED_DISCIPLINE(Rule):
    name = "SEED-DISCIPLINE"
    summary = ("RNG must flow through SeedSequence.spawn / "
               "shingle_seed_streams — no global-state RNG, no hand-rolled "
               "seed arithmetic")
    contract = (
        "Determinism across partitions/backends/thread schedules rests on "
        "every RNG stream being a SeedSequence child. Arithmetic on raw "
        "seeds aliases: the pre-PR-4 `seed * 7919 + t` collided (seed=0, "
        "t=7919 ≡ seed=1, t=0) and silently correlated iterations. "
        "Global-state RNG (`np.random.rand`, stdlib `random.*`) is "
        "order-dependent and thread-hostile. Flags: legacy "
        "`np.random.<fn>()` module-level draws, stdlib `random.<fn>()` "
        "draws, and `default_rng`/`SeedSequence` whose seed argument is an "
        "arithmetic expression. Derive streams with "
        "`SeedSequence(seed).spawn(n)` or entropy tuples "
        "`SeedSequence((seed, tag))` instead (PR 4; core/engine.py).")
    scope = ("src/repro/",)
    exclude = ("src/repro/analysis/",)

    _LEGACY_NP = {
        "seed", "rand", "randn", "randint", "random", "random_sample",
        "choice", "shuffle", "permutation", "normal", "uniform", "zipf",
        "poisson", "binomial", "exponential", "bytes",
    }
    _STDLIB = {
        "seed", "random", "randint", "randrange", "choice", "choices",
        "shuffle", "sample", "uniform", "gauss", "getrandbits",
    }
    _SEEDED = ("default_rng", "SeedSequence")

    def check(self, ctx):
        has_stdlib_random = any(
            isinstance(n, ast.Import)
            and any(a.name == "random" for a in n.names)
            for n in ast.walk(ctx.tree))
        for call in _walk_calls(ctx.tree):
            fn = dotted(call.func)
            if not fn:
                continue
            parts = fn.split(".")
            if (len(parts) == 3 and parts[0] in ("np", "numpy")
                    and parts[1] == "random"
                    and parts[2] in self._LEGACY_NP):
                yield ctx.finding(self, call,
                                  f"legacy global-state RNG `{fn}()`; draw "
                                  f"from a `SeedSequence`-derived Generator")
            elif (has_stdlib_random and len(parts) == 2
                  and parts[0] == "random" and parts[1] in self._STDLIB):
                yield ctx.finding(self, call,
                                  f"stdlib `{fn}()` is global-state RNG; "
                                  f"use a `SeedSequence`-derived Generator")
            elif parts[-1] in self._SEEDED and call.args:
                seed = call.args[0]
                if isinstance(seed, (ast.BinOp, ast.UnaryOp)):
                    yield ctx.finding(
                        self, call,
                        f"hand-rolled seed arithmetic in `{parts[-1]}(...)`"
                        f" can alias streams; spawn a child stream or pass "
                        f"an entropy tuple `SeedSequence((seed, tag))`")


# ---------------------------------------------------------------------------
# JIT-CACHE-BOUND
# ---------------------------------------------------------------------------
class JIT_CACHE_BOUND(Rule):
    name = "JIT-CACHE-BOUND"
    summary = ("module-level executable caches must be "
               "`kernels.common.LruCache`, never a bare dict")
    contract = (
        "Compiled jit/shard_map/pallas executables hold device buffers; a "
        "module-level dict keyed on padded shapes grows for the life of "
        "the process as batch shapes drift (the pre-PR-5 leak: one "
        "executable per shape, forever). Any module-level assignment of a "
        "`{}`/`dict()`/`OrderedDict()` to a name containing 'CACHE' must "
        "be `kernels.common.LruCache` instead (ISSUE 5; "
        "kernels/common.py).")
    scope = ("src/repro/",)
    exclude = ("src/repro/analysis/",)

    def check(self, ctx):
        for node in ctx.tree.body:  # module level only
            targets = []
            value = None
            if isinstance(node, ast.Assign):
                targets, value = node.targets, node.value
            elif isinstance(node, ast.AnnAssign) and node.value is not None:
                targets, value = [node.target], node.value
            for t in targets:
                if not (isinstance(t, ast.Name) and "CACHE" in t.id.upper()):
                    continue
                bare = isinstance(value, ast.Dict) or (
                    isinstance(value, ast.Call)
                    and dotted(value.func).split(".")[-1] in ("dict",
                                                              "OrderedDict"))
                if bare:
                    yield ctx.finding(
                        self, node,
                        f"module-level cache `{t.id}` is an unbounded dict; "
                        f"executables leak per shape — use "
                        f"`kernels.common.LruCache`")


# ---------------------------------------------------------------------------
# INT-RANK-ONLY
# ---------------------------------------------------------------------------
class INT_RANK_ONLY(Rule):
    name = "INT-RANK-ONLY"
    summary = ("no float division or float-literal comparison in the "
               "merge decision paths (rank/Saving/θ)")
    contract = (
        "PR 5/6 rebuilt ranking and Saving acceptance on integer-only "
        "keys (`rank_keys`, cross-product rational compares, quantized "
        "θ̂) so numpy/XLA/Pallas order candidates bit-identically — float "
        "division rounds differently across substrates and silently "
        "splits backends. In the decision-path modules "
        "(core/merging.py, core/distributed.py, kernels/bitset_fold/) "
        "true division `/` and float-literal comparisons are banned; "
        "float similarity VIEWS for diagnostics are fine but must be "
        "baselined or suppressed with a justification saying no decision "
        "reads them (ISSUE 5/7; DESIGN.md §9).")
    scope = ("src/repro/core/merging.py", "src/repro/core/distributed.py",
             "src/repro/kernels/bitset_fold/")

    def check(self, ctx):
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Div):
                yield ctx.finding(
                    self, node,
                    "float (true) division in a decision-path module; use "
                    "integer keys (`rank_keys`) / exact rational compares, "
                    "or justify the float view")
            elif isinstance(node, ast.Compare):
                operands = [node.left] + list(node.comparators)
                if any(isinstance(o, ast.Constant)
                       and isinstance(o.value, float) for o in operands):
                    yield ctx.finding(
                        self, node,
                        "comparison against a float literal in a "
                        "decision-path module; quantize to the integer "
                        "contract (theta_to_p / rank_keys)")


# ---------------------------------------------------------------------------
# NONDET-ITER
# ---------------------------------------------------------------------------
class NONDET_ITER(Rule):
    name = "NONDET-ITER"
    summary = ("no iteration over sets (or .keys()) in canonical-order "
               "paths without an explicit sorted(...)")
    contract = (
        "Merge replay, emission and pruning promise canonical order: "
        "summaries are bit-identical for any partition count, backend or "
        "thread schedule, which every equivalence test leans on. "
        "Iterating a set (or materializing one via list()/np.asarray()) "
        "exposes hash-table order — stable only by accident of insertion "
        "history. In the canonical-order modules, wrap set iteration in "
        "`sorted(...)` (insertion-ordered dict iteration is allowed; the "
        "determinism argument covers it). Motivated by the PR-4 exchange "
        "replay contract (DESIGN.md §8).")
    scope = ("src/repro/core/slugger.py", "src/repro/core/engine.py",
             "src/repro/core/merging.py", "src/repro/core/encode_batched.py",
             "src/repro/core/encode_dp.py", "src/repro/core/pruning.py",
             "src/repro/core/summary.py", "src/repro/core/summary_ir.py",
             "src/repro/core/minhash.py", "src/repro/graphs/partitioned.py",
             "src/repro/graphs/csr.py")

    _MATERIALIZERS = ("list", "tuple", "np.asarray", "np.array",
                      "numpy.asarray", "numpy.array", "np.fromiter",
                      "enumerate")

    def _set_names(self, func_node):
        """Local names bound to an obvious set expression in this scope."""
        names = set()
        for node in ast.walk(func_node):
            if isinstance(node, ast.Assign) and self._is_set_expr(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        names.add(t.id)
        return names

    @staticmethod
    def _is_set_expr(node) -> bool:
        return (isinstance(node, (ast.Set, ast.SetComp))
                or (isinstance(node, ast.Call)
                    and dotted(node.func) in ("set", "frozenset")))

    def _is_set_valued(self, node, set_names) -> bool:
        if self._is_set_expr(node):
            return True
        if isinstance(node, ast.Name) and node.id in set_names:
            return True
        if (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "keys"):
            return True
        return False

    def check(self, ctx):
        funcs = [n for n in ast.walk(ctx.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        scopes = [(f, self._set_names(f)) for f in funcs] or [(ctx.tree,
                                                               set())]
        seen = set()
        for func, set_names in scopes:
            for node in ast.walk(func):
                iters = []
                if isinstance(node, ast.For):
                    iters.append(node.iter)
                elif isinstance(node, (ast.ListComp, ast.SetComp,
                                       ast.GeneratorExp, ast.DictComp)):
                    iters.extend(g.iter for g in node.generators)
                elif (isinstance(node, ast.Call)
                      and dotted(node.func) in self._MATERIALIZERS
                      and node.args):
                    iters.append(node.args[0])
                for it in iters:
                    if (self._is_set_valued(it, set_names)
                            and id(it) not in seen):
                        seen.add(id(it))
                        yield ctx.finding(
                            self, it,
                            "iteration over a set exposes hash order in a "
                            "canonical-order path; wrap in `sorted(...)`")


# ---------------------------------------------------------------------------
# NO-RECURSION-LIMIT
# ---------------------------------------------------------------------------
class NO_RECURSION_LIMIT(Rule):
    name = "NO-RECURSION-LIMIT"
    summary = "`sys.setrecursionlimit` is banned"
    contract = (
        "Raising the interpreter recursion limit is how the seed emitter "
        "masked an O(height) recursive DP until deep forests overflowed "
        "the C stack anyway; PR 2 replaced the production emitter with "
        "level-synchronous array passes and deleted the module-level "
        "bump. New code must be iterative. The one sanctioned exception "
        "(the reference emitter kept for cross-checking, scoped and "
        "restored in a finally) carries an inline suppression "
        "(ISSUE 2/3; core/slugger.py).")
    scope = ("src/repro/", "benchmarks/")

    def check(self, ctx):
        for call in _walk_calls(ctx.tree):
            if dotted(call.func).split(".")[-1] == "setrecursionlimit":
                yield ctx.finding(
                    self, call,
                    "`sys.setrecursionlimit` call; restructure to "
                    "iteration (flat IR / explicit stack)")


# ---------------------------------------------------------------------------
# DTYPE-WIDTH
# ---------------------------------------------------------------------------
class DTYPE_WIDTH(Rule):
    name = "DTYPE-WIDTH"
    summary = ("no int64/uint64 dtypes on device-bound tensors "
               "(x64 is disabled; jax truncates silently)")
    contract = (
        "Device arrays run with x64 disabled: `jnp.int64` resolves to "
        "int32 with only a warning, and shipping an int64 host array "
        "through `jnp.asarray`/`device_put` truncates the same way — the "
        "PR-3 conftest guard catches this at RUNTIME via the "
        "'Explicitly requested dtype' warning; this rule catches the "
        "pattern statically. Flags any `jnp.int64`/`jnp.uint64` "
        "reference, and 64-bit integer dtype arguments handed directly "
        "to a device-upload call. Stage device-bound tensors as "
        "int32/uint32 explicitly (ISSUE 3; tests/conftest.py).")
    scope = ("src/repro/",)
    exclude = ("src/repro/analysis/",)

    _UPLOADERS = {"jnp.asarray", "jnp.array", "jnp.arange", "jnp.zeros",
                  "jnp.ones", "jnp.full", "jax.device_put"}
    _WIDE = {"jnp.int64", "jnp.uint64", "np.int64", "np.uint64",
             "numpy.int64", "numpy.uint64", "int64", "uint64"}

    def check(self, ctx):
        for node in ast.walk(ctx.tree):
            if (isinstance(node, ast.Attribute)
                    and dotted(node) in ("jnp.int64", "jnp.uint64")):
                yield ctx.finding(
                    self, node,
                    f"`{dotted(node)}` on a device tensor silently "
                    f"truncates to 32 bits under disabled x64; use an "
                    f"explicit 32-bit dtype")
            elif (isinstance(node, ast.Call)
                  and dotted(node.func) in self._UPLOADERS):
                wide = [a for a in list(node.args) + [k.value for k in
                                                      node.keywords]
                        if dotted(a) in self._WIDE
                        or (isinstance(a, ast.Call)
                            and isinstance(a.func, ast.Attribute)
                            and a.func.attr == "astype"
                            and any(dotted(x) in self._WIDE
                                    for x in a.args))]
                for a in wide:
                    yield ctx.finding(
                        self, node,
                        "64-bit integer dtype handed to a device upload; "
                        "it truncates to 32 bits under disabled x64 — "
                        "stage as int32/uint32 explicitly")


# ---------------------------------------------------------------------------
# HOST-SYNC-IN-LOOP
# ---------------------------------------------------------------------------
class HOST_SYNC_IN_LOOP(Rule):
    name = "HOST-SYNC-IN-LOOP"
    summary = ("device→host syncs inside round/carry loops must be "
               "transfer-accounted (TransferCounter)")
    contract = (
        "The resident backend's whole benchmark story (`BENCH_resident` "
        "gates bytes/round and bytes/iteration) assumes EVERY host↔device "
        "crossing reports to `core.transfer`. A stray `np.asarray(...)`/"
        "`.item()`/`device_get` inside a merge-round or carry loop is an "
        "unaccounted blocking sync: it corrupts the byte ledger and "
        "serializes the device pipeline. In the residency modules, any "
        "materializing sync lexically inside a for/while whose enclosing "
        "function never touches a `add_d2h`/`add_h2d` counter is flagged "
        "(ISSUE 6/7; core/transfer.py, DESIGN.md §9).")
    scope = ("src/repro/core/resident.py", "src/repro/core/merging.py",
             "src/repro/core/engine.py", "src/repro/kernels/bitset_fold/")

    _SYNC_FNS = {"jax.device_get", "np.asarray", "numpy.asarray"}
    _SYNC_METHODS = {"item", "block_until_ready"}

    def _is_sync(self, call) -> bool:
        fn = dotted(call.func)
        if fn in self._SYNC_FNS:
            # only flag materialization of a call result or device-state
            # attribute (`self._bits`-style) — host-array reshuffles with a
            # plain name argument are not syncs
            arg = call.args[0] if call.args else None
            return isinstance(arg, ast.Call) or (
                isinstance(arg, ast.Attribute) and arg.attr.startswith("_"))
        return (isinstance(call.func, ast.Attribute)
                and call.func.attr in self._SYNC_METHODS
                and not call.args)

    def check(self, ctx):
        funcs = [n for n in ast.walk(ctx.tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        for func in funcs:
            accounted = any(
                isinstance(c.func, ast.Attribute)
                and c.func.attr in ("add_d2h", "add_h2d")
                for c in _walk_calls(func))
            if accounted:
                continue
            for loop in ast.walk(func):
                if not isinstance(loop, (ast.For, ast.While)):
                    continue
                for call in _walk_calls(loop):
                    if self._is_sync(call):
                        yield ctx.finding(
                            self, call,
                            "device sync inside a loop with no transfer "
                            "accounting in scope; route it through "
                            "`TransferCounter.add_d2h/add_h2d`")


# ---------------------------------------------------------------------------
# ITER-REUPLOAD
# ---------------------------------------------------------------------------
class ITER_REUPLOAD(Rule):
    name = "ITER-REUPLOAD"
    summary = ("no host→device upload of a loop-invariant tensor inside "
               "an iteration loop")
    contract = (
        "The ISSUE-9 adjacency bank exists because re-shipping unchanged "
        "state every iteration was the dominant cost (79MB/iteration of "
        "`phase=upload` at 220k edges). The bug class: a `jnp.asarray`/"
        "`jax.device_put` (or an arena `_put`/`_replicate`) inside a "
        "for/while whose first argument is a bare name NEVER assigned in "
        "that loop's body — i.e. an iteration-invariant tensor uploaded "
        "once per iteration instead of once per run. Hoist the upload out "
        "of the loop, or carry the state on device across iterations "
        "(the `ResidentAdjacencyBank` pattern, DESIGN.md §9). Slabs built "
        "inside the loop (assigned in its body) are per-iteration payloads "
        "and stay legal.")
    scope = ("src/repro/core/resident.py", "src/repro/core/engine.py",
             "src/repro/kernels/")

    _UPLOADERS = {"jnp.asarray", "jnp.array", "jax.device_put",
                  "jnp.device_put"}
    _METHODS = {"device_put", "_put", "_replicate"}

    @staticmethod
    def _assigned_names(loop) -> set:
        names = set()
        for node in ast.walk(loop):
            if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign,
                                 ast.NamedExpr)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    for leaf in ast.walk(t):
                        if isinstance(leaf, ast.Name):
                            names.add(leaf.id)
            elif isinstance(node, ast.For):
                for leaf in ast.walk(node.target):
                    if isinstance(leaf, ast.Name):
                        names.add(leaf.id)
        return names

    def _is_uploader(self, call) -> bool:
        fn = dotted(call.func)
        if fn in self._UPLOADERS:
            return True
        return (isinstance(call.func, ast.Attribute)
                and call.func.attr in self._METHODS)

    def check(self, ctx):
        calls: list = []

        def visit(node, loop):
            if isinstance(node, (ast.For, ast.While)):
                loop = node
            elif isinstance(node, ast.Call) and loop is not None:
                calls.append((node, loop))
            for child in ast.iter_child_nodes(node):
                visit(child, loop)

        visit(ctx.tree, None)
        assigned: dict = {}
        for call, loop in calls:
            if not self._is_uploader(call):
                continue
            if not call.args or not isinstance(call.args[0], ast.Name):
                continue
            key = id(loop)
            if key not in assigned:
                assigned[key] = self._assigned_names(loop)
            name = call.args[0].id
            if name in assigned[key]:
                continue
            yield ctx.finding(
                self, call,
                f"host→device upload of loop-invariant `{name}` inside an "
                f"iteration loop; hoist it out of the loop or carry it on "
                f"device across iterations")


# ---------------------------------------------------------------------------
# KERNEL-TRIPLE
# ---------------------------------------------------------------------------
class KERNEL_TRIPLE(TreeRule):
    name = "KERNEL-TRIPLE"
    summary = ("every kernels/<name>/ ships kernel.py + ops.py + ref.py "
               "and is referenced by a test")
    contract = (
        "The kernel contract since PR 1: `kernel.py` (Pallas), `ops.py` "
        "(dispatch + jit cache), `ref.py` (jnp twin the parity tests pin "
        "the kernel to). A kernel directory missing a leg — or not "
        "referenced by any test under tests/ — has no enforced parity "
        "and WILL drift from its backends (DESIGN.md §3/§9).")

    _REQUIRED = ("kernel.py", "ops.py", "ref.py")

    def check_tree(self, root, relpaths):
        kdir = os.path.join(root, "src", "repro", "kernels")
        if not os.path.isdir(kdir):
            return
        test_blob = ""
        tdir = os.path.join(root, "tests")
        if os.path.isdir(tdir):
            for fn in sorted(os.listdir(tdir)):
                if fn.endswith(".py"):
                    with open(os.path.join(tdir, fn),
                              encoding="utf-8") as fh:
                        test_blob += fh.read()
        for name in sorted(os.listdir(kdir)):
            sub = os.path.join(kdir, name)
            if not os.path.isdir(sub) or name == "__pycache__":
                continue
            relsub = f"src/repro/kernels/{name}"
            for req in self._REQUIRED:
                if not os.path.isfile(os.path.join(sub, req)):
                    yield Finding(
                        rule=self.name, path=relsub, line=1, col=0,
                        symbol="<package>", snippet=name,
                        message=(f"kernel package `{name}` is missing "
                                 f"`{req}` (kernel/ops/ref triple)"))
            if not re.search(rf"kernels[./]{re.escape(name)}", test_blob):
                yield Finding(
                    rule=self.name, path=relsub, line=1, col=0,
                    symbol="<package>", snippet=name,
                    message=(f"kernel package `{name}` is not referenced "
                             f"by any test under tests/ — no parity "
                             f"enforcement"))


# ---------------------------------------------------------------------------
# TIME-MONOTONIC
# ---------------------------------------------------------------------------
class TIME_MONOTONIC(Rule):
    name = "TIME-MONOTONIC"
    summary = ("duration measurement uses time.perf_counter(), never "
               "time.time()")
    contract = (
        "`time.time()` is wall-clock: NTP steps/slews move it mid-"
        "measurement, which corrupts the speedup ratios the BENCH_*.json "
        "gates compare against (a one-second step during a 3-second "
        "phase flips a 1.6x gate). All duration measurement in "
        "benchmarks/ and launch/ uses the monotonic "
        "`time.perf_counter()`; a genuine wall-clock timestamp (artifact "
        "metadata) takes an inline suppression (ISSUE 8 satellite).")
    scope = ("benchmarks/", "src/repro/launch/")

    def check(self, ctx):
        for call in _walk_calls(ctx.tree):
            if dotted(call.func) == "time.time":
                yield ctx.finding(
                    self, call,
                    "`time.time()` is not monotonic; use "
                    "`time.perf_counter()` for durations")


# ---------------------------------------------------------------------------
# ATOMIC-WRITE
# ---------------------------------------------------------------------------
class ATOMIC_WRITE(Rule):
    name = "ATOMIC-WRITE"
    summary = ("durable artifacts (checkpoints, caches, spill runs, "
               "manifests) are written temp-then-rename, never in place")
    contract = (
        "A kill mid-write leaves an in-place-written artifact truncated, "
        "and every consumer then trusts a half file: a torn checkpoint "
        "manifest resumes from garbage, a torn spill run merges partial "
        "edges into a LATER ingestion. The protocol (ISSUE 10; "
        "core/checkpoint.py, graphs/partitioned.py) is write to a "
        "sibling temp path, then commit with the atomic `os.replace`/"
        "`os.rename`. Flags: `open(path, 'w'/'wb')` or `np.save`/"
        "`np.savez*` whose path expression mentions a durable-artifact "
        "word (ckpt/checkpoint/artifact/cache/spill/sidecar/manifest) in "
        "a scope with no `os.replace`/`os.rename` commit.")
    scope = ("src/repro/", "benchmarks/")
    exclude = ("src/repro/analysis/",)

    _KEYWORD = re.compile(
        r"ckpt|checkpoint|artifact|cache|spill|sidecar|manifest", re.I)
    _NP_SAVERS = {"np.save", "numpy.save", "np.savez", "numpy.savez",
                  "np.savez_compressed", "numpy.savez_compressed"}

    def _arg_text(self, node) -> str:
        """All identifiers + string literals in an expression subtree —
        the haystack the durable-artifact keywords are matched against."""
        parts = []
        for leaf in ast.walk(node):
            if isinstance(leaf, ast.Name):
                parts.append(leaf.id)
            elif isinstance(leaf, ast.Attribute):
                parts.append(leaf.attr)
            elif isinstance(leaf, ast.Constant) and isinstance(leaf.value, str):
                parts.append(leaf.value)
        return " ".join(parts)

    def _write_target(self, call):
        """The path expression of a durable write call, or None."""
        fn = dotted(call.func)
        if fn == "open":
            mode = None
            if len(call.args) >= 2:
                mode = call.args[1]
            for kw in call.keywords:
                if kw.arg == "mode":
                    mode = kw.value
            if (isinstance(mode, ast.Constant) and isinstance(mode.value, str)
                    and "w" in mode.value and call.args):
                return call.args[0]
            return None
        if fn in self._NP_SAVERS and call.args:
            return call.args[0]
        return None

    def check(self, ctx):
        # group calls by enclosing function scope: the quiet condition is
        # "this scope also commits with os.replace/os.rename"
        scopes: dict = {}

        def visit(node, scope):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scope = node
            elif isinstance(node, ast.Call):
                scopes.setdefault(id(scope), [[], False])
                entry = scopes[id(scope)]
                fn = dotted(node.func)
                if fn in ("os.replace", "os.rename"):
                    entry[1] = True
                else:
                    target = self._write_target(node)
                    if (target is not None
                            and self._KEYWORD.search(self._arg_text(target))):
                        entry[0].append(node)
            for child in ast.iter_child_nodes(node):
                visit(child, scope)

        visit(ctx.tree, ctx.tree)
        for writes, has_commit in scopes.values():
            if has_commit:
                continue
            for call in writes:
                yield ctx.finding(
                    self, call,
                    "in-place write of a durable artifact; write to a "
                    "sibling temp path and commit with `os.replace` so a "
                    "kill mid-write never leaves a torn file")


RULES = (SEED_DISCIPLINE(), JIT_CACHE_BOUND(), INT_RANK_ONLY(),
         NONDET_ITER(), NO_RECURSION_LIMIT(), DTYPE_WIDTH(),
         HOST_SYNC_IN_LOOP(), ITER_REUPLOAD(), KERNEL_TRIPLE(),
         TIME_MONOTONIC(), ATOMIC_WRITE())


def rules_by_name():
    return {r.name: r for r in RULES}
