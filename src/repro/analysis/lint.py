"""CLI for the invariant linter.

    python -m repro.analysis.lint [paths...]       # default: src tests benchmarks
    python -m repro.analysis.lint --list-rules
    python -m repro.analysis.lint --stats src      # also writes artifacts/lint_report.json

Exit codes: 0 clean (every finding suppressed or baselined), 1 new
findings, 2 baseline problems (stale or unjustified entries, or
unparsable files). Stdlib-only and <10s cold — it runs as the first CI
gate, before any heavy import.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.baseline import (DEFAULT_BASELINE, apply_baseline,
                                     entry_key, load_baseline)
from repro.analysis.core import lint_paths
from repro.analysis.rules import RULES


def find_repo_root(start: str) -> str:
    """Nearest ancestor holding a .git dir (fallback: cwd)."""
    d = os.path.abspath(start)
    while True:
        if os.path.isdir(os.path.join(d, ".git")):
            return d
        parent = os.path.dirname(d)
        if parent == d:
            return os.path.abspath(start)
        d = parent


def build_stats(result, match) -> dict:
    per_rule: dict = {r.name: {"new": 0, "baselined": 0, "suppressed": 0}
                      for r in RULES}
    for f in match.new:
        per_rule.setdefault(f.rule, {"new": 0, "baselined": 0,
                                     "suppressed": 0})["new"] += 1
    for f, _ in match.matched:
        per_rule[f.rule]["baselined"] += 1
    for f, _ in result.suppressed:
        per_rule[f.rule]["suppressed"] += 1
    return {
        "files_scanned": result.files_scanned,
        "rules_active": len(RULES),
        "baseline_size": match.size,
        "stale_baseline_entries": len(match.stale),
        "unjustified_baseline_entries": len(match.unjustified),
        "new_findings": len(match.new),
        "suppressed_findings": len(result.suppressed),
        "parse_errors": len(result.errors),
        "per_rule": per_rule,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Invariant linter: determinism, seeding and "
                    "device-residency contracts as named, static rules.")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs relative to the repo root "
                         "(default: src tests benchmarks)")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline JSON (default: the checked-in one)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: nearest .git ancestor)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--stats", action="store_true",
                    help="emit a JSON findings summary")
    ap.add_argument("--stats-out", default="artifacts/lint_report.json",
                    help="where --stats writes its JSON "
                         "(repo-root-relative)")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in RULES:
            print(f"{rule.name}: {rule.summary}")
            scope = ", ".join(rule.scope) or "everywhere"
            print(f"    scope: {scope}")
        return 0

    root = args.root or find_repo_root(os.getcwd())
    paths = args.paths or ["src", "tests", "benchmarks"]
    result = lint_paths(root, paths, RULES)
    entries = load_baseline(args.baseline)
    # only entries whose path was actually scanned can be declared stale
    scanned_prefixes = tuple(p.rstrip("/") for p in paths)

    def _in_scan(entry):
        p = entry.get("path", "")
        return any(p == s or p.startswith(s + "/") for s in scanned_prefixes)

    match = apply_baseline(result.findings,
                           [e for e in entries if _in_scan(e)])
    match.size = len(entries)

    for err in result.errors:
        print(f"error: cannot parse {err}", file=sys.stderr)
    for f in sorted(match.new, key=lambda f: (f.path, f.line, f.rule)):
        print(f.render())
    for e in match.stale:
        print(f"stale baseline entry (code changed; delete it): "
              f"{entry_key(e)}", file=sys.stderr)
    for e in match.unjustified:
        print(f"baseline entry without justification (mandatory): "
              f"{entry_key(e)}", file=sys.stderr)

    if args.stats:
        stats = build_stats(result, match)
        out = os.path.join(root, args.stats_out)
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w", encoding="utf-8") as fh:
            json.dump(stats, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(json.dumps(stats, indent=2, sort_keys=True))

    if match.new:
        print(f"\n{len(match.new)} new finding(s) across "
              f"{result.files_scanned} files "
              f"({len(result.suppressed)} suppressed, "
              f"{len(match.matched)} baselined).", file=sys.stderr)
        return 1
    if match.stale or match.unjustified or result.errors:
        return 2
    print(f"clean: {result.files_scanned} files, {len(RULES)} rules, "
          f"{len(result.suppressed)} suppressed, "
          f"{len(match.matched)} baselined.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
