"""`repro.analysis` — the repo's own static-analysis layer (DESIGN.md §10).

Every guarantee the engines ship — bit-identical summaries across the
numpy/batched/resident/mesh backends, plan-replay determinism, the resident
path's transfer accounting — rests on a handful of coding contracts that
used to live only in reviewers' heads and slow end-to-end bit-identity
tests. This package makes them cheap, static and NAMED:

* `repro.analysis.core`     — the rule engine: `Finding`, `Rule`,
  inline ``# lint: disable=RULE -- reason`` suppressions, module walking.
* `repro.analysis.rules`    — the rule catalog (SEED-DISCIPLINE,
  JIT-CACHE-BOUND, INT-RANK-ONLY, …), each documenting the contract it
  encodes and the PR/bug that motivated it.
* `repro.analysis.baseline` — the checked-in grandfather list
  (``baseline.json``): intentional exemptions, each with a written
  justification; stale entries are themselves an error.
* `repro.analysis.lint`     — the CLI:
  ``python -m repro.analysis.lint src tests benchmarks`` exits nonzero on
  any NEW violation (<10s cold, stdlib-only — it is the first CI gate).

No dependencies beyond the stdlib: the linter must run before (and
regardless of) jax/numpy being importable.
"""
from repro.analysis.core import Finding, Rule, TreeRule, lint_paths, lint_source
from repro.analysis.rules import RULES, rules_by_name

__all__ = ["Finding", "Rule", "TreeRule", "RULES", "rules_by_name",
           "lint_paths", "lint_source"]
