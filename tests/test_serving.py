"""Serving-path correctness: prefill + step-by-step decode must reproduce the
full teacher-forced forward pass (per family, in float32 for tight bounds)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.models.api import get_api

FAMILIES = {
    "dense-gqa": "deepseek-7b",
    "dense-swa": "h2o-danube-1.8b",
    "gqa-bias": "qwen2.5-3b",
    "mla-moe": "deepseek-v2-lite-16b",
    "ssm": "mamba2-130m",
    "hybrid": "zamba2-7b",
}


def f32(cfg):
    cfg = dataclasses.replace(cfg, dtype="float32", remat="none")
    if cfg.moe is not None:
        # capacity dropping is a function of the *total* token count, so it is
        # not causal; give full capacity so prefill/decode match teacher forcing
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=float(cfg.moe.n_experts)))
    return cfg


def _decode_vs_full(cfg, prompt_len=6, total_len=12, atol=2e-2):
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, total_len)), jnp.int32)
    from repro.models import transformer as T

    full_logits, _, _ = T.forward(params, cfg, toks)
    logits_pre, cache = T.prefill(params, cfg, toks[:, :prompt_len], cache_len=total_len)
    # prefill returns LAST-position logits only (b, 1, V)
    np.testing.assert_allclose(
        np.asarray(logits_pre[:, -1], np.float32),
        np.asarray(full_logits[:, prompt_len - 1], np.float32), atol=atol, rtol=0
    )
    for pos in range(prompt_len, total_len):
        step_logits, cache = T.decode_step(params, cfg, cache, toks[:, pos : pos + 1], jnp.int32(pos))
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0], np.float32),
            np.asarray(full_logits[:, pos], np.float32),
            atol=atol, rtol=0,
            err_msg=f"pos={pos}",
        )


@pytest.mark.parametrize("fam", ["dense-gqa", "gqa-bias", "mla-moe"])
def test_decode_matches_full_attention(fam):
    cfg = f32(get_config(FAMILIES[fam], smoke=True))
    _decode_vs_full(cfg)


def test_decode_matches_full_ssm():
    cfg = f32(get_config(FAMILIES["ssm"], smoke=True))
    _decode_vs_full(cfg, atol=5e-2)


def test_decode_matches_full_hybrid():
    cfg = f32(get_config(FAMILIES["hybrid"], smoke=True))
    _decode_vs_full(cfg, atol=5e-2)


def test_decode_matches_full_swa():
    # window smaller than sequence: ring cache must still match full forward
    cfg = f32(get_config(FAMILIES["dense-swa"], smoke=True))
    assert cfg.sliding_window == 8
    _decode_vs_full(cfg, prompt_len=4, total_len=14)


def test_decode_matches_full_encdec():
    cfg = f32(get_config("whisper-small", smoke=True))
    from repro.models import encdec as E

    params = E.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(1)
    frames = jnp.asarray(rng.normal(size=(2, 10, cfg.d_model)), jnp.float32)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(2, 12)), jnp.int32)
    full_logits, _, _ = E.forward(params, cfg, frames, toks)
    logits_pre, cache = E.prefill(params, cfg, frames, toks[:, :6], cache_len=12)
    np.testing.assert_allclose(np.asarray(logits_pre[:, -1], np.float32),
                               np.asarray(full_logits[:, 5], np.float32), atol=2e-2, rtol=0)
    for pos in range(6, 12):
        step_logits, cache = E.decode_step(params, cfg, cache, toks[:, pos : pos + 1], jnp.int32(pos))
        np.testing.assert_allclose(np.asarray(step_logits[:, 0], np.float32),
                                   np.asarray(full_logits[:, pos], np.float32), atol=2e-2, rtol=0)


def test_mla_absorbed_decode_matches_naive():
    """§Perf optimization: absorbed-MLA decode is numerically equivalent."""
    cfg = f32(get_config("deepseek-v2-lite-16b", smoke=True))
    from repro.models import attention as A

    p = A.init_mla(jax.random.key(2), cfg, jnp.float32)
    rng = np.random.default_rng(2)
    b, S = 2, 8
    cache = {
        "ckv": jnp.asarray(rng.normal(size=(b, S, cfg.mla.kv_lora_rank)), jnp.float32),
        "krope": jnp.asarray(rng.normal(size=(b, S, cfg.mla.qk_rope_head_dim)), jnp.float32),
    }
    x = jnp.asarray(rng.normal(size=(b, 1, cfg.d_model)), jnp.float32)
    pos = jnp.int32(5)
    out1, c1 = A.mla_decode(p, cfg, x, cache, pos)
    out2, c2 = A.mla_decode_absorbed(p, cfg, x, cache, pos)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(c1["ckv"]), np.asarray(c2["ckv"]), atol=1e-5, rtol=0)


def test_ssd_chunk_invariance():
    """Chunked SSD must be invariant to the chunk size (algebraic identity)."""
    from repro.models.ssm import ssd_chunked

    rng = np.random.default_rng(3)
    b, s, nh, hp, g, ds = 2, 16, 4, 8, 1, 8
    xh = jnp.asarray(rng.normal(size=(b, s, nh, hp)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, size=(b, s, nh)), jnp.float32)
    A_ = -jnp.asarray(rng.uniform(0.1, 1.0, size=(nh,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, s, g, ds)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, s, g, ds)), jnp.float32)
    y4, h4 = ssd_chunked(xh, dt, A_, B, C, chunk=4)
    y16, h16 = ssd_chunked(xh, dt, A_, B, C, chunk=16)
    y5, h5 = ssd_chunked(xh, dt, A_, B, C, chunk=5)  # non-divisible
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y16), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y5), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(h4), np.asarray(h16), atol=1e-4, rtol=1e-4)
