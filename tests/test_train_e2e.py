"""End-to-end train-loop tests: loss goes down; crash→resume is bit-exact."""
import os
import shutil

import numpy as np
import pytest

from repro.launch.train import main as train_main


def test_train_loss_decreases(tmp_path):
    losses = train_main([
        "--arch", "mamba2-130m", "--smoke", "--steps", "30",
        "--batch", "4", "--seq", "32", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "1000", "--lr", "1e-3",
    ])
    assert losses[-1] < losses[0]


def test_train_resume_bit_exact(tmp_path):
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    # uninterrupted run of 20 steps
    full = train_main([
        "--arch", "qwen2.5-3b", "--smoke", "--steps", "20",
        "--batch", "4", "--seq", "32", "--ckpt-dir", d1,
        "--ckpt-every", "10", "--seed", "3",
    ])
    # interrupted: 10 steps (checkpoint), then resume for the remaining 10
    train_main([
        "--arch", "qwen2.5-3b", "--smoke", "--steps", "10",
        "--batch", "4", "--seq", "32", "--ckpt-dir", d2,
        "--ckpt-every", "10", "--seed", "3",
    ])
    resumed = train_main([
        "--arch", "qwen2.5-3b", "--smoke", "--steps", "20",
        "--batch", "4", "--seq", "32", "--ckpt-dir", d2,
        "--ckpt-every", "10", "--seed", "3", "--resume",
    ])
    # the resumed tail must match the uninterrupted run step-for-step
    np.testing.assert_allclose(np.array(resumed), np.array(full[10:]), rtol=1e-5)
