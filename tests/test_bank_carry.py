"""Device-resident adjacency bank carry (ISSUE 9 / DESIGN.md §9).

The bank inverts the workspace dataflow: within a run the device streams
are authoritative and the host only keeps the row directory. These tests
pin the contract at its edges — zero-merge iterations leave the bank
untouched, pow2 regrow preserves every row, groups dying mid-run stay
bit-identical to the numpy backend, `host_rows` (the verification
contract) matches `SluggerState.gather_rows`, and — the core guarantee —
bank-EXTRACTED arena state is bit-identical to the host-rebuilt
`from_workspace` path, with `REPRO_FORCE_PALLAS=1` forcing the kernel
dispatch on the graphs the equivalence suite uses (ba/er/caveman).
"""
import numpy as np
import pytest

from repro.core.slugger import SluggerState
from repro.graphs import generators as GG

jax = pytest.importorskip("jax")


def _ctx_with_bank(g, counter=None):
    from repro.core.resident import ResidentRunContext
    from repro.core.transfer import GLOBAL

    ctx = ResidentRunContext(g, counter=counter or GLOBAL, bank=True)
    assert ctx.bank is not None
    return ctx


def _merge_and_advance(st, ctx, A, Z):
    """Mirror one applied batch on host state and bank, engine-style."""
    A = np.asarray(A, dtype=np.int64)
    Z = np.asarray(Z, dtype=np.int64)
    M = st.merge_batch(A, Z)
    ctx.advance([(A, Z, M, st.row_len[M].copy())])
    return M


def _assert_rows_match(st, ctx, roots):
    """bank.host_rows == state.gather_rows for ``roots`` (both coalesce by
    current resolution; gather_rows compacts the host arena in place)."""
    got = ctx.bank.host_rows(roots, ctx._res_map)
    seg, nbr, cnt = st.gather_rows(np.asarray(roots, dtype=np.int64))
    for i in range(len(roots)):
        sel = seg == i
        want_nbr, want_cnt = nbr[sel], cnt[sel]
        order = np.argsort(want_nbr, kind="stable")
        assert np.array_equal(got[i][0], want_nbr[order]), roots[i]
        assert np.array_equal(got[i][1], want_cnt[order]), roots[i]


# -- degenerate iterations ----------------------------------------------------
def test_bank_zero_merge_iteration_untouched():
    from repro.core.transfer import TransferCounter

    g = GG.caveman(4, 5, 0.0, seed=1)
    counter = TransferCounter()
    ctx = _ctx_with_bank(g, counter)
    bank = ctx.bank
    top0, cap0 = bank.top, bank.capacity
    ptr0, len0 = bank.ptr_host.copy(), bank.len_host.copy()
    rm0 = ctx.root_of_host()
    bank0 = counter.snapshot()["phases"].get("bank", 0)
    e = np.zeros(0, np.int64)
    ctx.advance([])
    ctx.advance([(e, e, e, e)])
    assert bank.top == top0 and bank.capacity == cap0
    assert np.array_equal(bank.ptr_host, ptr0)
    assert np.array_equal(bank.len_host, len0)
    assert counter.snapshot()["phases"].get("bank", 0) == bank0
    assert np.array_equal(ctx.root_of_host(), rm0)


def test_bank_mode_rejects_legacy_triples():
    g = GG.caveman(2, 4, 0.0, seed=0)
    ctx = _ctx_with_bank(g)
    with pytest.raises(ValueError, match="on_batch"):
        ctx.advance([(np.array([0]), np.array([1]), np.array([g.n]))])


# -- row contract across merges, chains, and regrow ---------------------------
def test_bank_rows_match_gather_rows_after_merges():
    g = GG.caveman(3, 6, 0.08, seed=3)
    st = SluggerState(g)
    ctx = _ctx_with_bank(g)
    _merge_and_advance(st, ctx, [0, 6, 12], [1, 7, 13])
    _merge_and_advance(st, ctx, [2, 8], [3, 9])
    roots = np.unique(st.root_of)
    assert roots.size < g.n  # the fixture actually merged something
    _assert_rows_match(st, ctx, list(roots[:8]))
    # consumed roots own no bank row anymore
    assert (ctx.bank.len_host[[0, 1, 6, 7]] == 0).all()


def test_bank_pow2_regrow_preserves_rows():
    """A chain of merges re-appends whole rows every step — enough to
    outgrow the initial 2·m capacity and force (at least one) pow2 regrow;
    every row must survive the device-to-device copy."""
    g = GG.caveman(1, 16, 0.0, seed=0)  # one 16-clique
    st = SluggerState(g)
    ctx = _ctx_with_bank(g)
    cap0 = ctx.bank.capacity
    cur = 0
    for nxt in range(1, 16):
        M = _merge_and_advance(st, ctx, [cur], [nxt])
        cur = int(M[0])
    assert ctx.bank.capacity > cap0          # the regrow actually happened
    assert ctx.bank.top > cap0
    _assert_rows_match(st, ctx, [cur])
    assert np.array_equal(ctx.root_of_host(), st.root_of)
    # the final root absorbed the whole clique: its row is empty
    assert ctx.bank.host_rows([cur], ctx._res_map)[0][0].size == 0


def test_bank_stats_track_state_exactly():
    g = GG.barabasi_albert(60, 3, seed=5)
    st = SluggerState(g)
    ctx = _ctx_with_bank(g)
    _merge_and_advance(st, ctx, [0, 2, 4], [1, 3, 5])
    _merge_and_advance(st, ctx, [g.n], [g.n + 1])  # minted parents re-merge
    bank = ctx.bank
    size = np.asarray(bank._size)
    selfc = np.asarray(bank._selfc)
    nd = np.asarray(bank._nd)
    hgt = np.asarray(bank._hgt)
    ids = np.arange(st.n_ids)
    assert np.array_equal(size[ids], st.size[ids])
    assert np.array_equal(selfc[ids], st.selfcnt[ids])
    assert np.array_equal(nd[ids], st.ndesc[ids])
    assert np.array_equal(hgt[ids], st.height[ids])


# -- groups dying mid-run -----------------------------------------------------
def test_bank_engine_matches_numpy_when_groups_die():
    """A long resident run in which whole caves collapse to single roots
    (their groups die mid-run) stays decision- and summary-identical to
    the numpy backend, and dead roots leave the bank directory."""
    from repro.core import summarize
    from repro.core.engine import SummarizerEngine

    g = GG.caveman(3, 5, 0.02, seed=13)
    want = summarize(g, T=8, seed=6, backend="numpy")
    e = SummarizerEngine(backend="resident", T=8, seed=6)
    state, _ = e.merge_forest(g)
    got = summarize(g, T=8, seed=6, backend="resident")
    assert np.array_equal(want.parent, got.parent)
    assert np.array_equal(want.edges, got.edges)
    assert e._run_ctx is not None and e._run_ctx.bank is not None
    fwd = state.forward[: state.n_ids]
    dead = np.flatnonzero(fwd != np.arange(state.n_ids))
    assert dead.size  # caves collapsed: some roots really died
    assert (e._run_ctx.bank.len_host[dead] == 0).all()


# -- extraction bit-identity vs the host-rebuilt path -------------------------
def _extraction_case(g, batches, groups, G):
    """After ``batches`` of merges, a bank-extracted arena must equal the
    host-rebuilt `from_workspace` arena of the SAME chunk, bit for bit."""
    from repro.core.merging import BatchedGroupWorkspace
    from repro.core.resident import ResidentBitmapArena

    st = SluggerState(g)
    ctx = _ctx_with_bank(g)
    for A, Z in batches:
        _merge_and_advance(st, ctx, A, Z)
    full = BatchedGroupWorkspace.build_bucket(st, groups, G)
    shell = BatchedGroupWorkspace.build_bucket(st, groups, G, shell=True)
    assert len(full) == len(shell)  # chunking is host-planned on both paths
    for ws_f, ws_s in zip(full, shell):
        assert ws_s.CNT.shape[2] == 0 and ws_s.bits.shape[2] == 1
        assert np.array_equal(ws_f.members, ws_s.members)
        a_host = ResidentBitmapArena.from_workspace(ws_f, top_j=4)
        a_bank = ResidentBitmapArena.from_bank(ctx.bank, ws_s, ctx._res_map,
                                               top_j=4)
        assert a_bank.Bp == a_host.Bp and a_bank.Wp == a_host.Wp
        assert a_bank.Rp == a_host.Rp
        assert np.array_equal(a_bank.host_bits(), a_host.host_bits())
        assert np.array_equal(a_bank.host_alive(), a_host.host_alive())
        for got, want in zip(a_bank.host_counts(), a_host.host_counts()):
            assert got.dtype == want.dtype
            assert np.array_equal(got, want)
        assert np.array_equal(np.asarray(a_bank._dirty),
                              np.asarray(a_host._dirty))


def _alive_groups(st, k):
    roots = np.unique(st.root_of)
    return [roots[i:i + k] for i in range(0, roots.size, k)
            if roots[i:i + k].size >= 2]


@pytest.mark.parametrize("force", ["0", "1"])
def test_bank_extraction_bit_identical(force, monkeypatch):
    monkeypatch.setenv("REPRO_FORCE_PALLAS", force)
    g = GG.caveman(4, 6, 0.05, seed=2)
    st = SluggerState(g)
    batches = [([0, 6, 12], [1, 7, 13]), ([2, 18], [3, 19])]
    for A, Z in batches:
        st.merge_batch(np.asarray(A, np.int64), np.asarray(Z, np.int64))
    groups = _alive_groups(st, 4)
    _extraction_case(g, batches, groups, 4)


@pytest.mark.parametrize("gen", ["ba", "er", "caveman"])
def test_bank_extraction_forced_kernel_all_graphs(gen, monkeypatch):
    """`REPRO_FORCE_PALLAS=1` on the three equivalence-suite graph
    families: extraction AND a full forced-kernel sweep from the extracted
    state agree with the host-rebuilt path."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    g = {"ba": lambda: GG.barabasi_albert(80, 3, seed=7),
         "er": lambda: GG.erdos_renyi(90, 0.06, seed=8),
         "caveman": lambda: GG.caveman(5, 6, 0.1, seed=9)}[gen]()
    st = SluggerState(g)
    pairs = np.unique(st.root_of)[:8]
    batches = [(pairs[0::2], pairs[1::2])]
    for A, Z in batches:
        st.merge_batch(np.asarray(A, np.int64), np.asarray(Z, np.int64))
    groups = _alive_groups(st, 6)
    _extraction_case(g, batches, groups, 8)


def test_bank_sweep_plans_match_host_rebuilt(monkeypatch):
    """Record-mode sweeps from a bank-extracted arena and a host-rebuilt
    arena record IDENTICAL merge rounds (the decision-level face of the
    extraction bit-identity), under the forced kernel dispatch."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    from repro.core.merging import (BatchedGroupWorkspace, MergePlan,
                                    ResidentRankSource)
    from repro.core.resident import ResidentBitmapArena

    g = GG.caveman(2, 8, 0.0, seed=4)
    groups = [np.arange(8), np.arange(8) + 8]
    seeds = np.arange(2, dtype=np.uint64) + 11

    def sweep(shell):
        st = SluggerState(g)
        ctx = _ctx_with_bank(g)
        plans = [MergePlan(gr) for gr in groups]
        wss = BatchedGroupWorkspace.build_bucket(
            st, groups, 8, plans=plans, group_seeds=seeds, shell=shell)
        for ws in wss:
            if shell:
                arena = ResidentBitmapArena.from_bank(
                    ctx.bank, ws, ctx._res_map, top_j=4)
            else:
                arena = ResidentBitmapArena.from_workspace(ws, top_j=4)
            ws.sweep(0.0, ResidentRankSource(arena))
        return plans

    want, got = sweep(False), sweep(True)
    for pw, pg in zip(want, got):
        assert len(pw.rounds) == len(pg.rounds)
        for (aw, zw), (ag, zg) in zip(pw.rounds, pg.rounds):
            assert np.array_equal(aw, ag) and np.array_equal(zw, zg)
