"""Roofline unit tests: HLO collective parsing + term arithmetic + a real
small-mesh lower/compile in a subprocess (8 host devices)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.launch import roofline as RL


def test_shape_bytes():
    assert RL._shape_bytes("bf16[128,1024]{1,0}") == 128 * 1024 * 2
    assert RL._shape_bytes("f32[16]") == 64
    assert RL._shape_bytes("pred[8]") == 8
    assert RL._shape_bytes("(f32[4,4], s32[2])") == 64 + 8


def test_collective_bytes_parses_categories():
    hlo = textwrap.dedent("""
      %ag = bf16[256,1024]{1,0} all-gather(%x), replica_groups={...}
      %ar = f32[512]{0} all-reduce(%y), to_apply=%add
      %rs = bf16[64,64]{1,0} reduce-scatter(%z), dimensions={0}
      %a2a = f32[32,32]{1,0} all-to-all(%w)
      %cp = bf16[128]{0} collective-permute(%v)
      %other = f32[4096]{0} add(%a, %b)
    """)
    got = RL.collective_bytes(hlo)
    assert got["all-gather"] == 256 * 1024 * 2
    assert got["all-reduce"] == 512 * 4
    assert got["reduce-scatter"] == 64 * 64 * 2
    assert got["all-to-all"] == 32 * 32 * 4
    assert got["collective-permute"] == 128 * 2
    assert got["count"]["all-gather"] == 1


def test_roofline_terms_and_bottleneck():
    r = RL.Roofline(
        arch="x", shape="train_4k", mesh="single", chips=256,
        hlo_flops=256 * RL.PEAK_FLOPS,          # 1s compute
        hlo_bytes=256 * RL.HBM_BW * 0.5,        # 0.5s memory
        coll_bytes=256 * RL.ICI_BW * RL.ICI_LINKS * 0.1,  # 0.1s collective
        coll_breakdown={}, model_flops=256 * RL.PEAK_FLOPS * 0.5,
        per_device_hbm=1 << 30,
    )
    assert abs(r.t_compute - 1.0) < 1e-9
    assert abs(r.t_memory - 0.5) < 1e-9
    assert abs(r.t_collective - 0.1) < 1e-9
    assert r.bottleneck == "compute"
    assert abs(r.useful_ratio - 0.5) < 1e-9
    assert abs(r.roofline_fraction - 0.5) < 1e-9


def test_model_flops_train_vs_decode():
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    cfg = get_config("deepseek-7b")
    n = cfg.param_count()
    tr = RL.model_flops_for(cfg, SHAPES["train_4k"])
    de = RL.model_flops_for(cfg, SHAPES["decode_32k"])
    assert tr == 6.0 * n * 256 * 4096
    assert de == 2.0 * n * 128


MINI_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, jax
    from repro.configs.base import ShapeConfig
    from repro.configs.registry import get_config
    from repro.launch import roofline as RL
    from repro.launch.mesh import dp_axes_of
    from repro.models.api import input_specs
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import TrainPlan, build_train_step

    cfg = get_config("qwen2.5-3b", smoke=True)
    try:
        mesh = jax.make_mesh((4, 2), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
    except AttributeError:  # older jax has no AxisType (Auto is the default)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
    shape = ShapeConfig("mini", 32, 8, "train")
    plan = TrainPlan(cfg=cfg, mesh=mesh, dp_axes=("data",), opt=AdamWConfig())
    step, state_sh, batch_sh, state_abs = build_train_step(plan, shape)
    lowered = step.lower(state_abs, input_specs(cfg, shape))
    compiled = lowered.compile()
    rl = RL.from_compiled("qwen2.5-3b", "mini", "test", 8, compiled, compiled.as_text(), cfg, shape)
    assert rl.hlo_flops > 0 and rl.hlo_bytes > 0
    assert rl.coll_bytes > 0, "TP matmuls must produce collectives"
    assert rl.bottleneck in ("compute", "memory", "collective")
    print("MINI_DRYRUN_OK", rl.bottleneck)
""")


def test_mini_dryrun_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", MINI_DRYRUN], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "MINI_DRYRUN_OK" in r.stdout, r.stderr[-2500:]


def test_ideal_decode_bytes_counts_params_and_cache():
    from repro.configs.base import ShapeConfig
    from repro.configs.registry import get_config
    from repro.launch.roofline import ideal_decode_bytes

    cfg = get_config("qwen2.5-3b", smoke=True)
    sh = ShapeConfig("d", 64, 4, "decode")
    got = ideal_decode_bytes(cfg, sh)
    n = cfg.param_count()
    assert got > 2.0 * n  # params once (bf16) + a nonempty cache
    # cache scales with S; params do not
    got2 = ideal_decode_bytes(cfg, ShapeConfig("d", 128, 4, "decode"))
    assert got2 > got
