"""Per-kernel allclose tests: shape/dtype sweeps against the pure-jnp oracle
(interpret=True executes the Pallas kernel body on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.graphs import generators as GG
from repro.kernels.bitset_jaccard import ops as jops
from repro.kernels.bitset_jaccard import ref as jref
from repro.kernels.bitset_jaccard.kernel import pairwise_intersection_kernel
from repro.kernels.interval_expand import ref as iref
from repro.kernels.interval_expand.kernel import interval_count_kernel
from repro.kernels.minhash import ops as mops
from repro.kernels.minhash import ref as mref
from repro.kernels.minhash.kernel import rowmin_hash_kernel


@pytest.mark.parametrize("R,W", [(8, 8), (64, 16), (100, 128), (256, 32), (300, 130)])
@pytest.mark.parametrize("ab", [(2654435761, 12345), (0x9E3779B1, 0)])
def test_minhash_kernel_matches_ref(R, W, ab):
    rng = np.random.default_rng(R * W)
    nbr = rng.integers(0, 1 << 20, size=(R, W)).astype(np.uint32)
    # sprinkle sentinel padding
    mask = rng.random((R, W)) < 0.3
    nbr[mask] = np.uint32(0xFFFFFFFF)
    got = rowmin_hash_kernel(jnp.asarray(nbr), *ab, interpret=True)
    want = mref.rowmin_hash(jnp.asarray(nbr), *ab)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_r,block_w", [(16, 16), (256, 128), (7, 5)])
def test_minhash_kernel_block_shapes(block_r, block_w):
    rng = np.random.default_rng(0)
    nbr = rng.integers(0, 1 << 16, size=(64, 48)).astype(np.uint32)
    got = rowmin_hash_kernel(jnp.asarray(nbr), 2654435761, 7, block_r=block_r,
                             block_w=block_w, interpret=True)
    want = mref.rowmin_hash(jnp.asarray(nbr), 2654435761, 7)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_minhash_empty_rows_yield_max():
    nbr = np.full((4, 8), np.uint32(0xFFFFFFFF), dtype=np.uint32)
    got = rowmin_hash_kernel(jnp.asarray(nbr), 2654435761, 7, interpret=True)
    assert (np.asarray(got) == np.uint32(0xFFFFFFFF)).all()


@pytest.mark.parametrize("G,W", [(4, 1), (32, 8), (128, 16), (60, 33)])
def test_jaccard_kernel_matches_ref(G, W):
    rng = np.random.default_rng(G + W)
    bits = rng.integers(0, 1 << 32, size=(G, W), dtype=np.uint64).astype(np.uint32)
    got = pairwise_intersection_kernel(jnp.asarray(bits), interpret=True)
    want = jref.pairwise_intersection(jnp.asarray(bits))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_g,block_w", [(8, 8), (128, 128), (5, 3)])
def test_jaccard_kernel_block_shapes(block_g, block_w):
    rng = np.random.default_rng(1)
    bits = rng.integers(0, 1 << 32, size=(24, 10), dtype=np.uint64).astype(np.uint32)
    got = pairwise_intersection_kernel(jnp.asarray(bits), block_g=block_g,
                                       block_w=block_w, interpret=True)
    want = jref.pairwise_intersection(jnp.asarray(bits))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,E,P", [(1, 1, 1), (4, 33, 17), (8, 200, 513)])
def test_interval_count_kernel_matches_ref(B, E, P):
    rng = np.random.default_rng(B * E + P)
    lo = rng.integers(0, 60, size=(B, E)).astype(np.int32)
    hi = lo + rng.integers(0, 25, size=(B, E)).astype(np.int32)
    sg = rng.choice([-1, 0, 1], size=(B, E)).astype(np.int32)
    pos = rng.integers(-1, 90, size=(B, P)).astype(np.int32)
    got = interval_count_kernel(jnp.asarray(lo), jnp.asarray(hi),
                                jnp.asarray(sg), jnp.asarray(pos),
                                interpret=True)
    want = iref.interval_counts(lo, hi, sg, pos)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_p,block_e", [(8, 8), (512, 1024), (7, 5)])
def test_interval_count_kernel_block_shapes(block_p, block_e):
    rng = np.random.default_rng(9)
    lo = rng.integers(0, 40, size=(3, 29)).astype(np.int32)
    hi = lo + rng.integers(0, 12, size=(3, 29)).astype(np.int32)
    sg = rng.choice([-1, 1], size=(3, 29)).astype(np.int32)
    pos = rng.integers(0, 60, size=(3, 23)).astype(np.int32)
    got = interval_count_kernel(jnp.asarray(lo), jnp.asarray(hi),
                                jnp.asarray(sg), jnp.asarray(pos),
                                block_p=block_p, block_e=block_e,
                                interpret=True)
    want = iref.interval_counts(lo, hi, sg, pos)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_jaccard_against_python_sets():
    g = GG.barabasi_albert(150, 4, seed=2)
    sets = [set(map(int, g.neighbors(u))) for u in range(40)]
    bits = jops.pack_bitsets(sets, g.n)
    jac = np.asarray(jops.group_jaccard(bits, use_kernel=True))
    for i in range(0, 40, 7):
        for j in range(0, 40, 5):
            inter = len(sets[i] & sets[j])
            uni = len(sets[i] | sets[j])
            expect = inter / uni if uni else 0.0
            assert abs(jac[i, j] - expect) < 1e-6


def test_pack_adjacency_roundtrip_and_shingles():
    g = GG.star_of_cliques(30, 8, seed=3)
    rows, owners = mops.pack_adjacency(g.indptr, g.indices, width=8)
    got = np.asarray(mops.node_shingles(jnp.asarray(rows), owners, g.n,
                                        a=2654435761, b=99, use_kernel=True))
    # oracle: direct per-node min over N(u) ∪ {u}
    import jax
    h = np.asarray(mref.hash_u32(jnp.arange(g.n, dtype=jnp.uint32), 2654435761, 99))
    for u in range(g.n):
        grp = np.concatenate([[u], g.neighbors(u)]).astype(np.int64)
        assert got[u] == h[grp].min(), u
