"""Per-kernel allclose tests: shape/dtype sweeps against the pure-jnp oracle
(interpret=True executes the Pallas kernel body on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.graphs import generators as GG
from repro.kernels.bitset_fold import ref as fref
from repro.kernels.bitset_fold.kernel import (bitset_fold_kernel,
                                              jaccard_topj_kernel)
from repro.kernels.bitset_jaccard import ops as jops
from repro.kernels.bitset_jaccard import ref as jref
from repro.kernels.bitset_jaccard.kernel import (
    batch_masked_intersection_kernel, pairwise_intersection_kernel)
from repro.kernels.common import LruCache
from repro.kernels.interval_expand import ref as iref
from repro.kernels.interval_expand.kernel import interval_count_kernel
from repro.kernels.minhash import ops as mops
from repro.kernels.minhash import ref as mref
from repro.kernels.minhash.kernel import rowmin_hash_kernel


@pytest.mark.parametrize("R,W", [(8, 8), (64, 16), (100, 128), (256, 32), (300, 130)])
@pytest.mark.parametrize("ab", [(2654435761, 12345), (0x9E3779B1, 0)])
def test_minhash_kernel_matches_ref(R, W, ab):
    rng = np.random.default_rng(R * W)
    nbr = rng.integers(0, 1 << 20, size=(R, W)).astype(np.uint32)
    # sprinkle sentinel padding
    mask = rng.random((R, W)) < 0.3
    nbr[mask] = np.uint32(0xFFFFFFFF)
    got = rowmin_hash_kernel(jnp.asarray(nbr), *ab, interpret=True)
    want = mref.rowmin_hash(jnp.asarray(nbr), *ab)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_r,block_w", [(16, 16), (256, 128), (7, 5)])
def test_minhash_kernel_block_shapes(block_r, block_w):
    rng = np.random.default_rng(0)
    nbr = rng.integers(0, 1 << 16, size=(64, 48)).astype(np.uint32)
    got = rowmin_hash_kernel(jnp.asarray(nbr), 2654435761, 7, block_r=block_r,
                             block_w=block_w, interpret=True)
    want = mref.rowmin_hash(jnp.asarray(nbr), 2654435761, 7)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_minhash_empty_rows_yield_max():
    nbr = np.full((4, 8), np.uint32(0xFFFFFFFF), dtype=np.uint32)
    got = rowmin_hash_kernel(jnp.asarray(nbr), 2654435761, 7, interpret=True)
    assert (np.asarray(got) == np.uint32(0xFFFFFFFF)).all()


@pytest.mark.parametrize("G,W", [(4, 1), (32, 8), (128, 16), (60, 33)])
def test_jaccard_kernel_matches_ref(G, W):
    rng = np.random.default_rng(G + W)
    bits = rng.integers(0, 1 << 32, size=(G, W), dtype=np.uint64).astype(np.uint32)
    got = pairwise_intersection_kernel(jnp.asarray(bits), interpret=True)
    want = jref.pairwise_intersection(jnp.asarray(bits))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_g,block_w", [(8, 8), (128, 128), (5, 3)])
def test_jaccard_kernel_block_shapes(block_g, block_w):
    rng = np.random.default_rng(1)
    bits = rng.integers(0, 1 << 32, size=(24, 10), dtype=np.uint64).astype(np.uint32)
    got = pairwise_intersection_kernel(jnp.asarray(bits), block_g=block_g,
                                       block_w=block_w, interpret=True)
    want = jref.pairwise_intersection(jnp.asarray(bits))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,E,P", [(1, 1, 1), (4, 33, 17), (8, 200, 513)])
def test_interval_count_kernel_matches_ref(B, E, P):
    rng = np.random.default_rng(B * E + P)
    lo = rng.integers(0, 60, size=(B, E)).astype(np.int32)
    hi = lo + rng.integers(0, 25, size=(B, E)).astype(np.int32)
    sg = rng.choice([-1, 0, 1], size=(B, E)).astype(np.int32)
    pos = rng.integers(-1, 90, size=(B, P)).astype(np.int32)
    got = interval_count_kernel(jnp.asarray(lo), jnp.asarray(hi),
                                jnp.asarray(sg), jnp.asarray(pos),
                                interpret=True)
    want = iref.interval_counts(lo, hi, sg, pos)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_p,block_e", [(8, 8), (512, 1024), (7, 5)])
def test_interval_count_kernel_block_shapes(block_p, block_e):
    rng = np.random.default_rng(9)
    lo = rng.integers(0, 40, size=(3, 29)).astype(np.int32)
    hi = lo + rng.integers(0, 12, size=(3, 29)).astype(np.int32)
    sg = rng.choice([-1, 1], size=(3, 29)).astype(np.int32)
    pos = rng.integers(0, 60, size=(3, 23)).astype(np.int32)
    got = interval_count_kernel(jnp.asarray(lo), jnp.asarray(hi),
                                jnp.asarray(sg), jnp.asarray(pos),
                                block_p=block_p, block_e=block_e,
                                interpret=True)
    want = iref.interval_counts(lo, hi, sg, pos)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -- batch-masked intersections (mesh padding early-exit) --------------------
@pytest.mark.parametrize("B,valid,G,W", [(4, 4, 8, 4), (8, 3, 16, 10),
                                         (2, 0, 8, 1)])
def test_batch_masked_intersection_kernel(B, valid, G, W):
    rng = np.random.default_rng(B * G + W)
    bits = rng.integers(0, 1 << 32, size=(B, G, W),
                        dtype=np.uint64).astype(np.uint32)
    got = np.asarray(batch_masked_intersection_kernel(
        jnp.asarray(bits), jnp.asarray(np.array([valid], np.int32)),
        interpret=True))
    for b in range(B):
        if b < valid:
            want = np.asarray(jref.pairwise_intersection(jnp.asarray(bits[b])))
        else:  # padded rows early-exit to zeros — padding is transfer-only
            want = np.zeros((G, G), dtype=np.int32)
        np.testing.assert_array_equal(got[b], want)


# -- resident merge-round kernels (bitset_fold) ------------------------------
def _np_rank_ckey(bits_u32, alive, G):
    """NumPy oracle for the fused ranking: quantized keys + unique combined
    key with the column folded into both branches."""
    from repro.core.bitops import popcount
    from repro.core.merging import rank_keys

    inter = popcount(bits_u32[:, None, :] & bits_u32[None, :, :]).sum(
        axis=-1, dtype=np.int64)
    deg = np.diagonal(inter)
    keys = rank_keys(inter, deg[:, None], deg[None, :])
    col = np.broadcast_to(np.arange(G), (G, G))
    ok = alive[None, :] & (col != np.arange(G)[:, None])
    return np.where(ok, (keys + 1) * G - 1 - col, -1 - col)


@pytest.mark.parametrize("G,W,J", [(8, 2, 4), (16, 10, 7), (64, 33, 16)])
def test_topj_kernel_and_ref_match_numpy_oracle(G, W, J):
    rng = np.random.default_rng(G * W + J)
    bits = rng.integers(0, 1 << 32, size=(G, W),
                        dtype=np.uint64).astype(np.uint32)
    # duplicate rows force equal-key ties → broken by ascending column
    bits[G // 2] = bits[0]
    alive = rng.random(G) < 0.8
    alive[:2] = True
    bits[~alive] = 0
    ckey = _np_rank_ckey(bits, alive, G)
    want = np.argsort(-ckey, axis=1, kind="stable")[:, :J]
    got_k = np.asarray(jaccard_topj_kernel(
        jnp.asarray(bits), jnp.asarray(alive.astype(np.int8)[:, None]), J,
        interpret=True))
    got_r = np.asarray(fref.topj_all(jnp.asarray(bits[None]),
                                     jnp.asarray(alive.astype(np.int8)[None]),
                                     J))[0]
    np.testing.assert_array_equal(got_k, want)
    np.testing.assert_array_equal(got_r, want)


def test_topj_rows_matches_topj_all_gather():
    rng = np.random.default_rng(5)
    B, G, W, J = 3, 16, 4, 7
    bits = rng.integers(0, 1 << 32, size=(B, G, W),
                        dtype=np.uint64).astype(np.uint32)
    alive = (rng.random((B, G)) < 0.9).astype(np.int8)
    rows = np.stack([rng.integers(0, B, 10), rng.integers(0, G, 10)],
                    axis=1).astype(np.int32)
    full = np.asarray(fref.topj_all(jnp.asarray(bits), jnp.asarray(alive), J))
    sel = np.asarray(fref.topj_rows(jnp.asarray(bits), jnp.asarray(alive),
                                    jnp.asarray(rows), J))
    np.testing.assert_array_equal(sel, full[rows[:, 0], rows[:, 1]])


def test_rank_keys_numpy_jnp_identical():
    from repro.core.merging import rank_keys as np_keys

    rng = np.random.default_rng(0)
    deg_r = rng.integers(0, 1 << 22, size=257).astype(np.int64)
    deg_c = rng.integers(0, 1 << 22, size=257).astype(np.int64)
    inter = (np.minimum(deg_r, deg_c) * rng.random(257)).astype(np.int64)
    inter[:8] = [0, 1, 0, 5, 0, 0, 0, 0]
    deg_r[:4] = [0, 1, 7, 5]
    deg_c[:4] = [0, 1, 9, 5]  # zero-union and jaccard-1 corner cases
    want = np_keys(inter, deg_r, deg_c)
    got = np.asarray(fref.rank_keys(jnp.asarray(inter, dtype=jnp.int32),
                                    jnp.asarray(deg_r, dtype=jnp.int32),
                                    jnp.asarray(deg_c, dtype=jnp.int32)))
    np.testing.assert_array_equal(got, want.astype(np.int64))
    assert want.max() <= 1 << 15 and want.min() >= 0


def _np_fold(bits_u32, pairs):
    """Host-fold oracle on the uint32 view (mirrors the
    `BatchedGroupWorkspace.apply_merges` bitmap block)."""
    b = bits_u32.copy()
    one = np.uint32(1)
    for a, z, ca, cz in pairs:
        wa, ba = ca >> 5, np.uint32(ca & 31)
        wz, bz = cz >> 5, np.uint32(cz & 31)
        zbit = (b[:, wz] >> bz) & one
        b[:, wa] |= zbit << ba
        b[:, wz] &= ~(one << bz)
        b[a] |= b[z]
        b[z] = 0
        b[a, wa] &= ~(one << ba)
    return b


@pytest.mark.parametrize("use_kernel", [True, False])
def test_bitset_fold_matches_host_fold(use_kernel):
    rng = np.random.default_rng(7)
    G, W = 8, 3
    bits = rng.integers(0, 1 << 32, size=(G, W),
                        dtype=np.uint64).astype(np.uint32)
    # two pairs whose member columns share the SAME 32-bit word (cols 3, 7,
    # 9, 20 → words 0, 0, 0, 0) — the order-sensitivity hot spot
    pairs = [(0, 3, 3, 9), (1, 5, 7, 20)]
    instr = np.zeros((4, 8), dtype=np.int32)
    for i, (a, z, ca, cz) in enumerate(pairs):
        instr[i] = [a, z, ca >> 5, ca & 31, cz >> 5, cz & 31, 1, 0]
    want = _np_fold(bits, pairs)
    alive = np.ones((G,), dtype=np.int8)
    if use_kernel:
        got, oalive = bitset_fold_kernel(jnp.asarray(bits),
                                         jnp.asarray(alive[:, None]),
                                         jnp.asarray(instr), interpret=True)
        oalive = np.asarray(oalive)[:, 0]
    else:
        got, oalive = fref.fold_pairs(jnp.asarray(bits), jnp.asarray(alive),
                                      jnp.asarray(instr))
        oalive = np.asarray(oalive)
    np.testing.assert_array_equal(np.asarray(got), want)
    assert not oalive[3] and not oalive[5] and oalive[0] and oalive[1]


def test_bit_length_matches_python():
    vals = np.array([0, 1, 2, 3, 7, 8, 32767, 32768, (1 << 22) - 1, 1 << 22],
                    dtype=np.int64)
    from repro.core.merging import _bit_length

    want = np.array([int(v).bit_length() for v in vals])
    np.testing.assert_array_equal(_bit_length(vals.copy()), want)
    got = np.asarray(fref.bit_length(jnp.asarray(vals, dtype=jnp.int32)))
    np.testing.assert_array_equal(got, want)


# -- bounded jit caches ------------------------------------------------------
def test_lru_cache_evicts_oldest():
    c = LruCache(maxsize=2)
    c["a"] = 1
    c["b"] = 2
    assert c.get("a") == 1  # touch: "b" is now the LRU entry
    c["c"] = 3
    assert "b" not in c and "a" in c and "c" in c and len(c) == 2
    with pytest.raises(ValueError):
        LruCache(0)


def test_jit_caches_are_bounded():
    from repro.core import distributed, query_batch
    from repro.kernels.bitset_fold import ops as fold_ops

    for cache in (distributed._MESH_JACCARD_CACHE,
                  query_batch._JAX_SWEEP_CACHE,
                  query_batch._JAX_COUNT_CACHE,
                  jops._BATCH_JIT_CACHE,
                  fold_ops._TOPJ_CACHE,
                  fold_ops._FOLD_CACHE):
        assert isinstance(cache, LruCache)


def test_jaccard_against_python_sets():
    g = GG.barabasi_albert(150, 4, seed=2)
    sets = [set(map(int, g.neighbors(u))) for u in range(40)]
    bits = jops.pack_bitsets(sets, g.n)
    jac = np.asarray(jops.group_jaccard(bits, use_kernel=True))
    for i in range(0, 40, 7):
        for j in range(0, 40, 5):
            inter = len(sets[i] & sets[j])
            uni = len(sets[i] | sets[j])
            expect = inter / uni if uni else 0.0
            assert abs(jac[i, j] - expect) < 1e-6


def test_pack_adjacency_roundtrip_and_shingles():
    g = GG.star_of_cliques(30, 8, seed=3)
    rows, owners = mops.pack_adjacency(g.indptr, g.indices, width=8)
    got = np.asarray(mops.node_shingles(jnp.asarray(rows), owners, g.n,
                                        a=2654435761, b=99, use_kernel=True))
    # oracle: direct per-node min over N(u) ∪ {u}
    import jax
    h = np.asarray(mref.hash_u32(jnp.arange(g.n, dtype=jnp.uint32), 2654435761, 99))
    for u in range(g.n):
        grp = np.concatenate([[u], g.neighbors(u)]).astype(np.int64)
        assert got[u] == h[grp].min(), u
