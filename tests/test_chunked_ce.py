"""Chunked cross-entropy (memory substrate) ≡ the naive full-logits loss."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.registry import get_config
from repro.models.api import get_api, lm_loss
from repro.models.transformer import _logits


def _naive_loss(params, cfg, batch, aux_weight=0.01):
    api = get_api(cfg)
    tokens = batch["tokens"]
    inputs = dict(batch)
    inputs["tokens"] = tokens[:, :-1]
    logits, aux = api.forward(params, cfg, inputs)
    if cfg.n_patches and not cfg.encoder_layers:
        logits = logits[:, cfg.n_patches:, :]
    labels = tokens[:, 1:]
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:
        logits = jnp.where(jnp.arange(cfg.padded_vocab) < cfg.vocab, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return (logz - gold).mean() + aux_weight * aux


@settings(max_examples=8, deadline=None)
@given(b=st.integers(1, 3), s=st.sampled_from([8, 12, 16]),
       chunk=st.sampled_from([1, 4, 64]), seed=st.integers(0, 100))
def test_chunked_ce_equals_naive(b, s, chunk, seed):
    cfg = dataclasses.replace(get_config("qwen2.5-3b", smoke=True), dtype="float32")
    params = get_api(cfg).init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(seed), (b, s + 1), 0, cfg.vocab, jnp.int32)
    got = lm_loss(params, cfg, {"tokens": toks}, ce_chunk_tokens=chunk * b)
    want = _naive_loss(params, cfg, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-5)


def test_chunked_ce_grads_equal_naive():
    cfg = dataclasses.replace(get_config("h2o-danube-1.8b", smoke=True), dtype="float32")
    params = get_api(cfg).init_params(cfg, jax.random.key(1))
    toks = jax.random.randint(jax.random.key(2), (2, 17), 0, cfg.vocab, jnp.int32)
    g1 = jax.grad(lambda p: lm_loss(p, cfg, {"tokens": toks}, ce_chunk_tokens=8))(params)
    g2 = jax.grad(lambda p: _naive_loss(p, cfg, {"tokens": toks}))(params)
    for a, b_ in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=5e-4, atol=5e-5)


def test_chunked_ce_vlm_patch_slicing():
    cfg = dataclasses.replace(get_config("internvl2-26b", smoke=True), dtype="float32")
    params = get_api(cfg).init_params(cfg, jax.random.key(3))
    b, s_text = 2, 12
    batch = {
        "tokens": jax.random.randint(jax.random.key(4), (b, s_text + 1), 0, cfg.vocab, jnp.int32),
        "embeds": jax.random.normal(jax.random.key(5), (b, cfg.n_patches, cfg.d_model), jnp.float32),
    }
    got = lm_loss(params, cfg, batch, ce_chunk_tokens=6)
    want = _naive_loss(params, cfg, batch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=1e-5)
