"""Validate the trip-count-aware HLO analyzer against XLA's cost_analysis.

Three invariants:
  1. Loop-free programs: our dot-FLOPs match cost_analysis() closely.
  2. Scanned programs: our FLOPs match the hand-UNROLLED program's
     cost_analysis (the whole reason the analyzer exists: XLA counts while
     bodies once).
  3. Collectives inside a scan are multiplied by the trip count.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import analyze_hlo, parse_computations, shape_bytes


def _compiled(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def _xla_flops(comp) -> float:
    """compiled.cost_analysis() returns a dict on new jax, [dict] on old."""
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0))


def test_loop_free_matmul_flops_match_xla():
    def f(a, b):
        return jnp.tanh(a @ b) @ b

    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    comp = _compiled(f, a, b)
    ours = analyze_hlo(comp.as_text())
    theirs = _xla_flops(comp)
    # 2 dots: 2*64*128*128 each = 4.19M; elementwise is noise on top
    assert ours["flops"] == pytest.approx(theirs, rel=0.05)


def test_scan_flops_match_unrolled():
    L, B, D = 6, 8, 64

    def layer(x, w):
        return jnp.tanh(x @ w)

    def scanned(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (layer(c, w), None), x, ws)
        return y

    def unrolled(x, ws):
        for i in range(L):
            x = layer(x, ws[i])
        return x

    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    ours = analyze_hlo(_compiled(scanned, x, ws).as_text())
    unroll_flops = _xla_flops(_compiled(unrolled, x, ws))
    scan_flops_xla = _xla_flops(_compiled(scanned, x, ws))
    # sanity: XLA undercounts the scanned program
    assert scan_flops_xla < 0.5 * unroll_flops
    # ours: within 10% of the unrolled truth (loop bookkeeping adds epsilon)
    assert ours["flops"] == pytest.approx(unroll_flops, rel=0.10)
    assert any(w["trips"] == L for w in ours["while_loops"])


def test_scan_grad_flops_match_unrolled():
    L, B, D = 5, 4, 32

    def layer(x, w):
        return jnp.tanh(x @ w)

    def loss_scan(x, ws):
        y, _ = jax.lax.scan(lambda c, w: (layer(c, w), None), x, ws)
        return y.sum()

    def loss_unroll(x, ws):
        for i in range(L):
            x = layer(x, ws[i])
        return x.sum()

    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    g_scan = _compiled(jax.value_and_grad(loss_scan, argnums=(0, 1)), x, ws)
    g_unroll = _compiled(jax.value_and_grad(loss_unroll, argnums=(0, 1)), x, ws)
    ours = analyze_hlo(g_scan.as_text())
    truth = _xla_flops(g_unroll)
    assert ours["flops"] == pytest.approx(truth, rel=0.15)


def test_collectives_multiplied_by_trip_count():
    n = jax.device_count()
    mesh = jax.make_mesh((n,), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    from functools import partial

    L, D = 7, 64
    try:
        shard_map = jax.shard_map
    except AttributeError:  # older jax: experimental location
        from jax.experimental.shard_map import shard_map
    pvary = getattr(jax.lax, "pvary", lambda x, axis: x)  # identity pre-0.4.40

    @partial(shard_map, mesh=mesh, in_specs=P("d"), out_specs=P("d"))
    def step(x):
        def body(c, _):
            return pvary(jax.lax.psum(c, "d") * 0.5, "d"), None
        y, _ = jax.lax.scan(body, x, None, length=L)
        return y

    x = jax.ShapeDtypeStruct((n * D,), jnp.float32)
    comp = jax.jit(step).lower(x).compile()
    res = analyze_hlo(comp.as_text())
    per = D * 4  # one psum operand per device per iteration
    assert res["coll"]["all-reduce"] == pytest.approx(L * per, rel=0.01)
    assert res["coll_count"]["all-reduce"] == L


def test_shape_bytes_tuple():
    assert shape_bytes("(f32[2,3]{1,0}, bf16[4])") == 24 + 8
    assert shape_bytes("pred[]") == 1


def test_parse_computations_smoke():
    def f(x):
        return (x @ x).sum()

    comp = _compiled(f, jax.ShapeDtypeStruct((32, 32), jnp.float32))
    comps = parse_computations(comp.as_text())
    assert len(comps) >= 1
