"""Unit tests for the flat Summary IR (Euler-tour/DFS-interval forest view)."""
import numpy as np
import pytest

from repro.core import summarize
from repro.core.summary_ir import SummaryIR, group_pairs
from repro.graphs import generators as GG
from repro.graphs.csr import Graph


def _summaries():
    out = []
    for g, T in [(GG.caveman(10, 6, 0.05, seed=8), 6),
                 (GG.barabasi_albert(120, 3, seed=9), 6),
                 (GG.bipartite_nested(32, 31, 5), 8)]:
        for steps in [(), (1, 2, 3)]:
            out.append((g, summarize(g, T=T, seed=0, prune_steps=steps)))
    return out


def test_intervals_partition_leaves():
    for g, s in _summaries():
        ir = s.ir
        # every leaf position is claimed exactly once
        assert np.array_equal(np.sort(ir.pos_of), np.arange(g.n))
        assert np.array_equal(ir.order[ir.pos_of], np.arange(g.n))
        # root intervals tile [0, n)
        starts = np.sort(ir.first[ir.roots])
        assert starts[0] == 0
        sizes = ir.size(ir.roots)
        assert int(sizes.sum()) == g.n


def test_leaves_and_children_match_recursive_walk():
    for g, s in _summaries():
        ir = s.ir
        parent = s.parent
        kids_ref: dict = {}
        for i, p in enumerate(parent):
            if p >= 0:
                kids_ref.setdefault(int(p), []).append(i)

        def leaves_ref(x):
            if x < s.n_leaves:
                return [x]
            return [l for c in kids_ref.get(x, []) for l in leaves_ref(c)]

        for x in np.flatnonzero(parent > -2):
            x = int(x)
            assert sorted(ir.children_of(x).tolist()) == sorted(kids_ref.get(x, []))
            assert sorted(ir.leaves_of(x).tolist()) == sorted(leaves_ref(x))
            # the child interval union is exactly the parent interval
            ch = ir.children_of(x)
            if ch.size:
                assert ir.first[x] == ir.first[ch].min()
                assert ir.last[x] == ir.last[ch].max()
                assert int(ir.size(np.array([x]))[0]) == int(ir.size(ch).sum())


def test_depth_and_heights():
    for g, s in _summaries():
        ir = s.ir
        d_ref = np.zeros(g.n, dtype=np.int64)
        for u in range(g.n):
            x, depth = u, 0
            while s.parent[x] >= 0:
                x = int(s.parent[x])
                depth += 1
            d_ref[u] = depth
        assert np.array_equal(ir.depth[: g.n], d_ref)
        # height per root = max leaf depth inside the root's interval
        hs = ir.tree_heights()
        for r, h in zip(ir.roots, hs):
            assert h == int(ir.depth[ir.leaves_of(int(r))].max())


def test_incidence_csr():
    g = GG.caveman(8, 5, 0.05, seed=1)
    s = summarize(g, T=5, seed=2)
    ir = s.ir
    ir.build_incidence(s.edges)
    inc_ref: dict = {}
    for e, (X, Y, _sg) in enumerate(s.edges):
        inc_ref.setdefault(int(X), []).append(e)
        if X != Y:
            inc_ref.setdefault(int(Y), []).append(e)
    for x in range(ir.n_ids):
        eids, _ = ir.incident_eids(np.array([x]))
        assert sorted(eids.tolist()) == sorted(inc_ref.get(x, []))


def test_parent_order_invariant_enforced():
    # parent[x] <= x is not a merge forest; the builder must refuse it
    with pytest.raises(ValueError):
        SummaryIR(np.array([-1, 0, 1], dtype=np.int64), 1)


def test_group_pairs_no_overflow():
    """The ka * (max(kb)+1) + kb keying overflows int64 for large ids; the
    diff-based grouping must not (the regression this guards: silent root-pair
    collisions in emission on billion-node forests)."""
    big = np.int64(2 ** 62)
    a = np.array([big, big, 5, 5, big, 3], dtype=np.int64)
    b = np.array([big - 1, big - 1, 7, 8, 3, big], dtype=np.int64)
    order, starts = group_pairs(a, b)
    sa, sb = a[order], b[order]
    bounds = np.concatenate([starts, [a.size]])
    got = {(int(sa[s]), int(sb[s])): int(e - s)
           for s, e in zip(bounds[:-1], bounds[1:])}
    assert got == {(3, int(big)): 1, (5, 7): 1, (5, 8): 1,
                   (int(big), 3): 1, (int(big), int(big) - 1): 2}
    # sanity: the old multiplicative key really does overflow here
    with np.errstate(over="ignore"):
        key = a * (np.max(b) + 1) + b
    assert np.unique(key).size < len(got) + 1  # collisions under overflow


def test_empty_and_singleton_forests():
    ir = SummaryIR(np.full(4, -1, dtype=np.int64), 4)
    assert np.array_equal(ir.roots, np.arange(4))
    assert np.array_equal(ir.tree_heights(), np.zeros(4, dtype=np.int64))
    assert ir.max_children() == 0
