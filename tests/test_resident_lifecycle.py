"""Whole-iteration device residency (ISSUE 7 / DESIGN.md §9).

The resident backend's contract is that state which OUTLIVES one iteration
— the run context's edge arrays and root map, and the per-iteration arenas'
bitmaps + integer count tensors — evolves on device in exact lockstep with
the host's authoritative copies. These tests pin that contract at its seams:
plan-replay carry across iterations, the unified u32 shingle family's four
bit-identical paths, the zero-merge and all-dead degenerate iterations, the
integer-Saving clamp constants shared by host and device, transfer-counter
thread safety under the engine's thread pool, and the int32 count dtypes at
their clamp boundary.
"""
import numpy as np
import pytest

from repro.core.engine import SummarizerEngine
from repro.core.minhash import (host_shingle_provider, hash_u32,
                                node_shingles_u32, rootwise_min,
                                u32_seed_consts)
from repro.core.slugger import SluggerState
from repro.graphs import generators as GG


# -- plan-driven carry: res_map replay vs host root_of ------------------------
def test_run_context_carry_matches_host_every_iteration():
    """After EVERY exchange stage (≥3 iterations with merges), the device
    root map — advanced only by replaying applied MergePlans, never
    re-uploaded — equals the host ``root_of`` bit for bit."""
    g = GG.caveman(10, 6, 0.05, seed=3)
    checked = {"iters": 0, "merge_iters": 0}

    def stage_exchange(engine, ctx):
        SummarizerEngine.stage_exchange(engine, ctx)
        assert engine._run_ctx is not None
        rm = engine._run_ctx.root_of_host()
        host = ctx.state.root_of
        assert np.array_equal(rm[: host.size], host), f"iter {ctx.t}"
        checked["iters"] += 1
        checked["merge_iters"] += ctx.merges > 0

    e = SummarizerEngine(backend="resident", T=6, seed=2,
                         stages={"exchange": stage_exchange})
    state, _ = e.merge_forest(g)
    assert checked["iters"] == 6
    assert checked["merge_iters"] >= 3  # the carry was exercised, not idle
    assert e.stats["merges"] > 0


def test_run_context_zero_merge_iteration_is_noop():
    """An iteration that accepts no merges advances nothing: empty batch
    list → res_map unchanged, no carry bytes counted."""
    from repro.core.resident import ResidentRunContext
    from repro.core.transfer import TransferCounter

    g = GG.caveman(4, 5, 0.0, seed=1)
    counter = TransferCounter()
    ctx = ResidentRunContext(g, counter=counter)
    before = ctx.root_of_host()
    carry0 = counter.snapshot()["phases"].get("carry", 0)
    ctx.advance([])
    ctx.advance([(np.zeros(0, np.int64), np.zeros(0, np.int64),
                  np.zeros(0, np.int64))])
    assert counter.snapshot()["phases"].get("carry", 0) == carry0
    assert np.array_equal(ctx.root_of_host(), before)


def test_run_context_multi_round_chain_collapses():
    """Chained merges WITHIN one iteration (a merges into a parent that
    itself merges in a later round) must collapse to the final root —
    the pointer-doubling fixpoint, replayed against a real state."""
    from repro.core.resident import ResidentRunContext

    g = GG.caveman(2, 6, 0.0, seed=0)
    st = SluggerState(g)
    ctx = ResidentRunContext(g)
    m1 = st.merge_batch(np.array([0]), np.array([1]))
    m2 = st.merge_batch(np.array([int(m1[0])]), np.array([2]))
    m3 = st.merge_batch(np.array([int(m2[0])]), np.array([3]))
    ctx.advance([(np.array([0]), np.array([1]), m1),
                 (m1.astype(np.int64), np.array([2]), m2),
                 (m2.astype(np.int64), np.array([3]), m3)])
    rm = ctx.root_of_host()
    assert np.array_equal(rm[: g.n], st.root_of)
    assert rm[0] == rm[1] == rm[2] == rm[3] == int(m3[0])


# -- unified u32 shingle family: four bit-identical paths --------------------
def test_u32_shingle_paths_bit_identical():
    """Host NumPy twin, replicated jnp reference, and the resident
    on-device path agree bit for bit, per node and per root (the mesh
    shard_map path is pinned to the jnp reference in
    test_distributed_core.test_shingles_sharded_equivalence_8dev)."""
    import jax.numpy as jnp
    from repro.core import distributed as D
    from repro.core.resident import ResidentRunContext

    g = GG.barabasi_albert(120, 3, seed=9)
    src = np.repeat(np.arange(g.n), np.diff(g.indptr))
    st = SluggerState(g)
    for a, z in ((0, 1), (2, 3), (10, 50)):
        st.merge(a, z)
    root_of = st.root_of
    n_ids = int(root_of.max()) + 1

    ctx = ResidentRunContext(g)
    batches = [(np.array([0]), np.array([1]), np.array([g.n])),
               (np.array([2]), np.array([3]), np.array([g.n + 1])),
               (np.array([10]), np.array([50]), np.array([g.n + 2]))]
    ctx.advance(batches)
    host_fn = host_shingle_provider(g)(root_of)
    dev_fn = ctx.for_roots(root_of)

    for sub_seed in (0, 1, 42, 2**63 - 5):
        a, b = u32_seed_consts(sub_seed)
        node_host = node_shingles_u32(g, sub_seed)
        node_ref = np.asarray(D.node_shingles_dense(
            jnp.asarray(src.astype(np.int32)),
            jnp.asarray(g.indices.astype(np.int32)), g.n, int(a), int(b)))
        assert np.array_equal(node_host, node_ref.astype(np.uint32))
        want = rootwise_min(node_host.astype(np.int64), root_of, n_ids,
                            1 << 32)
        assert np.array_equal(host_fn(sub_seed, n_ids), want)
        assert np.array_equal(dev_fn(sub_seed, n_ids), want)


def test_u32_leafless_root_sentinel():
    """Ids owning no leaves take the unique 2^32+id sentinel on every path
    — a leafless root must never spuriously group under a real shingle."""
    from repro.core.resident import ResidentRunContext

    g = GG.caveman(3, 4, 0.0, seed=2)
    root_of = np.zeros(g.n, dtype=np.int64)  # every leaf under root 0
    n_ids = 5
    ctx = ResidentRunContext(g)
    A = np.arange(1, g.n, dtype=np.int64)
    ctx.advance([(np.zeros(g.n - 1, np.int64), A,
                  np.zeros(g.n - 1, np.int64))])
    for fn in (host_shingle_provider(g)(root_of), ctx.for_roots(root_of)):
        sh = fn(7, n_ids)
        assert sh[0] < (1 << 32)
        assert np.array_equal(sh[1:], (1 << 32) + np.arange(1, n_ids))


def test_u32_engine_backends_group_identically():
    """With the family unified, numpy / batched / resident runs of the SAME
    seed group (and therefore merge) identically — the engine-level face
    of the provider contract."""
    from repro.core import summarize

    g = GG.erdos_renyi(150, 0.04, seed=11)
    runs = {be: summarize(g, T=5, seed=4, backend=be)
            for be in ("numpy", "batched", "resident")}
    assert runs["numpy"].validate_lossless(g)
    for be in ("batched", "resident"):
        assert np.array_equal(runs["numpy"].parent, runs[be].parent), be
        assert np.array_equal(runs["numpy"].edges, runs[be].edges), be


# -- arena degenerate iterations ---------------------------------------------
def test_arena_all_dead_group_sweeps_to_nothing():
    """Degenerate sweeps terminate with BOTH dirty mirrors drained: a
    workspace of 2-cliques (no proposal ever passes — merging an isolated
    edge saves nothing) ends round 1 with zero merges, and a dense-clique
    workspace whose rows progressively die still drains to no dirty rows."""
    from repro.core.merging import (BatchedGroupWorkspace, MergePlan,
                                    ResidentRankSource)
    from repro.core.resident import ResidentBitmapArena

    def sweep(g, groups, size):
        st = SluggerState(g)
        plans = [MergePlan(gr) for gr in groups]
        ws = BatchedGroupWorkspace.build_bucket(
            st, groups, size, plans=plans,
            group_seeds=np.arange(len(groups), dtype=np.uint64) + 1)[0]
        arena = ResidentBitmapArena.from_workspace(ws, top_j=4)
        merges = ws.sweep(0.0, ResidentRankSource(arena))
        # the sweep only terminates when the HOST queue drains; the device
        # dirty mirror must have drained in lockstep
        assert not np.asarray(arena._dirty).any()
        return plans, merges

    # all proposals rejected: every row dies undirty in round 1
    g = GG.caveman(4, 2, 0.0, seed=5)  # 4 disjoint 2-cliques
    plans, merges = sweep(g, [np.array([2 * i, 2 * i + 1])
                              for i in range(4)], 2)
    assert merges == 0
    assert all(len(p.rounds) == 0 for p in plans)

    # dense cliques merge down over several rounds, then drain
    g2 = GG.caveman(2, 8, 0.0, seed=6)
    plans2, merges2 = sweep(g2, [np.arange(8), np.arange(8) + 8], 8)
    assert merges2 > 0
    assert any(len(p.rounds) >= 1 for p in plans2)


def test_engine_zero_merge_run_keeps_resident_state_consistent():
    """An edgeless graph yields zero groups, zero merges, zero carry — but
    the run context and per-iteration transfer stats stay well-formed."""
    from repro.graphs.csr import Graph

    g = Graph.from_edges(6, np.zeros((0, 2), dtype=np.int64))
    e = SummarizerEngine(backend="resident", T=3, seed=0)
    state, _ = e.merge_forest(g)
    assert e.stats["merges"] == 0
    assert len(e.stats["transfer_iters"]) == 3
    assert np.array_equal(e._run_ctx.root_of_host(), state.root_of)


# -- per-iteration transfer accounting ---------------------------------------
def test_engine_transfer_iters_sum_to_total():
    g = GG.caveman(8, 6, 0.05, seed=7)
    e = SummarizerEngine(backend="resident", T=4, seed=1)
    e.merge_forest(g)
    iters = e.stats["transfer_iters"]
    assert len(iters) == 4
    total = e.stats["transfer"]
    assert sum(d["bytes_h2d"] for d in iters) == total["bytes_h2d"]
    assert sum(d["bytes_d2h"] for d in iters) == total["bytes_d2h"]
    assert sum(d["rounds"] for d in iters) == total["rounds"]
    for ph, v in total["phases"].items():
        assert sum(d["phases"].get(ph, 0) for d in iters) == v, ph
    # the whole iteration is device-resident: candgen bytes are per-root
    # results only, and rank traffic is the (K,2) verdicts + θ̂ scalars
    assert any(d["phases"].get("candgen", 0) > 0 for d in iters)
    # every crossing is attributed: no phase outside the audited set
    allowed = {"init", "upload", "rank", "fold", "carry", "candgen",
               "bank", "extract", "sync"}
    assert set(total["phases"]) <= allowed, total["phases"]
    # the bank path is live on this run: merge batches advance the bank,
    # chunk state extracts on device, and NO iteration re-uploads host
    # workspaces (phase `upload` stays zero even in iteration 1 — the bank
    # seeds under `init`)
    assert e._run_ctx is not None and e._run_ctx.bank is not None
    assert total["phases"].get("bank", 0) > 0
    assert total["phases"].get("extract", 0) > 0
    assert total["phases"].get("upload", 0) == 0
    assert total["phases"].get("carry", 0) == 0  # superseded by `bank`
    assert iters[0]["phases"].get("init", 0) > 0  # seeding lands in iter 1


def test_transfer_counter_thread_safe():
    """64 threads × 1000 increments each — the lock must not lose counts
    (the engine's merge_round pool reports concurrently)."""
    import threading

    from repro.core.transfer import TransferCounter

    c = TransferCounter()
    N, T = 1000, 64

    def hammer():
        for _ in range(N):
            c.add_h2d(3, phase="rank")
            c.add_d2h(5, phase="fold")
            c.tick_round()

    threads = [threading.Thread(target=hammer) for _ in range(T)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = c.snapshot()
    assert snap["bytes_h2d"] == 3 * N * T
    assert snap["bytes_d2h"] == 5 * N * T
    assert snap["rounds"] == N * T
    assert snap["phases"] == {"rank": 3 * N * T, "fold": 5 * N * T}


# -- integer-Saving constants and clamp boundary -----------------------------
def test_saving_constants_pinned_host_device():
    """merging.py (host int64 path) and kernels/bitset_fold/ref.py (device
    32-bit-limb path) must clamp and quantize with the SAME constants."""
    from repro.core import merging
    from repro.kernels.bitset_fold import ref

    assert merging.C_CLAMP == ref.C_CLAMP == 1 << 30
    assert merging.THETA_SHIFT == ref.THETA_SHIFT == 20


def test_theta_accept_agrees_at_clamp_boundary():
    """Host int64 and device 32-bit-limb θ̂ acceptance agree across the
    clamp boundary and the full θ̂ range — including denominators at
    C_CLAMP, where naive 32-bit arithmetic would overflow."""
    import jax.numpy as jnp

    from repro.core.merging import C_CLAMP, theta_accept_host, theta_to_p
    from repro.kernels.bitset_fold import ref

    rng = np.random.default_rng(0)
    edge = np.array([0, 1, 2, C_CLAMP - 2, C_CLAMP - 1, C_CLAMP],
                    dtype=np.int64)
    denom = np.concatenate([edge, rng.integers(1, C_CLAMP, 64)])
    numer = np.minimum(
        np.concatenate([edge, rng.integers(0, C_CLAMP, 64)]), denom)
    for theta in (0.0, 1e-9, 1.0 / 3.0, 0.5, 1.0 - 1e-9, 1.0):
        p = theta_to_p(theta)
        want = theta_accept_host(numer, denom, p)
        got = np.asarray(ref.theta_accept(
            jnp.asarray(numer.astype(np.int32)),
            jnp.asarray(denom.astype(np.int32)), jnp.uint32(p)))
        assert np.array_equal(got, want), theta


def test_workspace_counts_are_int32_and_clamped():
    """CNT/selfc live as exact integers (ISSUE 7 satellite): int32 dtype,
    and the possible-pairs terms never exceed C_CLAMP even for counts at
    the clamp boundary."""
    from repro.core.merging import (BatchedGroupWorkspace, C_CLAMP,
                                    MergePlan, poss_pair_i, poss_self_i)

    g = GG.caveman(2, 6, 0.0, seed=0)
    st = SluggerState(g)
    ws = BatchedGroupWorkspace.build_bucket(
        st, [np.arange(6)], 6, plans=[MergePlan(np.arange(6))],
        group_seeds=np.ones(1, dtype=np.uint64))[0]
    assert ws.CNT.dtype == np.int32
    assert np.issubdtype(ws.selfc.dtype, np.integer)  # exact, never float
    big = np.array([1, 1 << 16, C_CLAMP - 1, C_CLAMP], dtype=np.int64)
    assert (poss_pair_i(big, big) <= C_CLAMP).all()
    assert (poss_self_i(big) <= C_CLAMP).all()
    assert poss_pair_i(np.array([C_CLAMP]), np.array([C_CLAMP]))[0] == C_CLAMP


# -- u32 hash twins -----------------------------------------------------------
def test_hash_u32_twins_bit_identical():
    """The NumPy mix, the distributed jnp mix, and the carry-op jnp mix are
    one function in three dialects."""
    import jax.numpy as jnp

    from repro.core import distributed as D
    from repro.kernels.bitset_fold import carry

    x = np.arange(4096, dtype=np.uint32)
    for seed in (0, 1, 7, 123456789):
        a, b = u32_seed_consts(seed)
        want = hash_u32(x, a, b)
        d1 = np.asarray(D._hash_u32(jnp.asarray(x), jnp.uint32(a),
                                    jnp.uint32(b)))
        d2 = np.asarray(carry._hash_u32(jnp.asarray(x), jnp.uint32(a),
                                        jnp.uint32(b)))
        assert np.array_equal(d1.astype(np.uint32), want)
        assert np.array_equal(d2.astype(np.uint32), want)
