"""Multi-device correctness of the §Perf sharding choices.

These run in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main test session keeps seeing 1 device (per the dry-run isolation
rule). Verified claims:

  1. decode over a TIME-sharded KV cache (the §Perf decode iteration) is
     numerically identical to single-device decode;
  2. a train step with bf16 optimizer moments still learns (loss decreases)
     and the moments really are bf16.
"""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.models import sharding as SH
from repro.train.train_step import build_serve_step

import dataclasses
cfg = dataclasses.replace(get_config("qwen2.5-3b", smoke=True), dtype="float32")
# GQA kv=2: triggers time-sharding; f32 for a tight numeric comparison
mesh = jax.make_mesh((2, 4), ("data", "model"))
dp = ("data",)

B, S = 8, 32
params = T.init_params(cfg, jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab, jnp.int32)

# single-device reference: prefill 16, decode the rest
ref_logits, cache = T.prefill(params, cfg, toks[:, :16], cache_len=S)
outs_ref = []
c = cache
for pos in range(16, S):
    lg, c = T.decode_step(params, cfg, c, toks[:, pos:pos+1], jnp.int32(pos))
    outs_ref.append(np.asarray(lg[:, 0], np.float32))

# sharded decode: same cache content, sharded per cache_pspecs
shape = ShapeConfig("d", S, B, "decode")
step, params_sh, in_sh, _ = build_serve_step(cfg, mesh, dp, shape)
cspecs = SH.cache_pspecs(cfg, cache, mesh, dp, B)
# confirm the time axis really is sharded over "model" for this config
kspec = jax.tree.leaves(cspecs, is_leaf=lambda x: hasattr(x, "index"))[0]
pp = jax.device_put(params, jax.tree.map(lambda s: s, params_sh))
cc = jax.tree.map(lambda a, s: jax.device_put(a, jax.sharding.NamedSharding(mesh, s)),
                  cache, cspecs, is_leaf=lambda x: hasattr(x, "shape"))
outs = []
for pos in range(16, S):
    lg, cc = step(pp, cc, toks[:, pos:pos+1], jnp.int32(pos))
    outs.append(np.asarray(lg[:, 0], np.float32))

err = max(float(np.max(np.abs(a - b))) for a, b in zip(outs, outs_ref))
print(json.dumps({"max_err": err, "kspec": str(kspec)}))
"""

_SCRIPT_BF16 = r"""
import os
os.environ["XLA_FLAGS"] = os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ShapeConfig
from repro.configs.registry import get_config
from repro.models.api import abstract_params, get_api
from repro.optim.adamw import AdamWConfig
from repro.train.train_step import TrainPlan, build_train_step

cfg = get_config("deepseek-7b", smoke=True)
mesh = jax.make_mesh((4, 2), ("data", "model"))
shape = ShapeConfig("t", 32, 8, "train")
plan = TrainPlan(cfg=cfg, mesh=mesh, dp_axes=("data",),
                 opt=AdamWConfig(lr=1e-2, moment_dtype="bfloat16"), microbatch=4)
step, state_sh, _, state_abs = build_train_step(plan, shape)
api = get_api(cfg)
params = api.init_params(cfg, jax.random.key(0))
from repro.optim import adamw
opt = adamw.init_state(params, "bfloat16")
state = {"params": params, "opt": opt}
state = jax.device_put(state, state_sh)
rng = np.random.default_rng(0)
losses = []
for i in range(30):
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (8, 33)), jnp.int32)
    state, metrics = step(state, {"tokens": toks})
    losses.append(float(metrics["loss"]))
mdt = str(jax.tree.leaves(state["opt"]["m"])[0].dtype)
print(json.dumps({"first": losses[0], "last": losses[-1], "m_dtype": mdt}))
"""


def _run(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_decode_time_sharded_cache_matches_single_device():
    res = _run(_SCRIPT)
    assert res["max_err"] < 2e-3, res
    assert "model" in res["kspec"], res  # time axis really sharded


@pytest.mark.slow
def test_train_step_bf16_moments_learns():
    res = _run(_SCRIPT_BF16)
    assert res["m_dtype"] == "bfloat16"
    assert res["last"] < res["first"] - 0.2, res
