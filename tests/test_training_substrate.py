"""Tests: optimizer, schedules, checkpointing (atomic/async), fault-tolerant
loop (retry / restore / straggler), elastic remesh, gradient compression."""
import os
import shutil
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import adamw, schedules
from repro.optim.grad_compression import dequantize_int8, quantize_int8
from repro.train import checkpoint as CKPT
from repro.train.fault_tolerance import FaultToleranceConfig, ResilientLoop, StragglerWatch


# ----------------------------------------------------------------- optimizer
def test_adamw_minimizes_quadratic():
    params = {"w": jnp.ones((8,)) * 5.0}
    opt = adamw.init_state(params)
    cfg = adamw.AdamWConfig(lr=0.2, weight_decay=0.0)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, opt, _ = adamw.apply_updates(params, g, opt, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros((4,))}
    opt = adamw.init_state(params)
    cfg = adamw.AdamWConfig(lr=1.0, grad_clip=1e-3, weight_decay=0.0)
    g = {"w": jnp.full((4,), 1e6)}
    p2, _, metrics = adamw.apply_updates(params, g, opt, cfg)
    assert float(metrics["grad_norm"]) > 1e5
    assert np.isfinite(np.asarray(p2["w"])).all()


def test_cosine_schedule_shape():
    s = schedules.cosine_with_warmup(jnp.arange(1000), warmup=100, total=1000)
    s = np.asarray(s)
    assert s[0] < 0.02 and abs(s[99] - 1.0) < 0.02
    assert s[-1] <= s[150]


# --------------------------------------------------------------- checkpoints
def test_checkpoint_roundtrip(tmp_path):
    state = {"params": {"a": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"step": jnp.int32(7)}}
    CKPT.save(state, 7, str(tmp_path))
    got, step = CKPT.restore(state, str(tmp_path))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(got["params"]["a"]), np.asarray(state["params"]["a"]))
    assert int(got["opt"]["step"]) == 7


def test_checkpoint_atomic_no_partial(tmp_path):
    state = {"w": jnp.ones((4,))}
    CKPT.save(state, 1, str(tmp_path))
    # a stale tmp dir from a crashed save must not break latest_step/restore
    os.makedirs(tmp_path / "step_00000002.tmp")
    assert CKPT.latest_step(str(tmp_path)) == 1
    got, step = CKPT.restore(state, str(tmp_path))
    assert step == 1


def test_async_checkpointer_gc(tmp_path):
    ck = CKPT.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        ck.submit({"w": jnp.full((2,), s)}, s)
    ck.close()
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000020", "step_00000030"]
    assert not ck.errors


# ------------------------------------------------------ fault-tolerant loop
def _mini_step(state, batch):
    return {"x": state["x"] + batch}, {"loss": state["x"]}


def test_resilient_loop_retries_transient():
    calls = {"n": 0}

    def flaky(state, batch):
        calls["n"] += 1
        if calls["n"] == 3:
            raise RuntimeError("transient device error")
        return _mini_step(state, batch)

    loop = ResilientLoop(flaky, {"x": 0}, lambda s: 1,
                         ft=FaultToleranceConfig(max_retries=2, ckpt_every=10**9))
    state, end = loop.run(0, 5)
    assert state["x"] == 5 and end == 5
    assert any(f["action"] == "retry" for f in loop.failures)


def test_resilient_loop_restores_persistent(tmp_path):
    ck = CKPT.AsyncCheckpointer(str(tmp_path))
    boom = {"armed": False}

    def step(state, batch):
        if boom["armed"] and int(state["x"]) == 6:
            raise RuntimeError("persistent")
        return {"x": state["x"] + batch}, {"loss": 0.0}

    def restore_fn():
        # x64 is disabled in tests, so the restore template must request the
        # 32-bit dtype explicitly (jnp.int64 would warn and truncate)
        st, sp = CKPT.restore({"x": jnp.int32(0)}, str(tmp_path))
        boom["armed"] = False  # "replacement node" fixes the fault
        return {"x": int(st["x"])}, sp

    loop = ResilientLoop(step, {"x": 0}, lambda s: 1, checkpointer=ck,
                         ft=FaultToleranceConfig(ckpt_every=5, max_retries=1),
                         restore_fn=restore_fn)
    # run 5 steps -> ckpt at 5; arm the bomb; next run hits it at x==6
    state, end = loop.run(0, 5)
    ck.wait()
    boom["armed"] = True
    state, end = loop.run(5, 5)
    assert end == 10 and state["x"] == 10
    assert any(f["action"] == "restore" for f in loop.failures)
    ck.close()


def test_straggler_watch_flags_slow_steps():
    w = StragglerWatch(factor=3.0, min_history=3)
    for i in range(5):
        w.observe(i, 0.1)
    assert w.observe(5, 1.0)
    assert w.events and w.events[0]["step"] == 5


# ------------------------------------------------------------- compression
def test_int8_quantization_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(5000,)).astype(np.float32))
    q, scale, n = quantize_int8(x)
    back = dequantize_int8(q, scale, n)
    err = np.abs(np.asarray(back - x))
    assert err.max() <= float(np.abs(x).max()) / 127.0 + 1e-6


COMPRESSED_DP = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.optim.grad_compression import compressed_psum

    try:
        mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    except AttributeError:  # older jax has no AxisType (Auto is the default)
        mesh = jax.make_mesh((8,), ("data",))
    rng = np.random.default_rng(0)
    g_local = jnp.asarray(rng.normal(size=(8, 4096)).astype(np.float32))  # per-worker grads
    err0 = jnp.zeros((8, 4096), jnp.float32)

    def body(g, e):  # worker view: (1, 4096)
        out, ne = compressed_psum(g[0], e[0], ("data",))
        return out[None], ne[None]

    try:
        shard_map = jax.shard_map
    except AttributeError:
        from jax.experimental.shard_map import shard_map
    out, new_err = shard_map(body, mesh=mesh, in_specs=(P("data", None), P("data", None)),
                             out_specs=(P("data", None), P("data", None)))(g_local, err0)
    out = np.asarray(out)
    want = np.asarray(g_local).mean(axis=0)
    # every worker holds the same mean; quantization error is bounded
    for w in range(8):
        rel = np.abs(out[w] - want).max() / (np.abs(want).max() + 1e-9)
        assert rel < 0.05, (w, rel)
    # error feedback residual is finite and bounded by one quantization step
    assert np.isfinite(np.asarray(new_err)).all()
    print("COMPRESS_OK", rel)
""")


def test_compressed_allreduce_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", COMPRESSED_DP], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "COMPRESS_OK" in r.stdout, r.stderr[-2000:]


# ------------------------------------------------------------------ elastic
ELASTIC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.train.elastic import make_mesh_for, remesh_state

    devs = jax.devices()
    mesh8 = make_mesh_for(devs, model_parallel=2)
    state = {"w": jnp.arange(32.0).reshape(8, 4), "step": jnp.int32(3)}
    def spec_fn(state, mesh):
        return {"w": P("data", None), "step": P()}
    st8 = remesh_state(state, mesh8, spec_fn)
    # "lose half the pool": re-mesh onto 4 devices
    mesh4 = make_mesh_for(devs[:4], model_parallel=2)
    st4 = remesh_state(st8, mesh4, spec_fn)
    np.testing.assert_array_equal(np.asarray(st4["w"]), np.asarray(state["w"]))
    assert st4["w"].sharding.mesh.devices.size == 4
    # and back up to 8
    st8b = remesh_state(st4, mesh8, spec_fn)
    np.testing.assert_array_equal(np.asarray(st8b["w"]), np.asarray(state["w"]))
    print("ELASTIC_OK")
""")


def test_elastic_remesh_8_to_4_to_8():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", ELASTIC], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "ELASTIC_OK" in r.stdout, r.stderr[-2000:]
