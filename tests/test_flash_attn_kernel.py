"""Pallas flash attention kernel vs the pure-jnp oracle (interpret mode),
and vs the XLA chunked_sdpa twin — shape/dtype sweeps per deliverable (c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn.kernel import flash_attention_bhsd
from repro.kernels.flash_attn.ops import flash_attention
from repro.kernels.flash_attn.ref import attention_ref


def _mk(b, h, hkv, s, d, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, d), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5), (jnp.bfloat16, 2e-2)])
@pytest.mark.parametrize("s,bq,bk", [(128, 32, 32), (256, 64, 32), (128, 128, 128)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_matches_ref(dtype, atol, s, bq, bk, causal, window):
    b, h, hkv, d = 2, 4, 2, 32
    q, k, v = _mk(b, h, hkv, s, d, dtype)
    out = flash_attention_bhsd(q, k, v, causal=causal, window=window,
                               bq=bq, bk=bk, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=atol, rtol=1e-2)


def test_flash_wrapper_matches_chunked_sdpa():
    """The Pallas kernel and its pure-XLA twin agree bit-for-bit-ish."""
    from repro.models.attention import chunked_sdpa
    b, s, hkv, g, d = 2, 128, 2, 3, 32
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (b, s, hkv, g, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    out_k = flash_attention(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    out_x = chunked_sdpa(q, k, v, causal=True, chunk=32)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_x), atol=3e-5, rtol=1e-4)


def test_flash_single_block_noncausal():
    b, h, hkv, s, d = 1, 2, 1, 64, 64
    q, k, v = _mk(b, h, hkv, s, d, jnp.float32, seed=3)
    out = flash_attention_bhsd(q, k, v, causal=False, bq=64, bk=64, interpret=True)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)


def test_model_forward_with_pallas_attn_matches_default():
    """End-to-end: a smoke transformer with attn_impl=pallas_flash (interpret)
    produces the same logits as the default XLA-chunked path."""
    import dataclasses
    from repro.configs.registry import get_config
    from repro.models import transformer as T

    cfg = dataclasses.replace(get_config("qwen2.5-3b", smoke=True), dtype="float32")
    params = T.init_params(cfg, jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 16), 0, cfg.vocab)
    base, _, _ = T.forward(params, cfg, toks)
    cfg2 = dataclasses.replace(cfg, attn_impl="pallas_flash")
    out, _, _ = T.forward(params, cfg2, toks)
    np.testing.assert_allclose(np.asarray(out), np.asarray(base), atol=2e-4, rtol=1e-3)
