"""Batched summary-query serving (ISSUE 3 acceptance): every query backend —
numpy flat sweep, jit/vmap jax sweep, Pallas interval-expand boundary counts —
and the packed/reloaded artifact must answer `neighbors`/`edge_exists`
bit-identically to the per-call `Summary.neighbors` / decompressed rows."""
import numpy as np
import pytest

from repro.core import summarize
from repro.core.query_batch import (BACKENDS, edge_exists_batch,
                                    neighbors_batch, unpack_csr)
from repro.core.summary_ir import (PackedSummary, pack_for_serving,
                                   pack_sign_bits, unpack_sign_bits)
from repro.graphs import generators as GG
from repro.graphs.csr import Graph
from repro.launch.serve import RequestError
from repro.launch.summary_serve import SummaryQueryServer, make_queries


def _graphs():
    return [
        ("er", GG.erdos_renyi(120, 0.05, seed=21)),
        ("caveman", GG.caveman(12, 6, 0.05, seed=23)),
        ("star", GG.star_of_cliques(16, 5, seed=25)),
    ]


def _all_neighbors_match(s, ps, backend):
    vs = np.arange(s.n_leaves, dtype=np.int64)
    want = [s.neighbors(int(v)) for v in vs]
    indptr, ids = neighbors_batch(ps, vs, backend=backend)
    got = unpack_csr(indptr, ids)
    assert ids.dtype == np.int64
    for v in range(s.n_leaves):
        assert np.array_equal(got[v], want[v]), (backend, v)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name,g", _graphs(), ids=lambda v: v if isinstance(v, str) else "")
def test_neighbors_batch_matches_per_call(name, g, backend):
    for steps in [(), (1, 2, 3)]:
        s = summarize(g, T=5, seed=7, prune_steps=steps)
        _all_neighbors_match(s, s.pack_for_serving(), backend)


@pytest.mark.parametrize("backend", BACKENDS)
def test_edge_exists_matches_decompress_rows(backend):
    rng = np.random.default_rng(5)
    for name, g in _graphs():
        s = summarize(g, T=5, seed=9)
        ps = s.pack_for_serving()
        dec = s.decompress()
        us = rng.integers(0, g.n, size=120)
        vs = rng.integers(0, g.n, size=120)
        want = np.array([dec.has_edge(int(u), int(v)) for u, v in zip(us, vs)])
        got = edge_exists_batch(ps, us, vs, backend=backend)
        assert got.dtype == bool
        assert np.array_equal(got, want), (name, backend)


def test_packed_roundtrip_bit_identical(tmp_path):
    """save/load must preserve every serialized array AND every answer."""
    g = GG.caveman(10, 6, 0.05, seed=3)
    s = summarize(g, T=5, seed=3)
    ps = pack_for_serving(s)
    path = str(tmp_path / "packed.npz")
    ps.save(path)
    ps2 = PackedSummary.load(path)
    for f in ("parent", "first", "last", "order", "inc_ptr", "inc_eid",
              "edge_x", "edge_y", "sign_bits", "pos_of", "inc_lo", "inc_hi",
              "inc_sign"):
        assert np.array_equal(getattr(ps, f), getattr(ps2, f)), f
    assert (ps.n_leaves, ps.n_ids, ps.max_depth) == (
        ps2.n_leaves, ps2.n_ids, ps2.max_depth)
    for backend in BACKENDS:
        _all_neighbors_match(s, ps2, backend)
    # suffix-less path: savez appends ".npz"; load must find the same file
    p = ps.save(str(tmp_path / "noext"))
    assert p.endswith("noext.npz")
    assert PackedSummary.load(str(tmp_path / "noext")).n_edges == ps.n_edges


def test_sign_bit_packing_roundtrip():
    rng = np.random.default_rng(0)
    for k in (0, 1, 31, 32, 33, 100):
        sign = rng.choice([-1, 1], size=k)
        assert np.array_equal(unpack_sign_bits(pack_sign_bits(sign), k), sign)


def test_query_batch_random_graphs():
    rng = np.random.default_rng(11)
    for trial in range(8):
        n = int(rng.integers(2, 32))
        e = rng.integers(0, n, size=(max(int(n * n * rng.random() * 0.5), 1), 2))
        g = Graph.from_edges(n, e)
        s = summarize(g, T=4, seed=trial)
        ps = s.pack_for_serving()
        for backend in BACKENDS:
            _all_neighbors_match(s, ps, backend)


def test_query_batch_edgeless_and_singleton():
    # no edges: every answer is empty; n=1: the only query answers empty
    for g in (Graph.from_edges(5, np.zeros((0, 2), dtype=np.int64)),
              Graph.from_edges(1, np.zeros((0, 2), dtype=np.int64))):
        s = summarize(g, T=2, seed=0)
        ps = s.pack_for_serving()
        for backend in BACKENDS:
            indptr, ids = neighbors_batch(ps, np.arange(g.n), backend=backend)
            assert ids.size == 0 and indptr[-1] == 0
            assert not edge_exists_batch(ps, np.zeros(3, dtype=np.int64),
                                         np.zeros(3, dtype=np.int64),
                                         backend=backend).any()


def test_unknown_backend_rejected():
    g = GG.caveman(4, 4, 0.0, seed=0)
    ps = summarize(g, T=2, seed=0).pack_for_serving()
    with pytest.raises(ValueError):
        neighbors_batch(ps, np.array([0]), backend="cuda")
    with pytest.raises(ValueError):
        SummaryQueryServer(ps, backend="cuda")


def test_interval_expand_kernel_matches_numpy():
    from repro.kernels.interval_expand.ops import batch_interval_counts
    rng = np.random.default_rng(2)
    for B, E, P in [(1, 1, 1), (3, 17, 9), (8, 130, 257)]:
        lo = rng.integers(0, 50, size=(B, E)).astype(np.int32)
        hi = lo + rng.integers(0, 20, size=(B, E)).astype(np.int32)
        sg = rng.choice([-1, 0, 1], size=(B, E)).astype(np.int32)
        pos = rng.integers(-1, 70, size=(B, P)).astype(np.int32)
        want = batch_interval_counts(lo, hi, sg, pos, backend="numpy")
        got = batch_interval_counts(lo, hi, sg, pos, backend="pallas")
        assert np.array_equal(got, want), (B, E, P)


def test_query_server_mixed_queries_in_order():
    g = GG.caveman(12, 6, 0.05, seed=1)
    s = summarize(g, T=5, seed=1)
    ps = s.pack_for_serving()
    queries = make_queries(g.n, 101, edge_frac=0.4, seed=4)  # 101 % slots != 0
    for backend in ("numpy", "jax"):
        server = SummaryQueryServer(ps, batch_slots=16, backend=backend)
        answers = server.run(queries)
        assert len(answers) == len(queries)
        for q, a in zip(queries, answers):
            if q[0] == "neighbors":
                assert np.array_equal(a, s.neighbors(q[1])), q
            else:
                assert a == bool(np.isin(q[2], s.neighbors(q[1]))), q
    assert SummaryQueryServer(ps).run([]) == []


def test_query_server_malformed_queries_get_error_records():
    """A bad query must not poison the drain loop (ISSUE 10): it comes
    back as a `RequestError` in its slot and every other query is still
    answered."""
    g = GG.caveman(12, 6, 0.05, seed=1)
    s = summarize(g, T=5, seed=1)
    ps = s.pack_for_serving()
    bad = [("bfs", 0),                      # unknown kind
           ("neighbors", 1, 2),             # wrong arity
           ("neighbors", ps.n_leaves + 5),  # out of range
           ("edge", 0, "x"),                # non-integer id
           "neighbors",                     # not a tuple at all
           ("edge", 0, -1)]                 # negative id
    good = ("neighbors", 0)
    queries = bad[:3] + [good] + bad[3:]
    server = SummaryQueryServer(ps, batch_slots=4)
    answers = server.run(queries)
    assert len(answers) == len(queries)
    for q, a in zip(queries, answers):
        if q == good:
            assert np.array_equal(a, s.neighbors(0))
        else:
            assert isinstance(a, RequestError)
            assert a.request == q and a.reason
    # the error reasons are actionable, not generic
    assert "unknown query kind" in answers[0].reason
    assert "out of range" in answers[2].reason


def test_query_server_timeout_flushes_partial_results():
    g = GG.caveman(12, 6, 0.05, seed=1)
    s = summarize(g, T=5, seed=1)
    ps = s.pack_for_serving()
    queries = [("neighbors", int(v) % g.n) for v in range(40)]
    server = SummaryQueryServer(ps, batch_slots=8)
    # deadline already expired: the FIRST batch still runs (no starvation),
    # later batches are cut off and marked with timeout records
    answers = server.run(queries, timeout=0.0)
    assert not any(isinstance(a, RequestError) for a in answers[:8])
    assert all(isinstance(a, RequestError) for a in answers[8:])
    assert "timed out" in answers[-1].reason
    for q, a in zip(queries[:8], answers[:8]):
        assert np.array_equal(a, s.neighbors(q[1]))
    # a generous deadline answers everything
    answers = server.run(queries, timeout=60.0)
    assert not any(isinstance(a, RequestError) for a in answers)


def test_query_batch_property_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(n=st.integers(min_value=1, max_value=20),
           density=st.floats(min_value=0.0, max_value=0.7),
           seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
    def inner(n, density, seed):
        rng = np.random.default_rng(seed)
        k = int(n * n * density)
        e = (rng.integers(0, n, size=(k, 2)) if k
             else np.zeros((0, 2), dtype=np.int64))
        g = Graph.from_edges(n, e)
        s = summarize(g, T=3, seed=seed % 89)
        ps = s.pack_for_serving()
        dec = s.decompress()
        us = rng.integers(0, n, size=2 * n)
        ws = rng.integers(0, n, size=2 * n)
        for backend in BACKENDS:
            _all_neighbors_match(s, ps, backend)
            got = edge_exists_batch(ps, us, ws, backend=backend)
            want = np.array([dec.has_edge(int(u), int(w))
                             for u, w in zip(us, ws)])
            assert np.array_equal(got, want), backend

    inner()
