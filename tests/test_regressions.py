"""Regression tests for the ISSUE 3 latent-bug sweep in the merge/emission
path: recursion-limit leaking, min-hash sentinel collisions, and fixed-slot
padding of short/empty serving chunks."""
import sys

import numpy as np
import pytest

from repro.core import minhash
from repro.core.slugger import SluggerState, _emit_encoding_reference
from repro.graphs import generators as GG
from repro.launch.serve import pad_to_slots


# ---------------------------------------------------------------- slugger
def test_recursionlimit_restored_on_emission_error(monkeypatch):
    """An exception inside the recursive-DP emission must not leak the
    inflated recursion limit into the caller's process (try/finally)."""
    g = GG.caveman(6, 5, 0.05, seed=2)
    state = SluggerState(g)
    state.merge(0, 1)  # nonzero height so the limit is actually raised

    def boom(*a, **k):
        assert sys.getrecursionlimit() >= 2000  # the raise DID happen
        raise RuntimeError("mid-emission failure")

    monkeypatch.setattr("repro.core.slugger.encode_dp.TreeView", boom)
    before = sys.getrecursionlimit()
    with pytest.raises(RuntimeError, match="mid-emission"):
        _emit_encoding_reference(state)
    assert sys.getrecursionlimit() == before


# ---------------------------------------------------------------- minhash
def test_root_shingles_sentinel_outside_hash_range():
    """Leafless ids must get sentinels disjoint from [0, _P) — a root's own
    id is a valid hash value and can collide with another root's genuine
    shingle."""
    g = GG.caveman(3, 4, 0.0, seed=0)
    root_of = np.full(g.n, 13, dtype=np.int64)  # all leaves under root 13
    sh = minhash.root_shingles(g, root_of, seed=0, n_ids=20)
    missing = np.setdiff1d(np.arange(20), [13])
    assert sh[13] < minhash._P  # genuine shingle stays a hash value
    assert (sh[missing] >= minhash._P).all()  # sentinels can't collide with it
    assert np.unique(sh[missing]).size == missing.size  # nor with each other


def test_leafless_root_not_grouped_by_id_collision(monkeypatch):
    """Regression: with node_level_min forced so that root 5's shingle equals
    leafless root 7's id, the old ``out[missing] = missing`` sentinel put 5
    and 7 in one candidate group; the offset sentinel must not."""
    g = GG.caveman(2, 2, 0.0, seed=0)  # 4 leaves
    monkeypatch.setattr(minhash, "node_level_min",
                        lambda g_, seed: np.array([7, 7, 3, 3], dtype=np.int64))
    root_of = np.array([5, 5, 6, 6], dtype=np.int64)
    alive = np.array([5, 6, 7], dtype=np.int64)  # 7 is alive but leafless
    sh = minhash.root_shingles(g, root_of, seed=0, n_ids=8)
    assert sh[5] == 7 and sh[7] != 7  # the collision the old sentinel had
    groups = minhash.candidate_groups(g, root_of, alive, seed=0)
    assert all(7 not in grp for grp in groups)


# ---------------------------------------------------------------- serving
def test_pad_to_slots():
    assert pad_to_slots([1, 2], 4) == [1, 2, 2, 2]
    assert pad_to_slots([1, 2, 3], 3) == [1, 2, 3]
    with pytest.raises(ValueError):
        pad_to_slots([], 4)


def test_batch_server_empty_prompt_list():
    """BatchServer.run([]) used to crash on chunk[-1]; it must return []."""
    from repro.configs.registry import get_config
    from repro.launch.serve import BatchServer

    cfg = get_config("qwen2.5-3b", smoke=True)
    server = BatchServer(cfg, params=None)  # params untouched for 0 requests
    assert server.run([]) == []


def test_batch_server_all_malformed_prompts_never_decode():
    """Every malformed prompt gets a `RequestError` record; with nothing
    valid queued the model is never touched (params=None stays safe)."""
    from repro.configs.registry import get_config
    from repro.launch.serve import BatchServer, RequestError

    cfg = get_config("qwen2.5-3b", smoke=True)
    server = BatchServer(cfg, params=None)
    prompts = [np.zeros((2, 3), dtype=np.int64),          # wrong rank
               np.zeros(0, dtype=np.int64),               # empty
               np.array([0.5, 1.5]),                      # float dtype
               np.array([0, cfg.vocab], dtype=np.int64)]  # out of vocab
    out = server.run(prompts)
    assert len(out) == len(prompts)
    assert all(isinstance(o, RequestError) for o in out)
    assert "non-empty 1-D" in out[0].reason
    assert "not integer" in out[2].reason
    assert "out of range" in out[3].reason


def test_batch_server_mixed_malformed_and_timeout():
    """A bad prompt must not poison the batch (ISSUE 10): the valid ones
    still decode, in submission order; an expired deadline still runs the
    FIRST batch and marks the cut-off slots with timeout records."""
    import jax
    from repro.configs.registry import get_config
    from repro.launch.serve import BatchServer, RequestError
    from repro.models.api import get_api

    cfg = get_config("qwen2.5-3b", smoke=True)
    params = get_api(cfg).init_params(cfg, jax.random.key(0))
    server = BatchServer(cfg, params, batch_slots=2)
    rng = np.random.default_rng(1)
    good = [rng.integers(0, cfg.vocab, size=5) for _ in range(3)]
    prompts = [good[0], np.zeros((2, 2), dtype=np.int64), good[1], good[2]]
    out = server.run(prompts, gen_tokens=2)
    assert isinstance(out[1], RequestError)
    want = server.run(good, gen_tokens=2)
    for o, w in zip([out[0], out[2], out[3]], want):
        assert np.array_equal(o, w)
    # timeout: 3 valid prompts / 2 slots = 2 batches; an already-expired
    # deadline lets only the first run
    out = server.run(good, gen_tokens=2, timeout=0.0)
    assert np.array_equal(out[0], want[0]) and np.array_equal(out[1], want[1])
    assert isinstance(out[2], RequestError) and "timed out" in out[2].reason


# ------------------------------------------------- ISSUE 8 linter-found
def test_token_stream_seed_step_streams_do_not_alias():
    """The old `(seed << 20) ^ step` derivation collided whenever step
    spilled past 20 bits: (seed=0, step=1<<20) aliased (seed=1, step=0).
    SeedSequence entropy tuples keep every (seed, step) stream distinct."""
    from repro.data.pipeline import TokenStream

    a = TokenStream(vocab=97, batch=2, seq=32, seed=0).batch_np(1 << 20)
    b = TokenStream(vocab=97, batch=2, seq=32, seed=1).batch_np(0)
    assert not np.array_equal(a, b)
    # still pure in (seed, step)
    a2 = TokenStream(vocab=97, batch=2, seq=32, seed=0).batch_np(1 << 20)
    assert np.array_equal(a, a2)


def test_kernel_jit_caches_are_bounded():
    """seghist/interval_expand shipped unbounded module-level dict caches;
    every shape-keyed executable cache must be an LruCache."""
    from repro.kernels.common import LruCache
    from repro.kernels.interval_expand import ops as ie_ops
    from repro.kernels.seghist import ops as sh_ops

    assert isinstance(sh_ops._JIT_CACHE, LruCache)
    assert isinstance(ie_ops._JIT_CACHE, LruCache)
