"""SummarizerEngine: partitioned-vs-monolithic bit-equivalence + driver
edge cases (ISSUE 4).

The engine's hard guarantee: for a fixed seed, ``partitions=k`` produces
BIT-IDENTICAL canonical summary edges and parent arrays to ``summarize()``
(the ``partitions=1`` driver) on every merge backend, for any worker-thread
schedule, and with the partition-aware emission/pruning paths engaged.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import summarize
from repro.core.engine import STAGE_ORDER, SummarizerEngine
from repro.core.minhash import shingle_seed_streams
from repro.core.pruning import prune
from repro.graphs import Graph, PartitionedGraph, block_owner
from repro.graphs import generators as GG

BACKENDS = ("numpy", "batched", "loop", "resident")


def _graphs():
    return [
        ("caveman", GG.caveman(14, 6, 0.05, seed=13)),
        ("ba", GG.barabasi_albert(150, 3, seed=12)),
        ("hier", GG.planted_hierarchy((3, 3), 6, (0.02, 0.3, 0.95), seed=1)),
    ]


def _assert_same(sa, sb, msg=""):
    assert np.array_equal(sa.parent, sb.parent), msg
    assert np.array_equal(sa.edges, sb.edges), msg


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name,g", _graphs(), ids=lambda v: v if isinstance(v, str) else "")
def test_partitioned_bit_equivalence(name, g, backend):
    mono = summarize(g, T=6, seed=3, backend=backend)
    assert mono.validate_lossless(g)
    for k in (2, 4):
        part = SummarizerEngine(partitions=k, backend=backend, T=6,
                                seed=3).run(g)
        _assert_same(mono, part, (name, backend, k))


def test_thread_schedule_invariance():
    g = GG.caveman(20, 6, 0.05, seed=7)
    runs = [SummarizerEngine(partitions=4, T=5, seed=1, workers=w).run(g)
            for w in (1, 2, 4)]
    for s in runs[1:]:
        _assert_same(runs[0], s)


def test_summarize_partitions_kwarg():
    g = GG.caveman(10, 5, 0.05, seed=2)
    _assert_same(summarize(g, T=4, seed=5),
                 summarize(g, T=4, seed=5, partitions=3))


def test_accepts_prepartitioned_graph():
    g = GG.caveman(12, 5, 0.05, seed=4)
    pg = PartitionedGraph.from_graph(g, 3)
    s = SummarizerEngine(partitions=3, T=4, seed=0).run(pg)
    _assert_same(s, summarize(g, T=4, seed=0))


# -- driver edge cases -------------------------------------------------------
@pytest.mark.parametrize("backend", BACKENDS)
def test_t1_theta_jumps_to_zero(backend):
    """T=1: the only iteration runs at θ=0 straight away."""
    g = GG.caveman(8, 5, 0.0, seed=1)
    s = summarize(g, T=1, seed=0, backend=backend)
    assert s.validate_lossless(g)
    s2 = SummarizerEngine(partitions=2, backend=backend, T=1, seed=0).run(g)
    _assert_same(s, s2)


@pytest.mark.parametrize("backend", BACKENDS)
def test_empty_graph(backend):
    g = Graph.from_edges(0, np.zeros((0, 2)))
    s = summarize(g, T=3, seed=0, backend=backend)
    assert s.n_leaves == 0 and s.edges.shape == (0, 3)
    assert s.validate_lossless(g)
    _assert_same(s, SummarizerEngine(partitions=2, backend=backend,
                                     T=3, seed=0).run(g))


@pytest.mark.parametrize("backend", BACKENDS)
def test_edgeless_graph(backend):
    g = Graph.from_edges(7, np.zeros((0, 2)))
    s = summarize(g, T=2, seed=0, backend=backend)
    assert s.validate_lossless(g)
    assert s.cost() == 0


@pytest.mark.parametrize("backend", BACKENDS)
def test_single_group_spans_whole_partition(backend):
    """One clique = one candidate group; with 2 partitions the group covers
    partition 0 entirely and the replay must still be bit-stable."""
    clique = Graph.from_edges(
        12, np.array([(u, v) for u in range(12) for v in range(u + 1, 12)]))
    mono = summarize(clique, T=4, seed=2, backend=backend, max_group=500)
    assert mono.validate_lossless(clique)
    part = SummarizerEngine(partitions=2, backend=backend, T=4, seed=2,
                            max_group=500).run(clique)
    _assert_same(mono, part, backend)


# -- partition-aware post-merge stages --------------------------------------
def test_prune_partition_map_bit_identical():
    g = GG.planted_hierarchy((3, 3), 6, (0.02, 0.3, 0.95), seed=2)
    raw = summarize(g, T=6, seed=1, prune_steps=())
    owner = block_owner(g.n, 3)
    a = prune(raw, steps=(1, 2, 3))
    b = prune(raw, steps=(1, 2, 3), partition_map=owner)
    assert np.array_equal(a.parent, b.parent)
    assert np.array_equal(a.edges, b.edges)


def test_seed_iteration_streams_do_not_collide():
    """Regression for the old ``seed * 7919 + t`` keying: (0, t=7919) and
    (1, t=0) used to draw identical shingle seeds."""
    s0 = np.random.SeedSequence(0).spawn(7920)[7919]
    s1 = np.random.SeedSequence(1).spawn(1)[0]
    seeds0, _ = shingle_seed_streams(s0, 2)
    seeds1, _ = shingle_seed_streams(s1, 2)
    assert seeds0 != seeds1


def test_stage_override_hook():
    """Stages are pluggable: wrap the exchange stage and count its calls."""
    calls = []

    def counting_exchange(engine, ctx):
        calls.append(ctx.t)
        SummarizerEngine.stage_exchange(engine, ctx)

    g = GG.caveman(8, 5, 0.05, seed=3)
    eng = SummarizerEngine(T=4, seed=0, stages={"exchange": counting_exchange})
    s = eng.run(g)
    assert calls == [1, 2, 3, 4]
    _assert_same(s, summarize(g, T=4, seed=0))


def test_verbose_logging_not_sticky(capsys):
    import logging
    g = GG.caveman(4, 4, 0.0, seed=0)
    logger = logging.getLogger("repro.engine")
    before = (logger.level, list(logger.handlers))
    summarize(g, T=2, seed=0, verbose=True)
    assert (logger.level, logger.handlers) == before
    summarize(g, T=2, seed=0, verbose=False)
    assert capsys.readouterr().err == ""  # silent again after verbose run


def test_unknown_stage_rejected():
    with pytest.raises(ValueError):
        SummarizerEngine(stages={"nope": lambda e, c: None})
    with pytest.raises(ValueError):
        SummarizerEngine(backend="nope")
    with pytest.raises(ValueError):
        SummarizerEngine(partitions=0)
    assert STAGE_ORDER == ("shingle", "group", "pack", "merge_round",
                           "exchange")


# -- property test (hypothesis-optional) -------------------------------------
def test_random_graphs_partition_equivalence():
    rng = np.random.default_rng(11)
    for trial in range(6):
        n = int(rng.integers(2, 40))
        e = rng.integers(0, n, size=(max(int(n * 2), 1), 2))
        g = Graph.from_edges(n, e)
        for backend in BACKENDS:
            mono = summarize(g, T=3, seed=trial, backend=backend)
            assert mono.validate_lossless(g), (trial, backend)
            part = SummarizerEngine(partitions=int(rng.integers(2, 5)),
                                    backend=backend, T=3, seed=trial).run(g)
            _assert_same(mono, part, (trial, backend))


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(2, 28), m=st.integers(0, 80),
           seed=st.integers(0, 5), k=st.integers(1, 5))
    def test_hypothesis_partition_equivalence(n, m, seed, k):
        rng = np.random.default_rng(seed * 1009 + n)
        g = Graph.from_edges(n, rng.integers(0, n, size=(m, 2)))
        mono = summarize(g, T=3, seed=seed)
        part = SummarizerEngine(partitions=k, T=3, seed=seed).run(g)
        assert mono.validate_lossless(g)
        assert np.array_equal(mono.parent, part.parent)
        assert np.array_equal(mono.edges, part.edges)
except ImportError:  # hypothesis not installed: seeded loop above covers it
    pass


# -- multi-device mesh path ---------------------------------------------------
MESH_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax
    from repro.core.engine import SummarizerEngine
    from repro.launch.mesh import make_host_mesh
    from repro.graphs import generators as GG

    g = GG.caveman(12, 6, 0.05, seed=3)
    mesh = make_host_mesh(data=8)
    runs = [SummarizerEngine(partitions=k, backend="batched", T=4, seed=2,
                             mesh=mesh).run(g) for k in (1, 2, 4)]
    # resident arenas shard over the same mesh; decisions must not move
    runs += [SummarizerEngine(partitions=k, backend="resident", T=4, seed=2,
                              mesh=mesh).run(g) for k in (1, 2)]
    # the unified u32 shingle family makes the single-device engines agree
    # with the 8-device mesh runs bit for bit (ISSUE 7)
    runs += [SummarizerEngine(partitions=1, backend=be, T=4,
                              seed=2).run(g) for be in ("numpy", "resident")]
    assert runs[0].validate_lossless(g)
    for s in runs[1:]:
        assert np.array_equal(runs[0].parent, s.parent)
        assert np.array_equal(runs[0].edges, s.edges)
    print("MESH_OK")
""")


def test_mesh_dispatch_equivalence_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", MESH_EQUIV], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "MESH_OK" in r.stdout, r.stderr[-2000:]
