"""Tests for the JAX distributed engine (single-device semantics + a
multi-device shard_map equivalence run in a subprocess with 8 host devices)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import distributed as D
from repro.core import summarize
from repro.graphs import generators as GG


def test_dense_shingles_match_segment_semantics():
    g = GG.barabasi_albert(100, 3, seed=0)
    src = np.repeat(np.arange(g.n), np.diff(g.indptr))
    got = np.asarray(D.node_shingles_dense(jnp.asarray(src), jnp.asarray(g.indices), g.n, 123457, 99))
    h = np.asarray(D._hash_u32(jnp.arange(g.n, dtype=jnp.uint32), 123457, 99))
    for u in range(g.n):
        grp = np.concatenate([[u], g.neighbors(u)]).astype(np.int64)
        assert got[u] == h[grp].min()


def test_greedy_matching_respects_threshold():
    scores = jnp.asarray(np.array([[[0, 0.9, 0.1], [0.9, 0, 0.2], [0.1, 0.2, 0]]], dtype=np.float32))
    pairs = np.asarray(D.greedy_group_matching(scores, threshold=0.5))
    flat = {tuple(sorted(p)) for p in pairs[0] if p[0] >= 0}
    assert flat == {(0, 1)}


def test_greedy_matching_is_a_matching():
    rng = np.random.default_rng(0)
    s = rng.random((4, 16, 16)).astype(np.float32)
    s = (s + s.transpose(0, 2, 1)) / 2
    pairs = np.asarray(D.greedy_group_matching(jnp.asarray(s), threshold=0.0))
    for gp in pairs:
        used = set()
        for r, c in gp:
            if r < 0:
                continue
            assert r not in used and c not in used
            used.update((int(r), int(c)))


def test_summarize_jax_lossless_and_competitive():
    g = GG.planted_hierarchy((3, 3), 6, (0.02, 0.3, 0.95), seed=1)
    s = D.summarize_jax(g, T=8, seed=0)
    assert s.validate_lossless(g)
    exact = summarize(g, T=8, seed=0)
    # approximate engine stays within 25% of the exact engine's cost
    assert s.cost() <= exact.cost() * 1.25


def test_summarize_step_fn_jits():
    g = GG.barabasi_albert(64, 3, seed=5)
    src = np.repeat(np.arange(g.n), np.diff(g.indptr))
    step = jax.jit(D.summarize_step_fn(g.n))
    sh, counts = step(jnp.asarray(src), jnp.asarray(g.indices),
                      jnp.arange(g.n), jnp.uint32(3))
    assert sh.shape == (g.n,) and counts.shape == (g.n,)


SHARDED_EQUIV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import distributed as D
    from repro.graphs import generators as GG

    g = GG.barabasi_albert(96, 3, seed=7)
    src = np.repeat(np.arange(g.n), np.diff(g.indptr)).astype(np.int32)
    dst = g.indices.astype(np.int32)
    # pad edges to a multiple of 8 shards; padding folds into dummy segment n
    pad = (-len(src)) % 8
    src_p = np.concatenate([src, np.full(pad, g.n, np.int32)])
    dst_p = np.concatenate([dst, np.zeros(pad, np.int32)])
    try:
        mesh = jax.make_mesh((8,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
    except AttributeError:  # older jax has no AxisType (Auto is the default)
        mesh = jax.make_mesh((8,), ("data",))
    fn = D.shingles_sharded(mesh)
    got = np.asarray(fn(jnp.asarray(src_p), jnp.asarray(dst_p), g.n, 123457, 99))
    want = np.asarray(D.node_shingles_dense(jnp.asarray(src), jnp.asarray(dst), g.n, 123457, 99))
    assert (got == want).all(), "sharded shingles != dense shingles"
    print("SHARDED_OK")
""")


def test_shingles_sharded_equivalence_8dev():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SHARDED_EQUIV], capture_output=True,
                       text=True, env=env, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "SHARDED_OK" in r.stdout, r.stderr[-2000:]
