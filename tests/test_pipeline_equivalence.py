"""Post-merge pipeline equivalence (ISSUE 2 acceptance): the batched emitter
and the IR pruner must match their kept references bit for bit on the same
merge forest, for every merge backend; `Summary.neighbors` must agree with
full decompression row by row."""
import numpy as np
import pytest

from repro.core import summarize
from repro.core.encode_batched import encode_forest
from repro.core.merging import process_group, process_groups
from repro.core.minhash import candidate_groups
from repro.core.pruning import prune
from repro.core.slugger import (SluggerState, _emit_encoding,
                                _emit_encoding_reference)
from repro.core.summary_ir import SummaryIR
from repro.graphs import generators as GG
from repro.graphs.csr import Graph

BACKENDS = ("loop", "numpy", "batched")


def _forest(g, backend, T=6, seed=3):
    state = SluggerState(g)
    rng = np.random.default_rng(seed)
    for t in range(1, T + 1):
        theta = 0.0 if t == T else 1.0 / (1 + t)
        groups = candidate_groups(g, state.root_of, state.alive,
                                  seed=seed * 7919 + t, max_group=500)
        if backend == "loop":
            for grp in groups:
                process_group(state, grp, theta, rng)
        else:
            process_groups(state, groups, theta, rng, backend=backend)
    return state


def _graphs():
    return [
        ("er", GG.erdos_renyi(150, 0.04, seed=11)),
        ("caveman", GG.caveman(14, 6, 0.05, seed=13)),
        ("nested", GG.bipartite_nested(32, 31, 5)),
        ("star", GG.star_of_cliques(20, 6, seed=10)),
    ]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name,g", _graphs(), ids=lambda v: v if isinstance(v, str) else "")
def test_batched_emitter_matches_recursive_dp(name, g, backend):
    """Same forest -> bit-identical canonical edge arrays and cost."""
    state = _forest(g, backend)
    ref = _emit_encoding_reference(state)
    new = _emit_encoding(state, backend="numpy")
    assert np.array_equal(ref.edges, new.edges)
    assert ref.cost() == new.cost()
    assert new.decompress() == g


@pytest.mark.parametrize("name,g", _graphs(), ids=lambda v: v if isinstance(v, str) else "")
def test_ir_prune_matches_dict_reference(name, g):
    s = _emit_encoding(_forest(g, "numpy"))
    for steps in [(1,), (1, 2), (1, 2, 3)]:
        a = prune(s, steps=steps, impl="ir")
        b = prune(s, steps=steps, impl="dict")
        assert np.array_equal(a.parent, b.parent), steps
        assert np.array_equal(a.edges, b.edges), steps
        assert a.cost() == b.cost()
        assert a.decompress() == g


def test_equivalence_on_random_graphs():
    rng = np.random.default_rng(7)
    for trial in range(10):
        n = int(rng.integers(2, 36))
        e = rng.integers(0, n, size=(max(int(n * n * rng.random() * 0.5), 1), 2))
        g = Graph.from_edges(n, e)
        state = _forest(g, "numpy", T=4, seed=trial)
        ref = _emit_encoding_reference(state)
        new = _emit_encoding(state)
        assert np.array_equal(ref.edges, new.edges), trial
        a = prune(new, impl="ir")
        b = prune(new, impl="dict")
        assert np.array_equal(a.edges, b.edges), trial
        assert np.array_equal(a.parent, b.parent), trial
        assert a.decompress() == g


def test_emission_pallas_backend_matches_numpy():
    """backend="batched" routes membership counts through the seghist Pallas
    kernel (interpret mode off-TPU) — identical output required."""
    g = GG.caveman(8, 5, 0.05, seed=1)
    state = _forest(g, "numpy", T=4)
    ir = SummaryIR(state.parent[: state.n_ids], g.n)
    el = g.edge_list()
    cost_np, edges_np = encode_forest(ir, el[:, 0], el[:, 1], backend="numpy")
    cost_pl, edges_pl = encode_forest(ir, el[:, 0], el[:, 1], backend="batched")
    assert cost_np == cost_pl
    assert np.array_equal(edges_np, edges_pl)


def test_seghist_kernel_matches_bincount():
    from repro.kernels.seghist.ops import membership_counts
    rng = np.random.default_rng(0)
    for E, S in [(1, 1), (7, 3), (1000, 37), (513, 300)]:
        seg = rng.integers(0, S, size=E).astype(np.int64)
        want = np.bincount(seg, minlength=S)
        assert np.array_equal(membership_counts(seg, S, backend="batched"), want)
        assert np.array_equal(membership_counts(seg, S, backend="numpy"), want)


def test_nonbinary_forest_rejected_by_batched_emitter():
    # 3 leaves under one parent: encode_forest must refuse (the emission
    # wrapper then falls back to the recursive reference)
    parent = np.array([3, 3, 3, -1], dtype=np.int64)
    ir = SummaryIR(parent, 3)
    with pytest.raises(ValueError):
        encode_forest(ir, np.array([0]), np.array([1]))


def test_prune_step3_on_edgeless_summary():
    """Regression: step 3 alone must still splice edge-free supernodes (its
    benefit test accepts them), identically in both implementations."""
    from repro.core.summary import Summary
    s = Summary(n_leaves=2, parent=np.array([2, 2, -1], dtype=np.int64),
                edges=np.zeros((0, 3), dtype=np.int64))
    a = prune(s, steps=(3,), impl="ir")
    b = prune(s, steps=(3,), impl="dict")
    assert np.array_equal(a.parent, b.parent)
    assert np.array_equal(a.parent, np.array([-1, -1, -2]))
    assert a.edges.shape == b.edges.shape == (0, 3)


def test_prune_deterministic_identical_arrays():
    """Satellite: two prune runs on the same summary produce identical edge
    arrays (stable candidate ordering + canonical export, no dict/set
    iteration dependence)."""
    for name, g in _graphs():
        s = _emit_encoding(_forest(g, "numpy"))
        for impl in ("ir", "dict"):
            a = prune(s, impl=impl)
            b = prune(s, impl=impl)
            assert np.array_equal(a.edges, b.edges), (name, impl)
            assert np.array_equal(a.parent, b.parent), (name, impl)


def test_neighbors_equals_decompress_rows():
    """Satellite (Algorithm 4 property): neighbors(v) == v-th row of the
    decompressed graph for every v, before and after pruning."""
    rng = np.random.default_rng(3)
    for trial in range(8):
        n = int(rng.integers(2, 30))
        e = rng.integers(0, n, size=(max(int(n * n * rng.random() * 0.6), 1), 2))
        g = Graph.from_edges(n, e)
        for steps in [(), (1, 2, 3)]:
            s = summarize(g, T=4, seed=trial, prune_steps=steps)
            dec = s.decompress()
            for v in range(n):
                assert np.array_equal(s.neighbors(v), dec.neighbors(v).astype(np.int64)), (
                    trial, steps, v)


def test_neighbors_property_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(min_value=1, max_value=24),
           density=st.floats(min_value=0.0, max_value=0.7),
           seed=st.integers(min_value=0, max_value=2 ** 31 - 1))
    def inner(n, density, seed):
        rng = np.random.default_rng(seed)
        k = int(n * n * density)
        e = (rng.integers(0, n, size=(k, 2)) if k
             else np.zeros((0, 2), dtype=np.int64))
        g = Graph.from_edges(n, e)
        s = summarize(g, T=3, seed=seed % 97)
        dec = s.decompress()
        for v in range(n):
            assert np.array_equal(s.neighbors(v), dec.neighbors(v).astype(np.int64))

    inner()
