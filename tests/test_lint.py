"""Tests for the invariant linter (`repro.analysis`).

Three layers: per-rule fixtures (each rule firing, staying quiet on
conforming code, and honoring a justified suppression), the baseline
machinery (matching, staleness, mandatory justifications), and the
meta-test that holds the WHOLE tree to the gate — the same invocation CI
runs, so tier-1 and the CI lint step can never disagree.
"""
from __future__ import annotations

import json
import os
import textwrap

from repro.analysis.baseline import apply_baseline, load_baseline
from repro.analysis.core import lint_source, lint_paths
from repro.analysis.rules import (ATOMIC_WRITE, DTYPE_WIDTH,
                                  HOST_SYNC_IN_LOOP, INT_RANK_ONLY,
                                  ITER_REUPLOAD, JIT_CACHE_BOUND,
                                  KERNEL_TRIPLE, NO_RECURSION_LIMIT,
                                  NONDET_ITER, RULES, SEED_DISCIPLINE,
                                  TIME_MONOTONIC, rules_by_name)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run(src: str, relpath: str, rule):
    return lint_source(textwrap.dedent(src), relpath, [rule])


def rules_hit(src: str, relpath: str, rule):
    return [f.rule for f in run(src, relpath, rule).findings]


# ---------------------------------------------------------------- registry
def test_registry_has_at_least_eight_rules_with_docs():
    assert len(RULES) >= 8
    names = rules_by_name()
    assert len(names) == len(RULES)  # unique names
    for r in RULES:
        assert r.name and r.summary and r.contract


# ---------------------------------------------------------------- SEED
def test_seed_discipline_fires_on_legacy_np_random():
    src = """
        import numpy as np
        def f():
            return np.random.rand(3)
    """
    assert rules_hit(src, "src/repro/x.py", SEED_DISCIPLINE()) == [
        "SEED-DISCIPLINE"]


def test_seed_discipline_fires_on_seed_arithmetic():
    src = """
        import numpy as np
        def f(seed, t):
            return np.random.default_rng(seed * 7919 + t)
    """
    assert rules_hit(src, "src/repro/x.py", SEED_DISCIPLINE()) == [
        "SEED-DISCIPLINE"]


def test_seed_discipline_fires_on_stdlib_random():
    src = """
        import random
        def f():
            return random.randint(0, 10)
    """
    assert rules_hit(src, "src/repro/x.py", SEED_DISCIPLINE()) == [
        "SEED-DISCIPLINE"]


def test_seed_discipline_quiet_on_seedsequence_flow():
    src = """
        import numpy as np
        def f(seed, t):
            rng = np.random.default_rng(np.random.SeedSequence((seed, t)))
            kids = np.random.SeedSequence(seed).spawn(4)
            return rng, kids
    """
    assert rules_hit(src, "src/repro/x.py", SEED_DISCIPLINE()) == []


def test_seed_discipline_out_of_scope_and_suppressed():
    src = "import numpy as np\nx = np.random.rand(3)\n"
    assert rules_hit(src, "benchmarks/x.py", SEED_DISCIPLINE()) == []
    sup = ("import numpy as np\n"
           "x = np.random.rand(3)  # lint: disable=SEED-DISCIPLINE -- "
           "fixture noise, not a determinism surface\n")
    res = lint_source(sup, "src/repro/x.py", [SEED_DISCIPLINE()])
    assert res.findings == [] and len(res.suppressed) == 1


def test_suppression_without_reason_does_not_suppress():
    src = ("import numpy as np\n"
           "x = np.random.rand(3)  # lint: disable=SEED-DISCIPLINE\n")
    res = lint_source(src, "src/repro/x.py", [SEED_DISCIPLINE()])
    assert len(res.findings) == 1
    assert "justification is mandatory" in res.findings[0].message


# ---------------------------------------------------------------- JIT CACHE
def test_jit_cache_bound_fires_on_bare_dicts():
    src = """
        _JIT_CACHE: dict = {}
        _MESH_CACHE = dict()
    """
    assert rules_hit(src, "src/repro/kernels/x/ops.py",
                     JIT_CACHE_BOUND()) == ["JIT-CACHE-BOUND"] * 2


def test_jit_cache_bound_quiet_on_lru_and_locals():
    src = """
        from repro.kernels.common import LruCache
        _JIT_CACHE = LruCache(16)
        def f():
            local_cache = {}  # function-local: bounded by the call
            return local_cache
    """
    assert rules_hit(src, "src/repro/kernels/x/ops.py",
                     JIT_CACHE_BOUND()) == []


# ---------------------------------------------------------------- INT RANK
def test_int_rank_only_fires_on_division_and_float_compare():
    src = """
        def f(inter, union, s):
            j = inter / union
            return j if s >= 0.5 else None
    """
    assert rules_hit(src, "src/repro/core/merging.py",
                     INT_RANK_ONLY()) == ["INT-RANK-ONLY"] * 2


def test_int_rank_only_quiet_on_integer_ops_and_other_modules():
    src = """
        def f(inter, union):
            return (inter << 15) // max(union, 1)
    """
    assert rules_hit(src, "src/repro/core/merging.py", INT_RANK_ONLY()) == []
    # out of scope: float math in the IR/query modules is fine
    assert rules_hit("x = 1 / 3\n", "src/repro/core/summary_ir.py",
                     INT_RANK_ONLY()) == []


# ---------------------------------------------------------------- NONDET
def test_nondet_iter_fires_on_set_iteration():
    src = """
        def f(xs, d):
            touched = set(xs)
            for w in touched:
                d[w] = True
            return [k for k in d.keys()]
    """
    assert rules_hit(src, "src/repro/core/pruning.py",
                     NONDET_ITER()) == ["NONDET-ITER"] * 2


def test_nondet_iter_fires_on_materialized_set():
    src = """
        def f(xs):
            return list({x + 1 for x in xs})
    """
    assert rules_hit(src, "src/repro/core/pruning.py",
                     NONDET_ITER()) == ["NONDET-ITER"]


def test_nondet_iter_quiet_on_sorted_and_dict_items():
    src = """
        def f(xs, d):
            for w in sorted(set(xs)):
                d[w] = True
            for k, v in d.items():  # dicts iterate in insertion order
                pass
    """
    assert rules_hit(src, "src/repro/core/pruning.py", NONDET_ITER()) == []


# ---------------------------------------------------------------- RECURSION
def test_no_recursion_limit_fires_and_suppresses():
    src = "import sys\nsys.setrecursionlimit(100000)\n"
    assert rules_hit(src, "src/repro/core/x.py", NO_RECURSION_LIMIT()) == [
        "NO-RECURSION-LIMIT"]
    sup = ("import sys\n"
           "# lint: disable=NO-RECURSION-LIMIT -- scoped reference-emitter "
           "bump, restored in finally\n"
           "sys.setrecursionlimit(100000)\n")
    res = lint_source(sup, "src/repro/core/x.py", [NO_RECURSION_LIMIT()])
    assert res.findings == [] and len(res.suppressed) == 1


# ---------------------------------------------------------------- DTYPE
def test_dtype_width_fires_on_wide_device_dtypes():
    src = """
        import jax.numpy as jnp
        import numpy as np
        def f(x):
            a = jnp.asarray(x, dtype=jnp.int64)
            b = jnp.arange(4, dtype=np.int64)
            return a, b
    """
    found = rules_hit(src, "src/repro/kernels/x/ops.py", DTYPE_WIDTH())
    # jnp.int64 attribute + both uploader calls
    assert found.count("DTYPE-WIDTH") >= 3


def test_dtype_width_quiet_on_32bit_and_host_math():
    src = """
        import jax.numpy as jnp
        import numpy as np
        def f(x, idx):
            a = jnp.asarray(x, dtype=jnp.int32)
            hosts = idx.astype(np.int64)  # host-side index math is fine
            return a, hosts
    """
    assert rules_hit(src, "src/repro/kernels/x/ops.py", DTYPE_WIDTH()) == []


# ---------------------------------------------------------------- HOST SYNC
def test_host_sync_in_loop_fires_without_accounting():
    src = """
        import numpy as np
        class A:
            def run(self, rounds):
                for _ in range(rounds):
                    v = np.asarray(self._verdicts)
                    n = v.sum().item()
                return n
    """
    found = rules_hit(src, "src/repro/core/resident.py", HOST_SYNC_IN_LOOP())
    assert found == ["HOST-SYNC-IN-LOOP"] * 2


def test_host_sync_in_loop_quiet_when_accounted():
    src = """
        import numpy as np
        class A:
            def run(self, rounds, counter):
                for _ in range(rounds):
                    v = np.asarray(self._verdicts)
                    counter.add_d2h(v.nbytes)
                return v
    """
    assert rules_hit(src, "src/repro/core/resident.py",
                     HOST_SYNC_IN_LOOP()) == []


def test_host_sync_in_loop_quiet_on_host_array_reshuffle():
    src = """
        import numpy as np
        def pack(groups):
            out = []
            for grp in groups:
                out.append(np.asarray(grp, dtype=np.int64))
            return out
    """
    assert rules_hit(src, "src/repro/core/merging.py",
                     HOST_SYNC_IN_LOOP()) == []


# ---------------------------------------------------------------- REUPLOAD
def test_iter_reupload_fires_on_loop_invariant_upload():
    src = """
        import jax.numpy as jnp
        class A:
            def run(self, bits, iters, counter):
                for t in range(iters):
                    dev = jnp.asarray(bits)   # same tensor every iteration
                    counter.add_h2d(dev.nbytes)
                return dev
    """
    assert rules_hit(src, "src/repro/core/resident.py", ITER_REUPLOAD()) == [
        "ITER-REUPLOAD"]


def test_iter_reupload_fires_on_put_method():
    src = """
        class A:
            def run(self, instr_all, counter):
                while True:
                    dev = self._put(instr_all)
                    counter.add_h2d(dev.nbytes)
    """
    assert rules_hit(src, "src/repro/core/resident.py", ITER_REUPLOAD()) == [
        "ITER-REUPLOAD"]


def test_iter_reupload_quiet_on_per_iteration_slabs():
    src = """
        import numpy as np
        import jax.numpy as jnp
        class A:
            def run(self, batches, counter):
                for batch in batches:
                    slab = np.zeros((8, 64), dtype=np.int32)
                    slab[0] = batch
                    dev = jnp.asarray(slab)   # built fresh in the loop body
                    counter.add_h2d(slab.nbytes)
                # uploads outside any loop are one-time by construction
                final = jnp.asarray(batches)
                return dev, final
    """
    assert rules_hit(src, "src/repro/core/resident.py", ITER_REUPLOAD()) == []


def test_iter_reupload_out_of_scope_and_suppressed():
    src = """
        import jax.numpy as jnp
        def f(bits, iters):
            for _ in range(iters):
                dev = jnp.asarray(bits)
            return dev
    """
    assert rules_hit(src, "src/repro/core/merging.py", ITER_REUPLOAD()) == []
    sup = ("import jax.numpy as jnp\n"
           "def f(bits, iters):\n"
           "    for _ in range(iters):\n"
           "        dev = jnp.asarray(bits)  # lint: disable=ITER-REUPLOAD "
           "-- convergence probe re-reads a host-mutated buffer\n"
           "    return dev\n")
    res = lint_source(sup, "src/repro/core/resident.py", [ITER_REUPLOAD()])
    assert res.findings == [] and len(res.suppressed) == 1


# ---------------------------------------------------------------- TRIPLE
def test_kernel_triple_fires_on_missing_leg_and_missing_test(tmp_path):
    kdir = tmp_path / "src" / "repro" / "kernels"
    good = kdir / "goodk"
    bad = kdir / "badk"
    good.mkdir(parents=True)
    bad.mkdir(parents=True)
    for leg in ("kernel.py", "ops.py", "ref.py"):
        (good / leg).write_text("x = 1\n")
    (bad / "kernel.py").write_text("x = 1\n")
    (bad / "ops.py").write_text("x = 1\n")  # ref.py missing
    tdir = tmp_path / "tests"
    tdir.mkdir()
    (tdir / "test_k.py").write_text(
        "from repro.kernels.goodk import ops\n")
    res = lint_paths(str(tmp_path), ["src"], [KERNEL_TRIPLE()])
    msgs = sorted((f.snippet, f.message) for f in res.findings)
    assert len(msgs) == 2  # badk: missing ref.py + unreferenced
    assert all(s == "badk" for s, _ in msgs)
    assert any("ref.py" in m for _, m in msgs)
    assert any("not referenced" in m for _, m in msgs)


# ---------------------------------------------------------------- TIME
def test_time_monotonic_fires_in_scope_only():
    src = "import time\nt0 = time.time()\n"
    assert rules_hit(src, "benchmarks/run.py", TIME_MONOTONIC()) == [
        "TIME-MONOTONIC"]
    assert rules_hit(src, "src/repro/launch/x.py", TIME_MONOTONIC()) == [
        "TIME-MONOTONIC"]
    assert rules_hit(src, "src/repro/core/x.py", TIME_MONOTONIC()) == []
    ok = "import time\nt0 = time.perf_counter()\n"
    assert rules_hit(ok, "benchmarks/run.py", TIME_MONOTONIC()) == []


# ---------------------------------------------------------------- baseline
def _finding(src: str, relpath: str, rule):
    res = lint_source(textwrap.dedent(src), relpath, [rule])
    assert len(res.findings) == 1
    return res.findings[0]


def test_baseline_matches_on_symbol_and_snippet_not_line():
    f = _finding("""
        import time
        def main():
            t0 = time.time()
    """, "benchmarks/run.py", TIME_MONOTONIC())
    entry = {"rule": f.rule, "path": f.path, "symbol": f.symbol,
             "snippet": f.snippet, "justification": "fixture"}
    m = apply_baseline([f], [entry])
    assert m.new == [] and len(m.matched) == 1 and m.stale == []


def test_baseline_entry_without_justification_rejected():
    f = _finding("""
        import time
        def main():
            t0 = time.time()
    """, "benchmarks/run.py", TIME_MONOTONIC())
    entry = {"rule": f.rule, "path": f.path, "symbol": f.symbol,
             "snippet": f.snippet, "justification": "  "}
    m = apply_baseline([f], [entry])
    assert len(m.unjustified) == 1 and len(m.new) == 1  # no silent pass


def test_baseline_stale_entry_detected():
    entry = {"rule": "TIME-MONOTONIC", "path": "benchmarks/gone.py",
             "symbol": "main", "snippet": "t0 = time.time()",
             "justification": "code this excused was deleted"}
    m = apply_baseline([], [entry])
    assert len(m.stale) == 1


def test_baseline_is_a_multiset():
    f = _finding("""
        import time
        def main():
            t0 = time.time()
    """, "benchmarks/run.py", TIME_MONOTONIC())
    entry = {"rule": f.rule, "path": f.path, "symbol": f.symbol,
             "snippet": f.snippet, "justification": "fixture"}
    m = apply_baseline([f, f], [entry])  # one entry cannot cover two hits
    assert len(m.matched) == 1 and len(m.new) == 1


# ---------------------------------------------------------------- ATOMIC
def test_atomic_write_fires_on_in_place_artifact_write():
    src = """
        import json, os
        def save(payload, ckpt_dir):
            with open(os.path.join(ckpt_dir, "manifest.json"), "w") as f:
                json.dump(payload, f)
    """
    assert rules_hit(src, "src/repro/x.py", ATOMIC_WRITE()) == [
        "ATOMIC-WRITE"]


def test_atomic_write_fires_on_np_save_to_spill():
    src = """
        import numpy as np
        def cut(sel, spill_dir):
            np.save(spill_dir + "/run-0.npy", sel)
    """
    assert rules_hit(src, "src/repro/x.py", ATOMIC_WRITE()) == [
        "ATOMIC-WRITE"]


def test_atomic_write_quiet_with_replace_commit():
    src = """
        import json, os
        def save(payload, ckpt_dir):
            path = os.path.join(ckpt_dir, "manifest.json")
            tmp = path + ".part"
            with open(tmp, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, path)
    """
    assert rules_hit(src, "src/repro/x.py", ATOMIC_WRITE()) == []


def test_atomic_write_quiet_on_reads_and_ephemeral_paths():
    src = """
        import json
        def load(ckpt_path, scratch):
            with open(ckpt_path) as f:          # read: fine
                payload = json.load(f)
            with open(scratch + "/log.txt", "w") as f:  # not durable
                f.write("hi")
            return payload
    """
    assert rules_hit(src, "src/repro/x.py", ATOMIC_WRITE()) == []


def test_atomic_write_scope_is_per_function():
    # a commit in ANOTHER function does not quiet this one
    src = """
        import json, os
        def committer(tmp, path):
            os.replace(tmp, path)
        def save(payload, ckpt_dir):
            with open(ckpt_dir + "/manifest.json", "w") as f:
                json.dump(payload, f)
    """
    assert rules_hit(src, "src/repro/x.py", ATOMIC_WRITE()) == [
        "ATOMIC-WRITE"]


def test_atomic_write_suppressed_with_reason():
    src = ("import json\n"
           "def save(payload, cache_dir):\n"
           "    f = open(cache_dir + '/x.json', 'w')  "
           "# lint: disable=ATOMIC-WRITE -- "
           "append-only debug log, torn tail is acceptable\n"
           "    json.dump(payload, f)\n")
    res = lint_source(src, "src/repro/x.py", [ATOMIC_WRITE()])
    assert res.findings == [] and len(res.suppressed) == 1


# ---------------------------------------------------------------- meta
def test_full_tree_is_lint_clean():
    """The CI gate, as a tier-1 test: no new findings, no stale or
    unjustified baseline entries, anywhere under src/tests/benchmarks."""
    result = lint_paths(REPO, ["src", "tests", "benchmarks"], RULES)
    assert result.errors == []
    match = apply_baseline(result.findings, load_baseline())
    assert [f.render() for f in match.new] == []
    assert match.stale == []
    assert match.unjustified == []
    # every suppression carried a reason (core enforces it; double-check)
    assert all(reason.strip() for _, reason in result.suppressed)


def test_checked_in_baseline_entries_are_justified():
    entries = load_baseline()
    assert entries, "baseline exists and documents the intentional exemptions"
    for e in entries:
        assert len(e.get("justification", "").strip()) > 20


def test_cli_stats_report(tmp_path):
    from repro.analysis.lint import main

    out = tmp_path / "report.json"
    code = main(["src", "tests", "benchmarks", "--root", REPO,
                 "--stats", "--stats-out", str(out)])
    assert code == 0
    stats = json.loads(out.read_text())
    assert stats["rules_active"] >= 8
    assert stats["new_findings"] == 0
    assert stats["files_scanned"] > 100
    assert set(stats["per_rule"]) == {r.name for r in RULES}
