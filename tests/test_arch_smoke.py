"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, asserting shapes and finiteness (assignment f)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import SHAPES, applicable_shapes
from repro.configs.registry import ARCH_NAMES, get_config
from repro.models.api import get_api, input_specs, lm_loss


def make_batch(cfg, B=2, S=16, seed=0):
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(B, S + 1)), jnp.int32)
    if cfg.encoder_layers:
        return {"frames": jnp.asarray(rng.normal(size=(B, S, cfg.d_model)), jnp.bfloat16), "tokens": toks}
    if cfg.n_patches:
        return {"embeds": jnp.asarray(rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.bfloat16), "tokens": toks}
    return {"tokens": toks}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finiteness(name):
    cfg = get_config(name, smoke=True)
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.key(0))
    batch = make_batch(cfg)
    inputs = dict(batch)
    inputs["tokens"] = batch["tokens"][:, :-1]
    logits, aux = api.forward(params, cfg, inputs)
    s_expect = 16 + (cfg.n_patches if (cfg.n_patches and not cfg.encoder_layers) else 0)
    assert logits.shape == (2, s_expect, cfg.vocab)
    assert np.isfinite(np.asarray(logits, dtype=np.float32)).all()


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_reduces_loss(name):
    cfg = get_config(name, smoke=True)
    api = get_api(cfg)
    params = api.init_params(cfg, jax.random.key(1))
    batch = make_batch(cfg, seed=3)

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(lambda q: lm_loss(q, cfg, batch))(p)
        p2 = jax.tree.map(lambda w, gw: (w.astype(jnp.float32) - 0.5 * gw.astype(jnp.float32)).astype(w.dtype), p, g)
        return loss, p2

    l0, params = step(params)
    assert np.isfinite(float(l0))
    for _ in range(3):
        l1, params = step(params)
    assert np.isfinite(float(l1))
    assert float(l1) < float(l0)  # same batch: loss must drop


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_full_config_exactness(name):
    """The full config matches the assignment row (never instantiated)."""
    cfg = get_config(name)
    rows = {
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "whisper-small": (12, 768, 12, 12, 3072, 51865),
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936),
        "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
        "deepseek-7b": (30, 4096, 32, 32, 11008, 102400),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 1408, 102400),
    }
    L, d, h, kv, ff, v = rows[name]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v)


def test_param_counts_match_published():
    checks = {
        "qwen3-moe-235b-a22b": (235e9, 0.03),
        "deepseek-7b": (6.9e9, 0.1),
        "zamba2-7b": (7.0e9, 0.1),
        "mamba2-130m": (130e6, 0.15),
    }
    for name, (want, tol) in checks.items():
        got = get_config(name).param_count()
        assert abs(got - want) / want < tol, (name, got)
    active = get_config("qwen3-moe-235b-a22b").param_count(active_only=True)
    assert abs(active - 22e9) / 22e9 < 0.05


def test_moe_active_lt_total():
    cfg = get_config("qwen3-moe-235b-a22b")
    assert cfg.param_count(True) < 0.15 * cfg.param_count()


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_input_specs_all_shapes(name):
    cfg = get_config(name, smoke=False)
    app = applicable_shapes(cfg)
    assert len(app) == 4
    for sh_name, status in app.items():
        if status != "run":
            assert sh_name == "long_500k" and not cfg.subquadratic
            continue
        specs = input_specs(cfg, SHAPES[sh_name])
        leaves = jax.tree.leaves(specs)
        assert all(hasattr(l, "shape") for l in leaves)


def test_long_500k_applicability_set():
    runs = [n for n in ARCH_NAMES if applicable_shapes(get_config(n))["long_500k"] == "run"]
    assert set(runs) == {"zamba2-7b", "h2o-danube-1.8b", "mamba2-130m"}
