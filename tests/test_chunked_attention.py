"""chunked_sdpa (flash-style blocked attention) vs the dense _sdpa oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.attention import _causal_mask, _sdpa, chunked_sdpa


def _rand(key, shape):
    return jax.random.normal(key, shape, jnp.float32)


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16), (False, 0)])
@pytest.mark.parametrize("sq,sk,chunk", [(64, 64, 16), (32, 64, 16), (64, 64, 64)])
def test_chunked_matches_dense(causal, window, sq, sk, chunk):
    if causal and sq != sk:
        pytest.skip("causal path assumes self-attention")
    b, hkv, g, hd, vd = 2, 2, 3, 8, 8
    ks = jax.random.split(jax.random.key(0), 3)
    q = _rand(ks[0], (b, sq, hkv, g, hd))
    k = _rand(ks[1], (b, sk, hkv, hd))
    v = _rand(ks[2], (b, sk, hkv, vd))
    mask = _causal_mask(sq, sk, 0, window) if causal else jnp.ones((1, sq, sk), bool)
    ref = _sdpa(q, k, v, mask)
    out = chunked_sdpa(q, k, v, causal=causal, window=window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)


def test_chunked_mixed_head_dims():
    """MLA-style: k head dim != v head dim."""
    b, s, h, hd, vd = 1, 48, 3, 12, 20
    ks = jax.random.split(jax.random.key(1), 3)
    q = _rand(ks[0], (b, s, h, 1, hd))
    k = _rand(ks[1], (b, s, h, hd))
    v = _rand(ks[2], (b, s, h, vd))
    ref = _sdpa(q, k, v, _causal_mask(s, s, 0, 0))
    out = chunked_sdpa(q, k, v, causal=True, chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5, rtol=1e-4)


def test_chunked_grads_match_dense():
    b, s, hkv, g, hd = 1, 32, 1, 2, 8
    ks = jax.random.split(jax.random.key(2), 3)
    q = _rand(ks[0], (b, s, hkv, g, hd))
    k = _rand(ks[1], (b, s, hkv, hd))
    v = _rand(ks[2], (b, s, hkv, hd))

    def f_dense(q, k, v):
        return _sdpa(q, k, v, _causal_mask(s, s, 0, 0)).sum()

    def f_chunk(q, k, v):
        return chunked_sdpa(q, k, v, causal=True, chunk=8).sum()

    gd = jax.grad(f_dense, argnums=(0, 1, 2))(q, k, v)
    gc = jax.grad(f_chunk, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(gd, gc):
        np.testing.assert_allclose(np.asarray(b_), np.asarray(a), atol=1e-4, rtol=1e-3)


def test_static_block_pruning_flops():
    """Causal chunking must not compute upper-triangle blocks: the compiled
    HLO FLOPs of the chunked version stay well under the dense version."""
    from repro.launch.hlo_analysis import analyze_hlo
    b, s, hkv, g, hd, chunk = 1, 512, 1, 1, 16, 64
    q = jax.ShapeDtypeStruct((b, s, hkv, g, hd), jnp.float32)
    k = jax.ShapeDtypeStruct((b, s, hkv, hd), jnp.float32)
    v = jax.ShapeDtypeStruct((b, s, hkv, hd), jnp.float32)

    def dense(q, k, v):
        return _sdpa(q, k, v, _causal_mask(s, s, 0, 0))

    def chunked(q, k, v):
        return chunked_sdpa(q, k, v, causal=True, chunk=chunk)

    f_dense = analyze_hlo(jax.jit(dense).lower(q, k, v).compile().as_text())["flops"]
    f_chunk = analyze_hlo(jax.jit(chunked).lower(q, k, v).compile().as_text())["flops"]
    # lower triangle = (n+1)/2n of the blocks; with n=8 chunks -> 56%
    assert f_chunk < 0.75 * f_dense
