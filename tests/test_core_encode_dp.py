"""Unit tests for the optimal pairwise hierarchical encoding DP."""
import numpy as np

from repro.core import encode_dp
from repro.core.encode_dp import TreeView, encode_pair, encode_self, flat_pair_cost


def two_level_tree(root_gid, children, n_leaves):
    return TreeView(root_gid, children, n_leaves)


def test_complete_bipartite_single_pedge():
    # A = {0,1} (supernode 4), B = {2,3} (supernode 5), complete bipartite
    children = {4: [0, 1], 5: [2, 3]}
    ta, tb = TreeView(4, children, 4), TreeView(5, children, 4)
    pa = np.array([0, 0, 1, 1])
    pb = np.array([0, 1, 0, 1])
    cost, edges = encode_pair(ta, tb, pa, pb)
    assert cost == 1
    assert edges == [(4, 5, 1)]


def test_empty_pair_no_edges():
    children = {4: [0, 1], 5: [2, 3]}
    ta, tb = TreeView(4, children, 4), TreeView(5, children, 4)
    cost, edges = encode_pair(ta, tb, np.zeros(0, int), np.zeros(0, int))
    assert cost == 0 and edges == []


def test_single_edge_lands_on_leaves():
    """Tie-break prefers descending: one edge is encoded at leaf level so the
    internal nodes stay edge-free and prunable."""
    children = {4: [0, 1], 5: [2, 3]}
    ta, tb = TreeView(4, children, 4), TreeView(5, children, 4)
    cost, edges = encode_pair(ta, tb, np.array([0]), np.array([1]))
    assert cost == 1
    assert edges == [(0, 3, 1)]


def test_almost_complete_uses_negative_correction():
    # complete bipartite 3x3 minus one edge: p-edge + 1 n-edge = 2 < 8
    children = {6: [0, 1, 2], 7: [3, 4, 5]}
    ta, tb = TreeView(6, children, 6), TreeView(7, children, 6)
    pairs = [(i, j) for i in range(3) for j in range(3) if not (i == 2 and j == 2)]
    pa = np.array([p[0] for p in pairs])
    pb = np.array([p[1] for p in pairs])
    cost, edges = encode_pair(ta, tb, pa, pb)
    assert cost == 2
    assert (6, 7, 1) in edges
    assert (2, 5, -1) in edges


def test_hierarchical_block_correction():
    """Fig. 2 regime: A = {0,1,2} ∪ child {3,4,5}; all of A connects to b
    except the child block — DP places p(A,b) + n(child,b): cost 2, strictly
    better than the flat model's 3 leaf corrections."""
    children = {7: [0, 1, 2, 8], 8: [3, 4, 5]}
    ta = TreeView(7, children, 7)
    tb = TreeView(6, {}, 7)  # singleton leaf 6
    cost, edges = encode_pair(ta, tb, np.array([0, 1, 2]), np.array([0, 0, 0]))
    assert cost == 2
    assert set(edges) == {(7, 6, 1), (8, 6, -1)}
    assert cost < flat_pair_cost(3, 6, 1)


def test_self_clique():
    children = {4: [0, 1, 2, 3][:2] + [5], 5: [2, 3]}
    children = {4: [0, 1, 5], 5: [2, 3]}
    tv = TreeView(4, children, 4)
    # complete graph on 4 leaves: all 6 pairs
    pu, pv = np.triu_indices(4, k=1)
    cost, edges = encode_self(tv, pu, pv)
    assert cost == 1
    assert edges == [(4, 4, 1)]


def test_self_two_cliques_no_cross():
    children = {6: [4, 5], 4: [0, 1], 5: [2, 3]}
    tv = TreeView(6, children, 4)
    # edges: (0,1) and (2,3) only -> two child self-loops or leaf edges, cost 2
    cost, edges = encode_self(tv, np.array([0, 2]), np.array([1, 3]))
    assert cost == 2


def test_dp_never_worse_than_flat():
    rng = np.random.default_rng(0)
    for trial in range(30):
        # random binary tree over 8 leaves on both sides
        def rand_tree(base):
            ids = list(range(base, base + 8))
            nxt = base + 100
            children = {}
            while len(ids) > 1:
                a = ids.pop(rng.integers(0, len(ids)))
                b = ids.pop(rng.integers(0, len(ids)))
                children[nxt] = [a, b]
                ids.append(nxt)
                nxt += 1
            return ids[0], children
        ra, ca = rand_tree(0)
        rb, cb = rand_tree(8)
        children = {**ca, **cb}
        ta, tb = TreeView(ra, children, 16), TreeView(rb, children, 16)
        mask = rng.random((8, 8)) < rng.random()
        pa, pb = np.nonzero(mask)
        cost, edges = encode_pair(ta, tb, pa, pb)
        assert cost <= flat_pair_cost(int(mask.sum()), 8, 8)
        # verify the emitted encoding reproduces the exact bipartite pattern
        acc = np.zeros((16, 16))
        leaves_a = ta.leaf_order(children, 16)
        leaves_b = tb.leaf_order(children, 16)
        span = {}
        for tv in (ta, tb):
            lo_leaves = tv.leaf_order(children, 16)
            for i, gid in enumerate(tv.gid):
                span[gid] = lo_leaves[tv.lo[i]:tv.hi[i]]
        for (x, y, s) in edges:
            for u in span[x]:
                for v in span[y]:
                    acc[u, v] += s
        got = acc[np.ix_(leaves_a, leaves_b)] > 0
        assert np.array_equal(got, mask)
