"""Analytic HBM model sanity: shard factors + breakdown behave as expected."""
import numpy as np
import jax
import pytest

from repro.configs.base import SHAPES, ShapeConfig
from repro.configs.registry import get_config
from repro.launch.memory_model import _shard_factor, analytic_hbm
from jax.sharding import PartitionSpec as P


@pytest.fixture(scope="module")
def mesh():
    # 1-device mesh with the production axis names (sizes 1 keep math trivial
    # but exercise the full code path)
    return jax.make_mesh((1, 1), ("data", "model"))


def test_shard_factor(mesh):
    assert _shard_factor(P("data", None), (8, 4), mesh) == 1  # size-1 axes
    m2 = jax.make_mesh((jax.device_count(),), ("data",))
    n = jax.device_count()
    assert _shard_factor(P("data"), (n * 4,), m2) == n
    assert _shard_factor(P("data"), (n * 4 + 1,), m2) == 1  # non-divisible


def test_train_breakdown_has_all_terms(mesh):
    cfg = get_config("deepseek-7b", smoke=True)
    out = analytic_hbm(cfg, SHAPES["train_4k"], mesh, ("data",))
    for k in ("params", "opt_moments", "grads_f32", "saved_residuals",
              "recompute_peak", "ce_chunk", "total"):
        assert k in out and out[k] >= 0
    # moments are 8 bytes/param vs 2 for bf16 params (both unsharded here)
    assert out["opt_moments"] == pytest.approx(4 * out["params"], rel=0.01)
    assert out["grads_f32"] == pytest.approx(2 * out["params"], rel=0.01)


def test_microbatch_scales_residuals(mesh):
    cfg = get_config("deepseek-7b", smoke=True)
    sh = ShapeConfig("t", 128, 32, "train")
    full = analytic_hbm(cfg, sh, mesh, ("data",), microbatch=32)
    half = analytic_hbm(cfg, sh, mesh, ("data",), microbatch=8)
    assert half["saved_residuals"] == pytest.approx(full["saved_residuals"] / 4)


def test_decode_counts_cache(mesh):
    cfg = get_config("qwen2.5-3b", smoke=True)
    out = analytic_hbm(cfg, ShapeConfig("d", 256, 8, "decode"), mesh, ("data",))
    assert out["kv_cache"] > 0
    assert out["total"] >= out["kv_cache"]
