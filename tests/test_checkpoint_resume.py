"""Crash-safety tests (ISSUE 10): plan-log checkpoint/resume bit-identity
across backends and partition counts, deterministic fault injection, and
the graceful-degradation policy (DESIGN.md §11).

The contract under test: the merge forest is a pure function of (graph,
config, plan log), so killing the engine at ANY stage boundary and resuming
from the newest committed checkpoint must reproduce the uninterrupted
summary array-for-array — on every backend, at every partition count, and
even across backend/partition changes between the kill and the resume.
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro import faults
from repro.core import checkpoint as ckpt_mod
from repro.core.checkpoint import (CheckpointMismatch, PlanCheckpointer,
                                   graph_fingerprint, pack_plans,
                                   unpack_plans)
from repro.core.engine import STAGE_ORDER, SummarizerEngine
from repro.core.merging import MergePlan
from repro.graphs import generators as GG

G = GG.caveman(14, 6, 0.05, seed=13)
T = 4
KILL_AT = 2  # iteration the stage faults fire in (commit lands after iters)


def engine(backend="numpy", partitions=1, seed=3, T_=T):
    return SummarizerEngine(partitions=partitions, backend=backend, T=T_,
                            seed=seed)


def assert_same(a, b):
    assert np.array_equal(a.parent, b.parent)
    assert np.array_equal(a.edges, b.edges)


# ---------------------------------------------------------------- tentpole
@pytest.mark.parametrize("backend,partitions", [
    ("numpy", 1), ("numpy", 2), ("numpy", 4),
    ("batched", 1), ("batched", 2), ("batched", 4),
    ("resident", 1), ("resident", 2), ("resident", 4),
])
def test_kill_at_every_stage_boundary_resumes_bit_identical(
        backend, partitions, tmp_path):
    want = engine(backend, partitions).run(G)
    assert want.validate_lossless(G)
    for stage in STAGE_ORDER:
        ckpt = str(tmp_path / f"ckpt-{stage}")
        with pytest.raises(faults.InjectedFault):
            with faults.inject(f"engine.{stage}", iteration=KILL_AT):
                engine(backend, partitions).run(G, checkpoint_dir=ckpt)
        eng = engine(backend, partitions)
        got = eng.run(G, checkpoint_dir=ckpt, resume=True)
        # the commit lands after the iteration's stages: a kill anywhere
        # inside iteration KILL_AT resumes from KILL_AT - 1
        assert eng.stats["resumed_from"] == KILL_AT - 1, stage
        assert_same(got, want)
        assert got.validate_lossless(G)


def test_resume_crosses_backend_and_partition_count(tmp_path):
    """A checkpoint is plans + identity, not backend state: written under
    numpy/partitions=1, it must resume under resident/partitions=4 (and
    batched/2) bit-identically — replay determinism is what makes the
    format portable."""
    want = engine().run(G)
    for backend, partitions in (("resident", 4), ("batched", 2),
                                ("numpy", 2)):
        ckpt = str(tmp_path / f"ckpt-{backend}-{partitions}")
        with pytest.raises(faults.InjectedFault):
            with faults.inject("engine.merge_round", iteration=3):
                engine().run(G, checkpoint_dir=ckpt)
        eng = engine(backend, partitions)
        got = eng.run(G, checkpoint_dir=ckpt, resume=True)
        assert eng.stats["resumed_from"] == 2
        assert_same(got, want)


def test_resume_with_no_checkpoint_starts_fresh(tmp_path):
    eng = engine()
    got = eng.run(G, checkpoint_dir=str(tmp_path / "empty"), resume=True)
    assert "resumed_from" not in eng.stats
    assert_same(got, engine().run(G))


def test_resume_of_completed_run_replays_to_the_end(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    want = engine().run(G, checkpoint_dir=ckpt)
    eng = engine()
    got = eng.run(G, checkpoint_dir=ckpt, resume=True)
    assert eng.stats["resumed_from"] == T  # nothing left to run
    assert_same(got, want)


def test_checkpoint_every_commits_less_often_same_result(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    want = engine().run(G)
    with pytest.raises(faults.InjectedFault):
        with faults.inject("engine.exchange", iteration=3):
            engine().run(G, checkpoint_dir=ckpt, checkpoint_every=2)
    eng = engine()
    got = eng.run(G, checkpoint_dir=ckpt, resume=True, checkpoint_every=2)
    # iteration 3 was killed before its (t % 2 == 0 or t == T) commit at
    # t=4 — the newest commit is t=2
    assert eng.stats["resumed_from"] == 2
    assert_same(got, want)


def test_checkpoint_commit_cost_is_tracked(tmp_path):
    eng = engine()
    eng.run(G, checkpoint_dir=str(tmp_path / "ckpt"))
    assert eng.stats["checkpoint"] > 0.0


# ------------------------------------------------------------- identity
def test_resume_refuses_different_graph(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    engine().run(G, checkpoint_dir=ckpt)
    other = GG.caveman(15, 6, 0.05, seed=14)
    with pytest.raises(CheckpointMismatch, match="fingerprint"):
        engine().run(other, checkpoint_dir=ckpt, resume=True)


@pytest.mark.parametrize("kw,val", [("seed", 99), ("T_", T + 2)])
def test_resume_refuses_decision_config_change(tmp_path, kw, val):
    ckpt = str(tmp_path / "ckpt")
    engine().run(G, checkpoint_dir=ckpt)
    with pytest.raises(CheckpointMismatch, match="config mismatch"):
        engine(**{kw: val}).run(G, checkpoint_dir=ckpt, resume=True)


def test_fingerprint_is_stable_and_graph_sensitive():
    assert graph_fingerprint(G) == graph_fingerprint(G)
    assert graph_fingerprint(G) != graph_fingerprint(
        GG.caveman(15, 6, 0.05, seed=14))


# ------------------------------------------------------------- atomicity
def test_half_written_tmp_dir_is_ignored_and_swept(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    engine().run(G, checkpoint_dir=ckpt)
    committed = sorted(d for d in os.listdir(ckpt) if not d.endswith(".tmp"))
    # simulate a kill mid-save: a .tmp dir with a torn manifest
    torn = os.path.join(ckpt, "it_000099.tmp")
    os.makedirs(torn)
    with open(os.path.join(torn, "manifest.json"), "w") as f:
        f.write('{"version": 1, "t": 99')  # truncated JSON
    eng = engine()
    got = eng.run(G, checkpoint_dir=ckpt, resume=True)
    assert eng.stats["resumed_from"] == T  # newest COMMITTED dir won
    assert not os.path.exists(torn)  # swept by the next checkpointer
    assert_same(got, engine().run(G))
    assert sorted(d for d in os.listdir(ckpt)
                  if not d.endswith(".tmp")) == committed


def test_gc_keeps_last_two_checkpoints(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    engine().run(G, checkpoint_dir=ckpt)
    dirs = sorted(os.listdir(ckpt))
    assert dirs == [f"it_{T-1:06d}", f"it_{T:06d}"]


def test_pack_unpack_plans_round_trip():
    plans = []
    rng = np.random.default_rng(np.random.SeedSequence(7))
    for k in range(5):
        p = MergePlan(rng.integers(0, 100, size=3 + k))
        for r in range(k % 3):
            p.record(rng.integers(0, 50, size=2 + r),
                     rng.integers(50, 99, size=2 + r))
        plans.append(p)
    out = unpack_plans(pack_plans(plans))
    assert len(out) == len(plans)
    for a, b in zip(plans, out):
        assert np.array_equal(a.members0, b.members0)
        assert len(a.rounds) == len(b.rounds)
        for (aa, az), (ba, bz) in zip(a.rounds, b.rounds):
            assert np.array_equal(aa, ba) and np.array_equal(az, bz)
    assert unpack_plans(pack_plans([])) == []


def test_checkpointer_version_gate(tmp_path):
    ckpt = PlanCheckpointer(str(tmp_path))
    fp = graph_fingerprint(G)
    ckpt.save(1, [[MergePlan(np.array([1, 2]))]], fp, {"T": 1})
    import json
    d = os.path.join(str(tmp_path), "it_000001")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    manifest["version"] = ckpt_mod.CKPT_VERSION + 1
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with pytest.raises(CheckpointMismatch, match="version"):
        PlanCheckpointer(str(tmp_path)).load_latest(fp, {"T": 1})


# ------------------------------------------------------------- fault plans
def test_fault_plan_exact_site_and_iteration():
    plan = faults.FaultPlan("engine.pack", iteration=3)
    plan.note("engine.pack", iteration=2)  # wrong iteration: no fire
    plan.note("engine.group", iteration=3)  # wrong site: no fire
    with pytest.raises(faults.InjectedFault) as ei:
        plan.note("engine.pack", iteration=3)
    assert ei.value.site == "engine.pack" and ei.value.iteration == 3
    plan.note("engine.pack", iteration=3)  # disarmed after `times` firings


def test_fault_plan_prefix_match_and_hit_targeting():
    plan = faults.FaultPlan("kernel.", hit=3)
    plan.note("kernel.bitset_fold.topj")
    plan.note("kernel.bitset_jaccard.intersections")
    with pytest.raises(faults.InjectedFault):
        plan.note("kernel.bitset_fold.round")
    plan = faults.FaultPlan("kernel.", hit=1)
    plan.note("transfer.h2d")  # not under the prefix


def test_fault_plan_from_spec_round_trips():
    plan = faults.FaultPlan.from_spec("engine.merge_round@3#2")
    assert (plan.site, plan.iteration, plan.hit) == ("engine.merge_round",
                                                     3, 2)
    plan = faults.FaultPlan.from_spec("kernel.#5")
    assert (plan.site, plan.iteration, plan.hit) == ("kernel.", None, 5)


def test_fault_plan_seeded_is_deterministic():
    a = faults.FaultPlan.seeded(11)
    b = faults.FaultPlan.seeded(11)
    assert (a.site, a.iteration) == (b.site, b.iteration)
    assert a.site in faults.STAGE_SITES
    picks = {(faults.FaultPlan.seeded(s).site,
              faults.FaultPlan.seeded(s).iteration) for s in range(32)}
    assert len(picks) > 1  # the seed actually varies the kill point


def test_env_plan_arms_and_disarms(monkeypatch):
    monkeypatch.setenv(faults.ENV_VAR, "engine.pack@1")
    faults.install_env_plan()
    try:
        with pytest.raises(faults.InjectedFault):
            engine().run(G)
    finally:
        monkeypatch.delenv(faults.ENV_VAR)
        faults.install_env_plan()
    engine().run(G)  # disarmed again


def test_check_is_noop_when_nothing_armed():
    faults.check("engine.pack", iteration=1)  # must not raise


# ------------------------------------------------------------ degradation
def test_kernel_dispatch_fault_degrades_to_ref_twin(monkeypatch):
    """With the Pallas path forced on (interpret mode on CPU), a dispatch
    fault must retry once on the jnp twin and finish bit-identically."""
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    want = engine().run(G)
    eng = engine(backend="resident")
    with faults.inject("kernel.bitset_fold.round", hit=2):
        got = eng.run(G)
    assert eng.stats["degradations"] >= 1
    assert_same(got, want)
    assert got.validate_lossless(G)


def test_bank_extract_fault_degrades_to_host_path():
    want = engine().run(G)
    eng = engine(backend="resident")
    with faults.inject("resident.bank.extract"):
        got = eng.run(G)
    assert eng.stats["degradations"] >= 1
    assert eng._run_ctx is None  # resident context dropped for the run
    assert_same(got, want)
    assert got.validate_lossless(G)


def test_bank_advance_fault_degrades_to_host_path():
    want = engine().run(G)
    eng = engine(backend="resident")
    with faults.inject("resident.bank.advance"):
        got = eng.run(G)
    assert eng.stats["degradations"] >= 1
    assert_same(got, want)


def test_clean_run_reports_zero_degradations():
    eng = engine(backend="resident")
    eng.run(G)
    assert eng.stats["degradations"] == 0
