"""Batched group-merge engine: equivalence with the sequential loop.

Losslessness is structural (the emission DP re-encodes the input edges), so
every backend must reconstruct the input graph bit-for-bit from `Summary`
decompression; the backends may produce different merge forests, so costs
only need to agree within a small tolerance (ISSUE 1 / DESIGN.md §3).
No hypothesis dependency: seeded generator graphs cover the regimes.
"""
import numpy as np
import pytest

from repro.core import summarize
from repro.core.bitops import popcount, popcount_swar
from repro.core.minhash import candidate_groups
from repro.core.slugger import SluggerState
from repro.graphs import generators as GG
from repro.graphs.csr import Graph

BACKENDS = ("loop", "numpy", "batched")


def _graphs():
    return [
        ("er", GG.erdos_renyi(150, 0.04, seed=11)),
        ("ba", GG.barabasi_albert(150, 3, seed=12)),
        ("caveman", GG.caveman(14, 6, 0.05, seed=13)),
    ]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name,g", _graphs(), ids=lambda v: v if isinstance(v, str) else "")
def test_engines_lossless(name, g, backend):
    s = summarize(g, T=6, seed=3, backend=backend)
    assert s.validate_lossless(g)
    assert s.cost() <= max(g.m, 1)


@pytest.mark.parametrize("name,g", _graphs(), ids=lambda v: v if isinstance(v, str) else "")
def test_engine_costs_close(name, g):
    costs = {be: summarize(g, T=6, seed=3, backend=be).cost() for be in BACKENDS}
    lo, hi = min(costs.values()), max(costs.values())
    assert hi <= lo * 1.25 + 8, costs


@pytest.mark.parametrize("backend", ("numpy", "batched"))
def test_batched_engine_height_bound(backend):
    g = GG.caveman(12, 6, 0.05, seed=3)
    s = summarize(g, T=5, seed=1, height_bound=2, backend=backend)
    assert s.validate_lossless(g)
    assert max(s.tree_heights()) <= 2


def test_random_graphs_all_backends_lossless():
    rng = np.random.default_rng(7)
    for trial in range(8):
        n = int(rng.integers(2, 32))
        e = rng.integers(0, n, size=(max(int(n * n * rng.random() * 0.5), 1), 2))
        g = Graph.from_edges(n, e)
        for backend in BACKENDS:
            s = summarize(g, T=4, seed=trial, backend=backend)
            assert s.validate_lossless(g), (trial, backend)


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        summarize(GG.caveman(3, 4, 0.0, seed=0), T=1, backend="nope")


# -- bitops -----------------------------------------------------------------
def test_popcount_swar_matches_native():
    rng = np.random.default_rng(0)
    x64 = rng.integers(0, 2**63, size=257, dtype=np.int64).astype(np.uint64)
    x32 = rng.integers(0, 2**32, size=257, dtype=np.int64).astype(np.uint32)
    for x in (x64, x32, np.array([0, 1, (1 << 32) - 1], dtype=np.uint32),
              np.array([0, 1, (1 << 64) - 1], dtype=np.uint64)):
        want = np.array([bin(int(v)).count("1") for v in x], dtype=np.uint8)
        assert np.array_equal(popcount_swar(x), want)
        assert np.array_equal(popcount(x), want)


def test_popcount_swar_rejects_signed():
    with pytest.raises(TypeError):
        popcount_swar(np.arange(4, dtype=np.int64))


# -- state / candidate generation ------------------------------------------
def test_state_merge_folds_rows():
    g = Graph.from_edges(4, np.array([[0, 1], [1, 2], [2, 3], [0, 2]]))
    st = SluggerState(g)
    m = st.merge(0, 1)
    assert st.parent[0] == m and st.parent[1] == m
    assert not st.alive_mask[0] and st.alive_mask[m]
    assert st.selfcnt[m] == 1  # the (0,1) edge went internal
    seg, nbr, cnt = st.gather_rows(np.array([m]))
    got = dict(zip(nbr.tolist(), cnt.tolist()))
    assert got == {2: 2, 3: 0} or got == {2: 2}  # 0→2 and 1→2 folded
    # neighbors resolve lazily: node 2's stored row still references 0/1
    seg2, nbr2, cnt2 = st.gather_rows(np.array([2]))
    assert dict(zip(nbr2.tolist(), cnt2.tolist())) == {m: 2, 3: 1}


def test_state_merge_batch_matches_sequential():
    g = GG.caveman(6, 5, 0.1, seed=2)
    st1, st2 = SluggerState(g), SluggerState(g)
    pairs = np.array([[0, 1], [5, 6], [10, 11]], dtype=np.int64)
    ms = st2.merge_batch(pairs[:, 0], pairs[:, 1])
    singles = [st1.merge(int(a), int(b)) for a, b in pairs]
    assert list(ms) == singles
    for m in singles:
        _, n1, c1 = st1.gather_rows(np.array([m]))
        _, n2, c2 = st2.gather_rows(np.array([m]))
        assert np.array_equal(n1, n2) and np.array_equal(c1, c2)
        assert st1.selfcnt[m] == st2.selfcnt[m]
    assert np.array_equal(st1.root_of, st2.root_of)


def test_candidate_groups_partition_alive_roots():
    g = GG.barabasi_albert(200, 3, seed=5)
    st = SluggerState(g)
    alive = st.alive
    groups = candidate_groups(g, st.root_of, alive, seed=9, max_group=50)
    seen = np.concatenate(groups) if groups else np.zeros(0, dtype=np.int64)
    assert len(np.unique(seen)) == len(seen)  # disjoint
    assert np.isin(seen, alive).all()
    assert all(2 <= len(grp) <= 50 for grp in groups)