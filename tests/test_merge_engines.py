"""Batched group-merge engine: equivalence with the sequential loop.

Losslessness is structural (the emission DP re-encodes the input edges), so
every backend must reconstruct the input graph bit-for-bit from `Summary`
decompression; the backends may produce different merge forests, so costs
only need to agree within a small tolerance (ISSUE 1 / DESIGN.md §3).
No hypothesis dependency: seeded generator graphs cover the regimes.
"""
import numpy as np
import pytest

from repro.core import summarize
from repro.core.bitops import popcount, popcount_swar
from repro.core.minhash import candidate_groups
from repro.core.slugger import SluggerState
from repro.graphs import generators as GG
from repro.graphs.csr import Graph

BACKENDS = ("loop", "numpy", "batched", "resident")
# the batched-family backends must agree bit for bit — same ranking keys,
# same sweeps, only the ranking/fold substrate differs (DESIGN.md §9)
EXACT_FAMILY = ("numpy", "batched", "resident")


def _graphs():
    return [
        ("er", GG.erdos_renyi(150, 0.04, seed=11)),
        ("ba", GG.barabasi_albert(150, 3, seed=12)),
        ("caveman", GG.caveman(14, 6, 0.05, seed=13)),
    ]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("name,g", _graphs(), ids=lambda v: v if isinstance(v, str) else "")
def test_engines_lossless(name, g, backend):
    s = summarize(g, T=6, seed=3, backend=backend)
    assert s.validate_lossless(g)
    # er/ba are near-incompressible: cost lands within a whisker of the
    # flat encoding m. Candidate groups evaluate Savings against the
    # iteration-start snapshot (concurrent groups, paper Sect. III-B), so
    # a zero-Saving merge can come out a unit or two worse once a
    # neighboring group's merges land — same slack rule as
    # test_engine_costs_close below.
    assert s.cost() <= max(g.m, 1) + 8


@pytest.mark.parametrize("name,g", _graphs(), ids=lambda v: v if isinstance(v, str) else "")
def test_engine_costs_close(name, g):
    costs = {be: summarize(g, T=6, seed=3, backend=be).cost() for be in BACKENDS}
    lo, hi = min(costs.values()), max(costs.values())
    assert hi <= lo * 1.25 + 8, costs


# -- resident-backend bit-identity (ISSUE 5) ---------------------------------
@pytest.mark.parametrize("seed", (0, 3, 11))
@pytest.mark.parametrize("name,g", _graphs(), ids=lambda v: v if isinstance(v, str) else "")
def test_exact_family_bit_identical(name, g, seed):
    """numpy / batched / resident summaries, parent ids, and edges agree bit
    for bit — the resident backend's device rounds change WHERE the ranking
    and fold run, never their outcome."""
    runs = {be: summarize(g, T=5, seed=seed, backend=be)
            for be in EXACT_FAMILY}
    base = runs["numpy"]
    assert base.validate_lossless(g)
    for be in EXACT_FAMILY[1:]:
        assert np.array_equal(base.parent, runs[be].parent), (name, be, seed)
        assert np.array_equal(base.edges, runs[be].edges), (name, be, seed)


def test_resident_kernel_path_bit_identical(monkeypatch):
    """REPRO_FORCE_PALLAS=1 swaps the jnp twins for the interpret-mode
    Pallas kernels; results must not move."""
    g = GG.caveman(10, 6, 0.05, seed=2)
    want = summarize(g, T=4, seed=1, backend="numpy")
    monkeypatch.setenv("REPRO_FORCE_PALLAS", "1")
    got = summarize(g, T=4, seed=1, backend="resident")
    assert np.array_equal(want.parent, got.parent)
    assert np.array_equal(want.edges, got.edges)


def test_resident_merge_plans_identical_multi_round():
    """A whole-clique candidate group needs SEVERAL matching rounds (one
    conflict-free subset per round); the recorded MergePlan rounds — not
    just the final summary — must match the host backend pair for pair."""
    from repro.core.merging import build_merge_work

    n = 24
    g = Graph.from_edges(
        n, np.array([(u, v) for u in range(n) for v in range(u + 1, n)]))
    seeds = np.arange(1, dtype=np.uint64) + 7
    plans = {}
    for be in EXACT_FAMILY:
        state = SluggerState(g)
        p, thunks = build_merge_work(state, [np.arange(n)], theta=0.0,
                                     group_seeds=seeds, backend=be)
        for t in thunks:
            t()
        plans[be] = p[0]
    assert len(plans["numpy"].rounds) > 1  # actually multi-round
    for be in EXACT_FAMILY[1:]:
        assert len(plans[be].rounds) == len(plans["numpy"].rounds), be
        for (a1, z1), (a2, z2) in zip(plans["numpy"].rounds,
                                      plans[be].rounds):
            assert np.array_equal(a1, a2) and np.array_equal(z1, z2), be


def test_resident_arena_fold_matches_host_and_counts_transfers():
    """Sweep one workspace on the host ranker and a copy on the resident
    arena: decisions agree, the device bitmaps (sync-back contract,
    DESIGN.md §9) equal the host-folded ones, and the transfer counter saw
    the upload / top-J / fold traffic."""
    from repro.core.merging import (BatchedGroupWorkspace, HostRankSource,
                                    MergePlan, ResidentRankSource)
    from repro.core.resident import ResidentBitmapArena
    from repro.core.transfer import TransferCounter

    g = GG.caveman(6, 8, 0.05, seed=4)
    groups = [np.arange(8) + 8 * i for i in range(6)]
    seeds = np.arange(6, dtype=np.uint64) * 13 + 1

    def build():
        state = SluggerState(g)
        plans = [MergePlan(gr) for gr in groups]
        ws = BatchedGroupWorkspace.build_bucket(
            state, groups, 8, plans=plans, group_seeds=seeds)
        assert len(ws) == 1
        return ws[0], plans

    ws_h, plans_h = build()
    ws_r, plans_r = build()
    counter = TransferCounter()
    arena = ResidentBitmapArena.from_workspace(ws_r, top_j=16,
                                               counter=counter)
    m_h = ws_h.sweep(0.0, HostRankSource(None))
    m_r = ws_r.sweep(0.0, ResidentRankSource(arena))
    assert m_h == m_r > 0
    for ph, pr in zip(plans_h, plans_r):
        assert len(ph.rounds) == len(pr.rounds)
        for (a1, z1), (a2, z2) in zip(ph.rounds, pr.rounds):
            assert np.array_equal(a1, a2) and np.array_equal(z1, z2)
    # device bitmaps == host-folded bitmaps (the host ws_r copy is stale by
    # design — Savings never read it; the DEVICE copy must match ws_h)
    W32 = ws_h.bits.view(np.uint32).shape[-1]
    dev = arena.host_bits()[:, :, :W32]
    np.testing.assert_array_equal(dev, ws_h.bits.view(np.uint32))
    np.testing.assert_array_equal(arena.host_alive(), ws_h.alive)
    rows = np.argwhere(ws_h.alive)
    sync = arena.sync_rows(rows[:, 0], rows[:, 1])[:, :W32]
    np.testing.assert_array_equal(
        sync, ws_h.bits.view(np.uint32)[rows[:, 0], rows[:, 1]])
    assert counter.bytes_h2d > 0 and counter.bytes_d2h > 0
    assert counter.rounds == arena.rounds > 0


@pytest.mark.parametrize("backend", ("numpy", "batched", "resident"))
def test_batched_engine_height_bound(backend):
    g = GG.caveman(12, 6, 0.05, seed=3)
    s = summarize(g, T=5, seed=1, height_bound=2, backend=backend)
    assert s.validate_lossless(g)
    assert max(s.tree_heights()) <= 2


def test_random_graphs_all_backends_lossless():
    rng = np.random.default_rng(7)
    for trial in range(8):
        n = int(rng.integers(2, 32))
        e = rng.integers(0, n, size=(max(int(n * n * rng.random() * 0.5), 1), 2))
        g = Graph.from_edges(n, e)
        for backend in BACKENDS:
            s = summarize(g, T=4, seed=trial, backend=backend)
            assert s.validate_lossless(g), (trial, backend)


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        summarize(GG.caveman(3, 4, 0.0, seed=0), T=1, backend="nope")


# -- bitops -----------------------------------------------------------------
def test_popcount_swar_matches_native():
    rng = np.random.default_rng(0)
    x64 = rng.integers(0, 2**63, size=257, dtype=np.int64).astype(np.uint64)
    x32 = rng.integers(0, 2**32, size=257, dtype=np.int64).astype(np.uint32)
    for x in (x64, x32, np.array([0, 1, (1 << 32) - 1], dtype=np.uint32),
              np.array([0, 1, (1 << 64) - 1], dtype=np.uint64)):
        want = np.array([bin(int(v)).count("1") for v in x], dtype=np.uint8)
        assert np.array_equal(popcount_swar(x), want)
        assert np.array_equal(popcount(x), want)


def test_popcount_swar_rejects_signed():
    with pytest.raises(TypeError):
        popcount_swar(np.arange(4, dtype=np.int64))


# -- state / candidate generation ------------------------------------------
def test_state_merge_folds_rows():
    g = Graph.from_edges(4, np.array([[0, 1], [1, 2], [2, 3], [0, 2]]))
    st = SluggerState(g)
    m = st.merge(0, 1)
    assert st.parent[0] == m and st.parent[1] == m
    assert not st.alive_mask[0] and st.alive_mask[m]
    assert st.selfcnt[m] == 1  # the (0,1) edge went internal
    seg, nbr, cnt = st.gather_rows(np.array([m]))
    got = dict(zip(nbr.tolist(), cnt.tolist()))
    assert got == {2: 2, 3: 0} or got == {2: 2}  # 0→2 and 1→2 folded
    # neighbors resolve lazily: node 2's stored row still references 0/1
    seg2, nbr2, cnt2 = st.gather_rows(np.array([2]))
    assert dict(zip(nbr2.tolist(), cnt2.tolist())) == {m: 2, 3: 1}


def test_state_merge_batch_matches_sequential():
    g = GG.caveman(6, 5, 0.1, seed=2)
    st1, st2 = SluggerState(g), SluggerState(g)
    pairs = np.array([[0, 1], [5, 6], [10, 11]], dtype=np.int64)
    ms = st2.merge_batch(pairs[:, 0], pairs[:, 1])
    singles = [st1.merge(int(a), int(b)) for a, b in pairs]
    assert list(ms) == singles
    for m in singles:
        _, n1, c1 = st1.gather_rows(np.array([m]))
        _, n2, c2 = st2.gather_rows(np.array([m]))
        assert np.array_equal(n1, n2) and np.array_equal(c1, c2)
        assert st1.selfcnt[m] == st2.selfcnt[m]
    assert np.array_equal(st1.root_of, st2.root_of)


def test_candidate_groups_partition_alive_roots():
    g = GG.barabasi_albert(200, 3, seed=5)
    st = SluggerState(g)
    alive = st.alive
    groups = candidate_groups(g, st.root_of, alive, seed=9, max_group=50)
    seen = np.concatenate(groups) if groups else np.zeros(0, dtype=np.int64)
    assert len(np.unique(seen)) == len(seen)  # disjoint
    assert np.isin(seen, alive).all()
    assert all(2 <= len(grp) <= 50 for grp in groups)