"""Property tests: SLUGGER is lossless on arbitrary graphs (the paper's
central claim), and its cost never exceeds the trivial encoding |E|."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import baselines, summarize
from repro.graphs import generators as GG
from repro.graphs.csr import Graph


@st.composite
def random_graphs(draw):
    n = draw(st.integers(min_value=1, max_value=36))
    density = draw(st.floats(min_value=0.0, max_value=0.6))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    k = int(n * n * density)
    if k == 0:
        return Graph.from_edges(n, np.zeros((0, 2), dtype=np.int64))
    e = rng.integers(0, n, size=(k, 2))
    return Graph.from_edges(n, e)


@settings(max_examples=40, deadline=None)
@given(g=random_graphs(), T=st.integers(min_value=1, max_value=6))
def test_slugger_lossless(g, T):
    s = summarize(g, T=T, seed=1)
    assert s.validate_lossless(g)
    # +8: concurrent candidate groups evaluate Savings against the
    # iteration-start snapshot, so zero-Saving merges on near-incompressible
    # graphs can land a unit or two above the flat encoding (see
    # test_merge_engines.test_engines_lossless)
    assert s.cost() <= max(g.m, 0) + 8 or g.m == 0


@settings(max_examples=15, deadline=None)
@given(g=random_graphs())
def test_slugger_no_prune_lossless(g):
    s = summarize(g, T=3, seed=2, prune_steps=())
    assert s.validate_lossless(g)


@settings(max_examples=15, deadline=None)
@given(g=random_graphs(), hb=st.integers(min_value=1, max_value=4))
def test_slugger_height_bound(g, hb):
    s = summarize(g, T=3, seed=3, height_bound=hb)
    assert s.validate_lossless(g)
    heights = s.tree_heights()
    assert all(h <= hb for h in heights)


@settings(max_examples=15, deadline=None)
@given(g=random_graphs())
def test_partial_decompression_matches(g):
    s = summarize(g, T=3, seed=4)
    for u in range(min(g.n, 12)):
        assert set(s.neighbors(u)) == set(int(x) for x in g.neighbors(u))


@settings(max_examples=10, deadline=None)
@given(g=random_graphs())
def test_baselines_lossless(g):
    for fn in (lambda: baselines.sweg(g, T=3, seed=5),
               lambda: baselines.randomized(g, seed=5, max_steps=200),
               lambda: baselines.sags_like(g, seed=5)):
        s = fn()
        assert s.validate_lossless(g)


def test_structured_graphs_lossless():
    cases = [
        GG.planted_hierarchy((3, 3), 5, (0.02, 0.3, 0.95), seed=7),
        GG.caveman(10, 6, 0.05, seed=8),
        GG.barabasi_albert(120, 3, seed=9),
        GG.star_of_cliques(20, 6, seed=10),
        GG.bipartite_nested(32, 31, 5),
        GG.rmat(8, 4, seed=11),
    ]
    for g in cases:
        s = summarize(g, T=8, seed=0)
        assert s.validate_lossless(g)
        assert s.relative_size(g) <= 1.0


def test_hierarchy_beats_flat_on_nested_structure():
    """Theorem-1 regime: hierarchical model strictly better than flat SWEG."""
    g = GG.bipartite_nested(64, 63, levels=6)
    s = summarize(g, T=20, seed=0)
    sw = baselines.sweg(g, T=20, seed=0)
    assert s.cost() < sw.cost()
