"""Session guards: smoke tests and benches must see exactly ONE device —
the 512-device XLA flag belongs to the dry-run (and to subprocess tests)
only. A leak here would silently shard every smoke test 512 ways."""
import jax


def pytest_sessionstart(session):
    assert jax.device_count() == 1, (
        "test session must run on 1 device; XLA_FLAGS leaked: "
        f"{jax.devices()[:4]}...")
