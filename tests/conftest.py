"""Session guards: smoke tests and benches must see exactly ONE device —
the 512-device XLA flag belongs to the dry-run (and to subprocess tests)
only. A leak here would silently shard every smoke test 512 ways.

Dtype guard: with x64 disabled, an explicit 64-bit dtype request anywhere in
a JAX path silently truncates to 32 bits and emits a UserWarning — promote it
to an error so the intended dtypes stay explicit."""
import jax


def pytest_configure(config):
    config.addinivalue_line(
        "filterwarnings", "error:Explicitly requested dtype")


def pytest_sessionstart(session):
    assert jax.device_count() == 1, (
        "test session must run on 1 device; XLA_FLAGS leaked: "
        f"{jax.devices()[:4]}...")
