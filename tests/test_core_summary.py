"""Unit tests for the hierarchical graph summarization model (Sect. II-B)."""
import numpy as np
import pytest

from repro.core.summary import Summary
from repro.graphs.csr import Graph


def paper_fig2_summary():
    """The paper's Fig. 2 final state: supernode {0,1,2,3} contains {2,3};
    p-edge ({0,1,2,3}, {5}) and n-edge ({2,3}, {5})."""
    # ids: leaves 0..5, supernode 6 = {2,3}, supernode 7 = {0,1,2,3}
    parent = np.array([7, 7, 6, 6, -1, -1, 7, -1], dtype=np.int64)
    edges = np.array([[5, 7, 1], [5, 6, -1]], dtype=np.int64)
    return Summary(n_leaves=6, parent=parent, edges=edges)


def test_fig2_interpretation():
    s = paper_fig2_summary()
    g = s.decompress()
    assert g.edge_set() == {(0, 5), (1, 5)}


def test_fig2_partial_decompression():
    s = paper_fig2_summary()
    assert set(s.neighbors(5)) == {0, 1}
    assert set(s.neighbors(0)) == {5}
    assert set(s.neighbors(2)) == set()
    assert set(s.neighbors(4)) == set()


def test_fig2_cost():
    s = paper_fig2_summary()
    # |P+| = 1, |P-| = 1, |H| = 5 ({0,1,6}->7 is 3 edges, {2,3}->6 is 2)
    assert s.num_pos == 1 and s.num_neg == 1 and s.num_h == 5
    assert s.cost() == 7


def test_more_pos_than_neg_rule():
    """Edge exists iff #p-edges > #n-edges between ancestor pairs."""
    # leaves 0,1 under supernode 2; p-edge (2,2) with n-edge (0,1) cancels
    parent = np.array([2, 2, -1], dtype=np.int64)
    edges = np.array([[2, 2, 1], [0, 1, -1]], dtype=np.int64)
    s = Summary(n_leaves=2, parent=parent, edges=edges)
    assert s.decompress().edge_set() == set()


def test_self_loop_supernode():
    # clique {0,1,2} as one p self-loop
    parent = np.array([3, 3, 3, -1], dtype=np.int64)
    edges = np.array([[3, 3, 1]], dtype=np.int64)
    s = Summary(n_leaves=3, parent=parent, edges=edges)
    assert s.decompress().edge_set() == {(0, 1), (0, 2), (1, 2)}
    assert set(s.neighbors(0)) == {1, 2}


def test_stats_shapes():
    s = paper_fig2_summary()
    g = s.decompress()
    st = s.stats(g)
    assert st["max_height"] == 2
    assert st["cost"] == 7
    assert 0 < st["avg_leaf_depth"] <= 2


def test_empty_graph():
    s = Summary(n_leaves=4, parent=np.full(4, -1, dtype=np.int64), edges=np.zeros((0, 3), dtype=np.int64))
    assert s.cost() == 0
    g = s.decompress()
    assert g.m == 0 and g.n == 4
