"""PartitionedGraph: shard round-trips, streamed ingestion, ownership."""
import numpy as np
import pytest

from repro.graphs import Graph, PartitionedGraph, block_owner
from repro.graphs import generators as GG


def _graphs():
    return [
        ("caveman", GG.caveman(14, 6, 0.05, seed=13)),
        ("rmat", GG.rmat(8, 4, seed=2)),
        ("ba", GG.barabasi_albert(120, 3, seed=5)),
        ("no-edges", Graph.from_edges(9, np.zeros((0, 2)))),
        ("empty", Graph.from_edges(0, np.zeros((0, 2)))),
    ]


@pytest.mark.parametrize("name,g", _graphs(), ids=lambda v: v if isinstance(v, str) else "")
@pytest.mark.parametrize("k", [1, 2, 4, 7])
def test_from_graph_round_trip(name, g, k):
    pg = PartitionedGraph.from_graph(g, k)
    assert pg.to_graph() == g
    assert pg.m == g.m
    assert pg.n_parts == k
    # shards cover every node exactly once with their full adjacency rows
    seen = np.concatenate([s.nodes for s in pg.shards])
    assert np.array_equal(np.sort(seen), np.arange(g.n))
    for s in pg.shards:
        for i, u in enumerate(s.nodes):
            assert np.array_equal(s.neighbors(i), g.neighbors(int(u)))


@pytest.mark.parametrize("name,g", _graphs(), ids=lambda v: v if isinstance(v, str) else "")
@pytest.mark.parametrize("k", [1, 3])
def test_from_edge_stream_matches_from_edges(name, g, k):
    pg = PartitionedGraph.from_edge_stream(
        g.n, GG.stream_edges(g, chunk_edges=57), n_parts=k)
    assert pg.to_graph() == g


def test_from_edge_stream_spill_dir_matches_in_memory(tmp_path):
    g = GG.caveman(10, 6, 0.05, seed=3)
    spill = tmp_path / "runs"
    pg = PartitionedGraph.from_edge_stream(
        g.n, GG.stream_edges(g, chunk_edges=41), n_parts=3,
        spill_dir=str(spill))
    assert pg.to_graph() == g
    assert not list(spill.glob("*.npy"))  # spilled runs were cleaned up


def test_spill_dir_survives_kill_mid_run_write(tmp_path):
    """Crash-safety of spilled ingestion (ISSUE 10): a prior run killed
    mid-write leaves committed orphan runs and a half-written ``.npy.tmp``
    in the spill dir. The next ingestion must sweep BOTH — a stale
    committed run merged into a later build would silently add edges."""
    g = GG.caveman(10, 6, 0.05, seed=3)
    spill = tmp_path / "runs"
    spill.mkdir()
    # orphan committed run from a "crashed" previous ingestion + a torn
    # half-write (np.save got killed partway)
    np.save(str(spill / "run-0-7.npy"),
            np.array([0 * g.n + 59, 59 * g.n + 0], dtype=np.int64))
    (spill / "run-1-3.npy.tmp").write_bytes(b"\x93NUMPY torn")
    pg = PartitionedGraph.from_edge_stream(
        g.n, GG.stream_edges(g, chunk_edges=41), n_parts=3,
        spill_dir=str(spill))
    assert pg.to_graph() == g  # the orphan's fake edge did NOT leak in
    assert not list(spill.glob("run-*"))  # orphans swept, new runs consumed


def test_spill_run_files_commit_atomically(tmp_path, monkeypatch):
    """Every committed run file appears via rename: at no point during
    ingestion does a partially-written ``.npy`` exist under its final
    name. Asserted by auditing the dir at every os.replace boundary."""
    import os as _os

    g = GG.caveman(10, 6, 0.05, seed=3)
    spill = tmp_path / "runs"
    real_replace = _os.replace
    seen_tmp = []

    def audited_replace(src, dst):
        if str(spill) in str(dst):
            assert str(src).endswith(".tmp")
            seen_tmp.append(src)
            # the committed name must not exist until this rename
            assert not _os.path.exists(dst)
        return real_replace(src, dst)

    monkeypatch.setattr(_os, "replace", audited_replace)
    pg = PartitionedGraph.from_edge_stream(
        g.n, GG.stream_edges(g, chunk_edges=41), n_parts=3,
        spill_dir=str(spill))
    assert pg.to_graph() == g
    assert seen_tmp  # the atomic path was actually exercised


def test_from_edge_stream_cleans_dirty_chunks():
    # self-loops, duplicates, and cross-chunk duplicates must all fold away
    chunks = [
        np.array([[0, 1], [1, 1], [2, 3], [1, 0]]),
        np.array([[0, 1], [3, 2], [4, 0]]),
    ]
    pg = PartitionedGraph.from_edge_stream(5, iter(chunks), n_parts=2)
    want = Graph.from_edges(5, np.concatenate(chunks))
    assert pg.to_graph() == want


def test_graph_partitioned_helper_is_one_partition_special_case():
    g = GG.caveman(6, 5, 0.1, seed=1)
    pg = g.partitioned()
    assert pg.n_parts == 1
    s = pg.shard(0)
    assert np.array_equal(s.indptr, g.indptr)
    assert np.array_equal(s.indices, g.indices)
    assert np.array_equal(s.nodes, np.arange(g.n))


def test_block_owner_balanced_and_contiguous():
    own = block_owner(10, 3)
    assert own.min() == 0 and own.max() == 2
    assert np.all(np.diff(own) >= 0)  # contiguous blocks
    counts = np.bincount(own)
    assert counts.max() - counts.min() <= 1


def test_out_of_range_owner_rejected():
    g = GG.caveman(4, 4, 0.0, seed=0)
    with pytest.raises(ValueError):
        PartitionedGraph.from_graph(g, 2, owner=np.array([0, 0, 1, 2] * 4))
    with pytest.raises(ValueError):
        PartitionedGraph.from_edge_stream(
            4, iter([np.array([[0, 1], [2, 3]])]), n_parts=2,
            owner=np.array([0, 0, 1, 2]))
    with pytest.raises(ValueError):  # wrong length
        PartitionedGraph.from_graph(g, 2, owner=np.zeros(3, dtype=np.int64))


def test_custom_owner_map():
    g = GG.caveman(8, 4, 0.0, seed=0)
    owner = np.arange(g.n) % 3  # interleaved, non-contiguous
    pg = PartitionedGraph.from_graph(g, 3, owner=owner)
    assert pg.to_graph() == g
    for s in pg.shards:
        assert np.array_equal(np.asarray(owner)[s.nodes], np.full(s.n_local, s.part))


def test_rmat_stream_deterministic_and_bounded():
    chunks1 = list(GG.rmat_stream(7, 4, seed=9, chunk_edges=100))
    chunks2 = list(GG.rmat_stream(7, 4, seed=9, chunk_edges=100))
    assert len(chunks1) == len(chunks2)
    assert all(np.array_equal(a, b) for a, b in zip(chunks1, chunks2))
    assert all(c.shape[0] <= 100 for c in chunks1)
    assert sum(c.shape[0] for c in chunks1) == (1 << 7) * 4
    # the partition count must not change the resulting graph
    g2 = PartitionedGraph.from_edge_stream(
        128, GG.rmat_stream(7, 4, seed=9, chunk_edges=100), 2).to_graph()
    g1 = PartitionedGraph.from_edge_stream(
        128, GG.rmat_stream(7, 4, seed=9, chunk_edges=100), 1).to_graph()
    assert g1 == g2
