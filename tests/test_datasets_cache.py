"""Dataset download cache: checksum verification + offline behavior, all
against a mocked fetch — no network in tests."""
import gzip
import io
import urllib.error

import numpy as np
import pytest

from repro.graphs import datasets


EDGE_TEXT = b"""\
# Undirected graph: mock
# FromNodeId\tToNodeId
0\t1
1\t2
2\t0
2\t3
"""


class _MockOpener:
    """urlopen stand-in serving fixed bytes and counting calls."""

    def __init__(self, payload: bytes, fail: Exception | None = None):
        self.payload = payload
        self.fail = fail
        self.calls = 0

    def __call__(self, url):
        self.calls += 1
        if self.fail is not None:
            raise self.fail
        return io.BytesIO(self.payload)


def _gz_payload() -> bytes:
    return gzip.compress(EDGE_TEXT)


def test_load_remote_parses_and_caches(tmp_path):
    opener = _MockOpener(_gz_payload())
    g = datasets.load_remote("ca-GrQc", cache=str(tmp_path), opener=opener)
    assert opener.calls == 1
    assert g.n == 4 and g.m == 4
    assert g.has_edge(0, 1) and g.has_edge(2, 3)
    # second load: served from disk, the network is never touched
    g2 = datasets.load_remote("ca-GrQc", cache=str(tmp_path), opener=opener)
    assert opener.calls == 1
    assert g2 == g
    # sha256 sidecar was recorded (trust-on-first-use)
    sidecars = list(tmp_path.glob("*.sha256"))
    assert len(sidecars) == 1


def test_offline_error_is_actionable(tmp_path):
    opener = _MockOpener(b"", fail=urllib.error.URLError("no route to host"))
    with pytest.raises(datasets.DatasetFetchError) as ei:
        datasets.load_remote("ca-GrQc", cache=str(tmp_path), opener=opener)
    msg = str(ei.value)
    # the message must say where to put a manually fetched file
    assert str(tmp_path) in msg
    assert "offline" in msg
    assert datasets._CACHE_ENV in msg


def test_corrupt_cache_detected(tmp_path):
    opener = _MockOpener(_gz_payload())
    path = datasets.fetch("ca-GrQc", cache=str(tmp_path), opener=opener)
    with open(path, "ab") as f:
        f.write(b"corruption")
    with pytest.raises(datasets.DatasetFetchError) as ei:
        datasets.fetch("ca-GrQc", cache=str(tmp_path), opener=opener)
    assert "checksum mismatch" in str(ei.value)
    assert path in str(ei.value)


def test_pinned_digest_rejects_tampered_download(tmp_path, monkeypatch):
    url, _ = datasets.REMOTE["ca-GrQc"]
    monkeypatch.setitem(datasets.REMOTE, "ca-GrQc", (url, "0" * 64))
    opener = _MockOpener(_gz_payload())
    with pytest.raises(datasets.DatasetFetchError) as ei:
        datasets.fetch("ca-GrQc", cache=str(tmp_path), opener=opener)
    assert "refusing to cache" in str(ei.value)
    assert not list(tmp_path.glob("*.txt.gz"))


def test_unknown_remote_name():
    with pytest.raises(KeyError):
        datasets.fetch("definitely-not-a-dataset")


def test_cache_dir_env_override(monkeypatch, tmp_path):
    monkeypatch.setenv(datasets._CACHE_ENV, str(tmp_path / "alt"))
    assert datasets.cache_dir() == str(tmp_path / "alt")


def test_parse_edge_text_skips_comments_and_blanks():
    arr = datasets._parse_edge_text(b"# c\n\n% x\n5 7\n7 5\n")
    assert np.array_equal(arr, np.array([[5, 7], [7, 5]]))
