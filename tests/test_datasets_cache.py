"""Dataset download cache: checksum verification + offline behavior, all
against a mocked fetch — no network in tests."""
import gzip
import io
import urllib.error

import numpy as np
import pytest

from repro.graphs import datasets


EDGE_TEXT = b"""\
# Undirected graph: mock
# FromNodeId\tToNodeId
0\t1
1\t2
2\t0
2\t3
"""


class _MockOpener:
    """urlopen stand-in serving fixed bytes and counting calls.

    ``fail`` raises on every call; ``fail_first`` raises on only the first
    N calls and then serves — the transient-outage fixture for the bounded
    retry loop."""

    def __init__(self, payload: bytes, fail: Exception | None = None,
                 fail_first: int = 0):
        self.payload = payload
        self.fail = fail
        self.fail_first = fail_first
        self.calls = 0

    def __call__(self, url):
        self.calls += 1
        if self.fail is not None:
            raise self.fail
        if self.calls <= self.fail_first:
            raise urllib.error.URLError(f"transient outage {self.calls}")
        return io.BytesIO(self.payload)


def _gz_payload() -> bytes:
    return gzip.compress(EDGE_TEXT)


def test_load_remote_parses_and_caches(tmp_path):
    opener = _MockOpener(_gz_payload())
    g = datasets.load_remote("ca-GrQc", cache=str(tmp_path), opener=opener)
    assert opener.calls == 1
    assert g.n == 4 and g.m == 4
    assert g.has_edge(0, 1) and g.has_edge(2, 3)
    # second load: served from disk, the network is never touched
    g2 = datasets.load_remote("ca-GrQc", cache=str(tmp_path), opener=opener)
    assert opener.calls == 1
    assert g2 == g
    # sha256 sidecar was recorded (trust-on-first-use)
    sidecars = list(tmp_path.glob("*.sha256"))
    assert len(sidecars) == 1


def test_offline_error_is_actionable(tmp_path):
    opener = _MockOpener(b"", fail=urllib.error.URLError("no route to host"))
    with pytest.raises(datasets.DatasetFetchError) as ei:
        datasets.load_remote("ca-GrQc", cache=str(tmp_path), opener=opener)
    msg = str(ei.value)
    # the message must say where to put a manually fetched file
    assert str(tmp_path) in msg
    assert "offline" in msg
    assert datasets._CACHE_ENV in msg


def test_corrupt_cache_detected(tmp_path):
    opener = _MockOpener(_gz_payload())
    path = datasets.fetch("ca-GrQc", cache=str(tmp_path), opener=opener)
    with open(path, "ab") as f:
        f.write(b"corruption")
    with pytest.raises(datasets.DatasetFetchError) as ei:
        datasets.fetch("ca-GrQc", cache=str(tmp_path), opener=opener)
    assert "checksum mismatch" in str(ei.value)
    assert path in str(ei.value)


def test_pinned_digest_rejects_tampered_download(tmp_path, monkeypatch):
    url, _ = datasets.REMOTE["ca-GrQc"]
    monkeypatch.setitem(datasets.REMOTE, "ca-GrQc", (url, "0" * 64))
    opener = _MockOpener(_gz_payload())
    with pytest.raises(datasets.DatasetFetchError) as ei:
        datasets.fetch("ca-GrQc", cache=str(tmp_path), opener=opener)
    assert "refusing to cache" in str(ei.value)
    assert not list(tmp_path.glob("*.txt.gz"))


def test_transient_failure_retries_then_succeeds(tmp_path):
    opener = _MockOpener(_gz_payload(), fail_first=2)
    slept = []
    path = datasets.fetch("ca-GrQc", cache=str(tmp_path), opener=opener,
                          retries=3, backoff=0.5, sleep=slept.append)
    assert opener.calls == 3  # 2 failures + the success
    assert len(slept) == 2  # one backoff before each retry
    # exponential schedule with deterministic jitter in [0.5, 1.5)
    assert 0.5 * 0.5 <= slept[0] < 0.5 * 1.5
    assert 1.0 * 0.5 <= slept[1] < 1.0 * 1.5
    # the jitter is seeded: the same retry_seed reproduces the schedule
    opener2 = _MockOpener(_gz_payload(), fail_first=2)
    slept2 = []
    datasets.fetch("ca-GrQc", cache=str(tmp_path / "b"), opener=opener2,
                   retries=3, backoff=0.5, sleep=slept2.append)
    assert slept == slept2
    with open(path, "rb") as f:
        assert f.read() == _gz_payload()


def test_distinct_retry_seeds_decorrelate_jitter(tmp_path):
    schedules = []
    for seed in (0, 1):
        opener = _MockOpener(_gz_payload(), fail_first=1)
        slept = []
        datasets.fetch("ca-GrQc", cache=str(tmp_path / str(seed)),
                       opener=opener, retry_seed=seed, sleep=slept.append)
        schedules.append(tuple(slept))
    assert schedules[0] != schedules[1]


def test_permanent_failure_exhausts_bounded_retries(tmp_path):
    opener = _MockOpener(b"", fail=urllib.error.URLError("down for good"))
    slept = []
    with pytest.raises(datasets.DatasetFetchError) as ei:
        datasets.fetch("ca-GrQc", cache=str(tmp_path), opener=opener,
                       retries=3, sleep=slept.append)
    assert opener.calls == 4  # initial attempt + 3 retries, then give up
    assert len(slept) == 3
    assert "after 4 attempts" in str(ei.value)


def test_checksum_mismatch_never_retries(tmp_path, monkeypatch):
    """A pinned-digest failure is corruption, not weather — re-downloading
    would fetch the same bad bytes, so the loop must not spin."""
    url, _ = datasets.REMOTE["ca-GrQc"]
    monkeypatch.setitem(datasets.REMOTE, "ca-GrQc", (url, "0" * 64))
    opener = _MockOpener(_gz_payload())
    slept = []
    with pytest.raises(datasets.DatasetFetchError):
        datasets.fetch("ca-GrQc", cache=str(tmp_path), opener=opener,
                       retries=3, sleep=slept.append)
    assert opener.calls == 1
    assert slept == []


def test_unknown_remote_name():
    with pytest.raises(KeyError):
        datasets.fetch("definitely-not-a-dataset")


def test_cache_dir_env_override(monkeypatch, tmp_path):
    monkeypatch.setenv(datasets._CACHE_ENV, str(tmp_path / "alt"))
    assert datasets.cache_dir() == str(tmp_path / "alt")


def test_parse_edge_text_skips_comments_and_blanks():
    arr = datasets._parse_edge_text(b"# c\n\n% x\n5 7\n7 5\n")
    assert np.array_equal(arr, np.array([[5, 7], [7, 5]]))
