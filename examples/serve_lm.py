"""Batched serving example: prefill + continuous batched decode.

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--arch", "qwen2.5-3b", "--smoke", "--requests", "6",
                "--prompt-len", "12", "--gen", "12"])
