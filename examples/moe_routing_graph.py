"""Where the paper's technique legitimately touches the LM pillar
(DESIGN.md §Arch-applicability): expert CO-ACTIVATION graphs from MoE router
logs are real-world hierarchical graphs (experts specialize in nested topic
clusters) — SLUGGER compresses them losslessly for storage/analysis.

  PYTHONPATH=src python examples/moe_routing_graph.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core import summarize
from repro.graphs.csr import Graph
from repro.models.api import get_api
from repro.models import moe as MOE

cfg = get_config("deepseek-v2-lite-16b", smoke=True)
api = get_api(cfg)
params = api.init_params(cfg, jax.random.key(0))

# run the router over a synthetic batch and log expert co-activations
rng = np.random.default_rng(0)
toks = jnp.asarray(rng.integers(0, cfg.vocab, size=(8, 64)), jnp.int32)
from repro.models import transformer as T
x = jnp.take(params["embed"], toks, axis=0)
layer0 = jax.tree.map(lambda t: t[0], params["layers"])
logits = jnp.einsum("gsd,de->gse", x, layer0["moe"]["router"].astype(x.dtype),
                    preferred_element_type=jnp.float32)
_, top_e = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.moe.top_k)
top_e = np.asarray(top_e).reshape(-1, cfg.moe.top_k)

edges = set()
for row in top_e:  # experts co-activated on the same token
    for i in range(len(row)):
        for j in range(i + 1, len(row)):
            a, b = int(row[i]), int(row[j])
            if a != b:
                edges.add((min(a, b), max(a, b)))
g = Graph.from_edge_set(cfg.moe.n_experts, edges)
print(f"expert co-activation graph: {g.n} experts, {g.m} co-activation edges")

s = summarize(g, T=10, seed=0)
print(f"SLUGGER summary: cost {s.cost()} (relative {s.relative_size(g):.3f}), "
      f"lossless={s.validate_lossless(g)}")
print("NOTE: this is offline analysis/storage — the MoE compute path itself "
      "is untouched (the technique is not a neural-network layer).")
