"""Quickstart: summarize a graph with SLUGGER, verify losslessness, inspect.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import summarize, baselines
from repro.graphs import generators as GG

# a hierarchically-structured graph (communities in communities)
g = GG.planted_hierarchy((4, 4), 10, (0.01, 0.35, 0.95), seed=7)
print(f"input graph: {g.n} nodes, {g.m} edges")

summary = summarize(g, T=20, seed=0, verbose=True)

print("\nlossless:", summary.validate_lossless(g))
st = summary.stats(g)
print(f"encoding cost |P+|+|P-|+|H| = {st['cost']}  (relative size {st['relative_size']:.3f})")
print(f"composition: {summary.composition()}")
print(f"hierarchy: max height {st['max_height']}, avg leaf depth {st['avg_leaf_depth']:.2f}")

# compare with the flat state-of-the-art (SWEG)
sw = baselines.sweg(g, T=20, seed=0)
print(f"\nSWEG (flat) relative size: {sw.relative_size(g):.3f}  "
      f"→ SLUGGER is {100*(1-st['relative_size']/sw.relative_size(g)):.1f}% smaller")

# partial decompression: neighbors straight off the summary (Algorithm 4)
u = 3
print(f"\nneighbors({u}) via partial decompression:", summary.neighbors(u)[:12], "...")
assert set(summary.neighbors(u)) == set(int(v) for v in g.neighbors(u))
print("matches the input graph exactly.")
