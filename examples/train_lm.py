"""End-to-end training driver: train a ~130M-param architecture (reduced
config on CPU) for a few hundred steps with checkpointing + fault tolerance.

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import sys

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="mamba2-130m")
    args = ap.parse_args()
    losses = train_main([
        "--arch", args.arch, "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "64",
        "--ckpt-every", "100",
        "--ckpt-dir", "/tmp/repro_example_ckpt",
    ])
    assert losses and losses[-1] < losses[0], "training must reduce loss"
    print(f"OK: loss {losses[0]:.3f} -> {losses[-1]:.3f}")
