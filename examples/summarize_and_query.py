"""Graph algorithms ON the compressed representation (paper §VIII-C):
BFS and PageRank access the graph only via neighbor queries, which the
hierarchical summary answers directly (partial decompression).

  PYTHONPATH=src python examples/summarize_and_query.py
"""
import time
from collections import deque

import numpy as np

from repro.core import summarize
from repro.graphs import datasets

g = datasets.load("PR")  # protein-like stand-in: SLUGGER's best regime
print(f"dataset PR: {g.n} nodes, {g.m} edges")
s = summarize(g, T=10, seed=0)
print(f"summary cost {s.cost()} (relative {s.relative_size(g):.3f}), lossless={s.validate_lossless(g)}")


def bfs_on_summary(summary, src):
    seen = {src}
    q = deque([src])
    order = []
    while q:
        u = q.popleft()
        order.append(u)
        for v in summary.neighbors(u):
            if int(v) not in seen:
                seen.add(int(v))
                q.append(int(v))
    return order


t0 = time.perf_counter()
order = bfs_on_summary(s, 0)
print(f"BFS on the summary reached {len(order)} nodes in {time.perf_counter()-t0:.3f}s")


def pagerank_on_summary(summary, n, iters=10, d=0.85):
    r = np.full(n, 1.0 / n)
    nbrs = [summary.neighbors(u) for u in range(n)]
    deg = np.array([max(len(x), 1) for x in nbrs])
    for _ in range(iters):
        new = np.zeros(n)
        for u in range(n):
            new[nbrs[u]] += r[u] / deg[u]
        r = d * new + (1 - d) / n
    return r


t0 = time.perf_counter()
pr = pagerank_on_summary(s, g.n)
print(f"PageRank on the summary: {time.perf_counter()-t0:.2f}s; top-5 nodes: {np.argsort(-pr)[:5].tolist()}")
