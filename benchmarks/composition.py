"""Fig. 6: composition of output edges (p / n / h proportions)."""
from __future__ import annotations

from benchmarks.common import fmt_table, save_result
from repro.core import summarize
from repro.graphs import datasets


def run(quick: bool = True):
    names = datasets.names()[:6] if quick else datasets.names()
    T = 10 if quick else 20
    rows, payload = [], {}
    for name in names:
        g = datasets.load(name)
        s = summarize(g, T=T, seed=0)
        comp = s.composition()
        tot = max(1, sum(comp.values()))
        fr = {k: v / tot for k, v in comp.items()}
        rows.append([name, comp["pos"], comp["neg"], comp["h"],
                     f"{100*fr['pos']:.1f}%", f"{100*fr['neg']:.1f}%", f"{100*fr['h']:.1f}%"])
        payload[name] = {"counts": comp, "fractions": fr}
    print("\n== Composition (Fig 6): output edge types ==")
    print(fmt_table(rows, ["dataset", "|P+|", "|P-|", "|H|", "p%", "n%", "h%"]))
    save_result("composition", payload)
    return payload
