"""Fig. 1(b): SLUGGER scales linearly with |E| (node-sampled series of the
largest stand-in, as the paper samples UK-05) — plus the partition sweep of
the stage-based engine (DESIGN.md §8).

  PYTHONPATH=src python -m benchmarks.scalability                 # Fig 1b
  PYTHONPATH=src python -m benchmarks.scalability --partitions 1,2,4
                                                                  # sweep
  PYTHONPATH=src python -m benchmarks.scalability --resident --full
                                     # resident merge rounds (BENCH_resident)

The partition sweep times ONLY the merge phase (the five engine stages, no
emission/pruning) on the 220k-edge serving bench graph (55k with --quick),
against the seed per-group loop engine as the baseline — the same protocol
`benchmarks/merge_throughput.py` uses. Artifact: ``BENCH_partitioned.json``.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import Timer, fmt_table, save_result
from repro.core import summarize
from repro.core.engine import STAGE_ORDER, SummarizerEngine
from repro.graphs import datasets, generators


def run(quick: bool = True):
    base = datasets.load("SK" if quick else "U5")
    fracs = [0.25, 0.5, 0.75, 1.0] if quick else [0.125, 0.25, 0.5, 0.75, 1.0]
    T = 5
    rows, payload = [], []
    for f in fracs:
        g = generators.sample_subgraph(base, int(base.n * f), seed=1)
        with Timer() as t:
            s = summarize(g, T=T, seed=0)
        assert s.validate_lossless(g)
        rows.append([f"{f:.3f}", g.n, g.m, f"{t.dt:.2f}s", f"{1e6*t.dt/max(g.m,1):.1f}"])
        payload.append({"frac": f, "n": g.n, "m": g.m, "time_s": t.dt})
    print("\n== Scalability (Fig 1b): time vs |E| (T=5) ==")
    print(fmt_table(rows, ["frac", "n", "m", "time", "us/edge"]))
    # linearity check: time per edge roughly constant (within 3x across range)
    upe = [p["time_s"] / max(p["m"], 1) for p in payload]
    ratio = max(upe) / max(min(upe), 1e-12)
    print(f"   max/min time-per-edge ratio: {ratio:.2f} (linear ⇒ ≈ constant)")
    save_result("scalability", {"series": payload, "tpe_ratio": ratio})
    return payload


def _merge_phase_secs(engine: SummarizerEngine, g, **run_kw) -> dict:
    engine.merge_forest(g, **run_kw)
    stats = engine.stats
    return {
        "sec": float(sum(stats[name] for name in STAGE_ORDER)),
        "stages": {name: float(stats[name]) for name in STAGE_ORDER},
        "merges": int(stats["merges"]),
        "checkpoint_sec": float(stats.get("checkpoint", 0.0)),
    }


def _checkpoint_overhead(g, backend: str, T: int) -> dict:
    """Plan-log checkpoint commit cost as a fraction of merge wall (ISSUE
    10 gate: < 5%). One engine run with per-iteration checkpointing into a
    scratch dir; the fraction compares the atomic-commit time against the
    five engine stages plus the commit itself."""
    import shutil
    import tempfile

    ckpt = tempfile.mkdtemp(prefix="slugger-ckpt-bench-")
    try:
        res = _merge_phase_secs(
            SummarizerEngine(partitions=1, backend=backend, T=T, seed=0),
            g, checkpoint_dir=ckpt)
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)
    frac = res["checkpoint_sec"] / max(res["sec"] + res["checkpoint_sec"],
                                       1e-12)
    return {"merge_sec": res["sec"], "checkpoint_sec": res["checkpoint_sec"],
            "fraction": frac, "fraction_ok": frac < 0.05}


def run_partitioned(quick: bool = True, partitions=(1, 2, 4),
                    backend: str = "numpy", T: int = 5):
    """Partition sweep: merge-phase wall time at each partition count,
    loop-engine baseline included. Writes ``BENCH_partitioned.json``."""
    name, g = (("caveman-55k", generators.caveman(1000, 11, 0.03, seed=0))
               if quick else
               ("caveman-220k", generators.caveman(4000, 11, 0.03, seed=0)))
    loop = _merge_phase_secs(
        SummarizerEngine(partitions=1, backend="loop", T=T, seed=0), g)
    rows = [[name, g.m, "loop", 1, f"{loop['sec']:.2f}s", loop["merges"],
             "1.00x", "-"]]
    sweep = {}
    for k in partitions:
        res = _merge_phase_secs(
            SummarizerEngine(partitions=int(k), backend=backend, T=T,
                             seed=0), g)
        res["speedup_vs_loop"] = loop["sec"] / res["sec"]
        sweep[int(k)] = res
    # "vs p1" is meaningful only when partitions=1 is actually in the sweep
    base_p1 = sweep[1]["sec"] if 1 in sweep else None
    for k, res in sweep.items():
        res["speedup_vs_p1"] = (base_p1 / res["sec"]
                                if base_p1 is not None else None)
        rows.append([name, g.m, backend, k, f"{res['sec']:.2f}s",
                     res["merges"], f"{res['speedup_vs_loop']:.2f}x",
                     "-" if res["speedup_vs_p1"] is None
                     else f"{res['speedup_vs_p1']:.2f}x"])
    # the sweep is only meaningful if every partition count merged the same
    # forest — the engine guarantees it, assert it here too
    merge_counts = {r["merges"] for r in sweep.values()}
    assert len(merge_counts) == 1, f"partition counts disagree: {sweep}"
    print(f"\n== Partition sweep: merge phase on {name} (T={T}) ==")
    print(fmt_table(rows, ["graph", "m", "engine", "parts", "time", "merges",
                           "vs loop", "vs p1"]))
    ckpt = _checkpoint_overhead(g, backend, T)
    print(f"   checkpoint commit overhead: {ckpt['checkpoint_sec']*1e3:.1f}ms "
          f"over {ckpt['merge_sec']:.2f}s merge = "
          f"{100*ckpt['fraction']:.2f}% (gate < 5%)")
    payload = {"graph": name, "m": g.m, "T": T, "backend": backend,
               "loop_baseline": loop, "partitions": sweep,
               "checkpoint_overhead": ckpt}
    save_result("BENCH_partitioned", payload)
    assert ckpt["fraction_ok"], (
        f"checkpoint commit cost {100*ckpt['fraction']:.2f}% of "
        f"per-iteration wall exceeds the 5% gate")
    return payload


def _steady_bytes_per_iter(transfer_iters: list) -> float:
    """Steady-state marginal bytes per iteration: the mean over iterations
    2..T. Iteration 1 is EXCLUDED by protocol (ISSUE 9 satellite): it pays
    the one-time run-context init and the adjacency-bank seeding, which the
    artifact records separately (``seeding_bytes``) — the marginal cost is
    what scales with T."""
    tail = transfer_iters[1:] or transfer_iters
    return float(np.mean([d["bytes_total"] for d in tail])) if tail else 0.0


def _steady_phase_bytes(transfer_iters: list) -> dict:
    """Per-phase steady-state bytes/iteration (mean over iterations 2..T,
    same exclusion as `_steady_bytes_per_iter`)."""
    tail = transfer_iters[1:] or transfer_iters
    if not tail:
        return {}
    phases = sorted({p for d in tail for p in d["phases"]})
    return {p: float(np.mean([d["phases"].get(p, 0) for d in tail]))
            for p in phases}


# PR 6 steady-state numbers at the 220k --full config (the pre-bank
# host-rebuilt path) — the ISSUE 9 acceptance gates measure against them
_PR6_STEADY_UPLOAD_BYTES = 14_592_680.0   # phase=upload bytes/iteration
_PR6_MERGE_WALL_SEC = 8.682               # pack + merge_round stages


def run_resident(quick: bool = True, smoke: bool = False):
    """Whole-iteration device residency vs the batched mesh path (ISSUE 7).

    Both engines run the SAME config (unified u32 shingles — merge
    decisions are asserted identical) on the scalability bench graph. The
    batched mesh baseline ships the (B, G, W) bitmap batch to devices and
    pulls a dense (B, G, G) intersection matrix back EVERY round; the
    resident backend keeps the whole iteration device-resident: counts and
    bitmaps live in the arena, each round exchanges a 12-byte/pair fold
    instruction up and (K, 2) int8 verdicts down, candidate shingles
    compute from the device-held edges + root map (phase ``candgen``), and
    the root map advances by replaying applied merge plans (phase
    ``carry``) — DESIGN.md §9.

    Protocol: two reps per engine, gate on the faster (steady state — jit
    caches warm; rep timings both land in the artifact). Bytes are
    deterministic and come from the `core.transfer` counter; a "round" is
    one ranking round-trip, and the artifact carries the per-iteration
    per-phase byte breakdown (upload/rank/fold/carry/candgen) from the
    engine's ``transfer_iters`` stats.

    The byte ledger is phase-honest, and with the adjacency bank (ISSUE 9)
    the per-iteration ``upload`` phase is GONE in steady state: the bank
    seeds once (iteration 1, phase ``init``), advances from the tiny
    per-batch plan slabs (phase ``bank``, 32 B per applied pair), and
    extraction builds next-iteration packed bitmaps and count tensors
    entirely on device from index slabs (phase ``extract``) — host
    workspaces are shape-only shells. The steady-state protocol therefore
    EXCLUDES iteration 1 from per-phase averages (it pays the one-time
    seeding, recorded as its own ``seeding_bytes`` field) and gates the
    marginal iterations 2..T. Gates (``BENCH_resident.json``):

    * merge decisions bit-identical (always enforced),
    * round-EXCHANGE bytes/round (resident rank+fold+carry+candgen vs the
      batched path's per-round total — batched has no amortized phase, its
      every byte is round traffic) reduced ≥ 4x (quick/full; smoke byte
      counts are too small to be meaningful),
    * steady-state TOTAL bytes/iteration no worse than the batched path
      (≥ 1.0x, quick/full),
    * steady-state ``upload`` bytes/iteration ≈ 0 (≤ 64 KiB slack;
      enforced whenever the bank engaged — the bank path re-uploads
      nothing, so any recurring upload is a regression),
    * steady-state upload reduced ≥ 4x vs the recorded PR 6 number
      (14.59 MB/iter at the 220k --full config; enforced at ``--full``),
    * merge phase (pack + merge_round) ≥ 2.5x faster than the recorded
      PR 6 wall (8.682 s at --full; enforced at ``--full`` only — 2-core
      CI runners are too noisy to gate wall time on the small graphs).

    At ``--full`` the artifact also carries ``large_run``: a resident-only
    multi-million-edge RMAT run (scale 19, ~4M directed edges) proving the
    bank path at paper scale.

    ``smoke`` is the CI config: a tiny graph at T=3 (≥ 3 iterations, so
    carry-over across iterations is exercised, not just one upload), and
    typically run with ``REPRO_FORCE_PALLAS=1`` so the resident path
    exercises the Pallas kernels in interpret mode (bit-identity still
    enforced).
    """
    from repro.launch.mesh import make_data_mesh

    if smoke:
        name, g, T = "caveman-1k", generators.caveman(40, 5, 0.05, seed=0), 3
    elif quick:
        name, g, T = "caveman-55k", generators.caveman(1000, 11, 0.03, seed=0), 5
    else:
        name, g, T = "caveman-220k", generators.caveman(4000, 11, 0.03, seed=0), 5
    mesh = make_data_mesh()
    rows, results = [], {}
    for be in ("batched", "resident"):
        # the resident engine runs the single-device whole-iteration path
        # (run context + propose protocol); the baseline keeps the mesh
        # dispatch it has always used
        eng_mesh = mesh if be == "batched" else None
        # rep 1 pays every jit compile; at --full the resident engine gets
        # a third rep so the per-stage minima (the PR 6 merge-wall gate)
        # come from two warm samples, not one
        n_reps = 1 if smoke else 2
        if not (smoke or quick) and be == "resident":
            n_reps = 3
        reps = []
        for _ in range(n_reps):
            eng = SummarizerEngine(partitions=1, backend=be, T=T, seed=0,
                                   mesh=eng_mesh)
            reps.append(_merge_phase_secs(eng, g)
                        | {"transfer": eng.stats["transfer"],
                           "transfer_iters": eng.stats["transfer_iters"]})
        best = min(reps, key=lambda r: r["sec"])
        iters = best["transfer_iters"]
        results[be] = {"reps": reps, "best_sec": best["sec"],
                       "merges": best["merges"],
                       "transfer": best["transfer"],
                       "transfer_iters": iters,
                       "steady_bytes_per_iter": _steady_bytes_per_iter(iters),
                       "steady_phase_bytes_per_iter":
                           _steady_phase_bytes(iters),
                       # iteration 1's bytes = one-time seeding (bank init +
                       # first extraction warm-up) — excluded from steady state
                       "seeding_bytes": (float(iters[0]["bytes_total"])
                                         if iters else 0.0),
                       "seeding_phases": (dict(iters[0]["phases"])
                                          if iters else {})}
        tr = best["transfer"]
        rows.append([name, g.m, be, f"{best['sec']:.2f}s", best["merges"],
                     tr["rounds"], f"{tr['bytes_total']/1e6:.2f}MB",
                     f"{tr['bytes_per_round']/1e3:.0f}KB",
                     f"{results[be]['steady_bytes_per_iter']/1e3:.0f}KB"])
    b, r = results["batched"], results["resident"]
    speedup = b["best_sec"] / r["best_sec"]
    rph = r["transfer"]["phases"]
    exchange = sum(rph.get(k, 0) for k in ("rank", "fold", "carry", "candgen"))
    exch_per_round = exchange / max(r["transfer"]["rounds"], 1)
    exch_ratio = b["transfer"]["bytes_per_round"] / max(exch_per_round, 1e-9)
    iter_ratio = (b["steady_bytes_per_iter"]
                  / max(r["steady_bytes_per_iter"], 1e-9))
    steady_upload = r["steady_phase_bytes_per_iter"].get("upload", 0.0)
    upload_reduction = _PR6_STEADY_UPLOAD_BYTES / max(steady_upload, 1.0)
    merge_wall = float(sum(min(rep["stages"][s] for rep in r["reps"])
                           for s in ("pack", "merge_round")))
    merge_speedup = _PR6_MERGE_WALL_SEC / max(merge_wall, 1e-9)
    gates = {
        "decisions_identical": b["merges"] == r["merges"],
        "speedup_vs_batched_mesh": speedup,
        "speedup_ok": speedup >= 2.5,
        "exchange_bytes_per_round": exch_per_round,
        "exchange_bytes_per_round_ratio": exch_ratio,
        "exchange_ok": exch_ratio >= 4.0,
        "bytes_per_iter_ratio": iter_ratio,
        "bytes_per_iter_ok": iter_ratio >= 1.0,
        "steady_upload_bytes_per_iter": steady_upload,
        "steady_upload_ok": steady_upload <= 65536.0,
        "upload_reduction_vs_pr6": upload_reduction,
        "upload_reduction_ok": upload_reduction >= 4.0,
        "merge_wall_sec": merge_wall,
        "merge_speedup_vs_pr6": merge_speedup,
        "merge_speedup_ok": merge_speedup >= 2.5,
    }
    print(f"\n== Resident whole-iteration residency vs batched mesh path on "
          f"{name} (T={T}) ==")
    print(fmt_table(rows, ["graph", "m", "engine", "time", "merges",
                           "rounds", "bytes", "bytes/round", "bytes/iter"]))
    print("   resident phase bytes: " + " ".join(
        f"{k}={v/1e3:.0f}KB" for k, v in sorted(rph.items())))
    print("   resident steady phase bytes/iter: " + " ".join(
        f"{k}={v/1e3:.0f}KB"
        for k, v in sorted(r["steady_phase_bytes_per_iter"].items())))
    print(f"   seeding (iter 1, excluded): "
          f"{r['seeding_bytes']/1e6:.2f}MB")
    print(f"   speedup {speedup:.2f}x (gate ≥ 2.5x at --full) · exchange "
          f"bytes/round {exch_per_round/1e3:.0f}KB vs "
          f"{b['transfer']['bytes_per_round']/1e3:.0f}KB = {exch_ratio:.2f}x "
          f"(gate ≥ 4x) · total bytes/iter {iter_ratio:.2f}x (gate ≥ 1x)")
    print(f"   steady upload {steady_upload/1e3:.1f}KB/iter = "
          f"{upload_reduction:.1f}x under PR 6's "
          f"{_PR6_STEADY_UPLOAD_BYTES/1e6:.2f}MB (gate ≥ 4x at --full) · "
          f"merge wall {merge_wall:.2f}s = {merge_speedup:.2f}x vs PR 6's "
          f"{_PR6_MERGE_WALL_SEC:.2f}s (gate ≥ 2.5x at --full)")
    payload = {"graph": name, "m": g.m, "T": T, "engines": results,
               "gates": gates,
               "pr6_baseline": {
                   "steady_upload_bytes_per_iter": _PR6_STEADY_UPLOAD_BYTES,
                   "merge_wall_sec": _PR6_MERGE_WALL_SEC}}
    if not (smoke or quick):
        payload["large_run"] = run_resident_large()
    save_result("BENCH_resident", payload)
    assert gates["decisions_identical"], (
        f"resident merge decisions diverged from batched: "
        f"{b['merges']} vs {r['merges']}")
    assert gates["steady_upload_ok"], (
        f"bank path re-uploaded {steady_upload:.0f} B/iter in steady "
        f"state — the adjacency bank should make this ~0")
    if not smoke:
        assert gates["exchange_ok"], (
            f"exchange bytes/round reduction {exch_ratio:.2f}x below the "
            f"4x gate")
        assert gates["bytes_per_iter_ok"], (
            f"total bytes/iteration {iter_ratio:.2f}x regressed vs the "
            f"batched path")
    if not (smoke or quick):
        assert gates["speedup_ok"], (
            f"resident speedup {speedup:.2f}x below the 2.5x gate")
        assert gates["upload_reduction_ok"], (
            f"steady upload reduction {upload_reduction:.1f}x vs PR 6 "
            f"below the 4x gate")
        assert gates["merge_speedup_ok"], (
            f"merge wall {merge_wall:.2f}s is only {merge_speedup:.2f}x "
            f"vs PR 6's {_PR6_MERGE_WALL_SEC:.2f}s (gate ≥ 2.5x)")
    return payload


def run_resident_large(scale: int = 19, T: int = 3):
    """Resident-only multi-million-edge RMAT run (the ISSUE 9 artifact's
    ``large_run``): no batched baseline (it would dominate wall time), just
    the bank path at paper scale with its steady-state byte profile. The
    lossless check pins correctness at this size."""
    g = generators.rmat(scale, seed=0)
    name = f"rmat-{scale}"
    eng = SummarizerEngine(partitions=1, backend="resident", T=T, seed=0)
    with Timer() as t:
        s = eng.run(g)  # one run: Summary (lossless check) + engine stats
    iters = eng.stats["transfer_iters"]
    assert s.validate_lossless(g)
    assert eng._run_ctx is not None and eng._run_ctx.bank is not None, (
        "bank did not engage on the large run")
    steady = _steady_phase_bytes(iters)
    out = {"graph": name, "n": g.n, "m": g.m, "T": T,
           "summarize_sec": float(t.dt),
           "merge_sec": float(sum(eng.stats[s_] for s_ in STAGE_ORDER)),
           "merges": int(eng.stats["merges"]),
           "steady_bytes_per_iter": _steady_bytes_per_iter(iters),
           "steady_phase_bytes_per_iter": steady,
           "seeding_bytes": float(iters[0]["bytes_total"]) if iters else 0.0}
    print(f"\n== Resident large run: {name} (n={g.n}, m={g.m}, T={T}) ==")
    print(f"   summarize {t.dt:.2f}s · merge phase {out['merge_sec']:.2f}s · "
          f"steady bytes/iter {out['steady_bytes_per_iter']/1e6:.2f}MB · "
          f"steady upload {steady.get('upload', 0.0):.0f}B")
    assert steady.get("upload", 0.0) <= 65536.0
    return out


def run_bank_smoke():
    """CI bank-carry smoke (ISSUE 9): a tiny T=3 resident run, asserting
    the bank engaged, steady-state upload is zero, and decisions match the
    numpy backend bit for bit. Pair with ``REPRO_FORCE_PALLAS=1`` so the
    extraction/fold kernels run (interpret mode on CPU). The run checkpoints
    every iteration (ISSUE 10): plan-log commits are host-side file IO, so
    the zero-steady-upload property must survive them unchanged."""
    import shutil
    import tempfile

    g = generators.caveman(40, 5, 0.05, seed=0)
    want = summarize(g, T=3, seed=0, backend="numpy")
    eng = SummarizerEngine(partitions=1, backend="resident", T=3, seed=0)
    ckpt = tempfile.mkdtemp(prefix="slugger-ckpt-smoke-")
    try:
        eng.merge_forest(g, checkpoint_dir=ckpt)
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)
    got = summarize(g, T=3, seed=0, backend="resident")
    assert np.array_equal(want.parent, got.parent)
    assert np.array_equal(want.edges, got.edges)
    assert eng._run_ctx is not None and eng._run_ctx.bank is not None, (
        "bank did not engage on the smoke graph")
    iters = eng.stats["transfer_iters"]
    steady = _steady_phase_bytes(iters)
    assert steady.get("upload", 0.0) == 0.0, steady
    assert steady.get("carry", 0.0) == 0.0, steady  # superseded by `bank`
    assert iters and iters[0]["phases"].get("init", 0) > 0  # seeded once
    print(f"bank smoke OK: n={g.n} m={g.m} merges={int(eng.stats['merges'])} "
          f"seeding={iters[0]['bytes_total']/1e3:.1f}KB steady phases=" +
          " ".join(f"{k}={v:.0f}B" for k, v in sorted(steady.items())))


def main(argv=None):
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true",
                      help="small graph (default)")
    mode.add_argument("--full", action="store_true",
                      help="paper-scale settings (220k-edge sweep graph)")
    ap.add_argument("--partitions", default=None,
                    help="comma-separated partition counts; selects the "
                         "partition-sweep mode (e.g. --partitions 1,2,4)")
    ap.add_argument("--backend", default="numpy",
                    choices=("numpy", "batched"))
    ap.add_argument("--resident", action="store_true",
                    help="resident-vs-batched merge-round comparison "
                         "(BENCH_resident.json)")
    ap.add_argument("--resident-smoke", action="store_true",
                    help="tiny resident equivalence smoke (CI; pair with "
                         "REPRO_FORCE_PALLAS=1 to exercise the kernels)")
    ap.add_argument("--bank-smoke", action="store_true",
                    help="tiny adjacency-bank carry smoke (CI): bank "
                         "engaged, steady upload == 0, decisions == numpy")
    args = ap.parse_args(argv)
    if args.bank_smoke:
        run_bank_smoke()
    elif args.resident or args.resident_smoke:
        run_resident(quick=not args.full, smoke=args.resident_smoke)
    elif args.partitions:
        ks = tuple(int(x) for x in args.partitions.split(","))
        run_partitioned(quick=not args.full, partitions=ks,
                        backend=args.backend)
    else:
        run(quick=not args.full)


if __name__ == "__main__":
    main()
