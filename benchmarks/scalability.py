"""Fig. 1(b): SLUGGER scales linearly with |E| (node-sampled series of the
largest stand-in, as the paper samples UK-05)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, fmt_table, save_result
from repro.core import summarize
from repro.graphs import datasets, generators


def run(quick: bool = True):
    base = datasets.load("SK" if quick else "U5")
    fracs = [0.25, 0.5, 0.75, 1.0] if quick else [0.125, 0.25, 0.5, 0.75, 1.0]
    T = 5
    rows, payload = [], []
    for f in fracs:
        g = generators.sample_subgraph(base, int(base.n * f), seed=1)
        with Timer() as t:
            s = summarize(g, T=T, seed=0)
        assert s.validate_lossless(g)
        rows.append([f"{f:.3f}", g.n, g.m, f"{t.dt:.2f}s", f"{1e6*t.dt/max(g.m,1):.1f}"])
        payload.append({"frac": f, "n": g.n, "m": g.m, "time_s": t.dt})
    print("\n== Scalability (Fig 1b): time vs |E| (T=5) ==")
    print(fmt_table(rows, ["frac", "n", "m", "time", "us/edge"]))
    # linearity check: time per edge roughly constant (within 3x across range)
    upe = [p["time_s"] / max(p["m"], 1) for p in payload]
    ratio = max(upe) / max(min(upe), 1e-12)
    print(f"   max/min time-per-edge ratio: {ratio:.2f} (linear ⇒ ≈ constant)")
    save_result("scalability", {"series": payload, "tpe_ratio": ratio})
    return payload
