"""Fig. 1(b): SLUGGER scales linearly with |E| (node-sampled series of the
largest stand-in, as the paper samples UK-05) — plus the partition sweep of
the stage-based engine (DESIGN.md §8).

  PYTHONPATH=src python -m benchmarks.scalability                 # Fig 1b
  PYTHONPATH=src python -m benchmarks.scalability --partitions 1,2,4
                                                                  # sweep
  PYTHONPATH=src python -m benchmarks.scalability --resident --full
                                     # resident merge rounds (BENCH_resident)

The partition sweep times ONLY the merge phase (the five engine stages, no
emission/pruning) on the 220k-edge serving bench graph (55k with --quick),
against the seed per-group loop engine as the baseline — the same protocol
`benchmarks/merge_throughput.py` uses. Artifact: ``BENCH_partitioned.json``.
"""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import Timer, fmt_table, save_result
from repro.core import summarize
from repro.core.engine import STAGE_ORDER, SummarizerEngine
from repro.graphs import datasets, generators


def run(quick: bool = True):
    base = datasets.load("SK" if quick else "U5")
    fracs = [0.25, 0.5, 0.75, 1.0] if quick else [0.125, 0.25, 0.5, 0.75, 1.0]
    T = 5
    rows, payload = [], []
    for f in fracs:
        g = generators.sample_subgraph(base, int(base.n * f), seed=1)
        with Timer() as t:
            s = summarize(g, T=T, seed=0)
        assert s.validate_lossless(g)
        rows.append([f"{f:.3f}", g.n, g.m, f"{t.dt:.2f}s", f"{1e6*t.dt/max(g.m,1):.1f}"])
        payload.append({"frac": f, "n": g.n, "m": g.m, "time_s": t.dt})
    print("\n== Scalability (Fig 1b): time vs |E| (T=5) ==")
    print(fmt_table(rows, ["frac", "n", "m", "time", "us/edge"]))
    # linearity check: time per edge roughly constant (within 3x across range)
    upe = [p["time_s"] / max(p["m"], 1) for p in payload]
    ratio = max(upe) / max(min(upe), 1e-12)
    print(f"   max/min time-per-edge ratio: {ratio:.2f} (linear ⇒ ≈ constant)")
    save_result("scalability", {"series": payload, "tpe_ratio": ratio})
    return payload


def _merge_phase_secs(engine: SummarizerEngine, g) -> dict:
    engine.merge_forest(g)
    stats = engine.stats
    return {
        "sec": float(sum(stats[name] for name in STAGE_ORDER)),
        "stages": {name: float(stats[name]) for name in STAGE_ORDER},
        "merges": int(stats["merges"]),
    }


def run_partitioned(quick: bool = True, partitions=(1, 2, 4),
                    backend: str = "numpy", T: int = 5):
    """Partition sweep: merge-phase wall time at each partition count,
    loop-engine baseline included. Writes ``BENCH_partitioned.json``."""
    name, g = (("caveman-55k", generators.caveman(1000, 11, 0.03, seed=0))
               if quick else
               ("caveman-220k", generators.caveman(4000, 11, 0.03, seed=0)))
    loop = _merge_phase_secs(
        SummarizerEngine(partitions=1, backend="loop", T=T, seed=0), g)
    rows = [[name, g.m, "loop", 1, f"{loop['sec']:.2f}s", loop["merges"],
             "1.00x", "-"]]
    sweep = {}
    for k in partitions:
        res = _merge_phase_secs(
            SummarizerEngine(partitions=int(k), backend=backend, T=T,
                             seed=0), g)
        res["speedup_vs_loop"] = loop["sec"] / res["sec"]
        sweep[int(k)] = res
    # "vs p1" is meaningful only when partitions=1 is actually in the sweep
    base_p1 = sweep[1]["sec"] if 1 in sweep else None
    for k, res in sweep.items():
        res["speedup_vs_p1"] = (base_p1 / res["sec"]
                                if base_p1 is not None else None)
        rows.append([name, g.m, backend, k, f"{res['sec']:.2f}s",
                     res["merges"], f"{res['speedup_vs_loop']:.2f}x",
                     "-" if res["speedup_vs_p1"] is None
                     else f"{res['speedup_vs_p1']:.2f}x"])
    # the sweep is only meaningful if every partition count merged the same
    # forest — the engine guarantees it, assert it here too
    merge_counts = {r["merges"] for r in sweep.values()}
    assert len(merge_counts) == 1, f"partition counts disagree: {sweep}"
    print(f"\n== Partition sweep: merge phase on {name} (T={T}) ==")
    print(fmt_table(rows, ["graph", "m", "engine", "parts", "time", "merges",
                           "vs loop", "vs p1"]))
    payload = {"graph": name, "m": g.m, "T": T, "backend": backend,
               "loop_baseline": loop, "partitions": sweep}
    save_result("BENCH_partitioned", payload)
    return payload


def _steady_bytes_per_iter(transfer_iters: list) -> float:
    """Steady-state marginal bytes per iteration: the mean over iterations
    2..T (iteration 1 pays one-time jit/compile-adjacent uploads and the
    run-context init — the marginal cost is what scales with T)."""
    tail = transfer_iters[1:] or transfer_iters
    return float(np.mean([d["bytes_total"] for d in tail])) if tail else 0.0


def run_resident(quick: bool = True, smoke: bool = False):
    """Whole-iteration device residency vs the batched mesh path (ISSUE 7).

    Both engines run the SAME config (unified u32 shingles — merge
    decisions are asserted identical) on the scalability bench graph. The
    batched mesh baseline ships the (B, G, W) bitmap batch to devices and
    pulls a dense (B, G, G) intersection matrix back EVERY round; the
    resident backend keeps the whole iteration device-resident: counts and
    bitmaps live in the arena, each round exchanges a 12-byte/pair fold
    instruction up and (K, 2) int8 verdicts down, candidate shingles
    compute from the device-held edges + root map (phase ``candgen``), and
    the root map advances by replaying applied merge plans (phase
    ``carry``) — DESIGN.md §9.

    Protocol: two reps per engine, gate on the faster (steady state — jit
    caches warm; rep timings both land in the artifact). Bytes are
    deterministic and come from the `core.transfer` counter; a "round" is
    one ranking round-trip, and the artifact carries the per-iteration
    per-phase byte breakdown (upload/rank/fold/carry/candgen) from the
    engine's ``transfer_iters`` stats.

    The byte ledger is phase-honest: moving the Saving evaluation on
    device means the exact count tensors (CNT et al.) now SHIP in the
    per-iteration ``upload`` phase — several times PR 5's bitmap-only
    upload — while the per-ROUND exchange collapsed to instructions up +
    verdicts down. Eliminating the upload phase (deriving next-iteration
    workspaces on device from the applied plans) is the bitmap-bank-carry
    ROADMAP item; until it lands, the upload dominates total bytes and is
    gated only against regression. Gates (``BENCH_resident.json``):

    * merge decisions bit-identical (always enforced),
    * round-EXCHANGE bytes/round (resident rank+fold+carry+candgen vs the
      batched path's per-round total — batched has no amortized phase, its
      every byte is round traffic) reduced ≥ 4x (quick/full; smoke byte
      counts are too small to be meaningful),
    * steady-state TOTAL bytes/iteration no worse than the batched path
      (≥ 1.0x, quick/full — holds despite the count-tensor upload),
    * merge phase ≥ 2.5x (enforced at the 220k-edge ``--full`` config the
      acceptance criterion names; recorded elsewhere — 2-core CI runners
      are too noisy to gate wall time on the small graphs).

    ``smoke`` is the CI config: a tiny graph at T=3 (≥ 3 iterations, so
    carry-over across iterations is exercised, not just one upload), and
    typically run with ``REPRO_FORCE_PALLAS=1`` so the resident path
    exercises the Pallas kernels in interpret mode (bit-identity still
    enforced).
    """
    from repro.launch.mesh import make_data_mesh

    if smoke:
        name, g, T = "caveman-1k", generators.caveman(40, 5, 0.05, seed=0), 3
    elif quick:
        name, g, T = "caveman-55k", generators.caveman(1000, 11, 0.03, seed=0), 5
    else:
        name, g, T = "caveman-220k", generators.caveman(4000, 11, 0.03, seed=0), 5
    mesh = make_data_mesh()
    rows, results = [], {}
    for be in ("batched", "resident"):
        # the resident engine runs the single-device whole-iteration path
        # (run context + propose protocol); the baseline keeps the mesh
        # dispatch it has always used
        eng_mesh = mesh if be == "batched" else None
        reps = []
        for _ in range(1 if smoke else 2):
            eng = SummarizerEngine(partitions=1, backend=be, T=T, seed=0,
                                   mesh=eng_mesh)
            reps.append(_merge_phase_secs(eng, g)
                        | {"transfer": eng.stats["transfer"],
                           "transfer_iters": eng.stats["transfer_iters"]})
        best = min(reps, key=lambda r: r["sec"])
        results[be] = {"reps": reps, "best_sec": best["sec"],
                       "merges": best["merges"],
                       "transfer": best["transfer"],
                       "transfer_iters": best["transfer_iters"],
                       "steady_bytes_per_iter":
                           _steady_bytes_per_iter(best["transfer_iters"])}
        tr = best["transfer"]
        rows.append([name, g.m, be, f"{best['sec']:.2f}s", best["merges"],
                     tr["rounds"], f"{tr['bytes_total']/1e6:.2f}MB",
                     f"{tr['bytes_per_round']/1e3:.0f}KB",
                     f"{results[be]['steady_bytes_per_iter']/1e3:.0f}KB"])
    b, r = results["batched"], results["resident"]
    speedup = b["best_sec"] / r["best_sec"]
    rph = r["transfer"]["phases"]
    exchange = sum(rph.get(k, 0) for k in ("rank", "fold", "carry", "candgen"))
    exch_per_round = exchange / max(r["transfer"]["rounds"], 1)
    exch_ratio = b["transfer"]["bytes_per_round"] / max(exch_per_round, 1e-9)
    iter_ratio = (b["steady_bytes_per_iter"]
                  / max(r["steady_bytes_per_iter"], 1e-9))
    gates = {
        "decisions_identical": b["merges"] == r["merges"],
        "speedup_vs_batched_mesh": speedup,
        "speedup_ok": speedup >= 2.5,
        "exchange_bytes_per_round": exch_per_round,
        "exchange_bytes_per_round_ratio": exch_ratio,
        "exchange_ok": exch_ratio >= 4.0,
        "bytes_per_iter_ratio": iter_ratio,
        "bytes_per_iter_ok": iter_ratio >= 1.0,
    }
    print(f"\n== Resident whole-iteration residency vs batched mesh path on "
          f"{name} (T={T}) ==")
    print(fmt_table(rows, ["graph", "m", "engine", "time", "merges",
                           "rounds", "bytes", "bytes/round", "bytes/iter"]))
    print("   resident phase bytes: " + " ".join(
        f"{k}={v/1e3:.0f}KB" for k, v in sorted(rph.items())))
    print(f"   speedup {speedup:.2f}x (gate ≥ 2.5x at --full) · exchange "
          f"bytes/round {exch_per_round/1e3:.0f}KB vs "
          f"{b['transfer']['bytes_per_round']/1e3:.0f}KB = {exch_ratio:.2f}x "
          f"(gate ≥ 4x) · total bytes/iter {iter_ratio:.2f}x (gate ≥ 1x)")
    payload = {"graph": name, "m": g.m, "T": T, "engines": results,
               "gates": gates}
    save_result("BENCH_resident", payload)
    assert gates["decisions_identical"], (
        f"resident merge decisions diverged from batched: "
        f"{b['merges']} vs {r['merges']}")
    if not smoke:
        assert gates["exchange_ok"], (
            f"exchange bytes/round reduction {exch_ratio:.2f}x below the "
            f"4x gate")
        assert gates["bytes_per_iter_ok"], (
            f"total bytes/iteration {iter_ratio:.2f}x regressed vs the "
            f"batched path")
    if not (smoke or quick):
        assert gates["speedup_ok"], (
            f"resident speedup {speedup:.2f}x below the 2.5x gate")
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true",
                      help="small graph (default)")
    mode.add_argument("--full", action="store_true",
                      help="paper-scale settings (220k-edge sweep graph)")
    ap.add_argument("--partitions", default=None,
                    help="comma-separated partition counts; selects the "
                         "partition-sweep mode (e.g. --partitions 1,2,4)")
    ap.add_argument("--backend", default="numpy",
                    choices=("numpy", "batched"))
    ap.add_argument("--resident", action="store_true",
                    help="resident-vs-batched merge-round comparison "
                         "(BENCH_resident.json)")
    ap.add_argument("--resident-smoke", action="store_true",
                    help="tiny resident equivalence smoke (CI; pair with "
                         "REPRO_FORCE_PALLAS=1 to exercise the kernels)")
    args = ap.parse_args(argv)
    if args.resident or args.resident_smoke:
        run_resident(quick=not args.full, smoke=args.resident_smoke)
    elif args.partitions:
        ks = tuple(int(x) for x in args.partitions.split(","))
        run_partitioned(quick=not args.full, partitions=ks,
                        backend=args.backend)
    else:
        run(quick=not args.full)


if __name__ == "__main__":
    main()
