"""§VIII-B: partial-decompression latency — neighbor queries directly on the
summary, plus PageRank run on the compressed representation (§VIII-C)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import fmt_table, save_result
from repro.core import summarize
from repro.graphs import datasets


def pagerank_on_summary(s, n, iters=5, d=0.85):
    r = np.full(n, 1.0 / n)
    deg = np.array([len(s.neighbors(u)) for u in range(n)], dtype=np.float64)
    for _ in range(iters):
        new = np.zeros(n)
        for u in range(n):
            nb = s.neighbors(u)
            if len(nb):
                new[nb] += r[u] / deg[u]
        r = d * new + (1 - d) / n
    return r


def run(quick: bool = True):
    names = ["PR", "FA", "CA"] if quick else datasets.names()[:8]
    rows, payload = [], {}
    for name in names:
        g = datasets.load(name)
        s = summarize(g, T=10, seed=0)
        rng = np.random.default_rng(0)
        qs = rng.integers(0, g.n, size=min(2000, g.n))
        s.neighbors(int(qs[0]))  # warm caches
        t0 = time.perf_counter()
        for u in qs:
            s.neighbors(int(u))
        dt = (time.perf_counter() - t0) / len(qs)
        # PageRank on the compressed representation vs on the raw graph
        pr_c = pagerank_on_summary(s, g.n, iters=3)
        r = np.full(g.n, 1.0 / g.n)
        deg = np.maximum(g.degree(), 1)
        for _ in range(3):
            new = np.zeros(g.n)
            for u in range(g.n):
                new[g.neighbors(u)] += r[u] / deg[u]
            r = 0.85 * new + 0.15 / g.n
        corr = float(np.corrcoef(pr_c, r)[0, 1])
        rows.append([name, f"{dt*1e6:.1f}µs", f"{corr:.5f}"])
        payload[name] = {"neighbor_query_us": dt * 1e6, "pagerank_corr": corr}
    print("\n== Partial decompression (§VIII-B): per-query latency; PageRank on summary ==")
    print(fmt_table(rows, ["dataset", "query", "PR corr"]))
    save_result("decompression", payload)
    return payload
