"""Render EXPERIMENTS.md tables from artifacts/dryrun/*.json.

  PYTHONPATH=src python -m benchmarks.render_experiments [--out artifacts/tables]

Produces markdown fragments: dryrun_table.md (all 80 cells), roofline_table.md
(single-pod baselines with the three terms + bottleneck + hint).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

HINTS = {
    "compute": "reduce recompute (remat policy) / skip masked attention blocks",
    "memory": "raise arithmetic intensity: larger per-device batch, fuse, or cut optimizer/grad traffic",
    "collective": "reshard to cut all-gather/all-reduce volume; overlap with compute",
}


def _load(mesh):
    cells = {}
    for f in glob.glob(os.path.join(ART, f"*__{mesh}.json")):
        d = json.load(open(f))
        cells[(d["arch"], d["shape"])] = d
    return cells


def _fmt_t(x):
    if x is None:
        return "-"
    return f"{x*1e3:.1f}ms" if x < 1 else f"{x:.2f}s"


def dryrun_table(archs):
    single, multi = _load("single"), _load("multi")
    lines = [
        "| arch | shape | mesh | status | HBM/dev (meas) | HBM/dev (analytic) | compile | collectives (count) |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in archs:
        for shape in SHAPE_ORDER:
            for mesh, cells in (("single(256)", single), ("multi(512)", multi)):
                d = cells.get((arch, shape))
                if d is None:
                    lines.append(f"| {arch} | {shape} | {mesh} | MISSING | | | | |")
                    continue
                if d["status"] == "skipped":
                    lines.append(f"| {arch} | {shape} | {mesh} | skip | — | — | — | {d['reason'].split('(')[0]} |")
                    continue
                if d["status"] != "ok":
                    lines.append(f"| {arch} | {shape} | {mesh} | ERROR | | | | {d.get('error','')[:60]} |")
                    continue
                meas = d["per_device_hbm"] / 2**30
                ana = d.get("analytic_hbm", {}).get("total")
                ana_s = f"{ana/2**30:.2f} GiB" if ana else "-"
                cnt = d["coll_breakdown"].get("count", {})
                cc = ", ".join(f"{k.replace('all-','a')}:{v}" for k, v in cnt.items() if v)
                lines.append(
                    f"| {arch} | {shape} | {mesh} | ok | {meas:.2f} GiB | {ana_s} |"
                    f" {d.get('compile_s',0):.0f}s | {cc or '—'} |")
    return "\n".join(lines)


def _fraction(d):
    """Recompute the roofline fraction, adding the decode memory ideal for
    artifacts written before model_bytes existed."""
    from repro.configs.base import SHAPES
    from repro.configs.registry import get_config
    from repro.launch import roofline as RL

    mb = d.get("model_bytes", 0.0)
    if not mb and SHAPES[d["shape"]].kind == "decode":
        mb = RL.ideal_decode_bytes(get_config(d["arch"]), SHAPES[d["shape"]])
    ideal = max(d["model_flops"] / (d["chips"] * RL.PEAK_FLOPS),
                mb / (d["chips"] * RL.HBM_BW))
    return ideal / max(d["t_compute"], d["t_memory"], d["t_collective"], 1e-12)


def roofline_table(archs):
    single = _load("single")
    lines = [
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck | MODEL_FLOPS | useful (6ND/HLO) | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in archs:
        for shape in SHAPE_ORDER:
            d = single.get((arch, shape))
            if d is None or d["status"] != "ok":
                continue
            lines.append(
                f"| {arch} | {shape} | {_fmt_t(d['t_compute'])} | {_fmt_t(d['t_memory'])} |"
                f" {_fmt_t(d['t_collective'])} | **{d['bottleneck']}** |"
                f" {d['model_flops']:.2e} | {d['useful_ratio']:.2f} |"
                f" {_fraction(d):.3f} | {HINTS[d['bottleneck']]} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=os.path.join(os.path.dirname(__file__), "..", "artifacts", "tables"))
    args = ap.parse_args()
    from repro.configs.registry import ARCH_NAMES
    os.makedirs(args.out, exist_ok=True)
    dt = dryrun_table(ARCH_NAMES)
    rt = roofline_table(ARCH_NAMES)
    with open(os.path.join(args.out, "dryrun_table.md"), "w") as f:
        f.write(dt + "\n")
    with open(os.path.join(args.out, "roofline_table.md"), "w") as f:
        f.write(rt + "\n")
    print(dt[:400], "...\n")
    print("wrote", args.out)


if __name__ == "__main__":
    main()
