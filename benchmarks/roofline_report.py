"""Roofline report: aggregates the dry-run artifacts into the §Roofline table."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import fmt_table, save_result

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_cells(mesh=None):
    cells = []
    for p in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(p) as f:
            rec = json.load(f)
        if mesh and rec.get("mesh") != mesh:
            continue
        cells.append(rec)
    return cells


def run(quick: bool = True, mesh="single"):
    cells = [c for c in load_cells(mesh) if not c.get("variant")]
    if not cells:
        print("\n== Roofline: no dry-run artifacts found (run `python -m repro.launch.dryrun --all`) ==")
        return {}
    rows = []
    for c in cells:
        if c.get("status") == "skipped":
            rows.append([c["arch"], c["shape"], "SKIP", "-", "-", "-", "-", "-", "-"])
            continue
        if c.get("status") != "ok":
            rows.append([c["arch"], c["shape"], "ERR", "-", "-", "-", "-", "-", "-"])
            continue
        if "bottleneck" not in c:  # slugger-summarize extra row (no LM terms)
            rows.append([
                c["arch"], c["shape"], "memory",
                f"{c.get('t_compute', 0)*1e3:.2f}", f"{c.get('t_memory', 0)*1e3:.2f}",
                f"{c.get('t_collective', 0)*1e3:.2f}", "-", "-",
                f"{c['per_device_hbm']/2**30:.1f}",
            ])
            continue
        rows.append([
            c["arch"], c["shape"], c["bottleneck"],
            f"{c['t_compute']*1e3:.2f}", f"{c['t_memory']*1e3:.2f}", f"{c['t_collective']*1e3:.2f}",
            f"{c['useful_ratio']:.2f}", f"{c['roofline_fraction']*100:.1f}%",
            f"{c['per_device_hbm']/2**30:.1f}",
        ])
    print(f"\n== Roofline ({mesh}-pod, ms per step; fraction = MODEL_FLOPS@peak / max-term) ==")
    print(fmt_table(rows, ["arch", "shape", "bound", "t_comp", "t_mem", "t_coll",
                           "useful", "roofline", "GiB/dev"]))
    save_result(f"roofline_{mesh}", {f"{c['arch']}__{c['shape']}": c for c in cells})
    return cells
