"""Table IV: pruning-substep ablation — relative size, max height, avg leaf
depth after substeps 0 (none), 1, 1+2, 1+2+3."""
from __future__ import annotations

from benchmarks.common import fmt_table, save_result
from repro.core import summarize
from repro.graphs import datasets


def run(quick: bool = True):
    names = ["PR", "FA", "DB", "CN"] if quick else datasets.names()
    T = 10 if quick else 20
    variants = [(), (1,), (1, 2), (1, 2, 3)]
    rows, payload = [], {}
    for name in names:
        g = datasets.load(name)
        rel, hts, dep = [], [], []
        for steps in variants:
            s = summarize(g, T=T, seed=0, prune_steps=steps)
            assert s.validate_lossless(g)
            st = s.stats(g)
            rel.append(st["relative_size"])
            hts.append(st["max_height"])
            dep.append(st["avg_leaf_depth"])
        rows.append([name] + [f"{r:.3f}" for r in rel] + [str(h) for h in hts] + [f"{d:.2f}" for d in dep])
        payload[name] = {"relative_size": rel, "max_height": hts, "avg_leaf_depth": dep}
    hdr = (["dataset"] + [f"size@{i}" for i in range(4)]
           + [f"maxh@{i}" for i in range(4)] + [f"depth@{i}" for i in range(4)])
    print("\n== Pruning ablation (Table IV): substeps 0/1/2/3 ==")
    print(fmt_table(rows, hdr))
    save_result("pruning", payload)
    return payload
