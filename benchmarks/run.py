"""Benchmark harness: one module per paper table/figure + the roofline report.

  PYTHONPATH=src python -m benchmarks.run            # quick (default)
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale settings
  PYTHONPATH=src python -m benchmarks.run --only compactness,iterations
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (compactness, composition, decompression, height,
                        iterations, merge_throughput, pipeline_breakdown,
                        pruning_bench, query_serving, roofline_report,
                        scalability, speed)

SUITES = {
    "compactness": compactness.run,     # Fig 5a / Fig 1a
    "speed": speed.run,                 # Fig 5b
    "merge": merge_throughput.run,      # batched-engine speedup (BENCH_merge)
    "pipeline": pipeline_breakdown.run, # stage-level IR speedups (BENCH_pipeline)
    "serving": query_serving.run,       # batched query qps (BENCH_serving_queries)
    "scalability": scalability.run,     # Fig 1b
    "partitioned": scalability.run_partitioned,  # engine partition sweep (BENCH_partitioned)
    "resident": scalability.run_resident,  # resident merge rounds (BENCH_resident)
    "iterations": iterations.run,       # Table III
    "pruning": pruning_bench.run,       # Table IV
    "height": height.run,               # Table V
    "composition": composition.run,     # Fig 6
    "decompression": decompression.run, # §VIII-B
    "roofline": roofline_report.run,    # framework §Roofline
}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated suite names")
    args = ap.parse_args(argv)
    only = args.only.split(",") if args.only else list(SUITES)
    t0 = time.perf_counter()
    for name in only:
        t1 = time.perf_counter()
        SUITES[name](quick=not args.full)
        print(f"   [{name} done in {time.perf_counter()-t1:.1f}s]")
    print(f"\nAll benchmarks done in {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
