"""Fig. 5(a) / Fig. 1(a): relative output size of SLUGGER vs flat baselines.

Paper claim validated: SLUGGER yields the most concise representation on
every dataset (up to 29.6% better than the best competitor, on Protein).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, fmt_table, save_result
from repro.core import baselines, summarize
from repro.graphs import datasets


def run(quick: bool = True, T: int = None, seeds=(0,)):
    T = T or (10 if quick else 20)
    names = datasets.names()[:6] if quick else datasets.names()
    rows, payload = [], {}
    for name in names:
        g = datasets.load(name)
        rel = {}
        times = {}
        for algo, fn in [
            ("slugger", lambda s: summarize(g, T=T, seed=s)),
            ("sweg", lambda s: baselines.sweg(g, T=T, seed=s)),
            ("randomized", lambda s: baselines.randomized(g, seed=s)),
            ("sags", lambda s: baselines.sags_like(g, seed=s)),
        ]:
            vals, ts = [], []
            for s in seeds:
                with Timer() as t:
                    summ = fn(s)
                assert summ.validate_lossless(g), (name, algo)
                vals.append(summ.relative_size(g))
                ts.append(t.dt)
            rel[algo] = float(np.mean(vals))
            times[algo] = float(np.mean(ts))
        best_comp = min(v for k, v in rel.items() if k != "slugger")
        gain = 100 * (1 - rel["slugger"] / best_comp)
        rows.append([name, g.n, g.m] + [f"{rel[a]:.3f}" for a in ("slugger", "sweg", "randomized", "sags")] + [f"{gain:+.1f}%"])
        payload[name] = {"n": g.n, "m": g.m, "relative_size": rel, "time_s": times, "gain_vs_best_pct": gain}
    table = fmt_table(rows, ["dataset", "n", "m", "slugger", "sweg", "randomized", "sags", "gain"])
    print("\n== Compactness (Fig 5a): relative size (|P+|+|P-|+|H|)/|E|, lower=better ==")
    print(table)
    save_result("compactness", payload)
    return payload
