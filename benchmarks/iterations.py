"""Table III: effect of the iteration count T on compression."""
from __future__ import annotations

from benchmarks.common import fmt_table, save_result
from repro.core import summarize
from repro.graphs import datasets


def run(quick: bool = True):
    Ts = [1, 5, 10, 20] if quick else [1, 5, 10, 20, 40, 80]
    names = ["PR", "FA", "DB", "EM"] if quick else datasets.names()
    rows, payload = [], {}
    for name in names:
        g = datasets.load(name)
        rels = []
        for T in Ts:
            s = summarize(g, T=T, seed=0)
            assert s.validate_lossless(g)
            rels.append(s.relative_size(g))
        rows.append([name] + [f"{r:.3f}" for r in rels])
        payload[name] = dict(zip(map(str, Ts), rels))
        # paper: monotone-ish decrease, converging
    print("\n== Iterations (Table III): relative size vs T ==")
    print(fmt_table(rows, ["dataset"] + [f"T={t}" for t in Ts]))
    save_result("iterations", payload)
    return payload
