"""Table V: hierarchy-height bound H_b sweep — deeper trees, smaller output."""
from __future__ import annotations

from benchmarks.common import fmt_table, save_result
from repro.core import summarize
from repro.graphs import datasets


def run(quick: bool = True):
    bounds = [2, 5, 10, None] if quick else [2, 5, 7, 10, None]
    names = ["PR", "FA", "CN"] if quick else datasets.names()
    T = 10 if quick else 20
    rows, payload = [], {}
    for name in names:
        g = datasets.load(name)
        rel, dep = [], []
        for hb in bounds:
            s = summarize(g, T=T, seed=0, height_bound=hb)
            assert s.validate_lossless(g)
            if hb is not None:
                assert all(h <= hb for h in s.tree_heights())
            st = s.stats(g)
            rel.append(st["relative_size"])
            dep.append(st["avg_leaf_depth"])
        rows.append([name] + [f"{d:.2f}" for d in dep] + [f"{r:.3f}" for r in rel])
        payload[name] = {"bounds": [str(b) for b in bounds], "avg_depth": dep, "relative_size": rel}
    labels = [str(b) if b else "∞" for b in bounds]
    print("\n== Height bound (Table V): avg leaf depth | relative size per H_b ==")
    print(fmt_table(rows, ["dataset"] + [f"d@{l}" for l in labels] + [f"size@{l}" for l in labels]))
    save_result("height", payload)
    return payload
