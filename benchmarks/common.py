"""Shared benchmark utilities: timing, result tables, artifact IO."""
from __future__ import annotations

import json
import os
import time

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")


def save_result(name: str, payload: dict):
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, f"{name}.json")
    tmp = path + ".part"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    os.replace(tmp, path)


def load_result(name: str):
    p = os.path.join(ARTIFACTS, f"{name}.json")
    if os.path.exists(p):
        with open(p) as f:
            return json.load(f)
    return None


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.dt = time.perf_counter() - self.t0


def load_real_graphs(names=("ca-GrQc", "ca-HepTh")):
    """Opt-in ``--real`` mode: fetch SNAP datasets via the cached,
    checksummed `datasets.load_remote`. Returns ``(graphs, notes)`` —
    ``graphs`` is a list of (name, Graph) that loaded, ``notes`` maps every
    requested name to "ok" or the skip reason. Offline hosts (or corrupt
    caches) SKIP with the actionable error message in the artifact JSON
    instead of failing the suite (ROADMAP "real-dataset benchmark wiring").
    """
    from repro.graphs import datasets

    graphs, notes = [], {}
    for name in names:
        try:
            g = datasets.load_remote(name)
        except datasets.DatasetFetchError as e:
            notes[name] = f"skipped: {e}"
            print(f"   [--real] {name}: SKIPPED ({e})")
        else:
            notes[name] = "ok"
            graphs.append((name, g))
    return graphs, notes


def fmt_table(rows: list, headers: list) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows else len(str(h))
              for i, h in enumerate(headers)]
    out = ["  ".join(str(h).ljust(w) for h, w in zip(headers, widths))]
    out.append("  ".join("-" * w for w in widths))
    for r in rows:
        out.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(out)
