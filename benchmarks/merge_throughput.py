"""Merge-phase throughput: seed per-group loop vs the batched engines.

Times ONLY the merging hot path (candidate generation + Algorithm-2 sweeps,
no emission/pruning) on a generator graph, reporting merges/sec and
groups/sec per engine plus the speedup over the ``loop`` baseline — and,
for the device engines, the host↔device traffic from the `core.transfer`
counter. Artifact: ``BENCH_merge.json`` — the perf trajectory the ROADMAP
tracks.

``--real`` additionally runs the suite on `datasets.load_remote` SNAP
graphs (cached, checksummed downloads); offline hosts skip them with the
reason recorded in the artifact.

  PYTHONPATH=src python -m benchmarks.merge_throughput [--quick] [--full]
                                                       [--real]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import fmt_table, load_real_graphs, save_result
from repro.core.merging import process_group, process_groups
from repro.core.minhash import candidate_groups
from repro.core.slugger import SluggerState
from repro.core.transfer import GLOBAL as TRANSFER
from repro.graphs import generators as GG

ENGINES = ("loop", "numpy", "batched", "resident")


def _merge_phase(g, backend: str, T: int, seed: int = 0, max_group: int = 500):
    state = SluggerState(g)
    rng = np.random.default_rng(seed)
    streams = np.random.SeedSequence(seed).spawn(max(T, 1))
    merges = groups_n = 0
    transfer0 = TRANSFER.snapshot()
    t0 = time.perf_counter()
    for t in range(1, T + 1):
        theta = 0.0 if t == T else 1.0 / (1 + t)
        groups = candidate_groups(g, state.root_of, state.alive,
                                  seed=streams[t - 1], max_group=max_group)
        groups_n += len(groups)
        if backend == "loop":
            for grp in groups:
                merges += process_group(state, grp, theta, rng)
        else:
            merges += process_groups(state, groups, theta, rng, backend=backend)
    dt = time.perf_counter() - t0
    return {
        "sec": dt,
        "merges": merges,
        "groups": groups_n,
        "merges_per_s": merges / dt,
        "groups_per_s": groups_n / dt,
        "roots_left": int(state.alive.size),
        "transfer": TRANSFER.delta_since(transfer0),
    }


def _bench_graphs(graphs, rows, payload):
    for name, g, T in graphs:
        res = {be: _merge_phase(g, be, T=T) for be in ENGINES}
        base = res["loop"]["sec"]
        for be in ENGINES:
            r = res[be]
            r["speedup_vs_loop"] = base / r["sec"]
            tr = r["transfer"]
            rows.append([
                name, g.m, be, f"{r['sec']:.2f}s", r["merges"],
                f"{r['merges_per_s']:.0f}", f"{r['groups_per_s']:.0f}",
                f"{r['speedup_vs_loop']:.2f}x",
                f"{tr['bytes_total']/1e6:.2f}MB",
            ])
        payload[name] = {"m": g.m, "T": T, "engines": res}


def run(quick: bool = True, real: bool = False):
    if quick:
        graphs = [("caveman-55k", GG.caveman(1000, 11, 0.03, seed=0), 5)]
    else:
        graphs = [
            ("caveman-55k", GG.caveman(1000, 11, 0.03, seed=0), 10),
            ("rmat-210k", GG.rmat(15, 8, seed=3), 10),
            ("ba-60k", GG.barabasi_albert(20000, 3, seed=1), 10),
        ]
    rows, payload = [], {}
    _bench_graphs(graphs, rows, payload)
    if real:
        real_graphs, notes = load_real_graphs()
        payload["real_datasets"] = notes
        _bench_graphs([(f"snap-{n}", g, 5) for n, g in real_graphs],
                      rows, payload)
    print("\n== Merge throughput: seed loop vs batched engines ==")
    print(fmt_table(rows, ["graph", "m", "engine", "time", "merges",
                           "merges/s", "groups/s", "speedup", "h2d+d2h"]))
    save_result("BENCH_merge", payload)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true", help="one small graph (default)")
    mode.add_argument("--full", action="store_true", help="paper-scale graph set")
    ap.add_argument("--real", action="store_true",
                    help="also run on load_remote SNAP graphs (skips "
                         "cleanly when offline)")
    args = ap.parse_args(argv)
    run(quick=not args.full, real=args.real)


if __name__ == "__main__":
    main()
