"""Stage-level wall time for the whole pipeline: merge vs emit vs prune vs
decompress, each stage's refactored path against its kept reference.

The merge phase was batched in PR 1 (BENCH_merge); this artifact tracks the
three post-merge stages that ISSUE 2 moved onto the flat Summary IR:

  emit       recursive per-root-pair DP  vs  batched level-synchronous DP
  prune      dict-of-set _Work           vs  array _IRWork
  decompress per-edge Python loop        vs  single-gather IR expansion
  neighbors  per-ancestor set walk       vs  difference-array sweep

Artifact: ``BENCH_pipeline.json`` with per-stage seconds, speedups, and the
combined emit+prune+decompress speedup future PRs regression-track.

  PYTHONPATH=src python -m benchmarks.pipeline_breakdown [--quick] [--full]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import fmt_table, save_result
from repro.core.merging import process_groups
from repro.core.minhash import candidate_groups
from repro.core.pruning import prune
from repro.core.slugger import SluggerState, _emit_encoding, _emit_encoding_reference
from repro.graphs import generators as GG


def _merge_phase(g, T: int, seed: int = 0):
    state = SluggerState(g)
    rng = np.random.default_rng(seed)
    streams = np.random.SeedSequence(seed).spawn(max(T, 1))
    t0 = time.perf_counter()
    for t in range(1, T + 1):
        theta = 0.0 if t == T else 1.0 / (1 + t)
        groups = candidate_groups(g, state.root_of, state.alive,
                                  seed=streams[t - 1], max_group=500)
        process_groups(state, groups, theta, rng, backend="numpy")
    return state, time.perf_counter() - t0


def _stage(fn, repeat: int = 1):
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


def run(quick: bool = True):
    if quick:
        graphs = [("caveman-55k", GG.caveman(1000, 11, 0.03, seed=0), 5, 200)]
    else:
        graphs = [
            ("caveman-55k", GG.caveman(1000, 11, 0.03, seed=0), 10, 500),
            ("ba-60k", GG.barabasi_albert(20000, 3, seed=1), 10, 500),
        ]
    rows, payload = [], {}
    for name, g, T, n_queries in graphs:
        state, t_merge = _merge_phase(g, T)
        s_ref, t_emit_ref = _stage(lambda: _emit_encoding_reference(state))
        s_new, t_emit_new = _stage(lambda: _emit_encoding(state, backend="numpy"))
        assert np.array_equal(s_ref.edges, s_new.edges), "emitters disagree"
        p_ref, t_prune_ref = _stage(lambda: prune(s_ref, impl="dict"))
        p_new, t_prune_new = _stage(lambda: prune(s_new, impl="ir"))
        assert p_ref.cost() == p_new.cost(), "pruners disagree"
        g_ref, t_dec_ref = _stage(p_new._decompress_reference)
        g_new, t_dec_new = _stage(p_new.decompress)
        assert g_new == g, "decompression is not lossless"
        rng = np.random.default_rng(0)
        qs = rng.integers(0, g.n, size=n_queries)
        p_new.neighbors(0)  # warm the IR + incidence caches
        _, t_nb_ref = _stage(lambda: [p_new._neighbors_reference(int(q)) for q in qs])
        _, t_nb_new = _stage(lambda: [p_new.neighbors(int(q)) for q in qs])
        ref_total = t_emit_ref + t_prune_ref + t_dec_ref
        new_total = t_emit_new + t_prune_new + t_dec_new
        stages = {
            "merge": {"sec": t_merge},
            "emit": {"ref_sec": t_emit_ref, "new_sec": t_emit_new,
                     "speedup": t_emit_ref / t_emit_new},
            "prune": {"ref_sec": t_prune_ref, "new_sec": t_prune_new,
                      "speedup": t_prune_ref / t_prune_new},
            "decompress": {"ref_sec": t_dec_ref, "new_sec": t_dec_new,
                           "speedup": t_dec_ref / t_dec_new},
            # per-query latency: the event sweep is O(deg) and flat in n,
            # the reference is O(n) — parity near n=10k, sweep wins beyond
            # (3.7x at n=220k); serving scale is what the rewrite targets.
            "neighbors": {"ref_sec": t_nb_ref, "new_sec": t_nb_new,
                          "speedup": t_nb_ref / t_nb_new,
                          "queries": int(n_queries),
                          "ref_us_per_query": t_nb_ref / n_queries * 1e6,
                          "new_us_per_query": t_nb_new / n_queries * 1e6},
        }
        payload[name] = {
            "m": g.m, "T": T, "stages": stages,
            "combined_ref_sec": ref_total, "combined_new_sec": new_total,
            "combined_speedup": ref_total / new_total,
            "cost": p_new.cost(),
        }
        for st in ("emit", "prune", "decompress", "neighbors"):
            d = stages[st]
            rows.append([name, st, f"{d['ref_sec']:.3f}s", f"{d['new_sec']:.3f}s",
                         f"{d['speedup']:.2f}x"])
        rows.append([name, "emit+prune+dec", f"{ref_total:.3f}s",
                     f"{new_total:.3f}s", f"{ref_total/new_total:.2f}x"])
    print("\n== Pipeline breakdown: reference vs Summary-IR stages ==")
    print(fmt_table(rows, ["graph", "stage", "reference", "IR", "speedup"]))
    save_result("BENCH_pipeline", payload)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true", help="one small graph (default)")
    mode.add_argument("--full", action="store_true", help="paper-scale graph set")
    args = ap.parse_args(argv)
    run(quick=not args.full)


if __name__ == "__main__":
    main()
