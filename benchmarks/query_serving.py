"""Serving throughput for queries ON the summary: batched engine vs the
per-call loop.

The serving regime (ROADMAP north star) is thousands of concurrent
``neighbors``/``edge_exists`` queries against a frozen summary. PR 2 made a
single `Summary.neighbors` call O(deg log deg + answer); this benchmark
measures what batching adds on top: the per-call loop pays Python dispatch,
chain climb, and an allocation per query, while `core/query_batch` answers
the whole batch through one flat gather + sweep on the packed artifact
(`summary_ir.PackedSummary`), per backend (numpy / jax / pallas).

Artifact: ``BENCH_serving_queries.json`` with queries/sec per engine and the
batched-over-loop speedup regression-tracked by the acceptance gate
(>= 5x at n=220k).

  PYTHONPATH=src python -m benchmarks.query_serving [--quick] [--full]
                                                    [--real]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import fmt_table, load_real_graphs, save_result
from repro.core.query_batch import (BACKENDS, edge_exists_batch,
                                    neighbors_batch, unpack_csr)
from repro.core.slugger import summarize
from repro.graphs.generators import SERVING_GRAPHS


def _best(fn, repeat: int = 3):
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best


def run(quick: bool = True, real: bool = False):
    graphs = [("caveman-55k", SERVING_GRAPHS["55k"]()),
              ("caveman-220k", SERVING_GRAPHS["220k"]())]
    n_queries = 2000 if quick else 20000
    backends = ("numpy", "jax") if quick else BACKENDS
    rows, payload = [], {}
    if real:  # opt-in SNAP datasets; offline hosts skip with a note
        real_graphs, notes = load_real_graphs()
        payload["real_datasets"] = notes
        graphs += [(f"snap-{n}", g) for n, g in real_graphs]
    for name, g in graphs:
        t0 = time.perf_counter()
        s = summarize(g, T=5, seed=0)
        ps = s.pack_for_serving()
        t_build = time.perf_counter() - t0
        rng = np.random.default_rng(0)
        vs = rng.integers(0, g.n, size=n_queries)
        us = rng.integers(0, g.n, size=n_queries)

        s.neighbors(0)  # warm IR + incidence caches for the per-call loop
        loop_ans, t_loop = _best(
            lambda: [s.neighbors(int(v)) for v in vs], repeat=1)
        ee_truth, t_loop_ee = _best(
            lambda: np.array([np.isin(w, s.neighbors(int(u)))
                              for u, w in zip(us, vs)]), repeat=1)

        engines = {"loop": {"nb_sec": t_loop, "nb_qps": n_queries / t_loop,
                            "ee_sec": t_loop_ee, "ee_qps": n_queries / t_loop_ee}}
        for bk in backends:
            neighbors_batch(ps, vs[:64], backend=bk)  # warm jit/kernel caches
            edge_exists_batch(ps, us[:64], vs[:64], backend=bk)
            (indptr, ids), t_nb = _best(
                lambda: neighbors_batch(ps, vs, backend=bk))
            got = unpack_csr(indptr, ids)
            for i in range(n_queries):  # answers must stay bit-identical
                assert np.array_equal(got[i], loop_ans[i]), (name, bk, i)
            ee, t_ee = _best(lambda: edge_exists_batch(ps, us, vs, backend=bk))
            assert np.array_equal(ee, ee_truth), (name, bk)
            engines[bk] = {
                "nb_sec": t_nb, "nb_qps": n_queries / t_nb,
                "nb_speedup": t_loop / t_nb,
                "ee_sec": t_ee, "ee_qps": n_queries / t_ee,
                "ee_speedup": t_loop_ee / t_ee,
            }
            rows.append([name, bk, f"{n_queries/t_nb:,.0f}",
                         f"{t_loop/t_nb:.1f}x", f"{n_queries/t_ee:,.0f}",
                         f"{t_loop_ee/t_ee:.1f}x"])
        rows.append([name, "loop", f"{n_queries/t_loop:,.0f}", "1.0x",
                     f"{n_queries/t_loop_ee:,.0f}", "1.0x"])
        payload[name] = {
            "n": g.n, "m": g.m, "queries": n_queries,
            "build_sec": t_build, "artifact_mb": ps.nbytes() / 1e6,
            "engines": engines,
        }
    print("\n== Summary-query serving: batched engines vs per-call loop ==")
    print(fmt_table(rows, ["graph", "engine", "neighbors q/s", "speedup",
                           "edge_exists q/s", "speedup"]))
    save_result("BENCH_serving_queries", payload)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    mode = ap.add_mutually_exclusive_group()
    mode.add_argument("--quick", action="store_true",
                      help="2k queries, numpy+jax backends (default)")
    mode.add_argument("--full", action="store_true",
                      help="20k queries, all backends")
    ap.add_argument("--real", action="store_true",
                    help="also serve load_remote SNAP graphs (skips "
                         "cleanly when offline)")
    args = ap.parse_args(argv)
    run(quick=not args.full, real=args.real)


if __name__ == "__main__":
    main()
