"""Fig. 5(b): running time of SLUGGER vs baselines (means over trials)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Timer, fmt_table, save_result
from repro.core import baselines, summarize
from repro.graphs import datasets


def run(quick: bool = True, trials: int = 1):
    T = 10 if quick else 20
    names = datasets.names()[:5] if quick else datasets.names()
    rows, payload = [], {}
    for name in names:
        g = datasets.load(name)
        times = {}
        for algo, fn in [
            ("slugger", lambda s: summarize(g, T=T, seed=s)),
            ("sweg", lambda s: baselines.sweg(g, T=T, seed=s)),
            ("sags", lambda s: baselines.sags_like(g, seed=s)),
        ]:
            ts = []
            for s in range(trials):
                with Timer() as t:
                    fn(s)
                ts.append(t.dt)
            times[algo] = (float(np.mean(ts)), float(np.std(ts)))
        rows.append([name, g.m] + [f"{times[a][0]:.2f}±{times[a][1]:.2f}s" for a in ("slugger", "sweg", "sags")])
        payload[name] = {"m": g.m, "times": {k: v[0] for k, v in times.items()}}
    print("\n== Speed (Fig 5b): wall time ==")
    print(fmt_table(rows, ["dataset", "m", "slugger", "sweg", "sags"]))
    save_result("speed", payload)
    return payload
